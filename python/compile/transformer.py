"""Byte-level decoder-only transformer LM (flat-parameter convention).

The end-to-end driver (examples/e2e_transformer.rs) trains this model with
the full SPARQ-SGD stack over a simulated ring: PJRT grad artifacts + the
event trigger + SignTopK compression + gossip consensus. Size is a config
knob (DESIGN.md §Substitutions explains the scale-down from the
system-prompt's 100M reference point for this 1-CPU testbed).

Architecture: learned token+position embeddings, `n_layers` pre-LN blocks
(causal MHA + GELU MLP), final LN, untied LM head. Next-token
cross-entropy over a [B, S+1] token window.
"""

from __future__ import annotations

from dataclasses import dataclass
from typing import List, Tuple

import jax
import jax.numpy as jnp

from .model import flatten, shapes_size, unflatten


@dataclass(frozen=True)
class TransformerConfig:
    vocab: int = 256
    seq: int = 64
    d_model: int = 128
    n_layers: int = 4
    n_heads: int = 4

    @property
    def d_ff(self) -> int:
        return 4 * self.d_model

    def shapes(self) -> List[Tuple[int, ...]]:
        c = self
        shapes: List[Tuple[int, ...]] = [
            (c.vocab, c.d_model),       # token embedding
            (c.seq, c.d_model),         # positional embedding
        ]
        for _ in range(c.n_layers):
            shapes += [
                (c.d_model,), (c.d_model,),          # ln1 scale, bias
                (c.d_model, 3 * c.d_model),          # qkv
                (3 * c.d_model,),
                (c.d_model, c.d_model),              # attn out
                (c.d_model,),
                (c.d_model,), (c.d_model,),          # ln2 scale, bias
                (c.d_model, c.d_ff), (c.d_ff,),      # mlp in
                (c.d_ff, c.d_model), (c.d_model,),   # mlp out
            ]
        shapes += [(c.d_model,), (c.d_model,)]       # final ln
        shapes += [(c.d_model, c.vocab), (c.vocab,)]  # lm head
        return shapes

    @property
    def dim(self) -> int:
        return shapes_size(self.shapes())


def _layernorm(x, scale, bias, eps=1e-5):
    mu = jnp.mean(x, axis=-1, keepdims=True)
    var = jnp.var(x, axis=-1, keepdims=True)
    return (x - mu) * jax.lax.rsqrt(var + eps) * scale + bias


def _block(x, params, n_heads):
    (ln1s, ln1b, wqkv, bqkv, wo, bo,
     ln2s, ln2b, w1, b1, w2, b2) = params
    B, S, D = x.shape
    hd = D // n_heads

    h = _layernorm(x, ln1s, ln1b)
    qkv = h @ wqkv + bqkv                                # [B,S,3D]
    q, k, v = jnp.split(qkv, 3, axis=-1)

    def heads(t):
        return t.reshape(B, S, n_heads, hd).transpose(0, 2, 1, 3)

    q, k, v = heads(q), heads(k), heads(v)               # [B,H,S,hd]
    att = (q @ k.transpose(0, 1, 3, 2)) / jnp.sqrt(float(hd))
    causal = jnp.tril(jnp.ones((S, S), jnp.float32))
    att = jnp.where(causal[None, None] > 0, att, -1e30)
    att = jax.nn.softmax(att, axis=-1)
    out = (att @ v).transpose(0, 2, 1, 3).reshape(B, S, D)
    x = x + out @ wo + bo

    h = _layernorm(x, ln2s, ln2b)
    h = jax.nn.gelu(h @ w1 + b1)
    return x + h @ w2 + b2


def lm_loss(flat: jax.Array, tokens: jax.Array, cfg: TransformerConfig) -> jax.Array:
    """tokens: [B, S+1] int32; next-token mean cross-entropy."""
    params = unflatten(flat, cfg.shapes())
    tok_emb, pos_emb = params[0], params[1]
    per_block = 12
    x_tok = tokens[:, :-1]
    y_tok = tokens[:, 1:]
    x = tok_emb[x_tok] + pos_emb[None, :cfg.seq]
    off = 2
    for _ in range(cfg.n_layers):
        x = _block(x, params[off:off + per_block], cfg.n_heads)
        off += per_block
    x = _layernorm(x, params[off], params[off + 1])
    logits = x @ params[off + 2] + params[off + 3]
    logz = jax.nn.logsumexp(logits, axis=-1)
    gold = jnp.take_along_axis(logits, y_tok[..., None], axis=-1)[..., 0]
    return jnp.mean(logz - gold)


def lm_grad(flat: jax.Array, tokens: jax.Array, cfg: TransformerConfig):
    loss, g = jax.value_and_grad(lm_loss)(flat, tokens, cfg)
    return loss, g


def _ln_scale_indices(cfg: TransformerConfig) -> set:
    """Indices into cfg.shapes() that are LayerNorm scale vectors."""
    per_block, base = 12, 2
    idx = set()
    for layer in range(cfg.n_layers):
        idx.add(base + layer * per_block + 0)   # ln1 scale
        idx.add(base + layer * per_block + 6)   # ln2 scale
    idx.add(base + cfg.n_layers * per_block)    # final ln scale
    return idx


def init_flat(cfg: TransformerConfig, key: jax.Array) -> jax.Array:
    """Gaussian(0.02) matrices, zero biases, unit LN scales."""
    ln_scales = _ln_scale_indices(cfg)
    parts = []
    for i, s in enumerate(cfg.shapes()):
        key, sub = jax.random.split(key)
        if len(s) == 1:
            fill = 1.0 if i in ln_scales else 0.0
            parts.append(jnp.full(s, fill, jnp.float32))
        else:
            parts.append(0.02 * jax.random.normal(sub, s, jnp.float32))
    return flatten(parts)
