"""Fused heavy-ball momentum + SGD parameter update as a Pallas kernel.

Algorithm 1 line 4 is a plain SGD step; Section 5.2 runs the non-convex
experiments "with momentum with a factor of 0.9" (the paper's Conclusion
lists momentum analysis as future work — the implementation applies it to
the local step exactly as the experiments do). Fusing

    m' = mu * m + g
    x' = x  - eta * m'

into one kernel reads each of (x, g, m) once from HBM and writes (x', m')
once — the minimal 3-read/2-write traffic for this update, vs 4/3 for the
unfused pair. Blocks of 512 f32 lanes; index masking is unnecessary because
padding lanes just compute garbage that the wrapper slices off.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

BLOCK = 512


def _sgd_kernel(x_ref, g_ref, m_ref, eta_ref, mu_ref, xo_ref, mo_ref):
    m_new = mu_ref[0] * m_ref[...] + g_ref[...]
    mo_ref[...] = m_new
    xo_ref[...] = x_ref[...] - eta_ref[0] * m_new


def sgd_momentum_step(x: jax.Array, g: jax.Array, m: jax.Array,
                      eta: jax.Array, mu: jax.Array):
    """Returns (x', m') = (x - eta*(mu*m + g), mu*m + g)."""
    d = x.shape[0]
    rem = (-d) % BLOCK
    if rem:
        x = jnp.pad(x, (0, rem))
        g = jnp.pad(g, (0, rem))
        m = jnp.pad(m, (0, rem))
    dp = x.shape[0]
    eta = jnp.asarray(eta, jnp.float32).reshape((1,))
    mu = jnp.asarray(mu, jnp.float32).reshape((1,))
    xo, mo = pl.pallas_call(
        _sgd_kernel,
        grid=(dp // BLOCK,),
        in_specs=[
            pl.BlockSpec((BLOCK,), lambda i: (i,)),
            pl.BlockSpec((BLOCK,), lambda i: (i,)),
            pl.BlockSpec((BLOCK,), lambda i: (i,)),
            pl.BlockSpec((1,), lambda i: (0,)),
            pl.BlockSpec((1,), lambda i: (0,)),
        ],
        out_specs=[
            pl.BlockSpec((BLOCK,), lambda i: (i,)),
            pl.BlockSpec((BLOCK,), lambda i: (i,)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((dp,), jnp.float32),
            jax.ShapeDtypeStruct((dp,), jnp.float32),
        ],
        interpret=True,
    )(x, g, m, eta, mu)
    return xo[:d], mo[:d]
