"""Pallas kernels for the SignTopK composed compression operator.

SignTopK (paper Section 2, operator (v), from [BDKD19]) is the compression
used in all of the paper's experiments: keep the top-k coordinates by
magnitude, transmit only their signs plus one shared ℓ1 scale.

The hot-spot is split into two data-parallel kernels over 1-D VMEM blocks
of the parameter vector (the threshold tau itself is a tiny `lax.top_k` in
the surrounding L2 graph — see ``compile.steps``):

* :func:`l1_and_count_masked` — block-reduction producing per-block partial
  (sum |x_i|, count) over the selected set {i : |x_i| >= tau}.
* :func:`masked_sign_scale` — elementwise emission
  ``q_i = scale * sign(x_i) * [|x_i| >= tau]``.

Both kernels mask by global index so callers can pad the vector to a block
multiple without perturbing the reduction (exact zeros in the padding would
otherwise be "selected" whenever tau == 0).

TPU mapping (DESIGN.md §Hardware-Adaptation): blocks of 512 f32 lanes keep
each grid step's VMEM working set at 2 KiB/input — far under the ~16 MiB
VMEM budget, allowing the Mosaic pipeline to double-buffer HBM↔VMEM copies
behind the VPU elementwise work. interpret=True everywhere: CPU PJRT cannot
execute Mosaic custom-calls.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

BLOCK = 512


def _pad_to_block(x: jax.Array) -> jax.Array:
    d = x.shape[0]
    rem = (-d) % BLOCK
    if rem:
        x = jnp.pad(x, (0, rem))
    return x


def _l1_count_kernel(d_valid: int, x_ref, tau_ref, l1_ref, cnt_ref):
    pid = pl.program_id(0)
    x = x_ref[...]
    idx = jax.lax.broadcasted_iota(jnp.int32, x.shape, 0) + pid * BLOCK
    absx = jnp.abs(x)
    sel = (absx >= tau_ref[0]) & (idx < d_valid)
    l1_ref[0] = jnp.sum(jnp.where(sel, absx, 0.0))
    cnt_ref[0] = jnp.sum(sel.astype(jnp.float32))


def l1_and_count_masked(x: jax.Array, tau: jax.Array):
    """Per-block partial (l1, count) reduction, summed to scalars.

    Matches ``ref.l1_and_count_masked`` exactly (fp32 summation order is
    block-partials-then-total, which is associativity-safe at test
    tolerances).
    """
    d = x.shape[0]
    xp = _pad_to_block(x)
    nblocks = xp.shape[0] // BLOCK
    tau = jnp.asarray(tau, jnp.float32).reshape((1,))
    l1p, cntp = pl.pallas_call(
        functools.partial(_l1_count_kernel, d),
        grid=(nblocks,),
        in_specs=[
            pl.BlockSpec((BLOCK,), lambda i: (i,)),
            pl.BlockSpec((1,), lambda i: (0,)),
        ],
        out_specs=[
            pl.BlockSpec((1,), lambda i: (i,)),
            pl.BlockSpec((1,), lambda i: (i,)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((nblocks,), jnp.float32),
            jax.ShapeDtypeStruct((nblocks,), jnp.float32),
        ],
        interpret=True,
    )(xp, tau)
    return jnp.sum(l1p), jnp.sum(cntp)


def _mss_kernel(d_valid: int, x_ref, tau_ref, scale_ref, o_ref):
    pid = pl.program_id(0)
    x = x_ref[...]
    idx = jax.lax.broadcasted_iota(jnp.int32, x.shape, 0) + pid * BLOCK
    sel = (jnp.abs(x) >= tau_ref[0]) & (idx < d_valid)
    o_ref[...] = jnp.where(sel, scale_ref[0] * jnp.sign(x), 0.0)


def masked_sign_scale(x: jax.Array, tau: jax.Array, scale: jax.Array) -> jax.Array:
    """Elementwise q = scale * sign(x) on the selected set, 0 elsewhere."""
    d = x.shape[0]
    xp = _pad_to_block(x)
    nblocks = xp.shape[0] // BLOCK
    tau = jnp.asarray(tau, jnp.float32).reshape((1,))
    scale = jnp.asarray(scale, jnp.float32).reshape((1,))
    out = pl.pallas_call(
        functools.partial(_mss_kernel, d),
        grid=(nblocks,),
        in_specs=[
            pl.BlockSpec((BLOCK,), lambda i: (i,)),
            pl.BlockSpec((1,), lambda i: (0,)),
            pl.BlockSpec((1,), lambda i: (0,)),
        ],
        out_specs=pl.BlockSpec((BLOCK,), lambda i: (i,)),
        out_shape=jax.ShapeDtypeStruct(xp.shape, jnp.float32),
        interpret=True,
    )(xp, tau, scale)
    return out[:d]
