"""Pallas kernel for the consensus (gossip) step — Algorithm 1, line 15.

Matrix form (Appendix A.3, transposed to row-major node layout):

    X' = X + gamma * (W @ Xhat - Xhat),   X, Xhat in R^{n x d}, W in R^{n x n}

The kernel tiles the parameter axis: grid step j owns the (n, BLOCK_D)
column panel of X/Xhat and multiplies the full (n, n) mixing matrix against
it. n is the node count (8–64 in the paper's experiments), so W lives in
VMEM for the whole launch while X̂ panels stream through.

TPU mapping: each grid step is an (n×n)@(n×BLOCK_D) matmul — with
BLOCK_D=128 this is exactly an MXU systolic pass per 128-wide panel plus a
VPU AXPY; VMEM per step is n*(3*BLOCK_D + n) f32 (~100 KiB at n=64), so
double-buffering has ample headroom. interpret=True for CPU validation.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

BLOCK_D = 128


def _gossip_kernel(x_ref, xhat_ref, w_ref, gamma_ref, o_ref):
    x = x_ref[...]
    xhat = xhat_ref[...]
    w = w_ref[...]
    mixed = jnp.dot(w, xhat, preferred_element_type=jnp.float32)
    o_ref[...] = x + gamma_ref[0] * (mixed - xhat)


def gossip_step(x: jax.Array, xhat: jax.Array, w: jax.Array,
                gamma: jax.Array) -> jax.Array:
    """X + gamma (W Xhat - Xhat) with (n, d) row-major node layout."""
    n, d = x.shape
    rem = (-d) % BLOCK_D
    if rem:
        x = jnp.pad(x, ((0, 0), (0, rem)))
        xhat = jnp.pad(xhat, ((0, 0), (0, rem)))
    dp = x.shape[1]
    gamma = jnp.asarray(gamma, jnp.float32).reshape((1,))
    out = pl.pallas_call(
        _gossip_kernel,
        grid=(dp // BLOCK_D,),
        in_specs=[
            pl.BlockSpec((n, BLOCK_D), lambda j: (0, j)),
            pl.BlockSpec((n, BLOCK_D), lambda j: (0, j)),
            pl.BlockSpec((n, n), lambda j: (0, 0)),
            pl.BlockSpec((1,), lambda j: (0,)),
        ],
        out_specs=pl.BlockSpec((n, BLOCK_D), lambda j: (0, j)),
        out_shape=jax.ShapeDtypeStruct((n, dp), jnp.float32),
        interpret=True,
    )(x, xhat, w, gamma)
    return out[:, :d]
