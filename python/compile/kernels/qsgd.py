"""Pallas kernel for the QSGD stochastic quantizer Q_s ([AGL+17]).

Section 2 lists Q_s (compression parameter omega = 1 - beta_{d,s}) and the
composed Q_s(Top_k) operator; the Rust L3 mirrors this for bit accounting.
Randomness is *external*: the caller supplies u ~ U[0,1)^d (from
jax.random in L2, from the deterministic xoshiro RNG in L3's Rust twin) so
the kernel itself is a pure function and oracle comparison is exact.

    q_i = ||x||_2 / s * sign(x_i) * floor(s |x_i| / ||x||_2 + u_i)

The ||x||_2 reduction happens in the surrounding L2 graph (one rsqrt-sum,
negligible next to the elementwise pass); the kernel receives it as a
scalar, keeping every grid step embarrassingly parallel.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

BLOCK = 512


def _qsgd_kernel(s: int, x_ref, u_ref, norm_ref, o_ref):
    x = x_ref[...]
    norm = norm_ref[0]
    safe = jnp.where(norm > 0, norm, 1.0)
    level = jnp.floor(s * jnp.abs(x) / safe + u_ref[...])
    q = safe / s * jnp.sign(x) * level
    o_ref[...] = jnp.where(norm > 0, q, 0.0)


def qsgd(x: jax.Array, u: jax.Array, s: int) -> jax.Array:
    """Stochastic s-level quantization of x with external uniforms u."""
    d = x.shape[0]
    rem = (-d) % BLOCK
    if rem:
        x = jnp.pad(x, (0, rem))
        u = jnp.pad(u, (0, rem))
    dp = x.shape[0]
    norm = jnp.linalg.norm(x).reshape((1,))
    out = pl.pallas_call(
        functools.partial(_qsgd_kernel, s),
        grid=(dp // BLOCK,),
        in_specs=[
            pl.BlockSpec((BLOCK,), lambda i: (i,)),
            pl.BlockSpec((BLOCK,), lambda i: (i,)),
            pl.BlockSpec((1,), lambda i: (0,)),
        ],
        out_specs=pl.BlockSpec((BLOCK,), lambda i: (i,)),
        out_shape=jax.ShapeDtypeStruct((dp,), jnp.float32),
        interpret=True,
    )(x, u, norm)
    return out[:d]
