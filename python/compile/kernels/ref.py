"""Pure-jnp reference oracles for every Pallas kernel.

These are the correctness ground truth: pytest (and hypothesis sweeps)
assert that each interpret-mode Pallas kernel in this package matches its
oracle to float32 tolerance. The Rust L3 implementations of the same
operators are cross-checked against the AOT artifacts built from these
graphs (rust/tests/runtime_integration.rs).

Semantics notes
---------------
* ``sign_topk`` uses *threshold* semantics: select every coordinate with
  ``|x_i| >= tau`` where ``tau`` is the k-th largest absolute value, then
  emit ``scale * sign(x_i)`` on the selected set with
  ``scale = l1(selected) / count(selected)``. With distinct magnitudes this
  is exactly the paper's SignTopK composed operator ((v) in Section 2,
  [BDKD19]); with ties it selects the whole tie class, which keeps the
  compression contract (Definition 1) intact and gives the kernel a
  deterministic, order-independent spec.
* ``qsgd`` is the stochastic quantizer Q_s of [AGL+17] with external
  uniform randomness ``u`` (supplied by the caller so that kernel and
  oracle see identical bits).
"""

from __future__ import annotations

import jax
import jax.numpy as jnp


# ----------------------------------------------------------------------
# SignTopK building blocks
# ----------------------------------------------------------------------

def topk_threshold(x: jax.Array, k: int) -> jax.Array:
    """tau = k-th largest |x_i| (scalar, f32).

    Implemented with a full sort rather than ``lax.top_k``: jax ≥ 0.8
    lowers top_k to an HLO ``topk(..., largest=true)`` attribute that the
    xla_extension 0.5.1 text parser (behind the Rust `xla` crate) rejects,
    while ``sort`` round-trips cleanly. d is ≤ a few hundred thousand and
    this runs once per compression, so the O(d log d) cost is immaterial.
    """
    d = x.shape[-1]
    absx = jnp.sort(jnp.abs(x))
    return absx[d - k]


def l1_and_count_masked(x: jax.Array, tau: jax.Array):
    """(sum of |x_i| over selected, number selected) for |x_i| >= tau.

    A vector with tau == 0 selects everything (including exact zeros),
    matching the kernel's index-masked semantics.
    """
    absx = jnp.abs(x)
    mask = absx >= tau
    l1 = jnp.sum(jnp.where(mask, absx, 0.0))
    cnt = jnp.sum(mask.astype(jnp.float32))
    return l1, cnt


def masked_sign_scale(x: jax.Array, tau: jax.Array, scale: jax.Array) -> jax.Array:
    """q_i = scale * sign(x_i) * [|x_i| >= tau]."""
    absx = jnp.abs(x)
    mask = absx >= tau
    return jnp.where(mask, scale * jnp.sign(x), 0.0)


def sign_topk(x: jax.Array, k: int) -> jax.Array:
    """Full SignTopK composed operator (threshold semantics)."""
    tau = topk_threshold(x, k)
    l1, cnt = l1_and_count_masked(x, tau)
    scale = jnp.where(cnt > 0, l1 / jnp.maximum(cnt, 1.0), 0.0)
    return masked_sign_scale(x, tau, scale)


# ----------------------------------------------------------------------
# Gossip / consensus step (Algorithm 1 line 15; matrix form X + γ X̂(W−I))
# ----------------------------------------------------------------------

def gossip_step(x: jax.Array, xhat: jax.Array, w: jax.Array,
                gamma: jax.Array) -> jax.Array:
    """X' = X + gamma * (W @ Xhat - Xhat).

    Row-major layout: ``x``/``xhat`` are (n, d) with one node per row and
    ``w`` is the (n, n) doubly-stochastic mixing matrix; this is the
    transpose of the paper's column-layout X + γ X̂ (W − I) (W symmetric).
    """
    return x + gamma * (w @ xhat - xhat)


# ----------------------------------------------------------------------
# Fused SGD + heavy-ball momentum update
# ----------------------------------------------------------------------

def sgd_momentum_step(x: jax.Array, g: jax.Array, m: jax.Array,
                      eta: jax.Array, mu: jax.Array):
    """m' = mu*m + g ; x' = x - eta*m'. Returns (x', m')."""
    m_new = mu * m + g
    return x - eta * m_new, m_new


# ----------------------------------------------------------------------
# QSGD stochastic quantizer (Q_s of [AGL+17])
# ----------------------------------------------------------------------

def qsgd(x: jax.Array, u: jax.Array, s: int) -> jax.Array:
    """Stochastically quantize x to s levels of |x|/||x||_2.

    q_i = ||x||_2 / s * sign(x_i) * floor(s*|x_i|/||x||_2 + u_i),
    u_i ~ U[0,1). For x == 0 returns 0.
    """
    norm = jnp.linalg.norm(x)
    safe = jnp.where(norm > 0, norm, 1.0)
    level = jnp.floor(s * jnp.abs(x) / safe + u)
    q = safe / s * jnp.sign(x) * level
    return jnp.where(norm > 0, q, jnp.zeros_like(x))
