"""AOT export: lower every L2 entry point to HLO *text* artifacts.

Run once at build time (`make artifacts`); the Rust coordinator loads the
artifacts through PJRT and Python never appears on the request path.

Interchange format is HLO text, NOT a serialized HloModuleProto: jax >= 0.5
emits protos with 64-bit instruction ids which xla_extension 0.5.1 (the
version behind the `xla` crate) rejects; the text parser reassigns ids and
round-trips cleanly (see /opt/xla-example/README.md).

Every artifact is listed in artifacts/manifest.json with its input/output
signature so the Rust runtime can validate shapes at load time.
"""

from __future__ import annotations

import argparse
import json
import os
from typing import List

import jax
import jax.numpy as jnp
from jax._src.lib import xla_client as xc

from . import model, steps
from .transformer import TransformerConfig, lm_grad, lm_loss

# Fixed AOT shapes (per-experiment configs; DESIGN.md per-experiment index).
LOGREG_TRAIN_B = 5      # paper Section 5.1: mini-batch 5 per node
LOGREG_EVAL_B = 256
MLP_TRAIN_B = 32        # scaled from the paper's 128 for the 1-CPU testbed
MLP_EVAL_B = 256
LM_B, LM_SEQ = 8, 64

# (d, k) pairs for the compression/step artifacts exercised from Rust.
STEP_DIMS = [(4096, 409), (model.LOGREG_DIM, 10)]
GOSSIP_SHAPES = [(8, 4096), (60, model.LOGREG_DIM)]


def to_hlo_text(lowered) -> str:
    mlir_mod = lowered.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=True
    )
    return comp.as_hlo_text()


def _sig(avals) -> List[dict]:
    out = []
    for name, a in avals:
        out.append({"name": name, "dtype": str(a.dtype), "shape": list(a.shape)})
    return out


class Exporter:
    def __init__(self, out_dir: str):
        self.out_dir = out_dir
        self.manifest = {"format": "hlo-text", "artifacts": {}}
        os.makedirs(out_dir, exist_ok=True)

    def export(self, name: str, fn, inputs, outputs, meta=None):
        """inputs/outputs: list of (name, ShapeDtypeStruct)."""
        specs = [a for _, a in inputs]
        lowered = jax.jit(fn).lower(*specs)
        text = to_hlo_text(lowered)
        fname = f"{name}.hlo.txt"
        with open(os.path.join(self.out_dir, fname), "w") as f:
            f.write(text)
        self.manifest["artifacts"][name] = {
            "file": fname,
            "inputs": _sig(inputs),
            "outputs": _sig(outputs),
            "meta": meta or {},
        }
        print(f"  {name}: {len(text)} chars")

    def finish(self):
        with open(os.path.join(self.out_dir, "manifest.json"), "w") as f:
            json.dump(self.manifest, f, indent=2, sort_keys=True)
        print(f"manifest: {len(self.manifest['artifacts'])} artifacts -> "
              f"{self.out_dir}/manifest.json")


def f32(*shape):
    return jax.ShapeDtypeStruct(shape, jnp.float32)


def i32(*shape):
    return jax.ShapeDtypeStruct(shape, jnp.int32)


def export_models(ex: Exporter):
    d = model.LOGREG_DIM
    ex.export(
        "logreg_grad", model.logreg_grad,
        [("params", f32(d)), ("x", f32(LOGREG_TRAIN_B, model.LOGREG_IN)),
         ("y", i32(LOGREG_TRAIN_B))],
        [("loss", f32()), ("grad", f32(d))],
        meta={"dim": d, "batch": LOGREG_TRAIN_B, "model": "logreg"},
    )
    ex.export(
        "logreg_eval", model.logreg_eval,
        [("params", f32(d)), ("x", f32(LOGREG_EVAL_B, model.LOGREG_IN)),
         ("y", i32(LOGREG_EVAL_B))],
        [("loss", f32()), ("ncorrect", f32())],
        meta={"dim": d, "batch": LOGREG_EVAL_B, "model": "logreg"},
    )
    d = model.MLP_DIM
    ex.export(
        "mlp_grad", model.mlp_grad,
        [("params", f32(d)), ("x", f32(MLP_TRAIN_B, model.MLP_IN)),
         ("y", i32(MLP_TRAIN_B))],
        [("loss", f32()), ("grad", f32(d))],
        meta={"dim": d, "batch": MLP_TRAIN_B, "model": "mlp"},
    )
    ex.export(
        "mlp_eval", model.mlp_eval,
        [("params", f32(d)), ("x", f32(MLP_EVAL_B, model.MLP_IN)),
         ("y", i32(MLP_EVAL_B))],
        [("loss", f32()), ("ncorrect", f32())],
        meta={"dim": d, "batch": MLP_EVAL_B, "model": "mlp"},
    )


def export_transformer(ex: Exporter, cfg: TransformerConfig):
    d = cfg.dim
    ex.export(
        "lm_grad", lambda p, t: lm_grad(p, t, cfg),
        [("params", f32(d)), ("tokens", i32(LM_B, LM_SEQ + 1))],
        [("loss", f32()), ("grad", f32(d))],
        meta={"dim": d, "batch": LM_B, "seq": LM_SEQ, "model": "transformer",
              "d_model": cfg.d_model, "n_layers": cfg.n_layers,
              "n_heads": cfg.n_heads, "vocab": cfg.vocab},
    )
    ex.export(
        "lm_loss", lambda p, t: (lm_loss(p, t, cfg),),
        [("params", f32(d)), ("tokens", i32(LM_B, LM_SEQ + 1))],
        [("loss", f32())],
        meta={"dim": d, "model": "transformer"},
    )


def export_steps(ex: Exporter):
    """SPARQ round building blocks — these HLO modules contain the lowered
    Pallas kernels (interpret=True unrolls them into plain HLO ops)."""
    for d, k in STEP_DIMS:
        ex.export(
            f"compress_sign_topk_d{d}_k{k}",
            lambda x, _k=k: (steps.compress_sign_topk(x, _k),),
            [("x", f32(d))], [("q", f32(d))],
            meta={"dim": d, "k": k, "op": "sign_topk"},
        )
        ex.export(
            f"sgd_momentum_d{d}",
            steps.sgd_momentum_step,
            [("x", f32(d)), ("g", f32(d)), ("m", f32(d)),
             ("eta", f32()), ("mu", f32())],
            [("x_new", f32(d)), ("m_new", f32(d))],
            meta={"dim": d, "op": "sgd_momentum"},
        )
    d, s = 4096, 16
    ex.export(
        f"qsgd_d{d}_s{s}",
        lambda x, u: (steps.qsgd_compress(x, u, s),),
        [("x", f32(d)), ("u", f32(d))], [("q", f32(d))],
        meta={"dim": d, "s": s, "op": "qsgd"},
    )
    ex.export(
        f"trigger_check_d{d}",
        lambda xh, xhat, c, e: (steps.trigger_check(xh, xhat, c, e),),
        [("x_half", f32(d)), ("xhat", f32(d)), ("c_t", f32()), ("eta_t", f32())],
        [("fired", jax.ShapeDtypeStruct((), jnp.bool_))],
        meta={"dim": d, "op": "trigger"},
    )
    for n, d in GOSSIP_SHAPES:
        ex.export(
            f"gossip_n{n}_d{d}",
            lambda x, xh, w, g: (steps.gossip_step(x, xh, w, g),),
            [("x", f32(n, d)), ("xhat", f32(n, d)), ("w", f32(n, n)),
             ("gamma", f32())],
            [("x_new", f32(n, d))],
            meta={"n": n, "dim": d, "op": "gossip"},
        )


def main():
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--out-dir", default="../artifacts")
    ap.add_argument("--skip-transformer", action="store_true")
    ap.add_argument("--d-model", type=int, default=128)
    ap.add_argument("--n-layers", type=int, default=4)
    ap.add_argument("--n-heads", type=int, default=4)
    args = ap.parse_args()

    ex = Exporter(args.out_dir)
    print("exporting model artifacts...")
    export_models(ex)
    print("exporting step/kernel artifacts...")
    export_steps(ex)
    if not args.skip_transformer:
        print("exporting transformer artifacts...")
        cfg = TransformerConfig(d_model=args.d_model, n_layers=args.n_layers,
                                n_heads=args.n_heads, seq=LM_SEQ)
        export_transformer(ex, cfg)
    ex.finish()


if __name__ == "__main__":
    main()
