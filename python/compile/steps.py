"""L2 SPARQ-SGD building-block graphs. Each function here is a jittable
JAX computation that *calls the L1 Pallas kernels*, so the kernels lower
into the same HLO module when `aot.py` exports these entry points.

These are the pieces of Algorithm 1 that run on every node each round:

* :func:`compress_sign_topk` — line 8, q = C(x - x̂) with the SignTopK
  composed operator used throughout Section 5.
* :func:`gossip_step` — line 15 consensus update.
* :func:`sgd_momentum_step` — line 4 local step (+ Section 5.2 momentum).
* :func:`qsgd_compress` — alternative quantizer for ablations.
* :func:`trigger_check` — line 7, ||x^{t+1/2} - x̂||^2 > c_t eta_t^2.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

from .kernels import gossip as k_gossip
from .kernels import qsgd as k_qsgd
from .kernels import sgd_fused as k_sgd
from .kernels import sign_topk as k_st
from .kernels import ref


def compress_sign_topk(x: jax.Array, k: int) -> jax.Array:
    """SignTopK composed operator (threshold semantics; see kernels.ref)."""
    tau = ref.topk_threshold(x, k)          # tiny top-k, stays in XLA
    l1, cnt = k_st.l1_and_count_masked(x, tau)
    scale = jnp.where(cnt > 0, l1 / jnp.maximum(cnt, 1.0), 0.0)
    return k_st.masked_sign_scale(x, tau, scale)


def gossip_step(x: jax.Array, xhat: jax.Array, w: jax.Array,
                gamma: jax.Array) -> jax.Array:
    return k_gossip.gossip_step(x, xhat, w, gamma)


def sgd_momentum_step(x: jax.Array, g: jax.Array, m: jax.Array,
                      eta: jax.Array, mu: jax.Array):
    return k_sgd.sgd_momentum_step(x, g, m, eta, mu)


def qsgd_compress(x: jax.Array, u: jax.Array, s: int) -> jax.Array:
    return k_qsgd.qsgd(x, u, s)


def trigger_check(x_half: jax.Array, xhat: jax.Array, c_t: jax.Array,
                  eta_t: jax.Array) -> jax.Array:
    """Event trigger (Algorithm 1 line 7): returns bool(||diff||^2 > c eta^2)."""
    diff = x_half - xhat
    return jnp.sum(diff * diff) > c_t * eta_t * eta_t
