"""L1/L2 performance estimation (EXPERIMENTS.md §Perf).

interpret=True Pallas gives CPU-numpy timings that say nothing about TPU
behaviour, so the L1 analysis is *structural*: per-kernel VMEM working set
per grid step (from the BlockSpecs), HBM traffic per launch, arithmetic
intensity, and MXU tile utilization for the matmul kernel. The L2 analysis
counts HLO ops in the lowered modules (fusion opportunities / redundant
recomputation show up as op-count blowups).

Run: cd python && python -m compile.perf_estimate
"""

from __future__ import annotations

import os
import re

from .kernels import gossip, sgd_fused, sign_topk

F32 = 4  # bytes

TPU_HBM_GBPS = 800.0   # v4-lite class, order of magnitude
TPU_VMEM_MIB = 16.0


def fmt_bytes(n: float) -> str:
    if n < 1024:
        return f"{n:.0f} B"
    if n < 1024**2:
        return f"{n/1024:.1f} KiB"
    return f"{n/1024**2:.2f} MiB"


def elementwise_kernel(name, d, n_in, n_out, block, flops_per_elem):
    """VMEM/traffic model for a 1-D blocked elementwise kernel."""
    vmem = (n_in + n_out) * block * F32
    traffic = (n_in + n_out) * d * F32
    flops = flops_per_elem * d
    ai = flops / traffic  # arithmetic intensity (flops/byte)
    est_us = traffic / (TPU_HBM_GBPS * 1e3)  # µs, memory-bound
    print(
        f"  {name:<28} block={block:<5} VMEM/step={fmt_bytes(vmem):<10} "
        f"HBM traffic={fmt_bytes(traffic):<11} AI={ai:.2f} flop/B "
        f"→ est {est_us:.1f} µs @ {TPU_HBM_GBPS:.0f} GB/s (memory-bound)"
    )
    assert vmem < TPU_VMEM_MIB * 1024**2 / 8, "block too large for double-buffering"


def gossip_kernel(n, d, block_d):
    """MXU model for the consensus matmul X + γ(W X̂ − X̂)."""
    steps = (d + block_d - 1) // block_d
    vmem = (3 * n * block_d + n * n) * F32
    macs = n * n * d
    # MXU is a 128×128 systolic array: a (n×n)@(n×block_d) pass uses
    # (n/128)^2 of the array when n < 128.
    util = min(1.0, (n / 128.0) ** 2)
    traffic = (3 * n * d + n * n) * F32
    est_us = traffic / (TPU_HBM_GBPS * 1e3)
    print(
        f"  gossip n={n:<3} d={d:<7} grid={steps:<5} VMEM/step={fmt_bytes(vmem):<10} "
        f"MACs={macs/1e6:.2f}M MXU-util={util*100:.1f}% "
        f"HBM={fmt_bytes(traffic)} → est {est_us:.1f} µs (memory-bound; "
        f"MXU idle headroom {100*(1-util):.0f}%)"
    )


def l2_hlo_report(art_dir: str):
    print("\nL2 HLO op census (lowered modules; fusion health check):")
    interesting = ["logreg_grad", "mlp_grad", "lm_grad",
                   "compress_sign_topk_d7850_k10", "gossip_n60_d7850"]
    op_re = re.compile(r"^\s+[%\w.\-]+ = \S+ (\w+)\(", re.M)
    for name in interesting:
        path = os.path.join(art_dir, f"{name}.hlo.txt")
        if not os.path.exists(path):
            continue
        text = open(path).read()
        ops = op_re.findall(text)
        counts = {}
        for o in ops:
            counts[o] = counts.get(o, 0) + 1
        top = sorted(counts.items(), key=lambda kv: -kv[1])[:6]
        dots = counts.get("dot", 0)
        total = len(ops)
        print(f"  {name:<32} {total:>5} ops, {dots} dot(s); top: "
              + ", ".join(f"{k}:{v}" for k, v in top))


def main():
    print("L1 Pallas kernel structural estimates (TPU model, f32):")
    d_small, d_large = 7850, 394_634

    for d in (d_small, d_large):
        elementwise_kernel(f"masked_sign_scale d={d}", d, n_in=1, n_out=1,
                           block=sign_topk.BLOCK, flops_per_elem=3)
        elementwise_kernel(f"l1_count_masked  d={d}", d, n_in=1, n_out=0,
                           block=sign_topk.BLOCK, flops_per_elem=4)
        elementwise_kernel(f"sgd_momentum     d={d}", d, n_in=3, n_out=2,
                           block=sgd_fused.BLOCK, flops_per_elem=3)

    print()
    gossip_kernel(60, d_small, gossip.BLOCK_D)
    gossip_kernel(8, d_large, gossip.BLOCK_D)

    art = os.path.join(os.path.dirname(__file__), "..", "..", "artifacts")
    l2_hlo_report(art)

    print(
        "\nreading: every L1 kernel is memory-bound (AI < 1 flop/B), so the\n"
        "BlockSpec schedule (double-buffered HBM↔VMEM streaming) is the\n"
        "whole game; block sizes keep VMEM/step ≲ 8 KiB (≪ 16 MiB budget),\n"
        "so Mosaic can deep-pipeline. The gossip matmul underutilizes the\n"
        "MXU at n ≤ 60 (22% at n=60, 0.4% at n=8) but is still HBM-bound —\n"
        "a TPU would hide the MXU pass entirely behind the panel loads."
    )


if __name__ == "__main__":
    main()
