"""L2 models with the flat-parameter convention.

Every model exposes:
    grad(flat[d], batch...) -> (loss f32[], grad_flat f32[d])
    evaluate(flat[d], X, y) -> (loss f32[], ncorrect f32[])

SPARQ-SGD's trigger / compression / consensus all operate on the whole
parameter vector, so the Rust coordinator keeps one flat f32 vector per
node and the (un)flattening lives inside the jitted graph. `aot.py` lowers
these for fixed shapes into artifacts/*.hlo.txt.

Models mirror DESIGN.md §Substitutions:
* ``logreg``     — multinomial logistic regression, the convex objective of
                   Section 5.1 (784 -> 10, d = 7850).
* ``mlp``        — 3072 -> hidden -> 10 ReLU classifier, the non-convex
                   stand-in for ResNet-20/CIFAR of Section 5.2.
* transformer LM lives in ``compile.transformer``.
"""

from __future__ import annotations

from typing import List, Tuple

import jax
import jax.numpy as jnp

# ----------------------------------------------------------------------
# flat <-> pytree helpers
# ----------------------------------------------------------------------

def shapes_size(shapes: List[Tuple[int, ...]]) -> int:
    tot = 0
    for s in shapes:
        n = 1
        for v in s:
            n *= v
        tot += n
    return tot


def unflatten(flat: jax.Array, shapes: List[Tuple[int, ...]]) -> List[jax.Array]:
    out, off = [], 0
    for s in shapes:
        n = 1
        for v in s:
            n *= v
        out.append(flat[off:off + n].reshape(s))
        off += n
    return out


def flatten(arrs: List[jax.Array]) -> jax.Array:
    return jnp.concatenate([a.reshape(-1) for a in arrs])


# ----------------------------------------------------------------------
# Multinomial logistic regression (convex; Section 5.1)
# ----------------------------------------------------------------------

LOGREG_IN, LOGREG_CLASSES = 784, 10
LOGREG_SHAPES = [(LOGREG_IN, LOGREG_CLASSES), (LOGREG_CLASSES,)]
LOGREG_DIM = shapes_size(LOGREG_SHAPES)  # 7850 — paper's "7840 length" +bias


def _softmax_xent(logits: jax.Array, y: jax.Array) -> jax.Array:
    logz = jax.nn.logsumexp(logits, axis=-1)
    gold = jnp.take_along_axis(logits, y[:, None], axis=-1)[:, 0]
    return jnp.mean(logz - gold)


def logreg_loss(flat: jax.Array, x: jax.Array, y: jax.Array,
                l2: float = 1e-4) -> jax.Array:
    """Cross-entropy + L2 (the ridge term makes the objective strongly
    convex, matching Theorem 1's setting)."""
    w, b = unflatten(flat, LOGREG_SHAPES)
    logits = x @ w + b
    return _softmax_xent(logits, y) + 0.5 * l2 * jnp.sum(flat * flat)


def logreg_grad(flat: jax.Array, x: jax.Array, y: jax.Array):
    loss, g = jax.value_and_grad(logreg_loss)(flat, x, y)
    return loss, g


def logreg_eval(flat: jax.Array, x: jax.Array, y: jax.Array):
    w, b = unflatten(flat, LOGREG_SHAPES)
    logits = x @ w + b
    loss = _softmax_xent(logits, y)
    ncorrect = jnp.sum((jnp.argmax(logits, axis=-1) == y).astype(jnp.float32))
    return loss, ncorrect


# ----------------------------------------------------------------------
# MLP classifier (non-convex; Section 5.2 stand-in)
# ----------------------------------------------------------------------

MLP_IN, MLP_HIDDEN, MLP_CLASSES = 3072, 128, 10
MLP_SHAPES = [(MLP_IN, MLP_HIDDEN), (MLP_HIDDEN,),
              (MLP_HIDDEN, MLP_CLASSES), (MLP_CLASSES,)]
MLP_DIM = shapes_size(MLP_SHAPES)  # 394,634


def mlp_logits(flat: jax.Array, x: jax.Array) -> jax.Array:
    w1, b1, w2, b2 = unflatten(flat, MLP_SHAPES)
    h = jax.nn.relu(x @ w1 + b1)
    return h @ w2 + b2


def mlp_loss(flat: jax.Array, x: jax.Array, y: jax.Array) -> jax.Array:
    return _softmax_xent(mlp_logits(flat, x), y)


def mlp_grad(flat: jax.Array, x: jax.Array, y: jax.Array):
    loss, g = jax.value_and_grad(mlp_loss)(flat, x, y)
    return loss, g


def mlp_eval(flat: jax.Array, x: jax.Array, y: jax.Array):
    logits = mlp_logits(flat, x)
    loss = _softmax_xent(logits, y)
    ncorrect = jnp.sum((jnp.argmax(logits, axis=-1) == y).astype(jnp.float32))
    return loss, ncorrect


# ----------------------------------------------------------------------
# Initialization (used by aot.py to export an init artifact and by tests)
# ----------------------------------------------------------------------

def init_flat(shapes: List[Tuple[int, ...]], key: jax.Array,
              scale: str = "glorot") -> jax.Array:
    parts = []
    for s in shapes:
        key, sub = jax.random.split(key)
        if len(s) == 1:
            parts.append(jnp.zeros(s, jnp.float32))
        else:
            fan_in, fan_out = s[0], s[-1]
            std = (2.0 / (fan_in + fan_out)) ** 0.5
            parts.append(std * jax.random.normal(sub, s, jnp.float32))
    return flatten(parts)
