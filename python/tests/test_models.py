"""L2 model correctness: flat-parameter convention, gradient checks
against numerical differentiation, loss/eval semantics, transformer
shape/regression sanity."""

import numpy as np
import jax
import jax.numpy as jnp
import pytest
from hypothesis import given, settings, strategies as stf

from compile import model
from compile.transformer import (TransformerConfig, init_flat as lm_init,
                                 lm_grad, lm_loss)

SET = dict(max_examples=10, deadline=None)


def rand_batch(seed, b, din, classes):
    rng = np.random.default_rng(seed)
    x = jnp.asarray(rng.normal(size=(b, din)).astype(np.float32))
    y = jnp.asarray(rng.integers(0, classes, b).astype(np.int32))
    return x, y


class TestFlatten:
    @settings(**SET)
    @given(stf.integers(min_value=0, max_value=10**6))
    def test_roundtrip(self, seed):
        rng = np.random.default_rng(seed)
        shapes = [(3, 4), (4,), (4, 2), (2,)]
        arrs = [jnp.asarray(rng.normal(size=s).astype(np.float32))
                for s in shapes]
        flat = model.flatten(arrs)
        assert flat.shape == (model.shapes_size(shapes),)
        back = model.unflatten(flat, shapes)
        for a, b in zip(arrs, back):
            np.testing.assert_array_equal(a, b)

    def test_dims(self):
        assert model.LOGREG_DIM == 784 * 10 + 10
        assert model.MLP_DIM == 3072 * 128 + 128 + 128 * 10 + 10


class TestLogreg:
    def test_grad_matches_fd(self):
        """Central finite differences on random coordinates."""
        key = jax.random.PRNGKey(0)
        p = model.init_flat(model.LOGREG_SHAPES, key)
        x, y = rand_batch(1, 5, 784, 10)
        loss, g = model.logreg_grad(p, x, y)
        rng = np.random.default_rng(2)
        eps = 1e-3
        for idx in rng.integers(0, model.LOGREG_DIM, 8):
            e = jnp.zeros_like(p).at[idx].set(eps)
            fd = (model.logreg_loss(p + e, x, y) -
                  model.logreg_loss(p - e, x, y)) / (2 * eps)
            np.testing.assert_allclose(g[idx], fd, rtol=2e-2, atol=2e-3)

    def test_uniform_prediction_loss(self):
        p = jnp.zeros(model.LOGREG_DIM)
        x, y = rand_batch(3, 32, 784, 10)
        loss, _ = model.logreg_grad(p, x, y)
        np.testing.assert_allclose(loss, np.log(10), rtol=1e-5)

    def test_eval_counts(self):
        p = jnp.zeros(model.LOGREG_DIM)
        # bias trick: make class 3 always win
        w, b = model.unflatten(p, model.LOGREG_SHAPES)
        b = b.at[3].set(10.0)
        p = model.flatten([w, b])
        x, _ = rand_batch(4, 16, 784, 10)
        y = jnp.full(16, 3, jnp.int32)
        _, ncorrect = model.logreg_eval(p, x, y)
        assert float(ncorrect) == 16.0

    def test_strong_convexity_term(self):
        """L2 ridge present: loss at large params exceeds CE alone."""
        x, y = rand_batch(5, 8, 784, 10)
        p = jnp.ones(model.LOGREG_DIM) * 10.0
        assert float(model.logreg_loss(p, x, y)) > 0.5 * 1e-4 * float(
            jnp.sum(p * p)) - 1.0


class TestMlp:
    def test_grad_matches_fd(self):
        key = jax.random.PRNGKey(1)
        p = model.init_flat(model.MLP_SHAPES, key)
        x, y = rand_batch(7, 4, 3072, 10)
        _, g = model.mlp_grad(p, x, y)
        rng = np.random.default_rng(8)
        eps = 1e-2
        checked = 0
        for idx in rng.integers(0, model.MLP_DIM, 12):
            e = jnp.zeros_like(p).at[idx].set(eps)
            fd = (model.mlp_loss(p + e, x, y) -
                  model.mlp_loss(p - e, x, y)) / (2 * eps)
            if abs(float(fd)) > 1e-4:  # skip dead-ReLU coordinates
                np.testing.assert_allclose(g[idx], fd, rtol=5e-2, atol=5e-3)
                checked += 1
        assert checked >= 1

    def test_training_reduces_loss(self):
        key = jax.random.PRNGKey(2)
        p = model.init_flat(model.MLP_SHAPES, key)
        x, y = rand_batch(9, 32, 3072, 10)
        l0, _ = model.mlp_grad(p, x, y)
        for _ in range(30):
            _, g = model.mlp_grad(p, x, y)
            p = p - 0.05 * g
        l1, _ = model.mlp_grad(p, x, y)
        assert float(l1) < float(l0) * 0.9


class TestTransformer:
    CFG = TransformerConfig(d_model=32, n_layers=2, n_heads=2, seq=16)

    def test_dim_formula(self):
        c = self.CFG
        per_layer = (2 * c.d_model + c.d_model * 3 * c.d_model +
                     3 * c.d_model + c.d_model * c.d_model + c.d_model +
                     2 * c.d_model + c.d_model * c.d_ff + c.d_ff +
                     c.d_ff * c.d_model + c.d_model)
        expect = (c.vocab * c.d_model + c.seq * c.d_model +
                  c.n_layers * per_layer + 2 * c.d_model +
                  c.d_model * c.vocab + c.vocab)
        assert c.dim == expect

    def test_init_loss_near_uniform(self):
        key = jax.random.PRNGKey(0)
        p = lm_init(self.CFG, key)
        toks = jax.random.randint(key, (4, 17), 0, 256, jnp.int32)
        loss = lm_loss(p, toks, self.CFG)
        np.testing.assert_allclose(float(loss), np.log(256), rtol=0.05)

    def test_grad_shape_and_finite(self):
        key = jax.random.PRNGKey(1)
        p = lm_init(self.CFG, key)
        toks = jax.random.randint(key, (4, 17), 0, 256, jnp.int32)
        loss, g = lm_grad(p, toks, self.CFG)
        assert g.shape == (self.CFG.dim,)
        assert bool(jnp.all(jnp.isfinite(g)))

    def test_causality(self):
        """Future tokens cannot change earlier-position losses: perturb the
        last input token and check per-position logits before it agree."""
        key = jax.random.PRNGKey(2)
        p = lm_init(self.CFG, key)
        toks = jax.random.randint(key, (1, 17), 0, 256, jnp.int32)
        toks2 = toks.at[0, -2].set((toks[0, -2] + 1) % 256)

        # compare loss restricted to first positions via masking trick:
        # losses computed per position from logits; we recompute manually.
        from compile.transformer import unflatten, _layernorm, _block
        def per_pos_logits(t):
            params = unflatten(p, self.CFG.shapes())
            x = params[0][t[:, :-1]] + params[1][None, :self.CFG.seq]
            off = 2
            for _ in range(self.CFG.n_layers):
                x = _block(x, params[off:off + 12], self.CFG.n_heads)
                off += 12
            x = _layernorm(x, params[off], params[off + 1])
            return x @ params[off + 2] + params[off + 3]

        l1 = per_pos_logits(toks)
        l2 = per_pos_logits(toks2)
        np.testing.assert_allclose(l1[0, :14], l2[0, :14], atol=1e-5)
        assert not np.allclose(l1[0, 15], l2[0, 15], atol=1e-5)

    def test_overfit_tiny_sequence(self):
        key = jax.random.PRNGKey(3)
        p = lm_init(self.CFG, key)
        toks = jnp.tile(jnp.arange(17, dtype=jnp.int32)[None], (2, 1))
        l0 = float(lm_loss(p, toks, self.CFG))
        grad = jax.jit(lambda q: lm_grad(q, toks, self.CFG))
        for _ in range(40):
            _, g = grad(p)
            p = p - 0.5 * g
        l1 = float(lm_loss(p, toks, self.CFG))
        assert l1 < l0 * 0.5
