"""L1 correctness: every Pallas kernel (interpret mode) vs its pure-jnp
oracle in kernels/ref.py. Hypothesis sweeps shapes and value regimes —
this is the core correctness signal for the compression/consensus math
that the Rust L3 mirrors."""

import numpy as np
import jax
import jax.numpy as jnp
import pytest
from hypothesis import given, settings, strategies as stf

from compile.kernels import gossip, qsgd, ref, sgd_fused, sign_topk

SET = dict(max_examples=20, deadline=None)

dims = stf.integers(min_value=1, max_value=2000)
seeds = stf.integers(min_value=0, max_value=2**31 - 1)


def vec(seed, d, scale=1.0, dist="normal"):
    rng = np.random.default_rng(seed)
    if dist == "normal":
        v = rng.normal(0, scale, d)
    else:
        v = rng.uniform(-scale, scale, d)
    return jnp.asarray(v.astype(np.float32))


# ----------------------------------------------------------------------
# sign_topk kernels
# ----------------------------------------------------------------------

class TestL1Count:
    @settings(**SET)
    @given(seeds, dims)
    def test_matches_ref(self, seed, d):
        x = vec(seed, d)
        k = max(1, d // 10)
        tau = ref.topk_threshold(x, k)
        l1k, ck = sign_topk.l1_and_count_masked(x, tau)
        l1r, cr = ref.l1_and_count_masked(x, tau)
        np.testing.assert_allclose(l1k, l1r, rtol=1e-5)
        np.testing.assert_allclose(ck, cr)

    def test_zero_vector_counts_all(self):
        # tau == 0 selects everything *within the valid range* — padding
        # lanes must be masked out (the bug this kernel's index test guards).
        x = jnp.zeros(700, jnp.float32)
        l1, cnt = sign_topk.l1_and_count_masked(x, jnp.float32(0.0))
        assert float(l1) == 0.0
        assert float(cnt) == 700.0  # not 1024 (padded length)

    @settings(**SET)
    @given(seeds)
    def test_tau_above_max_selects_none(self, seed):
        x = vec(seed, 513)
        tau = jnp.max(jnp.abs(x)) * 2 + 1.0
        l1, cnt = sign_topk.l1_and_count_masked(x, tau)
        assert float(cnt) == 0.0 and float(l1) == 0.0


class TestMaskedSignScale:
    @settings(**SET)
    @given(seeds, dims, stf.floats(min_value=0.0, max_value=10.0))
    def test_matches_ref(self, seed, d, scale):
        x = vec(seed, d)
        tau = ref.topk_threshold(x, max(1, d // 4))
        qk = sign_topk.masked_sign_scale(x, tau, scale)
        qr = ref.masked_sign_scale(x, tau, scale)
        np.testing.assert_allclose(qk, qr, rtol=1e-6)

    @settings(**SET)
    @given(seeds, dims)
    def test_full_operator_compression_contract(self, seed, d):
        """Definition 1: E||x - C(x)||^2 <= (1-omega)||x||^2 with
        omega = k/d for (Sign)TopK-style selection (deterministic op, so
        no expectation needed). The composed SignTopK satisfies the
        contract with omega = max{1/d, ...} >= something > 0 [BDKD19]."""
        x = vec(seed, d)
        k = max(1, d // 10)
        tau = ref.topk_threshold(x, k)
        l1, cnt = sign_topk.l1_and_count_masked(x, tau)
        scale = jnp.where(cnt > 0, l1 / jnp.maximum(cnt, 1.0), 0.0)
        q = sign_topk.masked_sign_scale(x, tau, scale)
        err = float(jnp.sum((x - q) ** 2))
        nx2 = float(jnp.sum(x * x))
        omega = 1.0 / d
        assert err <= (1 - omega) * nx2 + 1e-4 * max(nx2, 1.0)

    def test_c_of_zero_is_zero(self):
        x = jnp.zeros(100, jnp.float32)
        q = ref.sign_topk(x, 10)
        assert float(jnp.sum(jnp.abs(q))) == 0.0


# ----------------------------------------------------------------------
# gossip kernel
# ----------------------------------------------------------------------

def ring_w(n):
    w = np.zeros((n, n), np.float32)
    for i in range(n):
        w[i, i] = 1 / 3 if n > 2 else 1 / 2
        w[i, (i + 1) % n] += 1 / 3 if n > 2 else 1 / 4
        w[i, (i - 1) % n] += 1 / 3 if n > 2 else 1 / 4
    return w


class TestGossip:
    @settings(**SET)
    @given(seeds, stf.integers(min_value=2, max_value=16),
           stf.integers(min_value=1, max_value=600),
           stf.floats(min_value=0.0, max_value=1.0))
    def test_matches_ref(self, seed, n, d, gamma):
        rng = np.random.default_rng(seed)
        x = jnp.asarray(rng.normal(size=(n, d)).astype(np.float32))
        xh = jnp.asarray(rng.normal(size=(n, d)).astype(np.float32))
        w = jnp.asarray(ring_w(n))
        gk = gossip.gossip_step(x, xh, w, gamma)
        gr = ref.gossip_step(x, xh, w, gamma)
        np.testing.assert_allclose(gk, gr, rtol=2e-5, atol=2e-5)

    @settings(**SET)
    @given(seeds, stf.integers(min_value=2, max_value=16))
    def test_preserves_average(self, seed, n):
        """Paper Eq. (20): the consensus step cannot move the node average
        because W is doubly stochastic."""
        rng = np.random.default_rng(seed)
        x = jnp.asarray(rng.normal(size=(n, 257)).astype(np.float32))
        xh = jnp.asarray(rng.normal(size=(n, 257)).astype(np.float32))
        w = jnp.asarray(ring_w(n))
        out = gossip.gossip_step(x, xh, w, 0.7)
        np.testing.assert_allclose(out.mean(axis=0), x.mean(axis=0),
                                   rtol=1e-4, atol=1e-4)

    def test_gamma_zero_identity(self):
        rng = np.random.default_rng(3)
        x = jnp.asarray(rng.normal(size=(4, 100)).astype(np.float32))
        xh = jnp.asarray(rng.normal(size=(4, 100)).astype(np.float32))
        out = gossip.gossip_step(x, xh, jnp.asarray(ring_w(4)), 0.0)
        np.testing.assert_allclose(out, x)


# ----------------------------------------------------------------------
# fused SGD + momentum
# ----------------------------------------------------------------------

class TestSgdFused:
    @settings(**SET)
    @given(seeds, dims, stf.floats(min_value=0.0, max_value=0.99),
           stf.floats(min_value=1e-5, max_value=1.0))
    def test_matches_ref(self, seed, d, mu, eta):
        rng = np.random.default_rng(seed)
        x, g, m = (jnp.asarray(rng.normal(size=d).astype(np.float32))
                   for _ in range(3))
        xk, mk = sgd_fused.sgd_momentum_step(x, g, m, eta, mu)
        xr, mr = ref.sgd_momentum_step(x, g, m, eta, mu)
        np.testing.assert_allclose(xk, xr, rtol=1e-5, atol=1e-6)
        np.testing.assert_allclose(mk, mr, rtol=1e-5, atol=1e-6)

    def test_zero_momentum_is_plain_sgd(self):
        x = jnp.ones(10)
        g = jnp.full(10, 2.0)
        m = jnp.zeros(10)
        xk, mk = sgd_fused.sgd_momentum_step(x, g, m, 0.5, 0.0)
        np.testing.assert_allclose(xk, jnp.zeros(10))
        np.testing.assert_allclose(mk, g)


# ----------------------------------------------------------------------
# QSGD quantizer
# ----------------------------------------------------------------------

class TestQsgd:
    @settings(**SET)
    @given(seeds, dims, stf.sampled_from([1, 4, 16, 256]))
    def test_matches_ref(self, seed, d, s):
        rng = np.random.default_rng(seed)
        x = jnp.asarray(rng.normal(size=d).astype(np.float32))
        u = jnp.asarray(rng.random(d).astype(np.float32))
        np.testing.assert_allclose(qsgd.qsgd(x, u, s), ref.qsgd(x, u, s),
                                   rtol=1e-5, atol=1e-6)

    @settings(**SET)
    @given(seeds, stf.sampled_from([4, 16]))
    def test_unbiased(self, seed, s):
        """E[Q_s(x)] = x over the external uniforms (Footnote 4 property
        (i)); checked empirically at 3-sigma."""
        rng = np.random.default_rng(seed)
        d, reps = 64, 400
        x = jnp.asarray(rng.normal(size=d).astype(np.float32))
        acc = np.zeros(d, np.float64)
        for r in range(reps):
            u = jnp.asarray(rng.random(d).astype(np.float32))
            acc += np.asarray(ref.qsgd(x, u, s), np.float64)
        mean = acc / reps
        norm = float(jnp.linalg.norm(x))
        se = norm / s / np.sqrt(reps)  # per-coord rounding sd <= norm/s
        np.testing.assert_allclose(mean, np.asarray(x), atol=5 * se + 1e-6)

    def test_zero_input(self):
        x = jnp.zeros(32, jnp.float32)
        u = jnp.full(32, 0.99, jnp.float32)
        assert float(jnp.max(jnp.abs(qsgd.qsgd(x, u, 8)))) == 0.0
