"""L2 step graphs: the SPARQ-SGD building blocks that call the L1 kernels,
checked against ref oracles and against the paper's algebraic facts."""

import numpy as np
import jax
import jax.numpy as jnp
import pytest
from hypothesis import given, settings, strategies as stf

from compile import steps
from compile.kernels import ref

SET = dict(max_examples=15, deadline=None)


def vec(seed, d):
    rng = np.random.default_rng(seed)
    return jnp.asarray(rng.normal(size=d).astype(np.float32))


class TestCompressSignTopK:
    @settings(**SET)
    @given(stf.integers(min_value=0, max_value=2**31 - 1),
           stf.integers(min_value=2, max_value=1500))
    def test_matches_ref(self, seed, d):
        x = vec(seed, d)
        k = max(1, d // 8)
        np.testing.assert_allclose(steps.compress_sign_topk(x, k),
                                   ref.sign_topk(x, k), rtol=1e-5, atol=1e-6)

    @settings(**SET)
    @given(stf.integers(min_value=0, max_value=2**31 - 1))
    def test_support_size(self, seed):
        """With continuous data (no ties) exactly k coordinates survive."""
        x = vec(seed, 777)
        q = steps.compress_sign_topk(x, 77)
        assert int(jnp.sum(q != 0)) == 77

    @settings(**SET)
    @given(stf.integers(min_value=0, max_value=2**31 - 1))
    def test_two_valued_output(self, seed):
        """Transmitted payload is {±scale}: 1 bit/coord + one float."""
        x = vec(seed, 500)
        q = np.asarray(steps.compress_sign_topk(x, 50))
        nz = q[q != 0]
        assert len(np.unique(np.abs(nz))) == 1


class TestTrigger:
    @settings(**SET)
    @given(stf.integers(min_value=0, max_value=2**31 - 1),
           stf.floats(min_value=0.0, max_value=100.0),
           stf.floats(min_value=1e-4, max_value=1.0))
    def test_threshold_semantics(self, seed, c_t, eta):
        x_half, xhat = vec(seed, 128), vec(seed + 1, 128)
        fired = steps.trigger_check(x_half, xhat, c_t, eta)
        expect = float(jnp.sum((x_half - xhat) ** 2)) > c_t * eta * eta
        assert bool(fired) == expect

    def test_identical_states_never_fire(self):
        x = vec(0, 64)
        assert not bool(steps.trigger_check(x, x, 0.0, 0.1))
        # strict inequality in Algorithm 1 line 7: ||0||^2 > 0 is False


class TestQsgdCompress:
    @settings(**SET)
    @given(stf.integers(min_value=0, max_value=2**31 - 1),
           stf.sampled_from([2, 8, 64]))
    def test_matches_ref(self, seed, s):
        rng = np.random.default_rng(seed)
        x = jnp.asarray(rng.normal(size=300).astype(np.float32))
        u = jnp.asarray(rng.random(300).astype(np.float32))
        np.testing.assert_allclose(steps.qsgd_compress(x, u, s),
                                   ref.qsgd(x, u, s), rtol=1e-5, atol=1e-6)


class TestGossipStep:
    def test_consensus_convergence(self):
        """Repeated gossip with x̂ = x (perfect estimates) drives all nodes
        to the average — the delta=spectral-gap mechanism of Section 3."""
        rng = np.random.default_rng(0)
        n, d = 8, 40
        x = jnp.asarray(rng.normal(size=(n, d)).astype(np.float32))
        w = np.zeros((n, n), np.float32)
        for i in range(n):
            w[i, i] = 1 / 3
            w[i, (i + 1) % n] = 1 / 3
            w[i, (i - 1) % n] = 1 / 3
        w = jnp.asarray(w)
        target = x.mean(axis=0)
        for _ in range(200):
            x = steps.gossip_step(x, x, w, 1.0)
        np.testing.assert_allclose(x, jnp.tile(target[None], (n, 1)),
                                   atol=1e-3)
