"""AOT export checks: HLO text is produced, is parseable (well-formed
header + entry layout), and the manifest signature matches what the
exporter promises to the Rust runtime."""

import json
import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from compile import aot, model, steps

ART = os.path.join(os.path.dirname(__file__), "..", "..", "artifacts")


def test_to_hlo_text_roundtrip_smoke():
    lowered = jax.jit(lambda x: (x * 2.0,)).lower(
        jax.ShapeDtypeStruct((4,), jnp.float32))
    text = aot.to_hlo_text(lowered)
    assert text.startswith("HloModule")
    assert "ENTRY" in text


def test_pallas_kernel_lowers_to_plain_hlo():
    """interpret=True must not leave custom-calls behind (CPU PJRT cannot
    execute Mosaic)."""
    lowered = jax.jit(lambda x: (steps.compress_sign_topk(x, 4),)).lower(
        jax.ShapeDtypeStruct((128,), jnp.float32))
    text = aot.to_hlo_text(lowered)
    assert "custom-call" not in text or "mosaic" not in text.lower()


@pytest.mark.skipif(not os.path.exists(os.path.join(ART, "manifest.json")),
                    reason="run `make artifacts` first")
class TestManifest:
    @pytest.fixture(scope="class")
    def manifest(self):
        with open(os.path.join(ART, "manifest.json")) as f:
            return json.load(f)

    def test_all_files_exist(self, manifest):
        for name, art in manifest["artifacts"].items():
            path = os.path.join(ART, art["file"])
            assert os.path.exists(path), name
            with open(path) as f:
                head = f.read(64)
            assert head.startswith("HloModule"), name

    def test_expected_artifacts_present(self, manifest):
        names = set(manifest["artifacts"])
        for required in ["logreg_grad", "logreg_eval", "mlp_grad",
                         "mlp_eval", "lm_grad", "lm_loss",
                         f"compress_sign_topk_d{model.LOGREG_DIM}_k10",
                         f"gossip_n60_d{model.LOGREG_DIM}"]:
            assert required in names, required

    def test_signatures(self, manifest):
        lg = manifest["artifacts"]["logreg_grad"]
        assert lg["inputs"][0]["shape"] == [model.LOGREG_DIM]
        assert lg["inputs"][1]["shape"] == [aot.LOGREG_TRAIN_B, model.LOGREG_IN]
        assert lg["inputs"][2]["dtype"] == "int32"
        assert lg["outputs"][0]["shape"] == []
        assert lg["outputs"][1]["shape"] == [model.LOGREG_DIM]

    def test_entry_layout_matches_signature(self, manifest):
        """The HLO entry_computation_layout must agree with the manifest
        (this is what the Rust loader validates against)."""
        art = manifest["artifacts"]["logreg_grad"]
        with open(os.path.join(ART, art["file"])) as f:
            first = f.readline()
        d = model.LOGREG_DIM
        assert f"f32[{d}]" in first
        assert f"f32[{aot.LOGREG_TRAIN_B},{model.LOGREG_IN}]" in first
