//! Microbenchmarks for the compression operators (the per-round hot path
//! on every node) at the paper's two parameter scales: logreg d = 7850
//! and MLP d = 394,634. Reported via the in-tree harness (criterion is
//! unavailable offline); throughput is elements/second over the input.

use sparq::compress::{Compressor, QsgdOp, RandK, SignL1, SignTopK, TopK};
use sparq::util::bench::Bencher;
use sparq::util::Rng;

fn randvec(d: usize) -> Vec<f32> {
    let mut rng = Rng::new(1);
    let mut v = vec![0.0f32; d];
    rng.fill_normal(&mut v, 1.0);
    v
}

fn bench_dim(b: &mut Bencher, d: usize) {
    let x = randvec(d);
    let mut out = vec![0.0f32; d];
    let ops: Vec<Box<dyn Compressor>> = vec![
        Box::new(TopK::new(d / 10)),
        Box::new(SignTopK::new(d / 10)),
        Box::new(SignTopK::new(10)), // paper's k=10 setting
        Box::new(RandK::new(d / 10)),
        Box::new(SignL1),
        Box::new(QsgdOp::new(16)),
    ];
    for op in ops {
        let mut rng = Rng::new(2);
        b.bench_throughput(&format!("{}/d={d}", op.name()), d as u64, || {
            op.compress(&x, &mut rng, &mut out);
            out[0]
        });
    }
}

fn main() {
    let mut b = Bencher::new("compression").with_budget(100, 400);
    bench_dim(&mut b, 7850);
    bench_dim(&mut b, 394_634);
}
