//! Figure 1b bench — convex: total transmitted bits to reach the target
//! test error and the savings factors vs CHOCO/vanilla (the paper's
//! headline 250×/10–15×/1000× numbers, shape-reproduced at scale).

use sparq::experiments::{fig1, savings};

fn main() {
    println!("=== Fig 1b (scaled): test error vs total transmitted bits ===\n");
    let mut suite = fig1::convex_suite(2400, 7);
    for (_, cfg) in suite.iter_mut() {
        cfg.nodes = 12;
        cfg.problem = "logreg:96:10:5".into();
        if cfg.compressor == "sign_topk:10" {
            cfg.compressor = "sign_topk:5%".into();
        }
        cfg.trigger = "const:100".into();
        cfg.eval_every = 40;
    }
    let series = fig1::run_suite(suite, false);

    for target in [0.3, 0.2, 0.15] {
        println!("--- bits to reach test error ≤ {target} ---");
        println!("{}", fig1::savings_table(&series, target));
        // savings of SPARQ (index 0) vs each baseline
        for (idx, label) in [
            (1, "CHOCO-SGD (Sign)"),
            (2, "CHOCO-SGD (TopK)"),
            (3, "CHOCO-SGD (SignTopK)"),
            (4, "vanilla"),
        ] {
            match savings::savings_factor(&series, 0, idx, target) {
                Some(f) => println!("  SPARQ saves {f:>8.1}x vs {label}"),
                None => println!("  SPARQ vs {label}: target not reached"),
            }
        }
        println!();
    }

    println!("paper (MNIST, err 0.12): 250x vs CHOCO-Sign, 10-15x vs CHOCO-TopK, 1000x vs vanilla");
    println!("(absolute factors differ on the synthetic substrate; ordering + orders of magnitude are the claim)");
}
