//! Consensus-step and full-sync-round benchmarks: the L3 per-round cost
//! at the paper's two graph scales (n = 60 ring / d = 7850 and n = 8
//! ring / d = 394,634). These are the numbers behind EXPERIMENTS.md §Perf
//! (L3).

use sparq::comm::Bus;
use sparq::compress::SignTopK;
use sparq::coordinator::{DecentralizedAlgo, DecentralizedEngine, SparqConfig, SparqSgd};
use sparq::graph::{uniform_neighbor, Topology, TopologyKind};
use sparq::problems::{GradientSource, QuadraticProblem};
use sparq::schedule::{LrSchedule, SyncSchedule};
use sparq::trigger::{EventTrigger, ThresholdSchedule};
use sparq::util::bench::Bencher;
use sparq::util::Rng;

/// Zero-cost gradient source: isolates coordinator overhead from the
/// model math.
struct NullGrad {
    d: usize,
    n: usize,
}

impl GradientSource for NullGrad {
    fn dim(&self) -> usize {
        self.d
    }
    fn n_nodes(&self) -> usize {
        self.n
    }
    fn grad(&mut self, _node: usize, _x: &[f32], rng: &mut Rng, out: &mut [f32]) -> f64 {
        // cheap deterministic pseudo-gradient (no transcendental per lane —
        // the point is to measure the coordinator, not this function)
        let r = rng.next_u64() as f32 / u64::MAX as f32;
        let mut v = r;
        for o in out.iter_mut() {
            v = v * 0.9999 + 0.0001;
            *o = (v - 0.5) * 0.01;
        }
        0.0
    }
    fn global_loss(&mut self, _x: &[f32]) -> f64 {
        0.0
    }
}

fn mk(n: usize, d: usize, h: u64, always_fire: bool) -> DecentralizedEngine {
    let topo = Topology::new(TopologyKind::Ring, n, 0);
    SparqSgd::new(
        SparqConfig {
            mixing: uniform_neighbor(&topo),
            compressor: Box::new(SignTopK::new((d / 10).max(1))),
            trigger: EventTrigger::new(if always_fire {
                ThresholdSchedule::Zero
            } else {
                ThresholdSchedule::Constant(1e12)
            }),
            lr: LrSchedule::Constant(0.01),
            sync: SyncSchedule::EveryH(h),
            gamma: None,
            momentum: 0.0,
            seed: 1,
        },
        d,
    )
}

fn main() {
    let mut b = Bencher::new("round").with_budget(150, 600);

    for (n, d) in [(60usize, 7850usize), (8, 394_634)] {
        let mut src = NullGrad { d, n };
        let mut bus = Bus::new(n);

        // Full sync round, everyone transmits (worst case).
        let mut algo = mk(n, d, 1, true);
        let mut t = 0u64;
        b.bench_throughput(
            &format!("sync-round-all-fire/n={n},d={d}"),
            (n * d) as u64,
            || {
                algo.step(t, &mut src, &mut bus);
                t += 1;
            },
        );

        // Sync round where nobody fires (trigger suppresses everything):
        // measures the trigger-check + local-step floor.
        let mut algo = mk(n, d, 1, false);
        let mut t = 0u64;
        b.bench_throughput(
            &format!("sync-round-silent/n={n},d={d}"),
            (n * d) as u64,
            || {
                algo.step(t, &mut src, &mut bus);
                t += 1;
            },
        );

        // Local-only iteration (no sync): the H−1 out of H fast path.
        let mut algo = mk(n, d, 1_000_000, true);
        let mut t = 0u64;
        b.bench_throughput(
            &format!("local-step-only/n={n},d={d}"),
            (n * d) as u64,
            || {
                algo.step(t, &mut src, &mut bus);
                t += 1;
            },
        );
    }

    // Pure quadratic-problem round (gradient math included) at fig-1a size.
    let n = 60;
    let d = 7850;
    let mut src = QuadraticProblem::new(d, n, 0.5, 2.0, 0.05, 1.0, 3);
    let mut bus = Bus::new(n);
    let mut algo = mk(n, d, 5, true);
    let mut t = 0u64;
    b.bench_throughput(
        &format!("sync-round+quadratic-grad/n={n},d={d}"),
        (n * d) as u64,
        || {
            algo.step(t, &mut src, &mut bus);
            t += 1;
        },
    );
}
