//! Trigger ablation (the paper's headline mechanism, measured):
//! firing-rate and bits as a function of the threshold constant c₀, plus
//! the cost of the trigger check itself. End-to-end: a fixed-budget SPARQ
//! run per c₀ on the known-optimum quadratic, reporting (fire fraction,
//! total bits, final gap) — the knob behind Remark 1(iii).

use sparq::comm::Bus;
use sparq::compress::SignTopK;
use sparq::coordinator::{DecentralizedAlgo, SparqConfig, SparqSgd};
use sparq::graph::{uniform_neighbor, Topology, TopologyKind};
use sparq::problems::QuadraticProblem;
use sparq::schedule::{LrSchedule, SyncSchedule};
use sparq::trigger::{EventTrigger, ThresholdSchedule};
use sparq::util::bench::Bencher;
use sparq::util::Rng;

fn run_with_c0(c0: f64, steps: u64) -> (f64, u64, f64) {
    let (n, d) = (8, 64);
    let topo = Topology::new(TopologyKind::Ring, n, 0);
    let cfg = SparqConfig {
        mixing: uniform_neighbor(&topo),
        compressor: Box::new(SignTopK::new(16)),
        trigger: EventTrigger::new(if c0 == 0.0 {
            ThresholdSchedule::Zero
        } else {
            ThresholdSchedule::Poly { c0, eps: 0.5 }
        }),
        lr: LrSchedule::InverseTime { a: 60.0, b: 2.0 },
        sync: SyncSchedule::EveryH(5),
        gamma: None,
        momentum: 0.0,
        seed: 9,
    };
    let mut algo = SparqSgd::new(cfg, d);
    let mut prob = QuadraticProblem::new(d, n, 0.5, 2.0, 0.1, 0.5, 10);
    let mut bus = Bus::new(n);
    for t in 0..steps {
        algo.step(t, &mut prob, &mut bus);
    }
    let fire_frac = algo.total_fired as f64 / algo.total_checks.max(1) as f64;
    (fire_frac, bus.total_bits, prob.suboptimality(&algo.x_bar()))
}

fn main() {
    // Part 1: the trigger-check microcost (a norm over d floats).
    let mut b = Bencher::new("trigger").with_budget(100, 300);
    for d in [7850usize, 394_634] {
        let mut rng = Rng::new(1);
        let mut x = vec![0.0f32; d];
        let mut y = vec![0.0f32; d];
        rng.fill_normal(&mut x, 1.0);
        rng.fill_normal(&mut y, 1.0);
        let trig = EventTrigger::new(ThresholdSchedule::Constant(10.0));
        b.bench_throughput(&format!("check/d={d}"), d as u64, || {
            trig.fires(&x, &y, 100, 0.01)
        });
    }

    // Part 2: ablation table over c₀ (fixed 4000-step budget).
    println!("\ntrigger ablation (n=8 ring, d=64, H=5, SignTopK k=16, T=4000)");
    println!(
        "{:>10} {:>12} {:>14} {:>14} {:>12}",
        "c0", "fire rate", "total bits", "final gap", "bits saved"
    );
    let (base_fire, base_bits, base_gap) = run_with_c0(0.0, 4000);
    println!(
        "{:>10} {:>11.1}% {:>14} {:>14.6} {:>12}",
        "0 (off)",
        base_fire * 100.0,
        base_bits,
        base_gap,
        "-"
    );
    for c0 in [10.0, 50.0, 200.0, 1000.0, 5000.0] {
        let (fire, bits, gap) = run_with_c0(c0, 4000);
        println!(
            "{:>10} {:>11.1}% {:>14} {:>14.6} {:>11.1}x",
            c0,
            fire * 100.0,
            bits,
            gap,
            base_bits as f64 / bits.max(1) as f64
        );
    }
}
