//! Figure 1d bench — non-convex: top-1 accuracy vs total transmitted
//! bits; savings factors at the target accuracy (paper: 250× vs
//! CHOCO-Sign, 1000× vs CHOCO-TopK, 15K× vs vanilla).

use sparq::experiments::{fig1, savings};

fn main() {
    println!("=== Fig 1d (scaled): top-1 accuracy vs total bits ===\n");
    let steps = 1500u64;
    let suite = fig1::nonconvex_suite(steps, 50, 7, "mlp:256:32:10:8");
    let series = fig1::run_suite(suite, false);

    println!("{:<44} {:>12} {:>14}", "algorithm", "final top-1", "total bits");
    for s in &series {
        let last = s.records.last().unwrap();
        println!(
            "{:<44} {:>11.1}% {:>14.3e}",
            s.label,
            (1.0 - last.test_error) * 100.0,
            last.bits as f64
        );
    }

    for target_err in [0.35, 0.25] {
        println!(
            "\n--- bits to reach top-1 ≥ {:.0}% ---",
            (1.0 - target_err) * 100.0
        );
        println!("{}", fig1::savings_table(&series, target_err));
        for (idx, label) in [
            (1, "SPARQ (no trigger)"),
            (2, "CHOCO-SGD (Sign)"),
            (3, "CHOCO-SGD (TopK)"),
            (4, "vanilla"),
        ] {
            match savings::savings_factor(&series, 0, idx, target_err) {
                Some(f) => println!("  SPARQ saves {f:>8.1}x vs {label}"),
                None => println!("  SPARQ vs {label}: target not reached"),
            }
        }
    }
    println!("\npaper (CIFAR-10 ResNet-20, top-1 90%): 250x vs CHOCO-Sign, 1000x vs CHOCO-TopK, 15000x vs vanilla");
}
