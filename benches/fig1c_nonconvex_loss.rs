//! Figure 1c bench — non-convex: training loss vs epochs for SPARQ
//! (with/without trigger), CHOCO (Sign/TopK) and vanilla, at the scaled
//! MLP setting (n = 8 ring, momentum 0.9, H = 5), plus per-round timing.

use sparq::experiments::fig1;
use sparq::util::bench::Bencher;

fn main() {
    println!("=== Fig 1c (scaled): training loss vs epochs ===\n");
    let spe = 50usize;
    let steps = 1500u64;
    let suite = fig1::nonconvex_suite(steps, spe, 7, "mlp:256:32:10:8");
    let series = fig1::run_suite(suite, false);

    println!(
        "{:<44} {:>10} {:>10} {:>10} {:>10}",
        "algorithm", "ep 0", "ep 10", "ep 20", "ep 30"
    );
    for s in &series {
        let at_epoch = |e: usize| {
            s.records
                .iter()
                .find(|r| r.t as usize >= e * spe)
                .map(|r| format!("{:.3}", r.loss))
                .unwrap_or_else(|| "-".into())
        };
        println!(
            "{:<44} {:>10} {:>10} {:>10} {:>10}",
            s.label,
            at_epoch(0),
            at_epoch(10),
            at_epoch(20),
            at_epoch(30)
        );
    }

    // every curve must actually train
    for s in &series {
        let first = s.records.first().unwrap().loss;
        let last = s.records.last().unwrap().loss;
        assert!(last < first, "{} failed to reduce loss", s.label);
    }

    // per-round wall time (coordination + MLP grads)
    println!();
    let mut b = Bencher::new("fig1c-round").with_budget(100, 400);
    let mut suite = fig1::nonconvex_suite(steps, spe, 7, "mlp:256:32:10:8");
    let (label, cfg) = suite.remove(0);
    let mut problem = sparq::experiments::build_problem(&cfg);
    let d = problem.dim();
    let mut algo = sparq::experiments::build_algo(&cfg, d);
    let mut bus = sparq::comm::Bus::new(cfg.nodes);
    let mut t = 0u64;
    b.bench(&format!("{label} (d={d})"), || {
        algo.step(t, problem.as_mut(), &mut bus);
        t += 1;
    });
}
