//! Scale-out bench (EXPERIMENTS.md §Scale): step throughput as the node
//! count grows 16 → 256 → 4096 on a constant-degree ring — the workload
//! the sparse O(|E|) mixing state, the fused trigger→compress pass, and
//! the block-claiming thread pool exist for. With per-round cost
//! proportional to edges, rounds/sec should fall roughly linearly in n
//! (|E| = n on a ring), not quadratically like the old dense-matrix
//! coordinator.
//!
//! Also timed: graph construction + the spectral solve at n = 4096 for
//! ring / torus / regular4 (the Lanczos path — dense Jacobi at this n
//! would be an O(n³) non-starter), reported as info keys.
//!
//! A machine-readable summary is written to `BENCH_scale_n.json`
//! (override with `--out <path>`); CI gates the three rounds/sec keys
//! via `sparq perfgate --keys n16_rounds_per_sec,...`.
//!
//!     cargo bench --bench scale_n [-- --out results/scale.json]

use std::time::Instant;

use sparq::comm::Bus;
use sparq::compress::SignTopK;
use sparq::coordinator::{DecentralizedAlgo, DecentralizedEngine, SparqConfig, SparqSgd};
use sparq::graph::{uniform_neighbor, SpectralInfo, Topology, TopologyKind};
use sparq::problems::GradientSource;
use sparq::schedule::{LrSchedule, SyncSchedule};
use sparq::trigger::{EventTrigger, ThresholdSchedule};
use sparq::util::bench::Bencher;
use sparq::util::cli::Args;
use sparq::util::json::Json;
use sparq::util::Rng;

const D: usize = 256;
const K: usize = D / 10;
const SIZES: [usize; 3] = [16, 256, 4096];

/// Cheap deterministic pseudo-gradient source (same shape as the
/// sparse_fastpath bench): isolates coordinator pipeline cost from model
/// math while still exercising the parallel gradient phase.
struct NullGrad {
    d: usize,
    n: usize,
}

impl NullGrad {
    fn fill(&self, rng: &mut Rng, out: &mut [f32]) {
        let r = rng.next_u64() as f32 / u64::MAX as f32;
        let mut v = r;
        for o in out.iter_mut() {
            v = v * 0.9999 + 0.0001;
            *o = (v - 0.5) * 0.01;
        }
    }
}

impl GradientSource for NullGrad {
    fn dim(&self) -> usize {
        self.d
    }
    fn n_nodes(&self) -> usize {
        self.n
    }
    fn grad(&mut self, _node: usize, _x: &[f32], rng: &mut Rng, out: &mut [f32]) -> f64 {
        self.fill(rng, out);
        0.0
    }
    fn shared(&self) -> Option<&(dyn GradientSource + Sync)> {
        Some(self)
    }
    fn grad_shared(&self, _node: usize, _x: &[f32], rng: &mut Rng, out: &mut [f32]) -> f64 {
        self.fill(rng, out);
        0.0
    }
    fn global_loss(&mut self, _x: &[f32]) -> f64 {
        0.0
    }
}

fn mk_ring_sparq(n: usize, workers: usize) -> DecentralizedEngine {
    let topo = Topology::new(TopologyKind::Ring, n, 0);
    let mut algo = SparqSgd::new(
        SparqConfig {
            mixing: uniform_neighbor(&topo),
            compressor: Box::new(SignTopK::new(K)),
            trigger: EventTrigger::new(ThresholdSchedule::Constant(1e-4)),
            lr: LrSchedule::Constant(0.01),
            sync: SyncSchedule::EveryH(1),
            gamma: None,
            momentum: 0.0,
            seed: 1,
        },
        D,
    );
    algo.set_workers(workers);
    algo
}

/// Wall-clock one construction + spectral solve (ms) for a topology at
/// n = 4096 — the O(|E|) Lanczos path.
fn time_build_and_solve(kind: TopologyKind) -> (f64, f64) {
    let t0 = Instant::now();
    let topo = Topology::new(kind, 4096, 11);
    let mm = uniform_neighbor(&topo);
    let build_ms = t0.elapsed().as_secs_f64() * 1e3;
    let t1 = Instant::now();
    let s = SpectralInfo::compute(&mm);
    let solve_ms = t1.elapsed().as_secs_f64() * 1e3;
    assert!((s.lambda1 - 1.0).abs() < 1e-6);
    (build_ms, solve_ms)
}

fn main() {
    let args = Args::from_env();
    let out_path = args.get_or("out", "BENCH_scale_n.json");
    let workers = args.usize("workers", 8);
    println!("scale_n: ring, d={D}, SignTopK k={K}, H=1, workers={workers}, n in {SIZES:?}");

    let mut b = Bencher::new("scale_n").with_budget(300, 2000);
    let mut json = Json::obj()
        .set("bench", "scale_n")
        .set("d", D)
        .set("k", K)
        .set("workers", workers);

    for n in SIZES {
        let mut src = NullGrad { d: D, n };
        let mut algo = mk_ring_sparq(n, workers);
        let mut bus = Bus::new(n);
        let mut t = 0u64;
        let r = b.bench_throughput(&format!("ring-n={n}"), (n * D) as u64, || {
            algo.step(t, &mut src, &mut bus);
            t += 1;
        });
        let rounds_per_sec = 1e9 / r.mean_ns;
        json = json
            .set(&format!("n{n}_rounds_per_sec"), rounds_per_sec)
            .set(&format!("n{n}_ns_per_step"), r.mean_ns)
            .set(&format!("n{n}_node_steps_per_sec"), n as f64 / (r.mean_ns * 1e-9));
    }

    // Construction + spectral timings at n = 4096 across topology
    // families (info keys — not gated; they vary with machine load).
    for (label, kind) in [
        ("ring", TopologyKind::Ring),
        ("torus", TopologyKind::Torus),
        ("regular4", TopologyKind::RandomRegular(4)),
    ] {
        let (build_ms, solve_ms) = time_build_and_solve(kind);
        println!("n=4096 {label}: build {build_ms:.1} ms, spectral {solve_ms:.1} ms");
        json = json
            .set(&format!("n4096_{label}_build_ms"), build_ms)
            .set(&format!("n4096_{label}_spectral_ms"), solve_ms);
    }

    std::fs::write(&out_path, json.to_string_pretty()).expect("write bench json");
    println!("wrote {out_path}");
}
