//! Figure 1a bench — convex: communication rounds to reach the target
//! test error, per algorithm, plus per-round wall time.
//!
//! Scaled configuration (n = 12 ring, logreg 96→10) so the whole bench
//! finishes in seconds while keeping the paper's *shape*: SPARQ reaches
//! the target in the fewest communication rounds at comparable iteration
//! counts.

use sparq::experiments::fig1;
use sparq::metrics::Series;
use sparq::util::bench::Bencher;
use std::time::Instant;

fn scaled_suite(steps: u64) -> Vec<(String, sparq::config::ExperimentConfig)> {
    let mut suite = fig1::convex_suite(steps, 7);
    for (_, cfg) in suite.iter_mut() {
        cfg.nodes = 12;
        cfg.problem = "logreg:96:10:5".into();
        if cfg.compressor == "sign_topk:10" {
            cfg.compressor = "sign_topk:5%".into();
        }
        cfg.trigger = "const:100".into();
        cfg.eval_every = 40;
    }
    suite
}

fn main() {
    println!("=== Fig 1a (scaled): test error vs communication rounds ===\n");
    let mut b = Bencher::new("fig1a").with_budget(0, 1);

    let suite = scaled_suite(2400);
    let mut results: Vec<(String, Series, f64)> = Vec::new();
    for (label, cfg) in suite {
        let t0 = Instant::now();
        let series = sparq::experiments::run_config(&cfg, false);
        let wall = t0.elapsed().as_secs_f64();
        // per-round timing via the harness (one short re-run window)
        let mut problem = sparq::experiments::build_problem(&cfg);
        let d = problem.dim();
        let mut algo = sparq::experiments::build_algo(&cfg, d);
        let mut bus = sparq::comm::Bus::new(cfg.nodes);
        let mut t = 0u64;
        b.bench(&format!("round/{label}"), || {
            algo.step(t, problem.as_mut(), &mut bus);
            t += 1;
        });
        results.push((label, series, wall));
    }

    println!("\n{:<38} {:>10} {:>14} {:>12}", "algorithm", "run (s)", "final err", "comm rounds");
    for (label, series, wall) in &results {
        let last = series.records.last().unwrap();
        println!(
            "{:<38} {:>10.2} {:>14.4} {:>12}",
            label, wall, last.test_error, last.comm_rounds
        );
    }

    for target in [0.3, 0.2, 0.15] {
        println!("\n--- comm rounds to reach test error ≤ {target} ---");
        for (label, series, _) in &results {
            match series.first_reaching_error(target) {
                Some(r) => println!("{:<38} {:>8} rounds (t = {})", label, r.comm_rounds, r.t),
                None => println!("{:<38} not reached", label),
            }
        }
    }
}
