//! Sparse-message fast path + parallel node execution vs the seed's dense
//! sequential coordinator (EXPERIMENTS.md §Perf, sparse fast path).
//!
//! Workload: the ISSUE-1 target point — n = 16 ring, d = 2²⁰, SignTopK
//! with k = d/100, H = 1, always-firing trigger (worst-case: every node
//! compresses and broadcasts every round). `DenseSequentialBaseline`
//! reimplements the seed hot loop verbatim (dense compress into a shared
//! buffer, dense O(d) estimate update, per-edge full-d `scale_add` with a
//! `neighbors.clone()` per round, all phases sequential) on top of the
//! same public operator APIs, so the comparison isolates the pipeline
//! restructuring from everything else.
//!
//! Acceptance target: ≥ 3× step throughput for the sparse + parallel
//! configuration. A machine-readable summary is written to
//! `BENCH_sparse_fastpath.json` (override with `--out <path>`) so future
//! PRs can regress against the perf trajectory.
//!
//!     cargo bench --bench sparse_fastpath [-- --out results/sfp.json]

use sparq::comm::Bus;
use sparq::compress::{Compressor, SignTopK};
use sparq::coordinator::{DecentralizedAlgo, DecentralizedEngine, SparqConfig, SparqSgd};
use sparq::graph::{uniform_neighbor, MixingMatrix, SpectralInfo, Topology, TopologyKind};
use sparq::linalg::vecops::{scale_add, sub_into};
use sparq::problems::GradientSource;
use sparq::schedule::{LrSchedule, SyncSchedule};
use sparq::trigger::{EventTrigger, ThresholdSchedule};
use sparq::util::bench::Bencher;
use sparq::util::cli::Args;
use sparq::util::json::Json;
use sparq::util::Rng;

const N: usize = 16;
const D: usize = 1 << 20;
const K: usize = D / 100;

/// Cheap deterministic pseudo-gradient source: isolates the coordinator
/// pipeline cost from model math, with shared-state support so the fast
/// path can exercise the parallel gradient phase too.
struct NullGrad {
    d: usize,
    n: usize,
}

impl NullGrad {
    fn fill(&self, rng: &mut Rng, out: &mut [f32]) {
        let r = rng.next_u64() as f32 / u64::MAX as f32;
        let mut v = r;
        for o in out.iter_mut() {
            v = v * 0.9999 + 0.0001;
            *o = (v - 0.5) * 0.01;
        }
    }
}

impl GradientSource for NullGrad {
    fn dim(&self) -> usize {
        self.d
    }
    fn n_nodes(&self) -> usize {
        self.n
    }
    fn grad(&mut self, _node: usize, _x: &[f32], rng: &mut Rng, out: &mut [f32]) -> f64 {
        self.fill(rng, out);
        0.0
    }
    fn shared(&self) -> Option<&(dyn GradientSource + Sync)> {
        Some(self)
    }
    fn grad_shared(&self, _node: usize, _x: &[f32], rng: &mut Rng, out: &mut [f32]) -> f64 {
        self.fill(rng, out);
        0.0
    }
    fn global_loss(&mut self, _x: &[f32]) -> f64 {
        0.0
    }
}

/// The seed coordinator hot loop, verbatim: dense messages, shared
/// scratch, per-edge consensus, fully sequential.
struct DenseSequentialBaseline {
    mixing: MixingMatrix,
    compressor: Box<dyn Compressor>,
    lr: LrSchedule,
    x: Vec<Vec<f32>>,
    x_half: Vec<Vec<f32>>,
    grad: Vec<Vec<f32>>,
    xhat: Vec<Vec<f32>>,
    rngs: Vec<Rng>,
    diff: Vec<f32>,
    qbuf: Vec<f32>,
    gamma: f32,
}

impl DenseSequentialBaseline {
    fn new(mixing: MixingMatrix, compressor: Box<dyn Compressor>, gamma: f32, seed: u64) -> Self {
        let n = mixing.n();
        let mut root = Rng::new(seed);
        DenseSequentialBaseline {
            mixing,
            compressor,
            lr: LrSchedule::Constant(0.01),
            x: vec![vec![0.0; D]; n],
            x_half: vec![vec![0.0; D]; n],
            grad: vec![vec![0.0; D]; n],
            xhat: vec![vec![0.0; D]; n],
            rngs: (0..n).map(|i| root.fork(i as u64)).collect(),
            diff: vec![0.0; D],
            qbuf: vec![0.0; D],
            gamma,
        }
    }

    fn step(&mut self, t: u64, src: &mut dyn GradientSource, bus: &mut Bus) {
        let n = self.x.len();
        let eta = self.lr.eta(t) as f32;
        for i in 0..n {
            src.grad(i, &self.x[i], &mut self.rngs[i], &mut self.grad[i]);
            for ((xh, xi), gi) in self.x_half[i]
                .iter_mut()
                .zip(self.x[i].iter())
                .zip(self.grad[i].iter())
            {
                *xh = xi - eta * gi;
            }
        }
        // Every node fires (ThresholdSchedule::Zero equivalent at this
        // drift); dense compress + dense estimate update.
        let bits = self.compressor.encoded_bits(D);
        for i in 0..n {
            sub_into(&self.x_half[i], &self.xhat[i], &mut self.diff);
            self.compressor
                .compress(&self.diff, &mut self.rngs[i], &mut self.qbuf);
            bus.charge_broadcast(i, self.mixing.topology.degree(i), bits);
            for (h, qv) in self.xhat[i].iter_mut().zip(self.qbuf.iter()) {
                *h += qv;
            }
        }
        // Per-edge dense consensus with the seed's per-round clone.
        for i in 0..n {
            std::mem::swap(&mut self.x[i], &mut self.x_half[i]);
        }
        for i in 0..n {
            let neighbors = self.mixing.topology.neighbors[i].clone();
            for j in neighbors {
                let w = self.mixing.weight(i, j) as f32;
                if w == 0.0 {
                    continue;
                }
                let (xh_j, xh_i): (&[f32], &[f32]) = (&self.xhat[j], &self.xhat[i]);
                scale_add(&mut self.x[i], self.gamma * w, xh_j, xh_i);
            }
        }
        bus.end_round();
    }
}

fn mk_sparq(workers: usize) -> DecentralizedEngine {
    let topo = Topology::new(TopologyKind::Ring, N, 0);
    let mut algo = SparqSgd::new(
        SparqConfig {
            mixing: uniform_neighbor(&topo),
            compressor: Box::new(SignTopK::new(K)),
            trigger: EventTrigger::new(ThresholdSchedule::Zero),
            lr: LrSchedule::Constant(0.01),
            sync: SyncSchedule::EveryH(1),
            gamma: None,
            momentum: 0.0,
            seed: 1,
        },
        D,
    );
    algo.set_workers(workers);
    algo
}

fn main() {
    let args = Args::from_env();
    let out_path = args.get_or("out", "BENCH_sparse_fastpath.json");
    println!("sparse_fastpath: n={N}, d={D} (2^20), k={K} (d/100), SignTopK, H=1, all fire");

    let mut b = Bencher::new("sparse_fastpath").with_budget(400, 2500);
    let mut src = NullGrad { d: D, n: N };

    // --- dense sequential baseline (the seed hot loop) ---
    let baseline_ns;
    {
        let topo = Topology::new(TopologyKind::Ring, N, 0);
        let mixing = uniform_neighbor(&topo);
        // identical consensus step size to the SparqSgd runs below
        let op = SignTopK::new(K);
        let gamma = SpectralInfo::compute(&mixing)
            .gamma_tuned(op.omega(D), op.effective_omega(D)) as f32;
        let mut base =
            DenseSequentialBaseline::new(mixing, Box::new(SignTopK::new(K)), gamma, 1);
        let mut bus = Bus::new(N);
        let mut t = 0u64;
        let r = b.bench_throughput("dense-sequential", (N * D) as u64, || {
            base.step(t, &mut src, &mut bus);
            t += 1;
        });
        baseline_ns = r.mean_ns;
    }

    // --- sparse pipeline, sequential (isolates the O(k) message path) ---
    let sparse_seq_ns;
    {
        let mut algo = mk_sparq(1);
        let mut bus = Bus::new(N);
        let mut t = 0u64;
        let r = b.bench_throughput("sparse-workers=1", (N * D) as u64, || {
            algo.step(t, &mut src, &mut bus);
            t += 1;
        });
        sparse_seq_ns = r.mean_ns;
    }

    // --- sparse pipeline + parallel node phases (the full fast path) ---
    let workers = args.usize("workers", 8);
    let sparse_par_ns;
    let bits_per_round;
    {
        let mut algo = mk_sparq(workers);
        let mut bus = Bus::new(N);
        let mut t = 0u64;
        let r = b.bench_throughput(&format!("sparse-workers={workers}"), (N * D) as u64, || {
            algo.step(t, &mut src, &mut bus);
            t += 1;
        });
        sparse_par_ns = r.mean_ns;
        bits_per_round = bus.total_bits / t.max(1);
    }

    let speedup_seq = baseline_ns / sparse_seq_ns;
    let speedup = baseline_ns / sparse_par_ns;
    println!(
        "\nspeedup vs dense-sequential: sparse seq {speedup_seq:.2}x, \
         sparse + {workers} workers {speedup:.2}x (target >= 3x)"
    );
    println!("bits per sync round: {bits_per_round} (wire-exact accounting)");

    let json = Json::obj()
        .set("bench", "sparse_fastpath")
        .set("n", N)
        .set("d", D)
        .set("k", K)
        .set("workers", workers)
        .set("dense_sequential_ns_per_step", baseline_ns)
        .set("sparse_seq_ns_per_step", sparse_seq_ns)
        .set("sparse_parallel_ns_per_step", sparse_par_ns)
        .set("speedup_sparse_seq", speedup_seq)
        .set("speedup_sparse_parallel", speedup)
        .set("bits_per_round", bits_per_round)
        .set(
            "node_steps_per_sec",
            N as f64 / (sparse_par_ns * 1e-9),
        );
    std::fs::write(&out_path, json.to_string_pretty()).expect("write bench json");
    println!("wrote {out_path}");
}
