//! The serve wire protocol: CRC-framed JSON messages over a byte stream.
//!
//! Transport framing reuses the PR 6 codec (`comm::wire::frame` /
//! `unframe`): every message is `[len:u32 LE][crc32:u32 LE][payload]`
//! where the payload is one UTF-8 JSON object carrying a `"type"` tag.
//! The socket is the crate's first genuinely untrusted input boundary,
//! so every decode layer is fallible and bounded:
//!
//! * the declared length is capped at [`MAX_FRAME_BYTES`] (an insane
//!   length field means the stream is garbage — fatal for the
//!   connection, since framing sync is lost);
//! * a checksum mismatch with a sane length keeps the stream in sync —
//!   the server answers [`Response::Error`] and keeps the connection;
//! * payloads go through `util::json` (depth-bounded since this PR) and
//!   the typed [`Request`]/[`Response`] parsers, which reject unknown
//!   tags and ill-typed fields with a message instead of panicking.

use std::io::{ErrorKind, Read, Write};
use std::net::TcpStream;
#[cfg(unix)]
use std::os::unix::net::UnixStream;
use std::time::Duration;

use crate::comm::wire::{frame, unframe, FRAME_OVERHEAD};
use crate::util::json::Json;

/// Hard cap on one frame's declared payload length. Specs are a few KB;
/// anything near this is a corrupted or hostile length field.
pub const MAX_FRAME_BYTES: usize = 8 * 1024 * 1024;

/// One client → server message.
#[derive(Clone, Debug, PartialEq)]
pub enum Request {
    /// Liveness probe; answered with [`Response::Pong`].
    Ping,
    /// Submit a sweep spec (the JSON form of `sweep::SweepSpec`) for
    /// execution. Higher `priority` schedules first; ties run in
    /// submission order.
    Submit { spec: Json, priority: i64 },
    /// Switch this connection to a subscription: the server streams
    /// [`Response::Event`] frames until either side closes. With
    /// `from_start`, the full event log since daemon start replays
    /// first, so every subscriber observes the identical sequence.
    Watch { from_start: bool },
    /// Snapshot the job queue and the live claim/heartbeat table.
    Status,
    /// Gracefully stop the daemon (in-flight runs are abandoned to
    /// their checkpoints + claims, exactly like a crash — the next
    /// daemon takes them over bit-identically).
    Shutdown,
    /// Cancel a queued job: every slot not yet running is released,
    /// the persisted job file is marked so a restarted daemon skips
    /// it, and a `job-cancelled` event streams to subscribers. Runs
    /// already executing finish normally (their results are recorded —
    /// cancellation never discards work in flight).
    Cancel { job: String },
    /// Authenticate this connection. A daemon started with
    /// `--auth-token` requires this as the **first** request on every
    /// connection; without a configured token it is an accepted no-op,
    /// so clients can send it unconditionally.
    Auth { token: String },
}

/// One server → client message.
#[derive(Clone, Debug, PartialEq)]
pub enum Response {
    /// Liveness answer (`version` is the daemon's crate version).
    Pong { version: String },
    /// A submission passed admission: `runs` expanded runs under `job`.
    Accepted { job: String, runs: usize },
    /// A submission failed admission (spec parse, config resolve, or a
    /// run-id collision). The text matches what `sparq check` prints
    /// for the same spec.
    Rejected { error: String },
    /// Queue + claim snapshot.
    Status {
        jobs: Vec<JobStatus>,
        claims: Vec<ClaimView>,
    },
    /// One subscription event. `seq` is the event's index in the
    /// daemon-lifetime log (contiguous from 0 for `from_start`
    /// subscribers).
    Event { seq: u64, event: Json },
    /// A cancel succeeded: `released` slots were freed (0 when every
    /// remaining run was already executing or settled).
    Cancelled { job: String, released: usize },
    /// A malformed frame or request (the connection stays open when
    /// framing sync is intact), a failed cancel, or an authentication
    /// failure (the connection closes after an auth error).
    Error { error: String },
    /// Plain acknowledgement (shutdown, auth).
    Ok,
}

/// One job's row in a [`Response::Status`] snapshot.
#[derive(Clone, Debug, PartialEq)]
pub struct JobStatus {
    pub job: String,
    pub name: String,
    pub priority: i64,
    /// Expanded runs in the job.
    pub total: usize,
    /// Runs with a durable result record.
    pub done: usize,
    /// Runs that failed deterministically (not retried until restart).
    pub failed: usize,
    /// Runs released by a [`Request::Cancel`] before they started.
    pub cancelled: usize,
    /// "queued" | "running" | "complete" | "cancelled".
    pub state: String,
}

/// One held claim in a [`Response::Status`] snapshot (the same fields
/// `sparq sweep status` renders, serialized for the remote endpoint).
#[derive(Clone, Debug, PartialEq)]
pub struct ClaimView {
    pub id: String,
    pub owner: String,
    pub age_secs: f64,
    pub heartbeats: u64,
}

impl JobStatus {
    pub fn to_json(&self) -> Json {
        Json::obj()
            .set("job", self.job.as_str())
            .set("name", self.name.as_str())
            .set("priority", self.priority)
            .set("total", self.total)
            .set("done", self.done)
            .set("failed", self.failed)
            .set("cancelled", self.cancelled)
            .set("state", self.state.as_str())
    }

    pub fn from_json(j: &Json) -> Result<JobStatus, String> {
        Ok(JobStatus {
            job: req_str(j, "job")?,
            name: req_str(j, "name")?,
            priority: j.get("priority").and_then(Json::as_f64).unwrap_or(0.0) as i64,
            total: req_usize(j, "total")?,
            done: req_usize(j, "done")?,
            failed: req_usize(j, "failed")?,
            // Absent in records written by pre-cancel daemons.
            cancelled: j.get("cancelled").and_then(Json::as_usize).unwrap_or(0),
            state: req_str(j, "state")?,
        })
    }
}

impl ClaimView {
    pub fn to_json(&self) -> Json {
        Json::obj()
            .set("id", self.id.as_str())
            .set("owner", self.owner.as_str())
            .set("age_secs", crate::metrics::float_json(self.age_secs))
            .set("heartbeats", self.heartbeats)
    }

    pub fn from_json(j: &Json) -> Result<ClaimView, String> {
        Ok(ClaimView {
            id: req_str(j, "id")?,
            owner: req_str(j, "owner")?,
            age_secs: j
                .get("age_secs")
                .map(crate::metrics::json_f64_lossy)
                .unwrap_or(f64::NAN),
            heartbeats: j.get("heartbeats").and_then(Json::as_u64).unwrap_or(0),
        })
    }
}

fn req_str(j: &Json, key: &str) -> Result<String, String> {
    j.get(key)
        .and_then(Json::as_str)
        .map(str::to_string)
        .ok_or_else(|| format!("message missing string field {key:?}"))
}

fn req_usize(j: &Json, key: &str) -> Result<usize, String> {
    j.get(key)
        .and_then(Json::as_usize)
        .ok_or_else(|| format!("message field {key:?} must be a non-negative integer"))
}

impl Request {
    pub fn to_json(&self) -> Json {
        match self {
            Request::Ping => Json::obj().set("type", "ping"),
            Request::Submit { spec, priority } => Json::obj()
                .set("type", "submit")
                .set("spec", spec.clone())
                .set("priority", *priority),
            Request::Watch { from_start } => Json::obj()
                .set("type", "watch")
                .set("from_start", *from_start),
            Request::Status => Json::obj().set("type", "status"),
            Request::Shutdown => Json::obj().set("type", "shutdown"),
            Request::Cancel { job } => {
                Json::obj().set("type", "cancel").set("job", job.as_str())
            }
            Request::Auth { token } => {
                Json::obj().set("type", "auth").set("token", token.as_str())
            }
        }
    }

    pub fn from_json(j: &Json) -> Result<Request, String> {
        match j.get("type").and_then(Json::as_str) {
            Some("ping") => Ok(Request::Ping),
            Some("submit") => Ok(Request::Submit {
                spec: j.get("spec").cloned().ok_or("submit carries no spec")?,
                priority: j.get("priority").and_then(Json::as_f64).unwrap_or(0.0) as i64,
            }),
            Some("watch") => Ok(Request::Watch {
                from_start: j.get("from_start").and_then(Json::as_bool).unwrap_or(true),
            }),
            Some("status") => Ok(Request::Status),
            Some("shutdown") => Ok(Request::Shutdown),
            Some("cancel") => Ok(Request::Cancel {
                job: req_str(j, "job")?,
            }),
            Some("auth") => Ok(Request::Auth {
                token: req_str(j, "token")?,
            }),
            Some(other) => Err(format!("unknown request type {other:?}")),
            None => Err("request has no type field".into()),
        }
    }
}

impl Response {
    pub fn to_json(&self) -> Json {
        match self {
            Response::Pong { version } => Json::obj()
                .set("type", "pong")
                .set("version", version.as_str()),
            Response::Accepted { job, runs } => Json::obj()
                .set("type", "accepted")
                .set("job", job.as_str())
                .set("runs", *runs),
            Response::Rejected { error } => Json::obj()
                .set("type", "rejected")
                .set("error", error.as_str()),
            Response::Status { jobs, claims } => Json::obj()
                .set("type", "status")
                .set(
                    "jobs",
                    Json::Arr(jobs.iter().map(JobStatus::to_json).collect()),
                )
                .set(
                    "claims",
                    Json::Arr(claims.iter().map(ClaimView::to_json).collect()),
                ),
            Response::Event { seq, event } => Json::obj()
                .set("type", "event")
                .set("seq", *seq)
                .set("event", event.clone()),
            Response::Cancelled { job, released } => Json::obj()
                .set("type", "cancelled")
                .set("job", job.as_str())
                .set("released", *released),
            Response::Error { error } => Json::obj()
                .set("type", "error")
                .set("error", error.as_str()),
            Response::Ok => Json::obj().set("type", "ok"),
        }
    }

    pub fn from_json(j: &Json) -> Result<Response, String> {
        match j.get("type").and_then(Json::as_str) {
            Some("pong") => Ok(Response::Pong {
                version: req_str(j, "version")?,
            }),
            Some("accepted") => Ok(Response::Accepted {
                job: req_str(j, "job")?,
                runs: req_usize(j, "runs")?,
            }),
            Some("rejected") => Ok(Response::Rejected {
                error: req_str(j, "error")?,
            }),
            Some("status") => {
                let arr = |key: &str| -> Result<Vec<Json>, String> {
                    j.get(key)
                        .and_then(Json::as_arr)
                        .map(<[Json]>::to_vec)
                        .ok_or_else(|| format!("status carries no {key} array"))
                };
                Ok(Response::Status {
                    jobs: arr("jobs")?
                        .iter()
                        .map(JobStatus::from_json)
                        .collect::<Result<_, _>>()?,
                    claims: arr("claims")?
                        .iter()
                        .map(ClaimView::from_json)
                        .collect::<Result<_, _>>()?,
                })
            }
            Some("event") => Ok(Response::Event {
                seq: j
                    .get("seq")
                    .and_then(Json::as_u64)
                    .ok_or("event has no seq")?,
                event: j.get("event").cloned().ok_or("event carries no body")?,
            }),
            Some("cancelled") => Ok(Response::Cancelled {
                job: req_str(j, "job")?,
                released: req_usize(j, "released")?,
            }),
            Some("error") => Ok(Response::Error {
                error: req_str(j, "error")?,
            }),
            Some("ok") => Ok(Response::Ok),
            Some(other) => Err(format!("unknown response type {other:?}")),
            None => Err("response has no type field".into()),
        }
    }
}

/// What one [`read_frame`] call produced.
#[derive(Debug)]
pub enum FrameIn {
    /// A checksum-verified payload.
    Msg(Vec<u8>),
    /// A detected-corrupt frame. `fatal` means framing sync is lost
    /// (insane length field) and the connection must close; otherwise
    /// the stream is still aligned and the next frame is readable.
    Corrupt { error: String, fatal: bool },
    /// The peer closed the stream at a frame boundary.
    Eof,
    /// `should_stop` returned true while waiting for bytes.
    Stopped,
}

/// Write one framed message.
pub fn write_frame(w: &mut impl Write, payload: &[u8]) -> Result<(), String> {
    w.write_all(&frame(payload))
        .and_then(|_| w.flush())
        .map_err(|e| format!("write: {e}"))
}

/// Serialize + frame + send a message (request or response side).
pub fn write_msg(w: &mut impl Write, msg: &Json) -> Result<(), String> {
    write_frame(w, msg.to_string().as_bytes())
}

/// Read one frame. Read-timeout errors (`WouldBlock`/`TimedOut`) poll
/// `should_stop` and keep accumulating, so a server thread parked on a
/// quiet connection still notices shutdown; mid-frame EOF is corrupt
/// (truncated), EOF at a frame boundary is a clean close.
pub fn read_frame(r: &mut impl Read, should_stop: &dyn Fn() -> bool) -> Result<FrameIn, String> {
    let mut hdr = [0u8; FRAME_OVERHEAD];
    match read_exact_stoppable(r, &mut hdr, true, should_stop)? {
        ReadEnd::Done => {}
        ReadEnd::Eof => return Ok(FrameIn::Eof),
        ReadEnd::Stopped => return Ok(FrameIn::Stopped),
        ReadEnd::Truncated => {
            return Ok(FrameIn::Corrupt {
                error: "truncated frame header".into(),
                fatal: true,
            })
        }
    }
    let len = u32::from_le_bytes([hdr[0], hdr[1], hdr[2], hdr[3]]) as usize;
    if len > MAX_FRAME_BYTES {
        return Ok(FrameIn::Corrupt {
            error: format!("frame declares {len} payload bytes (cap {MAX_FRAME_BYTES})"),
            fatal: true,
        });
    }
    let mut buf = vec![0u8; FRAME_OVERHEAD + len];
    buf[..FRAME_OVERHEAD].copy_from_slice(&hdr);
    match read_exact_stoppable(r, &mut buf[FRAME_OVERHEAD..], false, should_stop)? {
        ReadEnd::Done => {}
        ReadEnd::Stopped => return Ok(FrameIn::Stopped),
        ReadEnd::Eof | ReadEnd::Truncated => {
            return Ok(FrameIn::Corrupt {
                error: "truncated frame payload".into(),
                fatal: true,
            })
        }
    }
    match unframe(&buf) {
        Ok(payload) => Ok(FrameIn::Msg(payload.to_vec())),
        // Length matched and the CRC failed: the stream is still frame-
        // aligned, so the connection survives the bad message.
        Err(e) => Ok(FrameIn::Corrupt {
            error: e.to_string(),
            fatal: false,
        }),
    }
}

enum ReadEnd {
    Done,
    /// EOF before the first byte (only reported when `eof_ok`).
    Eof,
    /// EOF after a partial read.
    Truncated,
    Stopped,
}

fn read_exact_stoppable(
    r: &mut impl Read,
    buf: &mut [u8],
    eof_ok: bool,
    should_stop: &dyn Fn() -> bool,
) -> Result<ReadEnd, String> {
    let mut filled = 0usize;
    while filled < buf.len() {
        match r.read(&mut buf[filled..]) {
            Ok(0) => {
                return Ok(if filled == 0 && eof_ok {
                    ReadEnd::Eof
                } else {
                    ReadEnd::Truncated
                })
            }
            Ok(n) => filled += n,
            Err(e) if matches!(e.kind(), ErrorKind::WouldBlock | ErrorKind::TimedOut) => {
                if should_stop() {
                    return Ok(ReadEnd::Stopped);
                }
            }
            Err(e) if e.kind() == ErrorKind::Interrupted => {}
            Err(e) => return Err(format!("read: {e}")),
        }
    }
    Ok(ReadEnd::Done)
}

/// Whether a `--socket` operand names a TCP endpoint: anything with a
/// `:` and no `/` is `host:port`; everything else is a Unix socket
/// path.
pub fn is_tcp_addr(s: &str) -> bool {
    !s.contains('/') && s.contains(':')
}

/// One connected duplex byte stream, Unix or TCP (both sides of the
/// protocol are transport-agnostic above this enum).
pub enum Stream {
    Tcp(TcpStream),
    #[cfg(unix)]
    Unix(UnixStream),
}

impl Stream {
    /// Connect to a daemon at a `--socket` operand (see [`is_tcp_addr`]).
    pub fn connect(addr: &str) -> Result<Stream, String> {
        if is_tcp_addr(addr) {
            TcpStream::connect(addr)
                .map(Stream::Tcp)
                .map_err(|e| format!("{addr}: {e}"))
        } else {
            #[cfg(unix)]
            {
                UnixStream::connect(addr)
                    .map(Stream::Unix)
                    .map_err(|e| format!("{addr}: {e}"))
            }
            #[cfg(not(unix))]
            Err(format!(
                "{addr}: unix socket paths are unsupported on this platform; use host:port"
            ))
        }
    }

    /// Bound blocking reads (lets server threads poll a shutdown flag).
    pub fn set_read_timeout(&self, d: Option<Duration>) -> Result<(), String> {
        match self {
            Stream::Tcp(s) => s.set_read_timeout(d).map_err(|e| e.to_string()),
            #[cfg(unix)]
            Stream::Unix(s) => s.set_read_timeout(d).map_err(|e| e.to_string()),
        }
    }
}

impl Read for Stream {
    fn read(&mut self, buf: &mut [u8]) -> std::io::Result<usize> {
        match self {
            Stream::Tcp(s) => s.read(buf),
            #[cfg(unix)]
            Stream::Unix(s) => s.read(buf),
        }
    }
}

impl Write for Stream {
    fn write(&mut self, buf: &[u8]) -> std::io::Result<usize> {
        match self {
            Stream::Tcp(s) => s.write(buf),
            #[cfg(unix)]
            Stream::Unix(s) => s.write(buf),
        }
    }

    fn flush(&mut self) -> std::io::Result<()> {
        match self {
            Stream::Tcp(s) => s.flush(),
            #[cfg(unix)]
            Stream::Unix(s) => s.flush(),
        }
    }
}

/// Decode a checksum-verified payload into a parsed JSON message.
pub fn parse_payload(payload: &[u8]) -> Result<Json, String> {
    let text = std::str::from_utf8(payload).map_err(|e| format!("payload is not UTF-8: {e}"))?;
    Json::parse(text).map_err(|e| format!("payload is not JSON: {e}"))
}

#[cfg(test)]
mod tests {
    use super::*;

    fn roundtrip_req(req: Request) {
        let j = req.to_json();
        assert_eq!(Request::from_json(&j).unwrap(), req);
        // and through the byte layer
        let mut wire = Vec::new();
        write_msg(&mut wire, &j).unwrap();
        let mut r = wire.as_slice();
        match read_frame(&mut r, &|| false).unwrap() {
            FrameIn::Msg(p) => {
                let back = parse_payload(&p).unwrap();
                assert_eq!(Request::from_json(&back).unwrap(), req);
            }
            other => panic!("expected Msg, got {other:?}"),
        }
    }

    fn roundtrip_resp(resp: Response) {
        let j = resp.to_json();
        assert_eq!(Response::from_json(&j).unwrap(), resp);
        let mut wire = Vec::new();
        write_msg(&mut wire, &j).unwrap();
        let mut r = wire.as_slice();
        match read_frame(&mut r, &|| false).unwrap() {
            FrameIn::Msg(p) => {
                let back = parse_payload(&p).unwrap();
                assert_eq!(Response::from_json(&back).unwrap(), resp);
            }
            other => panic!("expected Msg, got {other:?}"),
        }
    }

    #[test]
    fn every_request_kind_round_trips() {
        roundtrip_req(Request::Ping);
        roundtrip_req(Request::Submit {
            spec: Json::obj().set("name", "grid").set("base", Json::obj()),
            priority: -3,
        });
        roundtrip_req(Request::Watch { from_start: true });
        roundtrip_req(Request::Watch { from_start: false });
        roundtrip_req(Request::Status);
        roundtrip_req(Request::Shutdown);
        roundtrip_req(Request::Cancel {
            job: "job-00ff".into(),
        });
        roundtrip_req(Request::Auth {
            token: "s3cret".into(),
        });
    }

    #[test]
    fn every_response_kind_round_trips() {
        roundtrip_resp(Response::Pong {
            version: "0.1.0".into(),
        });
        roundtrip_resp(Response::Accepted {
            job: "job-12ab".into(),
            runs: 8,
        });
        roundtrip_resp(Response::Rejected {
            error: "run \"a\" (grid): steps: must be positive".into(),
        });
        roundtrip_resp(Response::Status {
            jobs: vec![JobStatus {
                job: "job-12ab".into(),
                name: "grid".into(),
                priority: 5,
                total: 8,
                done: 3,
                failed: 1,
                cancelled: 2,
                state: "running".into(),
            }],
            claims: vec![ClaimView {
                id: "abc".into(),
                owner: "w-1".into(),
                age_secs: 1.5,
                heartbeats: 4,
            }],
        });
        roundtrip_resp(Response::Event {
            seq: 7,
            event: Json::obj().set("kind", "started").set("id", "abc"),
        });
        roundtrip_resp(Response::Cancelled {
            job: "job-00ff".into(),
            released: 5,
        });
        roundtrip_resp(Response::Error {
            error: "bad frame".into(),
        });
        roundtrip_resp(Response::Ok);
    }

    #[test]
    fn job_status_without_a_cancelled_field_defaults_to_zero() {
        // Wire compatibility: records written before cancellation
        // existed still parse.
        let j = Json::obj()
            .set("job", "job-12ab")
            .set("name", "grid")
            .set("total", 4)
            .set("done", 4)
            .set("failed", 0)
            .set("state", "complete");
        assert_eq!(JobStatus::from_json(&j).unwrap().cancelled, 0);
    }

    #[test]
    fn bit_flip_is_nonfatal_corrupt() {
        let mut wire = Vec::new();
        write_msg(&mut wire, &Request::Ping.to_json()).unwrap();
        wire[FRAME_OVERHEAD] ^= 0x10; // flip a payload bit
        let mut r = wire.as_slice();
        match read_frame(&mut r, &|| false).unwrap() {
            FrameIn::Corrupt { error, fatal } => {
                assert!(!fatal, "payload corruption keeps framing sync");
                assert!(error.contains("checksum"), "{error}");
            }
            other => panic!("expected Corrupt, got {other:?}"),
        }
    }

    #[test]
    fn insane_length_is_fatal_corrupt() {
        let mut wire = vec![0xffu8; FRAME_OVERHEAD];
        wire.extend_from_slice(b"garbage");
        let mut r = wire.as_slice();
        match read_frame(&mut r, &|| false).unwrap() {
            FrameIn::Corrupt { fatal, .. } => assert!(fatal),
            other => panic!("expected Corrupt, got {other:?}"),
        }
    }

    #[test]
    fn truncated_frame_and_clean_eof() {
        let mut wire = Vec::new();
        write_msg(&mut wire, &Request::Status.to_json()).unwrap();
        let cut = &wire[..wire.len() - 2];
        let mut r = cut;
        match read_frame(&mut r, &|| false).unwrap() {
            FrameIn::Corrupt { fatal, .. } => assert!(fatal),
            other => panic!("expected Corrupt, got {other:?}"),
        }
        let mut empty: &[u8] = &[];
        assert!(matches!(
            read_frame(&mut empty, &|| false).unwrap(),
            FrameIn::Eof
        ));
    }

    #[test]
    fn unknown_types_are_rejected() {
        let j = Json::obj().set("type", "mystery");
        assert!(Request::from_json(&j).is_err());
        assert!(Response::from_json(&j).is_err());
        assert!(Request::from_json(&Json::obj()).is_err());
    }
}
