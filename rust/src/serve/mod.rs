//! `sparq serve` — a long-lived, multi-tenant sweep service daemon.
//!
//! The sweep engine (ISSUE 3) runs one grid per invocation; the
//! distributed layer (ISSUE 4) lets N transient processes share a grid.
//! This module closes the remaining gap for shared-cluster use: a
//! **daemon** that owns an output directory and a worker budget
//! permanently, and accepts work over a socket —
//!
//! * [`protocol`] — the wire protocol: CRC-framed (`comm::wire`) JSON
//!   request/response messages, with every decode layer fallible and
//!   bounded (this PR's input-hardening bugfixes — depth-limited JSON
//!   parsing, exact-integer `as_usize` — sit on this path).
//! * [`server`] — admission control (`SweepSpec::from_json` →
//!   `expand()` → per-run `ExperimentConfig::resolve()`, rejecting with
//!   `sparq check`'s exact text), priority scheduling onto the
//!   claim/lease worker loop shared with `sweep::run_distributed`, a
//!   sequence-numbered event hub fanned out to any number of
//!   subscribers, and durable job files under `<out>/jobs/` so a
//!   restarted daemon completes a killed daemon's work exactly once,
//!   bit-for-bit.
//! * [`client`] — the thin typed client behind `sparq submit`, `sparq
//!   watch`, `sparq status --socket`, and `sparq shutdown`.
//!
//! EXPERIMENTS.md §Serve documents the protocol, the admission
//! semantics, and the restart-takeover verification procedure;
//! `rust/tests/serve_system.rs` pins all three end to end over a real
//! socket.

pub mod client;
pub mod protocol;
pub mod server;

pub use client::Client;
pub use protocol::{
    is_tcp_addr, ClaimView, JobStatus, Request, Response, Stream, MAX_FRAME_BYTES,
};
pub use server::{serve, spawn, ServeConfig, ServerHandle};
