//! The `sparq serve` daemon: a long-lived, multi-tenant sweep service.
//!
//! One daemon owns one output directory and a worker budget; any number
//! of clients connect over a Unix or TCP socket to submit sweep specs,
//! subscribe to the run-lifecycle event stream, or inspect the queue
//! and the live claim table. The scheduler is the same claim/lease
//! worker loop `sweep::run_distributed` uses (claim → re-check recorded
//! → execute with heartbeat ticks → re-verify ownership → persist →
//! release), so everything that protocol guarantees carries over:
//!
//! * **Exactly-once recording.** A run's result is appended once, no
//!   matter how many daemons (or `sparq sweep --distributed` processes)
//!   share the output directory, and no matter how often a daemon is
//!   killed and restarted.
//! * **Restart takeover.** A killed daemon's in-flight runs leave their
//!   claims and mid-run checkpoints behind; the next daemon over the
//!   same directory re-admits the persisted jobs from `<out>/jobs/`,
//!   takes the stale claims over, and resumes from the checkpoints
//!   **bit-for-bit** — per-run series equal a serial sweep's exactly.
//! * **Budget sharing.** All tenants' runs draw from one
//!   `NodeBudget::Dynamic` pool: the node-worker split widens as the
//!   queue drains, and never affects results.
//!
//! Admission is strict: a submitted spec goes through
//! `SweepSpec::from_json` → `expand()` → per-run
//! `ExperimentConfig::resolve()`, and any failure rejects the whole job
//! with the same text `sparq check` prints — malformed work is refused
//! at the door, never half-executed.

use std::collections::BTreeMap;
use std::fs::{self, File, OpenOptions};
use std::io::{BufWriter, Write};
use std::net::TcpListener;
#[cfg(unix)]
use std::os::unix::net::UnixListener;
use std::path::{Path, PathBuf};
use std::sync::atomic::{AtomicBool, AtomicUsize, Ordering};
use std::sync::{Arc, Condvar, Mutex};
use std::thread;
use std::time::{Duration, Instant};

use crate::config::ExperimentConfig;
use crate::run::{EventFanout, RunEvent};
use crate::sweep::cache::ArtifactCache;
use crate::sweep::distributed::{
    default_owner, list_claims, now_secs, Acquire, ClaimStore, CompletedIndex,
};
use crate::sweep::runner::{
    execute_one, load_completed, persist, reject_duplicate_ids, NodeBudget, SweepOptions,
};
use crate::sweep::spec::{config_hash, fnv64, SweepSpec};
use crate::util::json::Json;

use super::protocol::{
    is_tcp_addr, parse_payload, read_frame, write_msg, ClaimView, FrameIn, JobStatus, Request,
    Response, Stream,
};

/// Daemon configuration (the `sparq serve` CLI surface).
#[derive(Clone, Debug)]
pub struct ServeConfig {
    /// `--socket`: a Unix socket path, or `host:port` for TCP (see
    /// [`is_tcp_addr`]).
    pub socket: String,
    /// Output directory shared by every tenant (`results.jsonl`,
    /// `series/`, `ckpt/`, `claims/`, `jobs/`).
    pub out: PathBuf,
    /// Total worker budget shared across all queued runs (0 ⇒ available
    /// CPUs). Never affects results.
    pub workers: usize,
    /// Mid-run checkpoint cadence (iterations; 0 ⇒ never). Restart
    /// takeover resumes killed runs from these snapshots.
    pub checkpoint_every: u64,
    /// Claim/lease knobs — same semantics as `sparq sweep
    /// --distributed`.
    pub lease_secs: f64,
    pub lease_margin_secs: f64,
    pub heartbeat_secs: f64,
    /// Scheduler poll interval while runs are held by other processes.
    pub poll_ms: u64,
    /// Fault-injection hook (tests/CI): every run abandons at this
    /// iteration and the daemon exits nonzero with claims and
    /// checkpoints left in place — a deterministic stand-in for
    /// `kill -9` mid-grid.
    pub fault_abort_at: Option<u64>,
    /// Event-log ring capacity: the daemon retains at most this many
    /// events for replay. Subscribers that ask for an evicted prefix
    /// (`watch --from-start` after long uptime, or a consumer that
    /// stalled past the ring) get a structured "log truncated" error
    /// instead of a silently incomplete stream. 0 ⇒ retain one event.
    pub event_capacity: usize,
    /// `--jobs-retain N`: keep at most the newest N **settled** job
    /// files under `<out>/jobs/`, deleting older ones when a job
    /// settles. Pending and running jobs are never touched (they are
    /// the restart-takeover state). 0 ⇒ keep everything.
    pub jobs_retain: usize,
    /// `--auth-token`: when set, every connection must authenticate
    /// with [`Request::Auth`] as its first request; any other first
    /// request (or a wrong token) gets a structured error and the
    /// connection closes. `None` preserves the open-socket behavior.
    pub auth_token: Option<String>,
    /// Per-run progress lines on stdout.
    pub verbose: bool,
}

impl Default for ServeConfig {
    fn default() -> Self {
        ServeConfig {
            socket: String::new(),
            out: PathBuf::new(),
            workers: 1,
            checkpoint_every: 0,
            lease_secs: 60.0,
            lease_margin_secs: 2.0,
            heartbeat_secs: 0.0,
            poll_ms: 200,
            fault_abort_at: None,
            event_capacity: 4096,
            jobs_retain: 0,
            auth_token: None,
            verbose: false,
        }
    }
}

/// How often a parked connection read re-checks the shutdown flag.
const CONN_POLL: Duration = Duration::from_millis(100);
/// Accept-loop poll interval.
const ACCEPT_POLL: Duration = Duration::from_millis(25);

// ---------------------------------------------------------------------
// Event hub
// ---------------------------------------------------------------------

/// Bounded event log + condvar: every subscriber replays from any
/// still-retained offset and blocks for new events, so all subscribers
/// observe the identical sequence regardless of when they attach.
///
/// The log is a **ring**: at most `capacity` events are retained, and
/// eviction advances `base` (global sequence numbers never recycle — a
/// seq names the same event for the daemon's lifetime). A subscriber
/// whose cursor falls behind `base` is told the log was truncated
/// rather than being handed a stream with a silent hole. A restart
/// starts a fresh sequence.
struct EventHub {
    state: Mutex<HubState>,
    cv: Condvar,
    capacity: usize,
}

struct HubState {
    log: std::collections::VecDeque<Json>,
    /// Global sequence number of `log[0]` (== number of evicted events).
    base: u64,
    closed: bool,
}

/// One `wait_from` poll: either a (possibly empty) batch plus the
/// closed flag, or notice that the requested cursor was evicted.
enum HubPoll {
    Batch(Vec<(u64, Json)>, bool),
    /// The earliest still-retained sequence number.
    Truncated(u64),
}

impl EventHub {
    fn new(capacity: usize) -> EventHub {
        EventHub {
            state: Mutex::new(HubState {
                log: std::collections::VecDeque::new(),
                base: 0,
                closed: false,
            }),
            cv: Condvar::new(),
            capacity: capacity.max(1),
        }
    }

    fn publish(&self, event: Json) {
        let mut st = self.state.lock().unwrap();
        st.log.push_back(event);
        while st.log.len() > self.capacity {
            st.log.pop_front();
            st.base += 1;
        }
        self.cv.notify_all();
    }

    /// Total events ever published (the next sequence number).
    fn len(&self) -> u64 {
        let st = self.state.lock().unwrap();
        st.base + st.log.len() as u64
    }

    fn close(&self) {
        self.state.lock().unwrap().closed = true;
        self.cv.notify_all();
    }

    /// Events at sequence `next` and beyond; blocks up to `timeout`
    /// when none are available yet. Reports truncation when `next`
    /// has already been evicted from the ring — checked on entry *and*
    /// after the wait, so a consumer the ring laps mid-block is told
    /// too.
    fn wait_from(&self, next: u64, timeout: Duration) -> HubPoll {
        let take = |st: &HubState| -> Vec<(u64, Json)> {
            st.log
                .iter()
                .enumerate()
                .skip((next - st.base) as usize)
                .map(|(i, j)| (st.base + i as u64, j.clone()))
                .collect()
        };
        let st = self.state.lock().unwrap();
        if next < st.base {
            return HubPoll::Truncated(st.base);
        }
        if st.base + st.log.len() as u64 > next || st.closed {
            return HubPoll::Batch(take(&st), st.closed);
        }
        let (st, _) = self.cv.wait_timeout(st, timeout).unwrap();
        if next < st.base {
            return HubPoll::Truncated(st.base);
        }
        HubPoll::Batch(take(&st), st.closed)
    }
}

// ---------------------------------------------------------------------
// Queue state
// ---------------------------------------------------------------------

#[derive(Clone, Copy, PartialEq, Debug)]
enum SlotState {
    /// Eligible for a claim attempt.
    Pending,
    /// Held by another process at last attempt.
    Waiting,
    /// Being executed by one of our workers.
    Running,
    /// Result recorded (by us or anyone sharing the directory).
    Done,
    /// Deterministic execution failure; not retried until restart.
    Failed,
    /// Released by a job cancel before any worker picked it up.
    Cancelled,
}

struct Slot {
    job: String,
    label: String,
    cfg: ExperimentConfig,
    id: String,
    state: SlotState,
    /// Per-job options (spec-level early-stop targets applied).
    opts: Arc<SweepOptions>,
}

struct JobInfo {
    name: String,
    priority: i64,
    /// Submission sequence (persisted in the job file name; the
    /// priority tie-break, so FIFO order survives restarts).
    seq: u64,
    total: usize,
    done: usize,
    failed: usize,
    cancelled: usize,
}

impl JobInfo {
    fn settled(&self) -> bool {
        self.done + self.failed + self.cancelled >= self.total
    }
}

struct QueueState {
    slots: Vec<Slot>,
    jobs: BTreeMap<String, JobInfo>,
    next_seq: u64,
}

enum Pick {
    Idx(usize),
    /// Claims held elsewhere — poll the completed index.
    Stalled,
    /// Nothing to do — wait for submissions.
    Idle,
}

// ---------------------------------------------------------------------
// Shared daemon state
// ---------------------------------------------------------------------

struct Shared {
    cfg: ServeConfig,
    out: PathBuf,
    series_dir: PathBuf,
    ckpt_dir: PathBuf,
    jobs_dir: PathBuf,
    queue: Mutex<QueueState>,
    work_cv: Condvar,
    hub: EventHub,
    fanout: Arc<EventFanout>,
    shutdown: AtomicBool,
    crashed: AtomicBool,
    errors: Mutex<Vec<String>>,
    /// Not-yet-settled runs (feeds `NodeBudget::Dynamic`).
    pending: AtomicUsize,
    /// Runs executed by this daemon instance.
    executed: AtomicUsize,
    claims: ClaimStore,
    sink: Mutex<BufWriter<File>>,
    completed: Mutex<CompletedIndex>,
    cache: ArtifactCache,
    base_opts: SweepOptions,
    budget: usize,
    run_workers: usize,
    heartbeat: Duration,
    poll: Duration,
}

impl Shared {
    fn stopping(&self) -> bool {
        self.shutdown.load(Ordering::SeqCst) || self.crashed.load(Ordering::SeqCst)
    }

    fn begin_shutdown(&self) {
        self.shutdown.store(true, Ordering::SeqCst);
        let _guard = self.queue.lock().unwrap();
        self.work_cv.notify_all();
    }

    /// Deliver a run-lifecycle event to in-process sinks (via the
    /// [`EventFanout`]) and to every socket subscriber (via the hub,
    /// with the owning job attached).
    fn publish_run_event(&self, job: &str, event: &RunEvent) {
        self.fanout.emit(event);
        self.hub.publish(event.to_json().set("job", job));
    }
}

// ---------------------------------------------------------------------
// Admission
// ---------------------------------------------------------------------

/// Validate and enqueue one submitted spec. Returns `(job id, runs)`.
///
/// Rejection text for a config that fails to resolve matches `sparq
/// check` on the same spec: `run {label:?} ({name}): {ConfigError}`.
/// `stored_seq` is set when re-admitting a persisted job at restart —
/// it pins the original submission order and skips re-persisting.
fn admit(
    shared: &Shared,
    spec_json: &Json,
    priority: i64,
    stored_seq: Option<u64>,
) -> Result<(String, usize), String> {
    let spec = SweepSpec::from_json(spec_json)?;
    let runs = spec.expand()?;
    for (label, cfg) in &runs {
        cfg.resolve()
            .map_err(|e| format!("run {label:?} ({}): {e}", cfg.name))?;
    }
    let slots: Vec<(String, ExperimentConfig, String)> = runs
        .into_iter()
        .map(|(label, cfg)| {
            let id = config_hash(&cfg);
            (label, cfg, id)
        })
        .collect();
    reject_duplicate_ids(slots.iter().map(|(label, _, id)| (id, label)))?;

    // Job identity is the normalized spec content, so a byte-different
    // rendering of the same grid is the same job.
    let normalized = spec.to_json();
    let job = format!("job-{:016x}", fnv64(normalized.to_string().as_bytes()));

    // Runs already recorded on disk settle at admission (instant
    // completion for resubmitted-after-done jobs, and the restart path's
    // way of recognizing work finished before the kill).
    let done_ids: Vec<bool> = {
        let mut ix = shared.completed.lock().unwrap();
        ix.refresh();
        slots
            .iter()
            .map(|(label, cfg, id)| match ix.get(id) {
                Some(record) => {
                    load_completed(label, cfg, id, record, Some(&shared.series_dir)).is_ok()
                }
                None => false,
            })
            .collect()
    };

    let opts = Arc::new(spec.apply_targets(&shared.base_opts));
    let mut queue = shared.queue.lock().unwrap();
    if let Some(existing) = queue.jobs.get(&job) {
        if !existing.settled() {
            return Err(format!("job {job} ({}) is already queued", existing.name));
        }
        // Settled duplicate: re-admit fresh. The old job's slots stay in
        // the vector (they are terminal, and workers hold slot indexes —
        // the vector only ever grows); only the job entry is replaced.
        queue.jobs.remove(&job);
    }
    for (label, _, id) in &slots {
        if let Some(held) = queue.slots.iter().find(|s| {
            s.id == *id
                && !matches!(
                    s.state,
                    SlotState::Done | SlotState::Failed | SlotState::Cancelled
                )
        }) {
            return Err(format!(
                "run {label:?} (id {id}) is already queued by job {}",
                held.job
            ));
        }
    }

    let seq = stored_seq.unwrap_or(queue.next_seq);
    queue.next_seq = queue.next_seq.max(seq + 1);
    if stored_seq.is_none() {
        let file = shared.jobs_dir.join(format!("{seq:06}-{job}.json"));
        let body = Json::obj()
            .set("job", job.as_str())
            .set("priority", priority)
            .set("spec", normalized);
        fs::write(&file, body.to_string_pretty()).map_err(|e| {
            format!("{}: {e}", file.display())
        })?;
    }

    let total = slots.len();
    let mut done = 0usize;
    for ((label, cfg, id), is_done) in slots.into_iter().zip(done_ids) {
        let state = if is_done {
            done += 1;
            SlotState::Done
        } else {
            shared.pending.fetch_add(1, Ordering::SeqCst);
            SlotState::Pending
        };
        queue.slots.push(Slot {
            job: job.clone(),
            label,
            cfg,
            id,
            state,
            opts: Arc::clone(&opts),
        });
    }
    queue.jobs.insert(
        job.clone(),
        JobInfo {
            name: spec.name.clone(),
            priority,
            seq,
            total,
            done,
            failed: 0,
            cancelled: 0,
        },
    );
    shared.hub.publish(
        Json::obj()
            .set("kind", "job-accepted")
            .set("job", job.as_str())
            .set("name", spec.name.as_str())
            .set("priority", priority)
            .set("runs", total),
    );
    if done >= total {
        publish_job_complete(shared, &queue, &job);
    }
    shared.work_cv.notify_all();
    Ok((job, total))
}

fn publish_job_complete(shared: &Shared, queue: &QueueState, job: &str) {
    if let Some(info) = queue.jobs.get(job) {
        shared.hub.publish(
            Json::obj()
                .set("kind", "job-complete")
                .set("job", job)
                .set("done", info.done)
                .set("failed", info.failed)
                .set("cancelled", info.cancelled)
                .set("total", info.total),
        );
    }
    gc_job_files(shared, queue);
}

/// Retention: with `--jobs-retain N`, drop the oldest settled job
/// files beyond the newest N whenever a job settles. Only files whose
/// job is *known settled* in this daemon's queue are candidates —
/// pending/running jobs (ours or a restarting predecessor's) are the
/// takeover state and are never deleted.
fn gc_job_files(shared: &Shared, queue: &QueueState) {
    let retain = shared.cfg.jobs_retain;
    if retain == 0 {
        return;
    }
    // Settled jobs, oldest submission first (seq is the file prefix).
    let mut settled: Vec<(u64, &str)> = queue
        .jobs
        .iter()
        .filter(|(_, info)| info.settled())
        .map(|(job, info)| (info.seq, job.as_str()))
        .collect();
    if settled.len() <= retain {
        return;
    }
    settled.sort();
    for (seq, job) in &settled[..settled.len() - retain] {
        let file = shared.jobs_dir.join(format!("{seq:06}-{job}.json"));
        match fs::remove_file(&file) {
            Ok(()) => shared.hub.publish(
                Json::obj()
                    .set("kind", "job-retired")
                    .set("job", *job)
                    .set("file", file.display().to_string()),
            ),
            // Already collected by an earlier pass (or never persisted).
            Err(e) if e.kind() == std::io::ErrorKind::NotFound => {}
            Err(e) => eprintln!("[serve] retention: {}: {e}", file.display()),
        }
    }
}

/// Mark slot `i` settled (`Done` or `Failed`), roll its job's counters,
/// and publish `job-complete` when the job fills. Idempotent: a slot
/// already settled (e.g. by a concurrent stalled-cycle resolution) is
/// left untouched so counters never double-roll.
fn settle_slot(shared: &Shared, i: usize, state: SlotState) {
    let mut queue = shared.queue.lock().unwrap();
    settle_locked(shared, &mut queue, i, state);
}

fn settle_locked(shared: &Shared, queue: &mut QueueState, i: usize, state: SlotState) {
    if matches!(
        queue.slots[i].state,
        SlotState::Done | SlotState::Failed | SlotState::Cancelled
    ) {
        return;
    }
    queue.slots[i].state = state;
    shared.pending.fetch_sub(1, Ordering::SeqCst);
    let job = queue.slots[i].job.clone();
    let filled = match queue.jobs.get_mut(&job) {
        Some(info) => {
            match state {
                SlotState::Failed => info.failed += 1,
                SlotState::Cancelled => info.cancelled += 1,
                _ => info.done += 1,
            }
            info.settled()
        }
        None => false,
    };
    if filled {
        publish_job_complete(shared, queue, &job);
    }
}

fn set_slot(shared: &Shared, i: usize, state: SlotState) {
    shared.queue.lock().unwrap().slots[i].state = state;
}

// ---------------------------------------------------------------------
// Scheduler workers
// ---------------------------------------------------------------------

/// One scheduler worker: the `run_distributed` claim loop, reshaped for
/// a daemon (no all-done exit — idle workers park on the submission
/// condvar; deterministic run failures fail the slot instead of the
/// process).
fn worker_loop(shared: &Arc<Shared>) {
    loop {
        if shared.stopping() {
            break;
        }
        let pick = {
            let mut queue = shared.queue.lock().unwrap();
            // Highest priority first; FIFO by job, then spec order.
            let best = queue
                .slots
                .iter()
                .enumerate()
                .filter(|(_, s)| s.state == SlotState::Pending)
                .min_by_key(|(i, s)| {
                    let (priority, seq) = queue
                        .jobs
                        .get(&s.job)
                        .map(|j| (j.priority, j.seq))
                        .unwrap_or((i64::MIN, u64::MAX));
                    (std::cmp::Reverse(priority), seq, *i)
                })
                .map(|(i, _)| i);
            match best {
                Some(i) => {
                    queue.slots[i].state = SlotState::Running;
                    Pick::Idx(i)
                }
                None if queue.slots.iter().any(|s| s.state == SlotState::Waiting) => Pick::Stalled,
                None => {
                    let _ = shared
                        .work_cv
                        .wait_timeout(queue, shared.poll)
                        .unwrap();
                    Pick::Idle
                }
            }
        };
        match pick {
            Pick::Idle => {}
            Pick::Stalled => stalled_cycle(shared),
            Pick::Idx(i) => run_slot(shared, i),
        }
    }
}

/// Everything claimable is held elsewhere: refresh the completed index
/// (a foreign holder may have finished), settle resolvable Waiting
/// slots, and flip the rest back to Pending so their (possibly stale)
/// claims get retried.
fn stalled_cycle(shared: &Arc<Shared>) {
    // Lock order is always index → queue.
    let mut ix = shared.completed.lock().unwrap();
    ix.refresh();
    let mut resolved = false;
    {
        let mut queue = shared.queue.lock().unwrap();
        for i in 0..queue.slots.len() {
            if queue.slots[i].state != SlotState::Waiting {
                continue;
            }
            let s = &queue.slots[i];
            let recorded = ix.get(&s.id).is_some_and(|record| {
                load_completed(&s.label, &s.cfg, &s.id, record, Some(&shared.series_dir)).is_ok()
            });
            if recorded {
                settle_locked(shared, &mut queue, i, SlotState::Done);
                resolved = true;
            } else {
                queue.slots[i].state = SlotState::Pending;
            }
        }
    }
    drop(ix);
    if !resolved && !shared.stopping() {
        thread::sleep(shared.poll);
    }
}

/// Execute one claimed-or-claimable slot to a settled state (or back to
/// Pending/Waiting). Mirrors `run_distributed`'s exactly-once dance:
/// pre-claim recorded check, claim, post-claim recorded re-check,
/// execute with heartbeat ticks, ownership re-verify, persist, release.
fn run_slot(shared: &Arc<Shared>, i: usize) {
    let (label, cfg, id, opts) = {
        let queue = shared.queue.lock().unwrap();
        let s = &queue.slots[i];
        (s.label.clone(), s.cfg.clone(), s.id.clone(), Arc::clone(&s.opts))
    };
    let recorded = || -> bool {
        let mut ix = shared.completed.lock().unwrap();
        ix.refresh();
        ix.get(&id).is_some_and(|record| {
            load_completed(&label, &cfg, &id, record, Some(&shared.series_dir)).is_ok()
        })
    };

    if recorded() {
        settle_slot(shared, i, SlotState::Done);
        return;
    }
    let mut claim = match shared.claims.try_acquire(&id) {
        Ok(Acquire::Acquired(c)) => c,
        Ok(Acquire::Held) => {
            set_slot(shared, i, SlotState::Waiting);
            return;
        }
        Err(e) => {
            fail_slot(shared, i, &label, &id, &e);
            return;
        }
    };
    // A previous holder persists *before* releasing, so a record
    // appearing between the pre-claim check and acquisition means the
    // run already finished — step aside instead of re-recording it.
    if recorded() {
        claim.release().ok();
        settle_slot(shared, i, SlotState::Done);
        return;
    }

    let job = shared.queue.lock().unwrap().slots[i].job.clone();
    shared.publish_run_event(
        &job,
        &RunEvent::Started {
            id: id.clone(),
            label: label.clone(),
            node_workers: NodeBudget::Dynamic {
                budget: shared.budget,
                run_workers: shared.run_workers,
                pending: &shared.pending,
            }
            .current(),
        },
    );

    let mut claim_lost = false;
    let mut shutdown_seen = false;
    let mut last_hb = Instant::now();
    let mut tick = |_t: u64| -> Result<bool, String> {
        if shared.stopping() {
            // Graceful drain: abandon to the checkpoint mid-run; the
            // restarted daemon resumes bit-for-bit.
            shutdown_seen = true;
            return Ok(false);
        }
        if last_hb.elapsed() >= shared.heartbeat {
            last_hb = Instant::now();
            if !claim.heartbeat()? {
                claim_lost = true;
                return Ok(false);
            }
        }
        Ok(true)
    };
    let budget = NodeBudget::Dynamic {
        budget: shared.budget,
        run_workers: shared.run_workers,
        pending: &shared.pending,
    };
    let res = execute_one(
        &label,
        &cfg,
        &id,
        &shared.cache,
        &budget,
        &opts,
        Some(&shared.ckpt_dir),
        Some(&mut tick),
    );
    match res {
        Err(e) => {
            // Deterministic failure: release so nobody burns a lease
            // waiting, fail the slot, keep the daemon serving.
            claim.release().ok();
            fail_slot(shared, i, &label, &id, &e);
        }
        Ok(outcome) if !outcome.completed => {
            if claim_lost {
                set_slot(shared, i, SlotState::Waiting);
            } else if shutdown_seen {
                // Graceful shutdown: the checkpoint stays, the claim is
                // ours to give back — the next daemon picks the run up
                // without waiting out the lease.
                claim.release().ok();
                set_slot(shared, i, SlotState::Pending);
            } else {
                // Fault injection: simulate a crash — leave the claim
                // and checkpoints in place and stop the whole daemon.
                shared.crashed.store(true, Ordering::SeqCst);
                shared.errors.lock().unwrap().push(format!(
                    "{label}: aborted by fault injection (claims and checkpoints left for \
                     takeover)"
                ));
                shared.begin_shutdown();
            }
        }
        Ok(outcome) => {
            // Re-verify ownership at the last moment: persisting after
            // a takeover would double-record the run.
            match claim.is_mine() {
                Ok(true) => {}
                Ok(false) => {
                    set_slot(shared, i, SlotState::Waiting);
                    return;
                }
                Err(e) => {
                    fail_slot(shared, i, &label, &id, &e);
                    return;
                }
            }
            if let Err(e) = persist(&outcome, Some(&shared.series_dir), Some(&shared.sink)) {
                fail_slot(shared, i, &label, &id, &e);
                return;
            }
            claim.release().ok();
            shared.executed.fetch_add(1, Ordering::SeqCst);
            // Finished before job-complete, so per-run and job-level
            // events arrive in causal order on every subscriber.
            shared.publish_run_event(
                &job,
                &RunEvent::Finished {
                    id: id.clone(),
                    label: label.clone(),
                    completed: true,
                    stopped: outcome.stopped.is_some(),
                },
            );
            settle_slot(shared, i, SlotState::Done);
        }
    }
}

fn fail_slot(shared: &Shared, i: usize, label: &str, id: &str, error: &str) {
    eprintln!("[serve] run {label} failed: {error}");
    let job = shared.queue.lock().unwrap().slots[i].job.clone();
    shared.hub.publish(
        Json::obj()
            .set("kind", "run-failed")
            .set("job", job.as_str())
            .set("id", id)
            .set("label", label)
            .set("error", error),
    );
    settle_slot(shared, i, SlotState::Failed);
}

// ---------------------------------------------------------------------
// Cancellation
// ---------------------------------------------------------------------

/// Cancel one queued job: flip every not-yet-running slot to
/// `Cancelled`, mark the persisted job file so a restarted daemon
/// skips it, and stream a `job-cancelled` event. Returns the number of
/// slots released. Slots already executing finish normally — their
/// results record, and the job settles once they do (cancellation
/// never discards work in flight).
fn cancel_job(shared: &Shared, job: &str) -> Result<usize, String> {
    let mut queue = shared.queue.lock().unwrap();
    let Some(info) = queue.jobs.get(job) else {
        return Err(format!("no such job {job:?}"));
    };
    if info.settled() {
        return Err(format!("job {job} ({}) is already settled", info.name));
    }
    let seq = info.seq;
    // Mark the file before touching the queue: a daemon killed between
    // here and the settle still skips the job at restart.
    mark_job_cancelled(shared, seq, job);
    let targets: Vec<usize> = queue
        .slots
        .iter()
        .enumerate()
        .filter(|(_, s)| s.job == job)
        .filter(|(_, s)| matches!(s.state, SlotState::Pending | SlotState::Waiting))
        .map(|(i, _)| i)
        .collect();
    // The cancel event precedes the job-complete the last settle may
    // publish, so subscribers see causal order.
    shared.hub.publish(
        Json::obj()
            .set("kind", "job-cancelled")
            .set("job", job)
            .set("released", targets.len()),
    );
    for i in &targets {
        settle_locked(shared, &mut queue, *i, SlotState::Cancelled);
    }
    Ok(targets.len())
}

/// Rewrite a persisted job file with `"cancelled": true` (best-effort:
/// a failure leaves a job that re-queues at restart, which is safe —
/// its runs were admissible).
fn mark_job_cancelled(shared: &Shared, seq: u64, job: &str) {
    let file = shared.jobs_dir.join(format!("{seq:06}-{job}.json"));
    let marked = fs::read_to_string(&file)
        .map_err(|e| e.to_string())
        .and_then(|text| Json::parse(&text).map_err(|e| e.to_string()))
        .map(|j| j.set("cancelled", true));
    match marked {
        Ok(j) => {
            if let Err(e) = fs::write(&file, j.to_string_pretty()) {
                eprintln!("[serve] cancel: {}: {e}", file.display());
            }
        }
        Err(e) => eprintln!("[serve] cancel: {}: {e}", file.display()),
    }
}

// ---------------------------------------------------------------------
// Connections
// ---------------------------------------------------------------------

fn send(stream: &mut Stream, resp: &Response) -> Result<(), String> {
    write_msg(stream, &resp.to_json())
}

fn handle_conn(shared: &Arc<Shared>, mut stream: Stream) {
    if stream.set_read_timeout(Some(CONN_POLL)).is_err() {
        return;
    }
    // With `--auth-token`, the first request must be a matching Auth —
    // anything else answers a structured error and closes, so an
    // unauthenticated peer can neither submit work nor read events.
    let mut authed = shared.cfg.auth_token.is_none();
    loop {
        let frame = match read_frame(&mut stream, &|| shared.stopping()) {
            Ok(f) => f,
            Err(_) => break,
        };
        let payload = match frame {
            FrameIn::Eof | FrameIn::Stopped => break,
            FrameIn::Corrupt { error, fatal } => {
                // A corrupt frame is rejected with a structured error;
                // the connection survives whenever framing sync does.
                let _ = send(
                    &mut stream,
                    &Response::Error {
                        error: format!("bad frame: {error}"),
                    },
                );
                if fatal {
                    break;
                }
                continue;
            }
            FrameIn::Msg(p) => p,
        };
        let req = match parse_payload(&payload).and_then(|j| Request::from_json(&j)) {
            Ok(r) => r,
            Err(e) => {
                let _ = send(
                    &mut stream,
                    &Response::Error {
                        error: format!("bad request: {e}"),
                    },
                );
                continue;
            }
        };
        let resp = match req {
            Request::Auth { token } => match &shared.cfg.auth_token {
                Some(expected) if *expected == token => {
                    authed = true;
                    Response::Ok
                }
                // Accepted no-op so clients may auth unconditionally.
                None => Response::Ok,
                Some(_) => {
                    let _ = send(
                        &mut stream,
                        &Response::Error {
                            error: "authentication failed: token mismatch".into(),
                        },
                    );
                    break;
                }
            },
            _ if !authed => {
                let _ = send(
                    &mut stream,
                    &Response::Error {
                        error: "authentication required: this daemon was started with \
                                --auth-token; send an auth request first"
                            .into(),
                    },
                );
                break;
            }
            Request::Ping => Response::Pong {
                version: crate::version().to_string(),
            },
            Request::Submit { spec, priority } => match admit(shared, &spec, priority, None) {
                Ok((job, runs)) => Response::Accepted { job, runs },
                Err(error) => Response::Rejected { error },
            },
            Request::Status => status_snapshot(shared),
            Request::Shutdown => {
                let _ = send(&mut stream, &Response::Ok);
                shared.begin_shutdown();
                break;
            }
            Request::Cancel { job } => match cancel_job(shared, &job) {
                Ok(released) => Response::Cancelled { job, released },
                Err(error) => Response::Error { error },
            },
            Request::Watch { from_start } => {
                watch_loop(shared, &mut stream, from_start);
                break;
            }
        };
        if send(&mut stream, &resp).is_err() {
            break;
        }
    }
}

/// Stream hub events to one subscriber until it disconnects or the
/// daemon shuts down (remaining events are flushed first, so two
/// subscribers that both live to the end see identical streams).
///
/// A cursor that falls off the ring — `--from-start` after the daemon
/// evicted its prefix, or a consumer too slow for the publish rate —
/// ends the stream with a structured `log truncated at seq N` error
/// (the client surfaces `Response::Error` as `Err`), never a stream
/// with a silent gap.
fn watch_loop(shared: &Arc<Shared>, stream: &mut Stream, from_start: bool) {
    let mut next = if from_start { 0 } else { shared.hub.len() };
    loop {
        let (batch, closed) = match shared.hub.wait_from(next, CONN_POLL) {
            HubPoll::Batch(batch, closed) => (batch, closed),
            HubPoll::Truncated(base) => {
                let _ = send(
                    stream,
                    &Response::Error {
                        error: format!(
                            "log truncated at seq {base}: events [{next}, {base}) were evicted \
                             from the {}-event ring; re-watch without --from-start to follow \
                             the live stream",
                            shared.cfg.event_capacity.max(1)
                        ),
                    },
                );
                return;
            }
        };
        for (seq, event) in batch {
            if send(stream, &Response::Event { seq, event }).is_err() {
                return;
            }
            next = seq + 1;
        }
        if closed {
            return;
        }
    }
}

fn status_snapshot(shared: &Arc<Shared>) -> Response {
    let queue = shared.queue.lock().unwrap();
    let mut jobs: Vec<(u64, JobStatus)> = queue
        .jobs
        .iter()
        .map(|(job, info)| {
            let state = if info.settled() {
                if info.cancelled > 0 {
                    "cancelled"
                } else {
                    "complete"
                }
            } else if queue
                .slots
                .iter()
                .any(|s| s.job == *job && s.state == SlotState::Running)
            {
                "running"
            } else {
                "queued"
            };
            (
                info.seq,
                JobStatus {
                    job: job.clone(),
                    name: info.name.clone(),
                    priority: info.priority,
                    total: info.total,
                    done: info.done,
                    failed: info.failed,
                    cancelled: info.cancelled,
                    state: state.to_string(),
                },
            )
        })
        .collect();
    drop(queue);
    jobs.sort_by_key(|(seq, _)| *seq);
    let claims = list_claims(&shared.out, now_secs())
        .unwrap_or_default()
        .into_iter()
        .map(|c| ClaimView {
            id: c.id,
            owner: c.owner,
            age_secs: c.age_secs,
            heartbeats: c.heartbeats,
        })
        .collect();
    Response::Status {
        jobs: jobs.into_iter().map(|(_, j)| j).collect(),
        claims,
    }
}

// ---------------------------------------------------------------------
// Listener + daemon lifecycle
// ---------------------------------------------------------------------

enum Listener {
    Tcp(TcpListener),
    #[cfg(unix)]
    Unix(UnixListener),
}

impl Listener {
    fn accept(&self) -> Option<Stream> {
        match self {
            Listener::Tcp(l) => l.accept().ok().map(|(s, _)| Stream::Tcp(s)),
            #[cfg(unix)]
            Listener::Unix(l) => l.accept().ok().map(|(s, _)| Stream::Unix(s)),
        }
    }
}

/// Bind the daemon socket. A stale Unix socket file (crashed daemon) is
/// replaced iff nothing answers on it; a live one is an error.
fn bind(socket: &str) -> Result<(Listener, String), String> {
    if is_tcp_addr(socket) {
        let l = TcpListener::bind(socket).map_err(|e| format!("{socket}: {e}"))?;
        let addr = l
            .local_addr()
            .map(|a| a.to_string())
            .unwrap_or_else(|_| socket.to_string());
        l.set_nonblocking(true).map_err(|e| e.to_string())?;
        return Ok((Listener::Tcp(l), addr));
    }
    #[cfg(unix)]
    {
        let path = Path::new(socket);
        if path.exists() {
            if std::os::unix::net::UnixStream::connect(path).is_ok() {
                return Err(format!("{socket}: a daemon is already listening"));
            }
            fs::remove_file(path).map_err(|e| format!("{socket}: {e}"))?;
        }
        if let Some(parent) = path.parent() {
            if !parent.as_os_str().is_empty() {
                fs::create_dir_all(parent).map_err(|e| format!("{}: {e}", parent.display()))?;
            }
        }
        let l = UnixListener::bind(path).map_err(|e| format!("{socket}: {e}"))?;
        l.set_nonblocking(true).map_err(|e| e.to_string())?;
        Ok((Listener::Unix(l), socket.to_string()))
    }
    #[cfg(not(unix))]
    Err(format!(
        "{socket}: unix socket paths are unsupported on this platform; use host:port"
    ))
}

fn build_shared(cfg: ServeConfig) -> Result<Arc<Shared>, String> {
    if !(cfg.lease_secs.is_finite() && cfg.lease_secs > 0.0) {
        return Err(format!(
            "lease must be a positive number of seconds, got {}",
            cfg.lease_secs
        ));
    }
    let out = cfg.out.clone();
    let series_dir = out.join("series");
    let ckpt_dir = out.join("ckpt");
    let jobs_dir = out.join("jobs");
    for dir in [&series_dir, &ckpt_dir, &jobs_dir] {
        fs::create_dir_all(dir).map_err(|e| format!("{}: {e}", dir.display()))?;
    }
    let claims = ClaimStore::new(out.join("claims"), default_owner(), cfg.lease_secs)?
        .with_margin(cfg.lease_margin_secs)?;
    let results_path = out.join("results.jsonl");
    let sink = Mutex::new(BufWriter::new(
        OpenOptions::new()
            .create(true)
            .append(true)
            .open(&results_path)
            .map_err(|e| format!("{}: {e}", results_path.display()))?,
    ));
    let budget = if cfg.workers == 0 {
        thread::available_parallelism().map(|n| n.get()).unwrap_or(1)
    } else {
        cfg.workers
    };
    let heartbeat = if cfg.heartbeat_secs > 0.0 {
        Duration::from_secs_f64(cfg.heartbeat_secs.min(cfg.lease_secs / 2.0))
    } else {
        Duration::from_secs_f64((cfg.lease_secs / 4.0).max(0.01))
    };
    let poll = Duration::from_millis(cfg.poll_ms.max(10));
    let base_opts = SweepOptions {
        workers: budget,
        out: Some(out.clone()),
        // Non-negotiable in a shared directory: never truncate state
        // another process may be appending to.
        resume: true,
        checkpoint_every: cfg.checkpoint_every,
        // Surfaces `[sweep] resume … from t=…` lines in daemon logs —
        // the restart-takeover test pins on them.
        verbose: cfg.verbose,
        fault_abort_at: cfg.fault_abort_at,
        target_error: None,
        target_loss: None,
        on_event: None,
    };
    let fanout = Arc::new(EventFanout::new());
    if cfg.verbose {
        fanout.add(Arc::new(|e: &RunEvent| match e {
            RunEvent::Started {
                label,
                node_workers,
                ..
            } => println!("[serve] start {label} ({node_workers} node workers)"),
            RunEvent::Finished {
                label,
                completed,
                stopped,
                ..
            } => println!("[serve] finish {label} (completed={completed}, stopped={stopped})"),
        }));
    }
    let event_capacity = cfg.event_capacity;
    let shared = Arc::new(Shared {
        run_workers: budget,
        cfg,
        out,
        series_dir,
        ckpt_dir,
        jobs_dir,
        queue: Mutex::new(QueueState {
            slots: Vec::new(),
            jobs: BTreeMap::new(),
            next_seq: 0,
        }),
        work_cv: Condvar::new(),
        hub: EventHub::new(event_capacity),
        fanout,
        shutdown: AtomicBool::new(false),
        crashed: AtomicBool::new(false),
        errors: Mutex::new(Vec::new()),
        pending: AtomicUsize::new(0),
        executed: AtomicUsize::new(0),
        claims,
        sink,
        completed: Mutex::new(CompletedIndex::new(results_path)),
        cache: ArtifactCache::new(),
        base_opts,
        budget,
        heartbeat,
        poll,
    });
    requeue_persisted_jobs(&shared);
    Ok(shared)
}

/// Re-admit every job persisted under `<out>/jobs/` (submission order),
/// so a restarted daemon finishes what a killed one started. Runs
/// already recorded settle instantly; half-finished ones take over the
/// dead daemon's claims and resume from checkpoints.
fn requeue_persisted_jobs(shared: &Arc<Shared>) {
    let Ok(entries) = fs::read_dir(&shared.jobs_dir) else {
        return;
    };
    let mut files: Vec<PathBuf> = entries
        .flatten()
        .map(|e| e.path())
        .filter(|p| p.extension().is_some_and(|x| x == "json"))
        .collect();
    files.sort();
    for file in files {
        let seq = file
            .file_name()
            .and_then(|n| n.to_str())
            .and_then(|n| n.split('-').next())
            .and_then(|s| s.parse::<u64>().ok());
        let parsed = fs::read_to_string(&file)
            .map_err(|e| e.to_string())
            .and_then(|text| Json::parse(&text).map_err(|e| e.to_string()));
        let j = match parsed {
            Ok(j) => j,
            Err(e) => {
                eprintln!("[serve] skipping job file {}: {e}", file.display());
                continue;
            }
        };
        // Cancelled jobs persist (until retention collects them) but
        // are never re-queued — a cancel survives a daemon restart.
        if j.get("cancelled").and_then(Json::as_bool) == Some(true) {
            continue;
        }
        let priority = j.get("priority").and_then(Json::as_f64).unwrap_or(0.0) as i64;
        let Some(spec) = j.get("spec") else {
            eprintln!("[serve] skipping job file {}: no spec", file.display());
            continue;
        };
        if let Err(e) = admit(shared, spec, priority, Some(seq.unwrap_or(0))) {
            eprintln!("[serve] skipping job file {}: {e}", file.display());
        }
    }
}

/// A daemon spawned in-process (tests, embedding). [`stop`] performs a
/// graceful shutdown and joins.
///
/// [`stop`]: ServerHandle::stop
pub struct ServerHandle {
    addr: String,
    shared: Arc<Shared>,
    join: thread::JoinHandle<Result<(), String>>,
}

impl ServerHandle {
    /// The bound address (resolved port for `host:0` TCP binds).
    pub fn addr(&self) -> &str {
        &self.addr
    }

    pub fn stop(self) -> Result<(), String> {
        self.shared.begin_shutdown();
        self.join.join().map_err(|_| "server thread panicked".to_string())?
    }
}

/// Bind and serve on a background thread; returns once the socket is
/// accepting.
pub fn spawn(cfg: ServeConfig) -> Result<ServerHandle, String> {
    let (listener, addr) = bind(&cfg.socket)?;
    let shared = build_shared(cfg)?;
    let shared2 = Arc::clone(&shared);
    let join = thread::spawn(move || run_server(listener, shared2));
    Ok(ServerHandle {
        addr,
        shared,
        join,
    })
}

/// Bind and serve on the calling thread until shutdown (the `sparq
/// serve` entry point). Returns `Err` after a fault-injected crash —
/// claims and checkpoints stay on disk for the next daemon.
pub fn serve(cfg: ServeConfig) -> Result<(), String> {
    let (listener, addr) = bind(&cfg.socket)?;
    let verbose = cfg.verbose;
    let shared = build_shared(cfg)?;
    if verbose {
        println!(
            "[serve] listening on {addr} ({} workers, out={})",
            shared.budget,
            shared.out.display()
        );
    }
    run_server(listener, shared)
}

fn run_server(listener: Listener, shared: Arc<Shared>) -> Result<(), String> {
    let mut workers = Vec::new();
    for _ in 0..shared.run_workers {
        let s = Arc::clone(&shared);
        workers.push(thread::spawn(move || worker_loop(&s)));
    }
    while !shared.stopping() {
        match listener.accept() {
            Some(stream) => {
                let s = Arc::clone(&shared);
                // Connection threads are detached: they exit on client
                // EOF, or once the hub closes / the stream drops.
                thread::spawn(move || handle_conn(&s, stream));
            }
            None => thread::sleep(ACCEPT_POLL),
        }
    }
    for w in workers {
        w.join().ok();
    }
    shared.sink.lock().unwrap().flush().ok();
    // Close the hub only after workers finished, so subscribers drain
    // the complete event stream before their connections end.
    shared.hub.close();
    #[cfg(unix)]
    if let Listener::Unix(_) = &listener {
        if !shared.crashed.load(Ordering::SeqCst) {
            fs::remove_file(&shared.cfg.socket).ok();
        }
    }
    let errors = shared.errors.lock().unwrap();
    if shared.crashed.load(Ordering::SeqCst) || !errors.is_empty() {
        return Err(errors.join("; "));
    }
    Ok(())
}
