//! Thin client for the `sparq serve` daemon (the `sparq submit` /
//! `sparq watch` / `sparq status --socket` / `sparq shutdown` CLI
//! surface, and the test harness's programmatic handle).

use std::time::{Duration, Instant};

use crate::util::json::Json;

use super::protocol::{
    parse_payload, read_frame, write_frame, write_msg, ClaimView, FrameIn, JobStatus, Request,
    Response, Stream,
};

/// One connected client. Requests are strictly serial: send one framed
/// request, read one framed response ([`watch`](Client::watch) upgrades
/// the connection to a one-way event stream instead).
pub struct Client {
    stream: Stream,
}

impl Client {
    pub fn connect(addr: &str) -> Result<Client, String> {
        Stream::connect(addr).map(|stream| Client { stream })
    }

    /// Connect, retrying until `timeout` (daemon startup races: the
    /// socket appears slightly after the daemon process does).
    pub fn connect_retry(addr: &str, timeout: Duration) -> Result<Client, String> {
        let deadline = Instant::now() + timeout;
        loop {
            match Client::connect(addr) {
                Ok(c) => return Ok(c),
                Err(e) if Instant::now() >= deadline => {
                    return Err(format!("{e} (gave up after {timeout:?})"))
                }
                Err(_) => std::thread::sleep(Duration::from_millis(25)),
            }
        }
    }

    /// Send one request, read one response.
    pub fn request(&mut self, req: &Request) -> Result<Response, String> {
        write_msg(&mut self.stream, &req.to_json())?;
        self.read_response()
    }

    /// Read one framed [`Response`] (blocking).
    pub fn read_response(&mut self) -> Result<Response, String> {
        match read_frame(&mut self.stream, &|| false)? {
            FrameIn::Msg(p) => {
                let j = parse_payload(&p)?;
                Response::from_json(&j)
            }
            FrameIn::Corrupt { error, .. } => Err(format!("corrupt response frame: {error}")),
            FrameIn::Eof | FrameIn::Stopped => Err("connection closed by daemon".into()),
        }
    }

    /// Send raw pre-framed bytes (protocol tests inject corrupt frames
    /// through this).
    pub fn send_raw(&mut self, bytes: &[u8]) -> Result<(), String> {
        use std::io::Write;
        self.stream
            .write_all(bytes)
            .and_then(|_| self.stream.flush())
            .map_err(|e| format!("write: {e}"))
    }

    /// Send an arbitrary framed payload (valid CRC, caller-chosen
    /// content).
    pub fn send_payload(&mut self, payload: &[u8]) -> Result<(), String> {
        write_frame(&mut self.stream, payload)
    }

    /// Liveness probe; returns the daemon's version string.
    pub fn ping(&mut self) -> Result<String, String> {
        match self.request(&Request::Ping)? {
            Response::Pong { version } => Ok(version),
            other => Err(format!("unexpected reply to ping: {other:?}")),
        }
    }

    /// Authenticate the connection. Must be the first request against a
    /// daemon started with `--auth-token`; a no-op against one without.
    pub fn auth(&mut self, token: &str) -> Result<(), String> {
        let req = Request::Auth {
            token: token.to_string(),
        };
        match self.request(&req)? {
            Response::Ok => Ok(()),
            Response::Error { error } => Err(error),
            other => Err(format!("unexpected reply to auth: {other:?}")),
        }
    }

    /// Cancel a queued job; `Ok(released slots)` on success.
    pub fn cancel(&mut self, job: &str) -> Result<usize, String> {
        let req = Request::Cancel {
            job: job.to_string(),
        };
        match self.request(&req)? {
            Response::Cancelled { released, .. } => Ok(released),
            Response::Error { error } => Err(error),
            other => Err(format!("unexpected reply to cancel: {other:?}")),
        }
    }

    /// Submit a sweep spec; `Ok((job, runs))` on admission,
    /// `Err(admission error)` on rejection.
    pub fn submit(&mut self, spec: &Json, priority: i64) -> Result<(String, usize), String> {
        let req = Request::Submit {
            spec: spec.clone(),
            priority,
        };
        match self.request(&req)? {
            Response::Accepted { job, runs } => Ok((job, runs)),
            Response::Rejected { error } => Err(error),
            other => Err(format!("unexpected reply to submit: {other:?}")),
        }
    }

    /// Queue + claim snapshot.
    pub fn status(&mut self) -> Result<(Vec<JobStatus>, Vec<ClaimView>), String> {
        match self.request(&Request::Status)? {
            Response::Status { jobs, claims } => Ok((jobs, claims)),
            other => Err(format!("unexpected reply to status: {other:?}")),
        }
    }

    /// Ask the daemon to shut down gracefully.
    pub fn shutdown(&mut self) -> Result<(), String> {
        match self.request(&Request::Shutdown)? {
            Response::Ok => Ok(()),
            other => Err(format!("unexpected reply to shutdown: {other:?}")),
        }
    }

    /// Upgrade to a subscription and stream events into `on_event`
    /// until it returns `false` or the daemon closes the stream.
    /// Consumes the client — a watch connection carries nothing else.
    pub fn watch(
        mut self,
        from_start: bool,
        on_event: &mut dyn FnMut(u64, &Json) -> bool,
    ) -> Result<(), String> {
        write_msg(&mut self.stream, &Request::Watch { from_start }.to_json())?;
        loop {
            match read_frame(&mut self.stream, &|| false)? {
                FrameIn::Msg(p) => {
                    let j = parse_payload(&p)?;
                    match Response::from_json(&j)? {
                        Response::Event { seq, event } => {
                            if !on_event(seq, &event) {
                                return Ok(());
                            }
                        }
                        Response::Error { error } => return Err(error),
                        other => return Err(format!("unexpected frame in stream: {other:?}")),
                    }
                }
                FrameIn::Corrupt { error, .. } => {
                    return Err(format!("corrupt event frame: {error}"))
                }
                // Daemon shut down: the stream is complete.
                FrameIn::Eof | FrameIn::Stopped => return Ok(()),
            }
        }
    }
}
