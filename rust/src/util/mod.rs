//! Offline-environment substrates.
//!
//! Only the `xla` crate's vendored dependency closure is available in this
//! build environment, so the usual ecosystem crates (rand, serde_json,
//! clap, criterion, proptest) are replaced by small, tested, in-tree
//! implementations. Each is a real substrate with its own unit tests — see
//! DESIGN.md §Substrates.

pub mod rng;
pub mod json;
pub mod stats;
pub mod cli;
pub mod threadpool;
pub mod bench;
pub mod prop;

pub use rng::Rng;
