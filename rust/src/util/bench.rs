//! In-tree micro-bench harness (criterion is unavailable offline).
//!
//! Provides warmup + timed sampling with mean/std/p50/p99 reporting in a
//! criterion-like one-line format, plus a `Bencher` group runner used by
//! every file in `benches/`.

use std::time::{Duration, Instant};

use super::stats::{percentile, Summary};

/// Result of one benchmark case.
#[derive(Clone, Debug)]
pub struct BenchResult {
    pub name: String,
    pub mean_ns: f64,
    pub std_ns: f64,
    pub p50_ns: f64,
    pub p99_ns: f64,
    pub samples: usize,
    /// Optional throughput denominator (elements per iteration).
    pub elements: Option<u64>,
}

impl BenchResult {
    pub fn report(&self) -> String {
        let mut s = format!(
            "{:<44} time: [{}] (±{}, p50 {}, p99 {}, n={})",
            self.name,
            fmt_ns(self.mean_ns),
            fmt_ns(self.std_ns),
            fmt_ns(self.p50_ns),
            fmt_ns(self.p99_ns),
            self.samples
        );
        if let Some(e) = self.elements {
            let per_sec = e as f64 / (self.mean_ns * 1e-9);
            s.push_str(&format!("  thrpt: {}/s", fmt_count(per_sec)));
        }
        s
    }
}

pub fn fmt_ns(ns: f64) -> String {
    if ns < 1_000.0 {
        format!("{ns:.1} ns")
    } else if ns < 1_000_000.0 {
        format!("{:.2} µs", ns / 1e3)
    } else if ns < 1e9 {
        format!("{:.2} ms", ns / 1e6)
    } else {
        format!("{:.3} s", ns / 1e9)
    }
}

fn fmt_count(x: f64) -> String {
    if x >= 1e9 {
        format!("{:.2}G", x / 1e9)
    } else if x >= 1e6 {
        format!("{:.2}M", x / 1e6)
    } else if x >= 1e3 {
        format!("{:.2}K", x / 1e3)
    } else {
        format!("{x:.1}")
    }
}

/// Benchmark group with shared warmup/measurement budgets.
pub struct Bencher {
    pub group: String,
    pub warmup: Duration,
    pub measure: Duration,
    pub max_samples: usize,
    results: Vec<BenchResult>,
}

impl Bencher {
    pub fn new(group: &str) -> Self {
        // Budgets kept modest: the full `cargo bench` suite must finish in
        // minutes on one core.
        Bencher {
            group: group.to_string(),
            warmup: Duration::from_millis(200),
            measure: Duration::from_millis(800),
            max_samples: 200,
            results: Vec::new(),
        }
    }

    pub fn with_budget(mut self, warmup_ms: u64, measure_ms: u64) -> Self {
        self.warmup = Duration::from_millis(warmup_ms);
        self.measure = Duration::from_millis(measure_ms);
        self
    }

    /// Time `f`, which should perform one logical iteration and return a
    /// value kept alive to prevent dead-code elimination.
    pub fn bench<T>(&mut self, name: &str, mut f: impl FnMut() -> T) -> &BenchResult {
        self.bench_elements(name, None, &mut f)
    }

    /// Like [`bench`], reporting throughput as elements/second.
    pub fn bench_throughput<T>(
        &mut self,
        name: &str,
        elements: u64,
        mut f: impl FnMut() -> T,
    ) -> &BenchResult {
        self.bench_elements(name, Some(elements), &mut f)
    }

    fn bench_elements<T>(
        &mut self,
        name: &str,
        elements: Option<u64>,
        f: &mut impl FnMut() -> T,
    ) -> &BenchResult {
        // Warmup.
        let start = Instant::now();
        while start.elapsed() < self.warmup {
            std::hint::black_box(f());
        }
        // Measure.
        let mut samples = Vec::new();
        let mut summary = Summary::new();
        let start = Instant::now();
        while start.elapsed() < self.measure && samples.len() < self.max_samples {
            let t0 = Instant::now();
            std::hint::black_box(f());
            let ns = t0.elapsed().as_nanos() as f64;
            samples.push(ns);
            summary.push(ns);
        }
        let result = BenchResult {
            name: format!("{}/{}", self.group, name),
            mean_ns: summary.mean(),
            std_ns: summary.std(),
            p50_ns: percentile(&samples, 50.0),
            p99_ns: percentile(&samples, 99.0),
            samples: samples.len(),
            elements,
        };
        println!("{}", result.report());
        self.results.push(result);
        self.results.last().unwrap()
    }

    pub fn results(&self) -> &[BenchResult] {
        &self.results
    }
}

/// CI perf regression gate: compare a freshly measured bench snapshot
/// against the committed baseline JSON on *higher-is-better* keys.
/// Returns one report line per key, or an error listing every key whose
/// measured value fell more than `max_regress` (a fraction, e.g. 0.15)
/// below the baseline. Missing or non-positive baseline keys are hard
/// errors — a silently skipped gate is worse than a loud one.
pub fn perf_gate(
    baseline: &crate::util::json::Json,
    measured: &crate::util::json::Json,
    keys: &[&str],
    max_regress: f64,
) -> Result<Vec<String>, String> {
    use crate::util::json::Json;
    if !(max_regress.is_finite() && (0.0..1.0).contains(&max_regress)) {
        return Err(format!("max_regress must lie in [0, 1), got {max_regress}"));
    }
    let mut lines = Vec::new();
    let mut failures = Vec::new();
    for key in keys {
        let b = baseline
            .get(key)
            .and_then(Json::as_f64)
            .ok_or_else(|| format!("baseline is missing numeric key {key:?}"))?;
        let m = measured
            .get(key)
            .and_then(Json::as_f64)
            .ok_or_else(|| format!("measured snapshot is missing numeric key {key:?}"))?;
        if !(b.is_finite() && b > 0.0) {
            return Err(format!("baseline {key:?} must be positive, got {b}"));
        }
        let delta_pct = (m / b - 1.0) * 100.0;
        let line = format!("{key}: baseline {b:.3}, measured {m:.3} ({delta_pct:+.1}%)");
        if m < b * (1.0 - max_regress) {
            failures.push(line);
        } else {
            lines.push(line);
        }
    }
    if failures.is_empty() {
        Ok(lines)
    } else {
        Err(format!(
            "perf regression beyond {:.0}%:\n  {}",
            max_regress * 100.0,
            failures.join("\n  ")
        ))
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::json::Json;

    #[test]
    fn measures_something() {
        let mut b = Bencher::new("test").with_budget(5, 20);
        let r = b.bench("noop-ish", || {
            let mut s = 0u64;
            for i in 0..100u64 {
                s = s.wrapping_add(i * i);
            }
            s
        });
        assert!(r.mean_ns > 0.0);
        assert!(r.samples > 0);
    }

    #[test]
    fn formats() {
        assert_eq!(fmt_ns(12.0), "12.0 ns");
        assert!(fmt_ns(2_500.0).contains("µs"));
        assert!(fmt_ns(3.2e6).contains("ms"));
        assert!(fmt_ns(1.5e9).contains(" s"));
    }

    fn snap(speedup: f64, steps: f64) -> Json {
        Json::obj()
            .set("speedup_sparse_parallel", speedup)
            .set("node_steps_per_sec", steps)
    }

    const GATE_KEYS: &[&str] = &["speedup_sparse_parallel", "node_steps_per_sec"];

    #[test]
    fn perf_gate_passes_within_tolerance() {
        // 10% down on one key, up on the other: inside a 15% gate.
        let lines = perf_gate(&snap(4.0, 100.0), &snap(3.6, 110.0), GATE_KEYS, 0.15).unwrap();
        assert_eq!(lines.len(), 2);
        assert!(lines[0].contains("-10.0%"), "{lines:?}");
        assert!(lines[1].contains("+10.0%"), "{lines:?}");
    }

    #[test]
    fn perf_gate_fails_beyond_tolerance() {
        let err = perf_gate(&snap(4.0, 100.0), &snap(3.0, 100.0), GATE_KEYS, 0.15).unwrap_err();
        assert!(err.contains("speedup_sparse_parallel"), "{err}");
        assert!(err.contains("-25.0%"), "{err}");
        // the non-regressed key is not listed as a failure
        assert!(!err.contains("node_steps_per_sec"), "{err}");
    }

    #[test]
    fn perf_gate_rejects_missing_or_bad_baselines() {
        let empty = Json::obj();
        assert!(perf_gate(&empty, &snap(4.0, 100.0), GATE_KEYS, 0.15).is_err());
        assert!(perf_gate(&snap(4.0, 100.0), &empty, GATE_KEYS, 0.15).is_err());
        assert!(perf_gate(&snap(0.0, 100.0), &snap(4.0, 100.0), GATE_KEYS, 0.15).is_err());
        assert!(perf_gate(&snap(4.0, 100.0), &snap(4.0, 100.0), GATE_KEYS, 1.5).is_err());
    }
}
