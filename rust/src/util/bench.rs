//! In-tree micro-bench harness (criterion is unavailable offline).
//!
//! Provides warmup + timed sampling with mean/std/p50/p99 reporting in a
//! criterion-like one-line format, plus a `Bencher` group runner used by
//! every file in `benches/`.

use std::time::{Duration, Instant};

use super::stats::{percentile, Summary};

/// Result of one benchmark case.
#[derive(Clone, Debug)]
pub struct BenchResult {
    pub name: String,
    pub mean_ns: f64,
    pub std_ns: f64,
    pub p50_ns: f64,
    pub p99_ns: f64,
    pub samples: usize,
    /// Optional throughput denominator (elements per iteration).
    pub elements: Option<u64>,
}

impl BenchResult {
    pub fn report(&self) -> String {
        let mut s = format!(
            "{:<44} time: [{}] (±{}, p50 {}, p99 {}, n={})",
            self.name,
            fmt_ns(self.mean_ns),
            fmt_ns(self.std_ns),
            fmt_ns(self.p50_ns),
            fmt_ns(self.p99_ns),
            self.samples
        );
        if let Some(e) = self.elements {
            let per_sec = e as f64 / (self.mean_ns * 1e-9);
            s.push_str(&format!("  thrpt: {}/s", fmt_count(per_sec)));
        }
        s
    }
}

pub fn fmt_ns(ns: f64) -> String {
    if ns < 1_000.0 {
        format!("{ns:.1} ns")
    } else if ns < 1_000_000.0 {
        format!("{:.2} µs", ns / 1e3)
    } else if ns < 1e9 {
        format!("{:.2} ms", ns / 1e6)
    } else {
        format!("{:.3} s", ns / 1e9)
    }
}

fn fmt_count(x: f64) -> String {
    if x >= 1e9 {
        format!("{:.2}G", x / 1e9)
    } else if x >= 1e6 {
        format!("{:.2}M", x / 1e6)
    } else if x >= 1e3 {
        format!("{:.2}K", x / 1e3)
    } else {
        format!("{x:.1}")
    }
}

/// Benchmark group with shared warmup/measurement budgets.
pub struct Bencher {
    pub group: String,
    pub warmup: Duration,
    pub measure: Duration,
    pub max_samples: usize,
    results: Vec<BenchResult>,
}

impl Bencher {
    pub fn new(group: &str) -> Self {
        // Budgets kept modest: the full `cargo bench` suite must finish in
        // minutes on one core.
        Bencher {
            group: group.to_string(),
            warmup: Duration::from_millis(200),
            measure: Duration::from_millis(800),
            max_samples: 200,
            results: Vec::new(),
        }
    }

    pub fn with_budget(mut self, warmup_ms: u64, measure_ms: u64) -> Self {
        self.warmup = Duration::from_millis(warmup_ms);
        self.measure = Duration::from_millis(measure_ms);
        self
    }

    /// Time `f`, which should perform one logical iteration and return a
    /// value kept alive to prevent dead-code elimination.
    pub fn bench<T>(&mut self, name: &str, mut f: impl FnMut() -> T) -> &BenchResult {
        self.bench_elements(name, None, &mut f)
    }

    /// Like [`bench`], reporting throughput as elements/second.
    pub fn bench_throughput<T>(
        &mut self,
        name: &str,
        elements: u64,
        mut f: impl FnMut() -> T,
    ) -> &BenchResult {
        self.bench_elements(name, Some(elements), &mut f)
    }

    fn bench_elements<T>(
        &mut self,
        name: &str,
        elements: Option<u64>,
        f: &mut impl FnMut() -> T,
    ) -> &BenchResult {
        // Warmup.
        let start = Instant::now();
        while start.elapsed() < self.warmup {
            std::hint::black_box(f());
        }
        // Measure.
        let mut samples = Vec::new();
        let mut summary = Summary::new();
        let start = Instant::now();
        while start.elapsed() < self.measure && samples.len() < self.max_samples {
            let t0 = Instant::now();
            std::hint::black_box(f());
            let ns = t0.elapsed().as_nanos() as f64;
            samples.push(ns);
            summary.push(ns);
        }
        let result = BenchResult {
            name: format!("{}/{}", self.group, name),
            mean_ns: summary.mean(),
            std_ns: summary.std(),
            p50_ns: percentile(&samples, 50.0),
            p99_ns: percentile(&samples, 99.0),
            samples: samples.len(),
            elements,
        };
        println!("{}", result.report());
        self.results.push(result);
        self.results.last().unwrap()
    }

    pub fn results(&self) -> &[BenchResult] {
        &self.results
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn measures_something() {
        let mut b = Bencher::new("test").with_budget(5, 20);
        let r = b.bench("noop-ish", || {
            let mut s = 0u64;
            for i in 0..100u64 {
                s = s.wrapping_add(i * i);
            }
            s
        });
        assert!(r.mean_ns > 0.0);
        assert!(r.samples > 0);
    }

    #[test]
    fn formats() {
        assert_eq!(fmt_ns(12.0), "12.0 ns");
        assert!(fmt_ns(2_500.0).contains("µs"));
        assert!(fmt_ns(3.2e6).contains("ms"));
        assert!(fmt_ns(1.5e9).contains(" s"));
    }
}
