//! Minimal scoped thread pool (tokio/rayon are unavailable offline).
//!
//! The coordinator's per-node phases (gradient/local-step, trigger check
//! + compress, consensus commit) are embarrassingly parallel across
//! nodes; [`ThreadPool::for_each_mut`] hands whole `NodeState`s out to
//! worker threads and [`ThreadPool::parallel_for`] covers index ranges,
//! both joining before returning — the synchronous-algorithm semantics
//! (and bit-for-bit determinism, since every node owns its RNG and all
//! cross-node writes stay on the sequential path) are preserved
//! regardless of worker count.
//!
//! Work is claimed in contiguous *blocks* (~4 per worker), not single
//! indices: at n in the thousands a per-index atomic claim costs a
//! contended fetch_add per tiny closure — the dispatch overhead drowns
//! the work. Block claiming amortizes the atomic over the block while
//! keeping dynamic load balancing; which worker runs a block never
//! affects results (see determinism note above).

use std::sync::atomic::{AtomicUsize, Ordering};
use std::sync::Arc;

/// Fixed-size pool executing scoped parallel-for over index ranges.
pub struct ThreadPool {
    pub workers: usize,
}

/// Contiguous claim granularity: ~4 blocks per worker balances load
/// (stragglers steal) against claim traffic. Small n degenerates to
/// one-index blocks, identical to the old per-index dispatch.
fn block_size(n: usize, workers: usize) -> usize {
    n.div_ceil(workers * 4).max(1)
}

impl ThreadPool {
    /// `workers = 0` ⇒ number of available CPUs.
    pub fn new(workers: usize) -> ThreadPool {
        let workers = if workers == 0 {
            std::thread::available_parallelism()
                .map(|n| n.get())
                .unwrap_or(1)
        } else {
            workers
        };
        ThreadPool { workers }
    }

    /// Run `f(i)` for every i in 0..n, partitioned dynamically across the
    /// pool. `f` must be Sync (it is called concurrently from workers).
    pub fn parallel_for<F>(&self, n: usize, f: F)
    where
        F: Fn(usize) + Sync,
    {
        if self.workers <= 1 || n <= 1 {
            for i in 0..n {
                f(i);
            }
            return;
        }
        let chunk = block_size(n, self.workers);
        let nblocks = n.div_ceil(chunk);
        let next = Arc::new(AtomicUsize::new(0));
        std::thread::scope(|scope| {
            for _ in 0..self.workers.min(nblocks) {
                let next = Arc::clone(&next);
                let f = &f;
                scope.spawn(move || loop {
                    let b = next.fetch_add(1, Ordering::Relaxed);
                    if b >= nblocks {
                        break;
                    }
                    for i in b * chunk..((b + 1) * chunk).min(n) {
                        f(i);
                    }
                });
            }
        });
    }

    /// Apply `f` to every element of `items` in parallel (mutable,
    /// disjoint — each worker takes whole blocks of elements).
    pub fn for_each_mut<T: Send, F>(&self, items: &mut [T], f: F)
    where
        F: Fn(usize, &mut T) + Sync,
    {
        if self.workers <= 1 || items.len() <= 1 {
            for (i, item) in items.iter_mut().enumerate() {
                f(i, item);
            }
            return;
        }
        let n = items.len();
        let chunk = block_size(n, self.workers);
        let nblocks = n.div_ceil(chunk);
        let next = Arc::new(AtomicUsize::new(0));
        // Hand out raw element pointers; each block is claimed exactly
        // once via the atomic counter, so access is exclusive.
        let base = items.as_mut_ptr() as usize;
        std::thread::scope(|scope| {
            for _ in 0..self.workers.min(nblocks) {
                let next = Arc::clone(&next);
                let f = &f;
                scope.spawn(move || loop {
                    let b = next.fetch_add(1, Ordering::Relaxed);
                    if b >= nblocks {
                        break;
                    }
                    for i in b * chunk..((b + 1) * chunk).min(n) {
                        // SAFETY: block b (and hence index i) is claimed
                        // exactly once across all workers, blocks are
                        // disjoint, and the scope joins before `items`
                        // is usable again.
                        let item = unsafe { &mut *(base as *mut T).add(i) };
                        f(i, item);
                    }
                });
            }
        });
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::atomic::AtomicU64;

    #[test]
    fn parallel_for_covers_all_indices() {
        let pool = ThreadPool::new(4);
        let hits = AtomicU64::new(0);
        pool.parallel_for(1000, |i| {
            hits.fetch_add(i as u64 + 1, Ordering::Relaxed);
        });
        // Σ (i+1) for i in 0..1000
        assert_eq!(hits.load(Ordering::Relaxed), 500_500);
    }

    #[test]
    fn for_each_mut_touches_every_element_once() {
        let pool = ThreadPool::new(3);
        let mut v = vec![0u64; 257];
        pool.for_each_mut(&mut v, |i, x| {
            *x += i as u64 + 7;
        });
        for (i, x) in v.iter().enumerate() {
            assert_eq!(*x, i as u64 + 7);
        }
    }

    #[test]
    fn block_claiming_covers_awkward_sizes() {
        // Sizes around block boundaries: n < workers, n == workers,
        // n % chunk ≠ 0, and n ≫ workers·4.
        for n in [2usize, 3, 4, 5, 17, 31, 32, 33, 4096] {
            let pool = ThreadPool::new(4);
            let mut v = vec![0u8; n];
            pool.for_each_mut(&mut v, |_, x| *x += 1);
            assert!(v.iter().all(|&x| x == 1), "n={n}: {v:?}");
            let hits = AtomicU64::new(0);
            pool.parallel_for(n, |i| {
                hits.fetch_add(i as u64 + 1, Ordering::Relaxed);
            });
            assert_eq!(
                hits.load(Ordering::Relaxed),
                (n as u64 * (n as u64 + 1)) / 2,
                "n={n}"
            );
        }
    }

    #[test]
    fn single_worker_is_sequential() {
        let pool = ThreadPool::new(1);
        let mut v = vec![0usize; 10];
        pool.for_each_mut(&mut v, |i, x| *x = i * 2);
        assert_eq!(v[9], 18);
    }

    #[test]
    fn zero_means_auto() {
        let pool = ThreadPool::new(0);
        assert!(pool.workers >= 1);
    }
}
