//! Minimal scoped thread pool (tokio/rayon are unavailable offline).
//!
//! The coordinator's per-node phases (gradient/local-step, trigger check
//! + compress, consensus commit) are embarrassingly parallel across
//! nodes; [`ThreadPool::for_each_mut`] hands whole `NodeState`s out to
//! worker threads and [`ThreadPool::parallel_for`] covers index ranges,
//! both joining before returning — the synchronous-algorithm semantics
//! (and bit-for-bit determinism, since every node owns its RNG and all
//! cross-node writes stay on the sequential path) are preserved
//! regardless of worker count.

use std::sync::atomic::{AtomicUsize, Ordering};
use std::sync::Arc;

/// Fixed-size pool executing scoped parallel-for over index ranges.
pub struct ThreadPool {
    pub workers: usize,
}

impl ThreadPool {
    /// `workers = 0` ⇒ number of available CPUs.
    pub fn new(workers: usize) -> ThreadPool {
        let workers = if workers == 0 {
            std::thread::available_parallelism()
                .map(|n| n.get())
                .unwrap_or(1)
        } else {
            workers
        };
        ThreadPool { workers }
    }

    /// Run `f(i)` for every i in 0..n, partitioned dynamically across the
    /// pool. `f` must be Sync (it is called concurrently from workers).
    pub fn parallel_for<F>(&self, n: usize, f: F)
    where
        F: Fn(usize) + Sync,
    {
        if self.workers <= 1 || n <= 1 {
            for i in 0..n {
                f(i);
            }
            return;
        }
        let next = Arc::new(AtomicUsize::new(0));
        std::thread::scope(|scope| {
            for _ in 0..self.workers.min(n) {
                let next = Arc::clone(&next);
                let f = &f;
                scope.spawn(move || loop {
                    let i = next.fetch_add(1, Ordering::Relaxed);
                    if i >= n {
                        break;
                    }
                    f(i);
                });
            }
        });
    }

    /// Apply `f` to every element of `items` in parallel (mutable,
    /// disjoint — each worker takes whole elements).
    pub fn for_each_mut<T: Send, F>(&self, items: &mut [T], f: F)
    where
        F: Fn(usize, &mut T) + Sync,
    {
        if self.workers <= 1 || items.len() <= 1 {
            for (i, item) in items.iter_mut().enumerate() {
                f(i, item);
            }
            return;
        }
        let next = Arc::new(AtomicUsize::new(0));
        let n = items.len();
        // Hand out raw element pointers; each index is claimed exactly
        // once via the atomic counter, so access is exclusive.
        let base = items.as_mut_ptr() as usize;
        std::thread::scope(|scope| {
            for _ in 0..self.workers.min(n) {
                let next = Arc::clone(&next);
                let f = &f;
                scope.spawn(move || loop {
                    let i = next.fetch_add(1, Ordering::Relaxed);
                    if i >= n {
                        break;
                    }
                    // SAFETY: i is claimed exactly once across all
                    // workers, elements are disjoint, and the scope joins
                    // before `items` is usable again.
                    let item = unsafe { &mut *(base as *mut T).add(i) };
                    f(i, item);
                });
            }
        });
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::atomic::AtomicU64;

    #[test]
    fn parallel_for_covers_all_indices() {
        let pool = ThreadPool::new(4);
        let hits = AtomicU64::new(0);
        pool.parallel_for(1000, |i| {
            hits.fetch_add(i as u64 + 1, Ordering::Relaxed);
        });
        // Σ (i+1) for i in 0..1000
        assert_eq!(hits.load(Ordering::Relaxed), 500_500);
    }

    #[test]
    fn for_each_mut_touches_every_element_once() {
        let pool = ThreadPool::new(3);
        let mut v = vec![0u64; 257];
        pool.for_each_mut(&mut v, |i, x| {
            *x += i as u64 + 7;
        });
        for (i, x) in v.iter().enumerate() {
            assert_eq!(*x, i as u64 + 7);
        }
    }

    #[test]
    fn single_worker_is_sequential() {
        let pool = ThreadPool::new(1);
        let mut v = vec![0usize; 10];
        pool.for_each_mut(&mut v, |i, x| *x = i * 2);
        assert_eq!(v[9], 18);
    }

    #[test]
    fn zero_means_auto() {
        let pool = ThreadPool::new(0);
        assert!(pool.workers >= 1);
    }
}
