//! Tiny CLI argument parser (clap is unavailable offline).
//!
//! Supports `--flag`, `--key value`, `--key=value`, positional arguments
//! and subcommands; collects unknown flags as errors with a usage hint.

use std::collections::BTreeMap;

#[derive(Debug, Default)]
pub struct Args {
    pub positional: Vec<String>,
    flags: BTreeMap<String, String>,
    present: Vec<String>,
}

impl Args {
    /// Parse from an iterator of argument strings (no program name).
    pub fn parse<I: IntoIterator<Item = String>>(args: I) -> Args {
        let mut out = Args::default();
        let mut it = args.into_iter().peekable();
        while let Some(a) = it.next() {
            if let Some(rest) = a.strip_prefix("--") {
                if let Some((k, v)) = rest.split_once('=') {
                    out.flags.insert(k.to_string(), v.to_string());
                    out.present.push(k.to_string());
                } else if it
                    .peek()
                    .map(|n| !n.starts_with("--"))
                    .unwrap_or(false)
                {
                    let v = it.next().unwrap();
                    out.flags.insert(rest.to_string(), v);
                    out.present.push(rest.to_string());
                } else {
                    out.flags.insert(rest.to_string(), "true".to_string());
                    out.present.push(rest.to_string());
                }
            } else {
                out.positional.push(a);
            }
        }
        out
    }

    /// Parse the real process arguments.
    pub fn from_env() -> Args {
        Args::parse(std::env::args().skip(1))
    }

    pub fn has(&self, key: &str) -> bool {
        self.flags.contains_key(key)
    }

    pub fn get(&self, key: &str) -> Option<&str> {
        self.flags.get(key).map(|s| s.as_str())
    }

    pub fn get_or(&self, key: &str, default: &str) -> String {
        self.get(key).unwrap_or(default).to_string()
    }

    pub fn usize(&self, key: &str, default: usize) -> usize {
        self.get(key)
            .map(|v| v.parse().unwrap_or_else(|_| panic!("--{key} expects an integer, got {v:?}")))
            .unwrap_or(default)
    }

    pub fn u64(&self, key: &str, default: u64) -> u64 {
        self.get(key)
            .map(|v| v.parse().unwrap_or_else(|_| panic!("--{key} expects an integer, got {v:?}")))
            .unwrap_or(default)
    }

    pub fn f64(&self, key: &str, default: f64) -> f64 {
        self.get(key)
            .map(|v| v.parse().unwrap_or_else(|_| panic!("--{key} expects a number, got {v:?}")))
            .unwrap_or(default)
    }

    pub fn bool(&self, key: &str) -> bool {
        matches!(self.get(key), Some("true") | Some("1") | Some("yes"))
    }

    /// First positional arg (typically the subcommand).
    pub fn subcommand(&self) -> Option<&str> {
        self.positional.first().map(|s| s.as_str())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn parse(s: &str) -> Args {
        Args::parse(s.split_whitespace().map(String::from))
    }

    #[test]
    fn flags_and_positionals() {
        // NOTE: a bare `--flag` greedily consumes a following non-flag
        // token as its value (there is no registry of boolean flags), so
        // positionals go before flags or flags use `=`.
        let a = parse("train run.json --nodes 8 --topology=ring --verbose");
        assert_eq!(a.subcommand(), Some("train"));
        assert_eq!(a.usize("nodes", 0), 8);
        assert_eq!(a.get("topology"), Some("ring"));
        assert!(a.bool("verbose"));
        assert_eq!(a.positional, vec!["train", "run.json"]);
    }

    #[test]
    fn defaults() {
        let a = parse("bench");
        assert_eq!(a.usize("nodes", 60), 60);
        assert_eq!(a.f64("lr", 0.1), 0.1);
        assert!(!a.has("missing"));
    }

    #[test]
    fn flag_without_value_is_true() {
        let a = parse("--dry-run --steps 5");
        assert!(a.bool("dry-run"));
        assert_eq!(a.usize("steps", 0), 5);
    }
}
