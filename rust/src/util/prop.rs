//! In-tree property-testing harness (proptest is unavailable offline).
//!
//! A property is a predicate over randomly generated inputs; the harness
//! runs `cases` seeded generations and, on failure, retries with simpler
//! inputs drawn from a shrink ladder (smaller dimensions / magnitudes) to
//! report the least complex failing case it found.

use super::rng::Rng;

/// Configuration for a property run.
#[derive(Clone, Debug)]
pub struct Config {
    pub cases: usize,
    pub seed: u64,
}

impl Default for Config {
    fn default() -> Self {
        Config {
            cases: 64,
            seed: 0x5EED_CAFE,
        }
    }
}

/// Generator handle passed to properties: RNG plus the current shrink
/// scale (starts at 1.0; lowered while searching for simpler failures).
pub struct G<'a> {
    pub rng: &'a mut Rng,
    pub scale: f64,
}

impl<'a> G<'a> {
    /// Dimension in [1, max], biased by current shrink scale.
    pub fn dim(&mut self, max: usize) -> usize {
        let cap = ((max as f64) * self.scale).max(1.0) as usize;
        1 + self.rng.below(cap)
    }

    /// Random f32 vector with N(0, sigma) entries.
    pub fn vec_f32(&mut self, d: usize, sigma: f32) -> Vec<f32> {
        let mut v = vec![0.0f32; d];
        self.rng.fill_normal(&mut v, sigma * self.scale as f32);
        v
    }

    pub fn f64_in(&mut self, lo: f64, hi: f64) -> f64 {
        self.rng.range_f64(lo, hi)
    }

    pub fn usize_in(&mut self, lo: usize, hi: usize) -> usize {
        lo + self.rng.below(hi - lo + 1)
    }
}

/// Run `prop` over `cfg.cases` random cases. `prop` returns
/// `Err(description)` on failure. Panics with diagnostics on failure
/// (after attempting simpler counterexamples).
pub fn check<F>(name: &str, cfg: Config, mut prop: F)
where
    F: FnMut(&mut G) -> Result<(), String>,
{
    let mut rng = Rng::new(cfg.seed);
    for case in 0..cfg.cases {
        let mut case_rng = rng.fork(case as u64);
        let mut g = G {
            rng: &mut case_rng,
            scale: 1.0,
        };
        if let Err(msg) = prop(&mut g) {
            // Shrink: try progressively smaller scales with fresh seeds
            // derived from the failing case; keep the simplest failure.
            let mut simplest = (1.0, msg.clone());
            for (i, scale) in [0.5, 0.25, 0.1, 0.05].iter().enumerate() {
                let mut srng = rng.fork(case as u64 ^ (0xBEEF << i));
                let mut sg = G {
                    rng: &mut srng,
                    scale: *scale,
                };
                if let Err(m) = prop(&mut sg) {
                    simplest = (*scale, m);
                }
            }
            panic!(
                "property '{name}' failed (case {case}, seed {}):\n  at scale {}: {}",
                cfg.seed, simplest.0, simplest.1
            );
        }
    }
}

/// Assert-like helper producing `Result<(), String>` for use in properties.
#[macro_export]
macro_rules! prop_assert {
    ($cond:expr, $($fmt:tt)*) => {
        if !($cond) {
            return Err(format!($($fmt)*));
        }
    };
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn passing_property_runs_all_cases() {
        let mut count = 0;
        check("trivial", Config { cases: 32, seed: 1 }, |g| {
            count += 1;
            let d = g.dim(100);
            if d >= 1 {
                Ok(())
            } else {
                Err("impossible".into())
            }
        });
        assert_eq!(count, 32);
    }

    #[test]
    #[should_panic(expected = "property 'must-fail' failed")]
    fn failing_property_panics() {
        check("must-fail", Config { cases: 8, seed: 2 }, |g| {
            let d = g.dim(100);
            if d < 101 {
                Err(format!("d = {d}"))
            } else {
                Ok(())
            }
        });
    }
}
