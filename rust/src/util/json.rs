//! Minimal JSON parser/writer (serde_json is unavailable offline).
//!
//! Covers the full JSON grammar (objects, arrays, strings with escapes,
//! numbers, bools, null) — enough to read `artifacts/manifest.json`,
//! read/write experiment configs, and emit metric records.

use std::collections::BTreeMap;
use std::fmt;

/// A JSON value. Object keys are kept sorted (BTreeMap) so output is
/// deterministic.
#[derive(Clone, Debug, PartialEq)]
pub enum Json {
    Null,
    Bool(bool),
    Num(f64),
    Str(String),
    Arr(Vec<Json>),
    Obj(BTreeMap<String, Json>),
}

/// Deepest permitted array/object nesting. The recursive-descent parser
/// recurses once per level, so unbounded depth lets `[[[[…]]]]` from an
/// untrusted source (the serve socket) overflow the stack; 128 levels is
/// far beyond any artifact this crate reads or writes.
pub const MAX_PARSE_DEPTH: usize = 128;

/// Largest f64 at which every integer is still exactly representable
/// (2⁵³); beyond it `as_u64`/`as_usize` refuse to guess.
const MAX_EXACT_F64_INT: f64 = 9_007_199_254_740_992.0;

#[derive(Debug)]
pub struct JsonError {
    pub msg: String,
    pub pos: usize,
}

impl fmt::Display for JsonError {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "json error at byte {}: {}", self.pos, self.msg)
    }
}

impl std::error::Error for JsonError {}

impl Json {
    // ---------------- accessors ----------------

    pub fn get(&self, key: &str) -> Option<&Json> {
        match self {
            Json::Obj(m) => m.get(key),
            _ => None,
        }
    }

    pub fn as_str(&self) -> Option<&str> {
        match self {
            Json::Str(s) => Some(s),
            _ => None,
        }
    }

    pub fn as_f64(&self) -> Option<f64> {
        match self {
            Json::Num(x) => Some(*x),
            _ => None,
        }
    }

    /// Exactly-representable non-negative integer, else `None`. Unlike a
    /// raw `as usize` cast this *rejects* rather than truncates: -3.0 and
    /// 3.7 are `None`, as are NaN/±inf and anything above 2⁵³ (where f64
    /// can no longer represent every integer, so a parsed value may
    /// already have been rounded).
    pub fn as_usize(&self) -> Option<usize> {
        self.as_u64().and_then(|x| usize::try_from(x).ok())
    }

    /// `as_usize`'s u64 twin, with the same exactness contract.
    pub fn as_u64(&self) -> Option<u64> {
        match self.as_f64() {
            Some(x) if x.is_finite() && x >= 0.0 && x == x.trunc() && x <= MAX_EXACT_F64_INT => {
                Some(x as u64)
            }
            _ => None,
        }
    }

    pub fn as_bool(&self) -> Option<bool> {
        match self {
            Json::Bool(b) => Some(*b),
            _ => None,
        }
    }

    pub fn as_arr(&self) -> Option<&[Json]> {
        match self {
            Json::Arr(v) => Some(v),
            _ => None,
        }
    }

    pub fn as_obj(&self) -> Option<&BTreeMap<String, Json>> {
        match self {
            Json::Obj(m) => Some(m),
            _ => None,
        }
    }

    // ---------------- constructors ----------------

    pub fn obj() -> Json {
        Json::Obj(BTreeMap::new())
    }

    pub fn set(mut self, key: &str, v: impl Into<Json>) -> Json {
        if let Json::Obj(ref mut m) = self {
            m.insert(key.to_string(), v.into());
        }
        self
    }

    // ---------------- parse ----------------

    pub fn parse(text: &str) -> Result<Json, JsonError> {
        let mut p = Parser {
            b: text.as_bytes(),
            i: 0,
            depth: 0,
        };
        p.skip_ws();
        let v = p.value()?;
        p.skip_ws();
        if p.i != p.b.len() {
            return Err(p.err("trailing characters"));
        }
        Ok(v)
    }

    // ---------------- write ----------------

    #[allow(clippy::inherent_to_string)]
    pub fn to_string(&self) -> String {
        let mut s = String::new();
        self.write(&mut s);
        s
    }

    pub fn to_string_pretty(&self) -> String {
        let mut s = String::new();
        self.write_pretty(&mut s, 0);
        s
    }

    fn write(&self, out: &mut String) {
        match self {
            Json::Null => out.push_str("null"),
            Json::Bool(b) => out.push_str(if *b { "true" } else { "false" }),
            Json::Num(x) => write_num(*x, out),
            Json::Str(s) => write_str(s, out),
            Json::Arr(v) => {
                out.push('[');
                for (i, x) in v.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    x.write(out);
                }
                out.push(']');
            }
            Json::Obj(m) => {
                out.push('{');
                for (i, (k, x)) in m.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    write_str(k, out);
                    out.push(':');
                    x.write(out);
                }
                out.push('}');
            }
        }
    }

    fn write_pretty(&self, out: &mut String, indent: usize) {
        match self {
            Json::Arr(v) if !v.is_empty() => {
                out.push_str("[\n");
                for (i, x) in v.iter().enumerate() {
                    if i > 0 {
                        out.push_str(",\n");
                    }
                    out.push_str(&"  ".repeat(indent + 1));
                    x.write_pretty(out, indent + 1);
                }
                out.push('\n');
                out.push_str(&"  ".repeat(indent));
                out.push(']');
            }
            Json::Obj(m) if !m.is_empty() => {
                out.push_str("{\n");
                for (i, (k, x)) in m.iter().enumerate() {
                    if i > 0 {
                        out.push_str(",\n");
                    }
                    out.push_str(&"  ".repeat(indent + 1));
                    write_str(k, out);
                    out.push_str(": ");
                    x.write_pretty(out, indent + 1);
                }
                out.push('\n');
                out.push_str(&"  ".repeat(indent));
                out.push('}');
            }
            _ => self.write(out),
        }
    }
}

fn write_num(x: f64, out: &mut String) {
    if x.is_finite() && x == x.trunc() && x.abs() < 1e15 {
        out.push_str(&format!("{}", x as i64));
    } else if x.is_finite() {
        out.push_str(&format!("{x}"));
    } else {
        out.push_str("null"); // JSON has no NaN/Inf
    }
}

fn write_str(s: &str, out: &mut String) {
    out.push('"');
    for c in s.chars() {
        match c {
            '"' => out.push_str("\\\""),
            '\\' => out.push_str("\\\\"),
            '\n' => out.push_str("\\n"),
            '\r' => out.push_str("\\r"),
            '\t' => out.push_str("\\t"),
            c if (c as u32) < 0x20 => out.push_str(&format!("\\u{:04x}", c as u32)),
            c => out.push(c),
        }
    }
    out.push('"');
}

impl From<f64> for Json {
    fn from(x: f64) -> Json {
        Json::Num(x)
    }
}
impl From<usize> for Json {
    fn from(x: usize) -> Json {
        Json::Num(x as f64)
    }
}
impl From<u64> for Json {
    fn from(x: u64) -> Json {
        Json::Num(x as f64)
    }
}
impl From<i64> for Json {
    fn from(x: i64) -> Json {
        Json::Num(x as f64)
    }
}
impl From<bool> for Json {
    fn from(x: bool) -> Json {
        Json::Bool(x)
    }
}
impl From<&str> for Json {
    fn from(x: &str) -> Json {
        Json::Str(x.to_string())
    }
}
impl From<String> for Json {
    fn from(x: String) -> Json {
        Json::Str(x)
    }
}
impl<T: Into<Json>> From<Vec<T>> for Json {
    fn from(v: Vec<T>) -> Json {
        Json::Arr(v.into_iter().map(Into::into).collect())
    }
}

struct Parser<'a> {
    b: &'a [u8],
    i: usize,
    depth: usize,
}

impl<'a> Parser<'a> {
    fn err(&self, msg: &str) -> JsonError {
        JsonError {
            msg: msg.to_string(),
            pos: self.i,
        }
    }

    /// One recursion level per array/object. Errors abort the whole parse,
    /// so only the Ok paths need the matching `depth -= 1`.
    fn enter(&mut self) -> Result<(), JsonError> {
        self.depth += 1;
        if self.depth > MAX_PARSE_DEPTH {
            return Err(self.err(&format!("nesting deeper than {MAX_PARSE_DEPTH} levels")));
        }
        Ok(())
    }

    fn skip_ws(&mut self) {
        while self.i < self.b.len() && matches!(self.b[self.i], b' ' | b'\t' | b'\n' | b'\r') {
            self.i += 1;
        }
    }

    fn peek(&self) -> Option<u8> {
        self.b.get(self.i).copied()
    }

    fn expect(&mut self, c: u8) -> Result<(), JsonError> {
        if self.peek() == Some(c) {
            self.i += 1;
            Ok(())
        } else {
            Err(self.err(&format!("expected '{}'", c as char)))
        }
    }

    fn value(&mut self) -> Result<Json, JsonError> {
        match self.peek() {
            Some(b'{') => self.object(),
            Some(b'[') => self.array(),
            Some(b'"') => Ok(Json::Str(self.string()?)),
            Some(b't') => self.lit("true", Json::Bool(true)),
            Some(b'f') => self.lit("false", Json::Bool(false)),
            Some(b'n') => self.lit("null", Json::Null),
            Some(c) if c == b'-' || c.is_ascii_digit() => self.number(),
            _ => Err(self.err("unexpected character")),
        }
    }

    fn lit(&mut self, word: &str, v: Json) -> Result<Json, JsonError> {
        if self.b[self.i..].starts_with(word.as_bytes()) {
            self.i += word.len();
            Ok(v)
        } else {
            Err(self.err("bad literal"))
        }
    }

    fn number(&mut self) -> Result<Json, JsonError> {
        let start = self.i;
        if self.peek() == Some(b'-') {
            self.i += 1;
        }
        while matches!(self.peek(), Some(c) if c.is_ascii_digit()) {
            self.i += 1;
        }
        if self.peek() == Some(b'.') {
            self.i += 1;
            while matches!(self.peek(), Some(c) if c.is_ascii_digit()) {
                self.i += 1;
            }
        }
        if matches!(self.peek(), Some(b'e') | Some(b'E')) {
            self.i += 1;
            if matches!(self.peek(), Some(b'+') | Some(b'-')) {
                self.i += 1;
            }
            while matches!(self.peek(), Some(c) if c.is_ascii_digit()) {
                self.i += 1;
            }
        }
        let s = std::str::from_utf8(&self.b[start..self.i]).unwrap();
        s.parse::<f64>()
            .map(Json::Num)
            .map_err(|_| self.err("bad number"))
    }

    fn string(&mut self) -> Result<String, JsonError> {
        self.expect(b'"')?;
        let mut out = String::new();
        loop {
            match self.peek() {
                None => return Err(self.err("unterminated string")),
                Some(b'"') => {
                    self.i += 1;
                    return Ok(out);
                }
                Some(b'\\') => {
                    self.i += 1;
                    match self.peek() {
                        Some(b'"') => out.push('"'),
                        Some(b'\\') => out.push('\\'),
                        Some(b'/') => out.push('/'),
                        Some(b'n') => out.push('\n'),
                        Some(b't') => out.push('\t'),
                        Some(b'r') => out.push('\r'),
                        Some(b'b') => out.push('\u{8}'),
                        Some(b'f') => out.push('\u{c}'),
                        Some(b'u') => {
                            if self.i + 4 >= self.b.len() {
                                return Err(self.err("bad \\u escape"));
                            }
                            let hex =
                                std::str::from_utf8(&self.b[self.i + 1..self.i + 5]).unwrap();
                            let cp = u32::from_str_radix(hex, 16)
                                .map_err(|_| self.err("bad \\u escape"))?;
                            out.push(char::from_u32(cp).unwrap_or('\u{fffd}'));
                            self.i += 4;
                        }
                        _ => return Err(self.err("bad escape")),
                    }
                    self.i += 1;
                }
                Some(_) => {
                    // copy a full UTF-8 sequence
                    let start = self.i;
                    self.i += 1;
                    while self.i < self.b.len() && (self.b[self.i] & 0xC0) == 0x80 {
                        self.i += 1;
                    }
                    out.push_str(std::str::from_utf8(&self.b[start..self.i]).map_err(
                        |_| JsonError {
                            msg: "invalid utf8".into(),
                            pos: start,
                        },
                    )?);
                }
            }
        }
    }

    fn array(&mut self) -> Result<Json, JsonError> {
        self.expect(b'[')?;
        self.enter()?;
        let mut v = Vec::new();
        self.skip_ws();
        if self.peek() == Some(b']') {
            self.i += 1;
            self.depth -= 1;
            return Ok(Json::Arr(v));
        }
        loop {
            self.skip_ws();
            v.push(self.value()?);
            self.skip_ws();
            match self.peek() {
                Some(b',') => self.i += 1,
                Some(b']') => {
                    self.i += 1;
                    self.depth -= 1;
                    return Ok(Json::Arr(v));
                }
                _ => return Err(self.err("expected ',' or ']'")),
            }
        }
    }

    fn object(&mut self) -> Result<Json, JsonError> {
        self.expect(b'{')?;
        self.enter()?;
        let mut m = BTreeMap::new();
        self.skip_ws();
        if self.peek() == Some(b'}') {
            self.i += 1;
            self.depth -= 1;
            return Ok(Json::Obj(m));
        }
        loop {
            self.skip_ws();
            let k = self.string()?;
            self.skip_ws();
            self.expect(b':')?;
            self.skip_ws();
            let v = self.value()?;
            m.insert(k, v);
            self.skip_ws();
            match self.peek() {
                Some(b',') => self.i += 1,
                Some(b'}') => {
                    self.i += 1;
                    self.depth -= 1;
                    return Ok(Json::Obj(m));
                }
                _ => return Err(self.err("expected ',' or '}'")),
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parse_scalars() {
        assert_eq!(Json::parse("null").unwrap(), Json::Null);
        assert_eq!(Json::parse("true").unwrap(), Json::Bool(true));
        assert_eq!(Json::parse("-3.5e2").unwrap(), Json::Num(-350.0));
        assert_eq!(
            Json::parse("\"a\\nb\"").unwrap(),
            Json::Str("a\nb".to_string())
        );
    }

    #[test]
    fn parse_nested() {
        let j = Json::parse(r#"{"a": [1, 2, {"b": "x"}], "c": false}"#).unwrap();
        assert_eq!(j.get("c"), Some(&Json::Bool(false)));
        let arr = j.get("a").unwrap().as_arr().unwrap();
        assert_eq!(arr[2].get("b").unwrap().as_str(), Some("x"));
    }

    #[test]
    fn roundtrip() {
        let src = r#"{"arr":[1,2.5,"s"],"nested":{"k":null},"t":true}"#;
        let j = Json::parse(src).unwrap();
        let out = j.to_string();
        assert_eq!(Json::parse(&out).unwrap(), j);
    }

    #[test]
    fn pretty_roundtrip() {
        let j = Json::obj()
            .set("x", 1.5f64)
            .set("name", "sparq")
            .set("flags", vec![1u64, 2, 3]);
        let pretty = j.to_string_pretty();
        assert_eq!(Json::parse(&pretty).unwrap(), j);
    }

    #[test]
    fn unicode_escape() {
        let j = Json::parse("\"\\u0041\\u00e9\"").unwrap();
        assert_eq!(j.as_str(), Some("Aé"));
    }

    #[test]
    fn rejects_garbage() {
        assert!(Json::parse("{").is_err());
        assert!(Json::parse("[1,]").is_err());
        assert!(Json::parse("12 34").is_err());
        assert!(Json::parse("'single'").is_err());
    }

    #[test]
    fn as_usize_rejects_non_integers() {
        // Regression: these used to truncate through `x as usize`
        // (-3.0 → 0, 3.7 → 3) instead of rejecting.
        assert_eq!(Json::Num(-3.0).as_usize(), None);
        assert_eq!(Json::Num(3.7).as_usize(), None);
        assert_eq!(Json::Num(f64::NAN).as_usize(), None);
        assert_eq!(Json::Num(f64::INFINITY).as_usize(), None);
        assert_eq!(Json::Num(1e300).as_usize(), None);
        assert_eq!(Json::Str("3".into()).as_usize(), None);
        // exact values still pass, including zero and 2^53 itself
        assert_eq!(Json::Num(0.0).as_usize(), Some(0));
        assert_eq!(Json::Num(3.0).as_usize(), Some(3));
        assert_eq!(Json::Num(9_007_199_254_740_992.0).as_u64(), Some(1 << 53));
        // one past 2^53: 2^53 + 1 is not representable, so the parsed
        // value would already be rounded — refuse to guess
        assert_eq!(Json::Num((1u64 << 53) as f64 * 2.0).as_u64(), None);
    }

    #[test]
    fn parse_depth_at_limit_ok() {
        let src = format!(
            "{}1{}",
            "[".repeat(MAX_PARSE_DEPTH),
            "]".repeat(MAX_PARSE_DEPTH)
        );
        assert!(Json::parse(&src).is_ok());
    }

    #[test]
    fn parse_depth_beyond_limit_is_error() {
        let src = format!(
            "{}1{}",
            "[".repeat(MAX_PARSE_DEPTH + 1),
            "]".repeat(MAX_PARSE_DEPTH + 1)
        );
        let err = Json::parse(&src).unwrap_err();
        assert!(err.msg.contains("nesting deeper"), "{}", err);
        // mixed object/array nesting counts every level
        let src = "{\"a\":".repeat(70) + &"[".repeat(70) + "1" + &"]".repeat(70) + &"}".repeat(70);
        assert!(Json::parse(&src).is_err());
    }

    #[test]
    fn parse_pathological_depth_returns_error_not_crash() {
        // Regression: 100k nested arrays used to overflow the parser stack
        // (abort, not Err) — a remote crash once the daemon parses
        // client-supplied payloads.
        let src = "[".repeat(100_000);
        assert!(Json::parse(&src).is_err());
    }

    #[test]
    fn parses_real_manifest_shape() {
        let src = r#"{"artifacts": {"logreg_grad": {"file": "logreg_grad.hlo.txt",
            "inputs": [{"dtype": "float32", "name": "params", "shape": [7850]}],
            "outputs": [{"dtype": "float32", "name": "loss", "shape": []}]}},
            "format": "hlo-text"}"#;
        let j = Json::parse(src).unwrap();
        let art = j.get("artifacts").unwrap().get("logreg_grad").unwrap();
        assert_eq!(art.get("file").unwrap().as_str(), Some("logreg_grad.hlo.txt"));
        let shape = art.get("inputs").unwrap().as_arr().unwrap()[0]
            .get("shape")
            .unwrap()
            .as_arr()
            .unwrap();
        assert_eq!(shape[0].as_usize(), Some(7850));
    }
}
