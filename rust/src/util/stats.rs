//! Summary statistics for metrics and the bench harness.

/// Running mean/variance (Welford) plus min/max.
#[derive(Clone, Debug, Default)]
pub struct Summary {
    n: u64,
    mean: f64,
    m2: f64,
    min: f64,
    max: f64,
}

impl Summary {
    pub fn new() -> Self {
        Summary {
            n: 0,
            mean: 0.0,
            m2: 0.0,
            min: f64::INFINITY,
            max: f64::NEG_INFINITY,
        }
    }

    pub fn push(&mut self, x: f64) {
        self.n += 1;
        let d = x - self.mean;
        self.mean += d / self.n as f64;
        self.m2 += d * (x - self.mean);
        self.min = self.min.min(x);
        self.max = self.max.max(x);
    }

    pub fn count(&self) -> u64 {
        self.n
    }

    pub fn mean(&self) -> f64 {
        self.mean
    }

    pub fn var(&self) -> f64 {
        if self.n < 2 {
            0.0
        } else {
            self.m2 / (self.n - 1) as f64
        }
    }

    pub fn std(&self) -> f64 {
        self.var().sqrt()
    }

    pub fn min(&self) -> f64 {
        self.min
    }

    pub fn max(&self) -> f64 {
        self.max
    }
}

/// Percentile over a sample (nearest-rank on a sorted copy).
pub fn percentile(xs: &[f64], p: f64) -> f64 {
    assert!(!xs.is_empty());
    let mut v = xs.to_vec();
    // total_cmp: NaNs (diverged-run diagnostics) sort to the top end
    // instead of panicking.
    v.sort_by(|a, b| a.total_cmp(b));
    let rank = ((p / 100.0) * (v.len() - 1) as f64).round() as usize;
    v[rank.min(v.len() - 1)]
}

/// Mean of a slice.
pub fn mean(xs: &[f64]) -> f64 {
    if xs.is_empty() {
        return 0.0;
    }
    xs.iter().sum::<f64>() / xs.len() as f64
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn summary_matches_closed_form() {
        let mut s = Summary::new();
        for x in [1.0, 2.0, 3.0, 4.0] {
            s.push(x);
        }
        assert_eq!(s.count(), 4);
        assert!((s.mean() - 2.5).abs() < 1e-12);
        assert!((s.var() - 5.0 / 3.0).abs() < 1e-12);
        assert_eq!(s.min(), 1.0);
        assert_eq!(s.max(), 4.0);
    }

    #[test]
    fn percentiles() {
        let xs: Vec<f64> = (0..101).map(|i| i as f64).collect();
        assert_eq!(percentile(&xs, 0.0), 0.0);
        assert_eq!(percentile(&xs, 50.0), 50.0);
        assert_eq!(percentile(&xs, 100.0), 100.0);
    }

    #[test]
    fn percentile_tolerates_nan() {
        // A diverged run's metrics must degrade, not panic; NaN sorts
        // after every finite value under total_cmp.
        let xs = [1.0, f64::NAN, 0.5];
        assert_eq!(percentile(&xs, 0.0), 0.5);
        assert!(percentile(&xs, 100.0).is_nan());
    }
}
