//! Deterministic pseudo-random number generation.
//!
//! xoshiro256** (Blackman & Vigna) seeded through splitmix64 — the
//! standard recommendation for seeding xoshiro state from a single u64.
//! Every stochastic component in the crate (data synthesis, mini-batch
//! sampling, RandK/QSGD randomness, initialization) draws from an
//! explicitly seeded [`Rng`], which makes entire training runs replayable
//! bit-for-bit; several tests rely on that (e.g. SPARQ with `c_t = 0`,
//! `H = 1` must equal CHOCO-SGD exactly).

/// splitmix64 step — used for seeding and cheap stateless hashing.
#[inline]
pub fn splitmix64(state: &mut u64) -> u64 {
    *state = state.wrapping_add(0x9E3779B97F4A7C15);
    let mut z = *state;
    z = (z ^ (z >> 30)).wrapping_mul(0xBF58476D1CE4E5B9);
    z = (z ^ (z >> 27)).wrapping_mul(0x94D049BB133111EB);
    z ^ (z >> 31)
}

/// xoshiro256** generator.
#[derive(Clone, Debug)]
pub struct Rng {
    s: [u64; 4],
}

impl Rng {
    /// Construct from a single seed via splitmix64 expansion.
    pub fn new(seed: u64) -> Self {
        let mut sm = seed;
        let mut s = [0u64; 4];
        for v in s.iter_mut() {
            *v = splitmix64(&mut sm);
        }
        // xoshiro must not start at the all-zero state.
        if s == [0, 0, 0, 0] {
            s[0] = 0x9E3779B97F4A7C15;
        }
        Rng { s }
    }

    /// The raw xoshiro256** state (checkpointing). Restoring via
    /// [`from_state`](Rng::from_state) resumes the stream bit-for-bit.
    pub fn state(&self) -> [u64; 4] {
        self.s
    }

    /// Rebuild a generator from a captured [`state`](Rng::state).
    pub fn from_state(s: [u64; 4]) -> Rng {
        let mut s = s;
        if s == [0, 0, 0, 0] {
            // Not reachable from a live generator; guard anyway.
            s[0] = 0x9E3779B97F4A7C15;
        }
        Rng { s }
    }

    /// Derive an independent stream (for per-node RNGs).
    pub fn fork(&mut self, stream: u64) -> Rng {
        let mut sm = self.next_u64() ^ stream.wrapping_mul(0xA24BAED4963EE407);
        let mut s = [0u64; 4];
        for v in s.iter_mut() {
            *v = splitmix64(&mut sm);
        }
        if s == [0, 0, 0, 0] {
            s[0] = 1;
        }
        Rng { s }
    }

    #[inline]
    pub fn next_u64(&mut self) -> u64 {
        let result = self.s[1]
            .wrapping_mul(5)
            .rotate_left(7)
            .wrapping_mul(9);
        let t = self.s[1] << 17;
        self.s[2] ^= self.s[0];
        self.s[3] ^= self.s[1];
        self.s[1] ^= self.s[2];
        self.s[0] ^= self.s[3];
        self.s[2] ^= t;
        self.s[3] = self.s[3].rotate_left(45);
        result
    }

    /// Uniform in [0, 1) with 53 bits of precision.
    #[inline]
    pub fn f64(&mut self) -> f64 {
        (self.next_u64() >> 11) as f64 * (1.0 / (1u64 << 53) as f64)
    }

    /// Uniform f32 in [0, 1).
    #[inline]
    pub fn f32(&mut self) -> f32 {
        self.f64() as f32
    }

    /// Uniform integer in [0, n) without modulo bias (Lemire's method).
    #[inline]
    pub fn below(&mut self, n: usize) -> usize {
        debug_assert!(n > 0);
        let n = n as u64;
        let mut x = self.next_u64();
        let mut m = (x as u128) * (n as u128);
        let mut l = m as u64;
        if l < n {
            let t = n.wrapping_neg() % n;
            while l < t {
                x = self.next_u64();
                m = (x as u128) * (n as u128);
                l = m as u64;
            }
        }
        (m >> 64) as usize
    }

    /// Uniform in [lo, hi).
    #[inline]
    pub fn range_f64(&mut self, lo: f64, hi: f64) -> f64 {
        lo + (hi - lo) * self.f64()
    }

    /// Standard normal via Box–Muller (both values used, cached).
    pub fn normal(&mut self) -> f64 {
        // Marsaglia polar method; rejection loop terminates w.p. 1.
        loop {
            let u = 2.0 * self.f64() - 1.0;
            let v = 2.0 * self.f64() - 1.0;
            let s = u * u + v * v;
            if s > 0.0 && s < 1.0 {
                return u * (-2.0 * s.ln() / s).sqrt();
            }
        }
    }

    /// Standard normal as f32.
    #[inline]
    pub fn normal_f32(&mut self) -> f32 {
        self.normal() as f32
    }

    /// Fill a slice with N(0, sigma^2) samples.
    pub fn fill_normal(&mut self, out: &mut [f32], sigma: f32) {
        for v in out.iter_mut() {
            *v = self.normal_f32() * sigma;
        }
    }

    /// Fisher–Yates shuffle.
    pub fn shuffle<T>(&mut self, xs: &mut [T]) {
        for i in (1..xs.len()).rev() {
            let j = self.below(i + 1);
            xs.swap(i, j);
        }
    }

    /// Sample `k` distinct indices from 0..n (partial Fisher–Yates).
    pub fn sample_indices(&mut self, n: usize, k: usize) -> Vec<usize> {
        assert!(k <= n);
        let mut idx: Vec<usize> = (0..n).collect();
        for i in 0..k {
            let j = i + self.below(n - i);
            idx.swap(i, j);
        }
        idx.truncate(k);
        idx
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic() {
        let mut a = Rng::new(42);
        let mut b = Rng::new(42);
        for _ in 0..100 {
            assert_eq!(a.next_u64(), b.next_u64());
        }
    }

    #[test]
    fn seeds_differ() {
        let mut a = Rng::new(1);
        let mut b = Rng::new(2);
        assert_ne!(a.next_u64(), b.next_u64());
    }

    #[test]
    fn f64_in_unit_interval() {
        let mut r = Rng::new(7);
        for _ in 0..10_000 {
            let x = r.f64();
            assert!((0.0..1.0).contains(&x));
        }
    }

    #[test]
    fn below_unbiased_smoke() {
        let mut r = Rng::new(3);
        let mut counts = [0usize; 5];
        for _ in 0..50_000 {
            counts[r.below(5)] += 1;
        }
        for &c in &counts {
            // 10k expected, allow ±5%
            assert!((9_500..10_500).contains(&c), "{counts:?}");
        }
    }

    #[test]
    fn normal_moments() {
        let mut r = Rng::new(9);
        let n = 100_000;
        let (mut sum, mut sq) = (0.0, 0.0);
        for _ in 0..n {
            let x = r.normal();
            sum += x;
            sq += x * x;
        }
        let mean = sum / n as f64;
        let var = sq / n as f64 - mean * mean;
        assert!(mean.abs() < 0.02, "mean {mean}");
        assert!((var - 1.0).abs() < 0.03, "var {var}");
    }

    #[test]
    fn sample_indices_distinct() {
        let mut r = Rng::new(11);
        let idx = r.sample_indices(100, 30);
        assert_eq!(idx.len(), 30);
        let mut sorted = idx.clone();
        sorted.sort_unstable();
        sorted.dedup();
        assert_eq!(sorted.len(), 30);
    }

    #[test]
    fn fork_streams_independent() {
        let mut root = Rng::new(5);
        let mut a = root.fork(0);
        let mut b = root.fork(1);
        let xs: Vec<u64> = (0..10).map(|_| a.next_u64()).collect();
        let ys: Vec<u64> = (0..10).map(|_| b.next_u64()).collect();
        assert_ne!(xs, ys);
    }

    #[test]
    fn state_roundtrip_resumes_stream() {
        let mut a = Rng::new(99);
        for _ in 0..17 {
            a.next_u64();
        }
        let mut b = Rng::from_state(a.state());
        for _ in 0..100 {
            assert_eq!(a.next_u64(), b.next_u64());
        }
        // zero-state guard produces a working generator
        let mut z = Rng::from_state([0, 0, 0, 0]);
        assert_ne!(z.next_u64(), z.next_u64());
    }

    #[test]
    fn shuffle_is_permutation() {
        let mut r = Rng::new(13);
        let mut v: Vec<usize> = (0..50).collect();
        r.shuffle(&mut v);
        let mut s = v.clone();
        s.sort_unstable();
        assert_eq!(s, (0..50).collect::<Vec<_>>());
    }
}
