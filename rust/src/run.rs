//! The `Run` handle: one training run as a first-class value.
//!
//! Pre-redesign, the crate had three hand-rolled copies of the same
//! evaluation loop — `coordinator::runner::run`, the sweep engine's
//! `execute_one`, and the `examples/` drivers — each re-implementing
//! record construction, eval cadence, and checkpoint plumbing. [`Run`]
//! owns that loop once: it pairs an algorithm with a gradient source and
//! a bus, exposes [`step`](Run::step) / [`eval`](Run::eval) /
//! [`snapshot`](Run::snapshot) / [`restore`](Run::restore) for manual
//! driving, and [`drive`](Run::drive) for the canonical loop with an
//! observer hooking every decision point (per-iteration [`tick`]
//! heartbeats, early-stop at evaluation records, checkpoint cadence,
//! fault injection, mid-run worker rebalancing). Lifecycle observers
//! reuse the sweep engine's [`RunEvent`]/[`EventHook`] types.
//!
//! `Run` is generic over ownership: `Run<Box<dyn DecentralizedAlgo>,
//! Box<dyn GradientSource>>` (the default, what
//! [`Run::from_resolved`] builds from a typed config) and
//! `Run<&mut dyn DecentralizedAlgo, &mut dyn GradientSource>` (what the
//! legacy `coordinator::runner::run` signature wraps) drive identically
//! through the forwarding impls on `&mut T`/`Box<T>`.
//!
//! [`tick`]: RunObserver::tick
//!
//! ```
//! use sparq::config::{ExperimentConfig, TriggerSpec};
//! use sparq::run::Run;
//!
//! let cfg = ExperimentConfig {
//!     nodes: 4,
//!     steps: 60,
//!     eval_every: 20,
//!     problem: "quadratic:16".into(),
//!     trigger: TriggerSpec::constant(20.0),
//!     ..Default::default()
//! };
//! let resolved = cfg.resolve().expect("coherent config");
//! let mut run = Run::from_resolved(&resolved, None, 1);
//! let series = run.run_to_end().expect("no observer to fail");
//! assert_eq!(series.records.len(), 4); // t = 0, 20, 40, 60
//! assert!(series.records.last().unwrap().opt_gap < series.records[0].opt_gap);
//! ```

use std::sync::{Arc, Mutex};

use crate::comm::Bus;
use crate::config::ResolvedConfig;
use crate::coordinator::{checkpoint, Checkpoint, DecentralizedAlgo};
use crate::metrics::{RoundRecord, Series};
use crate::problems::GradientSource;
use crate::sweep::cache::ArtifactCache;
use crate::util::json::Json;
use crate::util::Rng;

/// A run-lifecycle event (used by the sweep engine's scheduling-order
/// tests and progress UIs, and re-emitted by [`Run::drive`] for hooks
/// registered via [`Run::observe`]).
#[derive(Clone, Debug)]
pub enum RunEvent {
    /// A run began executing (not emitted for resume-skipped runs).
    Started {
        id: String,
        label: String,
        /// Node-level worker threads granted at start (the sweep
        /// engine's ⌊budget/concurrent⌋ split; rebalancing may raise it
        /// mid-run).
        node_workers: usize,
    },
    /// A run finished executing. `completed` is false for fault-aborted
    /// or abandoned runs; `stopped` is true when an early-stop target
    /// truncated it.
    Finished {
        id: String,
        label: String,
        completed: bool,
        stopped: bool,
    },
}

impl RunEvent {
    /// Serialize for wire transport (the serve daemon streams these to
    /// its subscribers as `{"kind": ..., ...}` objects).
    pub fn to_json(&self) -> Json {
        match self {
            RunEvent::Started {
                id,
                label,
                node_workers,
            } => Json::obj()
                .set("kind", "started")
                .set("id", id.as_str())
                .set("label", label.as_str())
                .set("node_workers", *node_workers),
            RunEvent::Finished {
                id,
                label,
                completed,
                stopped,
            } => Json::obj()
                .set("kind", "finished")
                .set("id", id.as_str())
                .set("label", label.as_str())
                .set("completed", *completed)
                .set("stopped", *stopped),
        }
    }

    /// Inverse of [`to_json`](Self::to_json); `None` for unknown kinds
    /// (a subscriber must skip, not crash on, event kinds newer than
    /// itself — the serve stream also carries job-level events).
    pub fn from_json(j: &Json) -> Option<RunEvent> {
        let s = |key: &str| j.get(key).and_then(Json::as_str).map(str::to_string);
        let b = |key: &str| j.get(key).and_then(Json::as_bool).unwrap_or(false);
        match j.get("kind").and_then(Json::as_str) {
            Some("started") => Some(RunEvent::Started {
                id: s("id")?,
                label: s("label")?,
                node_workers: j.get("node_workers").and_then(Json::as_usize).unwrap_or(1),
            }),
            Some("finished") => Some(RunEvent::Finished {
                id: s("id")?,
                label: s("label")?,
                completed: b("completed"),
                stopped: b("stopped"),
            }),
            _ => None,
        }
    }
}

/// Lifecycle-event callback (called from run worker threads).
pub type EventHook = Arc<dyn Fn(&RunEvent) + Send + Sync>;

/// Fan one lifecycle-event stream out to many dynamically registered
/// sinks. A `Run` (or sweep) observes a single [`EventHook`]; the serve
/// daemon needs every event delivered to a durable log *and* to any
/// number of live subscribers that come and go — this is the
/// multiplexing point. Sinks registered mid-stream see only subsequent
/// events; delivery order within one event is registration order.
#[derive(Default)]
pub struct EventFanout {
    sinks: Mutex<Vec<EventHook>>,
}

impl EventFanout {
    pub fn new() -> EventFanout {
        EventFanout::default()
    }

    /// Register a sink for all subsequent events.
    pub fn add(&self, sink: EventHook) {
        self.sinks.lock().unwrap().push(sink);
    }

    /// Deliver one event to every registered sink.
    pub fn emit(&self, event: &RunEvent) {
        // Snapshot under the lock, call outside it: a sink is allowed
        // to register further sinks without deadlocking.
        let sinks: Vec<EventHook> = self.sinks.lock().unwrap().clone();
        for sink in &sinks {
            sink(event);
        }
    }

    /// An [`EventHook`] that forwards into this fanout — plug it into
    /// [`Run::observe`] or `SweepOptions::on_event`.
    pub fn hook(self: &Arc<Self>) -> EventHook {
        let fan = Arc::clone(self);
        Arc::new(move |e: &RunEvent| fan.emit(e))
    }
}

/// Observer of one [`Run::drive`] invocation. Every method has a no-op
/// default, so implementors opt into exactly the decision points they
/// need.
pub trait RunObserver {
    /// Called once per iteration *before* the step (the distributed
    /// runner refreshes its claim heartbeat here). `Ok(false)` abandons
    /// the run ([`DriveEnd::Abandoned`]); `Err` aborts with the error.
    fn tick(&mut self, _t: u64) -> Result<bool, String> {
        Ok(true)
    }

    /// Called at every evaluation record (including t = 0). `done` is
    /// true for the final record of the horizon. Return `true` to stop
    /// the run at this record ([`DriveEnd::Stopped`]); a stop on the
    /// final record is meaningless and ignored.
    fn evaluated(&mut self, _rec: &RoundRecord, _done: bool) -> bool {
        false
    }

    /// Should a checkpoint be taken at iteration boundary `t`? (Called
    /// after the step and its evaluation, never on the final iteration.)
    fn checkpoint_due(&mut self, _t: u64) -> bool {
        false
    }

    /// Persist a snapshot requested via
    /// [`checkpoint_due`](Self::checkpoint_due) (paired with the series
    /// evaluated so far).
    fn persist(&mut self, _ck: Checkpoint, _series: &Series) -> Result<(), String> {
        Ok(())
    }

    /// Fault-injection hook: abandon the run at iteration boundary `t`
    /// without recording a result (crash simulation for takeover tests).
    fn abort_due(&mut self, _t: u64) -> bool {
        false
    }

    /// Worker-count hint consulted every iteration; `Some(w)` applies
    /// `w` node workers if different from the current count (the sweep
    /// engine re-splits ⌊budget/pending⌋ as its run pool drains).
    /// Results are bit-for-bit identical for any worker count.
    fn workers_hint(&mut self, _t: u64) -> Option<usize> {
        None
    }
}

/// The no-op observer (plain uninterrupted runs).
pub struct NoObserver;

impl RunObserver for NoObserver {}

/// How a [`Run::drive`] invocation ended.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum DriveEnd {
    /// The horizon was reached.
    Completed,
    /// The observer stopped the run at an evaluation record
    /// (early-stop target reached).
    Stopped,
    /// The run was abandoned mid-flight (lost claim / fault injection);
    /// its partial state is *not* a result.
    Abandoned,
}

/// One training run as a value (see module docs). `A` and `P` are
/// anything that implements the algorithm/source traits — concrete
/// engines, boxed trait objects, or `&mut` borrows.
pub struct Run<A = Box<dyn DecentralizedAlgo>, P = Box<dyn GradientSource>>
where
    A: DecentralizedAlgo,
    P: GradientSource,
{
    algo: A,
    problem: P,
    bus: Bus,
    series: Series,
    id: String,
    t: u64,
    steps: u64,
    eval_every: u64,
    /// Last applied node-worker count (`usize::MAX` = engine default,
    /// nothing applied yet) — lets rebalancing hints skip redundant
    /// thread-pool rebuilds.
    workers: usize,
    hooks: Vec<EventHook>,
    announced: bool,
}

impl Run<Box<dyn DecentralizedAlgo>, Box<dyn GradientSource>> {
    /// Build a run from a resolved config: problem, engine, shared
    /// initial parameters, and `workers` node-worker threads. With a
    /// sweep [`ArtifactCache`], topology/spectral/dataset artifacts are
    /// shared across runs (bit-for-bit identical to uncached builds).
    pub fn from_resolved(
        resolved: &ResolvedConfig,
        cache: Option<&ArtifactCache>,
        workers: usize,
    ) -> Run {
        use crate::experiments::builder::{build_algo_resolved, build_problem_with};
        let cfg = resolved.config();
        let problem = build_problem_with(cfg, cache);
        let d = problem.dim();
        let mut algo = build_algo_resolved(resolved, d, cache);
        let mut init_rng = Rng::new(cfg.seed ^ 0x1217);
        if let Some(x0) = problem.init_params(&mut init_rng) {
            algo.set_params(&x0);
        }
        let label = format!("{}:{}", cfg.name, algo.name());
        let mut run = Run::new(algo, problem, cfg.steps, cfg.eval_every, label);
        run.id = crate::sweep::spec::config_hash(cfg);
        run.set_workers(workers);
        run
    }
}

impl<A: DecentralizedAlgo, P: GradientSource> Run<A, P> {
    /// Wrap an already-built algorithm/source pair. The series label is
    /// `label`; evaluation happens every `eval_every` iterations (plus
    /// t = 0 and the final iteration).
    pub fn new(algo: A, problem: P, steps: u64, eval_every: u64, label: String) -> Run<A, P> {
        let bus = Bus::new(algo.n());
        Run {
            id: label.clone(),
            series: Series::new(label),
            algo,
            problem,
            bus,
            t: 0,
            steps,
            eval_every,
            workers: usize::MAX,
            hooks: Vec::new(),
            announced: false,
        }
    }

    /// Register a lifecycle observer ([`RunEvent::Started`] at the first
    /// [`drive`](Self::drive), [`RunEvent::Finished`] when it returns).
    pub fn observe(&mut self, hook: EventHook) {
        self.hooks.push(hook);
    }

    /// Current iteration (0 before the first step).
    pub fn t(&self) -> u64 {
        self.t
    }

    /// Horizon T.
    pub fn steps(&self) -> u64 {
        self.steps
    }

    /// Has the horizon been reached?
    pub fn done(&self) -> bool {
        self.t >= self.steps
    }

    /// The evaluated series so far.
    pub fn series(&self) -> &Series {
        &self.series
    }

    /// Mutable access to the series (resume truncation).
    pub fn series_mut(&mut self) -> &mut Series {
        &mut self.series
    }

    /// Consume the run, returning its series.
    pub fn into_series(self) -> Series {
        self.series
    }

    /// The underlying algorithm.
    pub fn algo(&self) -> &A {
        &self.algo
    }

    /// Mutable access to the algorithm (the cluster runtime installs
    /// its socket transport here after the run is built).
    pub fn algo_mut(&mut self) -> &mut A {
        &mut self.algo
    }

    /// Communication totals (what evaluation records charge from).
    pub fn bus(&self) -> &Bus {
        &self.bus
    }

    /// Cumulative (transmitted, opportunities) trigger statistics.
    pub fn fired_stats(&self) -> (u64, u64) {
        self.algo.fired_stats()
    }

    /// Average iterate x̄ (the quantity the theorems track).
    pub fn x_bar(&self) -> Vec<f32> {
        self.algo.x_bar()
    }

    /// Set the node-worker count, skipping redundant pool rebuilds.
    /// Bit-for-bit identical results for every value.
    pub fn set_workers(&mut self, workers: usize) {
        if workers != self.workers {
            self.workers = workers;
            self.algo.set_workers(workers);
        }
    }

    /// Advance one iteration (no evaluation).
    pub fn step(&mut self) {
        self.algo.step(self.t, &mut self.problem, &mut self.bus);
        self.t += 1;
    }

    /// Evaluate at the current iteration and append the record.
    pub fn eval(&mut self) -> &RoundRecord {
        let xbar = self.algo.x_bar();
        let loss = self.problem.global_loss(&xbar);
        self.series.push(RoundRecord {
            t: self.t,
            loss,
            test_error: self.problem.test_error(&xbar).unwrap_or(f64::NAN),
            opt_gap: self.problem.opt_gap(&xbar).unwrap_or(f64::NAN),
            bits: self.bus.total_bits,
            comm_rounds: self.bus.comm_rounds,
            consensus: self.algo.consensus_distance(),
            fired: self.algo.last_fired(),
        });
        self.series.records.last().expect("just pushed")
    }

    /// Capture the full run state at the current iteration boundary.
    pub fn snapshot(&self) -> Checkpoint {
        checkpoint::snapshot(&self.algo, self.t, &self.bus)
    }

    /// Restore a snapshot (bit-for-bit resume) together with the series
    /// evaluated up to it. A snapshot that does not fit this run (wrong
    /// node count, dimension, or algorithm) is rejected with a
    /// [`checkpoint::RestoreError`] and the run is left untouched.
    pub fn restore(
        &mut self,
        ck: &Checkpoint,
        series: Series,
    ) -> Result<(), checkpoint::RestoreError> {
        checkpoint::restore(&mut self.algo, ck)?;
        checkpoint::restore_bus(&mut self.bus, ck);
        self.series = series;
        self.t = ck.t;
        Ok(())
    }

    fn emit(&self, event: RunEvent) {
        for hook in &self.hooks {
            hook(&event);
        }
    }

    /// The canonical evaluation loop (replicates the pre-redesign
    /// runner/sweep loops exactly — pinned by the sweep equivalence and
    /// engine-equivalence suites): evaluate at t = 0, then per
    /// iteration: observer tick → step → evaluate at the cadence (and at
    /// the horizon) → early-stop check (never on the final record) →
    /// checkpoint cadence → fault-injection check. Resumable: after
    /// [`restore`](Self::restore) the loop continues from the snapshot
    /// iteration without re-evaluating t = 0.
    pub fn drive(&mut self, obs: &mut dyn RunObserver) -> Result<DriveEnd, String> {
        if !self.announced {
            self.announced = true;
            self.emit(RunEvent::Started {
                id: self.id.clone(),
                label: self.series.label.clone(),
                node_workers: if self.workers == usize::MAX { 1 } else { self.workers },
            });
        }
        let end = self.drive_inner(obs)?;
        self.emit(RunEvent::Finished {
            id: self.id.clone(),
            label: self.series.label.clone(),
            completed: end != DriveEnd::Abandoned,
            stopped: end == DriveEnd::Stopped,
        });
        Ok(end)
    }

    fn drive_inner(&mut self, obs: &mut dyn RunObserver) -> Result<DriveEnd, String> {
        if self.t == 0 && self.series.records.is_empty() {
            self.eval();
            let rec = self.series.records.last().expect("t=0 record");
            // A zero-step run's t=0 record is final — stops are ignored.
            if obs.evaluated(rec, self.steps == 0) && self.steps > 0 {
                return Ok(DriveEnd::Stopped);
            }
        }
        while self.t < self.steps {
            let t = self.t;
            if let Some(w) = obs.workers_hint(t) {
                self.set_workers(w);
            }
            if !obs.tick(t)? {
                return Ok(DriveEnd::Abandoned);
            }
            self.step();
            let done = self.t == self.steps;
            if self.t % self.eval_every.max(1) == 0 || done {
                self.eval();
                let rec = self.series.records.last().expect("eval record");
                // Early stop truncates *at* the evaluation record that
                // reached the target; the cadence is config-fixed, so
                // the stop round — and the truncated series, bit for
                // bit — is identical for every worker budget and for
                // serial vs distributed execution.
                if obs.evaluated(rec, done) && !done {
                    return Ok(DriveEnd::Stopped);
                }
            }
            if !done && obs.checkpoint_due(self.t) {
                let ck = self.snapshot();
                obs.persist(ck, &self.series)?;
            }
            if !done && obs.abort_due(self.t) {
                return Ok(DriveEnd::Abandoned);
            }
        }
        Ok(DriveEnd::Completed)
    }

    /// Drive to the horizon with no observer; returns the series.
    pub fn run_to_end(&mut self) -> Result<&Series, String> {
        self.drive(&mut NoObserver)?;
        Ok(&self.series)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::ExperimentConfig;
    use crate::experiments::run_config;
    use std::sync::Mutex;

    fn quick_cfg() -> ExperimentConfig {
        ExperimentConfig {
            name: "run-handle".into(),
            nodes: 5,
            steps: 120,
            eval_every: 40,
            problem: "quadratic:16".into(),
            compressor: "sign_topk:25%".into(),
            trigger: "const:20".into(),
            ..Default::default()
        }
    }

    #[test]
    fn drive_matches_run_config_bit_for_bit() {
        let cfg = quick_cfg();
        let expect = run_config(&cfg, false);
        let resolved = cfg.resolve().unwrap();
        let mut run = Run::from_resolved(&resolved, None, 1);
        let got = run.run_to_end().unwrap();
        assert_eq!(got.to_csv(), expect.to_csv());
        assert_eq!(got.label, expect.label);
    }

    #[test]
    fn manual_step_eval_equals_drive() {
        let resolved = quick_cfg().resolve().unwrap();
        let mut a = Run::from_resolved(&resolved, None, 1);
        a.run_to_end().unwrap();
        let mut b = Run::from_resolved(&resolved, None, 1);
        b.eval();
        for t in 0..120u64 {
            b.step();
            if (t + 1) % 40 == 0 {
                b.eval();
            }
        }
        assert_eq!(a.series().to_csv(), b.series().to_csv());
    }

    #[test]
    fn snapshot_restore_resumes_bit_for_bit() {
        let resolved = quick_cfg().resolve().unwrap();
        let mut full = Run::from_resolved(&resolved, None, 1);
        full.run_to_end().unwrap();

        let mut first = Run::from_resolved(&resolved, None, 1);
        first.eval();
        for _ in 0..60 {
            first.step();
            if first.t() % 40 == 0 {
                first.eval();
            }
        }
        let ck = first.snapshot();
        let partial = first.series().clone();

        let mut second = Run::from_resolved(&resolved, None, 1);
        second.restore(&ck, partial).unwrap();
        assert_eq!(second.t(), 60);
        second.drive(&mut NoObserver).unwrap();
        assert_eq!(second.series().to_csv(), full.series().to_csv());
    }

    #[test]
    fn mismatched_snapshot_is_rejected_and_run_left_untouched() {
        let resolved = quick_cfg().resolve().unwrap();
        let mut donor = Run::from_resolved(&resolved, None, 1);
        donor.eval();
        for _ in 0..40 {
            donor.step();
        }
        let ck = donor.snapshot();

        // A run with a different node count must refuse the snapshot
        // (the old behavior was a panic deep in the restore path).
        let mut other_cfg = quick_cfg();
        other_cfg.nodes = 4;
        let other = other_cfg.resolve().unwrap();
        let mut run = Run::from_resolved(&other, None, 1);
        let err = run.restore(&ck, donor.series().clone()).unwrap_err();
        assert_eq!(err.field, "nodes");
        assert!(err.to_string().contains("run expects 4"), "{err}");
        // ...and the refused run still drives from scratch, unpoisoned.
        assert_eq!(run.t(), 0);
        run.run_to_end().unwrap();
        assert_eq!(run.series().records.last().unwrap().t, 120);
    }

    #[test]
    fn observer_hooks_fire_in_order() {
        struct Probe {
            ticks: u64,
            evals: Vec<u64>,
        }
        impl RunObserver for Probe {
            fn tick(&mut self, _t: u64) -> Result<bool, String> {
                self.ticks += 1;
                Ok(true)
            }
            fn evaluated(&mut self, rec: &RoundRecord, _done: bool) -> bool {
                self.evals.push(rec.t);
                false
            }
        }
        let resolved = quick_cfg().resolve().unwrap();
        let mut run = Run::from_resolved(&resolved, None, 1);
        let mut probe = Probe {
            ticks: 0,
            evals: Vec::new(),
        };
        let end = run.drive(&mut probe).unwrap();
        assert_eq!(end, DriveEnd::Completed);
        assert_eq!(probe.ticks, 120);
        assert_eq!(probe.evals, vec![0, 40, 80, 120]);
    }

    #[test]
    fn early_stop_and_abandon_paths() {
        struct StopAt(u64);
        impl RunObserver for StopAt {
            fn evaluated(&mut self, rec: &RoundRecord, _done: bool) -> bool {
                rec.t >= self.0
            }
        }
        let resolved = quick_cfg().resolve().unwrap();
        let mut run = Run::from_resolved(&resolved, None, 1);
        assert_eq!(run.drive(&mut StopAt(40)).unwrap(), DriveEnd::Stopped);
        assert_eq!(run.series().records.last().unwrap().t, 40);

        struct Abandon;
        impl RunObserver for Abandon {
            fn tick(&mut self, t: u64) -> Result<bool, String> {
                Ok(t < 10)
            }
        }
        let mut run = Run::from_resolved(&resolved, None, 1);
        assert_eq!(run.drive(&mut Abandon).unwrap(), DriveEnd::Abandoned);
        assert_eq!(run.t(), 10);
        // a stop on the final record is ignored (the run completed)
        struct StopAtEnd;
        impl RunObserver for StopAtEnd {
            fn evaluated(&mut self, rec: &RoundRecord, done: bool) -> bool {
                done && rec.t > 0
            }
        }
        let mut run = Run::from_resolved(&resolved, None, 1);
        assert_eq!(run.drive(&mut StopAtEnd).unwrap(), DriveEnd::Completed);
    }

    #[test]
    fn lifecycle_events_emit_once() {
        let resolved = quick_cfg().resolve().unwrap();
        let mut run = Run::from_resolved(&resolved, None, 2);
        let log: Arc<Mutex<Vec<String>>> = Arc::new(Mutex::new(Vec::new()));
        let sink = Arc::clone(&log);
        run.observe(Arc::new(move |e: &RunEvent| {
            let mut v = sink.lock().unwrap();
            match e {
                RunEvent::Started { node_workers, .. } => {
                    v.push(format!("start/{node_workers}"))
                }
                RunEvent::Finished {
                    completed, stopped, ..
                } => v.push(format!("finish/{completed}/{stopped}")),
            }
        }));
        run.drive(&mut NoObserver).unwrap();
        let log = log.lock().unwrap();
        assert_eq!(*log, vec!["start/2".to_string(), "finish/true/false".to_string()]);
    }

    #[test]
    fn borrowed_run_matches_owned_run() {
        // The &mut dyn forwarding path (what coordinator::runner::run
        // wraps) is bit-identical to the owned path.
        use crate::experiments::builder::{build_algo_resolved, build_problem_with};
        let resolved = quick_cfg().resolve().unwrap();
        let owned = {
            let mut run = Run::from_resolved(&resolved, None, 1);
            run.run_to_end().unwrap();
            run.into_series()
        };
        let mut problem = build_problem_with(resolved.config(), None);
        let d = problem.dim();
        let mut algo = build_algo_resolved(&resolved, d, None);
        let mut rng = Rng::new(resolved.config().seed ^ 0x1217);
        if let Some(x0) = problem.init_params(&mut rng) {
            algo.set_params(&x0);
        }
        let label = format!("{}:{}", resolved.config().name, algo.name());
        let mut run = Run::new(
            algo.as_mut() as &mut dyn DecentralizedAlgo,
            problem.as_mut() as &mut dyn GradientSource,
            120,
            40,
            label,
        );
        run.run_to_end().unwrap();
        assert_eq!(run.series().to_csv(), owned.to_csv());
    }

    #[test]
    fn run_event_json_round_trips() {
        let events = [
            RunEvent::Started {
                id: "abc".into(),
                label: "grid:a".into(),
                node_workers: 4,
            },
            RunEvent::Finished {
                id: "abc".into(),
                label: "grid:a".into(),
                completed: true,
                stopped: false,
            },
        ];
        for e in &events {
            let j = e.to_json();
            let back = RunEvent::from_json(&j).expect("round trip");
            assert_eq!(format!("{e:?}"), format!("{back:?}"));
        }
        // Unknown kinds are skipped, not errors: the serve stream also
        // carries job-level events.
        assert!(RunEvent::from_json(&Json::obj().set("kind", "job-complete")).is_none());
    }

    #[test]
    fn fanout_delivers_to_all_sinks_in_order() {
        let fan = Arc::new(EventFanout::new());
        let log: Arc<Mutex<Vec<String>>> = Arc::new(Mutex::new(Vec::new()));
        for tag in ["a", "b"] {
            let sink = Arc::clone(&log);
            fan.add(Arc::new(move |e: &RunEvent| {
                if let RunEvent::Finished { id, .. } = e {
                    sink.lock().unwrap().push(format!("{tag}/{id}"));
                }
            }));
        }
        fan.hook()(&RunEvent::Finished {
            id: "x".into(),
            label: "l".into(),
            completed: true,
            stopped: false,
        });
        assert_eq!(
            *log.lock().unwrap(),
            vec!["a/x".to_string(), "b/x".to_string()]
        );
    }
}
