//! Dependency-free stand-in for the artifact-backed gradient sources
//! (`model.rs`), compiled when the `pjrt` feature is off.
//!
//! Constructors take a [`Runtime`] by value; since the stub `Runtime` is
//! unconstructible, every body discharges through its `Infallible` member
//! — the types exist purely so consumers typecheck.

use std::convert::Infallible;

use super::client::Runtime;
use crate::data::corpus::LmBatcher;
use crate::data::{Dataset, Partition};
use crate::problems::GradientSource;
use crate::util::Rng;

/// Classification model (logreg / MLP) executed through PJRT.
pub struct PjrtModel {
    pub dim: usize,
    pub train_batch: usize,
    pub eval_batch: usize,
    pub(crate) never: Infallible,
}

impl PjrtModel {
    /// `base` is "logreg" or "mlp" (expects `<base>_grad` + `<base>_eval`).
    pub fn new(
        rt: Runtime,
        _base: &str,
        _partition: Partition,
        _test: Dataset,
    ) -> Result<PjrtModel, String> {
        match rt.never {}
    }
}

impl GradientSource for PjrtModel {
    fn dim(&self) -> usize {
        match self.never {}
    }

    fn n_nodes(&self) -> usize {
        match self.never {}
    }

    fn grad(&mut self, _node: usize, _x: &[f32], _rng: &mut Rng, _out: &mut [f32]) -> f64 {
        match self.never {}
    }

    fn global_loss(&mut self, _x: &[f32]) -> f64 {
        match self.never {}
    }

    fn test_error(&mut self, _x: &[f32]) -> Option<f64> {
        match self.never {}
    }
}

/// Transformer byte-LM through PJRT, one corpus shard per node.
pub struct PjrtLm {
    pub dim: usize,
    pub batch: usize,
    pub seq: usize,
    pub(crate) never: Infallible,
}

impl PjrtLm {
    pub fn new(rt: Runtime, _shards: Vec<LmBatcher>, _eval_seed: u64) -> Result<PjrtLm, String> {
        match rt.never {}
    }
}

impl GradientSource for PjrtLm {
    fn dim(&self) -> usize {
        match self.never {}
    }

    fn n_nodes(&self) -> usize {
        match self.never {}
    }

    fn grad(&mut self, _node: usize, _x: &[f32], _rng: &mut Rng, _out: &mut [f32]) -> f64 {
        match self.never {}
    }

    fn global_loss(&mut self, _x: &[f32]) -> f64 {
        match self.never {}
    }
}
