//! Dependency-free stand-in for the PJRT client (`client.rs`), compiled
//! when the `pjrt` feature is off.
//!
//! Mirrors the real module's public API exactly so every consumer
//! typechecks unchanged; [`Runtime::new`] always returns `Err`, which is
//! the same "skip gracefully" path callers already take when PJRT or the
//! artifacts are absent. The `Infallible` members make the dead execution
//! paths unconstructible rather than panicking.

use std::convert::Infallible;

use super::artifact::{ArtifactSig, Manifest};

/// Typed input tensor handed to an executor.
pub enum Input<'a> {
    F32(&'a [f32]),
    I32(&'a [i32]),
    ScalarF32(f32),
}

/// One compiled artifact (never constructed without the `pjrt` feature).
pub struct Executor {
    pub sig: ArtifactSig,
    pub(crate) never: Infallible,
}

impl Executor {
    /// Execute with positional inputs matching the manifest signature.
    pub fn run(&self, _inputs: &[Input]) -> Result<Vec<Vec<f32>>, String> {
        match self.never {}
    }
}

/// Lazy-compiling registry over a manifest (stub: construction fails).
pub struct Runtime {
    pub manifest: Manifest,
    pub(crate) never: Infallible,
}

impl Runtime {
    /// Always `Err` in the stub — callers report "PJRT unavailable" and
    /// skip, exactly as with a missing artifact build.
    pub fn new(_manifest: Manifest) -> Result<Runtime, String> {
        Err("PJRT support not compiled in \
             (enable the `pjrt` cargo feature with the `xla` and `anyhow` \
             dependencies available)"
            .into())
    }

    /// Load from the default artifact directory.
    pub fn load_default() -> Result<Runtime, String> {
        let m = Manifest::load_default()
            .ok_or("artifacts/manifest.json not found — run `make artifacts`")?;
        Runtime::new(m)
    }

    pub fn platform(&self) -> String {
        match self.never {}
    }

    /// Get (compiling if needed) the named executor.
    pub fn executor(&mut self, _name: &str) -> Result<&Executor, String> {
        match self.never {}
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn stub_runtime_reports_unavailable() {
        let err = Runtime::new(Manifest::default()).err().expect("stub must fail");
        assert!(err.contains("pjrt"), "{err}");
    }
}
