//! PJRT CPU client wrapper: compile-on-load executor cache over the AOT
//! HLO-text artifacts.

use std::collections::BTreeMap;

use anyhow::{bail, Context, Result};

use super::artifact::{ArtifactSig, Manifest};

/// Typed input tensor handed to an executor.
pub enum Input<'a> {
    F32(&'a [f32]),
    I32(&'a [i32]),
    ScalarF32(f32),
}

/// One compiled artifact.
pub struct Executor {
    pub sig: ArtifactSig,
    exe: xla::PjRtLoadedExecutable,
}

impl Executor {
    /// Execute with positional inputs matching the manifest signature.
    /// Returns the output tuple as flat f32 vectors (scalars are len-1;
    /// bool/i32 outputs are converted).
    pub fn run(&self, inputs: &[Input]) -> Result<Vec<Vec<f32>>> {
        if inputs.len() != self.sig.inputs.len() {
            bail!(
                "{}: expected {} inputs, got {}",
                self.sig.name,
                self.sig.inputs.len(),
                inputs.len()
            );
        }
        let mut literals = Vec::with_capacity(inputs.len());
        for (input, tsig) in inputs.iter().zip(self.sig.inputs.iter()) {
            let dims: Vec<i64> = tsig.shape.iter().map(|&x| x as i64).collect();
            let lit = match input {
                Input::F32(v) => {
                    if v.len() != tsig.elements() {
                        bail!(
                            "{}: input {} wants {} elements, got {}",
                            self.sig.name,
                            tsig.name,
                            tsig.elements(),
                            v.len()
                        );
                    }
                    let l = xla::Literal::vec1(v);
                    if tsig.shape.len() == 1 {
                        l
                    } else {
                        l.reshape(&dims)?
                    }
                }
                Input::I32(v) => {
                    if v.len() != tsig.elements() {
                        bail!(
                            "{}: input {} wants {} elements, got {}",
                            self.sig.name,
                            tsig.name,
                            tsig.elements(),
                            v.len()
                        );
                    }
                    let l = xla::Literal::vec1(v);
                    if tsig.shape.len() == 1 {
                        l
                    } else {
                        l.reshape(&dims)?
                    }
                }
                Input::ScalarF32(x) => xla::Literal::scalar(*x),
            };
            literals.push(lit);
        }
        let result = self.exe.execute::<xla::Literal>(&literals)?[0][0].to_literal_sync()?;
        let parts = result.to_tuple()?;
        let mut out = Vec::with_capacity(parts.len());
        for (part, tsig) in parts.into_iter().zip(self.sig.outputs.iter()) {
            let v: Vec<f32> = match tsig.dtype.as_str() {
                "float32" => part.to_vec::<f32>()?,
                "int32" => part
                    .to_vec::<i32>()?
                    .into_iter()
                    .map(|x| x as f32)
                    .collect(),
                "bool" => {
                    // booleans surface as u8
                    let conv = part.convert(xla::PrimitiveType::S32)?;
                    conv.to_vec::<i32>()?.into_iter().map(|x| x as f32).collect()
                }
                other => bail!("unsupported output dtype {other}"),
            };
            out.push(v);
        }
        Ok(out)
    }
}

/// Lazy-compiling registry over a manifest.
pub struct Runtime {
    pub manifest: Manifest,
    client: xla::PjRtClient,
    cache: BTreeMap<String, Executor>,
}

impl Runtime {
    /// Load the manifest in `dir` and start a CPU PJRT client.
    pub fn new(manifest: Manifest) -> Result<Runtime> {
        let client = xla::PjRtClient::cpu()?;
        Ok(Runtime {
            manifest,
            client,
            cache: BTreeMap::new(),
        })
    }

    /// Load from the default artifact directory.
    pub fn load_default() -> Result<Runtime> {
        let m = Manifest::load_default()
            .context("artifacts/manifest.json not found — run `make artifacts`")?;
        Runtime::new(m)
    }

    pub fn platform(&self) -> String {
        self.client.platform_name()
    }

    /// Get (compiling if needed) the named executor.
    pub fn executor(&mut self, name: &str) -> Result<&Executor> {
        if !self.cache.contains_key(name) {
            let sig = self.manifest.get(name).map_err(anyhow::Error::msg)?.clone();
            let proto = xla::HloModuleProto::from_text_file(&sig.file)
                .with_context(|| format!("parsing {}", sig.file.display()))?;
            let comp = xla::XlaComputation::from_proto(&proto);
            let exe = self
                .client
                .compile(&comp)
                .with_context(|| format!("compiling {name}"))?;
            self.cache.insert(name.to_string(), Executor { sig, exe });
        }
        Ok(self.cache.get(name).unwrap())
    }
}
