//! PJRT runtime: load and execute the AOT HLO artifacts.
//!
//! `python/compile/aot.py` lowers every L2 JAX entry point (with the L1
//! Pallas kernels inlined, interpret=True) to HLO *text*; this module
//! loads those files through the `xla` crate's PJRT CPU client, validates
//! them against `artifacts/manifest.json`, and exposes typed executors.
//! Python never runs on the training path.

pub mod artifact;
pub mod client;
pub mod model;

pub use artifact::{ArtifactSig, Manifest};
pub use client::{Executor, Runtime};
pub use model::PjrtModel;
