//! PJRT runtime: load and execute the AOT HLO artifacts.
//!
//! `python/compile/aot.py` lowers every L2 JAX entry point (with the L1
//! Pallas kernels inlined, interpret=True) to HLO *text*; this module
//! loads those files through the `xla` crate's PJRT CPU client, validates
//! them against `artifacts/manifest.json`, and exposes typed executors.
//! Python never runs on the training path.
//!
//! The real client (`client.rs`/`model.rs`) needs the external `xla` and
//! `anyhow` crates, which are not vendored in this offline environment —
//! they are compiled only under the `pjrt` cargo feature (after adding
//! those dependencies to Cargo.toml). Without the feature, API-identical
//! stubs compile instead whose `Runtime::new` always reports
//! "unavailable", so every artifact-backed test and example skips exactly
//! as it does when `artifacts/` has not been built.

pub mod artifact;

#[cfg(feature = "pjrt")]
pub mod client;
#[cfg(feature = "pjrt")]
pub mod model;

#[cfg(not(feature = "pjrt"))]
#[path = "client_stub.rs"]
pub mod client;
#[cfg(not(feature = "pjrt"))]
#[path = "model_stub.rs"]
pub mod model;

pub use artifact::{ArtifactSig, Manifest};
pub use client::{Executor, Runtime};
pub use model::PjrtModel;
