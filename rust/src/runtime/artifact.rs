//! Artifact manifest (artifacts/manifest.json) parsing and validation.

use std::collections::BTreeMap;
use std::path::{Path, PathBuf};

use crate::util::json::Json;

/// One tensor in an artifact signature.
#[derive(Clone, Debug, PartialEq)]
pub struct TensorSig {
    pub name: String,
    pub dtype: String,
    pub shape: Vec<usize>,
}

impl TensorSig {
    pub fn elements(&self) -> usize {
        self.shape.iter().product()
    }
}

/// Signature + file of one artifact.
#[derive(Clone, Debug, PartialEq)]
pub struct ArtifactSig {
    pub name: String,
    pub file: PathBuf,
    pub inputs: Vec<TensorSig>,
    pub outputs: Vec<TensorSig>,
    pub meta: BTreeMap<String, Json>,
}

impl ArtifactSig {
    /// usize meta field (dims, batch sizes...).
    pub fn meta_usize(&self, key: &str) -> Option<usize> {
        self.meta.get(key).and_then(Json::as_usize)
    }
}

/// Parsed manifest.
#[derive(Clone, Debug, Default)]
pub struct Manifest {
    pub dir: PathBuf,
    pub artifacts: BTreeMap<String, ArtifactSig>,
}

fn parse_tensor(j: &Json) -> Result<TensorSig, String> {
    Ok(TensorSig {
        name: j
            .get("name")
            .and_then(Json::as_str)
            .ok_or("tensor missing name")?
            .to_string(),
        dtype: j
            .get("dtype")
            .and_then(Json::as_str)
            .ok_or("tensor missing dtype")?
            .to_string(),
        shape: j
            .get("shape")
            .and_then(Json::as_arr)
            .ok_or("tensor missing shape")?
            .iter()
            .map(|v| v.as_usize().ok_or("bad shape entry"))
            .collect::<Result<Vec<_>, _>>()?,
    })
}

impl Manifest {
    /// Load `<dir>/manifest.json`.
    pub fn load(dir: impl AsRef<Path>) -> Result<Manifest, String> {
        let dir = dir.as_ref().to_path_buf();
        let text = std::fs::read_to_string(dir.join("manifest.json"))
            .map_err(|e| format!("reading manifest: {e}"))?;
        let j = Json::parse(&text).map_err(|e| e.to_string())?;
        let arts = j
            .get("artifacts")
            .and_then(Json::as_obj)
            .ok_or("manifest missing artifacts object")?;
        let mut artifacts = BTreeMap::new();
        for (name, a) in arts {
            let file = dir.join(
                a.get("file")
                    .and_then(Json::as_str)
                    .ok_or(format!("{name}: missing file"))?,
            );
            let parse_list = |key: &str| -> Result<Vec<TensorSig>, String> {
                a.get(key)
                    .and_then(Json::as_arr)
                    .ok_or(format!("{name}: missing {key}"))?
                    .iter()
                    .map(parse_tensor)
                    .collect()
            };
            artifacts.insert(
                name.clone(),
                ArtifactSig {
                    name: name.clone(),
                    file,
                    inputs: parse_list("inputs")?,
                    outputs: parse_list("outputs")?,
                    meta: a
                        .get("meta")
                        .and_then(Json::as_obj)
                        .cloned()
                        .unwrap_or_default(),
                },
            );
        }
        Ok(Manifest { dir, artifacts })
    }

    pub fn get(&self, name: &str) -> Result<&ArtifactSig, String> {
        self.artifacts
            .get(name)
            .ok_or_else(|| format!("artifact {name:?} not in manifest"))
    }

    /// The default artifact directory: `$SPARQ_ARTIFACTS` or
    /// `<repo>/artifacts` relative to the current dir / executable.
    pub fn default_dir() -> PathBuf {
        if let Ok(p) = std::env::var("SPARQ_ARTIFACTS") {
            return PathBuf::from(p);
        }
        // walk up from cwd looking for artifacts/manifest.json
        let mut cur = std::env::current_dir().unwrap_or_else(|_| PathBuf::from("."));
        for _ in 0..5 {
            let cand = cur.join("artifacts");
            if cand.join("manifest.json").exists() {
                return cand;
            }
            if !cur.pop() {
                break;
            }
        }
        PathBuf::from("artifacts")
    }

    /// Load from the default location if it exists.
    pub fn load_default() -> Option<Manifest> {
        let dir = Self::default_dir();
        if dir.join("manifest.json").exists() {
            Manifest::load(dir).ok()
        } else {
            None
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parses_manifest_snippet() {
        let dir = std::env::temp_dir().join(format!("sparq-manifest-{}", std::process::id()));
        std::fs::create_dir_all(&dir).unwrap();
        std::fs::write(
            dir.join("manifest.json"),
            r#"{"format": "hlo-text", "artifacts": {
                "toy": {"file": "toy.hlo.txt",
                        "inputs": [{"name": "x", "dtype": "float32", "shape": [4, 2]}],
                        "outputs": [{"name": "y", "dtype": "float32", "shape": []}],
                        "meta": {"dim": 8}}}}"#,
        )
        .unwrap();
        let m = Manifest::load(&dir).unwrap();
        let a = m.get("toy").unwrap();
        assert_eq!(a.inputs[0].shape, vec![4, 2]);
        assert_eq!(a.inputs[0].elements(), 8);
        assert_eq!(a.outputs[0].shape, Vec::<usize>::new());
        assert_eq!(a.meta_usize("dim"), Some(8));
        assert!(m.get("nope").is_err());
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn real_manifest_if_present() {
        if let Some(m) = Manifest::load_default() {
            let lg = m.get("logreg_grad").unwrap();
            assert_eq!(lg.inputs.len(), 3);
            assert_eq!(lg.outputs.len(), 2);
            assert!(lg.file.exists());
        }
    }
}
