//! Artifact-backed gradient sources (the production request path).
//!
//! [`PjrtModel`] wraps a `<model>_grad` (+ optional `<model>_eval`)
//! artifact pair over a heterogeneous data partition; [`PjrtLm`] wraps the
//! transformer `lm_grad`/`lm_loss` pair over a byte-corpus batcher. Both
//! satisfy `problems::GradientSource`, so the coordinator drives them
//! exactly like the native problems.

use anyhow::Result;

use super::client::{Input, Runtime};
use crate::data::corpus::LmBatcher;
use crate::data::{Dataset, Partition};
use crate::problems::GradientSource;
use crate::util::Rng;

/// Classification model (logreg / MLP) executed through PJRT.
pub struct PjrtModel {
    rt: Runtime,
    grad_name: String,
    eval_name: String,
    pub dim: usize,
    pub train_batch: usize,
    pub eval_batch: usize,
    partition: Partition,
    test: Dataset,
}

impl PjrtModel {
    /// `base` is "logreg" or "mlp" (expects `<base>_grad` + `<base>_eval`).
    pub fn new(
        mut rt: Runtime,
        base: &str,
        partition: Partition,
        test: Dataset,
    ) -> Result<PjrtModel> {
        let grad_name = format!("{base}_grad");
        let eval_name = format!("{base}_eval");
        let (dim, train_batch) = {
            let sig = &rt.executor(&grad_name)?.sig;
            (
                sig.inputs[0].elements(),
                sig.inputs[2].elements(), // y: [B]
            )
        };
        let eval_batch = rt.executor(&eval_name)?.sig.inputs[2].elements();
        Ok(PjrtModel {
            rt,
            grad_name,
            eval_name,
            dim,
            train_batch,
            eval_batch,
            partition,
            test,
        })
    }

    /// Evaluate (mean loss, error) over the test set in artifact-sized
    /// chunks (the last ragged chunk is padded by wrapping around).
    fn eval(&mut self, x: &[f32]) -> Result<(f64, f64)> {
        let b = self.eval_batch;
        let n = self.test.len();
        let mut loss = 0.0f64;
        let mut correct = 0.0f64;
        let mut batches = 0usize;
        let mut i = 0usize;
        while i < n {
            let idx: Vec<usize> = (0..b).map(|k| (i + k) % n).collect();
            let (xs, ys) = self.test.gather(&idx);
            let exe = self.rt.executor(&self.eval_name)?;
            let out = exe.run(&[Input::F32(x), Input::F32(&xs), Input::I32(&ys)])?;
            loss += out[0][0] as f64;
            correct += out[1][0] as f64;
            batches += 1;
            i += b;
        }
        let total = (batches * b) as f64;
        Ok((loss / batches as f64, 1.0 - correct / total))
    }
}

impl GradientSource for PjrtModel {
    fn dim(&self) -> usize {
        self.dim
    }

    fn n_nodes(&self) -> usize {
        self.partition.n_nodes()
    }

    fn grad(&mut self, node: usize, x: &[f32], rng: &mut Rng, out: &mut [f32]) -> f64 {
        let (xs, ys) = self.partition.batch(node, self.train_batch, rng);
        let exe = self
            .rt
            .executor(&self.grad_name)
            .expect("grad artifact must load");
        let res = exe
            .run(&[Input::F32(x), Input::F32(&xs), Input::I32(&ys)])
            .expect("grad execution failed");
        out.copy_from_slice(&res[1]);
        res[0][0] as f64
    }

    fn global_loss(&mut self, x: &[f32]) -> f64 {
        self.eval(x).map(|(l, _)| l).unwrap_or(f64::NAN)
    }

    fn test_error(&mut self, x: &[f32]) -> Option<f64> {
        self.eval(x).map(|(_, e)| e).ok()
    }
}

/// Transformer byte-LM through PJRT, one independent corpus shard per node.
pub struct PjrtLm {
    rt: Runtime,
    pub dim: usize,
    pub batch: usize,
    pub seq: usize,
    shards: Vec<LmBatcher>,
    eval_tokens: Vec<i32>,
}

impl PjrtLm {
    pub fn new(mut rt: Runtime, shards: Vec<LmBatcher>, eval_seed: u64) -> Result<PjrtLm> {
        let (dim, batch, seq) = {
            let sig = &rt.executor("lm_grad")?.sig;
            let tshape = &sig.inputs[1].shape; // [B, S+1]
            (sig.inputs[0].elements(), tshape[0], tshape[1] - 1)
        };
        // Fixed held-out eval batch from shard 0.
        let mut rng = Rng::new(eval_seed);
        let eval_tokens = shards[0].batch(batch, &mut rng);
        Ok(PjrtLm {
            rt,
            dim,
            batch,
            seq,
            shards,
            eval_tokens,
        })
    }
}

impl GradientSource for PjrtLm {
    fn dim(&self) -> usize {
        self.dim
    }

    fn n_nodes(&self) -> usize {
        self.shards.len()
    }

    fn grad(&mut self, node: usize, x: &[f32], rng: &mut Rng, out: &mut [f32]) -> f64 {
        let tokens = self.shards[node].batch(self.batch, rng);
        let exe = self.rt.executor("lm_grad").expect("lm_grad must load");
        let res = exe
            .run(&[Input::F32(x), Input::I32(&tokens)])
            .expect("lm_grad execution failed");
        out.copy_from_slice(&res[1]);
        res[0][0] as f64
    }

    fn global_loss(&mut self, x: &[f32]) -> f64 {
        let tokens = self.eval_tokens.clone();
        let exe = match self.rt.executor("lm_loss") {
            Ok(e) => e,
            Err(_) => return f64::NAN,
        };
        exe.run(&[Input::F32(x), Input::I32(&tokens)])
            .map(|o| o[0][0] as f64)
            .unwrap_or(f64::NAN)
    }
}
