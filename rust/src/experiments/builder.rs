//! Construct problems and algorithms from an `ExperimentConfig`.
//!
//! Since the typed-config redesign these builders are *consumers of
//! parsed data*: every spec field arrives validated (the spec types) and
//! cross-field-checked ([`ExperimentConfig::resolve`]), so construction
//! is a straight-line assembly of typed parts — no string splitting, no
//! config-error panics past the resolve gate. The `build_algo*` entry
//! points that take a raw config resolve it first and panic with the
//! structured error's message (legacy behavior for the driver paths);
//! library callers should resolve themselves and use
//! [`build_algo_resolved`] (or the [`Run`](crate::run::Run) handle,
//! which wraps all of this).

use crate::config::{Algo, ExperimentConfig, Family, ProblemKind, ResolvedConfig};
use crate::coordinator::{
    run, ChocoSgd, DecentralizedAlgo, RunOptions, SparqConfig, SparqSgd, SquarmConfig, SquarmSgd,
    VanillaDecentralized,
};
use crate::data::synthetic::ClassGaussian;
use crate::data::{by_class_shards, iid_split};
use crate::graph::{uniform_neighbor, MixingMatrix, Topology};
use crate::metrics::Series;
use crate::problems::{GradientSource, LogRegProblem, MlpProblem, QuadraticProblem};
use crate::sweep::cache::{ArtifactCache, CachedData};
use crate::trigger::EventTrigger;
use crate::util::Rng;

/// Per-node sample count for synthetic shards (≈ the paper's 60k/60).
pub const SAMPLES_PER_NODE: usize = 256;
pub const TEST_SAMPLES: usize = 1024;
/// Classes each node's shard covers (heterogeneous split).
pub const CLASSES_PER_NODE: usize = 2;

/// Class-mean separation, normalized so the expected inter-class mean
/// distance ‖μ_a − μ_b‖ ≈ 4.6 regardless of the input dimension: the
/// per-pair Bayes error is then ≈ Φ(−2.3) ≈ 1%, putting the 10-class
/// error floor near 0.08–0.12 — the regime the paper's Figure 1a/1b
/// operates in (target test error 0.12), reachable but not trivial.
pub fn class_sep(din: usize) -> f32 {
    4.6 / (2.0 * din as f32).sqrt()
}

/// Build the mixing matrix from the config's (typed) topology.
pub fn build_mixing(cfg: &ExperimentConfig) -> MixingMatrix {
    let topo = Topology::new(cfg.topology.kind(), cfg.nodes, cfg.seed);
    uniform_neighbor(&topo)
}

/// Build the gradient source from the config's problem spec.
pub fn build_problem(cfg: &ExperimentConfig) -> Box<dyn GradientSource> {
    build_problem_with(cfg, None)
}

/// Like [`build_problem`], sharing generated data through a sweep
/// [`ArtifactCache`] when one is supplied. Cached and uncached builds are
/// bit-for-bit identical (generation is seeded; the cache only memoizes).
pub fn build_problem_with(
    cfg: &ExperimentConfig,
    cache: Option<&ArtifactCache>,
) -> Box<dyn GradientSource> {
    let data_key = (cfg.problem.to_string(), cfg.nodes, cfg.seed);
    let cached = |build: &mut dyn FnMut() -> CachedData| -> CachedData {
        match cache {
            Some(c) => c.data_or_else(data_key.clone(), build),
            None => build(),
        }
    };
    match *cfg.problem.kind() {
        ProblemKind::Quadratic { d, noise, spread } => {
            let data = cached(&mut || {
                CachedData::Quadratic(QuadraticProblem::new(
                    d, cfg.nodes, 0.5, 2.0, noise, spread, cfg.seed,
                ))
            });
            match data {
                CachedData::Quadratic(p) => Box::new(p),
                _ => unreachable!("quadratic key cached non-quadratic data"),
            }
        }
        ProblemKind::LogReg {
            din,
            classes,
            batch,
        } => {
            let data = cached(&mut || {
                let mut rng = Rng::new(cfg.seed ^ 0xDA7A);
                let gen = ClassGaussian::new(din, classes, class_sep(din), cfg.seed);
                let part =
                    by_class_shards(&gen, cfg.nodes, SAMPLES_PER_NODE, CLASSES_PER_NODE, &mut rng);
                let test = gen.generate(TEST_SAMPLES, &mut rng);
                CachedData::Shards { part, test }
            });
            match data {
                CachedData::Shards { part, test } => {
                    Box::new(LogRegProblem::new(part, test, batch, 1e-4))
                }
                _ => unreachable!("logreg key cached non-shard data"),
            }
        }
        ProblemKind::Mlp {
            din,
            hidden,
            classes,
            batch,
        } => {
            // IID shards: Section 5.2 "matches the setting in CHOCO-SGD"
            // ([KLSJ19] CIFAR runs use a random partition); the convex
            // experiment (logreg above) is the heterogeneous one.
            let data = cached(&mut || {
                let mut rng = Rng::new(cfg.seed ^ 0xDA7A);
                let gen = ClassGaussian::new(din, classes, class_sep(din), cfg.seed);
                let part = iid_split(&gen, cfg.nodes, SAMPLES_PER_NODE, &mut rng);
                let test = gen.generate(TEST_SAMPLES, &mut rng);
                CachedData::Shards { part, test }
            });
            match data {
                CachedData::Shards { part, test } => {
                    Box::new(MlpProblem::new(part, test, hidden, batch))
                }
                _ => unreachable!("mlp key cached non-shard data"),
            }
        }
    }
}

/// Build the algorithm for parameter dimension `d`. Resolves the config
/// first and panics with the structured error on an incoherent
/// composition (driver-path convenience; library callers use
/// [`build_algo_resolved`]).
pub fn build_algo(cfg: &ExperimentConfig, d: usize) -> Box<dyn DecentralizedAlgo> {
    build_algo_with(cfg, d, None)
}

/// Like [`build_algo`], sharing topology construction and the tuned-γ
/// eigen solve through a sweep [`ArtifactCache`] when one is supplied.
pub fn build_algo_with(
    cfg: &ExperimentConfig,
    d: usize,
    cache: Option<&ArtifactCache>,
) -> Box<dyn DecentralizedAlgo> {
    let resolved = cfg.resolve().unwrap_or_else(|e| panic!("{e}"));
    build_algo_resolved(&resolved, d, cache)
}

/// Assemble the engine from a [`ResolvedConfig`] — pure construction,
/// no validation left to do. The returned engine has the link model and
/// topology schedule installed (defaults reproduce the pre-engine
/// behavior exactly). The cached tuned γ is exactly the value the engine
/// would compute for itself (same matrix, same deterministic solve), so
/// cached and uncached builds behave bit-for-bit identically.
pub fn build_algo_resolved(
    resolved: &ResolvedConfig,
    d: usize,
    cache: Option<&ArtifactCache>,
) -> Box<dyn DecentralizedAlgo> {
    let cfg = resolved.config();
    let schedule = resolved.schedule.clone();
    let link = resolved.link.clone();
    let build = || {
        schedule
            .initial_mixing()
            .unwrap_or_else(|| build_mixing(cfg))
    };
    let mixing = match cache {
        Some(c) => c.mixing_or_else(ArtifactCache::topo_key(cfg), build),
        None => build(),
    };
    let comp = cfg.compressor.build(d);
    // γ policy (decoded by resolve()): with a cache and an unpinned γ,
    // inject the shared eigen solve's tuned value — identical to the
    // engine's own.
    let gamma: Option<f64> = match (cfg.algo.clone(), resolved.gamma.pinned(), cache) {
        // Vanilla's exact averaging has no γ-consensus step; the
        // constructor pins 0 itself.
        (Algo::Vanilla, _, _) => None,
        (_, Some(g), _) => Some(g),
        (_, None, Some(c)) => {
            let s = c.spectral_or_compute(ArtifactCache::topo_key(cfg), &mixing);
            Some(s.gamma_tuned(comp.omega(d), comp.effective_omega(d)))
        }
        (_, None, None) => None,
    };
    let lr = resolved.lr.clone();
    // The per-coord flag travels alongside the threshold schedule
    // (resolve() split them so ResolvedConfig stays field-per-concern).
    let trigger = if resolved.trigger_per_coord {
        EventTrigger::new_per_coord(resolved.trigger.clone())
    } else {
        EventTrigger::new(resolved.trigger.clone())
    };
    let mut engine = match (&cfg.algo, resolved.family) {
        // Family dispatch: resolve() guarantees a non-default family only
        // reaches here paired with the event-triggered engine.
        (Algo::Sparq, Family::Squarm { beta }) => SquarmSgd::new(
            SquarmConfig {
                mixing,
                compressor: comp,
                trigger,
                lr,
                sync: resolved.sync.clone(),
                gamma,
                momentum: cfg.momentum as f32,
                beta: beta as f32,
                seed: cfg.seed,
            },
            d,
        ),
        (Algo::Sparq, Family::Sparq) => SparqSgd::new(
            SparqConfig {
                mixing,
                compressor: comp,
                trigger,
                lr,
                sync: resolved.sync.clone(),
                gamma,
                momentum: cfg.momentum as f32,
                seed: cfg.seed,
            },
            d,
        ),
        (Algo::Choco, _) => {
            ChocoSgd::with_gamma(mixing, comp, lr, cfg.momentum as f32, gamma, d, cfg.seed)
        }
        (Algo::Vanilla, _) => {
            VanillaDecentralized::new(mixing, lr, cfg.momentum as f32, d, cfg.seed)
        }
    };
    engine.set_link(link);
    engine.set_topology_schedule(schedule);
    engine.set_fault_plan(resolved.fault.clone());
    Box::new(engine)
}

/// Run a config end to end, returning its metric series.
pub fn run_config(cfg: &ExperimentConfig, verbose: bool) -> Series {
    let mut problem = build_problem(cfg);
    let d = problem.dim();
    let mut algo = build_algo(cfg, d);
    let mut init_rng = Rng::new(cfg.seed ^ 0x1217);
    if let Some(x0) = problem.init_params(&mut init_rng) {
        algo.set_params(&x0);
    }
    let opts = RunOptions {
        steps: cfg.steps,
        eval_every: cfg.eval_every,
        verbose,
        workers: cfg.workers,
    };
    let mut series = run(algo.as_mut(), problem.as_mut(), &opts);
    series.label = format!("{}:{}", cfg.name, algo.name());
    series
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn quadratic_config_runs() {
        let cfg = ExperimentConfig {
            steps: 300,
            eval_every: 100,
            nodes: 6,
            problem: "quadratic:24".into(),
            ..Default::default()
        };
        let series = run_config(&cfg, false);
        assert!(series.records.len() >= 3);
        let first = &series.records[0];
        let last = series.records.last().unwrap();
        assert!(last.opt_gap < first.opt_gap);
    }

    #[test]
    fn logreg_config_runs() {
        let cfg = ExperimentConfig {
            steps: 200,
            eval_every: 100,
            nodes: 6,
            problem: "logreg:20:4:8".into(),
            compressor: "sign_topk:10%".into(),
            trigger: "const:50".into(),
            ..Default::default()
        };
        let series = run_config(&cfg, false);
        let last = series.records.last().unwrap();
        assert!(last.test_error < 0.6);
        assert!(last.bits > 0);
    }

    #[test]
    fn all_algos_build() {
        for algo in [Algo::Sparq, Algo::Choco, Algo::Vanilla] {
            let cfg = ExperimentConfig {
                algo,
                nodes: 4,
                ..Default::default()
            };
            let a = build_algo(&cfg, 16);
            assert_eq!(a.n(), 4);
        }
    }

    #[test]
    fn family_configs_build_and_run() {
        // squarm builds the momentum-triggered engine (name carries β)…
        let cfg = ExperimentConfig {
            steps: 200,
            eval_every: 100,
            nodes: 6,
            problem: "quadratic:24".into(),
            family: "squarm:0.9".into(),
            ..Default::default()
        };
        let a = build_algo(&cfg, 24);
        assert!(a.name().starts_with("squarm(beta=0.9"), "{}", a.name());
        let series = run_config(&cfg, false);
        assert!(series.records.last().unwrap().opt_gap < series.records[0].opt_gap);
        // …and the per-coordinate trigger builds the plain engine with
        // the coordinate mask armed.
        let cfg = ExperimentConfig {
            steps: 200,
            eval_every: 100,
            nodes: 6,
            problem: "quadratic:24".into(),
            trigger: "percoord:0.5".into(),
            ..Default::default()
        };
        let series = run_config(&cfg, false);
        assert!(series.records.last().unwrap().opt_gap < series.records[0].opt_gap);
    }

    #[test]
    fn lossy_link_config_runs_and_charges_fewer_bits() {
        let base = ExperimentConfig {
            steps: 200,
            eval_every: 100,
            nodes: 6,
            problem: "quadratic:24".into(),
            trigger: "zero".into(),
            h: crate::config::SyncSpec::every(1),
            ..Default::default()
        };
        let ideal = run_config(&base, false);
        let lossy = run_config(
            &ExperimentConfig {
                link: "drop:0.3".into(),
                ..base
            },
            false,
        );
        let ib = ideal.records.last().unwrap().bits;
        let lb = lossy.records.last().unwrap().bits;
        assert!(lb < ib, "lossy {lb} vs ideal {ib}");
        assert!(lb > 0);
    }

    #[test]
    fn topology_schedule_config_runs() {
        let cfg = ExperimentConfig {
            steps: 400,
            eval_every: 100,
            nodes: 16,
            problem: "quadratic:24".into(),
            topology_schedule: "switch:ring,torus:100".into(),
            ..Default::default()
        };
        let series = run_config(&cfg, false);
        let first = &series.records[0];
        let last = series.records.last().unwrap();
        assert!(last.opt_gap < first.opt_gap);
        assert!(last.bits > 0);
    }

    #[test]
    #[should_panic(expected = "bad link spec")]
    fn bad_link_panics() {
        // Parse-don't-validate: the invalid literal now panics at
        // construction (the From<&str> facade), before any builder runs.
        let cfg = ExperimentConfig {
            link: "drop:2".into(),
            ..Default::default()
        };
        build_algo(&cfg, 16);
    }

    #[test]
    #[should_panic(expected = "out of range")]
    fn straggler_index_out_of_range_panics() {
        let cfg = ExperimentConfig {
            nodes: 4,
            link: "straggler:4:0.5".into(),
            ..Default::default()
        };
        build_algo(&cfg, 16);
    }

    #[test]
    #[should_panic(expected = "names its own graphs")]
    fn conflicting_topology_and_schedule_panics() {
        let cfg = ExperimentConfig {
            nodes: 16,
            topology: "torus".into(),
            topology_schedule: "switch:ring,torus:100".into(),
            ..Default::default()
        };
        build_algo(&cfg, 16);
    }

    #[test]
    #[should_panic(expected = "unknown problem spec")]
    fn bad_problem_panics() {
        let cfg = ExperimentConfig {
            problem: "svm:1".into(),
            ..Default::default()
        };
        build_problem(&cfg);
    }

    #[test]
    fn quadratic_spec_accepts_noise_and_spread() {
        // quadratic:D defaults, quadratic:D:NOISE, quadratic:D:NOISE:SPREAD
        for spec in ["quadratic:24", "quadratic:24:0.2", "quadratic:24:0.1:0.5"] {
            let cfg = ExperimentConfig {
                problem: spec.into(),
                nodes: 4,
                ..Default::default()
            };
            let p = build_problem(&cfg);
            assert_eq!(p.dim(), 24, "{spec}");
        }
        // the default form is exactly quadratic:D:0.05:1 (same seed path)
        let a = ExperimentConfig {
            problem: "quadratic:16".into(),
            steps: 100,
            eval_every: 50,
            nodes: 4,
            ..Default::default()
        };
        let b = ExperimentConfig {
            problem: "quadratic:16:0.05:1".into(),
            ..a.clone()
        };
        assert_eq!(run_config(&a, false).to_csv(), run_config(&b, false).to_csv());
    }

    #[test]
    fn negative_gamma_pins_zero_mixing() {
        // γ < 0 ⇒ consensus disabled exactly (the ablation diagnostic);
        // heterogeneous nodes then never agree.
        let base = ExperimentConfig {
            steps: 600,
            eval_every: 300,
            nodes: 6,
            problem: "quadratic:16".into(),
            trigger: "zero".into(),
            h: crate::config::SyncSpec::every(1),
            ..Default::default()
        };
        let tuned = run_config(&base, false);
        let frozen = run_config(
            &ExperimentConfig {
                gamma: -1.0,
                ..base
            },
            false,
        );
        let g_tuned = tuned.records.last().unwrap().consensus;
        let g_frozen = frozen.records.last().unwrap().consensus;
        assert!(
            g_frozen > g_tuned * 3.0,
            "γ=0 consensus {g_frozen} vs tuned {g_tuned}"
        );
    }

    #[test]
    fn cached_builds_are_bit_identical_to_uncached() {
        use crate::coordinator::RunOptions;

        let cache = ArtifactCache::new();
        for (algo, problem) in [
            (Algo::Sparq, "logreg:16:4:4"),
            (Algo::Choco, "quadratic:24"),
            (Algo::Vanilla, "quadratic:24"),
        ] {
            let cfg = ExperimentConfig {
                algo: algo.clone(),
                nodes: 5,
                steps: 120,
                eval_every: 60,
                problem: problem.into(),
                compressor: "sign_topk:25%".into(),
                trigger: "const:20".into(),
                ..Default::default()
            };
            let run_with = |cache: Option<&ArtifactCache>| {
                let mut problem = build_problem_with(&cfg, cache);
                let d = problem.dim();
                let mut algo = build_algo_with(&cfg, d, cache);
                let mut rng = Rng::new(cfg.seed ^ 0x1217);
                if let Some(x0) = problem.init_params(&mut rng) {
                    algo.set_params(&x0);
                }
                let opts = RunOptions {
                    steps: cfg.steps,
                    eval_every: cfg.eval_every,
                    verbose: false,
                    workers: 1,
                };
                run(algo.as_mut(), problem.as_mut(), &opts)
            };
            let uncached = run_with(None);
            let cached_once = run_with(Some(&cache));
            let cached_twice = run_with(Some(&cache)); // hits this time
            assert_eq!(
                uncached.to_csv(),
                cached_once.to_csv(),
                "{algo:?} cached != uncached"
            );
            assert_eq!(uncached.to_csv(), cached_twice.to_csv());
        }
        // the second+third builds actually hit
        let (h, m) = cache.data_stats();
        assert!(h >= 3, "data hits {h} misses {m}");
        let (h, m) = cache.mixing_stats();
        assert!(h >= 1, "mixing hits {h} misses {m}");
    }
}
