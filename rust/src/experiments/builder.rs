//! Construct problems and algorithms from an `ExperimentConfig`.

use crate::comm::LinkModel;
use crate::config::{Algo, ExperimentConfig};
use crate::coordinator::{
    run, ChocoSgd, DecentralizedAlgo, RunOptions, SparqConfig, SparqSgd, VanillaDecentralized,
};
use crate::data::synthetic::ClassGaussian;
use crate::data::{by_class_shards, iid_split};
use crate::graph::{uniform_neighbor, MixingMatrix, Topology, TopologyKind, TopologySchedule};
use crate::metrics::Series;
use crate::problems::{GradientSource, LogRegProblem, MlpProblem, QuadraticProblem};
use crate::schedule::{LrSchedule, SyncSchedule};
use crate::trigger::{EventTrigger, ThresholdSchedule};
use crate::util::Rng;

/// Per-node sample count for synthetic shards (≈ the paper's 60k/60).
pub const SAMPLES_PER_NODE: usize = 256;
pub const TEST_SAMPLES: usize = 1024;
/// Classes each node's shard covers (heterogeneous split).
pub const CLASSES_PER_NODE: usize = 2;

/// Class-mean separation, normalized so the expected inter-class mean
/// distance ‖μ_a − μ_b‖ ≈ 4.6 regardless of the input dimension: the
/// per-pair Bayes error is then ≈ Φ(−2.3) ≈ 1%, putting the 10-class
/// error floor near 0.08–0.12 — the regime the paper's Figure 1a/1b
/// operates in (target test error 0.12), reachable but not trivial.
pub fn class_sep(din: usize) -> f32 {
    4.6 / (2.0 * din as f32).sqrt()
}

/// Build the mixing matrix from the config's topology spec.
pub fn build_mixing(cfg: &ExperimentConfig) -> MixingMatrix {
    let kind = TopologyKind::parse(&cfg.topology)
        .unwrap_or_else(|| panic!("unknown topology {:?}", cfg.topology));
    let topo = Topology::new(kind, cfg.nodes, cfg.seed);
    uniform_neighbor(&topo)
}

/// Build the gradient source from the config's problem spec.
pub fn build_problem(cfg: &ExperimentConfig) -> Box<dyn GradientSource> {
    let parts: Vec<&str> = cfg.problem.split(':').collect();
    let mut rng = Rng::new(cfg.seed ^ 0xDA7A);
    match parts.as_slice() {
        ["quadratic", d] => {
            let d: usize = d.parse().expect("quadratic:D");
            Box::new(QuadraticProblem::new(
                d, cfg.nodes, 0.5, 2.0, 0.05, 1.0, cfg.seed,
            ))
        }
        ["logreg", din, classes, batch] => {
            let din: usize = din.parse().expect("logreg:DIN");
            let classes: usize = classes.parse().expect("logreg classes");
            let batch: usize = batch.parse().expect("logreg batch");
            let gen = ClassGaussian::new(din, classes, class_sep(din), cfg.seed);
            let part = by_class_shards(&gen, cfg.nodes, SAMPLES_PER_NODE, CLASSES_PER_NODE, &mut rng);
            let test = gen.generate(TEST_SAMPLES, &mut rng);
            Box::new(LogRegProblem::new(part, test, batch, 1e-4))
        }
        ["mlp", din, hidden, classes, batch] => {
            // IID shards: Section 5.2 "matches the setting in CHOCO-SGD"
            // ([KLSJ19] CIFAR runs use a random partition); the convex
            // experiment (logreg above) is the heterogeneous one.
            let din: usize = din.parse().expect("mlp:DIN");
            let hidden: usize = hidden.parse().expect("mlp hidden");
            let classes: usize = classes.parse().expect("mlp classes");
            let batch: usize = batch.parse().expect("mlp batch");
            let gen = ClassGaussian::new(din, classes, class_sep(din), cfg.seed);
            let part = iid_split(&gen, cfg.nodes, SAMPLES_PER_NODE, &mut rng);
            let test = gen.generate(TEST_SAMPLES, &mut rng);
            Box::new(MlpProblem::new(part, test, hidden, batch))
        }
        other => panic!("unknown problem spec {other:?}"),
    }
}

/// Build the algorithm for parameter dimension `d`. The returned engine
/// has the config's link model and topology schedule installed (defaults
/// reproduce the pre-engine behavior exactly).
pub fn build_algo(cfg: &ExperimentConfig, d: usize) -> Box<dyn DecentralizedAlgo> {
    let schedule = TopologySchedule::parse(&cfg.topology_schedule, cfg.nodes, cfg.seed)
        .unwrap_or_else(|e| {
            panic!("bad topology_schedule spec {:?}: {e}", cfg.topology_schedule)
        });
    let link = LinkModel::parse(&cfg.link, cfg.seed)
        .unwrap_or_else(|e| panic!("bad link spec {:?}: {e}", cfg.link));
    for &(node, _) in &link.stragglers {
        if node >= cfg.nodes {
            panic!(
                "bad link spec {:?}: straggler node {node} out of range for {} nodes",
                cfg.link, cfg.nodes
            );
        }
    }
    // A non-static schedule dictates the starting matrix (switch phase 0 /
    // the sampling base graph) and the `topology` field is NOT consulted —
    // the schedule spec names its own graphs. Reject the contradictory
    // combination instead of silently ignoring an explicit topology.
    if !schedule.is_static() && cfg.topology != ExperimentConfig::default().topology {
        panic!(
            "config sets topology {:?} AND non-static topology_schedule {:?} — \
             the schedule names its own graphs, so the topology field would be \
             ignored; remove one of the two",
            cfg.topology, cfg.topology_schedule
        );
    }
    let mixing = schedule.initial_mixing().unwrap_or_else(|| build_mixing(cfg));
    let lr = LrSchedule::parse(&cfg.lr).unwrap_or_else(|| panic!("bad lr spec {:?}", cfg.lr));
    let comp = crate::compress::parse(&cfg.compressor, d)
        .unwrap_or_else(|| panic!("bad compressor spec {:?}", cfg.compressor));
    let mut engine = match cfg.algo {
        Algo::Sparq => {
            let trigger = ThresholdSchedule::parse(&cfg.trigger)
                .unwrap_or_else(|| panic!("bad trigger spec {:?}", cfg.trigger));
            SparqSgd::new(
                SparqConfig {
                    mixing,
                    compressor: comp,
                    trigger: EventTrigger::new(trigger),
                    lr,
                    sync: SyncSchedule::EveryH(cfg.h),
                    gamma: if cfg.gamma > 0.0 { Some(cfg.gamma) } else { None },
                    momentum: cfg.momentum as f32,
                    seed: cfg.seed,
                },
                d,
            )
        }
        Algo::Choco => ChocoSgd::new(mixing, comp, lr, cfg.momentum as f32, d, cfg.seed),
        Algo::Vanilla => {
            VanillaDecentralized::new(mixing, lr, cfg.momentum as f32, d, cfg.seed)
        }
    };
    engine.set_link(link);
    engine.set_topology_schedule(schedule);
    Box::new(engine)
}

/// Run a config end to end, returning its metric series.
pub fn run_config(cfg: &ExperimentConfig, verbose: bool) -> Series {
    let mut problem = build_problem(cfg);
    let d = problem.dim();
    let mut algo = build_algo(cfg, d);
    let mut init_rng = Rng::new(cfg.seed ^ 0x1217);
    if let Some(x0) = problem.init_params(&mut init_rng) {
        algo.set_params(&x0);
    }
    let opts = RunOptions {
        steps: cfg.steps,
        eval_every: cfg.eval_every,
        verbose,
        workers: cfg.workers,
    };
    let mut series = run(algo.as_mut(), problem.as_mut(), &opts);
    series.label = format!("{}:{}", cfg.name, algo.name());
    series
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn quadratic_config_runs() {
        let cfg = ExperimentConfig {
            steps: 300,
            eval_every: 100,
            nodes: 6,
            problem: "quadratic:24".into(),
            ..Default::default()
        };
        let series = run_config(&cfg, false);
        assert!(series.records.len() >= 3);
        let first = &series.records[0];
        let last = series.records.last().unwrap();
        assert!(last.opt_gap < first.opt_gap);
    }

    #[test]
    fn logreg_config_runs() {
        let cfg = ExperimentConfig {
            steps: 200,
            eval_every: 100,
            nodes: 6,
            problem: "logreg:20:4:8".into(),
            compressor: "sign_topk:10%".into(),
            trigger: "const:50".into(),
            ..Default::default()
        };
        let series = run_config(&cfg, false);
        let last = series.records.last().unwrap();
        assert!(last.test_error < 0.6);
        assert!(last.bits > 0);
    }

    #[test]
    fn all_algos_build() {
        for algo in [Algo::Sparq, Algo::Choco, Algo::Vanilla] {
            let cfg = ExperimentConfig {
                algo,
                nodes: 4,
                ..Default::default()
            };
            let a = build_algo(&cfg, 16);
            assert_eq!(a.n(), 4);
        }
    }

    #[test]
    fn lossy_link_config_runs_and_charges_fewer_bits() {
        let base = ExperimentConfig {
            steps: 200,
            eval_every: 100,
            nodes: 6,
            problem: "quadratic:24".into(),
            trigger: "zero".into(),
            h: 1,
            ..Default::default()
        };
        let ideal = run_config(&base, false);
        let lossy = run_config(
            &ExperimentConfig {
                link: "drop:0.3".into(),
                ..base
            },
            false,
        );
        let ib = ideal.records.last().unwrap().bits;
        let lb = lossy.records.last().unwrap().bits;
        assert!(lb < ib, "lossy {lb} vs ideal {ib}");
        assert!(lb > 0);
    }

    #[test]
    fn topology_schedule_config_runs() {
        let cfg = ExperimentConfig {
            steps: 400,
            eval_every: 100,
            nodes: 16,
            problem: "quadratic:24".into(),
            topology_schedule: "switch:ring,torus:100".into(),
            ..Default::default()
        };
        let series = run_config(&cfg, false);
        let first = &series.records[0];
        let last = series.records.last().unwrap();
        assert!(last.opt_gap < first.opt_gap);
        assert!(last.bits > 0);
    }

    #[test]
    #[should_panic(expected = "bad link spec")]
    fn bad_link_panics() {
        let cfg = ExperimentConfig {
            link: "drop:2".into(),
            ..Default::default()
        };
        build_algo(&cfg, 16);
    }

    #[test]
    #[should_panic(expected = "out of range")]
    fn straggler_index_out_of_range_panics() {
        let cfg = ExperimentConfig {
            nodes: 4,
            link: "straggler:4:0.5".into(),
            ..Default::default()
        };
        build_algo(&cfg, 16);
    }

    #[test]
    #[should_panic(expected = "names its own graphs")]
    fn conflicting_topology_and_schedule_panics() {
        let cfg = ExperimentConfig {
            nodes: 16,
            topology: "torus".into(),
            topology_schedule: "switch:ring,torus:100".into(),
            ..Default::default()
        };
        build_algo(&cfg, 16);
    }

    #[test]
    #[should_panic(expected = "unknown problem spec")]
    fn bad_problem_panics() {
        let cfg = ExperimentConfig {
            problem: "svm:1".into(),
            ..Default::default()
        };
        build_problem(&cfg);
    }
}
