//! Experiment drivers regenerating the paper's evaluation (Figure 1a–1d),
//! the Remark-4 savings comparison, the Theorem-1 rate sweeps, and the
//! lossy-link / time-varying-topology robustness sweeps.

pub mod ablation;
pub mod builder;
pub mod fig1;
pub mod robustness;
pub mod savings;
pub mod rates;

pub use builder::{build_algo, build_problem, run_config};
