//! Experiment drivers regenerating the paper's evaluation (Figure 1a–1d),
//! the Remark-4 savings comparison, the Theorem-1 rate sweeps, and the
//! lossy-link / time-varying-topology robustness sweeps.
//!
//! Since the sweep refactor each driver is a *thin declarative spec* over
//! the sweep engine (`crate::sweep`): it states its config grid (a
//! `SweepSpec` or an explicit config list) and projects the returned
//! series into its point/table types. Run scheduling, cross-run artifact
//! caching, result streaming, and resume all live in the engine.

pub mod ablation;
pub mod builder;
pub mod families;
pub mod fig1;
pub mod robustness;
pub mod savings;
pub mod rates;

pub use builder::{
    build_algo, build_algo_resolved, build_algo_with, build_problem, build_problem_with,
    run_config,
};
