//! Structured ablations over SPARQ-SGD's design knobs (the quantities
//! Remark 1 predicts should only perturb higher-order terms): H, c₀, ω
//! (via k), γ, and topology δ. Each sweep runs matched-budget quadratic
//! experiments and returns a table row per point — used by the
//! `trigger_ablation` bench, the `sparq ablate` CLI subcommand, and the
//! ablation assertions in `rust/tests/convergence.rs`.

use crate::comm::Bus;
use crate::compress::SignTopK;
use crate::coordinator::{DecentralizedAlgo, SparqConfig, SparqSgd};
use crate::graph::{uniform_neighbor, Topology, TopologyKind};
use crate::problems::QuadraticProblem;
use crate::schedule::{LrSchedule, SyncSchedule};
use crate::trigger::{EventTrigger, ThresholdSchedule};

/// One ablation measurement.
#[derive(Clone, Debug)]
pub struct AblationPoint {
    pub knob: String,
    pub value: f64,
    pub final_gap: f64,
    pub total_bits: u64,
    pub comm_rounds: u64,
    pub fire_rate: f64,
}

/// Shared base setting for all sweeps (kept deliberately small so a full
/// ablation grid runs in seconds).
#[derive(Clone, Debug)]
pub struct AblationBase {
    pub n: usize,
    pub d: usize,
    pub steps: u64,
    pub seed: u64,
}

impl Default for AblationBase {
    fn default() -> Self {
        AblationBase {
            n: 8,
            d: 64,
            steps: 4000,
            seed: 11,
        }
    }
}

#[allow(clippy::too_many_arguments)]
fn run_one(
    base: &AblationBase,
    knob: &str,
    value: f64,
    h: u64,
    c0: f64,
    k: usize,
    gamma: Option<f64>,
    topology: TopologyKind,
) -> AblationPoint {
    let topo = Topology::new(topology, base.n, base.seed);
    let cfg = SparqConfig {
        mixing: uniform_neighbor(&topo),
        compressor: Box::new(SignTopK::new(k)),
        trigger: EventTrigger::new(if c0 > 0.0 {
            ThresholdSchedule::Poly { c0, eps: 0.5 }
        } else {
            ThresholdSchedule::Zero
        }),
        lr: LrSchedule::InverseTime { a: 60.0, b: 2.0 },
        sync: SyncSchedule::EveryH(h),
        gamma,
        momentum: 0.0,
        seed: base.seed,
    };
    let mut algo = SparqSgd::new(cfg, base.d);
    let mut prob = QuadraticProblem::new(base.d, base.n, 0.5, 2.0, 0.1, 0.5, base.seed ^ 0xF00D);
    let mut bus = Bus::new(base.n);
    for t in 0..base.steps {
        algo.step(t, &mut prob, &mut bus);
    }
    AblationPoint {
        knob: knob.to_string(),
        value,
        final_gap: prob.suboptimality(&algo.x_bar()),
        total_bits: bus.total_bits,
        comm_rounds: bus.comm_rounds,
        fire_rate: algo.total_fired as f64 / algo.total_checks.max(1) as f64,
    }
}

/// Sweep local-iteration count H (Remark 1(ii)).
pub fn h_sweep(base: &AblationBase, hs: &[u64]) -> Vec<AblationPoint> {
    hs.iter()
        .map(|&h| run_one(base, "H", h as f64, h, 50.0, base.d / 4, None, TopologyKind::Ring))
        .collect()
}

/// Sweep trigger constant c₀ (Remark 1(iii)).
pub fn c0_sweep(base: &AblationBase, c0s: &[f64]) -> Vec<AblationPoint> {
    c0s.iter()
        .map(|&c0| run_one(base, "c0", c0, 5, c0, base.d / 4, None, TopologyKind::Ring))
        .collect()
}

/// Sweep compression level via k (Remark 1(i); ω_eff ∝ k/d).
pub fn k_sweep(base: &AblationBase, ks: &[usize]) -> Vec<AblationPoint> {
    ks.iter()
        .map(|&k| run_one(base, "k", k as f64, 5, 50.0, k, None, TopologyKind::Ring))
        .collect()
}

/// Sweep the consensus step size γ (the tuned-vs-Lemma-6 question).
pub fn gamma_sweep(base: &AblationBase, gammas: &[f64]) -> Vec<AblationPoint> {
    gammas
        .iter()
        .map(|&g| {
            run_one(
                base,
                "gamma",
                g,
                5,
                50.0,
                base.d / 4,
                Some(g),
                TopologyKind::Ring,
            )
        })
        .collect()
}

/// Render points as an aligned text table.
pub fn table(points: &[AblationPoint]) -> String {
    use std::fmt::Write;
    let mut out = String::new();
    let _ = writeln!(
        out,
        "{:>8} {:>10} {:>12} {:>14} {:>12} {:>10}",
        "knob", "value", "final gap", "total bits", "comm rounds", "fire rate"
    );
    for p in points {
        let _ = writeln!(
            out,
            "{:>8} {:>10} {:>12.3e} {:>14} {:>12} {:>9.1}%",
            p.knob,
            p.value,
            p.final_gap,
            p.total_bits,
            p.comm_rounds,
            p.fire_rate * 100.0
        );
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;

    fn base() -> AblationBase {
        AblationBase {
            steps: 1500,
            ..Default::default()
        }
    }

    #[test]
    fn h_sweep_bits_monotone_decreasing() {
        let pts = h_sweep(&base(), &[1, 5, 25]);
        assert!(pts[0].total_bits > pts[1].total_bits);
        assert!(pts[1].total_bits > pts[2].total_bits);
        // all converge to something sensible at this budget
        for p in &pts {
            assert!(p.final_gap < 0.5, "{p:?}");
        }
    }

    #[test]
    fn c0_sweep_fire_rate_monotone_nonincreasing() {
        let pts = c0_sweep(&base(), &[0.0, 50.0, 5000.0]);
        assert!(pts[0].fire_rate >= pts[1].fire_rate);
        assert!(pts[1].fire_rate >= pts[2].fire_rate);
        assert!((pts[0].fire_rate - 1.0).abs() < 1e-9, "c0=0 always fires");
    }

    #[test]
    fn k_sweep_bits_increase_with_k() {
        let pts = k_sweep(&base(), &[4, 16, 48]);
        assert!(pts[0].total_bits < pts[1].total_bits);
        assert!(pts[1].total_bits < pts[2].total_bits);
    }

    #[test]
    fn gamma_zero_breaks_consensus() {
        // γ=0 disables mixing entirely: heterogeneous nodes never agree,
        // so the gap stays far above a healthy γ's.
        let pts = gamma_sweep(&base(), &[0.0, 0.25]);
        // NOTE: gamma=0.0 maps to Some(0.0) (explicit), not the heuristic.
        assert!(
            pts[0].final_gap > pts[1].final_gap * 3.0,
            "γ=0 gap {} vs γ=.25 gap {}",
            pts[0].final_gap,
            pts[1].final_gap
        );
    }

    #[test]
    fn table_renders() {
        let pts = c0_sweep(&base(), &[0.0, 10.0]);
        let t = table(&pts);
        assert!(t.contains("fire rate"));
        assert!(t.lines().count() >= 3);
    }
}
