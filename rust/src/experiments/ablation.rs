//! Structured ablations over SPARQ-SGD's design knobs (the quantities
//! Remark 1 predicts should only perturb higher-order terms): H, c₀, ω
//! (via k), γ, and topology δ. Each sweep is a declarative config list
//! executed on the sweep engine (one shared `ArtifactCache` per sweep —
//! the ring is built and eigen-solved once), returning a table row per
//! point — used by the `trigger_ablation` bench, the `sparq ablate` CLI
//! subcommand, and the ablation assertions in
//! `rust/tests/convergence.rs`.

use crate::config::{Algo, ExperimentConfig};
use crate::sweep::{run_configs, ArtifactCache, SweepOptions};

/// One ablation measurement.
#[derive(Clone, Debug)]
pub struct AblationPoint {
    pub knob: String,
    pub value: f64,
    pub final_gap: f64,
    pub total_bits: u64,
    pub comm_rounds: u64,
    pub fire_rate: f64,
}

/// Shared base setting for all sweeps (kept deliberately small so a full
/// ablation grid runs in seconds).
#[derive(Clone, Debug)]
pub struct AblationBase {
    pub n: usize,
    pub d: usize,
    pub steps: u64,
    pub seed: u64,
    /// Total sweep worker budget (0 ⇒ available CPUs); results are
    /// identical for any value.
    pub workers: usize,
}

impl Default for AblationBase {
    fn default() -> Self {
        AblationBase {
            n: 8,
            d: 64,
            steps: 4000,
            seed: 11,
            workers: 1,
        }
    }
}

/// One knob point as a config. γ semantics: `None` ⇒ tuned heuristic,
/// `Some(0.0)` ⇒ mixing disabled exactly (config gamma < 0 expresses it).
fn knob_config(
    base: &AblationBase,
    knob: &str,
    value: f64,
    h: u64,
    c0: f64,
    k: usize,
    gamma: Option<f64>,
) -> ExperimentConfig {
    ExperimentConfig {
        name: format!("ablate-{knob}-{value}"),
        algo: Algo::Sparq,
        nodes: base.n,
        compressor: crate::config::CompressorSpec::sign_top_k(k),
        trigger: if c0 > 0.0 {
            crate::config::TriggerSpec::poly(c0, 0.5)
        } else {
            crate::config::TriggerSpec::zero()
        },
        lr: "invtime:60:2".into(),
        h: h.into(),
        steps: base.steps,
        eval_every: base.steps.max(1),
        seed: base.seed,
        // σ = 0.1 noise, 0.5 heterogeneity spread — the ablation regime.
        problem: format!("quadratic:{}:0.1:0.5", base.d).into(),
        gamma: match gamma {
            None => 0.0,
            Some(g) if g == 0.0 => -1.0, // pin γ = 0 exactly
            Some(g) => g,
        },
        ..Default::default()
    }
}

/// Execute knob configs on the sweep engine, one point per config,
/// under the base's worker budget.
fn run_knobs(
    knob: &str,
    workers: usize,
    points: Vec<(f64, ExperimentConfig)>,
) -> Vec<AblationPoint> {
    let cache = ArtifactCache::new();
    let values: Vec<f64> = points.iter().map(|(v, _)| *v).collect();
    let runs: Vec<(String, ExperimentConfig)> = points
        .into_iter()
        .map(|(_, cfg)| (cfg.name.clone(), cfg))
        .collect();
    let opts = SweepOptions {
        workers,
        ..Default::default()
    };
    let report = run_configs(runs, &opts, &cache).expect("ablation sweep runs");
    report
        .outcomes
        .into_iter()
        .zip(values)
        .map(|(o, value)| {
            let last = o.series.records.last().expect("at least one record");
            AblationPoint {
                knob: knob.to_string(),
                value,
                final_gap: last.opt_gap,
                total_bits: last.bits,
                comm_rounds: last.comm_rounds,
                fire_rate: o.fired as f64 / o.checks.max(1) as f64,
            }
        })
        .collect()
}

/// Sweep local-iteration count H (Remark 1(ii)).
pub fn h_sweep(base: &AblationBase, hs: &[u64]) -> Vec<AblationPoint> {
    run_knobs(
        "H",
        base.workers,
        hs.iter()
            .map(|&h| {
                (
                    h as f64,
                    knob_config(base, "H", h as f64, h, 50.0, base.d / 4, None),
                )
            })
            .collect(),
    )
}

/// Sweep trigger constant c₀ (Remark 1(iii)).
pub fn c0_sweep(base: &AblationBase, c0s: &[f64]) -> Vec<AblationPoint> {
    run_knobs(
        "c0",
        base.workers,
        c0s.iter()
            .map(|&c0| (c0, knob_config(base, "c0", c0, 5, c0, base.d / 4, None)))
            .collect(),
    )
}

/// Sweep compression level via k (Remark 1(i); ω_eff ∝ k/d).
pub fn k_sweep(base: &AblationBase, ks: &[usize]) -> Vec<AblationPoint> {
    run_knobs(
        "k",
        base.workers,
        ks.iter()
            .map(|&k| (k as f64, knob_config(base, "k", k as f64, 5, 50.0, k, None)))
            .collect(),
    )
}

/// Sweep the consensus step size γ (the tuned-vs-Lemma-6 question).
pub fn gamma_sweep(base: &AblationBase, gammas: &[f64]) -> Vec<AblationPoint> {
    run_knobs(
        "gamma",
        base.workers,
        gammas
            .iter()
            .map(|&g| {
                (
                    g,
                    knob_config(base, "gamma", g, 5, 50.0, base.d / 4, Some(g)),
                )
            })
            .collect(),
    )
}

/// Render points as an aligned text table.
pub fn table(points: &[AblationPoint]) -> String {
    use std::fmt::Write;
    let mut out = String::new();
    let _ = writeln!(
        out,
        "{:>8} {:>10} {:>12} {:>14} {:>12} {:>10}",
        "knob", "value", "final gap", "total bits", "comm rounds", "fire rate"
    );
    for p in points {
        let _ = writeln!(
            out,
            "{:>8} {:>10} {:>12.3e} {:>14} {:>12} {:>9.1}%",
            p.knob,
            p.value,
            p.final_gap,
            p.total_bits,
            p.comm_rounds,
            p.fire_rate * 100.0
        );
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;

    fn base() -> AblationBase {
        AblationBase {
            steps: 1500,
            ..Default::default()
        }
    }

    #[test]
    fn h_sweep_bits_monotone_decreasing() {
        let pts = h_sweep(&base(), &[1, 5, 25]);
        assert!(pts[0].total_bits > pts[1].total_bits);
        assert!(pts[1].total_bits > pts[2].total_bits);
        // all converge to something sensible at this budget
        for p in &pts {
            assert!(p.final_gap < 0.5, "{p:?}");
        }
    }

    #[test]
    fn c0_sweep_fire_rate_monotone_nonincreasing() {
        let pts = c0_sweep(&base(), &[0.0, 50.0, 5000.0]);
        assert!(pts[0].fire_rate >= pts[1].fire_rate);
        assert!(pts[1].fire_rate >= pts[2].fire_rate);
        assert!((pts[0].fire_rate - 1.0).abs() < 1e-9, "c0=0 always fires");
    }

    #[test]
    fn k_sweep_bits_increase_with_k() {
        let pts = k_sweep(&base(), &[4, 16, 48]);
        assert!(pts[0].total_bits < pts[1].total_bits);
        assert!(pts[1].total_bits < pts[2].total_bits);
    }

    #[test]
    fn gamma_zero_breaks_consensus() {
        // γ=0 disables mixing entirely: heterogeneous nodes never agree,
        // so the gap stays far above a healthy γ's.
        let pts = gamma_sweep(&base(), &[0.0, 0.25]);
        // NOTE: gamma=0.0 maps to the pinned-zero config (gamma: -1), not
        // the tuned heuristic.
        assert!(
            pts[0].final_gap > pts[1].final_gap * 3.0,
            "γ=0 gap {} vs γ=.25 gap {}",
            pts[0].final_gap,
            pts[1].final_gap
        );
    }

    #[test]
    fn table_renders() {
        let pts = c0_sweep(&base(), &[0.0, 10.0]);
        let t = table(&pts);
        assert!(t.contains("fire rate"));
        assert!(t.lines().count() >= 3);
    }
}
