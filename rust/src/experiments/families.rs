//! Cross-family comparison driver: the same workload run under each
//! trigger-side composition of the event-triggered engine — plain SPARQ
//! (Algorithm 1), SQuARM (momentum-buffered trigger, arXiv 2005.07041),
//! and EventGraD-style per-coordinate triggers — plus the CHOCO
//! always-transmit baseline for scale.
//!
//! Like the other drivers this is a thin declarative spec over the sweep
//! engine: [`family_grid`] states the configs, [`run_family_comparison`]
//! executes them (shared topology/dataset artifacts) and projects the
//! outcomes into [`ReportRun`]s so `sweep::report::family_table` renders
//! the comparison panel — the same panel `sparq sweep report` prints for
//! on-disk result sets.

use crate::config::ExperimentConfig;
use crate::sweep::report::ReportRun;
use crate::sweep::{run_configs, ArtifactCache, SweepOptions};

/// The composition label a config groups under in the family panel:
/// the family field when set, "percoord" for per-coordinate triggers,
/// "sparq" otherwise. Mirrors the key the sweep runner persists.
pub fn family_label(cfg: &ExperimentConfig) -> String {
    if !cfg.family.is_default() {
        cfg.family.as_str().to_string()
    } else if cfg.trigger.per_coord() {
        "percoord".to_string()
    } else {
        "sparq".to_string()
    }
}

/// The comparison grid: one config per family on a shared quadratic
/// workload (same nodes, topology, compressor, sync schedule, and seed,
/// so the only degree of freedom is the trigger-side composition).
///
/// The per-coordinate threshold is the norm threshold split evenly over
/// the d = 64 coordinates, so both triggers police the same total drift
/// budget; β = 0.9 is the SQuARM paper's setting.
pub fn family_grid(steps: u64, seed: u64) -> Vec<(String, ExperimentConfig)> {
    let base = ExperimentConfig {
        name: "families-sparq".into(),
        nodes: 8,
        steps,
        eval_every: (steps / 20).max(1),
        seed,
        problem: "quadratic:64".into(),
        compressor: "sign_topk:25%".into(),
        trigger: "const:50".into(),
        h: 2u64.into(),
        ..Default::default()
    };
    let squarm = ExperimentConfig {
        name: "families-squarm".into(),
        family: "squarm:0.9".into(),
        ..base.clone()
    };
    let percoord = ExperimentConfig {
        name: "families-percoord".into(),
        trigger: "percoord:0.78125".into(), // 50 / 64
        ..base.clone()
    };
    let choco = ExperimentConfig {
        name: "families-choco".into(),
        algo: crate::config::Algo::Choco,
        h: 1u64.into(),
        trigger: "zero".into(),
        ..base.clone()
    };
    vec![
        ("SPARQ-SGD".to_string(), base),
        ("SQuARM-SGD(0.9)".to_string(), squarm),
        ("SPARQ-percoord".to_string(), percoord),
        ("CHOCO-SGD".to_string(), choco),
    ]
}

/// Run the family grid through the sweep engine and project each outcome
/// into a [`ReportRun`] (family tag attached), ready for
/// `sweep::report::family_table` / `savings_table`.
pub fn run_family_comparison(
    steps: u64,
    seed: u64,
    opts: &SweepOptions,
) -> Result<Vec<ReportRun>, String> {
    let cache = ArtifactCache::new();
    let report = run_configs(family_grid(steps, seed), opts, &cache)?;
    Ok(report
        .outcomes
        .into_iter()
        .map(|o| ReportRun {
            family: family_label(&o.cfg),
            id: o.id,
            name: o.cfg.name.clone(),
            label: o.label,
            algo: o.cfg.algo.as_str().to_string(),
            fired: o.fired,
            checks: o.checks,
            fault: o.fault,
            truncated: o.stopped,
            series: o.series,
        })
        .collect())
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::sweep::report::{family_table, TargetMetric};

    #[test]
    fn grid_resolves_and_labels_families() {
        let grid = family_grid(400, 3);
        assert_eq!(grid.len(), 4);
        for (label, cfg) in &grid {
            cfg.resolve().unwrap_or_else(|e| panic!("{label}: {e}"));
        }
        let fams: Vec<String> = grid.iter().map(|(_, c)| family_label(c)).collect();
        assert_eq!(fams, ["sparq", "squarm:0.9", "percoord", "sparq"]);
    }

    #[test]
    fn comparison_runs_and_panel_renders_every_family() {
        let runs = run_family_comparison(200, 7, &SweepOptions::default()).unwrap();
        assert_eq!(runs.len(), 4);
        // every triggered run actually checked its trigger
        for r in runs.iter().take(3) {
            assert!(r.checks > 0, "{}", r.label);
        }
        // pick a loss every run reaches: the worst final loss
        let target = runs
            .iter()
            .map(|r| r.series.records.last().unwrap().loss)
            .fold(f64::MIN, f64::max)
            * 1.02;
        let table = family_table(&runs, TargetMetric::Loss, target);
        for fam in ["sparq", "squarm:0.9", "percoord"] {
            assert!(table.contains(fam), "missing {fam}: {table}");
        }
    }
}
