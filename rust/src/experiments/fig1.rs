//! Figure 1 reproduction: the four panels of the paper's evaluation.
//!
//! * 1a — convex: test error vs *communication rounds* for SPARQ-SGD vs
//!   CHOCO-SGD (Sign / TopK / SignTopK) vs vanilla decentralized SGD.
//! * 1b — convex: test error vs *total transmitted bits*.
//! * 1c — non-convex: training loss vs epochs.
//! * 1d — non-convex: top-1 accuracy vs total transmitted bits.
//!
//! Scale: synthetic datasets and step-scaled horizons (DESIGN.md
//! §Substitutions). The claims under test are *shape* claims: SPARQ
//! reaches the target error in ≤ rounds and with orders-of-magnitude
//! fewer bits than CHOCO/vanilla.

use crate::config::{presets, Algo, ExperimentConfig};
use crate::metrics::Series;

use super::builder::run_config;

/// The five curves of Fig 1a/1b.
pub fn convex_suite(steps: u64, seed: u64) -> Vec<(String, ExperimentConfig)> {
    let base = presets::convex_sparq(steps);
    let mut out = Vec::new();

    let mut sparq = base.clone();
    sparq.seed = seed;
    out.push(("SPARQ-SGD (SignTopK)".to_string(), sparq));

    let mut choco_sign = base.clone();
    choco_sign.algo = Algo::Choco;
    choco_sign.compressor = "sign".into();
    choco_sign.name = "fig1-convex-choco-sign".into();
    choco_sign.seed = seed;
    out.push(("CHOCO-SGD (Sign)".to_string(), choco_sign));

    // Paper Section 5.1 uses k = 10 for the TopK baseline as well (the
    // quoted 10-15x SPARQ-vs-TopK factor only makes sense for k = 10:
    // TopK's 45 bits/coordinate vs Sign's 1 bit/coordinate).
    let mut choco_topk = base.clone();
    choco_topk.algo = Algo::Choco;
    choco_topk.compressor = "topk:10".into();
    choco_topk.name = "fig1-convex-choco-topk".into();
    choco_topk.seed = seed;
    out.push(("CHOCO-SGD (TopK)".to_string(), choco_topk));

    // The paper also implements SignTopK inside CHOCO for comparison.
    let mut choco_st = base.clone();
    choco_st.algo = Algo::Choco;
    choco_st.name = "fig1-convex-choco-signtopk".into();
    choco_st.seed = seed;
    out.push(("CHOCO-SGD (SignTopK)".to_string(), choco_st));

    let mut vanilla = base.clone();
    vanilla.algo = Algo::Vanilla;
    vanilla.compressor = "identity".into();
    vanilla.name = "fig1-convex-vanilla".into();
    vanilla.seed = seed;
    out.push(("Vanilla decentralized SGD".to_string(), vanilla));

    out
}

/// The Fig 1c/1d curves (non-convex, momentum 0.9).
pub fn nonconvex_suite(
    steps: u64,
    steps_per_epoch: usize,
    seed: u64,
    problem: &str,
) -> Vec<(String, ExperimentConfig)> {
    let mut base = presets::nonconvex_sparq(steps, steps_per_epoch);
    // Paper-convention bit accounting for SignTopK (signs + norm, no
    // index bits): Section 5.2 "only transmit the sign and norm of the
    // result" — the quoted 250×/1000×/15K× factors reconcile under this
    // convention. `compress::SignTopK` documents both accountings; the
    // savings tables in EXPERIMENTS.md report honest-indices numbers too.
    base.compressor = "sign_topk:10%:paper".into();
    base.problem = problem.to_string();
    base.seed = seed;
    let mut out = Vec::new();

    out.push(("SPARQ-SGD (SignTopK)".to_string(), base.clone()));

    // SPARQ without event trigger = "SPARQ-SGD (Sign-TopK)" curve of 1c/1d.
    let mut no_trig = base.clone();
    no_trig.trigger = "zero".into();
    no_trig.name = "fig1-nonconvex-signtopk-notrigger".into();
    out.push(("SPARQ-SGD (SignTopK, no trigger)".to_string(), no_trig));

    let mut choco_sign = base.clone();
    choco_sign.algo = Algo::Choco;
    choco_sign.compressor = "sign".into();
    choco_sign.name = "fig1-nonconvex-choco-sign".into();
    out.push(("CHOCO-SGD (Sign)".to_string(), choco_sign));

    let mut choco_topk = base.clone();
    choco_topk.algo = Algo::Choco;
    choco_topk.compressor = "topk:10%".into();
    choco_topk.name = "fig1-nonconvex-choco-topk".into();
    out.push(("CHOCO-SGD (TopK)".to_string(), choco_topk));

    let mut vanilla = base;
    vanilla.algo = Algo::Vanilla;
    vanilla.compressor = "identity".into();
    vanilla.name = "fig1-nonconvex-vanilla".into();
    out.push(("Vanilla decentralized SGD".to_string(), vanilla));

    out
}

/// Run a suite's curves concurrently on the in-tree thread pool (each
/// curve owns its problem + algorithm, so they are independent; results
/// are deterministic regardless of worker count).
pub fn run_suite_parallel(
    suite: Vec<(String, ExperimentConfig)>,
    workers: usize,
) -> Vec<Series> {
    use crate::util::threadpool::ThreadPool;
    let mut slots: Vec<(String, ExperimentConfig, Option<Series>)> = suite
        .into_iter()
        .map(|(label, cfg)| (label, cfg, None))
        .collect();
    ThreadPool::new(workers).for_each_mut(&mut slots, |_, slot| {
        let mut s = run_config(&slot.1, false);
        s.label = slot.0.clone();
        slot.2 = Some(s);
    });
    slots.into_iter().map(|(_, _, s)| s.unwrap()).collect()
}

/// Run a suite, printing progress.
pub fn run_suite(suite: Vec<(String, ExperimentConfig)>, verbose: bool) -> Vec<Series> {
    suite
        .into_iter()
        .map(|(label, cfg)| {
            if verbose {
                println!("== {label} ==");
            }
            let mut s = run_config(&cfg, verbose);
            s.label = label;
            s
        })
        .collect()
}

/// Render an ASCII table: for each series, the comm rounds and bits at
/// which it first reaches `target_err`, plus the savings factor vs the
/// reference series (last one by convention = vanilla).
pub fn savings_table(series: &[Series], target_err: f64) -> String {
    use std::fmt::Write;
    let mut out = String::new();
    let _ = writeln!(
        out,
        "{:<38} {:>12} {:>16} {:>12}",
        "algorithm", "comm rounds", "bits to target", "savings vs 1st"
    );
    let reference_bits = series
        .first()
        .and_then(|s| s.first_reaching_error(target_err))
        .map(|r| r.bits);
    for s in series {
        match s.first_reaching_error(target_err) {
            Some(r) => {
                let factor = match reference_bits {
                    Some(rb) if rb > 0 => format!("{:.1}x", r.bits as f64 / rb as f64),
                    _ => "-".to_string(),
                };
                let _ = writeln!(
                    out,
                    "{:<38} {:>12} {:>16} {:>12}",
                    s.label, r.comm_rounds, r.bits, factor
                );
            }
            None => {
                let _ = writeln!(
                    out,
                    "{:<38} {:>12} {:>16} {:>12}",
                    s.label, "-", "(not reached)", "-"
                );
            }
        }
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn suites_have_expected_curves() {
        let c = convex_suite(100, 1);
        assert_eq!(c.len(), 5);
        assert!(c.iter().any(|(l, _)| l.contains("SPARQ")));
        assert!(c.iter().any(|(l, _)| l.contains("Vanilla")));
        let n = nonconvex_suite(100, 10, 1, "mlp:64:16:4:8");
        assert_eq!(n.len(), 5);
    }

    #[test]
    fn mini_convex_suite_runs_and_orders_bits() {
        // Tiny dimensions so the test is fast; the *ordering* claim
        // (SPARQ bits < CHOCO bits < vanilla bits at equal error) is the
        // paper's Figure 1b shape.
        let mut suite = convex_suite(400, 3);
        for (_, cfg) in suite.iter_mut() {
            cfg.nodes = 8;
            cfg.problem = "logreg:24:4:8".into();
            if cfg.compressor == "sign_topk:10" {
                cfg.compressor = "sign_topk:10%".into();
            }
            cfg.trigger = "const:10".into();
            cfg.eval_every = 50;
        }
        let series = run_suite(suite, false);
        let target = 0.25;
        let sparq = series[0].first_reaching_error(target);
        let vanilla = series[4].first_reaching_error(target);
        if let (Some(s), Some(v)) = (sparq, vanilla) {
            assert!(
                s.bits < v.bits,
                "SPARQ bits {} should be < vanilla bits {}",
                s.bits,
                v.bits
            );
        }
        // table renders
        let tbl = savings_table(&series, target);
        assert!(tbl.contains("algorithm"));
    }
}
