//! Figure 1 reproduction: the four panels of the paper's evaluation.
//!
//! * 1a — convex: test error vs *communication rounds* for SPARQ-SGD vs
//!   CHOCO-SGD (Sign / TopK / SignTopK) vs vanilla decentralized SGD.
//! * 1b — convex: test error vs *total transmitted bits*.
//! * 1c — non-convex: training loss vs epochs.
//! * 1d — non-convex: top-1 accuracy vs total transmitted bits.
//!
//! Scale: synthetic datasets and step-scaled horizons (DESIGN.md
//! §Substitutions). The claims under test are *shape* claims: SPARQ
//! reaches the target error in ≤ rounds and with orders-of-magnitude
//! fewer bits than CHOCO/vanilla.

use crate::config::{presets, ExperimentConfig};
use crate::metrics::Series;
use crate::sweep::{run_configs, ArtifactCache, SweepOptions, SweepSpec};
use crate::util::json::Json;

/// The Fig 1a/1b grid as a declarative sweep spec: one base config, the
/// five curves as variants. `examples/specs/fig1_convex.json` is this
/// spec's on-disk form.
pub fn convex_spec(steps: u64, seed: u64) -> SweepSpec {
    let mut base = presets::convex_sparq(steps);
    base.seed = seed;
    SweepSpec::new("fig1-convex")
        .base(&base)
        .variant("SPARQ-SGD (SignTopK)", &[("name", Json::from("fig1-convex-sparq"))])
        .variant(
            "CHOCO-SGD (Sign)",
            &[
                ("name", Json::from("fig1-convex-choco-sign")),
                ("algo", Json::from("choco")),
                ("compressor", Json::from("sign")),
            ],
        )
        // Paper Section 5.1 uses k = 10 for the TopK baseline as well
        // (the quoted 10-15x SPARQ-vs-TopK factor only makes sense for
        // k = 10: TopK's 45 bits/coordinate vs Sign's 1 bit/coordinate).
        .variant(
            "CHOCO-SGD (TopK)",
            &[
                ("name", Json::from("fig1-convex-choco-topk")),
                ("algo", Json::from("choco")),
                ("compressor", Json::from("topk:10")),
            ],
        )
        // The paper also implements SignTopK inside CHOCO for comparison.
        .variant(
            "CHOCO-SGD (SignTopK)",
            &[
                ("name", Json::from("fig1-convex-choco-signtopk")),
                ("algo", Json::from("choco")),
            ],
        )
        .variant(
            "Vanilla decentralized SGD",
            &[
                ("name", Json::from("fig1-convex-vanilla")),
                ("algo", Json::from("vanilla")),
                ("compressor", Json::from("identity")),
            ],
        )
}

/// The five curves of Fig 1a/1b (the expanded [`convex_spec`] grid).
pub fn convex_suite(steps: u64, seed: u64) -> Vec<(String, ExperimentConfig)> {
    convex_spec(steps, seed)
        .expand()
        .expect("fig1 convex spec expands")
}

/// One SPARQ point of the Fig 1a/1b grid at a chosen node count — the
/// cluster runtime's identity checks run this config both in-process
/// and as one OS process per node (`nodes` must fit the machine, so the
/// n = 60 preset is scaled down rather than reused).
pub fn convex_point(nodes: usize, steps: u64, seed: u64) -> ExperimentConfig {
    let mut cfg = presets::convex_sparq(steps);
    cfg.name = format!("fig1-convex-point-n{nodes}");
    cfg.nodes = nodes;
    cfg.seed = seed;
    cfg
}

/// The Fig 1c/1d grid as a declarative sweep spec (non-convex, momentum
/// 0.9).
pub fn nonconvex_spec(
    steps: u64,
    steps_per_epoch: usize,
    seed: u64,
    problem: &str,
) -> SweepSpec {
    let mut base = presets::nonconvex_sparq(steps, steps_per_epoch);
    // Paper-convention bit accounting for SignTopK (signs + norm, no
    // index bits): Section 5.2 "only transmit the sign and norm of the
    // result" — the quoted 250×/1000×/15K× factors reconcile under this
    // convention. `compress::SignTopK` documents both accountings; the
    // savings tables in EXPERIMENTS.md report honest-indices numbers too.
    base.compressor = "sign_topk:10%:paper".into();
    base.problem = problem.into();
    base.seed = seed;
    SweepSpec::new("fig1-nonconvex")
        .base(&base)
        .variant(
            "SPARQ-SGD (SignTopK)",
            &[("name", Json::from("fig1-nonconvex-sparq"))],
        )
        // SPARQ without event trigger = "SPARQ-SGD (Sign-TopK)" of 1c/1d.
        .variant(
            "SPARQ-SGD (SignTopK, no trigger)",
            &[
                ("name", Json::from("fig1-nonconvex-signtopk-notrigger")),
                ("trigger", Json::from("zero")),
            ],
        )
        .variant(
            "CHOCO-SGD (Sign)",
            &[
                ("name", Json::from("fig1-nonconvex-choco-sign")),
                ("algo", Json::from("choco")),
                ("compressor", Json::from("sign")),
            ],
        )
        .variant(
            "CHOCO-SGD (TopK)",
            &[
                ("name", Json::from("fig1-nonconvex-choco-topk")),
                ("algo", Json::from("choco")),
                ("compressor", Json::from("topk:10%")),
            ],
        )
        .variant(
            "Vanilla decentralized SGD",
            &[
                ("name", Json::from("fig1-nonconvex-vanilla")),
                ("algo", Json::from("vanilla")),
                ("compressor", Json::from("identity")),
            ],
        )
}

/// The Fig 1c/1d curves (the expanded [`nonconvex_spec`] grid).
pub fn nonconvex_suite(
    steps: u64,
    steps_per_epoch: usize,
    seed: u64,
    problem: &str,
) -> Vec<(String, ExperimentConfig)> {
    nonconvex_spec(steps, steps_per_epoch, seed, problem)
        .expand()
        .expect("fig1 nonconvex spec expands")
}

/// Run a suite's curves on the sweep engine with the given total worker
/// budget (each curve owns its problem + algorithm; topology/spectral/
/// dataset artifacts are shared through the sweep cache; results are
/// bit-for-bit deterministic regardless of the budget).
pub fn run_suite_parallel(
    suite: Vec<(String, ExperimentConfig)>,
    workers: usize,
) -> Vec<Series> {
    let cache = ArtifactCache::new();
    let opts = SweepOptions {
        workers,
        ..Default::default()
    };
    let report = run_configs(suite, &opts, &cache).expect("suite runs");
    report
        .outcomes
        .into_iter()
        .map(|o| {
            let mut s = o.series;
            s.label = o.label;
            s
        })
        .collect()
}

/// Run a suite serially, printing per-run progress.
pub fn run_suite(suite: Vec<(String, ExperimentConfig)>, verbose: bool) -> Vec<Series> {
    let cache = ArtifactCache::new();
    let opts = SweepOptions {
        workers: 1,
        verbose,
        ..Default::default()
    };
    let report = run_configs(suite, &opts, &cache).expect("suite runs");
    report
        .outcomes
        .into_iter()
        .map(|o| {
            let mut s = o.series;
            s.label = o.label;
            s
        })
        .collect()
}

/// Render an ASCII table: for each series, the comm rounds and bits at
/// which it first reaches `target_err`, plus the savings factor vs the
/// reference series (last one by convention = vanilla).
pub fn savings_table(series: &[Series], target_err: f64) -> String {
    use std::fmt::Write;
    let mut out = String::new();
    let _ = writeln!(
        out,
        "{:<38} {:>12} {:>16} {:>12}",
        "algorithm", "comm rounds", "bits to target", "savings vs 1st"
    );
    let reference_bits = series
        .first()
        .and_then(|s| s.first_reaching_error(target_err))
        .map(|r| r.bits);
    for s in series {
        match s.first_reaching_error(target_err) {
            Some(r) => {
                let factor = match reference_bits {
                    Some(rb) if rb > 0 => format!("{:.1}x", r.bits as f64 / rb as f64),
                    _ => "-".to_string(),
                };
                let _ = writeln!(
                    out,
                    "{:<38} {:>12} {:>16} {:>12}",
                    s.label, r.comm_rounds, r.bits, factor
                );
            }
            None => {
                let _ = writeln!(
                    out,
                    "{:<38} {:>12} {:>16} {:>12}",
                    s.label, "-", "(not reached)", "-"
                );
            }
        }
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn specs_json_roundtrip_to_the_same_grid() {
        for spec in [convex_spec(100, 1), nonconvex_spec(100, 10, 1, "mlp:64:16:4:8")] {
            let runs = spec.expand().unwrap();
            let back = SweepSpec::from_json(&spec.to_json()).unwrap();
            let runs2 = back.expand().unwrap();
            assert_eq!(runs.len(), 5);
            assert_eq!(runs.len(), runs2.len());
            for ((la, ca), (lb, cb)) in runs.iter().zip(runs2.iter()) {
                assert_eq!(la, lb);
                assert_eq!(ca, cb);
            }
        }
    }

    #[test]
    fn suites_have_expected_curves() {
        let c = convex_suite(100, 1);
        assert_eq!(c.len(), 5);
        assert!(c.iter().any(|(l, _)| l.contains("SPARQ")));
        assert!(c.iter().any(|(l, _)| l.contains("Vanilla")));
        let n = nonconvex_suite(100, 10, 1, "mlp:64:16:4:8");
        assert_eq!(n.len(), 5);
    }

    #[test]
    fn mini_convex_suite_runs_and_orders_bits() {
        // Tiny dimensions so the test is fast; the *ordering* claim
        // (SPARQ bits < CHOCO bits < vanilla bits at equal error) is the
        // paper's Figure 1b shape.
        let mut suite = convex_suite(400, 3);
        for (_, cfg) in suite.iter_mut() {
            cfg.nodes = 8;
            cfg.problem = "logreg:24:4:8".into();
            if cfg.compressor == "sign_topk:10" {
                cfg.compressor = "sign_topk:10%".into();
            }
            cfg.trigger = "const:10".into();
            cfg.eval_every = 50;
        }
        let series = run_suite(suite, false);
        let target = 0.25;
        let sparq = series[0].first_reaching_error(target);
        let vanilla = series[4].first_reaching_error(target);
        if let (Some(s), Some(v)) = (sparq, vanilla) {
            assert!(
                s.bits < v.bits,
                "SPARQ bits {} should be < vanilla bits {}",
                s.bits,
                v.bits
            );
        }
        // table renders
        let tbl = savings_table(&series, target);
        assert!(tbl.contains("algorithm"));
    }
}
