//! Theorem-1 rate sweeps on the known-optimum quadratic: how the
//! suboptimality after T steps responds to n, H, c₀, ω, δ — the paper's
//! Remark 1 sensitivity analysis, measured.
//!
//! Each sweep is a declarative list of `ExperimentConfig`s executed on
//! the sweep engine (`sweep::run_configs`), sharing topology/spectral
//! artifacts across points through one `ArtifactCache` — the eigen solve
//! behind δ and the tuned γ runs once per distinct graph, not once per
//! point.

use crate::config::{Algo, ExperimentConfig};
use crate::graph::TopologyKind;
use crate::sweep::{run_configs, ArtifactCache, SweepOptions};

/// One sweep point.
#[derive(Clone, Debug)]
pub struct RatePoint {
    pub label: String,
    pub n: usize,
    pub h: u64,
    pub c0: f64,
    pub omega: f64,
    pub delta: f64,
    pub steps: u64,
    pub final_gap: f64,
    pub total_bits: u64,
}

/// Topology spec string for a kind (inverse of `TopologyKind::parse`).
fn topo_spec(kind: TopologyKind) -> String {
    kind.spec_str()
}

#[allow(clippy::too_many_arguments)]
fn point_config(
    n: usize,
    d: usize,
    h: u64,
    c0: f64,
    compressor: String,
    topology: TopologyKind,
    steps: u64,
    seed: u64,
) -> ExperimentConfig {
    ExperimentConfig {
        name: format!("rates-n{n}-h{h}-c{c0}-{}", topo_spec(topology)),
        algo: Algo::Sparq,
        nodes: n,
        topology: crate::config::TopologySpec::of_kind(topology),
        compressor: compressor.into(),
        // Theorem 1 form c_t = c0·√t (trigger off when c0 = 0).
        trigger: if c0 > 0.0 {
            crate::config::TriggerSpec::poly(c0, 0.5)
        } else {
            crate::config::TriggerSpec::zero()
        },
        // Practical inverse-time schedule: Theorem 1's a >= 5H/p with the
        // worst-case p makes eta so small that T-sweeps at test scale sit
        // in the pre-asymptotic plateau; the paper's own experiments use
        // eta_t = 1/(t+100)-style tuned schedules (Section 5.1).
        lr: "invtime:60:2".into(),
        h: h.into(),
        steps,
        eval_every: steps.max(1),
        seed,
        // σ = 0.2 noise, unit heterogeneity spread — the rate-test regime.
        problem: format!("quadratic:{d}:0.2:1").into(),
        ..Default::default()
    }
}

/// Execute rate-point configs on the sweep engine and project the
/// series into [`RatePoint`]s (ω from the compressor contract, δ from
/// the shared spectral cache).
fn run_points(configs: Vec<ExperimentConfig>, cache: &ArtifactCache) -> Vec<RatePoint> {
    let runs: Vec<(String, ExperimentConfig)> = configs
        .into_iter()
        .map(|cfg| (cfg.name.clone(), cfg))
        .collect();
    let report =
        run_configs(runs, &SweepOptions::default(), cache).expect("rate sweep runs");
    report
        .outcomes
        .into_iter()
        .map(|o| {
            let cfg = &o.cfg;
            // Typed payloads: no string re-splitting.
            let d = cfg.problem.dim();
            let comp = cfg.compressor.build(d);
            let omega = comp.omega(d);
            let mixing = cache.mixing_or_else(ArtifactCache::topo_key(cfg), || {
                super::builder::build_mixing(cfg)
            });
            let delta = cache
                .spectral_or_compute(ArtifactCache::topo_key(cfg), &mixing)
                .delta;
            let c0 = match cfg.trigger.schedule() {
                crate::trigger::ThresholdSchedule::Constant(c0) => *c0,
                crate::trigger::ThresholdSchedule::Poly { c0, .. } => *c0,
                _ => 0.0,
            };
            let h = cfg.h.period().unwrap_or(0);
            let last = o.series.records.last().expect("at least one record");
            RatePoint {
                label: format!(
                    "n={} H={h} c0={c0} ω={omega:.3} δ={delta:.3}",
                    cfg.nodes
                ),
                n: cfg.nodes,
                h,
                c0,
                omega,
                delta,
                steps: cfg.steps,
                final_gap: last.opt_gap,
                total_bits: last.bits,
            }
        })
        .collect()
}

/// Run SPARQ on a quadratic with the Theorem-1 learning-rate schedule.
#[allow(clippy::too_many_arguments)]
pub fn run_point(
    n: usize,
    d: usize,
    h: u64,
    c0: f64,
    k_frac: f64,
    topology: TopologyKind,
    steps: u64,
    seed: u64,
) -> RatePoint {
    let k = ((d as f64 * k_frac).round() as usize).clamp(1, d);
    let cfg = point_config(
        n,
        d,
        h,
        c0,
        format!("sign_topk:{k}"),
        topology,
        steps,
        seed,
    );
    let cache = ArtifactCache::new();
    run_points(vec![cfg], &cache).pop().expect("one point")
}

/// Sweep over T to observe the O(1/nT) decay (dominant term). One shared
/// cache: the ring is built and eigen-solved once for the whole sweep.
pub fn t_sweep(n: usize, steps_list: &[u64], seed: u64) -> Vec<RatePoint> {
    let cache = ArtifactCache::new();
    let configs = steps_list
        .iter()
        .map(|&steps| {
            point_config(
                n,
                32,
                5,
                1.0,
                "sign_topk:8".into(),
                TopologyKind::Ring,
                steps,
                seed,
            )
        })
        .collect();
    run_points(configs, &cache)
}

/// Sweep over n at fixed T (distributed 1/n variance gain, Remark 2).
/// Uses the complete graph so the mixing quality is constant across n and
/// the variance term is isolated (on a ring, growing n also shrinks δ,
/// confounding the comparison).
pub fn n_sweep(ns: &[usize], steps: u64, seed: u64) -> Vec<RatePoint> {
    let cache = ArtifactCache::new();
    let configs = ns
        .iter()
        .map(|&n| {
            point_config(
                n,
                32,
                5,
                1.0,
                "sign_topk:8".into(),
                TopologyKind::Complete,
                steps,
                seed,
            )
        })
        .collect();
    run_points(configs, &cache)
}

/// TopK-only variant used by ω ablations (ω = k/d exactly).
pub fn run_point_topk(
    n: usize,
    d: usize,
    h: u64,
    k_frac: f64,
    steps: u64,
    seed: u64,
) -> RatePoint {
    let k = ((d as f64 * k_frac).round() as usize).clamp(1, d);
    let cfg = point_config(
        n,
        d,
        h,
        0.0,
        format!("topk:{k}"),
        TopologyKind::Ring,
        steps,
        seed,
    );
    let cache = ArtifactCache::new();
    let mut point = run_points(vec![cfg], &cache).pop().expect("one point");
    point.label = format!(
        "topk n={n} H={h} ω={:.3} δ={:.3}",
        point.omega, point.delta
    );
    point
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn gap_decreases_with_t() {
        let pts = t_sweep(6, &[200, 2000], 1);
        assert!(
            pts[1].final_gap < pts[0].final_gap,
            "T=200: {}, T=2000: {}",
            pts[0].final_gap,
            pts[1].final_gap
        );
    }

    #[test]
    fn bits_scale_with_h() {
        // Doubling H should roughly halve the number of sync rounds and
        // therefore the bits (trigger off).
        let a = run_point(6, 32, 1, 0.0, 0.25, TopologyKind::Ring, 500, 2);
        let b = run_point(6, 32, 5, 0.0, 0.25, TopologyKind::Ring, 500, 2);
        assert!(b.total_bits * 4 < a.total_bits);
    }

    #[test]
    fn trigger_saves_bits_without_hurting_gap_much() {
        let no_trig = run_point(6, 32, 5, 0.0, 0.25, TopologyKind::Ring, 2000, 3);
        let trig = run_point(6, 32, 5, 2.0, 0.25, TopologyKind::Ring, 2000, 3);
        assert!(trig.total_bits <= no_trig.total_bits);
        // within 5x on the final gap (generous; these are stochastic runs)
        assert!(trig.final_gap < no_trig.final_gap * 5.0 + 1e-3);
    }

    #[test]
    fn sweep_points_share_the_eigen_solve() {
        let cache = ArtifactCache::new();
        let configs = [200u64, 400, 600]
            .iter()
            .map(|&steps| {
                point_config(
                    6,
                    16,
                    5,
                    1.0,
                    "sign_topk:4".into(),
                    TopologyKind::Ring,
                    steps,
                    1,
                )
            })
            .collect();
        let pts = run_points(configs, &cache);
        assert_eq!(pts.len(), 3);
        let (_, spectral_misses) = cache.spectral_stats();
        assert_eq!(spectral_misses, 1, "{}", cache.summary());
        let (_, mixing_misses) = cache.mixing_stats();
        assert_eq!(mixing_misses, 1, "{}", cache.summary());
    }
}
