//! Theorem-1 rate sweeps on the known-optimum quadratic: how the
//! suboptimality after T steps responds to n, H, c₀, ω, δ — the paper's
//! Remark 1 sensitivity analysis, measured.

use crate::comm::Bus;
use crate::compress::{Compressor, SignTopK, TopK};
use crate::coordinator::{DecentralizedAlgo, SparqConfig, SparqSgd};
use crate::graph::{uniform_neighbor, SpectralInfo, Topology, TopologyKind};
use crate::problems::QuadraticProblem;
use crate::schedule::{LrSchedule, SyncSchedule};
use crate::trigger::{EventTrigger, ThresholdSchedule};

/// One sweep point.
#[derive(Clone, Debug)]
pub struct RatePoint {
    pub label: String,
    pub n: usize,
    pub h: u64,
    pub c0: f64,
    pub omega: f64,
    pub delta: f64,
    pub steps: u64,
    pub final_gap: f64,
    pub total_bits: u64,
}

/// Run SPARQ on a quadratic with the Theorem-1 learning-rate schedule.
pub fn run_point(
    n: usize,
    d: usize,
    h: u64,
    c0: f64,
    k_frac: f64,
    topology: TopologyKind,
    steps: u64,
    seed: u64,
) -> RatePoint {
    let topo = Topology::new(topology, n, seed);
    let mixing = uniform_neighbor(&topo);
    let spectral = SpectralInfo::compute(&mixing);
    let k = ((d as f64 * k_frac).round() as usize).clamp(1, d);
    let comp: Box<dyn Compressor> = Box::new(SignTopK::new(k));
    let omega = comp.omega(d);
    let delta = spectral.delta;

    let (mu, l_smooth) = (0.5, 2.0);
    let gamma = spectral.gamma_tuned(omega, comp.effective_omega(d));
    // Practical inverse-time schedule: Theorem 1's a >= 5H/p with the
    // worst-case p makes eta so small that T-sweeps at test scale sit in
    // the pre-asymptotic plateau; the paper's own experiments use
    // eta_t = 1/(t+100)-style tuned schedules (Section 5.1).
    let lr = LrSchedule::InverseTime { a: 60.0, b: 2.0 };
    let _ = (mu, l_smooth);

    let cfg = SparqConfig {
        mixing,
        compressor: comp,
        trigger: EventTrigger::new(if c0 > 0.0 {
            ThresholdSchedule::Poly { c0, eps: 0.5 }
        } else {
            ThresholdSchedule::Zero
        }),
        lr,
        sync: SyncSchedule::EveryH(h),
        gamma: Some(gamma),
        momentum: 0.0,
        seed,
    };
    let mut algo = SparqSgd::new(cfg, d);
    let mut prob = QuadraticProblem::new(d, n, mu, l_smooth, 0.2, 1.0, seed ^ 0xF00D);
    let mut bus = Bus::new(n);
    for t in 0..steps {
        algo.step(t, &mut prob, &mut bus);
    }
    let final_gap = prob.suboptimality(&algo.x_bar());
    RatePoint {
        label: format!("n={n} H={h} c0={c0} ω={omega:.3} δ={delta:.3}"),
        n,
        h,
        c0,
        omega,
        delta,
        steps,
        final_gap,
        total_bits: bus.total_bits,
    }
}

/// Sweep over T to observe the O(1/nT) decay (dominant term).
pub fn t_sweep(n: usize, steps_list: &[u64], seed: u64) -> Vec<RatePoint> {
    steps_list
        .iter()
        .map(|&steps| run_point(n, 32, 5, 1.0, 0.25, TopologyKind::Ring, steps, seed))
        .collect()
}

/// Sweep over n at fixed T (distributed 1/n variance gain, Remark 2).
/// Uses the complete graph so the mixing quality is constant across n and
/// the variance term is isolated (on a ring, growing n also shrinks δ,
/// confounding the comparison).
pub fn n_sweep(ns: &[usize], steps: u64, seed: u64) -> Vec<RatePoint> {
    ns.iter()
        .map(|&n| run_point(n, 32, 5, 1.0, 0.25, TopologyKind::Complete, steps, seed))
        .collect()
}

/// TopK-only variant used by ω ablations (ω = k/d exactly).
pub fn run_point_topk(
    n: usize,
    d: usize,
    h: u64,
    k_frac: f64,
    steps: u64,
    seed: u64,
) -> RatePoint {
    let topo = Topology::new(TopologyKind::Ring, n, seed);
    let mixing = uniform_neighbor(&topo);
    let spectral = SpectralInfo::compute(&mixing);
    let k = ((d as f64 * k_frac).round() as usize).clamp(1, d);
    let comp: Box<dyn Compressor> = Box::new(TopK::new(k));
    let omega = comp.omega(d);
    let gamma = spectral.gamma_tuned(omega, comp.effective_omega(d));
    let lr = LrSchedule::InverseTime { a: 60.0, b: 2.0 };
    let cfg = SparqConfig {
        mixing,
        compressor: comp,
        trigger: EventTrigger::new(ThresholdSchedule::Zero),
        lr,
        sync: SyncSchedule::EveryH(h),
        gamma: Some(gamma),
        momentum: 0.0,
        seed,
    };
    let mut algo = SparqSgd::new(cfg, d);
    let mut prob = QuadraticProblem::new(d, n, 0.5, 2.0, 0.2, 1.0, seed ^ 0xF00D);
    let mut bus = Bus::new(n);
    for t in 0..steps {
        algo.step(t, &mut prob, &mut bus);
    }
    RatePoint {
        label: format!("topk n={n} H={h} ω={omega:.3} δ={:.3}", spectral.delta),
        n,
        h,
        c0: 0.0,
        omega,
        delta: spectral.delta,
        steps,
        final_gap: prob.suboptimality(&algo.x_bar()),
        total_bits: bus.total_bits,
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn gap_decreases_with_t() {
        let pts = t_sweep(6, &[200, 2000], 1);
        assert!(
            pts[1].final_gap < pts[0].final_gap,
            "T=200: {}, T=2000: {}",
            pts[0].final_gap,
            pts[1].final_gap
        );
    }

    #[test]
    fn bits_scale_with_h() {
        // Doubling H should roughly halve the number of sync rounds and
        // therefore the bits (trigger off).
        let a = run_point(6, 32, 1, 0.0, 0.25, TopologyKind::Ring, 500, 2);
        let b = run_point(6, 32, 5, 0.0, 0.25, TopologyKind::Ring, 500, 2);
        assert!(b.total_bits * 4 < a.total_bits);
    }

    #[test]
    fn trigger_saves_bits_without_hurting_gap_much() {
        let no_trig = run_point(6, 32, 5, 0.0, 0.25, TopologyKind::Ring, 2000, 3);
        let trig = run_point(6, 32, 5, 2.0, 0.25, TopologyKind::Ring, 2000, 3);
        assert!(trig.total_bits <= no_trig.total_bits);
        // within 5x on the final gap (generous; these are stochastic runs)
        assert!(trig.final_gap < no_trig.final_gap * 5.0 + 1e-3);
    }
}
