//! Robustness sweep: how the three schemes behave on unreliable fabrics
//! and time-varying topologies (the scenarios `comm::link` and
//! `graph::dynamic` add to the engine).
//!
//! The question the drop sweep answers: SPARQ's event trigger fires on
//! *drift* ‖x^{t+½} − x̂‖², and lost updates leave the sender's estimate
//! advanced while receivers stall — does the trigger keep suppressing
//! broadcasts under loss, or does the growing disagreement force it to
//! fire more? (EXPERIMENTS.md §Robustness records protocol + expected
//! behavior: the transmit rate rises with drop probability, while
//! CHOCO/vanilla — trigger-free — keep transmitting at rate 1 and pay
//! the loss purely as consensus error.)
//!
//! The switch sweep runs SPARQ on `switch:ring,torus:P` against the two
//! static topologies, checking that mid-run re-wiring (with the
//! consensus accumulator rebuilt at each switch) is no worse than the
//! weaker static graph.
//!
//! The chaos sweep (`sparq chaos`, EXPERIMENTS.md §Chaos) runs seeded
//! fault plans — node crash/rejoin windows, partitions, payload
//! corruption — against a fault-free baseline on the same workload and
//! seed, reporting each plan's degradation (final loss relative to the
//! baseline) next to its fault counters (crashes, rejoin resyncs,
//! corrupt copies discarded at the receiver's checksum). Plans are
//! deterministic schedules plus stateless per-(edge, round) corruption
//! coins, so every row is bit-for-bit reproducible for any worker
//! budget.

use crate::comm::FaultCounters;
use crate::config::{Algo, ExperimentConfig};
use crate::metrics::Series;
use crate::sweep::{run_configs, ArtifactCache, SweepOptions};

/// One (algorithm, scenario) measurement.
#[derive(Clone, Debug)]
pub struct RobustnessPoint {
    pub label: String,
    pub algo: Algo,
    /// Per-copy drop probability of the scenario (0 for switch runs).
    pub drop_p: f64,
    pub final_loss: f64,
    pub consensus: f64,
    pub total_bits: u64,
    /// Fraction of trigger checks that transmitted (1.0 for CHOCO/vanilla
    /// up to straggler skips).
    pub transmit_rate: f64,
}

/// Run a scenario list on the sweep engine with the given total worker
/// budget (0 ⇒ available CPUs; shared artifact cache; results identical
/// for any budget), returning each run's series plus the engine's
/// transmit rate.
fn run_scenarios(
    configs: Vec<ExperimentConfig>,
    workers: usize,
) -> (Vec<RobustnessPoint>, Vec<Series>) {
    let cache = ArtifactCache::new();
    let runs: Vec<(String, ExperimentConfig)> = configs
        .into_iter()
        .map(|cfg| (cfg.name.clone(), cfg))
        .collect();
    let opts = SweepOptions {
        workers,
        ..Default::default()
    };
    let report = run_configs(runs, &opts, &cache).expect("robustness sweep runs");
    let mut points = Vec::with_capacity(report.outcomes.len());
    let mut series = Vec::with_capacity(report.outcomes.len());
    for o in report.outcomes {
        let last = o.series.records.last().expect("at least one record");
        points.push(RobustnessPoint {
            label: o.cfg.name.clone(),
            algo: o.cfg.algo.clone(),
            drop_p: 0.0,
            final_loss: last.loss,
            consensus: last.consensus,
            total_bits: last.bits,
            transmit_rate: o.fired as f64 / o.checks.max(1) as f64,
        });
        series.push(o.series);
    }
    (points, series)
}

/// The sweep's shared base workload (small quadratic — the claims under
/// test are about communication behavior, not model quality).
fn base_cfg(steps: u64, seed: u64) -> ExperimentConfig {
    ExperimentConfig {
        name: "robustness".into(),
        nodes: 16,
        steps,
        eval_every: (steps / 20).max(1),
        seed,
        problem: "quadratic:64".into(),
        compressor: "sign_topk:25%".into(),
        trigger: "const:50".into(),
        h: crate::config::SyncSpec::every(2),
        ..Default::default()
    }
}

/// Lossy-link sweep: SPARQ vs CHOCO vs vanilla at each drop probability
/// (one declarative config grid, one engine invocation under the given
/// worker budget).
pub fn drop_sweep(
    steps: u64,
    seed: u64,
    probs: &[f64],
    workers: usize,
) -> (Vec<RobustnessPoint>, Vec<Series>) {
    let mut configs = Vec::new();
    let mut drop_ps = Vec::new();
    for &p in probs {
        for algo in [Algo::Sparq, Algo::Choco, Algo::Vanilla] {
            let mut cfg = base_cfg(steps, seed);
            cfg.algo = algo.clone();
            if p > 0.0 {
                cfg.link = format!("drop:{p}").into();
            }
            cfg.name = format!("robust-{}-drop{p}", algo.as_str());
            configs.push(cfg);
            drop_ps.push(p);
        }
    }
    let (mut points, series) = run_scenarios(configs, workers);
    for (point, p) in points.iter_mut().zip(drop_ps) {
        point.drop_p = p;
    }
    (points, series)
}

/// Time-varying-topology comparison: SPARQ on `switch:ring,torus:P` vs
/// the two static graphs (same workload, same seeds).
pub fn switch_sweep(
    steps: u64,
    seed: u64,
    workers: usize,
) -> (Vec<RobustnessPoint>, Vec<Series>) {
    let period = (steps / 8).max(1);
    let scenarios: [(&str, String, String); 3] = [
        ("robust-static-ring", "static".into(), "ring".into()),
        ("robust-static-torus", "static".into(), "torus".into()),
        (
            "robust-switch-ring-torus",
            format!("switch:ring,torus:{period}"),
            "ring".into(),
        ),
    ];
    let configs = scenarios
        .into_iter()
        .map(|(name, schedule, topology)| {
            let mut cfg = base_cfg(steps, seed);
            cfg.name = name.into();
            cfg.topology = topology.into();
            cfg.topology_schedule = schedule.into();
            cfg
        })
        .collect();
    run_scenarios(configs, workers)
}

/// One fault-plan measurement from the chaos sweep.
#[derive(Clone, Debug)]
pub struct ChaosPoint {
    pub label: String,
    /// The fault-plan spec this run executed ("none" for the baseline).
    pub plan: String,
    pub final_loss: f64,
    pub consensus: f64,
    pub total_bits: u64,
    pub transmit_rate: f64,
    /// Fault totals (all zero for the baseline).
    pub fault: FaultCounters,
    /// Final loss over the fault-free baseline's (1.0 = no degradation).
    pub loss_ratio: f64,
}

/// Chaos grid: the fault-free baseline plus one run per fault plan,
/// identical workload and seed throughout, on the sweep engine under
/// the given worker budget (results identical for any budget). Returns
/// an error for an unparsable plan or one the base config rejects
/// (node index out of range, activation past the horizon).
pub fn chaos_sweep(
    steps: u64,
    seed: u64,
    plans: &[&str],
    workers: usize,
) -> Result<(Vec<ChaosPoint>, Vec<Series>), String> {
    let mut base = base_cfg(steps, seed);
    base.name = "chaos-baseline".into();
    let mut configs = vec![base];
    let mut specs = vec!["none".to_string()];
    for (i, plan) in plans.iter().enumerate() {
        let mut cfg = base_cfg(steps, seed);
        cfg.fault = plan.parse().map_err(|e| format!("plan {plan:?}: {e}"))?;
        cfg.name = format!("chaos-{i}");
        configs.push(cfg);
        specs.push(plan.to_string());
    }
    let cache = ArtifactCache::new();
    let runs: Vec<(String, ExperimentConfig)> = configs
        .into_iter()
        .map(|cfg| (cfg.name.clone(), cfg))
        .collect();
    let opts = SweepOptions {
        workers,
        ..Default::default()
    };
    let report = run_configs(runs, &opts, &cache)?;
    let baseline_loss = report.outcomes[0]
        .series
        .records
        .last()
        .ok_or("baseline produced no records")?
        .loss;
    let mut points = Vec::with_capacity(report.outcomes.len());
    let mut series = Vec::with_capacity(report.outcomes.len());
    for (o, plan) in report.outcomes.into_iter().zip(specs) {
        let last = o.series.records.last().ok_or("run produced no records")?;
        points.push(ChaosPoint {
            label: o.cfg.name.clone(),
            plan,
            final_loss: last.loss,
            consensus: last.consensus,
            total_bits: last.bits,
            transmit_rate: o.fired as f64 / o.checks.max(1) as f64,
            fault: o.fault,
            loss_ratio: if baseline_loss > 0.0 {
                last.loss / baseline_loss
            } else {
                f64::NAN
            },
        });
        series.push(o.series);
    }
    Ok((points, series))
}

/// Formatted chaos comparison: degradation vs baseline next to the
/// fault counters, plan spec last (it can be long).
pub fn chaos_table(points: &[ChaosPoint]) -> String {
    let mut out = String::new();
    out.push_str(&format!(
        "{:<16} {:>12} {:>10} {:>14} {:>8} {:>6} {:>7} {:>8}  {}\n",
        "scenario",
        "final loss",
        "×baseline",
        "bits",
        "tx rate",
        "crash",
        "resync",
        "corrupt",
        "plan"
    ));
    for p in points {
        out.push_str(&format!(
            "{:<16} {:>12.5} {:>10.3} {:>14} {:>7.1}% {:>6} {:>7} {:>8}  {}\n",
            p.label,
            p.final_loss,
            p.loss_ratio,
            p.total_bits,
            100.0 * p.transmit_rate,
            p.fault.crashes,
            p.fault.resyncs,
            p.fault.corrupt_discards,
            p.plan
        ));
    }
    out
}

/// Formatted comparison table.
pub fn table(points: &[RobustnessPoint]) -> String {
    let mut out = String::new();
    out.push_str(&format!(
        "{:<28} {:>6} {:>12} {:>12} {:>14} {:>9}\n",
        "scenario", "drop", "final loss", "consensus", "bits", "tx rate"
    ));
    for p in points {
        out.push_str(&format!(
            "{:<28} {:>6.2} {:>12.5} {:>12.3e} {:>14} {:>8.1}%\n",
            p.label,
            p.drop_p,
            p.final_loss,
            p.consensus,
            p.total_bits,
            100.0 * p.transmit_rate
        ));
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn drop_sweep_runs_and_orders_bits() {
        // workers = 2 also exercises the run-level concurrency path
        let (points, series) = drop_sweep(300, 5, &[0.0, 0.3], 2);
        assert_eq!(points.len(), 6);
        assert_eq!(series.len(), 6);
        assert!(series.iter().all(|s| !s.records.is_empty()));
        let bits = |algo: &Algo, p: f64| {
            points
                .iter()
                .find(|pt| pt.algo == *algo && pt.drop_p == p)
                .unwrap()
                .total_bits
        };
        // fewer delivered copies ⇒ fewer charged bits, for every scheme
        assert!(bits(&Algo::Choco, 0.3) < bits(&Algo::Choco, 0.0));
        assert!(bits(&Algo::Vanilla, 0.3) < bits(&Algo::Vanilla, 0.0));
        // trigger-free schemes transmit at rate 1 regardless of loss
        let choco = points
            .iter()
            .find(|pt| pt.algo == Algo::Choco && pt.drop_p == 0.3)
            .unwrap();
        assert!((choco.transmit_rate - 1.0).abs() < 1e-12);
        // SPARQ's trigger actually suppresses some broadcasts
        let sparq = points
            .iter()
            .find(|pt| pt.algo == Algo::Sparq && pt.drop_p == 0.0)
            .unwrap();
        assert!(sparq.transmit_rate < 1.0);
    }

    #[test]
    fn chaos_sweep_counts_faults_and_degrades_gracefully() {
        // 16-node base config: crash node 3 for 80 rounds, then a
        // separate run with 5% payload corruption (workers = 2 also
        // exercises run-level concurrency under faults)
        let plans = ["crash:3:40:120", "corrupt:0.05"];
        let (points, series) = chaos_sweep(300, 9, &plans, 2).unwrap();
        assert_eq!(points.len(), 3);
        assert_eq!(series.len(), 3);
        // baseline row: fault-free, ratio exactly 1
        assert!(points[0].fault.is_zero());
        assert!((points[0].loss_ratio - 1.0).abs() < 1e-12);
        // crash plan: one crash, rejoin resyncs, no corrupt discards
        assert_eq!(points[1].fault.crashes, 1);
        assert!(points[1].fault.resyncs >= 1);
        assert_eq!(points[1].fault.corrupt_discards, 0);
        // corrupt plan: discards counted, nobody crashed
        assert!(points[2].fault.corrupt_discards > 0);
        assert_eq!(points[2].fault.crashes, 0);
        // graceful degradation: every scenario still optimizes
        for s in &series {
            let first = &s.records[0];
            let last = s.records.last().unwrap();
            assert!(last.loss < first.loss, "{}: no progress", s.label);
        }
        // the table carries the counters and the plan spec
        let t = chaos_table(&points);
        assert!(t.contains("chaos-baseline"), "{t}");
        assert!(t.contains("crash:3:40:120"), "{t}");
        // bad plans surface as errors, not panics
        assert!(chaos_sweep(300, 9, &["crash:3:40"], 1).is_err());
        assert!(chaos_sweep(300, 9, &["crash:99:40:120"], 1).is_err());
    }

    #[test]
    fn switch_sweep_emits_three_series() {
        let (points, series) = switch_sweep(320, 7, 1);
        assert_eq!(points.len(), 3);
        assert!(series.iter().all(|s| s.records.len() >= 2));
        // every scenario optimizes
        for s in &series {
            let first = &s.records[0];
            let last = s.records.last().unwrap();
            assert!(last.loss < first.loss, "{}: no progress", s.label);
        }
    }
}
