//! Remark 4: theoretical + measured communication-savings comparison.
//!
//! For a fixed bit budget, T CHOCO rounds correspond to T·H SPARQ
//! iterations (H local steps per transmission), so at equal transmitted
//! bits SPARQ has executed H× more SGD steps. The measured counterpart:
//! run both to the same target error and compare cumulative bits.

use crate::metrics::Series;

/// Bits each algorithm spent to first reach `target_err`, as
/// (label, bits, comm_rounds); series that never reach it are `None`.
pub fn bits_to_target(series: &[Series], target_err: f64) -> Vec<(String, Option<(u64, u64)>)> {
    series
        .iter()
        .map(|s| {
            (
                s.label.clone(),
                s.first_reaching_error(target_err)
                    .map(|r| (r.bits, r.comm_rounds)),
            )
        })
        .collect()
}

/// Savings factor of `a` over `b` (b.bits / a.bits) at the target error.
pub fn savings_factor(series: &[Series], a: usize, b: usize, target_err: f64) -> Option<f64> {
    let ra = series[a].first_reaching_error(target_err)?;
    let rb = series[b].first_reaching_error(target_err)?;
    if ra.bits == 0 {
        return None;
    }
    Some(rb.bits as f64 / ra.bits as f64)
}

/// Remark 4's closed-form comparison for the convex case: suboptimality
/// bounds after spending the same number of communication rounds R.
/// CHOCO: O(1/(μ n R)); SPARQ with H local steps: O(1/(μ n H R)).
pub fn remark4_bound_ratio(h: u64) -> f64 {
    h as f64
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::metrics::RoundRecord;

    fn series(label: &str, pts: &[(u64, f64, u64)]) -> Series {
        let mut s = Series::new(label);
        for &(t, err, bits) in pts {
            s.push(RoundRecord {
                t,
                loss: err,
                test_error: err,
                opt_gap: f64::NAN,
                bits,
                comm_rounds: t,
                consensus: 0.0,
                fired: 0,
            });
        }
        s
    }

    #[test]
    fn factors() {
        let a = series("sparq", &[(0, 1.0, 0), (10, 0.1, 100)]);
        let b = series("vanilla", &[(0, 1.0, 0), (10, 0.1, 100_000)]);
        let all = vec![a, b];
        assert_eq!(savings_factor(&all, 0, 1, 0.1), Some(1000.0));
        let t = bits_to_target(&all, 0.1);
        assert_eq!(t[0].1, Some((100, 10)));
    }

    #[test]
    fn unreached_target_is_none() {
        let a = series("x", &[(0, 1.0, 0)]);
        assert_eq!(bits_to_target(&[a], 0.5)[0].1, None);
    }

    #[test]
    fn remark4() {
        assert_eq!(remark4_bound_ratio(5), 5.0);
    }
}
