//! Remark 4: theoretical + measured communication-savings comparison.
//!
//! For a fixed bit budget, T CHOCO rounds correspond to T·H SPARQ
//! iterations (H local steps per transmission), so at equal transmitted
//! bits SPARQ has executed H× more SGD steps. The measured counterpart:
//! run both to the same target error and compare cumulative bits.

use crate::config::{Algo, ExperimentConfig};
use crate::metrics::Series;
use crate::sweep::{run_configs, ArtifactCache, SweepOptions};

/// Remark 4 *measured*: run SPARQ (H local steps, trigger on) and CHOCO
/// (H = 1, no trigger) on the same workload through the sweep engine and
/// return the two series — feed them to [`bits_to_target`] /
/// [`savings_factor`] for the measured counterpart of
/// [`remark4_bound_ratio`]. The pair is a declarative two-config sweep;
/// topology and dataset artifacts are shared.
pub fn remark4_measured(steps: u64, h: u64, seed: u64) -> (Series, Series) {
    let base = ExperimentConfig {
        name: "remark4".into(),
        nodes: 8,
        steps,
        eval_every: (steps / 20).max(1),
        seed,
        problem: "quadratic:64".into(),
        compressor: "sign_topk:25%".into(),
        trigger: "const:50".into(),
        h: h.into(),
        ..Default::default()
    };
    let sparq = ExperimentConfig {
        name: format!("remark4-sparq-h{h}"),
        ..base.clone()
    };
    let choco = ExperimentConfig {
        name: "remark4-choco".into(),
        algo: Algo::Choco,
        h: 1u64.into(),
        trigger: "zero".into(),
        ..base
    };
    let cache = ArtifactCache::new();
    let report = run_configs(
        vec![
            ("SPARQ-SGD".to_string(), sparq),
            ("CHOCO-SGD".to_string(), choco),
        ],
        &SweepOptions::default(),
        &cache,
    )
    .expect("remark4 sweep runs");
    let mut it = report.outcomes.into_iter();
    let a = it.next().expect("sparq outcome").series;
    let b = it.next().expect("choco outcome").series;
    (a, b)
}

/// Bits each algorithm spent to first reach `target_err`, as
/// (label, bits, comm_rounds); series that never reach it are `None`.
pub fn bits_to_target(series: &[Series], target_err: f64) -> Vec<(String, Option<(u64, u64)>)> {
    series
        .iter()
        .map(|s| {
            (
                s.label.clone(),
                s.first_reaching_error(target_err)
                    .map(|r| (r.bits, r.comm_rounds)),
            )
        })
        .collect()
}

/// Savings factor of `a` over `b` (b.bits / a.bits) at the target error.
pub fn savings_factor(series: &[Series], a: usize, b: usize, target_err: f64) -> Option<f64> {
    let ra = series[a].first_reaching_error(target_err)?;
    let rb = series[b].first_reaching_error(target_err)?;
    if ra.bits == 0 {
        return None;
    }
    Some(rb.bits as f64 / ra.bits as f64)
}

/// Remark 4's closed-form comparison for the convex case: suboptimality
/// bounds after spending the same number of communication rounds R.
/// CHOCO: O(1/(μ n R)); SPARQ with H local steps: O(1/(μ n H R)).
pub fn remark4_bound_ratio(h: u64) -> f64 {
    h as f64
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::metrics::RoundRecord;

    fn series(label: &str, pts: &[(u64, f64, u64)]) -> Series {
        let mut s = Series::new(label);
        for &(t, err, bits) in pts {
            s.push(RoundRecord {
                t,
                loss: err,
                test_error: err,
                opt_gap: f64::NAN,
                bits,
                comm_rounds: t,
                consensus: 0.0,
                fired: 0,
            });
        }
        s
    }

    #[test]
    fn factors() {
        let a = series("sparq", &[(0, 1.0, 0), (10, 0.1, 100)]);
        let b = series("vanilla", &[(0, 1.0, 0), (10, 0.1, 100_000)]);
        let all = vec![a, b];
        assert_eq!(savings_factor(&all, 0, 1, 0.1), Some(1000.0));
        let t = bits_to_target(&all, 0.1);
        assert_eq!(t[0].1, Some((100, 10)));
    }

    #[test]
    fn unreached_target_is_none() {
        let a = series("x", &[(0, 1.0, 0)]);
        assert_eq!(bits_to_target(&[a], 0.5)[0].1, None);
    }

    #[test]
    fn remark4() {
        assert_eq!(remark4_bound_ratio(5), 5.0);
    }

    #[test]
    fn remark4_measured_sparq_beats_choco_on_bits() {
        // The measured counterpart of the closed-form comparison: at the
        // same loss target SPARQ (H = 2, triggered) spends fewer bits
        // than CHOCO (H = 1, always-transmit).
        let (sparq, choco) = remark4_measured(800, 2, 7);
        assert!(!sparq.records.is_empty() && !choco.records.is_empty());
        // pick a target both runs reach: the worse of the two final losses
        let target = sparq
            .records
            .last()
            .unwrap()
            .loss
            .max(choco.records.last().unwrap().loss)
            * 1.02;
        let sb = sparq.first_reaching_loss(target).expect("sparq reaches").bits;
        let cb = choco.first_reaching_loss(target).expect("choco reaches").bits;
        assert!(sb < cb, "SPARQ bits {sb} !< CHOCO bits {cb}");
    }
}
