//! Link-level fault models for the simulated graph.
//!
//! The seed `Bus` was a perfect synchronous fabric: every broadcast
//! reached every neighbor, every round. Real decentralized deployments
//! (the EventGraD [GHG21] setting) face two failure axes the algorithms
//! must tolerate:
//!
//! * **message drop** — each (sender, receiver) copy of a broadcast is
//!   lost independently with probability p (`drop:p`);
//! * **stragglers** — a configured node misses whole sync rounds with
//!   probability p (`straggler:i:p`), behaving as if its trigger had not
//!   fired (nothing transmitted, nothing charged, drift persists).
//!
//! Faults are *stateless and seeded*: every coin is a splitmix64 hash of
//! `(seed, kind, endpoints, t)`, so outcomes are reproducible, independent
//! of evaluation order, and — critically — bit-for-bit identical across
//! worker-thread counts (the engine's parallel phases may consult the
//! model from any thread without sharing RNG state).
//!
//! Bits are charged only for *delivered* copies: a broadcast that loses
//! `k` of its `deg` copies costs `(deg − k) · message_bits` on the bus.
//! The default [`LinkModel::ideal`] short-circuits every check, so
//! configurations without a `link` spec reproduce the seed behavior
//! exactly.

use crate::util::rng::splitmix64;

/// Domain-separation tags so drop and straggler coins never collide.
const TAG_DROP: u64 = 0x4C49_4E4B_4452_4F50; // "LINKDROP"
const TAG_STRAGGLE: u64 = 0x4C49_4E4B_5354_5247; // "LINKSTRG"

/// Seeded link-fault model. Plain data — cloning or sharing across
/// threads is free, and identical configurations always produce
/// identical fault patterns.
#[derive(Clone, Debug, PartialEq)]
pub struct LinkModel {
    /// Per-copy drop probability in [0, 1).
    pub drop_p: f64,
    /// (node, skip probability) straggler list.
    pub stragglers: Vec<(usize, f64)>,
    /// Fault-stream seed (independent of the model/data seeds).
    pub seed: u64,
}

impl LinkModel {
    /// The loss-free default: no drops, no stragglers.
    pub fn ideal() -> LinkModel {
        LinkModel {
            drop_p: 0.0,
            stragglers: Vec::new(),
            seed: 0,
        }
    }

    /// True when no fault can ever occur (the engine takes the seed fast
    /// path: one `charge_broadcast` per sender, no per-edge coins).
    pub fn is_ideal(&self) -> bool {
        self.drop_p == 0.0 && self.stragglers.is_empty()
    }

    /// Parse a link spec: `none`, `drop:P`, `straggler:I:P`, or several
    /// segments joined with `+` (e.g. `drop:0.1+straggler:0:0.5`).
    pub fn parse(spec: &str, seed: u64) -> Result<LinkModel, String> {
        let mut model = LinkModel {
            seed: seed ^ 0x96C3_A4F1_0D5B_7E29,
            ..LinkModel::ideal()
        };
        if spec.is_empty() || spec == "none" || spec == "ideal" {
            return Ok(model);
        }
        for seg in spec.split('+') {
            let parts: Vec<&str> = seg.split(':').collect();
            match parts.as_slice() {
                ["drop", p] => {
                    let p: f64 = p
                        .parse()
                        .map_err(|_| format!("drop probability {p:?} is not a number"))?;
                    if !p.is_finite() || !(0.0..1.0).contains(&p) {
                        return Err(format!("drop probability must be in [0, 1), got {p}"));
                    }
                    model.drop_p = p;
                }
                ["straggler", i, p] => {
                    let i: usize = i
                        .parse()
                        .map_err(|_| format!("straggler node {i:?} is not an index"))?;
                    let p: f64 = p
                        .parse()
                        .map_err(|_| format!("straggler probability {p:?} is not a number"))?;
                    if !p.is_finite() || !(0.0..1.0).contains(&p) {
                        return Err(format!(
                            "straggler probability must be in [0, 1), got {p}"
                        ));
                    }
                    model.stragglers.push((i, p));
                }
                _ => {
                    return Err(format!(
                        "unknown link segment {seg:?}; expected none, drop:P, or straggler:I:P"
                    ))
                }
            }
        }
        Ok(model)
    }

    /// One seeded coin: uniform in [0, 1) from a hash of the arguments.
    fn coin(&self, tag: u64, a: u64, b: u64, t: u64) -> f64 {
        let mut s = self
            .seed
            .wrapping_add(tag)
            .wrapping_add(a.wrapping_mul(0x9E37_79B9_7F4A_7C15))
            .wrapping_add(b.wrapping_mul(0xC2B2_AE3D_27D4_EB4F))
            .wrapping_add(t.wrapping_mul(0x1656_67B1_9E37_79F9));
        (splitmix64(&mut s) >> 11) as f64 * (1.0 / (1u64 << 53) as f64)
    }

    /// Does node `i` sit out the sync round at iteration t?
    pub fn straggles(&self, i: usize, t: u64) -> bool {
        self.stragglers
            .iter()
            .any(|&(node, p)| node == i && self.coin(TAG_STRAGGLE, i as u64, 0, t) < p)
    }

    /// Is the `from → to` copy of iteration t's broadcast delivered?
    pub fn delivers(&self, from: usize, to: usize, t: u64) -> bool {
        self.drop_p == 0.0 || self.coin(TAG_DROP, from as u64, to as u64, t) >= self.drop_p
    }

    /// Human-readable spec (round-trips through [`parse`] semantics).
    pub fn describe(&self) -> String {
        if self.is_ideal() {
            return "none".into();
        }
        let mut parts = Vec::new();
        if self.drop_p > 0.0 {
            parts.push(format!("drop:{}", self.drop_p));
        }
        for &(i, p) in &self.stragglers {
            parts.push(format!("straggler:{i}:{p}"));
        }
        parts.join("+")
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn ideal_never_faults() {
        let m = LinkModel::ideal();
        assert!(m.is_ideal());
        for t in 0..50 {
            assert!(m.delivers(0, 1, t));
            assert!(!m.straggles(0, t));
        }
    }

    #[test]
    fn parse_specs() {
        assert!(LinkModel::parse("none", 1).unwrap().is_ideal());
        assert!(LinkModel::parse("", 1).unwrap().is_ideal());
        let m = LinkModel::parse("drop:0.25", 1).unwrap();
        assert_eq!(m.drop_p, 0.25);
        let m = LinkModel::parse("drop:0.1+straggler:3:0.5", 1).unwrap();
        assert_eq!(m.drop_p, 0.1);
        assert_eq!(m.stragglers, vec![(3, 0.5)]);
        assert_eq!(m.describe(), "drop:0.1+straggler:3:0.5");
        assert!(LinkModel::parse("drop:1.5", 1).is_err());
        assert!(LinkModel::parse("drop:-0.1", 1).is_err());
        assert!(LinkModel::parse("straggler:0:2", 1).is_err());
        assert!(LinkModel::parse("wat:1", 1).is_err());
    }

    #[test]
    fn drops_are_deterministic_and_order_free() {
        let m = LinkModel::parse("drop:0.3", 7).unwrap();
        let pattern: Vec<bool> = (0..200)
            .map(|t| m.delivers(t as usize % 5, (t as usize + 1) % 5, t))
            .collect();
        // same model, queries in reverse order — identical outcomes
        let m2 = LinkModel::parse("drop:0.3", 7).unwrap();
        let reversed: Vec<bool> = (0..200)
            .rev()
            .map(|t| m2.delivers(t as usize % 5, (t as usize + 1) % 5, t))
            .collect();
        let mut fwd = pattern.clone();
        fwd.reverse();
        assert_eq!(fwd, reversed);
        // and the empirical rate is in the right ballpark
        let delivered = pattern.iter().filter(|&&b| b).count();
        assert!((110..=170).contains(&delivered), "delivered {delivered}/200");
    }

    #[test]
    fn different_seeds_differ() {
        let a = LinkModel::parse("drop:0.5", 1).unwrap();
        let b = LinkModel::parse("drop:0.5", 2).unwrap();
        let pa: Vec<bool> = (0..64).map(|t| a.delivers(0, 1, t)).collect();
        let pb: Vec<bool> = (0..64).map(|t| b.delivers(0, 1, t)).collect();
        assert_ne!(pa, pb);
    }

    #[test]
    fn straggler_only_affects_configured_node() {
        let m = LinkModel::parse("straggler:2:0.9", 5).unwrap();
        assert!((0..100).all(|t| !m.straggles(0, t)));
        let skipped = (0..100).filter(|&t| m.straggles(2, t)).count();
        assert!(skipped > 70, "straggler skipped only {skipped}/100");
        // drops unaffected by a straggler-only model
        assert!((0..100).all(|t| m.delivers(2, 3, t)));
    }

    #[test]
    fn identical_seeds_reproduce_identical_coin_sequences() {
        // Two independently parsed models with the same (spec, seed) are
        // the same fault process: every drop AND straggler coin agrees,
        // in any query order, from any thread's interleaving (the coins
        // are stateless hashes, so query order cannot matter).
        let spec = "drop:0.35+straggler:1:0.4+straggler:3:0.2";
        let a = LinkModel::parse(spec, 99).unwrap();
        let b = LinkModel::parse(spec, 99).unwrap();
        for t in 0..300 {
            for from in 0..5 {
                for to in 0..5 {
                    if from != to {
                        assert_eq!(
                            a.delivers(from, to, t),
                            b.delivers(from, to, t),
                            "drop coin ({from}->{to}, t={t})"
                        );
                    }
                }
                assert_eq!(
                    a.straggles(from, t),
                    b.straggles(from, t),
                    "straggler coin ({from}, t={t})"
                );
            }
        }
        // and a clone is the same process too (plain data)
        let c = a.clone();
        assert!((0..300).all(|t| c.delivers(0, 1, t) == a.delivers(0, 1, t)));
    }

    #[test]
    fn delivered_set_shrinks_pointwise_as_p_grows() {
        // The coin value for an edge/round is independent of p (only the
        // threshold moves), so the delivered set at a larger p is a
        // subset of the delivered set at a smaller p — the mechanism
        // behind the engine-level bits-monotone-in-p test.
        let ps = [0.0, 0.2, 0.5, 0.8];
        let models: Vec<LinkModel> = ps
            .iter()
            .map(|p| LinkModel::parse(&format!("drop:{p}"), 13).unwrap())
            .collect();
        for t in 0..200 {
            for from in 0..4 {
                for to in 0..4 {
                    if from == to {
                        continue;
                    }
                    for w in models.windows(2) {
                        // delivered at higher p ⇒ delivered at lower p
                        if w[1].delivers(from, to, t) {
                            assert!(
                                w[0].delivers(from, to, t),
                                "({from}->{to}, t={t}): delivered at p={} but not p={}",
                                w[1].drop_p,
                                w[0].drop_p
                            );
                        }
                    }
                }
            }
        }
    }

    #[test]
    fn edge_directions_are_independent_coins() {
        let m = LinkModel::parse("drop:0.5", 11).unwrap();
        let fwd: Vec<bool> = (0..64).map(|t| m.delivers(0, 1, t)).collect();
        let rev: Vec<bool> = (0..64).map(|t| m.delivers(1, 0, t)).collect();
        assert_ne!(fwd, rev);
    }
}
