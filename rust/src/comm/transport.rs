//! The transport seam between the engine and the world.
//!
//! Every sync round, the engine's update rule walks the fired nodes in
//! deterministic order and applies each broadcast to the replicated
//! state. In-process that is the whole story: the [`Bus`] charges the
//! bits and the message never exists as bytes. The cluster runtime
//! (`crate::cluster`) runs the *same* engine in N OS processes — every
//! process holds a full replica of the deterministic n-node state, and
//! the only thing that must physically travel is each rank's own
//! broadcast. [`Transport`] is that seam:
//!
//! * [`LocalTransport`] (the default) does nothing — the engine is
//!   exactly the in-process simulator, bit for bit.
//! * `cluster::SocketTransport` sends rank r's broadcast as a CRC-framed
//!   `comm::wire::encode_sparse` payload to its live neighbors and, for
//!   a neighbor's broadcast, receives + decodes the frame and returns
//!   the decoded message for *substitution* into the local replica.
//!
//! The substitution contract is what makes the socket runtime
//! bit-identical to the simulator: `decode_sparse(encode_sparse(q)) ==
//! q` exactly (f32 bits round-trip losslessly — pinned by the wire
//! tests), so substituting the received copy changes nothing except
//! that the bytes really crossed a socket. Charged bits stay
//! `Compressor::message_bits` — the frame's 8-byte CRC armor is
//! transport overhead, accounted separately by the socket layer.
//!
//! [`Bus`]: crate::comm::Bus

use crate::compress::SparseVec;

/// How a sync-round broadcast physically travels (see module docs).
///
/// Called once per *transmitting* node per sync round, in the
/// deterministic node order of the update rule's charge loop, with the
/// live-subgraph neighbor list in force at `t`. The implementation
/// decides its role from `from`:
///
/// * `from == self rank` ⇒ send `q` to every neighbor; return `None`.
/// * `self rank ∈ neighbors` ⇒ receive sender `from`'s copy; return
///   `Some(decoded)` to substitute it for the locally computed `q`
///   (or `None` to fall back to the local copy).
/// * otherwise ⇒ not an edge this process participates in; return
///   `None` (the local replica already computed the message).
pub trait Transport: Send {
    /// Exchange one broadcast (see trait docs). `d` is the model
    /// dimension the sparse codec needs for index widths.
    fn exchange(
        &mut self,
        t: u64,
        from: usize,
        q: &SparseVec,
        d: usize,
        neighbors: &[usize],
    ) -> Option<SparseVec>;

    /// Human-readable description for logs.
    fn describe(&self) -> String {
        "local".into()
    }
}

/// The in-process no-op transport: every message stays a local
/// computation over the in-memory state, exactly as before the
/// transport seam existed.
pub struct LocalTransport;

impl Transport for LocalTransport {
    fn exchange(
        &mut self,
        _t: u64,
        _from: usize,
        _q: &SparseVec,
        _d: usize,
        _neighbors: &[usize],
    ) -> Option<SparseVec> {
        None
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn local_transport_never_substitutes() {
        let mut t = LocalTransport;
        let q = SparseVec::from_dense(&[0.0, 1.5, 0.0, -2.0]);
        assert!(t.exchange(7, 0, &q, 4, &[1, 2]).is_none());
        assert_eq!(t.describe(), "local");
    }
}
