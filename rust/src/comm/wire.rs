//! Wire format: actual bit-packed encodings for the compressed messages.
//!
//! `Compressor::encoded_bits` promises a per-message cost; this module
//! *implements* those encodings, so the accounting is backed by a real
//! codec rather than a formula: `encode → decode` round-trips to the
//! exact dense reconstruction, and the encoded length matches the charged
//! bits (tested in both this module and `rust/tests/properties.rs`).
//!
//! Decoding is fully fallible: a truncated buffer, an over-declared
//! entry count, or an out-of-range coordinate index yields a
//! [`WireError`] instead of a panic or a silently-garbage vector. For
//! transport over an untrusted byte stream, [`frame`] wraps a payload in
//! a `[len:u32 LE][crc32:u32 LE]` header and [`unframe`] verifies both
//! before handing the payload to a decoder — a corrupted copy is
//! *detected* and treated as a drop, never decoded into the consensus
//! step. Frame overhead is transport armor, not message content, so the
//! simulation's bit accounting (`Compressor::message_bits`) deliberately
//! excludes the 64-bit header.

use std::fmt;

use crate::compress::{index_bits, SparseVec};

/// Why a buffer failed to decode. Every variant is a *detected* fault:
/// callers count the copy as dropped instead of consuming garbage.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum WireError {
    /// A read ran past the end of the buffer.
    Truncated {
        /// Bits the read needed in total.
        need: u64,
        /// Bits the buffer holds.
        have: u64,
    },
    /// Frame checksum mismatch — the payload was corrupted in flight.
    Checksum { stored: u32, computed: u32 },
    /// Frame length field disagrees with the bytes actually present.
    Length { declared: usize, actual: usize },
    /// A decoded coordinate index is out of range for the dimension.
    Index { idx: usize, d: usize },
    /// A declared entry count exceeds the dimension.
    Count { nnz: usize, d: usize },
}

impl fmt::Display for WireError {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        match *self {
            WireError::Truncated { need, have } => {
                write!(f, "truncated buffer: need {need} bits, have {have}")
            }
            WireError::Checksum { stored, computed } => write!(
                f,
                "checksum mismatch: frame says {stored:#010x}, payload hashes to {computed:#010x}"
            ),
            WireError::Length { declared, actual } => write!(
                f,
                "length mismatch: frame declares {declared} payload bytes, {actual} present"
            ),
            WireError::Index { idx, d } => {
                write!(f, "coordinate index {idx} out of range for dimension {d}")
            }
            WireError::Count { nnz, d } => {
                write!(f, "entry count {nnz} exceeds dimension {d}")
            }
        }
    }
}

impl std::error::Error for WireError {}

/// LSB-first bit writer.
#[derive(Default)]
pub struct BitWriter {
    buf: Vec<u8>,
    /// Bits used in the last byte (0 ⇒ byte boundary).
    nbits: u64,
}

impl BitWriter {
    pub fn new() -> Self {
        Self::default()
    }

    pub fn write_bits(&mut self, value: u64, bits: u32) {
        debug_assert!(bits <= 64);
        for i in 0..bits {
            let bit = (value >> i) & 1;
            let pos = self.nbits % 8;
            if pos == 0 {
                self.buf.push(0);
            }
            if bit == 1 {
                *self.buf.last_mut().unwrap() |= 1 << pos;
            }
            self.nbits += 1;
        }
    }

    pub fn write_f32(&mut self, x: f32) {
        self.write_bits(x.to_bits() as u64, 32);
    }

    pub fn bit_len(&self) -> u64 {
        self.nbits
    }

    pub fn into_bytes(self) -> Vec<u8> {
        self.buf
    }
}

/// LSB-first bit reader. All reads are bounds-checked: running off the
/// end of the buffer is a [`WireError::Truncated`], never a panic.
pub struct BitReader<'a> {
    buf: &'a [u8],
    pos: u64,
}

impl<'a> BitReader<'a> {
    pub fn new(buf: &'a [u8]) -> Self {
        BitReader { buf, pos: 0 }
    }

    pub fn read_bits(&mut self, bits: u32) -> Result<u64, WireError> {
        debug_assert!(bits <= 64);
        let have = self.buf.len() as u64 * 8;
        let need = self.pos + bits as u64;
        if need > have {
            return Err(WireError::Truncated { need, have });
        }
        let mut out = 0u64;
        for i in 0..bits {
            let byte = (self.pos / 8) as usize;
            let off = self.pos % 8;
            let bit = (self.buf[byte] >> off) & 1;
            out |= (bit as u64) << i;
            self.pos += 1;
        }
        Ok(out)
    }

    pub fn read_f32(&mut self) -> Result<f32, WireError> {
        Ok(f32::from_bits(self.read_bits(32)? as u32))
    }
}

/// CRC-32 (IEEE 802.3, reflected polynomial 0xEDB88320) — the checksum
/// guarding [`frame`]d payloads. Bitwise, table-free: framing is not on
/// the simulation hot path, and dependency-free beats fast here.
pub fn crc32(bytes: &[u8]) -> u32 {
    let mut crc = !0u32;
    for &b in bytes {
        crc ^= b as u32;
        for _ in 0..8 {
            let mask = (crc & 1).wrapping_neg();
            crc = (crc >> 1) ^ (0xEDB8_8320 & mask);
        }
    }
    !crc
}

/// Bytes the frame header adds on top of the payload.
pub const FRAME_OVERHEAD: usize = 8;

/// Wrap a payload for transport: `[len:u32 LE][crc32:u32 LE][payload]`.
pub fn frame(payload: &[u8]) -> Vec<u8> {
    let mut out = Vec::with_capacity(FRAME_OVERHEAD + payload.len());
    out.extend_from_slice(&(payload.len() as u32).to_le_bytes());
    out.extend_from_slice(&crc32(payload).to_le_bytes());
    out.extend_from_slice(payload);
    out
}

/// Verify a [`frame`]d buffer and return the payload slice. Any header
/// damage shows up as a length mismatch; any payload damage (and header
/// damage that keeps the length plausible) fails the checksum.
pub fn unframe(bytes: &[u8]) -> Result<&[u8], WireError> {
    if bytes.len() < FRAME_OVERHEAD {
        return Err(WireError::Truncated {
            need: FRAME_OVERHEAD as u64 * 8,
            have: bytes.len() as u64 * 8,
        });
    }
    let declared = u32::from_le_bytes(bytes[0..4].try_into().unwrap()) as usize;
    let payload = &bytes[FRAME_OVERHEAD..];
    if declared != payload.len() {
        return Err(WireError::Length {
            declared,
            actual: payload.len(),
        });
    }
    let stored = u32::from_le_bytes(bytes[4..8].try_into().unwrap());
    let computed = crc32(payload);
    if stored != computed {
        return Err(WireError::Checksum { stored, computed });
    }
    Ok(payload)
}

/// Exact bit length of [`encode_topk`]/[`encode_topk_sparse`] for a
/// message with `nnz` stored nonzeros at dimension d — the hot path
/// charges this (via `Compressor::message_bits`) without materializing
/// any bytes.
pub fn topk_bits(nnz: usize, d: usize) -> u64 {
    nnz as u64 * (32 + index_bits(d))
}

/// Exact bit length of [`encode_sign_topk`]/[`encode_sign_topk_sparse`].
pub fn sign_topk_bits(nnz: usize, d: usize) -> u64 {
    32 + nnz as u64 * (1 + index_bits(d))
}

/// Encoded SignTopK message: k (index, sign) pairs + one f32 scale.
/// Matches `SignTopK::encoded_bits` (honest accounting) exactly.
pub fn encode_sign_topk(q: &[f32]) -> Vec<u8> {
    let d = q.len();
    let ib = index_bits(d) as u32;
    let mut w = BitWriter::new();
    let nz: Vec<usize> = (0..d).filter(|&i| q[i] != 0.0).collect();
    let scale = nz.first().map(|&i| q[i].abs()).unwrap_or(0.0);
    w.write_f32(scale);
    for &i in &nz {
        w.write_bits(i as u64, ib);
        w.write_bits((q[i] < 0.0) as u64, 1);
    }
    w.into_bytes()
}

/// Encode a sparse SignTopK message without densifying — bit-identical to
/// [`encode_sign_topk`] of its dense form (entries are stored in index
/// order, exactly the order the dense encoder scans).
pub fn encode_sign_topk_sparse(q: &SparseVec, d: usize) -> Vec<u8> {
    let ib = index_bits(d) as u32;
    let mut w = BitWriter::new();
    let scale = q.val.first().map(|v| v.abs()).unwrap_or(0.0);
    w.write_f32(scale);
    for (i, v) in q.iter() {
        w.write_bits(i as u64, ib);
        w.write_bits((v < 0.0) as u64, 1);
    }
    w.into_bytes()
}

/// Decode into a dense vector of dimension d with `k` nonzeros.
pub fn decode_sign_topk(bytes: &[u8], d: usize, k: usize) -> Result<Vec<f32>, WireError> {
    let ib = index_bits(d) as u32;
    let mut r = BitReader::new(bytes);
    let scale = r.read_f32()?;
    if k > d {
        return Err(WireError::Count { nnz: k, d });
    }
    let mut out = vec![0.0f32; d];
    for _ in 0..k {
        let idx = r.read_bits(ib)? as usize;
        let neg = r.read_bits(1)? == 1;
        if idx >= d {
            return Err(WireError::Index { idx, d });
        }
        out[idx] = if neg { -scale } else { scale };
    }
    Ok(out)
}

/// Encoded TopK message: k (index, f32 value) pairs.
pub fn encode_topk(q: &[f32]) -> Vec<u8> {
    let d = q.len();
    let ib = index_bits(d) as u32;
    let mut w = BitWriter::new();
    for (i, &v) in q.iter().enumerate() {
        if v != 0.0 {
            w.write_bits(i as u64, ib);
            w.write_f32(v);
        }
    }
    w.into_bytes()
}

pub fn decode_topk(bytes: &[u8], d: usize, k: usize) -> Result<Vec<f32>, WireError> {
    let ib = index_bits(d) as u32;
    let mut r = BitReader::new(bytes);
    if k > d {
        return Err(WireError::Count { nnz: k, d });
    }
    let mut out = vec![0.0f32; d];
    for _ in 0..k {
        let idx = r.read_bits(ib)? as usize;
        let val = r.read_f32()?;
        if idx >= d {
            return Err(WireError::Index { idx, d });
        }
        out[idx] = val;
    }
    Ok(out)
}

/// Encode a sparse TopK message without densifying — bit-identical to
/// [`encode_topk`] of its dense form.
pub fn encode_topk_sparse(q: &SparseVec, d: usize) -> Vec<u8> {
    let ib = index_bits(d) as u32;
    let mut w = BitWriter::new();
    for (i, v) in q.iter() {
        w.write_bits(i as u64, ib);
        w.write_f32(v);
    }
    w.into_bytes()
}

/// Decode a TopK payload straight into sparse form (k entries).
pub fn decode_topk_sparse(bytes: &[u8], d: usize, k: usize) -> Result<SparseVec, WireError> {
    let ib = index_bits(d) as u32;
    let mut r = BitReader::new(bytes);
    if k > d {
        return Err(WireError::Count { nnz: k, d });
    }
    let mut out = SparseVec::with_capacity(k);
    for _ in 0..k {
        let idx = r.read_bits(ib)? as usize;
        let val = r.read_f32()?;
        if idx >= d {
            return Err(WireError::Index { idx, d });
        }
        out.push(idx as u32, val);
    }
    Ok(out)
}

/// Encoded Sign(ℓ1) message: d sign bits + one f32 scale.
pub fn encode_sign(q: &[f32]) -> Vec<u8> {
    let mut w = BitWriter::new();
    let scale = q.first().map(|v| v.abs()).unwrap_or(0.0);
    w.write_f32(scale);
    for &v in q {
        w.write_bits((v < 0.0) as u64, 1);
    }
    w.into_bytes()
}

pub fn decode_sign(bytes: &[u8], d: usize) -> Result<Vec<f32>, WireError> {
    let mut r = BitReader::new(bytes);
    let scale = r.read_f32()?;
    let mut out = Vec::with_capacity(d);
    for _ in 0..d {
        out.push(if r.read_bits(1)? == 1 { -scale } else { scale });
    }
    Ok(out)
}

/// Self-describing sparse codec, usable for *any* compressor's output:
/// `[nnz:32][(idx:index_bits(d), val:f32) × nnz]`. Unlike the
/// per-operator codecs above, the entry count travels in-band, so a
/// framed `encode_sparse` payload decodes with no side channel — the
/// shape every message takes on a real transport.
pub fn encode_sparse(q: &SparseVec, d: usize) -> Vec<u8> {
    let ib = index_bits(d) as u32;
    let mut w = BitWriter::new();
    w.write_bits(q.nnz() as u64, 32);
    for (i, v) in q.iter() {
        w.write_bits(i as u64, ib);
        w.write_f32(v);
    }
    w.into_bytes()
}

/// Decode an [`encode_sparse`] payload; validates the declared count and
/// every coordinate index against `d`.
pub fn decode_sparse(bytes: &[u8], d: usize) -> Result<SparseVec, WireError> {
    let ib = index_bits(d) as u32;
    let mut r = BitReader::new(bytes);
    let nnz = r.read_bits(32)? as usize;
    if nnz > d {
        return Err(WireError::Count { nnz, d });
    }
    let mut out = SparseVec::with_capacity(nnz);
    for _ in 0..nnz {
        let idx = r.read_bits(ib)? as usize;
        let val = r.read_f32()?;
        if idx >= d {
            return Err(WireError::Index { idx, d });
        }
        out.push(idx as u32, val);
    }
    Ok(out)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::compress::{Compressor, SignL1, SignTopK, TopK};
    use crate::util::Rng;

    fn randvec(seed: u64, d: usize) -> Vec<f32> {
        let mut rng = Rng::new(seed);
        let mut v = vec![0.0f32; d];
        rng.fill_normal(&mut v, 1.0);
        v
    }

    #[test]
    fn bit_io_roundtrip() {
        let mut w = BitWriter::new();
        w.write_bits(0b1011, 4);
        w.write_bits(0x3FF, 10);
        w.write_f32(-1.5);
        assert_eq!(w.bit_len(), 4 + 10 + 32);
        let bytes = w.into_bytes();
        let mut r = BitReader::new(&bytes);
        assert_eq!(r.read_bits(4).unwrap(), 0b1011);
        assert_eq!(r.read_bits(10).unwrap(), 0x3FF);
        assert_eq!(r.read_f32().unwrap(), -1.5);
    }

    #[test]
    fn reads_past_the_end_are_errors_not_panics() {
        let bytes = vec![0xFFu8; 2];
        let mut r = BitReader::new(&bytes);
        assert_eq!(r.read_bits(16).unwrap(), 0xFFFF);
        assert_eq!(
            r.read_bits(1),
            Err(WireError::Truncated { need: 17, have: 16 })
        );
        // a failed read leaves the cursor in place
        let mut r = BitReader::new(&bytes);
        assert!(r.read_bits(17).is_err());
        assert_eq!(r.read_bits(16).unwrap(), 0xFFFF);
        // empty buffer
        let mut r = BitReader::new(&[]);
        assert!(r.read_f32().is_err());
    }

    #[test]
    fn crc32_matches_the_ieee_check_value() {
        // The canonical CRC-32/ISO-HDLC check: crc32("123456789").
        assert_eq!(crc32(b"123456789"), 0xCBF4_3926);
        assert_eq!(crc32(b""), 0);
    }

    #[test]
    fn frame_roundtrip_and_detection() {
        let payload = b"sparq frame payload";
        let framed = frame(payload);
        assert_eq!(framed.len(), payload.len() + FRAME_OVERHEAD);
        assert_eq!(unframe(&framed).unwrap(), payload);
        // empty payload frames too
        assert_eq!(unframe(&frame(b"")).unwrap(), b"");

        // payload corruption → checksum error
        let mut bad = framed.clone();
        bad[FRAME_OVERHEAD + 3] ^= 0x40;
        assert!(matches!(unframe(&bad), Err(WireError::Checksum { .. })));
        // length-field corruption → length error
        let mut bad = framed.clone();
        bad[0] ^= 1;
        assert!(matches!(unframe(&bad), Err(WireError::Length { .. })));
        // truncation below the header → truncated error
        assert!(matches!(
            unframe(&framed[..5]),
            Err(WireError::Truncated { .. })
        ));
        // lost tail bytes → length error (declared > actual)
        assert!(matches!(
            unframe(&framed[..framed.len() - 1]),
            Err(WireError::Length { .. })
        ));
    }

    #[test]
    fn sign_topk_roundtrip_and_size() {
        let d = 777;
        let k = 33;
        let x = randvec(1, d);
        let op = SignTopK::new(k);
        let mut rng = Rng::new(0);
        let q = op.compress_vec(&x, &mut rng);
        let bytes = encode_sign_topk(&q);
        // bit length matches the charged cost (up to byte padding)
        let charged = op.encoded_bits(d);
        assert!(
            (bytes.len() as u64) * 8 >= charged && (bytes.len() as u64) * 8 < charged + 8,
            "{} bytes vs {} charged bits",
            bytes.len(),
            charged
        );
        let back = decode_sign_topk(&bytes, d, k).unwrap();
        assert_eq!(q, back);
    }

    #[test]
    fn topk_roundtrip_and_size() {
        let d = 500;
        let k = 25;
        let x = randvec(2, d);
        let op = TopK::new(k);
        let mut rng = Rng::new(0);
        let q = op.compress_vec(&x, &mut rng);
        let bytes = encode_topk(&q);
        let charged = op.encoded_bits(d);
        assert!((bytes.len() as u64) * 8 >= charged && (bytes.len() as u64) * 8 < charged + 8);
        assert_eq!(decode_topk(&bytes, d, k).unwrap(), q);
    }

    #[test]
    fn sign_roundtrip_and_size() {
        let d = 301;
        let x = randvec(3, d);
        let mut rng = Rng::new(0);
        let q = SignL1.compress_vec(&x, &mut rng);
        let bytes = encode_sign(&q);
        let charged = SignL1.encoded_bits(d);
        assert!((bytes.len() as u64) * 8 >= charged && (bytes.len() as u64) * 8 < charged + 8);
        assert_eq!(decode_sign(&bytes, d).unwrap(), q);
    }

    #[test]
    fn empty_message() {
        let q = vec![0.0f32; 64];
        let bytes = encode_sign_topk(&q);
        let back = decode_sign_topk(&bytes, 64, 0).unwrap();
        assert_eq!(back, q);
    }

    #[test]
    fn truncated_payloads_decode_to_errors() {
        let d = 96;
        let x = randvec(11, d);
        let mut rng = Rng::new(0);
        let q = TopK::new(12).compress_vec(&x, &mut rng);
        let bytes = encode_topk(&q);
        assert!(decode_topk(&bytes[..bytes.len() / 2], d, 12).is_err());
        assert!(decode_topk_sparse(&bytes[..3], d, 12).is_err());
        assert!(decode_sign(&[], d).is_err());
        assert!(decode_sign_topk(&bytes[..2], d, 12).is_err());
        // over-declared counts are rejected before any allocation abuse
        assert_eq!(
            decode_topk(&bytes, d, d + 1),
            Err(WireError::Count { nnz: d + 1, d })
        );
    }

    #[test]
    fn sparse_codec_is_self_describing() {
        let d = 640;
        let x = randvec(13, d);
        let mut rng = Rng::new(0);
        let mut q = crate::compress::SparseVec::new();
        TopK::new(40).compress_sparse(&x, &mut rng, &mut q);
        let bytes = encode_sparse(&q, d);
        // nnz travels in-band: decode needs only d
        assert_eq!(decode_sparse(&bytes, d).unwrap(), q);
        // declared-count validation
        let mut w = BitWriter::new();
        w.write_bits(d as u64 + 5, 32);
        assert_eq!(
            decode_sparse(&w.into_bytes(), d),
            Err(WireError::Count { nnz: d + 5, d })
        );
        // an empty message still decodes
        let empty = crate::compress::SparseVec::new();
        assert_eq!(decode_sparse(&encode_sparse(&empty, d), d).unwrap(), empty);
    }

    #[test]
    fn sparse_encoders_match_dense_encoders() {
        let d = 901;
        let x = randvec(7, d);
        for k in [1usize, 17, 128] {
            let mut rng = Rng::new(0);
            let mut q = crate::compress::SparseVec::new();

            let topk = TopK::new(k);
            topk.compress_sparse(&x, &mut rng, &mut q);
            let dense = q.to_dense(d);
            assert_eq!(encode_topk_sparse(&q, d), encode_topk(&dense), "topk k={k}");
            assert_eq!(topk_bits(q.nnz(), d), topk.message_bits(d, q.nnz()));
            let back = decode_topk_sparse(&encode_topk_sparse(&q, d), d, q.nnz()).unwrap();
            assert_eq!(back, q);

            let st = SignTopK::new(k);
            st.compress_sparse(&x, &mut rng, &mut q);
            let dense = q.to_dense(d);
            assert_eq!(
                encode_sign_topk_sparse(&q, d),
                encode_sign_topk(&dense),
                "sign_topk k={k}"
            );
            assert_eq!(sign_topk_bits(q.nnz(), d), st.message_bits(d, q.nnz()));
        }
    }

    #[test]
    fn bit_length_functions_match_actual_encodings() {
        let d = 2048;
        let x = randvec(9, d);
        let mut rng = Rng::new(0);
        let mut q = crate::compress::SparseVec::new();
        TopK::new(64).compress_sparse(&x, &mut rng, &mut q);
        let bytes = encode_topk_sparse(&q, d);
        let bits = topk_bits(q.nnz(), d);
        assert!((bytes.len() as u64) * 8 >= bits && (bytes.len() as u64) * 8 < bits + 8);

        SignTopK::new(64).compress_sparse(&x, &mut rng, &mut q);
        let bytes = encode_sign_topk_sparse(&q, d);
        let bits = sign_topk_bits(q.nnz(), d);
        assert!((bytes.len() as u64) * 8 >= bits && (bytes.len() as u64) * 8 < bits + 8);
    }
}
