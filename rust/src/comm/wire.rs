//! Wire format: actual bit-packed encodings for the compressed messages.
//!
//! `Compressor::encoded_bits` promises a per-message cost; this module
//! *implements* those encodings, so the accounting is backed by a real
//! codec rather than a formula: `encode → decode` round-trips to the
//! exact dense reconstruction, and the encoded length matches the charged
//! bits (tested in both this module and `rust/tests/properties.rs`).

use crate::compress::{index_bits, SparseVec};

/// LSB-first bit writer.
#[derive(Default)]
pub struct BitWriter {
    buf: Vec<u8>,
    /// Bits used in the last byte (0 ⇒ byte boundary).
    nbits: u64,
}

impl BitWriter {
    pub fn new() -> Self {
        Self::default()
    }

    pub fn write_bits(&mut self, value: u64, bits: u32) {
        debug_assert!(bits <= 64);
        for i in 0..bits {
            let bit = (value >> i) & 1;
            let pos = self.nbits % 8;
            if pos == 0 {
                self.buf.push(0);
            }
            if bit == 1 {
                *self.buf.last_mut().unwrap() |= 1 << pos;
            }
            self.nbits += 1;
        }
    }

    pub fn write_f32(&mut self, x: f32) {
        self.write_bits(x.to_bits() as u64, 32);
    }

    pub fn bit_len(&self) -> u64 {
        self.nbits
    }

    pub fn into_bytes(self) -> Vec<u8> {
        self.buf
    }
}

/// LSB-first bit reader.
pub struct BitReader<'a> {
    buf: &'a [u8],
    pos: u64,
}

impl<'a> BitReader<'a> {
    pub fn new(buf: &'a [u8]) -> Self {
        BitReader { buf, pos: 0 }
    }

    pub fn read_bits(&mut self, bits: u32) -> u64 {
        let mut out = 0u64;
        for i in 0..bits {
            let byte = (self.pos / 8) as usize;
            let off = self.pos % 8;
            let bit = (self.buf[byte] >> off) & 1;
            out |= (bit as u64) << i;
            self.pos += 1;
        }
        out
    }

    pub fn read_f32(&mut self) -> f32 {
        f32::from_bits(self.read_bits(32) as u32)
    }
}

/// Exact bit length of [`encode_topk`]/[`encode_topk_sparse`] for a
/// message with `nnz` stored nonzeros at dimension d — the hot path
/// charges this (via `Compressor::message_bits`) without materializing
/// any bytes.
pub fn topk_bits(nnz: usize, d: usize) -> u64 {
    nnz as u64 * (32 + index_bits(d))
}

/// Exact bit length of [`encode_sign_topk`]/[`encode_sign_topk_sparse`].
pub fn sign_topk_bits(nnz: usize, d: usize) -> u64 {
    32 + nnz as u64 * (1 + index_bits(d))
}

/// Encoded SignTopK message: k (index, sign) pairs + one f32 scale.
/// Matches `SignTopK::encoded_bits` (honest accounting) exactly.
pub fn encode_sign_topk(q: &[f32]) -> Vec<u8> {
    let d = q.len();
    let ib = index_bits(d) as u32;
    let mut w = BitWriter::new();
    let nz: Vec<usize> = (0..d).filter(|&i| q[i] != 0.0).collect();
    let scale = nz.first().map(|&i| q[i].abs()).unwrap_or(0.0);
    w.write_f32(scale);
    for &i in &nz {
        w.write_bits(i as u64, ib);
        w.write_bits((q[i] < 0.0) as u64, 1);
    }
    w.into_bytes()
}

/// Encode a sparse SignTopK message without densifying — bit-identical to
/// [`encode_sign_topk`] of its dense form (entries are stored in index
/// order, exactly the order the dense encoder scans).
pub fn encode_sign_topk_sparse(q: &SparseVec, d: usize) -> Vec<u8> {
    let ib = index_bits(d) as u32;
    let mut w = BitWriter::new();
    let scale = q.val.first().map(|v| v.abs()).unwrap_or(0.0);
    w.write_f32(scale);
    for (i, v) in q.iter() {
        w.write_bits(i as u64, ib);
        w.write_bits((v < 0.0) as u64, 1);
    }
    w.into_bytes()
}

/// Decode into a dense vector of dimension d with `k` nonzeros.
pub fn decode_sign_topk(bytes: &[u8], d: usize, k: usize) -> Vec<f32> {
    let ib = index_bits(d) as u32;
    let mut r = BitReader::new(bytes);
    let scale = r.read_f32();
    let mut out = vec![0.0f32; d];
    for _ in 0..k {
        let idx = r.read_bits(ib) as usize;
        let neg = r.read_bits(1) == 1;
        out[idx] = if neg { -scale } else { scale };
    }
    out
}

/// Encoded TopK message: k (index, f32 value) pairs.
pub fn encode_topk(q: &[f32]) -> Vec<u8> {
    let d = q.len();
    let ib = index_bits(d) as u32;
    let mut w = BitWriter::new();
    for (i, &v) in q.iter().enumerate() {
        if v != 0.0 {
            w.write_bits(i as u64, ib);
            w.write_f32(v);
        }
    }
    w.into_bytes()
}

pub fn decode_topk(bytes: &[u8], d: usize, k: usize) -> Vec<f32> {
    let ib = index_bits(d) as u32;
    let mut r = BitReader::new(bytes);
    let mut out = vec![0.0f32; d];
    for _ in 0..k {
        let idx = r.read_bits(ib) as usize;
        out[idx] = r.read_f32();
    }
    out
}

/// Encode a sparse TopK message without densifying — bit-identical to
/// [`encode_topk`] of its dense form.
pub fn encode_topk_sparse(q: &SparseVec, d: usize) -> Vec<u8> {
    let ib = index_bits(d) as u32;
    let mut w = BitWriter::new();
    for (i, v) in q.iter() {
        w.write_bits(i as u64, ib);
        w.write_f32(v);
    }
    w.into_bytes()
}

/// Decode a TopK payload straight into sparse form (k entries).
pub fn decode_topk_sparse(bytes: &[u8], d: usize, k: usize) -> SparseVec {
    let ib = index_bits(d) as u32;
    let mut r = BitReader::new(bytes);
    let mut out = SparseVec::with_capacity(k);
    for _ in 0..k {
        let idx = r.read_bits(ib) as u32;
        out.push(idx, r.read_f32());
    }
    out
}

/// Encoded Sign(ℓ1) message: d sign bits + one f32 scale.
pub fn encode_sign(q: &[f32]) -> Vec<u8> {
    let mut w = BitWriter::new();
    let scale = q.first().map(|v| v.abs()).unwrap_or(0.0);
    w.write_f32(scale);
    for &v in q {
        w.write_bits((v < 0.0) as u64, 1);
    }
    w.into_bytes()
}

pub fn decode_sign(bytes: &[u8], d: usize) -> Vec<f32> {
    let mut r = BitReader::new(bytes);
    let scale = r.read_f32();
    (0..d)
        .map(|_| {
            if r.read_bits(1) == 1 {
                -scale
            } else {
                scale
            }
        })
        .collect()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::compress::{Compressor, SignL1, SignTopK, TopK};
    use crate::util::Rng;

    fn randvec(seed: u64, d: usize) -> Vec<f32> {
        let mut rng = Rng::new(seed);
        let mut v = vec![0.0f32; d];
        rng.fill_normal(&mut v, 1.0);
        v
    }

    #[test]
    fn bit_io_roundtrip() {
        let mut w = BitWriter::new();
        w.write_bits(0b1011, 4);
        w.write_bits(0x3FF, 10);
        w.write_f32(-1.5);
        assert_eq!(w.bit_len(), 4 + 10 + 32);
        let bytes = w.into_bytes();
        let mut r = BitReader::new(&bytes);
        assert_eq!(r.read_bits(4), 0b1011);
        assert_eq!(r.read_bits(10), 0x3FF);
        assert_eq!(r.read_f32(), -1.5);
    }

    #[test]
    fn sign_topk_roundtrip_and_size() {
        let d = 777;
        let k = 33;
        let x = randvec(1, d);
        let op = SignTopK::new(k);
        let mut rng = Rng::new(0);
        let q = op.compress_vec(&x, &mut rng);
        let bytes = encode_sign_topk(&q);
        // bit length matches the charged cost (up to byte padding)
        let charged = op.encoded_bits(d);
        assert!(
            (bytes.len() as u64) * 8 >= charged && (bytes.len() as u64) * 8 < charged + 8,
            "{} bytes vs {} charged bits",
            bytes.len(),
            charged
        );
        let back = decode_sign_topk(&bytes, d, k);
        assert_eq!(q, back);
    }

    #[test]
    fn topk_roundtrip_and_size() {
        let d = 500;
        let k = 25;
        let x = randvec(2, d);
        let op = TopK::new(k);
        let mut rng = Rng::new(0);
        let q = op.compress_vec(&x, &mut rng);
        let bytes = encode_topk(&q);
        let charged = op.encoded_bits(d);
        assert!((bytes.len() as u64) * 8 >= charged && (bytes.len() as u64) * 8 < charged + 8);
        assert_eq!(decode_topk(&bytes, d, k), q);
    }

    #[test]
    fn sign_roundtrip_and_size() {
        let d = 301;
        let x = randvec(3, d);
        let mut rng = Rng::new(0);
        let q = SignL1.compress_vec(&x, &mut rng);
        let bytes = encode_sign(&q);
        let charged = SignL1.encoded_bits(d);
        assert!((bytes.len() as u64) * 8 >= charged && (bytes.len() as u64) * 8 < charged + 8);
        assert_eq!(decode_sign(&bytes, d), q);
    }

    #[test]
    fn empty_message() {
        let q = vec![0.0f32; 64];
        let bytes = encode_sign_topk(&q);
        let back = decode_sign_topk(&bytes, 64, 0);
        assert_eq!(back, q);
    }

    #[test]
    fn sparse_encoders_match_dense_encoders() {
        let d = 901;
        let x = randvec(7, d);
        for k in [1usize, 17, 128] {
            let mut rng = Rng::new(0);
            let mut q = crate::compress::SparseVec::new();

            let topk = TopK::new(k);
            topk.compress_sparse(&x, &mut rng, &mut q);
            let dense = q.to_dense(d);
            assert_eq!(encode_topk_sparse(&q, d), encode_topk(&dense), "topk k={k}");
            assert_eq!(topk_bits(q.nnz(), d), topk.message_bits(d, q.nnz()));
            let back = decode_topk_sparse(&encode_topk_sparse(&q, d), d, q.nnz());
            assert_eq!(back, q);

            let st = SignTopK::new(k);
            st.compress_sparse(&x, &mut rng, &mut q);
            let dense = q.to_dense(d);
            assert_eq!(
                encode_sign_topk_sparse(&q, d),
                encode_sign_topk(&dense),
                "sign_topk k={k}"
            );
            assert_eq!(sign_topk_bits(q.nnz(), d), st.message_bits(d, q.nnz()));
        }
    }

    #[test]
    fn bit_length_functions_match_actual_encodings() {
        let d = 2048;
        let x = randvec(9, d);
        let mut rng = Rng::new(0);
        let mut q = crate::compress::SparseVec::new();
        TopK::new(64).compress_sparse(&x, &mut rng, &mut q);
        let bytes = encode_topk_sparse(&q, d);
        let bits = topk_bits(q.nnz(), d);
        assert!((bytes.len() as u64) * 8 >= bits && (bytes.len() as u64) * 8 < bits + 8);

        SignTopK::new(64).compress_sparse(&x, &mut rng, &mut q);
        let bytes = encode_sign_topk_sparse(&q, d);
        let bits = sign_topk_bits(q.nnz(), d);
        assert!((bytes.len() as u64) * 8 >= bits && (bytes.len() as u64) * 8 < bits + 8);
    }
}
