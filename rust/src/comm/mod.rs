//! Accounted communication bus for the simulated graph.
//!
//! The decentralized run is synchronous and in-process, but every exchange
//! goes through [`Bus`] so transmitted bits are charged exactly as a wire
//! format would (the figures' x-axes and the savings table come from these
//! counters). A message is one node's compressed update broadcast to all
//! its graph neighbors (Algorithm 1 line 9: "Send q_i and receive q_j").
//!
//! Counting convention — matching how the paper reports "total bits
//! communicated": a broadcast of an m-bit payload to `deg` neighbors
//! counts `deg * m` link-bits (each edge carries the payload in both
//! directions over a round where both endpoints fire).
//!
//! The payload size m is *per message*, not per operator: the coordinators
//! charge `Compressor::message_bits(d, nnz)` for the sparse message they
//! actually built. For operators with a [`wire`] codec (TopK, SignTopK)
//! that equals the codec's encoded bit length for that exact message —
//! magnitude ties select extra coordinates and are charged accordingly;
//! fixed-slot wire formats (dense operators, QsgdTopK) charge their
//! nominal cost regardless of stored nonzeros.

pub mod fault;
pub mod link;
pub mod transport;
pub mod wire;

pub use fault::{FaultCounters, FaultPlan};
pub use link::LinkModel;
pub use transport::{LocalTransport, Transport};

/// Per-round and cumulative communication accounting.
#[derive(Clone, Debug, Default)]
pub struct Bus {
    /// Cumulative bits over all links since construction.
    pub total_bits: u64,
    /// Cumulative messages (node-broadcasts).
    pub total_messages: u64,
    /// Rounds in which at least one node communicated.
    pub comm_rounds: u64,
    /// Per-node cumulative sent bits.
    pub node_bits: Vec<u64>,
    /// Bits charged in the current round (reset by `end_round`).
    round_bits: u64,
    round_messages: u64,
}

impl Bus {
    pub fn new(n: usize) -> Bus {
        Bus {
            node_bits: vec![0; n],
            ..Default::default()
        }
    }

    /// Charge one broadcast: node `from` sends an `encoded_bits` payload to
    /// `fanout` neighbors.
    pub fn charge_broadcast(&mut self, from: usize, fanout: usize, encoded_bits: u64) {
        let bits = encoded_bits * fanout as u64;
        self.total_bits += bits;
        self.node_bits[from] += bits;
        self.round_bits += bits;
        self.total_messages += 1;
        self.round_messages += 1;
    }

    /// Close the round; returns (bits, messages) charged within it.
    pub fn end_round(&mut self) -> (u64, u64) {
        let out = (self.round_bits, self.round_messages);
        if self.round_messages > 0 {
            self.comm_rounds += 1;
        }
        self.round_bits = 0;
        self.round_messages = 0;
        out
    }

    pub fn n(&self) -> usize {
        self.node_bits.len()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn charges_accumulate() {
        let mut bus = Bus::new(3);
        bus.charge_broadcast(0, 2, 100);
        bus.charge_broadcast(1, 2, 100);
        let (bits, msgs) = bus.end_round();
        assert_eq!(bits, 400);
        assert_eq!(msgs, 2);
        assert_eq!(bus.total_bits, 400);
        assert_eq!(bus.comm_rounds, 1);
        assert_eq!(bus.node_bits, vec![200, 200, 0]);
    }

    #[test]
    fn silent_round_not_counted() {
        let mut bus = Bus::new(2);
        let (bits, msgs) = bus.end_round();
        assert_eq!((bits, msgs), (0, 0));
        assert_eq!(bus.comm_rounds, 0);
    }

    #[test]
    fn round_counters_reset() {
        let mut bus = Bus::new(2);
        bus.charge_broadcast(0, 1, 64);
        bus.end_round();
        bus.charge_broadcast(1, 1, 32);
        let (bits, _) = bus.end_round();
        assert_eq!(bits, 32);
        assert_eq!(bus.total_bits, 96);
        assert_eq!(bus.comm_rounds, 2);
    }
}
