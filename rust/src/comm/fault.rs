//! Deterministic fault plans: node crashes, network partitions, and
//! per-copy message corruption for the simulated fabric.
//!
//! [`LinkModel`](super::LinkModel) covers the *stateless* failure axes
//! (independent per-copy drops, per-round stragglers). A [`FaultPlan`]
//! adds the *scheduled* axes a production deployment must survive:
//!
//! * **node crashes** — `crash:i:t_down:t_up`: node `i` is offline for
//!   every iteration in `[t_down, t_up)`. While down it computes
//!   nothing, transmits nothing, and receives nothing; the engine
//!   renormalizes its mixing weight away so gossip proceeds on the live
//!   subgraph. On rejoin the node resumes from its crash-time state (a
//!   crash-time checkpoint restore) and pays a full-precision resync
//!   over its live edges — recovery is never free.
//! * **partitions** — `partition:t0:t1:A|B`: for `[t0, t1)` the listed
//!   groups cannot reach each other (edges crossing a group boundary
//!   are severed; nodes not listed in any group are unaffected). Group
//!   members are comma-separated indices; `a-b` ranges are accepted
//!   (`partition:500:700:0-7|8-15`).
//! * **corruption** — `corrupt:p`: each delivered copy of a broadcast
//!   is corrupted in flight with probability `p`. The receiver's
//!   checksum ([`wire::unframe`](super::wire::unframe)) detects it, so
//!   a corrupted copy is charged on the bus (it consumed the link) but
//!   discarded like a drop — never silently decoded into the consensus
//!   step.
//!
//! Segments compose with `+`, and the whole plan composes with a
//! `LinkModel` (`drop:p` and `crash:...` can run together). Crashes and
//! partitions are pure schedules — no coins — so the down/severed sets
//! are identical across worker counts by construction. Corruption uses
//! the same splitmix64 hashed-coin discipline as `LinkModel`: every
//! coin is a stateless hash of `(seed, tag, endpoints, t)`, so fault
//! patterns are bit-for-bit reproducible from any thread interleaving.

use crate::util::rng::splitmix64;

/// Domain-separation tag for corruption coins (never collides with the
/// `LinkModel` drop/straggler tags).
const TAG_CORRUPT: u64 = 0x464C_5443_4F52_5054; // "FLTCORPT"

/// One scheduled outage: node `node` is down for `t` in `[down, up)`.
#[derive(Clone, Debug, PartialEq)]
pub struct CrashWindow {
    pub node: usize,
    pub down: u64,
    pub up: u64,
}

/// One scheduled partition: for `t` in `[from, to)`, nodes in different
/// groups cannot exchange messages.
#[derive(Clone, Debug, PartialEq)]
pub struct Partition {
    pub from: u64,
    pub to: u64,
    pub groups: Vec<Vec<usize>>,
}

impl Partition {
    /// Are `a` and `b` on opposite sides of this partition (regardless
    /// of time)? Nodes not listed in any group are unaffected.
    fn splits(&self, a: usize, b: usize) -> bool {
        let ga = self.groups.iter().position(|g| g.contains(&a));
        let gb = self.groups.iter().position(|g| g.contains(&b));
        matches!((ga, gb), (Some(x), Some(y)) if x != y)
    }
}

/// Per-run fault bookkeeping, surfaced in sweep results and reports.
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub struct FaultCounters {
    /// Crash events (a node entering a down window).
    pub crashes: u64,
    /// Node-level resync payments: at each fault transition, every node
    /// that regained at least one live edge (a rejoined node and each of
    /// its live neighbors; both sides of a healed partition) pays one
    /// full-precision x̂ exchange over its regained edges.
    pub resyncs: u64,
    /// Copies corrupted in flight: charged on the bus, detected by the
    /// frame checksum, and discarded like a drop.
    pub corrupt_discards: u64,
}

impl FaultCounters {
    /// Nothing ever went wrong.
    pub fn is_zero(&self) -> bool {
        *self == FaultCounters::default()
    }
}

/// A seeded, schedule-driven fault plan. Plain data — cloning or
/// sharing across threads is free, and identical `(spec, seed)` pairs
/// always produce identical fault patterns.
#[derive(Clone, Debug, PartialEq)]
pub struct FaultPlan {
    pub crashes: Vec<CrashWindow>,
    pub partitions: Vec<Partition>,
    /// Per-copy corruption probability in [0, 1).
    pub corrupt_p: f64,
    /// Corruption-coin seed (salted independently of the link seed).
    pub seed: u64,
}

impl FaultPlan {
    /// The fault-free default: the engine takes its seed fast path.
    pub fn ideal() -> FaultPlan {
        FaultPlan {
            crashes: Vec::new(),
            partitions: Vec::new(),
            corrupt_p: 0.0,
            seed: 0,
        }
    }

    /// True when no fault can ever occur.
    pub fn is_ideal(&self) -> bool {
        self.crashes.is_empty() && self.partitions.is_empty() && self.corrupt_p == 0.0
    }

    /// True when the plan can sever edges (crashes or partitions) — the
    /// engine only tracks fault epochs and staleness when it can.
    pub fn has_outages(&self) -> bool {
        !self.crashes.is_empty() || !self.partitions.is_empty()
    }

    /// Parse a fault spec: `none`, or `+`-joined segments
    /// `crash:I:T_DOWN:T_UP`, `partition:T0:T1:A|B[|C...]` (groups are
    /// comma-separated indices; `a-b` ranges allowed), `corrupt:P`.
    pub fn parse(spec: &str, seed: u64) -> Result<FaultPlan, String> {
        let mut plan = FaultPlan {
            seed: seed ^ 0x5FA7_1D3C_8B96_E042,
            ..FaultPlan::ideal()
        };
        if spec.is_empty() || spec == "none" || spec == "ideal" {
            return Ok(plan);
        }
        for seg in spec.split('+') {
            let parts: Vec<&str> = seg.split(':').collect();
            match parts.as_slice() {
                ["crash", i, down, up] => {
                    let node: usize = i
                        .parse()
                        .map_err(|_| format!("crash node {i:?} is not an index"))?;
                    let down: u64 = down
                        .parse()
                        .map_err(|_| format!("crash t_down {down:?} is not an iteration"))?;
                    let up: u64 = up
                        .parse()
                        .map_err(|_| format!("crash t_up {up:?} is not an iteration"))?;
                    if down >= up {
                        return Err(format!(
                            "crash window [{down}, {up}) is empty; need t_down < t_up"
                        ));
                    }
                    plan.crashes.push(CrashWindow { node, down, up });
                }
                ["partition", t0, t1, groups] => {
                    let from: u64 = t0
                        .parse()
                        .map_err(|_| format!("partition t0 {t0:?} is not an iteration"))?;
                    let to: u64 = t1
                        .parse()
                        .map_err(|_| format!("partition t1 {t1:?} is not an iteration"))?;
                    if from >= to {
                        return Err(format!(
                            "partition window [{from}, {to}) is empty; need t0 < t1"
                        ));
                    }
                    let groups = parse_groups(groups)?;
                    plan.partitions.push(Partition { from, to, groups });
                }
                ["corrupt", p] => {
                    let p: f64 = p
                        .parse()
                        .map_err(|_| format!("corrupt probability {p:?} is not a number"))?;
                    if !p.is_finite() || !(0.0..1.0).contains(&p) {
                        return Err(format!("corrupt probability must be in [0, 1), got {p}"));
                    }
                    if plan.corrupt_p > 0.0 {
                        return Err("only one corrupt:P segment is allowed".into());
                    }
                    plan.corrupt_p = p;
                }
                _ => {
                    return Err(format!(
                        "unknown fault segment {seg:?}; expected none, crash:I:T0:T1, \
                         partition:T0:T1:A|B, or corrupt:P"
                    ))
                }
            }
        }
        // Overlapping windows for one node would make the rejoin time
        // ambiguous; reject them instead of guessing.
        let mut windows = plan.crashes.clone();
        windows.sort_by_key(|w| (w.node, w.down));
        for pair in windows.windows(2) {
            if pair[0].node == pair[1].node && pair[1].down < pair[0].up {
                return Err(format!(
                    "crash windows [{}, {}) and [{}, {}) for node {} overlap",
                    pair[0].down, pair[0].up, pair[1].down, pair[1].up, pair[0].node
                ));
            }
        }
        Ok(plan)
    }

    /// Node indices referenced anywhere in the plan must be < `n`
    /// (called from `ExperimentConfig::resolve`, which knows `n`).
    pub fn check_nodes(&self, n: usize) -> Result<(), String> {
        for w in &self.crashes {
            if w.node >= n {
                return Err(format!("crash node {} out of range for {n} nodes", w.node));
            }
        }
        for p in &self.partitions {
            for g in &p.groups {
                for &i in g {
                    if i >= n {
                        return Err(format!(
                            "partition member {i} out of range for {n} nodes"
                        ));
                    }
                }
            }
        }
        Ok(())
    }

    /// Earliest iteration at which any fault activates (`None` for an
    /// ideal or corrupt-only plan — corruption is active from t = 0).
    pub fn first_activation(&self) -> Option<u64> {
        let c = self.crashes.iter().map(|w| w.down);
        let p = self.partitions.iter().map(|w| w.from);
        c.chain(p).min()
    }

    /// Is node `i` offline at iteration `t`? Pure schedule, no coins.
    pub fn is_down(&self, i: usize, t: u64) -> bool {
        self.crashes
            .iter()
            .any(|w| w.node == i && w.down <= t && t < w.up)
    }

    /// Fill `mask[i] = is_down(i, t)` for every node.
    pub fn down_mask_into(&self, t: u64, mask: &mut [bool]) {
        mask.fill(false);
        for w in &self.crashes {
            if w.down <= t && t < w.up && w.node < mask.len() {
                mask[w.node] = true;
            }
        }
    }

    /// Is the `a ↔ b` edge severed by an active partition at `t`?
    /// (Crash outages are handled separately via [`is_down`].)
    pub fn severed(&self, a: usize, b: usize, t: u64) -> bool {
        self.partitions
            .iter()
            .any(|p| p.from <= t && t < p.to && p.splits(a, b))
    }

    /// Is the `from → to` copy of iteration t's broadcast corrupted in
    /// flight? Stateless seeded coin — order- and thread-independent.
    pub fn corrupts(&self, from: usize, to: usize, t: u64) -> bool {
        if self.corrupt_p == 0.0 {
            return false;
        }
        let mut s = self
            .seed
            .wrapping_add(TAG_CORRUPT)
            .wrapping_add((from as u64).wrapping_mul(0x9E37_79B9_7F4A_7C15))
            .wrapping_add((to as u64).wrapping_mul(0xC2B2_AE3D_27D4_EB4F))
            .wrapping_add(t.wrapping_mul(0x1656_67B1_9E37_79F9));
        let coin = (splitmix64(&mut s) >> 11) as f64 * (1.0 / (1u64 << 53) as f64);
        coin < self.corrupt_p
    }

    /// The active outage windows at `t`, as (crash indices, partition
    /// indices) into [`crashes`](Self::crashes)/[`partitions`](Self::partitions).
    /// The engine keys its fault epochs on this: the live subgraph can
    /// only change when this value does.
    pub fn active(&self, t: u64) -> (Vec<usize>, Vec<usize>) {
        let c = self
            .crashes
            .iter()
            .enumerate()
            .filter(|(_, w)| w.down <= t && t < w.up)
            .map(|(i, _)| i)
            .collect();
        let p = self
            .partitions
            .iter()
            .enumerate()
            .filter(|(_, w)| w.from <= t && t < w.to)
            .map(|(i, _)| i)
            .collect();
        (c, p)
    }

    /// Human-readable spec (round-trips through [`parse`](Self::parse)
    /// semantics).
    pub fn describe(&self) -> String {
        if self.is_ideal() {
            return "none".into();
        }
        let mut parts = Vec::new();
        for w in &self.crashes {
            parts.push(format!("crash:{}:{}:{}", w.node, w.down, w.up));
        }
        for p in &self.partitions {
            let groups: Vec<String> = p
                .groups
                .iter()
                .map(|g| {
                    g.iter()
                        .map(|i| i.to_string())
                        .collect::<Vec<_>>()
                        .join(",")
                })
                .collect();
            parts.push(format!("partition:{}:{}:{}", p.from, p.to, groups.join("|")));
        }
        if self.corrupt_p > 0.0 {
            parts.push(format!("corrupt:{}", self.corrupt_p));
        }
        parts.join("+")
    }
}

/// Parse a `A|B[|C...]` group spec: groups split on `|`, members on
/// `,`, each member a node index or an `a-b` inclusive range.
fn parse_groups(spec: &str) -> Result<Vec<Vec<usize>>, String> {
    let mut groups: Vec<Vec<usize>> = Vec::new();
    for g in spec.split('|') {
        let mut members = Vec::new();
        for item in g.split(',') {
            let item = item.trim();
            if item.is_empty() {
                return Err(format!("empty member in partition group {g:?}"));
            }
            if let Some((a, b)) = item.split_once('-') {
                let a: usize = a
                    .parse()
                    .map_err(|_| format!("range start {a:?} is not an index"))?;
                let b: usize = b
                    .parse()
                    .map_err(|_| format!("range end {b:?} is not an index"))?;
                if a > b {
                    return Err(format!("range {item:?} runs backwards"));
                }
                members.extend(a..=b);
            } else {
                members.push(
                    item.parse()
                        .map_err(|_| format!("partition member {item:?} is not an index"))?,
                );
            }
        }
        groups.push(members);
    }
    if groups.len() < 2 {
        return Err("a partition needs at least two |-separated groups".into());
    }
    let mut seen = std::collections::HashSet::new();
    for g in &groups {
        for &i in g {
            if !seen.insert(i) {
                return Err(format!("node {i} appears in two partition groups"));
            }
        }
    }
    Ok(groups)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn ideal_never_faults() {
        let p = FaultPlan::ideal();
        assert!(p.is_ideal());
        assert!(!p.has_outages());
        for t in 0..50 {
            assert!(!p.is_down(0, t));
            assert!(!p.severed(0, 1, t));
            assert!(!p.corrupts(0, 1, t));
        }
        assert!(FaultPlan::parse("none", 1).unwrap().is_ideal());
        assert!(FaultPlan::parse("", 1).unwrap().is_ideal());
    }

    #[test]
    fn parse_specs_and_describe_roundtrip() {
        let p = FaultPlan::parse("crash:3:200:400+partition:500:700:0-3|4,5,6,7+corrupt:0.02", 7)
            .unwrap();
        assert_eq!(p.crashes, vec![CrashWindow { node: 3, down: 200, up: 400 }]);
        assert_eq!(p.partitions.len(), 1);
        assert_eq!(p.partitions[0].groups, vec![vec![0, 1, 2, 3], vec![4, 5, 6, 7]]);
        assert_eq!(p.corrupt_p, 0.02);
        assert_eq!(p.first_activation(), Some(200));
        assert_eq!(
            p.describe(),
            "crash:3:200:400+partition:500:700:0,1,2,3|4,5,6,7+corrupt:0.02"
        );
        // describe() re-parses to the same plan (ranges expand)
        let q = FaultPlan::parse(&p.describe(), 7).unwrap();
        assert_eq!(p, q);
    }

    #[test]
    fn parse_rejections() {
        assert!(FaultPlan::parse("crash:0:10:10", 1).is_err()); // empty window
        assert!(FaultPlan::parse("crash:0:20:10", 1).is_err()); // backwards
        assert!(FaultPlan::parse("crash:0:1:5+crash:0:3:9", 1).is_err()); // overlap
        assert!(FaultPlan::parse("partition:5:5:0|1", 1).is_err()); // empty window
        assert!(FaultPlan::parse("partition:0:5:0,1", 1).is_err()); // one group
        assert!(FaultPlan::parse("partition:0:5:0,1|1,2", 1).is_err()); // dup member
        assert!(FaultPlan::parse("partition:0:5:3-1|4", 1).is_err()); // bad range
        assert!(FaultPlan::parse("corrupt:1.5", 1).is_err());
        assert!(FaultPlan::parse("corrupt:0.1+corrupt:0.2", 1).is_err());
        assert!(FaultPlan::parse("wat:1", 1).is_err());
        // disjoint windows for one node are fine
        let p = FaultPlan::parse("crash:0:1:5+crash:0:5:9", 1).unwrap();
        assert_eq!(p.crashes.len(), 2);
    }

    #[test]
    fn crash_windows_are_exact_half_open_intervals() {
        let p = FaultPlan::parse("crash:2:10:20+crash:2:30:35", 1).unwrap();
        for t in 0..50 {
            let expect = (10..20).contains(&t) || (30..35).contains(&t);
            assert_eq!(p.is_down(2, t), expect, "t={t}");
            assert!(!p.is_down(1, t));
        }
        let mut mask = [false; 4];
        p.down_mask_into(12, &mut mask);
        assert_eq!(mask, [false, false, true, false]);
        p.down_mask_into(20, &mut mask);
        assert_eq!(mask, [false; 4]);
    }

    #[test]
    fn partitions_sever_only_cross_group_edges_in_window() {
        let p = FaultPlan::parse("partition:100:200:0,1|2,3", 1).unwrap();
        assert!(p.severed(0, 2, 150));
        assert!(p.severed(3, 1, 150));
        assert!(!p.severed(0, 1, 150)); // same side
        assert!(!p.severed(2, 3, 150));
        assert!(!p.severed(0, 4, 150)); // node 4 unlisted: unaffected
        assert!(!p.severed(0, 2, 99)); // outside the window
        assert!(!p.severed(0, 2, 200));
    }

    #[test]
    fn corruption_coins_are_deterministic_and_order_free() {
        let a = FaultPlan::parse("corrupt:0.3", 9).unwrap();
        let b = FaultPlan::parse("corrupt:0.3", 9).unwrap();
        let fwd: Vec<bool> = (0..300).map(|t| a.corrupts(1, 2, t)).collect();
        let rev: Vec<bool> = (0..300).rev().map(|t| b.corrupts(1, 2, t)).collect();
        let mut back = fwd.clone();
        back.reverse();
        assert_eq!(back, rev);
        let hits = fwd.iter().filter(|&&x| x).count();
        assert!((50..=130).contains(&hits), "corrupted {hits}/300");
        // different seeds give different patterns
        let c = FaultPlan::parse("corrupt:0.3", 10).unwrap();
        assert_ne!(fwd, (0..300).map(|t| c.corrupts(1, 2, t)).collect::<Vec<_>>());
        // and the corrupt coin never collides with a LinkModel drop coin
        let link = crate::comm::LinkModel::parse("drop:0.3", 9).unwrap();
        let drops: Vec<bool> = (0..300).map(|t| !link.delivers(1, 2, t)).collect();
        assert_ne!(fwd, drops);
    }

    #[test]
    fn corrupt_sets_shrink_pointwise_as_p_grows() {
        let lo = FaultPlan::parse("corrupt:0.1", 3).unwrap();
        let hi = FaultPlan::parse("corrupt:0.6", 3).unwrap();
        for t in 0..200 {
            for from in 0..4 {
                for to in 0..4 {
                    if from != to && lo.corrupts(from, to, t) {
                        assert!(hi.corrupts(from, to, t), "({from}->{to}, t={t})");
                    }
                }
            }
        }
    }

    #[test]
    fn active_windows_key_the_fault_epochs() {
        let p = FaultPlan::parse("crash:1:10:20+partition:15:30:0|1,2", 1).unwrap();
        assert_eq!(p.active(5), (vec![], vec![]));
        assert_eq!(p.active(10), (vec![0], vec![]));
        assert_eq!(p.active(15), (vec![0], vec![0]));
        assert_eq!(p.active(20), (vec![], vec![0]));
        assert_eq!(p.active(30), (vec![], vec![]));
    }

    #[test]
    fn check_nodes_bounds() {
        let p = FaultPlan::parse("crash:7:0:5", 1).unwrap();
        assert!(p.check_nodes(8).is_ok());
        assert!(p.check_nodes(7).is_err());
        let p = FaultPlan::parse("partition:0:5:0,1|2,9", 1).unwrap();
        assert!(p.check_nodes(10).is_ok());
        assert!(p.check_nodes(9).is_err());
    }
}
