//! Symmetric eigensolver (cyclic Jacobi rotations).
//!
//! Mixing matrices W are symmetric doubly-stochastic (Section 3), so the
//! spectral quantities the paper needs — δ = 1 − |λ₂| and
//! β = max_i (1 − λ_i) — come from the full (small-n) spectrum.

use super::matrix::Matrix;

/// All eigenvalues of a symmetric matrix, sorted descending.
///
/// Cyclic Jacobi: repeatedly zero the largest off-diagonal entries with
/// Givens rotations until the off-diagonal Frobenius mass is below `tol`.
/// Converges quadratically for symmetric input; n here is ≤ a few hundred.
pub fn symmetric_eigenvalues(m: &Matrix, tol: f64) -> Vec<f64> {
    assert!(m.is_symmetric(1e-9), "Jacobi requires symmetric input");
    let n = m.rows;
    let mut a = m.clone();
    let max_sweeps = 100;

    for _ in 0..max_sweeps {
        // Off-diagonal mass.
        let mut off = 0.0;
        for i in 0..n {
            for j in (i + 1)..n {
                off += a[(i, j)] * a[(i, j)];
            }
        }
        if off.sqrt() < tol {
            break;
        }
        for p in 0..n {
            for q in (p + 1)..n {
                let apq = a[(p, q)];
                if apq.abs() < tol * 1e-3 {
                    continue;
                }
                let app = a[(p, p)];
                let aqq = a[(q, q)];
                let theta = (aqq - app) / (2.0 * apq);
                let t = theta.signum() / (theta.abs() + (theta * theta + 1.0).sqrt());
                let c = 1.0 / (t * t + 1.0).sqrt();
                let s = t * c;
                // Apply rotation G(p, q, θ) on both sides.
                for k in 0..n {
                    let akp = a[(k, p)];
                    let akq = a[(k, q)];
                    a[(k, p)] = c * akp - s * akq;
                    a[(k, q)] = s * akp + c * akq;
                }
                for k in 0..n {
                    let apk = a[(p, k)];
                    let aqk = a[(q, k)];
                    a[(p, k)] = c * apk - s * aqk;
                    a[(q, k)] = s * apk + c * aqk;
                }
            }
        }
    }

    let mut eigs: Vec<f64> = (0..n).map(|i| a[(i, i)]).collect();
    // total_cmp: a NaN from diverged input sorts last instead of
    // panicking mid-diagnostics.
    eigs.sort_by(|x, y| y.total_cmp(x));
    eigs
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn diagonal_matrix() {
        let m = Matrix::from_rows(&[
            vec![3.0, 0.0, 0.0],
            vec![0.0, -1.0, 0.0],
            vec![0.0, 0.0, 2.0],
        ]);
        let e = symmetric_eigenvalues(&m, 1e-12);
        assert!((e[0] - 3.0).abs() < 1e-10);
        assert!((e[1] - 2.0).abs() < 1e-10);
        assert!((e[2] + 1.0).abs() < 1e-10);
    }

    #[test]
    fn known_2x2() {
        // [[2,1],[1,2]] has eigenvalues 3 and 1.
        let m = Matrix::from_rows(&[vec![2.0, 1.0], vec![1.0, 2.0]]);
        let e = symmetric_eigenvalues(&m, 1e-12);
        assert!((e[0] - 3.0).abs() < 1e-10);
        assert!((e[1] - 1.0).abs() < 1e-10);
    }

    #[test]
    fn ring_gossip_spectrum() {
        // Uniform ring weights on n=4: W = circulant(1/3 at self, 1/3 each
        // neighbor... for n=4 each node has 2 neighbors): eigenvalues are
        // 1/3 + 2/3*cos(2πk/4): {1, 1/3, 1/3, -1/3}.
        let n = 4;
        let mut w = Matrix::zeros(n, n);
        for i in 0..n {
            w[(i, i)] = 1.0 / 3.0;
            w[(i, (i + 1) % n)] += 1.0 / 3.0;
            w[(i, (i + n - 1) % n)] += 1.0 / 3.0;
        }
        let e = symmetric_eigenvalues(&w, 1e-12);
        assert!((e[0] - 1.0).abs() < 1e-9);
        assert!((e[1] - 1.0 / 3.0).abs() < 1e-9);
        assert!((e[3] + 1.0 / 3.0).abs() < 1e-9);
    }

    #[test]
    fn trace_preserved() {
        let m = Matrix::from_rows(&[
            vec![1.0, 0.5, 0.2],
            vec![0.5, 2.0, -0.3],
            vec![0.2, -0.3, -1.0],
        ]);
        let e = symmetric_eigenvalues(&m, 1e-12);
        let trace = 1.0 + 2.0 - 1.0;
        assert!((e.iter().sum::<f64>() - trace).abs() < 1e-9);
    }
}
