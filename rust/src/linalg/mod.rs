//! Dense linear algebra for the graph/mixing substrate.
//!
//! Node counts in the paper's experiments are small (n = 8 … 60), so a
//! straightforward row-major `Matrix` plus a cyclic Jacobi eigensolver is
//! both sufficient and exactly reproducible. The coordinator's per-round
//! hot path uses the fused vector kernels at the bottom of this module.

pub mod matrix;
pub mod eigen;
pub mod vecops;

pub use eigen::symmetric_eigenvalues;
pub use matrix::Matrix;
pub use vecops::{axpy, dot, norm2_sq, scale_add, sub_into};
