//! Linear algebra for the graph/mixing substrate.
//!
//! Small graphs (n ≤ 256) use the row-major `Matrix` plus a cyclic
//! Jacobi eigensolver — exact and exactly reproducible. Above that, the
//! `lanczos` module extracts the extremal eigenvalues the paper needs
//! from the sparse O(|E|) mixing operator without ever materializing
//! n×n state. The coordinator's per-round hot path uses the fused
//! vector kernels in `vecops`.

pub mod matrix;
pub mod eigen;
pub mod lanczos;
pub mod vecops;

pub use eigen::symmetric_eigenvalues;
pub use lanczos::{lanczos_extremes, LanczosExtremes, SymOp};
pub use matrix::Matrix;
pub use vecops::{axpy, dot, norm2_sq, scale_add, scale_add_into_dist2, sub_into, sub_into_dist2};
