//! Lanczos iteration for extremal eigenvalues of sparse symmetric
//! operators.
//!
//! The spectral quantities the paper needs — δ = 1 − |λ₂| and
//! β = 1 − λ_min — are *extremal* eigenvalues of the (doubly-stochastic,
//! symmetric) mixing matrix, exactly what Krylov methods converge to
//! first. With the sparse `MixingMatrix` each matvec is O(|E|), so a
//! full solve is O(m·|E| + n·m²) for m ≪ n Lanczos steps instead of the
//! dense Jacobi's O(n³) — the difference between milliseconds and hours
//! at n = 4096. (Plain power iteration was rejected: its rate is the
//! eigenvalue *ratio*, which for a large ring's λ₂ = 1 − Θ(1/n²) would
//! need Θ(n²) iterations; Lanczos' Chebyshev acceleration does far
//! better on the same matvec budget and gives both ends of the spectrum
//! in one run.)
//!
//! Full reorthogonalization against the stored basis keeps the Ritz
//! values honest (classic Lanczos loses orthogonality exactly when a
//! Ritz pair converges); the basis is m×n, bounded by
//! [`LANCZOS_MAX_ITERS`]. The m×m tridiagonal eigenproblem reuses the
//! in-tree Jacobi solver. Everything is seeded and deterministic.

use super::eigen::symmetric_eigenvalues;
use super::matrix::Matrix;
use crate::util::Rng;

/// Default Krylov-dimension cap. Extremal Ritz values of gossip
/// matrices settle far earlier; the cap bounds basis memory (m·n f64s)
/// and the tridiagonal solve.
pub const LANCZOS_MAX_ITERS: usize = 180;

/// A symmetric linear operator y = A x (the matrix itself is never
/// materialized).
pub trait SymOp {
    fn n(&self) -> usize;
    fn apply(&self, x: &[f64], y: &mut [f64]);
}

/// Extremal Ritz values after a Lanczos run.
#[derive(Clone, Copy, Debug)]
pub struct LanczosExtremes {
    /// Largest Ritz value (→ λ_max from below).
    pub theta_max: f64,
    /// Smallest Ritz value (→ λ_min from above).
    pub theta_min: f64,
    /// Krylov steps actually taken (< the cap on exact breakdown).
    pub iters: usize,
}

/// Run Lanczos with full reorthogonalization from a seeded start vector
/// and return the extremal Ritz values. `max_iters` is clamped to n;
/// an exact breakdown (invariant Krylov subspace) stops early — the
/// Ritz values are then exact for the captured subspace, which contains
/// both extremes whenever the start vector has components in their
/// eigenspaces (a seeded pseudo-random start does, up to rounding).
pub fn lanczos_extremes(op: &dyn SymOp, max_iters: usize, seed: u64) -> LanczosExtremes {
    let n = op.n();
    assert!(n >= 1, "operator must be at least 1×1");
    if n == 1 {
        let mut y = vec![0.0];
        op.apply(&[1.0], &mut y);
        return LanczosExtremes {
            theta_max: y[0],
            theta_min: y[0],
            iters: 1,
        };
    }
    let m_cap = max_iters.clamp(2, n);

    // Seeded start vector, normalized.
    let mut rng = Rng::new(seed);
    let mut v: Vec<f64> = (0..n)
        .map(|_| (rng.next_u64() >> 11) as f64 / (1u64 << 53) as f64 - 0.5)
        .collect();
    let norm = v.iter().map(|x| x * x).sum::<f64>().sqrt();
    for x in v.iter_mut() {
        *x /= norm;
    }

    let mut basis: Vec<Vec<f64>> = vec![v];
    let mut alphas: Vec<f64> = Vec::with_capacity(m_cap);
    let mut betas: Vec<f64> = Vec::with_capacity(m_cap);
    let mut w = vec![0.0f64; n];

    for j in 0..m_cap {
        op.apply(&basis[j], &mut w);
        let alpha = dot64(&basis[j], &w);
        alphas.push(alpha);
        // Three-term recurrence, then full reorthogonalization (the
        // recurrence terms are re-subtracted with everything else).
        for q in basis.iter() {
            let c = dot64(q, &w);
            for (wi, qi) in w.iter_mut().zip(q.iter()) {
                *wi -= c * qi;
            }
        }
        let beta = w.iter().map(|x| x * x).sum::<f64>().sqrt();
        if j + 1 == m_cap || beta < 1e-13 {
            break;
        }
        betas.push(beta);
        basis.push(w.iter().map(|x| x / beta).collect());
    }

    // Ritz values: eigenvalues of the m×m tridiagonal T.
    let m = alphas.len();
    let mut t = Matrix::zeros(m, m);
    for (j, &a) in alphas.iter().enumerate() {
        t[(j, j)] = a;
    }
    for (j, &b) in betas.iter().enumerate().take(m.saturating_sub(1)) {
        t[(j, j + 1)] = b;
        t[(j + 1, j)] = b;
    }
    let eigs = symmetric_eigenvalues(&t, 1e-12);
    LanczosExtremes {
        theta_max: eigs[0],
        theta_min: eigs[m - 1],
        iters: m,
    }
}

fn dot64(x: &[f64], y: &[f64]) -> f64 {
    x.iter().zip(y.iter()).map(|(a, b)| a * b).sum()
}

#[cfg(test)]
mod tests {
    use super::*;

    struct DenseOp(Matrix);

    impl SymOp for DenseOp {
        fn n(&self) -> usize {
            self.0.rows
        }
        fn apply(&self, x: &[f64], y: &mut [f64]) {
            y.copy_from_slice(&self.0.matvec(x));
        }
    }

    #[test]
    fn recovers_extremes_of_a_diagonal_operator() {
        let mut m = Matrix::zeros(50, 50);
        for i in 0..50 {
            m[(i, i)] = i as f64 / 49.0 * 3.0 - 1.0; // spectrum [-1, 2]
        }
        let r = lanczos_extremes(&DenseOp(m), 50, 7);
        assert!((r.theta_max - 2.0).abs() < 1e-9, "max {}", r.theta_max);
        assert!((r.theta_min + 1.0).abs() < 1e-9, "min {}", r.theta_min);
    }

    #[test]
    fn matches_jacobi_on_a_dense_symmetric_matrix() {
        // Deterministic symmetric test matrix.
        let n = 24;
        let mut m = Matrix::zeros(n, n);
        for i in 0..n {
            for j in i..n {
                let v = ((i * 31 + j * 17) % 13) as f64 / 13.0 - 0.5;
                m[(i, j)] = v;
                m[(j, i)] = v;
            }
        }
        let eigs = symmetric_eigenvalues(&m, 1e-12);
        let r = lanczos_extremes(&DenseOp(m), n, 3);
        assert!((r.theta_max - eigs[0]).abs() < 1e-8);
        assert!((r.theta_min - eigs[n - 1]).abs() < 1e-8);
    }

    #[test]
    fn early_breakdown_on_low_rank_is_exact() {
        // Rank-1 projector (1/n)·11ᵀ: spectrum {1, 0}. The Krylov space
        // exhausts after two steps; both extremes are still exact.
        let n = 32;
        let mut m = Matrix::zeros(n, n);
        for i in 0..n {
            for j in 0..n {
                m[(i, j)] = 1.0 / n as f64;
            }
        }
        let r = lanczos_extremes(&DenseOp(m), n, 11);
        assert!(r.iters <= 3, "iters {}", r.iters);
        assert!((r.theta_max - 1.0).abs() < 1e-9);
        assert!(r.theta_min.abs() < 1e-9);
    }

    #[test]
    fn deterministic_for_a_fixed_seed() {
        let mk = || {
            let mut m = Matrix::zeros(16, 16);
            for i in 0..16 {
                m[(i, i)] = (i as f64).cos();
            }
            m
        };
        let a = lanczos_extremes(&DenseOp(mk()), 16, 42);
        let b = lanczos_extremes(&DenseOp(mk()), 16, 42);
        assert_eq!(a.theta_max, b.theta_max);
        assert_eq!(a.theta_min, b.theta_min);
    }

    #[test]
    fn one_by_one_operator() {
        let mut m = Matrix::zeros(1, 1);
        m[(0, 0)] = 0.7;
        let r = lanczos_extremes(&DenseOp(m), 10, 1);
        assert_eq!(r.theta_max, 0.7);
        assert_eq!(r.theta_min, 0.7);
    }
}
