//! Row-major dense matrix over f64 (used for mixing matrices W; node
//! counts are small so f64 keeps the spectral quantities exact enough for
//! the γ*/δ formulas).

use std::ops::{Index, IndexMut};

#[derive(Clone, Debug, PartialEq)]
pub struct Matrix {
    pub rows: usize,
    pub cols: usize,
    data: Vec<f64>,
}

impl Matrix {
    pub fn zeros(rows: usize, cols: usize) -> Matrix {
        Matrix {
            rows,
            cols,
            data: vec![0.0; rows * cols],
        }
    }

    pub fn identity(n: usize) -> Matrix {
        let mut m = Matrix::zeros(n, n);
        for i in 0..n {
            m[(i, i)] = 1.0;
        }
        m
    }

    pub fn from_rows(rows: &[Vec<f64>]) -> Matrix {
        let r = rows.len();
        let c = if r == 0 { 0 } else { rows[0].len() };
        let mut m = Matrix::zeros(r, c);
        for (i, row) in rows.iter().enumerate() {
            assert_eq!(row.len(), c, "ragged rows");
            for (j, &v) in row.iter().enumerate() {
                m[(i, j)] = v;
            }
        }
        m
    }

    pub fn data(&self) -> &[f64] {
        &self.data
    }

    pub fn row(&self, i: usize) -> &[f64] {
        &self.data[i * self.cols..(i + 1) * self.cols]
    }

    pub fn transpose(&self) -> Matrix {
        let mut t = Matrix::zeros(self.cols, self.rows);
        for i in 0..self.rows {
            for j in 0..self.cols {
                t[(j, i)] = self[(i, j)];
            }
        }
        t
    }

    pub fn matmul(&self, other: &Matrix) -> Matrix {
        assert_eq!(self.cols, other.rows);
        let mut out = Matrix::zeros(self.rows, other.cols);
        for i in 0..self.rows {
            for k in 0..self.cols {
                let a = self[(i, k)];
                if a == 0.0 {
                    continue;
                }
                for j in 0..other.cols {
                    out[(i, j)] += a * other[(k, j)];
                }
            }
        }
        out
    }

    /// y = A x
    pub fn matvec(&self, x: &[f64]) -> Vec<f64> {
        assert_eq!(self.cols, x.len());
        let mut y = vec![0.0; self.rows];
        for i in 0..self.rows {
            let row = self.row(i);
            let mut acc = 0.0;
            for (a, b) in row.iter().zip(x.iter()) {
                acc += a * b;
            }
            y[i] = acc;
        }
        y
    }

    pub fn is_symmetric(&self, tol: f64) -> bool {
        if self.rows != self.cols {
            return false;
        }
        for i in 0..self.rows {
            for j in (i + 1)..self.cols {
                if (self[(i, j)] - self[(j, i)]).abs() > tol {
                    return false;
                }
            }
        }
        true
    }

    /// Doubly stochastic: non-negative entries, rows and columns sum to 1.
    pub fn is_doubly_stochastic(&self, tol: f64) -> bool {
        if self.rows != self.cols {
            return false;
        }
        let n = self.rows;
        for i in 0..n {
            let mut rsum = 0.0;
            for j in 0..n {
                if self[(i, j)] < -tol {
                    return false;
                }
                rsum += self[(i, j)];
            }
            if (rsum - 1.0).abs() > tol {
                return false;
            }
        }
        for j in 0..n {
            let mut csum = 0.0;
            for i in 0..n {
                csum += self[(i, j)];
            }
            if (csum - 1.0).abs() > tol {
                return false;
            }
        }
        true
    }

    /// Max |a_ij - b_ij|.
    pub fn max_abs_diff(&self, other: &Matrix) -> f64 {
        assert_eq!((self.rows, self.cols), (other.rows, other.cols));
        self.data
            .iter()
            .zip(other.data.iter())
            .map(|(a, b)| (a - b).abs())
            .fold(0.0, f64::max)
    }

    /// Rows as f32 (for feeding PJRT artifacts).
    pub fn to_f32(&self) -> Vec<f32> {
        self.data.iter().map(|&x| x as f32).collect()
    }
}

impl Index<(usize, usize)> for Matrix {
    type Output = f64;
    #[inline]
    fn index(&self, (i, j): (usize, usize)) -> &f64 {
        &self.data[i * self.cols + j]
    }
}

impl IndexMut<(usize, usize)> for Matrix {
    #[inline]
    fn index_mut(&mut self, (i, j): (usize, usize)) -> &mut f64 {
        &mut self.data[i * self.cols + j]
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn identity_matmul() {
        let i3 = Matrix::identity(3);
        let a = Matrix::from_rows(&[
            vec![1.0, 2.0, 3.0],
            vec![4.0, 5.0, 6.0],
            vec![7.0, 8.0, 9.0],
        ]);
        assert_eq!(i3.matmul(&a), a);
        assert_eq!(a.matmul(&i3), a);
    }

    #[test]
    fn matvec_matches_matmul() {
        let a = Matrix::from_rows(&[vec![1.0, 2.0], vec![3.0, 4.0]]);
        let y = a.matvec(&[1.0, -1.0]);
        assert_eq!(y, vec![-1.0, -1.0]);
    }

    #[test]
    fn transpose_involution() {
        let a = Matrix::from_rows(&[vec![1.0, 2.0, 3.0], vec![4.0, 5.0, 6.0]]);
        assert_eq!(a.transpose().transpose(), a);
    }

    #[test]
    fn doubly_stochastic_check() {
        let w = Matrix::from_rows(&[
            vec![0.5, 0.5, 0.0],
            vec![0.5, 0.0, 0.5],
            vec![0.0, 0.5, 0.5],
        ]);
        assert!(w.is_doubly_stochastic(1e-12));
        let bad = Matrix::from_rows(&[vec![0.9, 0.0], vec![0.1, 1.0]]);
        assert!(!bad.is_doubly_stochastic(1e-12));
    }
}
