//! Fused f32 vector kernels for the coordinator hot path.
//!
//! These run once per node per round over the full parameter vector, so
//! they are written as simple streaming loops the compiler auto-vectorizes
//! (checked via the `gossip_consensus` bench; see EXPERIMENTS.md §Perf).

/// y += a * x
#[inline]
pub fn axpy(a: f32, x: &[f32], y: &mut [f32]) {
    debug_assert_eq!(x.len(), y.len());
    for (yi, xi) in y.iter_mut().zip(x.iter()) {
        *yi += a * xi;
    }
}

/// out = x - y (allocating into `out`)
#[inline]
pub fn sub_into(x: &[f32], y: &[f32], out: &mut [f32]) {
    debug_assert_eq!(x.len(), y.len());
    debug_assert_eq!(x.len(), out.len());
    for ((o, a), b) in out.iter_mut().zip(x.iter()).zip(y.iter()) {
        *o = a - b;
    }
}

/// x += a * (u - v), the consensus accumulation x += γ w_ij (x̂_j − x̂_i).
#[inline]
pub fn scale_add(x: &mut [f32], a: f32, u: &[f32], v: &[f32]) {
    debug_assert_eq!(x.len(), u.len());
    debug_assert_eq!(x.len(), v.len());
    for ((xi, ui), vi) in x.iter_mut().zip(u.iter()).zip(v.iter()) {
        *xi += a * (ui - vi);
    }
}

/// Squared L2 norm (f64 accumulation for stability over large d).
#[inline]
pub fn norm2_sq(x: &[f32]) -> f64 {
    let mut acc = 0.0f64;
    for &v in x {
        acc += (v as f64) * (v as f64);
    }
    acc
}

/// Dot product with f64 accumulation.
#[inline]
pub fn dot(x: &[f32], y: &[f32]) -> f64 {
    debug_assert_eq!(x.len(), y.len());
    let mut acc = 0.0f64;
    for (a, b) in x.iter().zip(y.iter()) {
        acc += (*a as f64) * (*b as f64);
    }
    acc
}

/// Squared L2 distance ‖x − y‖² without materializing the difference.
///
/// Four independent f64 accumulators break the add dependency chain so
/// the loop sustains ~4 lanes of ILP (the trigger check runs this over
/// the full parameter vector for every node at every sync index —
/// EXPERIMENTS.md §Perf, L3 iteration 4).
#[inline]
pub fn dist2(x: &[f32], y: &[f32]) -> f64 {
    debug_assert_eq!(x.len(), y.len());
    let mut acc = [0.0f64; 4];
    let chunks = x.len() / 4;
    for i in 0..chunks {
        let b = i * 4;
        for lane in 0..4 {
            let d = (x[b + lane] - y[b + lane]) as f64;
            acc[lane] += d * d;
        }
    }
    let mut total = acc[0] + acc[1] + acc[2] + acc[3];
    for i in chunks * 4..x.len() {
        let d = (x[i] - y[i]) as f64;
        total += d * d;
    }
    total
}

/// Fused `out = x − y` + ‖x − y‖²: one pass instead of the trigger
/// path's former dist2-then-sub_into double walk. The accumulation
/// replicates [`dist2`] exactly — same 4-lane f64 accumulators, same
/// reduction order — so drift values (and thus every trigger decision)
/// are bit-identical to the unfused pair.
#[inline]
pub fn sub_into_dist2(x: &[f32], y: &[f32], out: &mut [f32]) -> f64 {
    debug_assert_eq!(x.len(), y.len());
    debug_assert_eq!(x.len(), out.len());
    let mut acc = [0.0f64; 4];
    let chunks = x.len() / 4;
    for i in 0..chunks {
        let b = i * 4;
        for lane in 0..4 {
            let d = x[b + lane] - y[b + lane];
            out[b + lane] = d;
            let df = d as f64;
            acc[lane] += df * df;
        }
    }
    let mut total = acc[0] + acc[1] + acc[2] + acc[3];
    for i in chunks * 4..x.len() {
        let d = x[i] - y[i];
        out[i] = d;
        let df = d as f64;
        total += df * df;
    }
    total
}

/// Fused trigger-momentum update `u = beta·u + x` + ‖u‖²: the
/// SQuARM-SGD sync pass folds the momentum-buffered drift update and its
/// norm into one sweep. The accumulation replicates [`sub_into_dist2`]
/// exactly — same 4-lane f64 accumulators, same reduction order — so
/// with `beta = 0` (where `0·u + x` equals `x` as an f32 value) the
/// returned norm is bit-identical to the drift `sub_into_dist2` computes
/// for `x`, which is what pins SQuARM(β = 0) ≡ SPARQ.
#[inline]
pub fn scale_add_into_dist2(beta: f32, u: &mut [f32], x: &[f32]) -> f64 {
    debug_assert_eq!(u.len(), x.len());
    let mut acc = [0.0f64; 4];
    let chunks = u.len() / 4;
    for i in 0..chunks {
        let b = i * 4;
        for lane in 0..4 {
            let d = beta * u[b + lane] + x[b + lane];
            u[b + lane] = d;
            let df = d as f64;
            acc[lane] += df * df;
        }
    }
    let mut total = acc[0] + acc[1] + acc[2] + acc[3];
    for i in chunks * 4..u.len() {
        let d = beta * u[i] + x[i];
        u[i] = d;
        let df = d as f64;
        total += df * df;
    }
    total
}

/// L1 norm with f64 accumulation.
#[inline]
pub fn norm1(x: &[f32]) -> f64 {
    x.iter().map(|&v| (v as f64).abs()).sum()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn axpy_basic() {
        let x = vec![1.0, 2.0, 3.0];
        let mut y = vec![10.0, 10.0, 10.0];
        axpy(2.0, &x, &mut y);
        assert_eq!(y, vec![12.0, 14.0, 16.0]);
    }

    #[test]
    fn sub_and_dist() {
        let x = vec![3.0f32, 4.0];
        let y = vec![0.0f32, 0.0];
        let mut d = vec![0.0f32; 2];
        sub_into(&x, &y, &mut d);
        assert_eq!(d, x);
        assert!((norm2_sq(&d) - 25.0).abs() < 1e-9);
        assert!((dist2(&x, &y) - 25.0).abs() < 1e-9);
    }

    #[test]
    fn scale_add_consensus_shape() {
        let mut x = vec![1.0f32, 1.0];
        let u = vec![2.0f32, 0.0];
        let v = vec![0.0f32, 2.0];
        scale_add(&mut x, 0.5, &u, &v);
        assert_eq!(x, vec![2.0, 0.0]);
    }

    #[test]
    fn sub_into_dist2_bit_matches_unfused_pair() {
        // Lengths straddling the 4-lane chunk boundary, values chosen so
        // intermediate sums actually round (bit-equality is the claim).
        for len in [0usize, 1, 3, 4, 5, 8, 17, 1000] {
            let x: Vec<f32> = (0..len).map(|i| ((i * 37 + 11) as f32).sin() * 3.7).collect();
            let y: Vec<f32> = (0..len).map(|i| ((i * 13 + 5) as f32).cos() * 1.3).collect();
            let mut d_ref = vec![0.0f32; len];
            sub_into(&x, &y, &mut d_ref);
            let dist_ref = dist2(&x, &y);
            let mut d_fused = vec![0.0f32; len];
            let dist_fused = sub_into_dist2(&x, &y, &mut d_fused);
            assert_eq!(d_ref, d_fused, "len {len}");
            assert_eq!(dist_ref.to_bits(), dist_fused.to_bits(), "len {len}");
        }
    }

    #[test]
    fn scale_add_into_dist2_with_zero_beta_bit_matches_sub_into_dist2() {
        // The SQuARM degeneracy pin at the kernel level: β = 0 makes the
        // fused momentum update compute exactly the plain drift, bit for
        // bit, across chunk-boundary lengths.
        for len in [0usize, 1, 3, 4, 5, 8, 17, 1000] {
            let x: Vec<f32> = (0..len).map(|i| ((i * 37 + 11) as f32).sin() * 3.7).collect();
            let y: Vec<f32> = (0..len).map(|i| ((i * 13 + 5) as f32).cos() * 1.3).collect();
            let mut diff = vec![0.0f32; len];
            let drift = sub_into_dist2(&x, &y, &mut diff);
            // stale momentum content must be annihilated by β = 0
            let mut u: Vec<f32> = (0..len).map(|i| (i as f32) - 3.0).collect();
            let drift_fused = scale_add_into_dist2(0.0, &mut u, &diff);
            assert_eq!(drift.to_bits(), drift_fused.to_bits(), "len {len}");
            for (a, b) in u.iter().zip(diff.iter()) {
                assert_eq!(*a, *b, "len {len}");
            }
        }
    }

    #[test]
    fn scale_add_into_dist2_accumulates_momentum() {
        let mut u = vec![2.0f32, -1.0, 0.0, 4.0, 1.0];
        let x = vec![1.0f32, 1.0, 1.0, 1.0, 1.0];
        let n2 = scale_add_into_dist2(0.5, &mut u, &x);
        assert_eq!(u, vec![2.0, 0.5, 1.0, 3.0, 1.5]);
        assert!((n2 - (4.0 + 0.25 + 1.0 + 9.0 + 2.25)).abs() < 1e-12);
    }

    #[test]
    fn dot_and_norm1() {
        assert_eq!(dot(&[1.0, 2.0], &[3.0, -1.0]), 1.0);
        assert_eq!(norm1(&[-1.0, 2.0, -3.0]), 6.0);
    }
}
