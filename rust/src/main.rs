//! `sparq` — SPARQ-SGD launcher.
//!
//! Subcommands:
//!   train    --config cfg.json | preset flags   run one experiment
//!   sweep    --spec spec.json --out results/    declarative config grid,
//!            [--workers N --resume              concurrent + resumable
//!             --checkpoint-every C              (see sweep::SweepSpec);
//!             --target-err E --target-loss L    early-stop budgets;
//!             --distributed=true                cooperative multi-process
//!             --lease-secs S --poll-ms P        claim/lease execution
//!             --lease-margin-secs M]            (+ clock-skew margin)
//!   sweep report --out results/                 savings table + Fig-1 CSV
//!            [--target-err E | --target-loss L  panels from results.jsonl,
//!             --csv-dir D]                      no re-running
//!   sweep status --out results/                 held distributed claims:
//!            [--lease-secs S                    owner, heartbeat age,
//!             --lease-margin-secs M]            staleness
//!   check    --spec spec.json | --config c.json resolve every run of a
//!                                               spec (config-schema gate)
//!   serve    --socket sock --out results/       long-lived sweep daemon:
//!            [--workers N --checkpoint-every C  typed spec submission over
//!             --lease-secs S --poll-ms P        a Unix/TCP socket, priority
//!             --lease-margin-secs M             scheduling, event streaming,
//!             --jobs-retain N --auth-token T    exactly-once restart takeover;
//!             --quiet]                          retention GC of settled job
//!                                               files; optional token auth
//!   submit   --socket sock --spec spec.json     submit a spec to a daemon
//!            [--priority P --wait]              (--wait streams until done)
//!   watch    --socket sock [--job J --tail]     stream daemon events (JSONL)
//!   status   --socket sock                      live daemon queue + claim
//!                                               tables (remote status)
//!   cancel   --socket sock --job J              release a job's queued runs
//!                                               (running ones finish; the
//!                                               cancel survives restarts)
//!   shutdown --socket sock                      stop a daemon gracefully
//!            (submit/watch/status/cancel/shutdown also take --auth-token T
//!             when the daemon requires it)
//!   cluster  --dir /shared/c1 [config flags     real multi-process run: one
//!            --checkpoint-every C --verify       OS process per node over
//!            --timeout-secs S --quiet]           UDS/TCP (see --cluster spec);
//!                                               lockstep runs are bit-identical
//!                                               to in-process; fault-plan crash
//!                                               windows become real SIGKILLs +
//!                                               checkpoint-restore rejoins
//!   fig1a|fig1b                                 convex suite (Fig 1a/1b)
//!   fig1c|fig1d                                 non-convex suite (Fig 1c/1d)
//!   families --steps 2000 [--seed S             cross-family panel: SPARQ
//!            --workers N --target-loss L]       vs SQuARM vs per-coordinate
//!                                               triggers vs CHOCO baseline
//!   spectral --topology ring --nodes 60         print δ, β, γ*, p
//!   ablate   --knob h|c0|k|gamma|all            Remark-1 knob sweeps
//!   robustness --steps 2000 --out results/      lossy links + switching
//!                                               topologies sweep
//!   chaos    --plans p1;p2 --steps 2000         seeded fault plans (crash/
//!            [--seed S --workers N --out D]     partition/corrupt) vs the
//!                                               fault-free baseline
//!   perfgate --measured bench.json              CI perf regression gate
//!            [--baseline BENCH_....json         vs the committed snapshot
//!             --max-regress 0.15]
//!   artifacts                                   list + smoke the manifest
//!   version
//!
//! Examples:
//!   sparq train --algo sparq --nodes 8 --steps 2000 --problem quadratic:64
//!   sparq train --workers 8 --nodes 16 --problem quadratic:4096
//!   sparq train --link drop:0.2 --trigger const:50 --h 2
//!   sparq train --nodes 16 --topology-schedule switch:ring,torus:500
//!   sparq sweep --spec examples/specs/fig1_convex.json --out results/fig1 --workers 8
//!   sparq sweep --spec examples/specs/smoke.json --out /tmp/sweep --resume
//!   sparq sweep --spec grid.json --out /shared/fig1 --distributed=true --lease-secs 60
//!   sparq sweep report --out /shared/fig1 --target-err 0.15
//!   sparq serve --socket /tmp/sparq.sock --out /shared/fig1 --workers 8
//!   sparq submit --socket /tmp/sparq.sock --spec examples/specs/smoke.json --wait
//!   sparq watch --socket /tmp/sparq.sock --job job-0123456789abcdef
//!   sparq perfgate --baseline BENCH_sparse_fastpath.json --measured /tmp/bench.json
//!   sparq fig1b --steps 4000 --out results/
//!   sparq spectral --topology torus --nodes 16
//!   sparq robustness --steps 2000 --drops 0.0,0.1,0.3
//!   sparq chaos --plans "crash:3:500:1200;corrupt:0.01" --steps 2000
//!   sparq cluster --dir /tmp/c1 --nodes 4 --steps 200 --verify
//!   sparq cluster --dir /tmp/c1 --nodes 4 --cluster tcp@127.0.0.1:8:2

use sparq::config::{Algo, ExperimentConfig};
use sparq::experiments::{fig1, run_config};
use sparq::graph::{uniform_neighbor, SpectralInfo, Topology, TopologyKind};
use sparq::util::cli::Args;

fn main() {
    let args = Args::from_env();
    match args.subcommand() {
        Some("train") => cmd_train(&args),
        Some("sweep") => cmd_sweep(&args),
        Some("check") => cmd_check(&args),
        Some("serve") => cmd_serve(&args),
        Some("submit") => cmd_submit(&args),
        Some("watch") => cmd_watch(&args),
        Some("status") => cmd_remote_status(&args),
        Some("cancel") => cmd_cancel(&args),
        Some("shutdown") => cmd_shutdown(&args),
        Some("cluster") => cmd_cluster(&args),
        // Hidden: what `cluster` spawns, one process per rank.
        Some("cluster-node") => cmd_cluster_node(&args),
        Some("fig1a") | Some("fig1b") => cmd_fig1_convex(&args),
        Some("fig1c") | Some("fig1d") => cmd_fig1_nonconvex(&args),
        Some("families") => cmd_families(&args),
        Some("spectral") => cmd_spectral(&args),
        Some("ablate") => cmd_ablate(&args),
        Some("robustness") => cmd_robustness(&args),
        Some("chaos") => cmd_chaos(&args),
        Some("perfgate") => cmd_perfgate(&args),
        Some("artifacts") => cmd_artifacts(),
        Some("version") => println!("sparq-sgd {}", sparq::version()),
        _ => {
            eprintln!(
                "usage: sparq <train|sweep|sweep report|sweep status|check|serve|submit|watch|status|cancel|shutdown|cluster|fig1a|fig1b|fig1c|fig1d|families|spectral|ablate|robustness|chaos|perfgate|artifacts|version> [flags]\n\
                 see `rust/src/main.rs` header for examples"
            );
            std::process::exit(2);
        }
    }
}

fn cmd_sweep(args: &Args) {
    use sparq::sweep::{
        run_distributed, run_spec, ArtifactCache, DistributedOptions, SweepOptions, SweepSpec,
    };

    if args.positional.get(1).map(|s| s.as_str()) == Some("report") {
        return cmd_sweep_report(args);
    }
    if args.positional.get(1).map(|s| s.as_str()) == Some("status") {
        return cmd_sweep_status(args);
    }
    let Some(spec_path) = args.get("spec") else {
        eprintln!("sweep requires --spec spec.json (see examples/specs/)");
        std::process::exit(2);
    };
    let spec = SweepSpec::from_file(spec_path).unwrap_or_else(|e| {
        eprintln!("spec error: {e}");
        std::process::exit(2);
    });
    let distributed = args.bool("distributed");
    let mut opts = SweepOptions {
        workers: args.usize("workers", 0),
        out: args.get("out").map(std::path::PathBuf::from),
        resume: args.bool("resume") || distributed,
        checkpoint_every: args.u64("checkpoint-every", 0),
        verbose: !args.bool("quiet"),
        // Test hook (crash simulation for the takeover tests).
        fault_abort_at: args.get("fault-abort-at").map(|_| args.u64("fault-abort-at", 0)),
        target_error: args.get("target-err").map(|_| args.f64("target-err", 0.0)),
        target_loss: args.get("target-loss").map(|_| args.f64("target-loss", 0.0)),
        on_event: None,
    };
    opts = spec.apply_targets(&opts);
    check_cli_targets(opts.target_error, opts.target_loss);
    println!(
        "sweep {:?}: {} runs{}{}",
        spec.name,
        spec.len(),
        if opts.resume { " (resume)" } else { "" },
        if distributed { " (distributed)" } else { "" }
    );
    let report = if distributed {
        let dopts = DistributedOptions {
            lease_secs: args
                .get("lease-secs")
                .map(|_| args.f64("lease-secs", 0.0))
                .or(spec.lease_secs)
                .unwrap_or(60.0),
            // Clock-skew allowance (CLI > spec > 2s default — one
            // filesystem does not imply one clock domain).
            lease_margin_secs: args
                .get("lease-margin-secs")
                .map(|_| args.f64("lease-margin-secs", 0.0))
                .or(spec.lease_margin_secs)
                .unwrap_or(2.0),
            heartbeat_secs: args.f64("heartbeat-secs", 0.0),
            poll_ms: args.u64("poll-ms", 200),
            owner: args.get_or("owner", ""),
        };
        let runs = spec.expand().unwrap_or_else(|e| {
            eprintln!("spec error: {e}");
            std::process::exit(2);
        });
        run_distributed(runs, &opts, &dopts, &ArtifactCache::new())
    } else {
        run_spec(&spec, &opts)
    }
    .unwrap_or_else(|e| {
        eprintln!("sweep error: {e}");
        std::process::exit(1);
    });
    println!(
        "\n{:<44} {:>12} {:>12} {:>14} {:>9}",
        "run", "final loss", "final err", "bits", "tx rate"
    );
    for o in &report.outcomes {
        let note = if o.skipped {
            "  (cached)".to_string()
        } else if let Some(stop) = &o.stopped {
            format!("  (early-stop t={})", stop.t)
        } else {
            String::new()
        };
        let last = o.series.records.last();
        println!(
            "{:<44} {:>12.5} {:>12.4} {:>14} {:>8.1}%{}",
            o.cfg.name,
            last.map(|r| r.loss).unwrap_or(f64::NAN),
            last.map(|r| r.test_error).unwrap_or(f64::NAN),
            last.map(|r| r.bits).unwrap_or(0),
            100.0 * o.fired as f64 / o.checks.max(1) as f64,
            note,
        );
    }
    println!(
        "\nsweep complete: {} executed, {} skipped, {} total ({} ms; cache: {})",
        report.executed,
        report.skipped,
        report.outcomes.len(),
        report.wall_ms,
        report.cache_summary
    );
    if let Some(out) = &opts.out {
        println!(
            "results: {} + series/<id>.jsonl",
            out.join("results.jsonl").display()
        );
    }
}

/// CLI-provided targets get the same validation spec-declared ones do
/// (a non-finite --target-loss would otherwise truncate every run at
/// its t=0 record and poison the output directory; on `sweep report`
/// an out-of-range target silently renders "(not reached)" everywhere).
fn check_cli_targets(target_error: Option<f64>, target_loss: Option<f64>) {
    if let Some(te) = target_error {
        if !(te.is_finite() && te > 0.0 && te <= 1.0) {
            eprintln!("--target-err must lie in (0, 1] (test error is a rate), got {te}");
            std::process::exit(2);
        }
    }
    if let Some(tl) = target_loss {
        if !tl.is_finite() {
            eprintln!("--target-loss must be finite, got {tl}");
            std::process::exit(2);
        }
    }
}

fn cmd_sweep_report(args: &Args) {
    use sparq::sweep::report::{self, TargetMetric};

    let Some(out) = args.get("out") else {
        eprintln!("sweep report requires --out <sweep output dir>");
        std::process::exit(2);
    };
    let out = std::path::Path::new(out);
    let runs = report::load(out).unwrap_or_else(|e| {
        eprintln!("report error: {e}");
        std::process::exit(1);
    });
    if runs.is_empty() {
        eprintln!("no completed runs in {}", out.display());
        std::process::exit(1);
    }
    let (metric, target) = if args.has("target-loss") {
        let t = args.f64("target-loss", 0.0);
        check_cli_targets(None, Some(t));
        (TargetMetric::Loss, t)
    } else {
        let t = args.f64("target-err", 0.15);
        check_cli_targets(Some(t), None);
        (TargetMetric::TestError, t)
    };
    print!("{}", report::savings_table(&runs, metric, target));
    println!();
    print!("{}", report::family_table(&runs, metric, target));
    let csv_dir = args
        .get("csv-dir")
        .map(std::path::PathBuf::from)
        .unwrap_or_else(|| out.join("report"));
    let paths = report::write_panels(&runs, &csv_dir).unwrap_or_else(|e| {
        eprintln!("report error: {e}");
        std::process::exit(1);
    });
    for p in paths {
        println!("wrote {}", p.display());
    }
}

fn cmd_sweep_status(args: &Args) {
    use sparq::sweep::{list_claims, now_secs, status_table};

    let Some(out) = args.get("out") else {
        eprintln!("sweep status requires --out <sweep output dir>");
        std::process::exit(2);
    };
    let lease = args.f64("lease-secs", 60.0);
    let margin = args.f64("lease-margin-secs", 2.0);
    let claims = list_claims(std::path::Path::new(out), now_secs()).unwrap_or_else(|e| {
        eprintln!("status error: {e}");
        std::process::exit(1);
    });
    if claims.is_empty() {
        println!("no held claims under {out}/claims/");
        return;
    }
    print!("{}", status_table(&claims, lease, margin));
}

fn require_socket(args: &Args, cmd: &str) -> String {
    // `--remote` is accepted as an alias for `--socket` on the client
    // commands (reads naturally for `sparq status --remote addr`).
    match args.get("socket").or_else(|| args.get("remote")) {
        Some(s) => s.to_string(),
        None => {
            eprintln!("{cmd} requires --socket <path|host:port>");
            std::process::exit(2);
        }
    }
}

fn connect_daemon(socket: &str, args: &Args) -> sparq::serve::Client {
    let mut client = sparq::serve::Client::connect_retry(socket, std::time::Duration::from_secs(10))
        .unwrap_or_else(|e| {
            eprintln!("connect error: {e}");
            std::process::exit(1);
        });
    // An --auth-token daemon requires this as the first request; with
    // no flag we send nothing, so open daemons behave as before.
    if let Some(token) = args.get("auth-token") {
        if let Err(e) = client.auth(token) {
            eprintln!("auth error: {e}");
            std::process::exit(1);
        }
    }
    client
}

fn cmd_serve(args: &Args) {
    use sparq::serve::{serve, ServeConfig};

    let socket = require_socket(args, "serve");
    let Some(out) = args.get("out") else {
        eprintln!("serve requires --out <dir>");
        std::process::exit(2);
    };
    let cfg = ServeConfig {
        socket,
        out: std::path::PathBuf::from(out),
        workers: args.usize("workers", 0),
        checkpoint_every: args.u64("checkpoint-every", 0),
        lease_secs: args.f64("lease-secs", 60.0),
        lease_margin_secs: args.f64("lease-margin-secs", 2.0),
        heartbeat_secs: args.f64("heartbeat-secs", 0.0),
        poll_ms: args.u64("poll-ms", 200),
        // Test hook (crash simulation for the takeover tests).
        fault_abort_at: args
            .get("fault-abort-at")
            .map(|_| args.u64("fault-abort-at", 0)),
        event_capacity: args.usize("event-capacity", 4096),
        jobs_retain: args.usize("jobs-retain", 0),
        auth_token: args.get("auth-token").cloned(),
        verbose: !args.bool("quiet"),
    };
    if let Err(e) = serve(cfg) {
        eprintln!("serve error: {e}");
        std::process::exit(1);
    }
}

fn cmd_submit(args: &Args) {
    use sparq::util::json::Json;

    let socket = require_socket(args, "submit");
    let Some(spec_path) = args.get("spec") else {
        eprintln!("submit requires --spec spec.json");
        std::process::exit(2);
    };
    let text = std::fs::read_to_string(spec_path).unwrap_or_else(|e| {
        eprintln!("{spec_path}: {e}");
        std::process::exit(2);
    });
    let spec = Json::parse(&text).unwrap_or_else(|e| {
        eprintln!("{spec_path}: {e}");
        std::process::exit(2);
    });
    let priority = args.f64("priority", 0.0) as i64;
    let mut client = connect_daemon(&socket, args);
    let job = match client.submit(&spec, priority) {
        Ok((job, runs)) => {
            println!("accepted {job}: {runs} run(s)");
            job
        }
        Err(e) => {
            eprintln!("{spec_path}: {e}");
            std::process::exit(1);
        }
    };
    if args.bool("wait") {
        let watcher = connect_daemon(&socket, args);
        let result = watcher.watch(true, &mut |_seq, event| {
            if event.get("job").and_then(Json::as_str) != Some(job.as_str()) {
                return true;
            }
            println!("{}", event.to_string());
            event.get("kind").and_then(Json::as_str) != Some("job-complete")
        });
        if let Err(e) = result {
            eprintln!("watch error: {e}");
            std::process::exit(1);
        }
    }
}

fn cmd_watch(args: &Args) {
    use sparq::util::json::Json;

    let socket = require_socket(args, "watch");
    let job_filter = args.get("job").map(str::to_string);
    // Default replays the daemon's full event log; --tail streams only
    // events published after this subscriber attached.
    let from_start = !args.bool("tail");
    let client = connect_daemon(&socket, args);
    let result = client.watch(from_start, &mut |seq, event| {
        if let Some(jf) = &job_filter {
            if event.get("job").and_then(Json::as_str) != Some(jf.as_str()) {
                return true;
            }
            println!("{}", event.to_string());
            // With a job filter, the stream is finite: stop at the
            // job's completion record.
            return event.get("kind").and_then(Json::as_str) != Some("job-complete");
        }
        println!("[{seq}] {}", event.to_string());
        true
    });
    if let Err(e) = result {
        eprintln!("watch error: {e}");
        std::process::exit(1);
    }
}

fn cmd_remote_status(args: &Args) {
    let socket = require_socket(args, "status");
    let mut client = connect_daemon(&socket, args);
    let (jobs, claims) = client.status().unwrap_or_else(|e| {
        eprintln!("status error: {e}");
        std::process::exit(1);
    });
    if jobs.is_empty() {
        println!("no jobs submitted");
    } else {
        println!(
            "{:<22} {:<20} {:>8} {:>12} {:>7} {:<9}",
            "job", "name", "priority", "done/total", "failed", "state"
        );
        for j in &jobs {
            println!(
                "{:<22} {:<20} {:>8} {:>12} {:>7} {:<9}",
                j.job,
                j.name,
                j.priority,
                format!("{}/{}", j.done, j.total),
                j.failed,
                j.state
            );
        }
    }
    if claims.is_empty() {
        println!("no held claims");
    } else {
        println!(
            "\n{:<18} {:<22} {:>10} {:>11}",
            "claim", "owner", "age (s)", "heartbeats"
        );
        for c in &claims {
            println!(
                "{:<18} {:<22} {:>10.1} {:>11}",
                c.id, c.owner, c.age_secs, c.heartbeats
            );
        }
    }
}

fn cmd_cancel(args: &Args) {
    let socket = require_socket(args, "cancel");
    let Some(job) = args.get("job") else {
        eprintln!("cancel requires --job <job id>");
        std::process::exit(2);
    };
    let mut client = connect_daemon(&socket, args);
    match client.cancel(job) {
        Ok(released) => println!("cancelled {job}: released {released} queued run(s)"),
        Err(e) => {
            eprintln!("cancel error: {e}");
            std::process::exit(1);
        }
    }
}

fn cmd_shutdown(args: &Args) {
    let socket = require_socket(args, "shutdown");
    let mut client = connect_daemon(&socket, args);
    match client.shutdown() {
        Ok(()) => println!("daemon at {socket} shutting down"),
        Err(e) => {
            eprintln!("shutdown error: {e}");
            std::process::exit(1);
        }
    }
}

/// Config-schema gate: feed a sweep spec (or a single config) through
/// `ExperimentConfig::resolve()` without running anything. CI points it
/// at every `examples/specs/*.json`.
fn cmd_check(args: &Args) {
    use sparq::sweep::SweepSpec;

    if let Some(path) = args.get("config") {
        let cfg = ExperimentConfig::from_file(path).unwrap_or_else(|e| {
            eprintln!("{path}: {e}");
            std::process::exit(1);
        });
        if let Err(e) = cfg.resolve() {
            eprintln!("{path}: {e}");
            std::process::exit(1);
        }
        println!("{path}: config resolves OK");
        return;
    }
    let Some(spec_path) = args.get("spec") else {
        eprintln!("check requires --spec spec.json or --config cfg.json");
        std::process::exit(2);
    };
    let spec = SweepSpec::from_file(spec_path).unwrap_or_else(|e| {
        eprintln!("{spec_path}: {e}");
        std::process::exit(1);
    });
    let runs = spec.expand().unwrap_or_else(|e| {
        eprintln!("{spec_path}: {e}");
        std::process::exit(1);
    });
    for (label, cfg) in &runs {
        if let Err(e) = cfg.resolve() {
            eprintln!("{spec_path}: run {label:?} ({}): {e}", cfg.name);
            std::process::exit(1);
        }
    }
    println!("{spec_path}: {} run(s) resolve OK", runs.len());
}

fn cmd_perfgate(args: &Args) {
    use sparq::util::bench::perf_gate;
    use sparq::util::json::Json;

    let baseline_path = args.get_or("baseline", "BENCH_sparse_fastpath.json");
    let Some(measured_path) = args.get("measured") else {
        eprintln!("perfgate requires --measured bench.json (a fresh bench snapshot)");
        std::process::exit(2);
    };
    let load = |path: &str| -> Json {
        let text = std::fs::read_to_string(path).unwrap_or_else(|e| {
            eprintln!("perfgate: {path}: {e}");
            std::process::exit(2);
        });
        Json::parse(&text).unwrap_or_else(|e| {
            eprintln!("perfgate: {path}: {e}");
            std::process::exit(2);
        })
    };
    let baseline = load(&baseline_path);
    let measured = load(measured_path);
    let keys: Vec<String> = args
        .get_or("keys", "speedup_sparse_parallel,node_steps_per_sec")
        .split(',')
        .map(str::to_string)
        .collect();
    let keys: Vec<&str> = keys.iter().map(String::as_str).collect();
    let max_regress = args.f64("max-regress", 0.15);
    match perf_gate(&baseline, &measured, &keys, max_regress) {
        Ok(lines) => {
            for line in lines {
                println!("{line}");
            }
            println!("perf gate OK (tolerance {:.0}%)", max_regress * 100.0);
        }
        Err(e) => {
            eprintln!("perf gate FAILED: {e}");
            std::process::exit(1);
        }
    }
}

fn config_from_args(args: &Args) -> ExperimentConfig {
    let mut cfg = if let Some(path) = args.get("config") {
        ExperimentConfig::from_file(path).unwrap_or_else(|e| {
            eprintln!("config error: {e}");
            std::process::exit(2);
        })
    } else {
        ExperimentConfig::default()
    };
    if let Some(a) = args.get("algo") {
        cfg.algo = Algo::parse(a).unwrap_or_else(|| {
            eprintln!("unknown algo {a:?}");
            std::process::exit(2);
        });
    }
    if let Some(v) = args.get("nodes") {
        cfg.nodes = v.parse().expect("--nodes");
    }
    // Typed spec flags: parse at the boundary, exit with the structured
    // error (field/value/reason/suggestion) on bad input.
    fn parse_flag<T: std::str::FromStr<Err = sparq::config::ConfigError>>(
        args: &Args,
        flag: &str,
        slot: &mut T,
    ) {
        if let Some(v) = args.get(flag) {
            *slot = v.parse().unwrap_or_else(|e| {
                eprintln!("--{flag}: {e}");
                std::process::exit(2);
            });
        }
    }
    parse_flag(args, "topology", &mut cfg.topology);
    parse_flag(args, "topology-schedule", &mut cfg.topology_schedule);
    parse_flag(args, "link", &mut cfg.link);
    parse_flag(args, "compressor", &mut cfg.compressor);
    parse_flag(args, "trigger", &mut cfg.trigger);
    parse_flag(args, "lr", &mut cfg.lr);
    parse_flag(args, "problem", &mut cfg.problem);
    parse_flag(args, "h", &mut cfg.h);
    parse_flag(args, "fault", &mut cfg.fault);
    parse_flag(args, "cluster", &mut cfg.cluster);
    cfg.steps = args.u64("steps", cfg.steps);
    cfg.eval_every = args.u64("eval-every", cfg.eval_every);
    cfg.momentum = args.f64("momentum", cfg.momentum);
    cfg.seed = args.u64("seed", cfg.seed);
    cfg.workers = args.usize("workers", cfg.workers);
    cfg
}

fn cmd_train(args: &Args) {
    let cfg = config_from_args(args);
    println!("running {:?}", cfg.name);
    let series = run_config(&cfg, true);
    if let Some(out) = args.get("out") {
        std::fs::create_dir_all(out).ok();
        let path = std::path::Path::new(out).join(format!("{}.csv", cfg.name));
        series.write_csv(&path).expect("write csv");
        println!("wrote {}", path.display());
    }
    let last = series.records.last().expect("at least one record");
    println!(
        "final: t={} loss={:.5} err={:.4} bits={} comm_rounds={}",
        last.t, last.loss, last.test_error, last.bits, last.comm_rounds
    );
}

fn write_series(series: &[sparq::metrics::Series], out: Option<&str>) {
    if let Some(out) = out {
        std::fs::create_dir_all(out).ok();
        for s in series {
            let fname = s.label.replace([' ', '(', ')', '/'], "_") + ".csv";
            let path = std::path::Path::new(out).join(fname);
            s.write_csv(&path).expect("write csv");
            println!("wrote {}", path.display());
        }
    }
}

fn cmd_fig1_convex(args: &Args) {
    let steps = args.u64("steps", 3000);
    let seed = args.u64("seed", 42);
    let target = args.f64("target-err", 0.15);
    let suite = fig1::convex_suite(steps, seed);
    let series = fig1::run_suite(suite, true);
    println!("\n=== Figure 1a/1b: convex (synthetic MNIST, n=60 ring) ===");
    println!("{}", fig1::savings_table(&series, target));
    write_series(&series, args.get("out"));
}

fn cmd_fig1_nonconvex(args: &Args) {
    let steps = args.u64("steps", 2000);
    let spe = args.usize("steps-per-epoch", 100);
    let seed = args.u64("seed", 42);
    let target = args.f64("target-err", 0.2);
    let problem = args.get_or("problem", "mlp:512:64:10:16");
    let suite = fig1::nonconvex_suite(steps, spe, seed, &problem);
    let series = fig1::run_suite(suite, true);
    println!("\n=== Figure 1c/1d: non-convex (synthetic CIFAR MLP, n=8 ring) ===");
    println!("{}", fig1::savings_table(&series, target));
    write_series(&series, args.get("out"));
}

fn cmd_families(args: &Args) {
    use sparq::experiments::families;
    use sparq::sweep::report::{family_table, savings_table, TargetMetric};
    use sparq::sweep::SweepOptions;

    let steps = args.u64("steps", 2000);
    let seed = args.u64("seed", 42);
    let opts = SweepOptions {
        workers: args.usize("workers", 0),
        out: args.get("out").map(std::path::PathBuf::from),
        verbose: !args.bool("quiet"),
        ..SweepOptions::default()
    };
    let runs = families::run_family_comparison(steps, seed, &opts).unwrap_or_else(|e| {
        eprintln!("families error: {e}");
        std::process::exit(1);
    });
    let (metric, target) = if args.has("target-err") {
        let t = args.f64("target-err", 0.15);
        check_cli_targets(Some(t), None);
        (TargetMetric::TestError, t)
    } else if args.has("target-loss") {
        let t = args.f64("target-loss", 0.0);
        check_cli_targets(None, Some(t));
        (TargetMetric::Loss, t)
    } else {
        // Default target: the worst final loss across the grid (with a
        // little headroom), so every family registers in the panel.
        let worst = runs
            .iter()
            .filter_map(|r| r.series.records.last().map(|rec| rec.loss))
            .fold(f64::MIN, f64::max);
        (TargetMetric::Loss, worst * 1.02)
    };
    println!("\n=== family comparison: SPARQ / SQuARM / per-coordinate / CHOCO ===");
    print!("{}", savings_table(&runs, metric, target));
    println!();
    print!("{}", family_table(&runs, metric, target));
}

fn cmd_ablate(args: &Args) {
    use sparq::experiments::ablation::{self, AblationBase};
    let base = AblationBase {
        n: args.usize("nodes", 8),
        d: args.usize("dim", 64),
        steps: args.u64("steps", 4000),
        seed: args.u64("seed", 11),
        workers: args.usize("workers", 0),
    };
    let which = args.get_or("knob", "all");
    if which == "h" || which == "all" {
        println!("-- H sweep (local iterations; Remark 1(ii)) --");
        println!("{}", ablation::table(&ablation::h_sweep(&base, &[1, 2, 5, 10, 25])));
    }
    if which == "c0" || which == "all" {
        println!("-- c0 sweep (trigger threshold; Remark 1(iii)) --");
        println!(
            "{}",
            ablation::table(&ablation::c0_sweep(&base, &[0.0, 10.0, 50.0, 200.0, 1000.0]))
        );
    }
    if which == "k" || which == "all" {
        println!("-- k sweep (compression level; Remark 1(i)) --");
        let ks = [base.d / 16, base.d / 8, base.d / 4, base.d / 2];
        println!("{}", ablation::table(&ablation::k_sweep(&base, &ks)));
    }
    if which == "gamma" || which == "all" {
        println!("-- gamma sweep (consensus step size; Lemma 6 vs tuned) --");
        println!(
            "{}",
            ablation::table(&ablation::gamma_sweep(&base, &[0.01, 0.05, 0.1, 0.25, 0.5]))
        );
    }
}

fn cmd_robustness(args: &Args) {
    use sparq::experiments::robustness;
    let steps = args.u64("steps", 2000);
    let seed = args.u64("seed", 42);
    let workers = args.usize("workers", 0);
    let drops: Vec<f64> = args
        .get_or("drops", "0.0,0.1,0.3")
        .split(',')
        .map(|p| p.parse().unwrap_or_else(|_| panic!("--drops expects numbers, got {p:?}")))
        .collect();
    println!("-- lossy links: SPARQ vs CHOCO vs vanilla, drop p ∈ {drops:?} --");
    let (points, mut series) = robustness::drop_sweep(steps, seed, &drops, workers);
    println!("{}", robustness::table(&points));
    println!("-- time-varying topology: static ring / static torus / switch --");
    let (points, switch_series) = robustness::switch_sweep(steps, seed, workers);
    println!("{}", robustness::table(&points));
    series.extend(switch_series);
    write_series(&series, args.get("out"));
}

fn cmd_chaos(args: &Args) {
    use sparq::experiments::robustness;
    let steps = args.u64("steps", 2000);
    let seed = args.u64("seed", 42);
    let workers = args.usize("workers", 0);
    let plans_raw = args.get_or(
        "plans",
        "crash:3:500:1200;partition:800:1400:0-7|8-15;corrupt:0.01",
    );
    let plans: Vec<&str> = plans_raw
        .split(';')
        .map(str::trim)
        .filter(|s| !s.is_empty())
        .collect();
    if plans.is_empty() {
        eprintln!("chaos requires at least one plan in --plans (';'-separated fault specs)");
        std::process::exit(2);
    }
    println!("-- chaos: seeded fault plans vs fault-free baseline (n=16 ring) --");
    let (points, series) =
        robustness::chaos_sweep(steps, seed, &plans, workers).unwrap_or_else(|e| {
            eprintln!("chaos error: {e}");
            std::process::exit(2);
        });
    println!("{}", robustness::chaos_table(&points));
    write_series(&series, args.get("out"));
}

fn cmd_cluster(args: &Args) {
    use sparq::cluster::{run_cluster, ClusterOptions};

    let cfg = config_from_args(args);
    let Some(dir) = args.get("dir") else {
        eprintln!("cluster requires --dir <shared cluster dir>");
        std::process::exit(2);
    };
    let exe = std::env::current_exe().unwrap_or_else(|e| {
        eprintln!("cluster: cannot locate own binary: {e}");
        std::process::exit(1);
    });
    let opts = ClusterOptions {
        cfg,
        dir: std::path::PathBuf::from(dir),
        exe,
        checkpoint_every: args.u64("checkpoint-every", 0),
        verify: args.bool("verify"),
        verbose: !args.bool("quiet"),
        timeout_secs: args.f64("timeout-secs", 600.0),
    };
    println!(
        "cluster: {} nodes over {} in {}",
        opts.cfg.nodes,
        opts.cfg.cluster.as_str(),
        opts.dir.display()
    );
    match run_cluster(&opts) {
        Ok(report) => {
            println!(
                "cluster complete: {} nodes, series {}, bits {}, fired {}/{}",
                report.nodes, report.series_hash, report.total_bits, report.fired, report.checks
            );
            for k in &report.kills {
                println!(
                    "kill: node-{} SIGKILLed at t={}, rejoined at t={}",
                    k.rank, k.t_down, k.t_up
                );
            }
            if report.crashes > 0 {
                println!(
                    "faults: {} crash(es), {} resync charge(s)",
                    report.crashes, report.resyncs
                );
            }
            if report.wire_fallbacks > 0 || report.wire_mismatches > 0 {
                println!(
                    "wire degradation: {} fallback(s), {} mismatch(es)",
                    report.wire_fallbacks, report.wire_mismatches
                );
            }
            if report.verified.is_some() {
                println!("verified: bit-identical to the in-process engine");
            }
            println!("report: {}", opts.dir.join("report.json").display());
        }
        Err(e) => {
            eprintln!("cluster error: {e}");
            std::process::exit(1);
        }
    }
}

fn cmd_cluster_node(args: &Args) {
    use sparq::cluster::{run_node, NodeOptions};

    let Some(dir) = args.get("dir") else {
        eprintln!("cluster-node requires --dir <shared cluster dir>");
        std::process::exit(2);
    };
    let dir = std::path::PathBuf::from(dir);
    let cfg_path = dir.join("config.json");
    let cfg = ExperimentConfig::from_file(&cfg_path.display().to_string()).unwrap_or_else(|e| {
        eprintln!("cluster-node: {e}");
        std::process::exit(2);
    });
    let opts = NodeOptions {
        rank: args.usize("rank", 0),
        dir,
        cfg,
        checkpoint_every: args.u64("checkpoint-every", 0),
        mute_until: args.u64("mute-until", 0),
        min_crash_start: args.u64("min-crash-start", 0),
        verbose: args.bool("verbose"),
    };
    if let Err(e) = run_node(opts) {
        eprintln!("cluster-node error: {e}");
        std::process::exit(1);
    }
}

fn cmd_spectral(args: &Args) {
    let n = args.usize("nodes", 60);
    let kind = TopologyKind::parse(&args.get_or("topology", "ring")).unwrap_or_else(|| {
        eprintln!("unknown topology");
        std::process::exit(2);
    });
    let topo = Topology::new(kind, n, args.u64("seed", 0));
    let mixing = uniform_neighbor(&topo);
    let s = SpectralInfo::compute(&mixing);
    let omega = args.f64("omega", 0.1);
    let gamma = s.gamma_star(omega);
    println!(
        "topology={:?} n={n}\n  δ (spectral gap) = {:.6}\n  β = ‖I−W‖₂     = {:.6}\n  γ*(ω={omega})     = {:.3e}\n  p = γ*δ/8        = {:.6e}  (bound δ²ω/644 = {:.6e})",
        kind, s.delta, s.beta, gamma, s.p(gamma), s.p_lower_bound(omega)
    );
}

fn cmd_artifacts() {
    match sparq::runtime::Manifest::load_default() {
        Some(m) => {
            println!("artifact dir: {}", m.dir.display());
            for (name, a) in &m.artifacts {
                let ins: Vec<String> = a
                    .inputs
                    .iter()
                    .map(|t| format!("{}{:?}", &t.dtype[..1], t.shape))
                    .collect();
                println!("  {:<32} {}", name, ins.join(", "));
            }
            match sparq::runtime::Runtime::new(m) {
                Ok(rt) => println!("PJRT platform: {}", rt.platform()),
                Err(e) => println!("PJRT unavailable: {e}"),
            }
        }
        None => println!("no artifacts found — run `make artifacts`"),
    }
}
