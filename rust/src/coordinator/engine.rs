//! The unified decentralized engine.
//!
//! SPARQ-SGD, CHOCO-SGD, and D-PSGD are one algorithm family — local
//! steps, an optional event trigger, a compression operator, and a gossip
//! consensus step (Qsparse-local-SGD [BDKD19] makes the composition
//! explicit). [`DecentralizedEngine`] implements the family once and is
//! parameterized by two small policies plus the existing
//! [`Compressor`]:
//!
//! * [`CommPolicy`] — *when* to synchronize and *which* nodes transmit.
//!   [`Triggered`] (SPARQ: sync at I_T, fire on the drift threshold) and
//!   [`AlwaysComm`] (CHOCO / vanilla: every round, every node).
//! * [`UpdateRule`] — *what* a sync round does with the transmissions.
//!   [`EstimateTracking`] (SPARQ/CHOCO: compressed estimate bank +
//!   γ-consensus, Algorithm 1 lines 7–15) and [`ExactAveraging`]
//!   (D-PSGD: full-precision neighbor averaging, gradient applied after
//!   mixing).
//!
//! The former `sparq.rs` / `choco.rs` / `vanilla.rs` step loops are gone;
//! those modules are now thin constructors over this engine, and the
//! `engine_equivalence` integration suite pins that each constructor
//! reproduces its seed coordinator bit-for-bit on fixed seeds.
//!
//! Two scenario layers the three bespoke loops could not express plug in
//! here:
//!
//! * [`TopologySchedule`] (`graph::dynamic`) — the mixing matrix can
//!   switch on a schedule or be re-sampled per round; on a switch the
//!   update rule rebuilds its topology-derived state (the consensus
//!   accumulator is reconstructed from the live estimate bank).
//! * [`LinkModel`] (`comm::link`) — seeded per-edge message drops and
//!   per-node stragglers, applied at broadcast time with bits charged
//!   only for delivered copies.
//!
//! Defaults ([`LinkModel::ideal`], [`TopologySchedule::fixed`]) preserve
//! the seed behavior exactly — the ideal-link broadcast path is the same
//! sequence of float operations and bus charges as the seed coordinators.
//!
//! Execution structure (EXPERIMENTS.md §Perf): messages are sparse
//! ([`crate::compress::SparseVec`]), the consensus step reads the
//! materialized [`NeighborAccumulator`], and per-node phases run on a
//! [`ThreadPool`] with bit-for-bit determinism for any worker count (all
//! cross-node effects — broadcasts, link coins — happen on the
//! sequential path or are stateless hashes).

use std::cell::OnceCell;

use super::consensus::NeighborAccumulator;
use super::node::NodeState;
use super::{gradient_phase, DecentralizedAlgo};
use crate::comm::link::LinkModel;
use crate::comm::transport::{LocalTransport, Transport};
use crate::comm::{Bus, FaultCounters, FaultPlan};
use crate::compress::Compressor;
use crate::graph::dynamic::TopologySchedule;
use crate::graph::{MixingMatrix, SpectralInfo, Topology};
use crate::linalg::vecops::{scale_add_into_dist2, sub_into_dist2};
use crate::problems::GradientSource;
use crate::schedule::{LrSchedule, SyncSchedule};
use crate::trigger::EventTrigger;
use crate::util::threadpool::ThreadPool;
use crate::util::Rng;

// ---------------------------------------------------------------------
// Policies
// ---------------------------------------------------------------------

/// When to synchronize and which nodes transmit (merges the seed's
/// `SyncSchedule` + `EventTrigger` roles). Implementations are consulted
/// from pool workers and must be stateless across calls.
pub trait CommPolicy: Send + Sync {
    /// Is iteration t a synchronization index ((t+1) ∈ I_T)?
    fn is_sync(&self, t: u64) -> bool;

    /// Does a node with drift ‖x^{t+½} − x̂‖² = `drift2` transmit at sync
    /// index t? The caller computes the drift (fused with materializing
    /// the difference vector — see `EstimateTracking::sync_round`), so
    /// the policy is a pure threshold comparison.
    ///
    /// Honored by estimate-tracking rules only: exact averaging has no
    /// estimate bank for a drift threshold to compare against, so it
    /// treats every sync round as all-transmit and is gated purely by
    /// [`is_sync`](Self::is_sync) (plus link-model stragglers).
    fn fires(&self, drift2: f64, t: u64, eta: f64) -> bool;

    /// Per-coordinate threshold c_t·η_t² when the policy triggers each
    /// coordinate independently (EventGraD-style `percoord:C` triggers),
    /// `None` for whole-vector policies. Estimate-tracking rules consult
    /// this before [`fires`](Self::fires): when `Some`, coordinate j
    /// transmits iff d_j² strictly exceeds the threshold and silent
    /// coordinates are zeroed out of the compressor input.
    fn coord_threshold(&self, _t: u64, _eta: f64) -> Option<f64> {
        None
    }
}

/// SPARQ-SGD's policy: sync every H (or explicit I_T), transmit only on
/// drift `‖x^{t+½} − x̂‖² > c_t η_t²` (Algorithm 1 lines 5–7).
pub struct Triggered {
    pub sync: SyncSchedule,
    pub trigger: EventTrigger,
}

impl CommPolicy for Triggered {
    fn is_sync(&self, t: u64) -> bool {
        self.sync.is_sync(t)
    }

    fn fires(&self, drift2: f64, t: u64, eta: f64) -> bool {
        self.trigger.fires_drift(drift2, t, eta)
    }

    fn coord_threshold(&self, t: u64, eta: f64) -> Option<f64> {
        self.trigger.coord_threshold(t, eta)
    }
}

/// CHOCO-SGD's / D-PSGD's policy: every iteration is a sync round and
/// every node transmits (H = 1, no trigger).
pub struct AlwaysComm;

impl CommPolicy for AlwaysComm {
    fn is_sync(&self, _t: u64) -> bool {
        true
    }

    fn fires(&self, _drift2: f64, _t: u64, _eta: f64) -> bool {
        true
    }
}

/// Shared engine state handed to the update rule for one sync round.
pub struct SyncCtx<'a> {
    pub t: u64,
    /// η_t (f64 — the trigger threshold compares in f64).
    pub eta: f64,
    /// Consensus step size γ (estimate tracking only).
    pub gamma: f32,
    pub momentum: f32,
    pub mixing: &'a MixingMatrix,
    pub comm: &'a dyn CommPolicy,
    pub compressor: &'a dyn Compressor,
    pub link: &'a LinkModel,
    /// The fault plan in force. Crash/partition outages are already
    /// folded into `mixing` (the engine hands rules the live-subgraph
    /// matrix); rules consult this only for per-copy corruption coins.
    pub fault: &'a FaultPlan,
    /// Per-node crash mask at `t` (`down[i]` ⇒ node i is dark this
    /// round: no trigger check, no transmission, no commit).
    pub down: &'a [bool],
    pub pool: &'a ThreadPool,
}

/// What one sync round did — the transmit count plus fault bookkeeping
/// that flows back to the engine's cumulative counters.
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub struct SyncOutcome {
    /// Nodes that actually transmitted.
    pub fired: usize,
    /// Copies discarded by receivers as corrupt (checksum failures).
    pub corrupt: u64,
}

/// What a sync round does with the transmissions. Rules own their
/// variant-specific state (estimate bank / mixing buffers) so the engine
/// step loop stays variant-free.
pub trait UpdateRule: Send {
    /// Whether the gradient phase applies the local half-step *before*
    /// communication (estimate tracking) or the rule applies the gradient
    /// itself after mixing (exact averaging). Rules returning `false`
    /// must be paired with an always-sync [`CommPolicy`], since non-sync
    /// rounds commit nothing for them.
    fn local_half_step(&self) -> bool;

    /// Run the communication + parameter commit of one sync round.
    /// `transport` is the physical seam for broadcasts
    /// (`comm::transport` — the default [`LocalTransport`] is a no-op
    /// and reproduces the in-process simulator bit for bit).
    fn sync_round(
        &mut self,
        ctx: &SyncCtx<'_>,
        nodes: &mut [NodeState],
        bus: &mut Bus,
        transport: &mut dyn Transport,
    ) -> SyncOutcome;

    /// Rebuild topology-derived internal state after a mixing switch.
    /// Rules that keep cross-round neighbor state must charge `bus` for
    /// whatever exchange makes the rebuilt state physically realizable
    /// (a node re-wired to a new neighbor has to *send* it x̂ before that
    /// neighbor can track it — re-wiring is not free signalling).
    fn rebuild(&mut self, mixing: &MixingMatrix, bus: &mut Bus);

    /// Re-derive topology-dependent internal state for a (possibly
    /// fault-pruned) mixing matrix *without* charging the bus. The
    /// engine's fault-transition handler prices recovery itself — only
    /// regained edges pay — so this hook must stay silent, unlike
    /// [`rebuild`](Self::rebuild), which prices a full re-wiring.
    fn refresh(&mut self, _mixing: &MixingMatrix) {}

    /// The public estimate x̂_i, for rules that keep an estimate bank.
    fn xhat(&self, _i: usize) -> Option<&[f32]> {
        None
    }

    /// The materialized consensus accumulator row for node i, for rules
    /// that keep one (checkpointed so resume is bit-for-bit — see
    /// [`NeighborAccumulator::restore_acc`]).
    fn acc(&self, _i: usize) -> Option<&[f32]> {
        None
    }

    /// Restore the estimate bank + accumulator from a checkpoint on the
    /// given (current) mixing matrix. Unlike [`rebuild`](Self::rebuild),
    /// this charges nothing: the traffic that built this state was paid
    /// for before the snapshot was taken.
    fn restore_bank(&mut self, _xhat: &[Vec<f32>], _acc: &[Vec<f32>], _mixing: &MixingMatrix) {}
}

// ---------------------------------------------------------------------
// Update rules
// ---------------------------------------------------------------------

/// CHOCO-style estimate tracking (Algorithm 1 lines 7–15): fired nodes
/// broadcast q = C(x^{t+½} − x̂), every receiver applies it to its view
/// of the sender's estimate, then x ← x^{t+½} + γ Σ w_ij (x̂_j − x̂_i).
///
/// With an ideal link every neighbor holds the *same* copy of x̂_j, so a
/// single bank suffices (node.rs explains the reduction). Lossy links
/// break that symmetry — receiver i's stale view of a dropped update
/// lives implicitly in its accumulator row, which simply doesn't move
/// for undelivered copies (the sender's own x̂ always advances).
pub struct EstimateTracking {
    /// Public estimates x̂_j (one authoritative copy per node).
    xhat: Vec<Vec<f32>>,
    /// Materialized Σ_j w_ij x̂_j per node (consensus.rs).
    nbr: NeighborAccumulator,
    /// SQuARM-SGD trigger momentum β: `Some(β)` evaluates the event
    /// trigger on the buffered drift u ← β·u + (x^{t+½} − x̂) instead of
    /// the raw drift (the transmitted message is still C(diff), so the
    /// x̂ tracking identity is unchanged). `None` ⇒ plain SPARQ path.
    trigger_beta: Option<f32>,
}

impl EstimateTracking {
    pub fn new(mixing: &MixingMatrix, d: usize) -> EstimateTracking {
        EstimateTracking {
            xhat: vec![vec![0.0; d]; mixing.n()],
            nbr: NeighborAccumulator::new(mixing, d),
            trigger_beta: None,
        }
    }

    /// SQuARM-SGD composition: same bank + γ-consensus, but the trigger
    /// decision uses a per-node momentum-buffered drift
    /// (`NodeState::trig_momentum`, flushed to zero on every delivered
    /// broadcast). β = 0 annihilates the buffer each round, so
    /// SQuARM(β=0) is pinned bit-for-bit equal to the SPARQ path
    /// (`rust/tests/engine_equivalence.rs`).
    pub fn with_trigger_beta(mixing: &MixingMatrix, d: usize, beta: f32) -> EstimateTracking {
        EstimateTracking {
            trigger_beta: Some(beta),
            ..EstimateTracking::new(mixing, d)
        }
    }
}

impl UpdateRule for EstimateTracking {
    fn local_half_step(&self) -> bool {
        true
    }

    fn sync_round(
        &mut self,
        ctx: &SyncCtx<'_>,
        nodes: &mut [NodeState],
        bus: &mut Bus,
        transport: &mut dyn Transport,
    ) -> SyncOutcome {
        // Algorithm 1 lines 7–9: trigger check and (if fired) compress,
        // all against the *pre-update* x̂ bank — parallel across nodes.
        // One fused pass materializes diff = x^{t+½} − x̂ while
        // accumulating its squared norm, so the vectors are walked once
        // instead of dist2-then-sub_into twice; the drift value (and
        // hence every trigger decision) is bit-identical to the unfused
        // pair. Crashed nodes are dark: no trigger check, no
        // transmission.
        let xhat = &self.xhat;
        let beta = self.trigger_beta;
        ctx.pool.for_each_mut(nodes, |i, node| {
            // SQuARM buffers are allocated for *every* node at the first
            // sync round — crashed nodes included — so checkpoint blocks
            // stay rectangular under a fault plan.
            if beta.is_some() && node.trig_momentum.is_none() {
                node.trig_momentum = Some(vec![0.0; node.diff.len()]);
            }
            if ctx.down[i] {
                node.fired = false;
                return;
            }
            let drift2 = sub_into_dist2(&node.x_half, &xhat[i], &mut node.diff);
            if let Some(thr) = ctx.comm.coord_threshold(ctx.t, ctx.eta) {
                // EventGraD-style per-coordinate trigger: coordinate j
                // transmits iff d_j² > thr (strict); silent coordinates
                // are zeroed so only fired ones enter the compressor.
                // Fired coordinates keep their exact diff value, so a
                // threshold every coordinate clears reproduces the norm
                // path bit-for-bit.
                let mut any = false;
                for v in node.diff.iter_mut() {
                    let dv = *v as f64;
                    if dv * dv > thr {
                        any = true;
                    } else {
                        *v = 0.0;
                    }
                }
                node.fired = any;
            } else if let Some(beta) = beta {
                // SQuARM-SGD: fire on the momentum-buffered drift
                // u ← β·u + diff (fused with its norm — vecops), but
                // transmit C(diff) so x̂ tracking stays exact. At β = 0
                // the fused pass reproduces `drift2` bit-for-bit.
                let u = node.trig_momentum.as_mut().unwrap();
                let mdrift2 = scale_add_into_dist2(beta, u, &node.diff);
                node.fired = ctx.comm.fires(mdrift2, ctx.t, ctx.eta);
            } else {
                node.fired = ctx.comm.fires(drift2, ctx.t, ctx.eta);
            }
            if node.fired {
                ctx.compressor
                    .compress_sparse(&node.diff, &mut node.rng, &mut node.q);
            }
        });

        // Lines 9–13: charge broadcasts and apply estimate updates in
        // deterministic node order; silent nodes (line 11) cost nothing.
        let d = self.xhat[0].len();
        let mut out = SyncOutcome::default();
        let filtered = !ctx.link.is_ideal() || ctx.fault.corrupt_p > 0.0;
        for i in 0..nodes.len() {
            if !nodes[i].fired {
                continue;
            }
            if ctx.link.straggles(i, ctx.t) {
                // Skipped broadcast: as if the trigger had not fired —
                // the estimate bank stays put and the drift persists to
                // the next sync index.
                nodes[i].fired = false;
                continue;
            }
            out.fired += 1;
            // Physical seam: over a socket transport this sends rank
            // i's own broadcast as real frame bytes and, for a
            // neighbor's broadcast, substitutes the received + decoded
            // copy (bit-identical to the local one — the sparse codec
            // is lossless) before it is charged and applied.
            if let Some(sub) =
                transport.exchange(ctx.t, i, &nodes[i].q, d, &ctx.mixing.topology.neighbors[i])
            {
                nodes[i].q = sub;
            }
            let q = &nodes[i].q;
            let bits = ctx.compressor.message_bits(d, q.nnz());
            if !filtered {
                bus.charge_broadcast(i, ctx.mixing.topology.degree(i), bits);
                q.add_to(&mut self.xhat[i]);
                self.nbr.apply_broadcast(i, q);
            } else {
                // A corrupt copy traveled the link — it is charged like a
                // delivered one — but fails its frame checksum at the
                // receiver, so the accumulator treats it as a drop.
                let mut corrupt_here = 0u64;
                let delivered = self.nbr.apply_broadcast_where(i, q, |to| {
                    if !ctx.link.delivers(i, to, ctx.t) {
                        return false;
                    }
                    if ctx.fault.corrupts(i, to, ctx.t) {
                        corrupt_here += 1;
                        return false;
                    }
                    true
                });
                bus.charge_broadcast(i, delivered + corrupt_here as usize, bits);
                out.corrupt += corrupt_here;
                q.add_to(&mut self.xhat[i]);
            }
            // SQuARM: a transmitted broadcast flushes the buffered drift
            // (straggler skips above reset `fired` and keep u intact, so
            // the untransmitted drift keeps accumulating).
            if self.trigger_beta.is_some() {
                if let Some(u) = nodes[i].trig_momentum.as_mut() {
                    for v in u.iter_mut() {
                        *v = 0.0;
                    }
                }
            }
        }

        // Line 15: consensus from the post-update estimates — one fused
        // pass per node from the materialized accumulator, parallel.
        // Commit by buffer swap (x_half is fully rewritten next round).
        // A crashed node's x_half is stale, so its parameters stay frozen.
        let gamma = ctx.gamma;
        let xhat = &self.xhat;
        let nbr = &self.nbr;
        ctx.pool.for_each_mut(nodes, |i, node| {
            if ctx.down[i] {
                return;
            }
            std::mem::swap(&mut node.x, &mut node.x_half);
            nbr.commit(i, gamma, &xhat[i], &mut node.x);
        });
        out
    }

    fn rebuild(&mut self, mixing: &MixingMatrix, bus: &mut Bus) {
        // Re-wiring resynchronizes the estimate bank over the new edge
        // set: every node sends its full-precision x̂_i to its new
        // neighborhood (how else would a fresh neighbor obtain the
        // estimate it is about to track, and how else would a stale
        // receiver — e.g. after lossy-link drops — catch back up?). The
        // exchange is charged at 32·d per copy; treating it as loss-free
        // control-plane traffic keeps the single-bank representation
        // exact after the switch.
        let d = self.xhat.first().map(Vec::len).unwrap_or(0);
        for i in 0..mixing.n() {
            let fanout = mixing.topology.degree(i);
            if fanout > 0 {
                bus.charge_broadcast(i, fanout, 32 * d as u64);
            }
        }
        self.nbr = NeighborAccumulator::from_bank(mixing, &self.xhat);
    }

    fn refresh(&mut self, mixing: &MixingMatrix) {
        // Same reconstruction as a rebuild but silent: the engine prices
        // fault recovery per regained edge before calling this.
        self.nbr = NeighborAccumulator::from_bank(mixing, &self.xhat);
    }

    fn xhat(&self, i: usize) -> Option<&[f32]> {
        Some(&self.xhat[i])
    }

    fn acc(&self, i: usize) -> Option<&[f32]> {
        Some(self.nbr.acc(i))
    }

    fn restore_bank(&mut self, xhat: &[Vec<f32>], acc: &[Vec<f32>], mixing: &MixingMatrix) {
        assert_eq!(xhat.len(), self.xhat.len(), "estimate bank size mismatch");
        for (dst, src) in self.xhat.iter_mut().zip(xhat.iter()) {
            dst.copy_from_slice(src);
        }
        // Fresh edge structure for the (possibly switched) matrix, then
        // the checkpointed accumulator rows verbatim.
        let d = self.xhat.first().map(Vec::len).unwrap_or(0);
        self.nbr = NeighborAccumulator::new(mixing, d);
        self.nbr.restore_acc(acc);
    }
}

/// D-PSGD exact averaging: everyone broadcasts x_i in full (32-bit), the
/// commit is x_i ← Σ_j w_ij x_j − η_t g_i (gradient applied *after*
/// mixing, so [`local_half_step`](UpdateRule::local_half_step) = false).
/// Only [`CommPolicy::is_sync`] gates communication — per-node
/// [`CommPolicy::fires`] thresholds need an estimate bank and are
/// ignored here (see the trait docs).
///
/// Under a lossy link a receiver substitutes its own x_i for any lost
/// neighbor copy (w_ij x_i instead of w_ij x_j), which keeps the mixing
/// row stochastic — the standard biased-gossip fallback.
pub struct ExactAveraging {
    mixed: Vec<Vec<f32>>,
}

impl ExactAveraging {
    pub fn new(n: usize, d: usize) -> ExactAveraging {
        ExactAveraging {
            mixed: vec![vec![0.0; d]; n],
        }
    }
}

impl UpdateRule for ExactAveraging {
    fn local_half_step(&self) -> bool {
        false
    }

    fn sync_round(
        &mut self,
        ctx: &SyncCtx<'_>,
        nodes: &mut [NodeState],
        bus: &mut Bus,
        _transport: &mut dyn Transport,
    ) -> SyncOutcome {
        let d = nodes[0].x.len();
        let bits = 32 * d as u64;

        // Who transmits this round (everyone, minus crashed nodes and
        // stragglers), and the per-copy charges — deterministic node
        // order. Corrupt copies travel the link (and are charged like
        // delivered ones) but fail the receiver's checksum; they are
        // tallied here, sequentially, so the count never depends on the
        // mixing pass's parallel layout.
        let mut out = SyncOutcome::default();
        for (i, node) in nodes.iter_mut().enumerate() {
            node.fired = !ctx.down[i] && !ctx.link.straggles(i, ctx.t);
            if !node.fired {
                continue;
            }
            out.fired += 1;
            if ctx.link.is_ideal() {
                bus.charge_broadcast(i, ctx.mixing.topology.degree(i), bits);
            } else {
                let delivered = ctx.mixing.topology.neighbors[i]
                    .iter()
                    .filter(|&&to| ctx.link.delivers(i, to, ctx.t))
                    .count();
                bus.charge_broadcast(i, delivered, bits);
            }
            if ctx.fault.corrupt_p > 0.0 {
                for &to in &ctx.mixing.topology.neighbors[i] {
                    if ctx.link.delivers(i, to, ctx.t) && ctx.fault.corrupts(i, to, ctx.t) {
                        out.corrupt += 1;
                    }
                }
            }
        }

        // mixed_i = w_ii x_i + Σ_j w_ij x_j (self-substituted on loss or
        // corruption) — each row reads the immutable parameter bank and
        // writes only its own buffer, so rows fan out on the pool.
        let nodes_ref: &[NodeState] = &*nodes;
        let mixing = ctx.mixing;
        let link = ctx.link;
        let fault = ctx.fault;
        let clean = ctx.link.is_ideal() && ctx.fault.corrupt_p == 0.0;
        let t = ctx.t;
        ctx.pool.for_each_mut(&mut self.mixed, |i, row| {
            let wii = mixing.self_weight(i) as f32;
            for (m, x) in row.iter_mut().zip(nodes_ref[i].x.iter()) {
                *m = wii * x;
            }
            let (nbrs, wts) = mixing.row(i);
            for (&j, &wf) in nbrs.iter().zip(wts.iter()) {
                let w = wf as f32;
                let landed = clean
                    || (nodes_ref[j].fired && link.delivers(j, i, t) && !fault.corrupts(j, i, t));
                let src = if landed {
                    &nodes_ref[j].x
                } else {
                    &nodes_ref[i].x
                };
                for (m, x) in row.iter_mut().zip(src.iter()) {
                    *m += w * x;
                }
            }
        });

        // Commit: x_i = mixed_i − η·(momentum-adjusted gradient) —
        // per-node independent, parallel. Crashed nodes stay frozen.
        let eta = ctx.eta as f32;
        let momentum = ctx.momentum;
        let mixed = &self.mixed;
        ctx.pool.for_each_mut(nodes, |i, node| {
            if ctx.down[i] {
                return;
            }
            match node.momentum.as_mut() {
                Some(m) => {
                    for ((x, mi), (g, mix)) in node
                        .x
                        .iter_mut()
                        .zip(m.iter_mut())
                        .zip(node.grad.iter().zip(mixed[i].iter()))
                    {
                        *mi = momentum * *mi + g;
                        *x = mix - eta * *mi;
                    }
                }
                None => {
                    for (x, (g, mix)) in node
                        .x
                        .iter_mut()
                        .zip(node.grad.iter().zip(mixed[i].iter()))
                    {
                        *x = mix - eta * g;
                    }
                }
            }
        });
        out
    }

    fn rebuild(&mut self, _mixing: &MixingMatrix, _bus: &mut Bus) {
        // `mixed` is recomputed from scratch every round from parameters
        // that are re-broadcast anyway; nothing cached, nothing to resync.
    }
}

// ---------------------------------------------------------------------
// The engine
// ---------------------------------------------------------------------

/// Everything that parameterizes an engine run. The thin constructors
/// (`SparqSgd::new`, `ChocoSgd::new`, `VanillaDecentralized::new`) fill
/// this in; building one directly composes new scheme variants.
pub struct EngineConfig {
    pub mixing: MixingMatrix,
    pub compressor: Box<dyn Compressor>,
    pub comm: Box<dyn CommPolicy>,
    pub rule: Box<dyn UpdateRule>,
    /// Consensus step size γ; `None` ⇒ tuned heuristic
    /// `SpectralInfo::gamma_tuned` (computed once and cached — rules that
    /// don't use γ should pass `Some(0.0)` to skip the eigen solve).
    pub gamma: Option<f64>,
    pub lr: LrSchedule,
    /// Momentum factor (Section 5.2 uses 0.9; 0 disables).
    pub momentum: f32,
    pub seed: u64,
    /// Display name (stable across the refactor for metrics labels).
    pub name: String,
}

/// The policy-driven decentralized optimizer (see module docs).
pub struct DecentralizedEngine {
    /// The mixing matrix currently in force (replaced by the schedule).
    pub mixing: MixingMatrix,
    pub lr: LrSchedule,
    /// Consensus step size γ (0 for exact-averaging rules).
    pub gamma: f64,
    pub momentum: f32,
    /// Cumulative trigger statistics (checks = n per sync round).
    pub total_fired: u64,
    pub total_checks: u64,
    comm: Box<dyn CommPolicy>,
    rule: Box<dyn UpdateRule>,
    compressor: Box<dyn Compressor>,
    link: LinkModel,
    schedule: TopologySchedule,
    /// The fault plan in force (default: [`FaultPlan::ideal`]).
    fault: FaultPlan,
    /// Per-node crash mask for the current step (all-false when ideal).
    down: Vec<bool>,
    /// The fault windows active at the last transition check, as
    /// (crash indices, partition indices) — the live subgraph can only
    /// change when this value does.
    fault_active: (Vec<usize>, Vec<usize>),
    /// The live-subgraph mixing matrix while outage windows are open
    /// (`None` ⇒ the base matrix is in force).
    effective: Option<MixingMatrix>,
    /// Per directed base edge (receiver-major CSR, aligned with the
    /// current mixing topology's adjacency lists): sync rounds since the
    /// receiver last got a fresh copy from that sender. O(|E|), sized
    /// only under a non-ideal fault plan; rebuilt (and zeroed) on a
    /// topology switch — the switch resync re-broadcasts full x̂, so
    /// every edge of the new graph starts fresh.
    stale: Vec<u64>,
    /// Row offsets into `stale`: receiver i's entries live at
    /// `stale[stale_off[i]..stale_off[i + 1]]`, one per neighbor in
    /// adjacency order.
    stale_off: Vec<usize>,
    /// Cumulative crash / resync / corrupt-discard counters.
    counters: FaultCounters,
    /// Physical broadcast seam (default: the no-op [`LocalTransport`];
    /// the cluster runtime installs a `SocketTransport` so each sync
    /// round's messages really cross a UDS/TCP socket).
    transport: Box<dyn Transport>,
    nodes: Vec<NodeState>,
    /// Worker pool for the per-node phases (workers = 1 ⇒ sequential;
    /// results are bit-identical for any worker count).
    pool: ThreadPool,
    /// Cached eigen solve of the current mixing matrix (computed at most
    /// once; skipped entirely when γ is pinned and nobody asks).
    spectral: OnceCell<SpectralInfo>,
    fired_last: usize,
    name: String,
}

impl DecentralizedEngine {
    pub fn new(cfg: EngineConfig, d: usize) -> DecentralizedEngine {
        let n = cfg.mixing.n();
        let spectral: OnceCell<SpectralInfo> = OnceCell::new();
        let gamma = cfg.gamma.unwrap_or_else(|| {
            let s = *spectral.get_or_init(|| SpectralInfo::compute(&cfg.mixing));
            s.gamma_tuned(cfg.compressor.omega(d), cfg.compressor.effective_omega(d))
        });
        let mut root = Rng::new(cfg.seed);
        let nodes = (0..n)
            .map(|i| NodeState::new(d, cfg.momentum > 0.0, root.fork(i as u64)))
            .collect();
        DecentralizedEngine {
            mixing: cfg.mixing,
            lr: cfg.lr,
            gamma,
            momentum: cfg.momentum,
            total_fired: 0,
            total_checks: 0,
            comm: cfg.comm,
            rule: cfg.rule,
            compressor: cfg.compressor,
            link: LinkModel::ideal(),
            schedule: TopologySchedule::fixed(),
            fault: FaultPlan::ideal(),
            down: vec![false; n],
            fault_active: (Vec::new(), Vec::new()),
            effective: None,
            stale: Vec::new(),
            stale_off: Vec::new(),
            counters: FaultCounters::default(),
            transport: Box::new(LocalTransport),
            nodes,
            pool: ThreadPool::new(1),
            spectral,
            fired_last: 0,
            name: cfg.name,
        }
    }

    /// Install a link-fault model (default: [`LinkModel::ideal`]).
    pub fn set_link(&mut self, link: LinkModel) {
        self.link = link;
    }

    /// Install a broadcast transport (default: [`LocalTransport`]).
    /// The cluster runtime hangs its `SocketTransport` here so sync
    /// rounds exchange real frames; the algorithm code is unchanged.
    pub fn install_transport(&mut self, transport: Box<dyn Transport>) {
        self.transport = transport;
    }

    /// Install a topology schedule (default: [`TopologySchedule::fixed`]).
    /// The engine must have been constructed on the schedule's
    /// [`initial_mixing`](TopologySchedule::initial_mixing) (the builder
    /// does this); switches take effect at subsequent sync indices.
    pub fn set_topology_schedule(&mut self, schedule: TopologySchedule) {
        self.schedule = schedule;
    }

    /// Install a fault plan (default: [`FaultPlan::ideal`]). Crash and
    /// partition windows prune the mixing matrix in force; per-copy
    /// corruption is applied at broadcast time by the update rules.
    pub fn set_fault_plan(&mut self, fault: FaultPlan) {
        self.fault = fault;
        self.rebuild_stale_table();
    }

    /// (Re)size the per-edge staleness CSR to the base mixing matrix in
    /// force, zeroed. Called when the fault plan is installed and after
    /// a topology switch (whose resync makes every new edge fresh).
    fn rebuild_stale_table(&mut self) {
        if self.fault.is_ideal() {
            self.stale = Vec::new();
            self.stale_off = Vec::new();
            return;
        }
        let n = self.mixing.n();
        let mut off = Vec::with_capacity(n + 1);
        off.push(0usize);
        for i in 0..n {
            off.push(off[i] + self.mixing.topology.neighbors[i].len());
        }
        self.stale = vec![0; off[n]];
        self.stale_off = off;
    }

    /// The most rounds any live directed base edge has gone without a
    /// fresh copy (0 ⇒ everything fresh, or no fault plan installed).
    /// Deliberately not checkpointed: it is a diagnostic, not state.
    pub fn max_staleness(&self) -> u64 {
        self.stale.iter().copied().max().unwrap_or(0)
    }

    /// Detect fault-window transitions at step `t`. On a change: update
    /// the crash mask, re-derive the live-subgraph matrix, charge the
    /// recovery resync for every *regained* directed edge (a rejoined
    /// node restores from its frozen state and then re-exchanges
    /// full-precision x̂ with each live neighbor, exactly like a topology
    /// switch — recovery is never free), and silently refresh the rule's
    /// neighbor state on the new live subgraph. Losing edges (a window
    /// opening) charges nothing: going dark is free, coming back isn't.
    fn fault_transition(&mut self, t: u64, bus: &mut Bus) {
        let active = self.fault.active(t);
        if active == self.fault_active {
            return;
        }
        let n = self.mixing.n();
        let mut down = vec![false; n];
        self.fault.down_mask_into(t, &mut down);
        for i in 0..n {
            if down[i] && !self.down[i] {
                self.counters.crashes += 1;
            }
        }
        let eff = effective_mixing(&self.mixing, &self.fault, &down, t);
        let d = self.nodes.first().map(|nd| nd.x.len()).unwrap_or(0);
        let prev = self.effective.as_ref().unwrap_or(&self.mixing);
        for i in 0..n {
            let gained = eff.topology.neighbors[i]
                .iter()
                .filter(|j| !prev.topology.neighbors[i].contains(j))
                .count();
            if gained > 0 {
                bus.charge_broadcast(i, gained, 32 * d as u64);
                self.counters.resyncs += 1;
            }
        }
        self.rule.refresh(&eff);
        self.effective = if active.0.is_empty() && active.1.is_empty() {
            None
        } else {
            Some(eff)
        };
        self.down = down;
        self.fault_active = active;
    }

    /// Age per-edge staleness after a sync round: a directed base edge
    /// (sender j → receiver i) is fresh only when the copy actually
    /// landed — sender fired, both endpoints up, no severing partition,
    /// the link delivered, and the frame survived its checksum.
    fn update_staleness(&mut self, t: u64) {
        let n = self.mixing.n();
        for i in 0..n {
            let row = self.stale_off[i];
            for (pos, &j) in self.mixing.topology.neighbors[i].iter().enumerate() {
                let fresh = self.nodes[j].fired
                    && !self.down[i]
                    && !self.down[j]
                    && !self.fault.severed(i, j, t)
                    && self.link.delivers(j, i, t)
                    && !self.fault.corrupts(j, i, t);
                if fresh {
                    self.stale[row + pos] = 0;
                } else {
                    self.stale[row + pos] += 1;
                }
            }
        }
    }

    /// Set all nodes to the same initial parameters.
    pub fn init_params(&mut self, x0: &[f32]) {
        for node in self.nodes.iter_mut() {
            node.x.copy_from_slice(x0);
        }
    }

    /// Spectral info of the mixing matrix currently in force (cached;
    /// recomputed only after a topology switch).
    pub fn spectral(&self) -> SpectralInfo {
        *self
            .spectral
            .get_or_init(|| SpectralInfo::compute(&self.mixing))
    }

    /// The estimate bank (exposed for tests; panics for update rules
    /// without one, i.e. exact averaging).
    pub fn xhat(&self, i: usize) -> &[f32] {
        self.rule
            .xhat(i)
            .expect("this update rule keeps no estimate bank")
    }

    /// Per-node state (exposed for tests).
    pub fn node(&self, i: usize) -> &NodeState {
        &self.nodes[i]
    }

    /// The installed link model.
    pub fn link(&self) -> &LinkModel {
        &self.link
    }
}

/// The live-subgraph mixing matrix under a fault plan at `t`: base edges
/// with a crashed endpoint or a severing partition are pruned and their
/// weight folded back onto the diagonal (w_ii = 1 − Σ live w_ij), which
/// keeps W symmetric and doubly stochastic — a down node degenerates to
/// an identity row. Gossip on the result is exactly gossip among the
/// live, mutually reachable nodes.
fn effective_mixing(
    base: &MixingMatrix,
    fault: &FaultPlan,
    down: &[bool],
    t: u64,
) -> MixingMatrix {
    let n = base.n();
    let mut neighbors = vec![Vec::new(); n];
    let mut weights = vec![Vec::new(); n];
    let mut diag = vec![0.0; n];
    for i in 0..n {
        let mut live_sum = 0.0;
        let (nbrs, wts) = base.row(i);
        for (&j, &wij) in nbrs.iter().zip(wts.iter()) {
            if down[i] || down[j] || fault.severed(i, j, t) {
                continue;
            }
            live_sum += wij;
            neighbors[i].push(j);
            weights[i].push(wij);
        }
        diag[i] = 1.0 - live_sum;
    }
    MixingMatrix::from_parts(
        Topology {
            n,
            kind: base.topology.kind,
            neighbors,
        },
        weights,
        diag,
    )
}

impl DecentralizedAlgo for DecentralizedEngine {
    fn step(&mut self, t: u64, src: &mut dyn GradientSource, bus: &mut Bus) {
        let eta64 = self.lr.eta(t);
        let half = self.rule.local_half_step();
        let sync = self.comm.is_sync(t);

        // Fault-window transitions take effect before anything else: a
        // node crashing at t is dark for all of step t, and a node
        // rejoining at t pays its resync before it gossips again.
        if self.fault.has_outages() {
            self.fault_transition(t, bus);
        }

        // Gradient (+ optional local half-step), every live node —
        // parallel when the source supports shared-state evaluation.
        // Rules without a standing half-step (exact averaging applies the
        // gradient after mixing) still take it on non-sync rounds: the
        // composition Triggered + ExactAveraging is local SGD between
        // periodic exact exchanges, and the step runs on the pool like
        // everything else.
        gradient_phase(
            &self.pool,
            &mut self.nodes,
            src,
            if half || !sync {
                Some((eta64 as f32, self.momentum))
            } else {
                None
            },
            &self.down,
        );

        if sync {
            // Time-varying topology: swap the mixing matrix and rebuild
            // topology-derived rule state before communicating (the rule
            // charges the bus for the state resync the re-wiring implies).
            if let Some(mixing) = self.schedule.update(t) {
                self.mixing = mixing;
                self.rule.rebuild(&self.mixing, bus);
                self.spectral = OnceCell::new();
                // The rebuild above re-broadcast full x̂ over the new
                // edge set, so the new graph's edges all start fresh.
                self.rebuild_stale_table();
                // The schedule swapped the base matrix mid-outage:
                // re-prune it for the live subgraph. The rebuild above
                // already paid a full resync, so this refresh is silent.
                if self.effective.is_some() {
                    let eff = effective_mixing(&self.mixing, &self.fault, &self.down, t);
                    self.rule.refresh(&eff);
                    self.effective = Some(eff);
                }
            }
            let ctx = SyncCtx {
                t,
                eta: eta64,
                gamma: self.gamma as f32,
                momentum: self.momentum,
                mixing: self.effective.as_ref().unwrap_or(&self.mixing),
                comm: &*self.comm,
                compressor: &*self.compressor,
                link: &self.link,
                fault: &self.fault,
                down: &self.down,
                pool: &self.pool,
            };
            let out = self
                .rule
                .sync_round(&ctx, &mut self.nodes, bus, self.transport.as_mut());
            let live = self.down.iter().filter(|&&dn| !dn).count();
            self.total_checks += live as u64;
            self.total_fired += out.fired as u64;
            self.counters.corrupt_discards += out.corrupt;
            self.fired_last = out.fired;
            if !self.fault.is_ideal() {
                self.update_staleness(t);
            }
        } else {
            // Commit the local step only (buffer swap, no copy); crashed
            // nodes hold a stale x_half and stay frozen.
            for (i, node) in self.nodes.iter_mut().enumerate() {
                if self.down[i] {
                    continue;
                }
                std::mem::swap(&mut node.x, &mut node.x_half);
            }
            self.fired_last = 0;
        }
        bus.end_round();
    }

    fn params(&self, node: usize) -> &[f32] {
        &self.nodes[node].x
    }

    fn set_params(&mut self, x0: &[f32]) {
        self.init_params(x0);
    }

    fn set_node_params(&mut self, node: usize, x: &[f32]) {
        self.nodes[node].x.copy_from_slice(x);
    }

    fn momentum(&self, node: usize) -> Option<&[f32]> {
        self.nodes[node].momentum.as_deref()
    }

    fn set_node_momentum(&mut self, node: usize, m: &[f32]) {
        if let Some(buf) = self.nodes[node].momentum.as_mut() {
            buf.copy_from_slice(m);
        }
    }

    fn trigger_momentum(&self, node: usize) -> Option<&[f32]> {
        self.nodes[node].trig_momentum.as_deref()
    }

    fn set_node_trigger_momentum(&mut self, node: usize, u: &[f32]) {
        self.nodes[node].trig_momentum = Some(u.to_vec());
    }

    fn estimate(&self, node: usize) -> Option<&[f32]> {
        self.rule.xhat(node)
    }

    fn consensus_acc(&self, node: usize) -> Option<&[f32]> {
        self.rule.acc(node)
    }

    fn restore_estimates(&mut self, xhat: &[Vec<f32>], acc: &[Vec<f32>]) {
        // Under an open outage window the accumulator's edge structure
        // must match the live subgraph the snapshot was taken on, not the
        // base matrix (prepare_resume replays the fault state first).
        let mixing = self.effective.as_ref().unwrap_or(&self.mixing);
        self.rule.restore_bank(xhat, acc, mixing);
    }

    fn rng_state(&self, node: usize) -> Option<[u64; 4]> {
        Some(self.nodes[node].rng.state())
    }

    fn set_rng_state(&mut self, node: usize, state: [u64; 4]) {
        self.nodes[node].rng = Rng::from_state(state);
    }

    fn set_fired_stats(&mut self, fired: u64, checks: u64) {
        self.total_fired = fired;
        self.total_checks = checks;
    }

    fn prepare_resume(&mut self, t0: u64) {
        // Replay the topology schedule to t0 so the matrix in force (and
        // the state restore_estimates is about to rebuild on it) matches
        // the uninterrupted run. Switch-boundary resync charges happened
        // before the snapshot and are already in the checkpointed bus
        // counters — replay must not charge them again, so the rule's
        // rebuild hook is NOT invoked here.
        let mut latest = None;
        for t in 0..t0 {
            if self.comm.is_sync(t) {
                if let Some(m) = self.schedule.update(t) {
                    latest = Some(m);
                }
            }
        }
        if let Some(m) = latest {
            self.mixing = m;
            self.spectral = OnceCell::new();
            self.rebuild_stale_table();
        }
        // Replay the fault state to just before t0 the same way — no
        // charges, no counter bumps (those are in the checkpoint). step(t0)
        // then prices exactly the transition the uninterrupted run would
        // have: a window opening or closing *at* t0 is t0's work.
        if !self.fault.is_ideal() && t0 > 0 {
            let t_last = t0 - 1;
            self.fault_active = self.fault.active(t_last);
            self.fault.down_mask_into(t_last, &mut self.down);
            self.effective = if self.fault_active.0.is_empty() && self.fault_active.1.is_empty() {
                None
            } else {
                Some(effective_mixing(
                    &self.mixing,
                    &self.fault,
                    &self.down,
                    t_last,
                ))
            };
        }
    }

    fn set_workers(&mut self, workers: usize) {
        self.pool = ThreadPool::new(workers);
    }

    fn set_transport(&mut self, transport: Box<dyn Transport>) {
        self.install_transport(transport);
    }

    fn n(&self) -> usize {
        self.nodes.len()
    }

    fn last_fired(&self) -> usize {
        self.fired_last
    }

    fn fault_counters(&self) -> FaultCounters {
        self.counters
    }

    fn set_fault_counters(&mut self, counters: FaultCounters) {
        self.counters = counters;
    }

    fn fired_stats(&self) -> (u64, u64) {
        (self.total_fired, self.total_checks)
    }

    fn name(&self) -> String {
        self.name.clone()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::compress::{Identity, SignTopK};
    use crate::coordinator::{ChocoSgd, SparqConfig, SparqSgd, VanillaDecentralized};
    use crate::graph::{uniform_neighbor, Topology, TopologyKind};
    use crate::problems::QuadraticProblem;
    use crate::trigger::ThresholdSchedule;

    fn mk(
        n: usize,
        d: usize,
        comp: Box<dyn Compressor>,
        trig: ThresholdSchedule,
        h: u64,
    ) -> (DecentralizedEngine, QuadraticProblem, Bus) {
        let topo = Topology::new(TopologyKind::Ring, n, 0);
        let mixing = uniform_neighbor(&topo);
        let cfg = SparqConfig {
            mixing,
            compressor: comp,
            trigger: EventTrigger::new(trig),
            lr: LrSchedule::InverseTime { a: 50.0, b: 2.0 },
            sync: SyncSchedule::EveryH(h),
            gamma: None,
            momentum: 0.0,
            seed: 7,
        };
        let algo = SparqSgd::new(cfg, d);
        let prob = QuadraticProblem::new(d, n, 0.5, 2.0, 0.05, 1.0, 3);
        let bus = Bus::new(n);
        (algo, prob, bus)
    }

    #[test]
    fn average_preserved_during_sync_round() {
        // Paper Eq. (20): x̄^{t+1} = x̄^{t+½} — the consensus step never
        // moves the average; only gradients do.
        let (mut algo, mut prob, mut bus) =
            mk(8, 12, Box::new(SignTopK::new(3)), ThresholdSchedule::Zero, 1);
        for t in 0..20 {
            let bar_before = algo.x_bar();
            algo.step(t, &mut prob, &mut bus);
            let eta = algo.lr.eta(t) as f32;
            let mut expected = bar_before;
            for i in 0..8 {
                for (e, g) in expected.iter_mut().zip(algo.node(i).grad.iter()) {
                    *e -= eta * g / 8.0;
                }
            }
            let bar = algo.x_bar();
            for (a, b) in bar.iter().zip(expected.iter()) {
                assert!((a - b).abs() < 1e-4, "t={t}: {a} vs {b}");
            }
        }
    }

    #[test]
    fn silent_nodes_cost_no_bits() {
        // Impossible threshold ⇒ nobody ever fires ⇒ zero bits on the bus.
        let (mut algo, mut prob, mut bus) = mk(
            6,
            10,
            Box::new(SignTopK::new(2)),
            ThresholdSchedule::Constant(1e12),
            1,
        );
        for t in 0..30 {
            algo.step(t, &mut prob, &mut bus);
        }
        assert_eq!(bus.total_bits, 0);
        assert_eq!(algo.total_fired, 0);
        assert_eq!(algo.total_checks, 30 * 6);
    }

    #[test]
    fn no_sync_rounds_never_communicate() {
        let (mut algo, mut prob, mut bus) =
            mk(4, 8, Box::new(Identity), ThresholdSchedule::Zero, 10);
        for t in 0..9 {
            // t = 0..8: (t+1) ∈ {1..9}, none divisible by 10
            algo.step(t, &mut prob, &mut bus);
            assert_eq!(bus.total_bits, 0, "t={t}");
        }
        algo.step(9, &mut prob, &mut bus); // t+1 = 10 syncs
        assert!(bus.total_bits > 0);
    }

    #[test]
    fn estimates_track_params_with_identity_compression() {
        // With Identity compression and always-firing trigger at H=1,
        // x̂_i = x_i^{t+½} after each sync round (perfect estimates).
        let (mut algo, mut prob, mut bus) =
            mk(4, 8, Box::new(Identity), ThresholdSchedule::Zero, 1);
        for t in 0..10 {
            let prev: Vec<Vec<f32>> = (0..4).map(|i| algo.params(i).to_vec()).collect();
            algo.step(t, &mut prob, &mut bus);
            let eta = algo.lr.eta(t) as f32;
            for i in 0..4 {
                for ((h, xp), g) in algo
                    .xhat(i)
                    .iter()
                    .zip(prev[i].iter())
                    .zip(algo.node(i).grad.iter())
                {
                    let x_half = xp - eta * g;
                    assert!((h - x_half).abs() < 1e-5, "t={t} node {i}");
                }
            }
        }
    }

    #[test]
    fn converges_on_quadratic() {
        let (mut algo, mut prob, mut bus) = mk(
            8,
            16,
            Box::new(SignTopK::new(4)),
            ThresholdSchedule::Poly { c0: 1.0, eps: 0.5 },
            5,
        );
        for t in 0..3000 {
            algo.step(t, &mut prob, &mut bus);
        }
        let gap = prob.suboptimality(&algo.x_bar());
        assert!(gap < 0.05, "suboptimality {gap}");
        assert!(
            algo.consensus_distance() < 10.0,
            "consensus {}",
            algo.consensus_distance()
        );
        // and the trigger actually saved some broadcasts
        assert!(algo.total_fired < algo.total_checks);
    }

    #[test]
    fn deterministic_replay() {
        let run = || {
            let (mut algo, mut prob, mut bus) = mk(
                5,
                10,
                Box::new(SignTopK::new(3)),
                ThresholdSchedule::Constant(10.0),
                5,
            );
            for t in 0..200 {
                algo.step(t, &mut prob, &mut bus);
            }
            (algo.x_bar(), bus.total_bits)
        };
        let (x1, b1) = run();
        let (x2, b2) = run();
        assert_eq!(x1, x2);
        assert_eq!(b1, b2);
    }

    #[test]
    fn pinned_gamma_skips_eigen_solve_but_spectral_still_works() {
        let topo = Topology::new(TopologyKind::Ring, 6, 0);
        let cfg = SparqConfig {
            mixing: uniform_neighbor(&topo),
            compressor: Box::new(Identity),
            trigger: EventTrigger::new(ThresholdSchedule::Zero),
            lr: LrSchedule::Constant(0.05),
            sync: SyncSchedule::EveryH(1),
            gamma: Some(0.3),
            momentum: 0.0,
            seed: 1,
        };
        let algo = SparqSgd::new(cfg, 8);
        assert_eq!(algo.gamma, 0.3);
        // lazy compute on demand, and both calls agree (cached)
        let a = algo.spectral();
        let b = algo.spectral();
        assert_eq!(a.delta, b.delta);
        assert!(a.delta > 0.0);
    }

    #[test]
    fn tuned_gamma_matches_direct_spectral_computation() {
        let topo = Topology::new(TopologyKind::Ring, 8, 0);
        let mixing = uniform_neighbor(&topo);
        let expect = SpectralInfo::compute(&mixing)
            .gamma_tuned(Identity.omega(12), Identity.effective_omega(12));
        let (algo, _, _) = mk(8, 12, Box::new(Identity), ThresholdSchedule::Zero, 1);
        assert_eq!(algo.gamma, expect);
    }

    #[test]
    fn lossy_link_charges_fewer_bits_than_ideal() {
        let run = |link: LinkModel| {
            let (mut algo, mut prob, mut bus) =
                mk(8, 16, Box::new(SignTopK::new(4)), ThresholdSchedule::Zero, 1);
            algo.set_link(link);
            for t in 0..200 {
                algo.step(t, &mut prob, &mut bus);
            }
            bus.total_bits
        };
        let ideal = run(LinkModel::ideal());
        let lossy = run(LinkModel::parse("drop:0.4", 3).unwrap());
        assert!(lossy < ideal, "lossy {lossy} vs ideal {ideal}");
        // roughly 60% of copies delivered (loose band)
        let frac = lossy as f64 / ideal as f64;
        assert!((0.4..0.8).contains(&frac), "delivered fraction {frac}");
    }

    #[test]
    fn straggler_node_transmits_less_and_still_converges() {
        let (mut algo, mut prob, mut bus) = mk(
            8,
            16,
            Box::new(SignTopK::new(4)),
            ThresholdSchedule::Zero,
            1,
        );
        algo.set_link(LinkModel::parse("straggler:0:0.7", 9).unwrap());
        for t in 0..2500 {
            algo.step(t, &mut prob, &mut bus);
        }
        // node 0 paid for far fewer broadcasts than its peers
        assert!(
            (bus.node_bits[0] as f64) < 0.6 * bus.node_bits[1] as f64,
            "node0 {} vs node1 {}",
            bus.node_bits[0],
            bus.node_bits[1]
        );
        // the run still optimizes
        let gap = prob.suboptimality(&algo.x_bar());
        assert!(gap < 0.2, "suboptimality {gap}");
    }

    #[test]
    fn topology_switch_runs_and_converges() {
        // 16 nodes so ring and torus both exist; switch every 300 steps.
        let topo = Topology::new(TopologyKind::Ring, 16, 0);
        let cfg = SparqConfig {
            mixing: uniform_neighbor(&topo),
            compressor: Box::new(SignTopK::new(4)),
            trigger: EventTrigger::new(ThresholdSchedule::Zero),
            lr: LrSchedule::InverseTime { a: 50.0, b: 2.0 },
            sync: SyncSchedule::EveryH(1),
            gamma: None,
            momentum: 0.0,
            seed: 7,
        };
        let mut algo = SparqSgd::new(cfg, 16);
        algo.set_topology_schedule(
            TopologySchedule::parse("switch:ring,torus:300", 16, 0).unwrap(),
        );
        let mut prob = QuadraticProblem::new(16, 16, 0.5, 2.0, 0.05, 1.0, 3);
        let mut bus = Bus::new(16);
        let mut ring_bits = 0u64;
        for t in 0..2400 {
            algo.step(t, &mut prob, &mut bus);
            if t == 299 {
                ring_bits = bus.total_bits;
            }
            if t == 300 {
                // torus phase: degree 4 ⇒ each broadcast now charges 2×
                // the ring's fanout
                assert!(algo.mixing.topology.neighbors.iter().all(|a| a.len() == 4));
            }
        }
        assert!(ring_bits > 0 && bus.total_bits > ring_bits);
        let gap = prob.suboptimality(&algo.x_bar());
        assert!(gap < 0.1, "suboptimality {gap}");
        // spectral() reflects the matrix in force after the last switch
        assert!(algo.spectral().delta > 0.0);
    }

    #[test]
    fn engine_composition_choco_equals_sparq_degenerate() {
        // The one-engine guarantee made structural: the CHOCO constructor
        // and SPARQ(c_t = 0, H = 1) build the same policies modulo the
        // trigger, and their trajectories agree bit-for-bit (nonzero
        // drift always fires the zero trigger).
        let topo = Topology::new(TopologyKind::Ring, 6, 0);
        let d = 20;
        let (mut sparq, mut prob_a, mut bus_a) =
            mk_pair(&topo, d, ThresholdSchedule::Zero);
        let mut choco = ChocoSgd::new(
            uniform_neighbor(&topo),
            Box::new(SignTopK::new(5)),
            LrSchedule::InverseTime { a: 50.0, b: 2.0 },
            0.0,
            d,
            7,
        );
        let mut prob_b = QuadraticProblem::new(d, 6, 0.5, 2.0, 0.05, 1.0, 3);
        let mut bus_b = Bus::new(6);
        for t in 0..300 {
            sparq.step(t, &mut prob_a, &mut bus_a);
            choco.step(t, &mut prob_b, &mut bus_b);
        }
        for i in 0..6 {
            assert_eq!(sparq.params(i), choco.params(i), "node {i}");
        }
        assert_eq!(bus_a.total_bits, bus_b.total_bits);
    }

    fn mk_pair(
        topo: &Topology,
        d: usize,
        trig: ThresholdSchedule,
    ) -> (DecentralizedEngine, QuadraticProblem, Bus) {
        let cfg = SparqConfig {
            mixing: uniform_neighbor(topo),
            compressor: Box::new(SignTopK::new(5)),
            trigger: EventTrigger::new(trig),
            lr: LrSchedule::InverseTime { a: 50.0, b: 2.0 },
            sync: SyncSchedule::EveryH(1),
            gamma: None,
            momentum: 0.0,
            seed: 7,
        };
        let algo = SparqSgd::new(cfg, d);
        let prob = QuadraticProblem::new(d, topo.n, 0.5, 2.0, 0.05, 1.0, 3);
        let bus = Bus::new(topo.n);
        (algo, prob, bus)
    }

    #[test]
    fn triggered_exact_averaging_is_local_sgd_between_exchanges() {
        // The doc-advertised novel composition: full-precision exchanges
        // every 4th round, plain local SGD in between. Must optimize and
        // charge exactly steps/4 rounds of vanilla-priced traffic.
        let topo = Topology::new(TopologyKind::Ring, 6, 0);
        let n = 6;
        let d = 16;
        let mut algo = DecentralizedEngine::new(
            EngineConfig {
                mixing: uniform_neighbor(&topo),
                compressor: Box::new(Identity),
                comm: Box::new(Triggered {
                    sync: SyncSchedule::EveryH(4),
                    trigger: EventTrigger::new(ThresholdSchedule::Zero),
                }),
                rule: Box::new(ExactAveraging::new(n, d)),
                gamma: Some(0.0),
                lr: LrSchedule::InverseTime { a: 50.0, b: 2.0 },
                momentum: 0.0,
                seed: 3,
                name: "local-dpsgd(H=4)".into(),
            },
            d,
        );
        let mut prob = QuadraticProblem::new(d, n, 0.5, 2.0, 0.05, 1.0, 4);
        let mut bus = Bus::new(n);
        for t in 0..2000 {
            algo.step(t, &mut prob, &mut bus);
        }
        // 500 sync rounds × 6 nodes × 2 neighbors × 32·16 bits
        assert_eq!(bus.comm_rounds, 500);
        assert_eq!(bus.total_bits, 500 * 6 * 2 * 32 * 16);
        let gap = prob.suboptimality(&algo.x_bar());
        assert!(gap < 0.05, "suboptimality {gap}");
    }

    #[test]
    fn topology_switch_resync_is_charged_on_the_bus() {
        // A switch is not free: every node re-broadcasts its full x̂ to
        // its new neighborhood (32·d per copy) so rebuilt accumulators
        // correspond to traffic that actually happened.
        let (mut algo, mut prob, mut bus) = mk(
            16,
            16,
            Box::new(SignTopK::new(4)),
            ThresholdSchedule::Constant(1e12), // nobody ever fires
            1,
        );
        algo.set_topology_schedule(
            TopologySchedule::parse("switch:ring,torus:10", 16, 0).unwrap(),
        );
        for t in 0..11 {
            algo.step(t, &mut prob, &mut bus);
        }
        // the only traffic is the single resync at t = 10 (ring → torus):
        // 16 nodes × 4 new neighbors × 32·16 bits
        assert_eq!(algo.total_fired, 0);
        assert_eq!(bus.total_bits, 16 * 4 * 32 * 16);
    }

    #[test]
    fn vanilla_constructor_charges_full_precision() {
        let topo = Topology::new(TopologyKind::Ring, 6, 0);
        let mut algo = VanillaDecentralized::new(
            uniform_neighbor(&topo),
            LrSchedule::Constant(0.05),
            0.0,
            20,
            1,
        );
        let mut prob = QuadraticProblem::new(20, 6, 0.5, 2.0, 0.0, 1.0, 2);
        let mut bus = Bus::new(6);
        algo.step(0, &mut prob, &mut bus);
        // 6 nodes × 2 neighbors × 32·20 bits
        assert_eq!(bus.total_bits, 6 * 2 * 32 * 20);
    }

    #[test]
    fn crash_rejoin_resync_is_charged_on_the_bus() {
        // Going dark is free; coming back is not. With an impossible
        // trigger the only traffic in the run is the rejoin resync:
        // node 3 regains its 2 ring edges and each ring neighbor regains
        // 1, so 4 directed copies of a full-precision x̂ cross the bus.
        let (mut algo, mut prob, mut bus) = mk(
            16,
            16,
            Box::new(SignTopK::new(4)),
            ThresholdSchedule::Constant(1e12), // nobody ever fires
            1,
        );
        algo.set_fault_plan(FaultPlan::parse("crash:3:2:8", 1).unwrap());
        for t in 0..11 {
            algo.step(t, &mut prob, &mut bus);
            if t < 8 {
                assert_eq!(bus.total_bits, 0, "crash itself must cost nothing (t={t})");
            }
        }
        assert_eq!(bus.total_bits, 4 * 32 * 16);
        let c = algo.fault_counters();
        assert_eq!(c.crashes, 1);
        assert_eq!(c.resyncs, 3);
        assert_eq!(c.corrupt_discards, 0);
    }

    #[test]
    fn crashed_node_is_frozen_and_dark() {
        let (mut algo, mut prob, mut bus) =
            mk(8, 16, Box::new(SignTopK::new(4)), ThresholdSchedule::Zero, 1);
        algo.set_fault_plan(FaultPlan::parse("crash:2:3:100", 1).unwrap());
        let mut frozen = Vec::new();
        let mut bits_at_crash = 0;
        for t in 0..10 {
            algo.step(t, &mut prob, &mut bus);
            if t == 2 {
                frozen = algo.params(2).to_vec();
                bits_at_crash = bus.node_bits[2];
            }
            if t > 2 {
                assert_eq!(algo.params(2), &frozen[..], "params moved while down (t={t})");
                assert_eq!(bus.node_bits[2], bits_at_crash, "down node paid bits (t={t})");
            }
        }
        // Down nodes are not trigger-checked: 3 rounds × 8 live + 7 × 7.
        assert_eq!(algo.total_checks, 3 * 8 + 7 * 7);
        // Node 2's edges went 7 sync rounds without a fresh copy.
        assert_eq!(algo.max_staleness(), 7);
    }

    #[test]
    fn chaos_is_bit_identical_across_worker_counts() {
        // Crash + partition + corruption composed with a lossy link:
        // every cross-node effect is a pure schedule or a stateless
        // hashed coin, so the trajectory, the bus, and the fault tally
        // are invariant under the pool's thread interleaving.
        let run = |workers: usize| {
            let (mut algo, mut prob, mut bus) =
                mk(8, 16, Box::new(SignTopK::new(4)), ThresholdSchedule::Zero, 1);
            algo.set_link(LinkModel::parse("drop:0.2", 5).unwrap());
            algo.set_fault_plan(
                FaultPlan::parse("crash:1:5:20+partition:10:30:0-3|4-7+corrupt:0.1", 7).unwrap(),
            );
            algo.set_workers(workers);
            for t in 0..40 {
                algo.step(t, &mut prob, &mut bus);
            }
            let params: Vec<Vec<f32>> = (0..8).map(|i| algo.params(i).to_vec()).collect();
            (params, bus.total_bits, algo.fault_counters(), algo.total_fired)
        };
        let (p1, b1, c1, f1) = run(1);
        let (p8, b8, c8, f8) = run(8);
        assert_eq!(p1, p8);
        assert_eq!(b1, b8);
        assert_eq!(c1, c8);
        assert_eq!(f1, f8);
        // and the plan actually did things
        assert_eq!(c1.crashes, 1);
        assert!(c1.resyncs > 0);
        assert!(c1.corrupt_discards > 0);
    }

    #[test]
    fn corrupt_copies_are_charged_but_discarded() {
        // A corrupted copy consumed the link, so it costs exactly what a
        // delivered copy costs — the bus tally matches the fault-free
        // run — but the receiver's checksum rejects it, so the consensus
        // trajectory diverges.
        let run = |spec: &str| {
            let (mut algo, mut prob, mut bus) =
                mk(6, 12, Box::new(SignTopK::new(3)), ThresholdSchedule::Zero, 1);
            algo.set_fault_plan(FaultPlan::parse(spec, 11).unwrap());
            for t in 0..30 {
                algo.step(t, &mut prob, &mut bus);
            }
            let params = algo.params(0).to_vec();
            (params, bus.total_bits, algo.fault_counters())
        };
        let (clean_params, clean_bits, clean_c) = run("none");
        let (noisy_params, noisy_bits, noisy_c) = run("corrupt:0.4");
        assert_eq!(clean_bits, noisy_bits, "corrupt copies must still be charged");
        assert!(clean_c.is_zero());
        assert!(noisy_c.corrupt_discards > 0);
        assert_eq!(noisy_c.crashes, 0);
        assert_ne!(clean_params, noisy_params, "discards must affect consensus");
    }
}
