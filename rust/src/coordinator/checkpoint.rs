//! Checkpointing: persist and restore a training run *exactly*.
//!
//! Format: a JSON header line (version, iteration, dims, algorithm name,
//! bus counters, trigger statistics) followed by raw little-endian
//! blocks: per-node f32 parameters, momentum buffers (when present), the
//! estimate bank x̂ and consensus accumulator rows (estimate-tracking
//! rules), trigger-momentum buffers u (SQuARM runs — an additive
//! `has_trigger_momentum` flag + block between acc and rng, so files
//! from non-SQuARM runs keep their exact prior bytes), and each node's
//! xoshiro256** RNG state. The header length is the first line so the
//! file is self-describing.
//!
//! Version 2 (this layout) captures everything a
//! [`DecentralizedEngine`](super::engine::DecentralizedEngine) run needs
//! for **bit-for-bit resume**: restoring a mid-run snapshot and stepping
//! to the horizon produces exactly the parameters, estimates, and bus
//! totals of the uninterrupted run (`rust/tests/sweep_system.rs` pins
//! this for SPARQ with momentum, CHOCO, and vanilla). Version-1 files
//! (params + momentum only) still load, with the extended blocks empty —
//! enough to warm-start, not enough for exact resume.

use std::fmt;
use std::fs::File;
use std::io::{BufReader, BufWriter, Read, Write};
use std::path::Path;

use super::DecentralizedAlgo;
use crate::comm::{Bus, FaultCounters};
use crate::util::json::Json;

/// Structured shape-mismatch error from [`restore`]: the snapshot does
/// not fit the run it is being applied to. Mirrors the config surface's
/// parse-don't-validate style (`config::ConfigError`): callers match on
/// structure or render `Display` — nothing panics on a stale or foreign
/// checkpoint file, and a rejected restore leaves the run untouched.
#[derive(Clone, Debug, PartialEq, Eq)]
pub struct RestoreError {
    /// What didn't line up (`nodes`, `dim`, `algo`, or a block name).
    pub field: String,
    /// What the target run requires.
    pub expected: String,
    /// What the checkpoint holds.
    pub found: String,
    /// An actionable fix, when one is obvious.
    pub suggestion: Option<String>,
}

impl RestoreError {
    fn new(
        field: impl Into<String>,
        expected: impl Into<String>,
        found: impl Into<String>,
    ) -> RestoreError {
        RestoreError {
            field: field.into(),
            expected: expected.into(),
            found: found.into(),
            suggestion: None,
        }
    }

    fn suggest(mut self, s: impl Into<String>) -> RestoreError {
        self.suggestion = Some(s.into());
        self
    }
}

impl fmt::Display for RestoreError {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(
            f,
            "checkpoint mismatch on {}: run expects {}, snapshot holds {}",
            self.field, self.expected, self.found
        )?;
        if let Some(s) = &self.suggestion {
            write!(f, " (try: {s})")?;
        }
        Ok(())
    }
}

impl std::error::Error for RestoreError {}

/// Everything needed to resume a run.
#[derive(Clone, Debug, PartialEq)]
pub struct Checkpoint {
    pub t: u64,
    pub algo_name: String,
    pub total_bits: u64,
    pub comm_rounds: u64,
    pub total_messages: u64,
    /// Per-node cumulative sent bits (empty for v1 files).
    pub node_bits: Vec<u64>,
    /// Cumulative trigger statistics (0 for v1 files).
    pub fired: u64,
    pub checks: u64,
    /// Per-node parameter vectors.
    pub params: Vec<Vec<f32>>,
    /// Per-node momentum buffers (empty if the run has none).
    pub momentum: Vec<Vec<f32>>,
    /// Estimate bank x̂ (empty for rules without one, and for v1 files).
    pub xhat: Vec<Vec<f32>>,
    /// Materialized consensus accumulator rows (paired with `xhat`; the
    /// accumulator is maintained incrementally during a run, so it must
    /// be restored verbatim rather than recomputed from the bank).
    pub acc: Vec<Vec<f32>>,
    /// Trigger-side momentum buffers u (SQuARM-SGD; empty for plain-drift
    /// triggers and for snapshots taken before the first sync round).
    pub trig_momentum: Vec<Vec<f32>>,
    /// Per-node RNG stream states (empty for v1 files).
    pub rng: Vec<[u64; 4]>,
    /// Cumulative fault counters (zero for fault-free runs and for files
    /// written before the chaos engine existed — the header keys default
    /// to 0 on load, so old files stay readable).
    pub fault: FaultCounters,
}

/// Capture the full coordinator state at iteration t (a round boundary).
pub fn snapshot(algo: &dyn DecentralizedAlgo, t: u64, bus: &Bus) -> Checkpoint {
    let n = algo.n();
    let (fired, checks) = algo.fired_stats();
    Checkpoint {
        t,
        algo_name: algo.name(),
        total_bits: bus.total_bits,
        comm_rounds: bus.comm_rounds,
        total_messages: bus.total_messages,
        node_bits: bus.node_bits.clone(),
        fired,
        checks,
        params: (0..n).map(|i| algo.params(i).to_vec()).collect(),
        momentum: (0..n)
            .filter_map(|i| algo.momentum(i).map(|m| m.to_vec()))
            .collect(),
        xhat: (0..n)
            .filter_map(|i| algo.estimate(i).map(|h| h.to_vec()))
            .collect(),
        acc: (0..n)
            .filter_map(|i| algo.consensus_acc(i).map(|a| a.to_vec()))
            .collect(),
        trig_momentum: (0..n)
            .filter_map(|i| algo.trigger_momentum(i).map(|u| u.to_vec()))
            .collect(),
        rng: (0..n).filter_map(|i| algo.rng_state(i)).collect(),
        fault: algo.fault_counters(),
    }
}

/// Restore node state from a checkpoint. For v2 checkpoints of an engine
/// run this is a *complete* restore: params, momentum, estimate bank +
/// accumulator, per-node RNG streams, trigger statistics, and fault
/// counters, with any time-varying topology schedule (and fault-window
/// state) replayed to the snapshot iteration first.
///
/// A snapshot that does not fit the run — wrong node count, wrong
/// dimension, a different algorithm, ragged blocks — is rejected up
/// front with a structured [`RestoreError`] before any state is touched,
/// so a failed restore leaves the run exactly as it was.
pub fn restore(algo: &mut dyn DecentralizedAlgo, ckpt: &Checkpoint) -> Result<(), RestoreError> {
    let fit = "re-run with the config the snapshot was taken from, or delete the checkpoint";
    if ckpt.n() != algo.n() {
        return Err(
            RestoreError::new("nodes", algo.n().to_string(), ckpt.n().to_string()).suggest(fit),
        );
    }
    let d = algo.params(0).len();
    if ckpt.dim() != d {
        return Err(
            RestoreError::new("dim", d.to_string(), ckpt.dim().to_string()).suggest(fit),
        );
    }
    if !ckpt.algo_name.is_empty() && ckpt.algo_name != algo.name() {
        return Err(RestoreError::new("algo", algo.name(), ckpt.algo_name.clone()).suggest(fit));
    }
    for (name, block) in [
        ("momentum", &ckpt.momentum),
        ("xhat", &ckpt.xhat),
        ("acc", &ckpt.acc),
        ("trig_momentum", &ckpt.trig_momentum),
    ] {
        if !block.is_empty() && block.len() != ckpt.n() {
            return Err(RestoreError::new(
                format!("{name} block"),
                format!("{} rows", ckpt.n()),
                format!("{} rows", block.len()),
            ));
        }
    }
    for (name, block) in [
        ("params", &ckpt.params),
        ("momentum", &ckpt.momentum),
        ("xhat", &ckpt.xhat),
        ("acc", &ckpt.acc),
        ("trig_momentum", &ckpt.trig_momentum),
    ] {
        if let Some(row) = block.iter().find(|r| r.len() != d) {
            return Err(RestoreError::new(
                format!("{name} row"),
                format!("{d} values"),
                format!("{} values", row.len()),
            ));
        }
    }
    algo.prepare_resume(ckpt.t);
    for (i, p) in ckpt.params.iter().enumerate() {
        algo.set_node_params(i, p);
    }
    for (i, m) in ckpt.momentum.iter().enumerate() {
        algo.set_node_momentum(i, m);
    }
    for (i, u) in ckpt.trig_momentum.iter().enumerate() {
        algo.set_node_trigger_momentum(i, u);
    }
    if !ckpt.xhat.is_empty() {
        algo.restore_estimates(&ckpt.xhat, &ckpt.acc);
    }
    for (i, s) in ckpt.rng.iter().enumerate() {
        algo.set_rng_state(i, *s);
    }
    algo.set_fired_stats(ckpt.fired, ckpt.checks);
    algo.set_fault_counters(ckpt.fault);
    Ok(())
}

/// Restore the bus counters from a checkpoint (snapshots are taken at
/// round boundaries, so the private in-round counters are zero by
/// construction).
pub fn restore_bus(bus: &mut Bus, ckpt: &Checkpoint) {
    bus.total_bits = ckpt.total_bits;
    bus.comm_rounds = ckpt.comm_rounds;
    bus.total_messages = ckpt.total_messages;
    if ckpt.node_bits.len() == bus.node_bits.len() {
        bus.node_bits.copy_from_slice(&ckpt.node_bits);
    }
}

impl Checkpoint {
    pub fn n(&self) -> usize {
        self.params.len()
    }

    pub fn dim(&self) -> usize {
        self.params.first().map(Vec::len).unwrap_or(0)
    }

    pub fn save(&self, path: &Path) -> std::io::Result<()> {
        let mut header = Json::obj()
            .set("version", 2u64)
            .set("t", self.t)
            .set("algo", self.algo_name.as_str())
            .set("total_bits", self.total_bits)
            .set("comm_rounds", self.comm_rounds)
            .set("total_messages", self.total_messages)
            .set("node_bits", self.node_bits.clone())
            .set("fired", self.fired)
            .set("checks", self.checks)
            .set("n", self.params.len())
            .set("dim", self.dim())
            .set("has_momentum", !self.momentum.is_empty())
            .set("has_estimates", !self.xhat.is_empty())
            .set("has_rng", !self.rng.is_empty());
        // Additive keys, written only when meaningful: fault-free runs
        // keep the exact pre-chaos header bytes, and the loader's
        // default-0 reads keep both directions compatible. The SQuARM
        // trigger-momentum flag follows the same rule: absent ⇒ no block.
        if !self.trig_momentum.is_empty() {
            header = header.set("has_trigger_momentum", true);
        }
        if !self.fault.is_zero() {
            header = header
                .set("f_crashes", self.fault.crashes)
                .set("f_resyncs", self.fault.resyncs)
                .set("f_corrupt", self.fault.corrupt_discards);
        }
        let header = header.to_string();
        let mut w = BufWriter::new(File::create(path)?);
        writeln!(w, "{header}")?;
        let write_f32_block = |w: &mut BufWriter<File>,
                                   block: &[Vec<f32>]|
         -> std::io::Result<()> {
            for row in block {
                for v in row {
                    w.write_all(&v.to_le_bytes())?;
                }
            }
            Ok(())
        };
        write_f32_block(&mut w, &self.params)?;
        write_f32_block(&mut w, &self.momentum)?;
        write_f32_block(&mut w, &self.xhat)?;
        write_f32_block(&mut w, &self.acc)?;
        write_f32_block(&mut w, &self.trig_momentum)?;
        for s in &self.rng {
            for word in s {
                w.write_all(&word.to_le_bytes())?;
            }
        }
        Ok(())
    }

    pub fn load(path: &Path) -> std::io::Result<Checkpoint> {
        let mut r = BufReader::new(File::open(path)?);
        let mut header = String::new();
        // read the first line (header)
        let mut byte = [0u8; 1];
        loop {
            r.read_exact(&mut byte)?;
            if byte[0] == b'\n' {
                break;
            }
            header.push(byte[0] as char);
        }
        let j = Json::parse(&header)
            .map_err(|e| std::io::Error::new(std::io::ErrorKind::InvalidData, e.to_string()))?;
        // Strict header numerics: a *missing* key reads as 0 (additive-key
        // compatibility across checkpoint versions), but a damaged value —
        // fractional, negative, non-numeric — is a load error instead of a
        // silent `as u64` truncation. `n`/`dim` size the binary block
        // reads below, so a truncated value would desync the whole file.
        let bad = |k: &str| {
            std::io::Error::new(
                std::io::ErrorKind::InvalidData,
                format!("checkpoint header {k:?} is not a non-negative integer"),
            )
        };
        let get = |k: &str| -> std::io::Result<u64> {
            match j.get(k) {
                None => Ok(0),
                Some(v) => v.as_u64().ok_or_else(|| bad(k)),
            }
        };
        let flag = |k: &str| -> bool { j.get(k).and_then(Json::as_bool).unwrap_or(false) };
        let version = get("version")?;
        let n = get("n")? as usize;
        let dim = get("dim")? as usize;
        let has_momentum = flag("has_momentum");
        let has_estimates = version >= 2 && flag("has_estimates");
        let has_trigger_momentum = version >= 2 && flag("has_trigger_momentum");
        let has_rng = version >= 2 && flag("has_rng");
        let node_bits: Vec<u64> = match j.get("node_bits").and_then(Json::as_arr) {
            Some(a) => a
                .iter()
                .map(|v| v.as_u64().ok_or_else(|| bad("node_bits")))
                .collect::<std::io::Result<_>>()?,
            None => Vec::new(),
        };

        let mut read_block = |count: usize| -> std::io::Result<Vec<Vec<f32>>> {
            let mut out = Vec::with_capacity(count);
            let mut buf = vec![0u8; dim * 4];
            for _ in 0..count {
                r.read_exact(&mut buf)?;
                out.push(
                    buf.chunks_exact(4)
                        .map(|b| f32::from_le_bytes([b[0], b[1], b[2], b[3]]))
                        .collect(),
                );
            }
            Ok(out)
        };
        let params = read_block(n)?;
        let momentum = if has_momentum { read_block(n)? } else { Vec::new() };
        let xhat = if has_estimates { read_block(n)? } else { Vec::new() };
        let acc = if has_estimates { read_block(n)? } else { Vec::new() };
        let trig_momentum = if has_trigger_momentum { read_block(n)? } else { Vec::new() };
        let mut rng = Vec::new();
        if has_rng {
            let mut buf = [0u8; 32];
            for _ in 0..n {
                r.read_exact(&mut buf)?;
                let mut s = [0u64; 4];
                for (w, chunk) in s.iter_mut().zip(buf.chunks_exact(8)) {
                    *w = u64::from_le_bytes(chunk.try_into().unwrap());
                }
                rng.push(s);
            }
        }
        Ok(Checkpoint {
            t: get("t")?,
            algo_name: j
                .get("algo")
                .and_then(Json::as_str)
                .unwrap_or("")
                .to_string(),
            total_bits: get("total_bits")?,
            comm_rounds: get("comm_rounds")?,
            total_messages: get("total_messages")?,
            node_bits,
            fired: get("fired")?,
            checks: get("checks")?,
            params,
            momentum,
            xhat,
            acc,
            trig_momentum,
            rng,
            fault: FaultCounters {
                crashes: get("f_crashes")?,
                resyncs: get("f_resyncs")?,
                corrupt_discards: get("f_corrupt")?,
            },
        })
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::Rng;

    fn mk(seed: u64, n: usize, d: usize, momentum: bool, estimates: bool) -> Checkpoint {
        let mut rng = Rng::new(seed);
        let block = |rng: &mut Rng| -> Vec<Vec<f32>> {
            (0..n)
                .map(|_| {
                    let mut v = vec![0.0f32; d];
                    rng.fill_normal(&mut v, 1.0);
                    v
                })
                .collect()
        };
        let params = block(&mut rng);
        let momentum = if momentum { block(&mut rng) } else { Vec::new() };
        let xhat = if estimates { block(&mut rng) } else { Vec::new() };
        let acc = if estimates { block(&mut rng) } else { Vec::new() };
        Checkpoint {
            t: 1234,
            algo_name: "sparq(test)".into(),
            total_bits: 98765,
            comm_rounds: 42,
            total_messages: 17,
            node_bits: (0..n as u64).map(|i| 1000 + i).collect(),
            fired: 33,
            checks: 99,
            params,
            momentum,
            xhat,
            acc,
            trig_momentum: Vec::new(),
            rng: (0..n)
                .map(|i| {
                    let r = Rng::new(seed ^ (i as u64) << 3);
                    r.state()
                })
                .collect(),
            fault: FaultCounters {
                crashes: 2,
                resyncs: 5,
                corrupt_discards: 11,
            },
        }
    }

    #[test]
    fn roundtrip_with_momentum_and_estimates() {
        let ckpt = mk(1, 4, 33, true, true);
        let path = std::env::temp_dir().join(format!("sparq-ckpt-{}.bin", std::process::id()));
        ckpt.save(&path).unwrap();
        let back = Checkpoint::load(&path).unwrap();
        assert_eq!(ckpt, back);
        std::fs::remove_file(&path).ok();
    }

    #[test]
    fn roundtrip_without_momentum_or_estimates() {
        let ckpt = mk(2, 3, 17, false, false);
        let path = std::env::temp_dir().join(format!("sparq-ckpt2-{}.bin", std::process::id()));
        ckpt.save(&path).unwrap();
        let back = Checkpoint::load(&path).unwrap();
        assert_eq!(ckpt, back);
        assert!(back.momentum.is_empty());
        assert!(back.xhat.is_empty() && back.acc.is_empty());
        // rng states persist regardless
        assert_eq!(back.rng.len(), 3);
        std::fs::remove_file(&path).ok();
    }

    #[test]
    fn roundtrip_with_trigger_momentum_block() {
        // SQuARM snapshots carry an extra f32 block between acc and rng;
        // the flag is additive, so files without it keep prior bytes.
        let mut ckpt = mk(7, 3, 9, true, true);
        let mut rng = Rng::new(99);
        ckpt.trig_momentum = (0..3)
            .map(|_| {
                let mut v = vec![0.0f32; 9];
                rng.fill_normal(&mut v, 1.0);
                v
            })
            .collect();
        let path =
            std::env::temp_dir().join(format!("sparq-ckpt-trig-{}.bin", std::process::id()));
        ckpt.save(&path).unwrap();
        let bytes = std::fs::read(&path).unwrap();
        let nl = bytes.iter().position(|&b| b == b'\n').unwrap();
        let header = std::str::from_utf8(&bytes[..nl]).unwrap();
        assert!(header.contains("has_trigger_momentum"), "{header}");
        let back = Checkpoint::load(&path).unwrap();
        assert_eq!(ckpt, back);
        std::fs::remove_file(&path).ok();
        // Plain runs omit the flag entirely.
        let plain = mk(8, 2, 4, false, false);
        let path2 =
            std::env::temp_dir().join(format!("sparq-ckpt-notrig-{}.bin", std::process::id()));
        plain.save(&path2).unwrap();
        let bytes = std::fs::read(&path2).unwrap();
        let nl = bytes.iter().position(|&b| b == b'\n').unwrap();
        let header = std::str::from_utf8(&bytes[..nl]).unwrap();
        assert!(!header.contains("has_trigger_momentum"), "{header}");
        assert!(Checkpoint::load(&path2).unwrap().trig_momentum.is_empty());
        std::fs::remove_file(&path2).ok();
    }

    #[test]
    fn header_is_json_v2() {
        let ckpt = mk(3, 2, 5, false, true);
        let path = std::env::temp_dir().join(format!("sparq-ckpt3-{}.bin", std::process::id()));
        ckpt.save(&path).unwrap();
        let bytes = std::fs::read(&path).unwrap();
        let nl = bytes.iter().position(|&b| b == b'\n').unwrap();
        let header = std::str::from_utf8(&bytes[..nl]).unwrap();
        let j = Json::parse(header).unwrap();
        assert_eq!(j.get("version").unwrap().as_usize(), Some(2));
        assert_eq!(j.get("n").unwrap().as_usize(), Some(2));
        assert_eq!(j.get("dim").unwrap().as_usize(), Some(5));
        assert_eq!(j.get("has_estimates").unwrap().as_bool(), Some(true));
        assert_eq!(j.get("fired").unwrap().as_usize(), Some(33));
        std::fs::remove_file(&path).ok();
    }

    #[test]
    fn loads_version1_files_with_empty_extended_blocks() {
        // Hand-write a v1 file (header + params [+ momentum]) and check
        // the loader fills the extended fields with empties.
        let n = 2;
        let d = 3;
        let header = Json::obj()
            .set("version", 1u64)
            .set("t", 77u64)
            .set("algo", "old")
            .set("total_bits", 5u64)
            .set("comm_rounds", 2u64)
            .set("n", n)
            .set("dim", d)
            .set("has_momentum", false)
            .to_string();
        let path = std::env::temp_dir().join(format!("sparq-ckpt-v1-{}.bin", std::process::id()));
        let mut bytes: Vec<u8> = format!("{header}\n").into_bytes();
        for v in 0..(n * d) {
            bytes.extend_from_slice(&(v as f32).to_le_bytes());
        }
        std::fs::write(&path, bytes).unwrap();
        let back = Checkpoint::load(&path).unwrap();
        assert_eq!(back.t, 77);
        assert_eq!(back.params.len(), 2);
        assert_eq!(back.params[1], vec![3.0, 4.0, 5.0]);
        assert!(back.xhat.is_empty() && back.acc.is_empty() && back.rng.is_empty());
        assert_eq!(back.total_messages, 0);
        assert!(back.fault.is_zero());
        std::fs::remove_file(&path).ok();
    }

    #[test]
    fn damaged_header_numeric_is_a_load_error() {
        // Regression: a fractional/negative header numeric used to
        // truncate through `as u64` and desync the binary block reads;
        // it must surface as InvalidData instead.
        for (k, v) in [("n", "2.5"), ("dim", "-3"), ("t", "1.25")] {
            let header = format!(
                r#"{{"version": 1, "t": 7, "n": 2, "dim": 3, "{k}": {v}}}"#
            );
            let path = std::env::temp_dir().join(format!(
                "sparq-ckpt-bad-{k}-{}.bin",
                std::process::id()
            ));
            let mut bytes: Vec<u8> = format!("{header}\n").into_bytes();
            bytes.extend_from_slice(&[0u8; 24]); // 2×3 f32 params
            std::fs::write(&path, bytes).unwrap();
            let err = Checkpoint::load(&path).unwrap_err();
            assert_eq!(err.kind(), std::io::ErrorKind::InvalidData, "{k}");
            assert!(err.to_string().contains(k), "{err}");
            std::fs::remove_file(&path).ok();
        }
    }

    #[test]
    fn fault_free_headers_omit_fault_keys() {
        let mut ckpt = mk(4, 2, 5, false, false);
        ckpt.fault = FaultCounters::default();
        let path = std::env::temp_dir().join(format!("sparq-ckpt4-{}.bin", std::process::id()));
        ckpt.save(&path).unwrap();
        let bytes = std::fs::read(&path).unwrap();
        let nl = bytes.iter().position(|&b| b == b'\n').unwrap();
        let header = std::str::from_utf8(&bytes[..nl]).unwrap();
        assert!(!header.contains("f_crashes"), "{header}");
        let back = Checkpoint::load(&path).unwrap();
        assert_eq!(ckpt, back);
        std::fs::remove_file(&path).ok();
    }

    #[test]
    fn restore_error_display_names_the_mismatch() {
        let e = RestoreError::new("nodes", "8", "4").suggest("delete the checkpoint");
        let s = e.to_string();
        assert!(s.contains("nodes"), "{s}");
        assert!(s.contains("run expects 8"), "{s}");
        assert!(s.contains("snapshot holds 4"), "{s}");
        assert!(s.contains("try: delete the checkpoint"), "{s}");
    }
}
