//! Checkpointing: persist and restore a training run.
//!
//! Format: a JSON header (version, iteration, dims, algorithm name,
//! cumulative bit counters) followed by raw little-endian f32 blocks for
//! every node's parameters (and momentum buffers when present). The
//! header length is the first line so the file is self-describing.

use std::fs::File;
use std::io::{BufReader, BufWriter, Read, Write};
use std::path::Path;

use super::DecentralizedAlgo;
use crate::comm::Bus;
use crate::util::json::Json;

/// Everything needed to resume a run.
#[derive(Clone, Debug, PartialEq)]
pub struct Checkpoint {
    pub t: u64,
    pub algo_name: String,
    pub total_bits: u64,
    pub comm_rounds: u64,
    /// Per-node parameter vectors.
    pub params: Vec<Vec<f32>>,
    /// Per-node momentum buffers (empty if the run has none).
    pub momentum: Vec<Vec<f32>>,
}

/// Capture the full coordinator state at iteration t.
pub fn snapshot(algo: &dyn DecentralizedAlgo, t: u64, bus: &Bus) -> Checkpoint {
    let n = algo.n();
    Checkpoint {
        t,
        algo_name: algo.name(),
        total_bits: bus.total_bits,
        comm_rounds: bus.comm_rounds,
        params: (0..n).map(|i| algo.params(i).to_vec()).collect(),
        momentum: (0..n)
            .filter_map(|i| algo.momentum(i).map(|m| m.to_vec()))
            .collect(),
    }
}

/// Restore node state from a checkpoint (panics on shape mismatch).
pub fn restore(algo: &mut dyn DecentralizedAlgo, ckpt: &Checkpoint) {
    assert_eq!(algo.n(), ckpt.n(), "node count mismatch");
    for (i, p) in ckpt.params.iter().enumerate() {
        algo.set_node_params(i, p);
    }
    for (i, m) in ckpt.momentum.iter().enumerate() {
        algo.set_node_momentum(i, m);
    }
}

impl Checkpoint {
    pub fn n(&self) -> usize {
        self.params.len()
    }

    pub fn dim(&self) -> usize {
        self.params.first().map(Vec::len).unwrap_or(0)
    }

    pub fn save(&self, path: &Path) -> std::io::Result<()> {
        let header = Json::obj()
            .set("version", 1u64)
            .set("t", self.t)
            .set("algo", self.algo_name.as_str())
            .set("total_bits", self.total_bits)
            .set("comm_rounds", self.comm_rounds)
            .set("n", self.params.len())
            .set("dim", self.dim())
            .set("has_momentum", !self.momentum.is_empty())
            .to_string();
        let mut w = BufWriter::new(File::create(path)?);
        writeln!(w, "{header}")?;
        for p in &self.params {
            for v in p {
                w.write_all(&v.to_le_bytes())?;
            }
        }
        for m in &self.momentum {
            for v in m {
                w.write_all(&v.to_le_bytes())?;
            }
        }
        Ok(())
    }

    pub fn load(path: &Path) -> std::io::Result<Checkpoint> {
        let mut r = BufReader::new(File::open(path)?);
        let mut header = String::new();
        // read the first line (header)
        let mut byte = [0u8; 1];
        loop {
            r.read_exact(&mut byte)?;
            if byte[0] == b'\n' {
                break;
            }
            header.push(byte[0] as char);
        }
        let j = Json::parse(&header)
            .map_err(|e| std::io::Error::new(std::io::ErrorKind::InvalidData, e.to_string()))?;
        let get = |k: &str| -> u64 { j.get(k).and_then(Json::as_f64).unwrap_or(0.0) as u64 };
        let n = get("n") as usize;
        let dim = get("dim") as usize;
        let has_momentum = j
            .get("has_momentum")
            .and_then(Json::as_bool)
            .unwrap_or(false);

        let mut read_block = |count: usize| -> std::io::Result<Vec<Vec<f32>>> {
            let mut out = Vec::with_capacity(count);
            let mut buf = vec![0u8; dim * 4];
            for _ in 0..count {
                r.read_exact(&mut buf)?;
                out.push(
                    buf.chunks_exact(4)
                        .map(|b| f32::from_le_bytes([b[0], b[1], b[2], b[3]]))
                        .collect(),
                );
            }
            Ok(out)
        };
        let params = read_block(n)?;
        let momentum = if has_momentum { read_block(n)? } else { Vec::new() };
        Ok(Checkpoint {
            t: get("t"),
            algo_name: j
                .get("algo")
                .and_then(Json::as_str)
                .unwrap_or("")
                .to_string(),
            total_bits: get("total_bits"),
            comm_rounds: get("comm_rounds"),
            params,
            momentum,
        })
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::Rng;

    fn mk(seed: u64, n: usize, d: usize, momentum: bool) -> Checkpoint {
        let mut rng = Rng::new(seed);
        let block = |rng: &mut Rng| -> Vec<Vec<f32>> {
            (0..n)
                .map(|_| {
                    let mut v = vec![0.0f32; d];
                    rng.fill_normal(&mut v, 1.0);
                    v
                })
                .collect()
        };
        Checkpoint {
            t: 1234,
            algo_name: "sparq(test)".into(),
            total_bits: 98765,
            comm_rounds: 42,
            params: block(&mut rng),
            momentum: if momentum { block(&mut rng) } else { Vec::new() },
        }
    }

    #[test]
    fn roundtrip_with_momentum() {
        let ckpt = mk(1, 4, 33, true);
        let path = std::env::temp_dir().join(format!("sparq-ckpt-{}.bin", std::process::id()));
        ckpt.save(&path).unwrap();
        let back = Checkpoint::load(&path).unwrap();
        assert_eq!(ckpt, back);
        std::fs::remove_file(&path).ok();
    }

    #[test]
    fn roundtrip_without_momentum() {
        let ckpt = mk(2, 3, 17, false);
        let path = std::env::temp_dir().join(format!("sparq-ckpt2-{}.bin", std::process::id()));
        ckpt.save(&path).unwrap();
        let back = Checkpoint::load(&path).unwrap();
        assert_eq!(ckpt, back);
        assert!(back.momentum.is_empty());
        std::fs::remove_file(&path).ok();
    }

    #[test]
    fn header_is_json() {
        let ckpt = mk(3, 2, 5, false);
        let path = std::env::temp_dir().join(format!("sparq-ckpt3-{}.bin", std::process::id()));
        ckpt.save(&path).unwrap();
        let bytes = std::fs::read(&path).unwrap();
        let nl = bytes.iter().position(|&b| b == b'\n').unwrap();
        let header = std::str::from_utf8(&bytes[..nl]).unwrap();
        let j = Json::parse(header).unwrap();
        assert_eq!(j.get("n").unwrap().as_usize(), Some(2));
        assert_eq!(j.get("dim").unwrap().as_usize(), Some(5));
        std::fs::remove_file(&path).ok();
    }
}
