//! SQuARM-SGD — momentum-triggered SPARQ (arXiv 2005.07041), as a
//! policy composition over the engine.
//!
//! The step loop is Algorithm 1 verbatim except for the trigger: instead
//! of the instantaneous drift ‖x^{t+½} − x̂‖², each node maintains a
//! trigger-side momentum buffer
//!
//! ```text
//! u_i ← β·u_i + (x_i^{t+½} − x̂_i)      (at every sync index)
//! ```
//!
//! and fires on ‖u_i‖² > c_t·η_t². A node that transmits still sends
//! q = C(x^{t+½} − x̂) — NOT C(u) — so the estimate-tracking identity
//! (every receiver's view of x̂_j advances by exactly what j sent) is
//! untouched; the buffer is flushed to zero after a delivered broadcast
//! and keeps accumulating across silent rounds (and straggler skips).
//! The buffered drift makes the trigger sensitive to *persistent* slow
//! drift that a per-round check under-fires on, which is the SQuARM
//! paper's motivation for combining momentum with event-triggering.
//!
//! Degeneracy pin: β = 0 annihilates the buffer every round, so the fire
//! decisions — and hence the whole trajectory — are bit-for-bit the
//! SPARQ path (`rust/tests/engine_equivalence.rs`).
//!
//! In engine terms this is [`Triggered`] + [`EstimateTracking`] with
//! [`EstimateTracking::with_trigger_beta`] — one constructor-line of
//! difference from SPARQ, which is the point of the plugin architecture.

use super::engine::{DecentralizedEngine, EngineConfig, EstimateTracking, Triggered};
use crate::compress::Compressor;
use crate::graph::MixingMatrix;
use crate::schedule::{LrSchedule, SyncSchedule};
use crate::trigger::EventTrigger;

/// Everything that parameterizes a SQuARM run: [`SparqConfig`]'s inputs
/// plus the trigger-momentum factor β.
///
/// [`SparqConfig`]: super::sparq::SparqConfig
pub struct SquarmConfig {
    pub mixing: MixingMatrix,
    pub compressor: Box<dyn Compressor>,
    pub trigger: EventTrigger,
    pub lr: LrSchedule,
    pub sync: SyncSchedule,
    /// Consensus step size γ; `None` ⇒ tuned heuristic.
    pub gamma: Option<f64>,
    /// Heavy-ball momentum on the local step (same role as SPARQ's).
    pub momentum: f32,
    /// Trigger-momentum factor β ∈ [0, 1); 0 degenerates to SPARQ.
    pub beta: f32,
    pub seed: u64,
}

/// Thin constructor: SQuARM-SGD as a [`DecentralizedEngine`] composition.
pub struct SquarmSgd;

impl SquarmSgd {
    pub fn new(cfg: SquarmConfig, d: usize) -> DecentralizedEngine {
        let name = format!(
            "squarm(beta={}, C={}, trigger={:?}, H={:?})",
            cfg.beta,
            cfg.compressor.name(),
            cfg.trigger.schedule,
            cfg.sync
        );
        let rule = EstimateTracking::with_trigger_beta(&cfg.mixing, d, cfg.beta);
        DecentralizedEngine::new(
            EngineConfig {
                mixing: cfg.mixing,
                compressor: cfg.compressor,
                comm: Box::new(Triggered {
                    sync: cfg.sync,
                    trigger: cfg.trigger,
                }),
                rule: Box::new(rule),
                gamma: cfg.gamma,
                lr: cfg.lr,
                momentum: cfg.momentum,
                seed: cfg.seed,
                name,
            },
            d,
        )
    }
}
