//! Per-node state.
//!
//! In the synchronous setting every neighbor of node i holds the *same*
//! estimate x̂_i (updates are broadcast and applied deterministically —
//! Algorithm 1 line 13 runs identically at every receiver), so the
//! simulation stores one copy per node instead of one per (node, neighbor)
//! pair. The paper's Appendix A.3 matrix form makes the same reduction
//! (a single X̂ matrix).

use crate::compress::SparseVec;
use crate::util::Rng;

/// State owned by one logical worker.
///
/// Every buffer a node touches during the per-node phases (gradient,
/// trigger check, compress) lives here, so the coordinator can hand whole
/// `NodeState`s to pool workers with no shared mutable scratch — that
/// structure is what makes the parallel phases bit-for-bit deterministic
/// regardless of worker count.
#[derive(Clone, Debug)]
pub struct NodeState {
    /// Local model x_i.
    pub x: Vec<f32>,
    /// Heavy-ball momentum buffer (None ⇔ plain SGD).
    pub momentum: Option<Vec<f32>>,
    /// Node-local RNG stream (mini-batch sampling + compressor noise).
    pub rng: Rng,
    /// Scratch: gradient buffer.
    pub grad: Vec<f32>,
    /// Scratch: x^{t+1/2} buffer.
    pub x_half: Vec<f32>,
    /// Scratch: drift x^{t+½} − x̂ fed to the compressor.
    pub diff: Vec<f32>,
    /// Scratch: this node's compressed sparse message q_i.
    pub q: SparseVec,
    /// Whether the event trigger fired at the last sync round.
    pub fired: bool,
    /// SQuARM-SGD trigger momentum u (None ⇔ plain SPARQ trigger).
    /// Allocated lazily by the update rule at the first sync round, and
    /// flushed to zero after every delivered broadcast.
    pub trig_momentum: Option<Vec<f32>>,
}

impl NodeState {
    pub fn new(d: usize, momentum: bool, rng: Rng) -> NodeState {
        NodeState {
            x: vec![0.0; d],
            momentum: if momentum { Some(vec![0.0; d]) } else { None },
            rng,
            grad: vec![0.0; d],
            x_half: vec![0.0; d],
            diff: vec![0.0; d],
            q: SparseVec::new(),
            fired: false,
            trig_momentum: None,
        }
    }

    /// Local step (Algorithm 1 line 4, plus Section 5.2 momentum):
    /// x_half = x − η·(μ_m·m + g), updating m in place.
    pub fn local_step(&mut self, eta: f32, momentum_factor: f32) {
        match self.momentum.as_mut() {
            Some(m) => {
                for ((xh, (xi, gi)), mi) in self
                    .x_half
                    .iter_mut()
                    .zip(self.x.iter().zip(self.grad.iter()))
                    .zip(m.iter_mut())
                {
                    *mi = momentum_factor * *mi + gi;
                    *xh = xi - eta * *mi;
                }
            }
            None => {
                for (xh, (xi, gi)) in self
                    .x_half
                    .iter_mut()
                    .zip(self.x.iter().zip(self.grad.iter()))
                {
                    *xh = xi - eta * gi;
                }
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn plain_sgd_step() {
        let mut n = NodeState::new(3, false, Rng::new(0));
        n.x = vec![1.0, 2.0, 3.0];
        n.grad = vec![1.0, 1.0, 1.0];
        n.local_step(0.5, 0.0);
        assert_eq!(n.x_half, vec![0.5, 1.5, 2.5]);
        // x itself untouched until consensus commits
        assert_eq!(n.x, vec![1.0, 2.0, 3.0]);
    }

    #[test]
    fn momentum_accumulates() {
        let mut n = NodeState::new(2, true, Rng::new(0));
        n.grad = vec![1.0, 0.0];
        n.local_step(1.0, 0.9);
        assert_eq!(n.x_half, vec![-1.0, 0.0]);
        n.x = n.x_half.clone();
        n.local_step(1.0, 0.9);
        // m = 0.9*1 + 1 = 1.9 ⇒ x_half = -1 - 1.9 = -2.9
        assert!((n.x_half[0] + 2.9).abs() < 1e-6);
    }
}
