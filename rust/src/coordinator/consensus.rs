//! Incremental weighted-neighbor accumulation for the consensus step.
//!
//! The consensus update (Algorithm 1 line 15) is
//!
//! ```text
//! x_i ← x_i^{t+½} + γ Σ_{j∈N(i)} w_ij (x̂_j − x̂_i)
//!     = x_i^{t+½} + γ (acc_i − wsum_i · x̂_i),
//!     acc_i = Σ_{j∈N(i)} w_ij x̂_j,   wsum_i = Σ_{j∈N(i)} w_ij
//! ```
//!
//! The seed implementation evaluated the left form per edge: deg(i) full-d
//! read-modify-write passes over x_i per node per sync round (plus a
//! `neighbors[i].clone()` per round). [`NeighborAccumulator`] keeps `acc_i`
//! *materialized between rounds* and updates it incrementally: when node j
//! broadcasts its sparse update q_j (so x̂_j ← x̂_j + q_j), every receiver's
//! accumulator moves by exactly `w_ij · q_j` — an O(nnz · deg) sparse
//! update instead of O(d · deg) dense recomputation. The commit is then a
//! single fused O(d) pass per node, independent across nodes and safe to
//! run on the thread pool.
//!
//! The right-hand form is algebraically identical to the per-edge form
//! (rows of W sum to 1; the w_ii term cancels); floating-point association
//! differs only at rounding level, which the consensus/average-preservation
//! tests bound.

use crate::compress::SparseVec;
use crate::graph::MixingMatrix;

/// Per-node materialized Σ_j w_ij x̂_j plus the static edge structure
/// needed to maintain it under sparse broadcasts.
pub struct NeighborAccumulator {
    /// acc[i] = Σ_{j∈N(i)} w_ij x̂_j (f32, same precision as the bank).
    acc: Vec<Vec<f32>>,
    /// wsum[i] = Σ_{j∈N(i)} w_ij = 1 − w_ii.
    wsum: Vec<f32>,
    /// For each sender j: the (receiver i, w_ij) list, precomputed once so
    /// the hot loop never touches the dense W or clones adjacency lists.
    receivers: Vec<Vec<(usize, f32)>>,
}

impl NeighborAccumulator {
    /// Build for a mixing matrix and parameter dimension d, assuming the
    /// estimate bank starts at x̂ = 0 (so every accumulator starts at 0).
    pub fn new(mixing: &MixingMatrix, d: usize) -> NeighborAccumulator {
        let n = mixing.n();
        let mut wsum = vec![0.0f32; n];
        let mut receivers: Vec<Vec<(usize, f32)>> = vec![Vec::new(); n];
        for i in 0..n {
            let (nbrs, wts) = mixing.row(i);
            for (&j, &wf) in nbrs.iter().zip(wts.iter()) {
                let w = wf as f32;
                if w == 0.0 {
                    continue;
                }
                wsum[i] += w;
                // j's broadcast lands in i's accumulator with weight w_ij.
                receivers[j].push((i, w));
            }
        }
        NeighborAccumulator {
            acc: vec![vec![0.0; d]; n],
            wsum,
            receivers,
        }
    }

    /// Rebuild for a (possibly different) mixing matrix from the current
    /// estimate bank: acc_i = Σ_{j∈N(i)} w_ij x̂_j recomputed densely.
    /// Called when a `TopologySchedule` switches the graph mid-run — one
    /// O(edges · d) pass, after which incremental maintenance resumes on
    /// the new edge set. With an all-zero bank this equals [`new`].
    pub fn from_bank(mixing: &MixingMatrix, xhat: &[Vec<f32>]) -> NeighborAccumulator {
        let d = xhat.first().map(Vec::len).unwrap_or(0);
        let mut nbr = NeighborAccumulator::new(mixing, d);
        for i in 0..mixing.n() {
            let (nbrs, wts) = mixing.row(i);
            for (&j, &wf) in nbrs.iter().zip(wts.iter()) {
                let w = wf as f32;
                if w == 0.0 {
                    continue;
                }
                crate::linalg::vecops::axpy(w, &xhat[j], &mut nbr.acc[i]);
            }
        }
        nbr
    }

    /// Node `from` broadcast sparse update `q` (x̂_from ← x̂_from + q):
    /// move every receiver's accumulator by w_{i,from} · q. O(nnz · deg).
    pub fn apply_broadcast(&mut self, from: usize, q: &SparseVec) {
        for &(i, w) in &self.receivers[from] {
            q.add_scaled_to(w, &mut self.acc[i]);
        }
    }

    /// Like [`apply_broadcast`](Self::apply_broadcast), but only for the
    /// receivers `deliver` accepts (lossy links — `comm::link`). Returns
    /// how many copies were delivered, which is what the bus charges.
    pub fn apply_broadcast_where(
        &mut self,
        from: usize,
        q: &SparseVec,
        mut deliver: impl FnMut(usize) -> bool,
    ) -> usize {
        let mut delivered = 0;
        for &(i, w) in &self.receivers[from] {
            if deliver(i) {
                q.add_scaled_to(w, &mut self.acc[i]);
                delivered += 1;
            }
        }
        delivered
    }

    /// Fused consensus commit for node i: x += γ (acc_i − wsum_i · x̂_i).
    /// Reads only node-i state — callable concurrently across nodes.
    #[inline]
    pub fn commit(&self, i: usize, gamma: f32, xhat_i: &[f32], x: &mut [f32]) {
        let wsum = self.wsum[i];
        for ((xv, av), hv) in x.iter_mut().zip(self.acc[i].iter()).zip(xhat_i.iter()) {
            *xv += gamma * (av - wsum * hv);
        }
    }

    /// The materialized accumulator (exposed for tests/checkpoints).
    pub fn acc(&self, i: usize) -> &[f32] {
        &self.acc[i]
    }

    /// Overwrite the accumulator rows with checkpointed values. Restore
    /// must NOT recompute from the bank ([`from_bank`](Self::from_bank)):
    /// the live accumulator is built incrementally, so a dense
    /// recomputation re-associates the f32 sums and diverges from an
    /// uninterrupted run at rounding level.
    pub fn restore_acc(&mut self, rows: &[Vec<f32>]) {
        assert_eq!(rows.len(), self.acc.len(), "accumulator row count mismatch");
        for (dst, src) in self.acc.iter_mut().zip(rows.iter()) {
            dst.copy_from_slice(src);
        }
    }

    /// Σ_{j∈N(i)} w_ij (exposed for tests).
    pub fn wsum(&self, i: usize) -> f32 {
        self.wsum[i]
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::graph::{uniform_neighbor, Topology, TopologyKind};
    use crate::linalg::vecops::scale_add;
    use crate::util::Rng;

    fn randvec(rng: &mut Rng, d: usize) -> Vec<f32> {
        let mut v = vec![0.0f32; d];
        rng.fill_normal(&mut v, 1.0);
        v
    }

    /// Reference: per-edge dense consensus exactly as the seed wrote it.
    fn per_edge_commit(
        mixing: &crate::graph::MixingMatrix,
        gamma: f32,
        xhat: &[Vec<f32>],
        x: &mut [Vec<f32>],
    ) {
        for i in 0..mixing.n() {
            for &j in &mixing.topology.neighbors[i] {
                let w = mixing.weight(i, j) as f32;
                if w == 0.0 {
                    continue;
                }
                scale_add(&mut x[i], gamma * w, &xhat[j], &xhat[i]);
            }
        }
    }

    #[test]
    fn incremental_accumulation_matches_per_edge_reference() {
        let d = 24;
        let topo = Topology::new(TopologyKind::Ring, 6, 0);
        let mixing = uniform_neighbor(&topo);
        let mut nbr = NeighborAccumulator::new(&mixing, d);
        let mut xhat: Vec<Vec<f32>> = vec![vec![0.0; d]; 6];
        let mut rng = Rng::new(3);

        // Several rounds of random sparse broadcasts from random subsets.
        for round in 0..20 {
            for j in 0..6 {
                if (round + j) % 3 == 0 {
                    continue; // silent node this round
                }
                let dense = randvec(&mut rng, d)
                    .iter()
                    .enumerate()
                    .map(|(c, v)| if c % 4 == (j + round) % 4 { *v } else { 0.0 })
                    .collect::<Vec<f32>>();
                let q = crate::compress::SparseVec::from_dense(&dense);
                q.add_to(&mut xhat[j]);
                nbr.apply_broadcast(j, &q);
            }
        }

        // Both commit forms must agree to f32 rounding on the same x.
        let gamma = 0.37f32;
        let x0: Vec<Vec<f32>> = (0..6).map(|_| randvec(&mut rng, d)).collect();
        let mut fused = x0.clone();
        for i in 0..6 {
            let xhat_i = &xhat[i];
            nbr.commit(i, gamma, xhat_i, &mut fused[i]);
        }
        let mut reference = x0.clone();
        per_edge_commit(&mixing, gamma, &xhat, &mut reference);
        for i in 0..6 {
            for c in 0..d {
                assert!(
                    (fused[i][c] - reference[i][c]).abs() < 1e-4,
                    "node {i} coord {c}: {} vs {}",
                    fused[i][c],
                    reference[i][c]
                );
            }
        }
    }

    #[test]
    fn wsum_is_one_minus_self_weight() {
        let topo = Topology::new(TopologyKind::Ring, 8, 0);
        let mixing = uniform_neighbor(&topo);
        let nbr = NeighborAccumulator::new(&mixing, 4);
        for i in 0..8 {
            let expect = (1.0 - mixing.weight(i, i)) as f32;
            assert!((nbr.wsum(i) - expect).abs() < 1e-6);
        }
    }

    #[test]
    fn from_bank_matches_incremental_accumulation() {
        let d = 16;
        let topo = Topology::new(TopologyKind::Torus, 9, 0);
        let mixing = uniform_neighbor(&topo);
        let mut nbr = NeighborAccumulator::new(&mixing, d);
        let mut xhat: Vec<Vec<f32>> = vec![vec![0.0; d]; 9];
        let mut rng = Rng::new(7);
        for _round in 0..10 {
            for j in 0..9 {
                let q = crate::compress::SparseVec::from_dense(&randvec(&mut rng, d));
                q.add_to(&mut xhat[j]);
                nbr.apply_broadcast(j, &q);
            }
        }
        let rebuilt = NeighborAccumulator::from_bank(&mixing, &xhat);
        for i in 0..9 {
            assert!((rebuilt.wsum(i) - nbr.wsum(i)).abs() < 1e-6);
            for c in 0..d {
                assert!(
                    (rebuilt.acc(i)[c] - nbr.acc(i)[c]).abs() < 1e-3,
                    "node {i} coord {c}: {} vs {}",
                    rebuilt.acc(i)[c],
                    nbr.acc(i)[c]
                );
            }
        }
    }

    #[test]
    fn from_bank_on_zero_bank_equals_new() {
        let topo = Topology::new(TopologyKind::Ring, 5, 0);
        let mixing = uniform_neighbor(&topo);
        let xhat = vec![vec![0.0f32; 6]; 5];
        let rebuilt = NeighborAccumulator::from_bank(&mixing, &xhat);
        for i in 0..5 {
            assert!(rebuilt.acc(i).iter().all(|v| *v == 0.0));
        }
    }

    #[test]
    fn filtered_broadcast_only_reaches_accepted_receivers() {
        let topo = Topology::new(TopologyKind::Complete, 4, 0);
        let mixing = uniform_neighbor(&topo);
        let mut nbr = NeighborAccumulator::new(&mixing, 4);
        let q = crate::compress::SparseVec::from_dense(&[1.0, 0.0, 0.0, 2.0]);
        let delivered = nbr.apply_broadcast_where(0, &q, |to| to != 2);
        assert_eq!(delivered, 2); // receivers 1 and 3
        assert!(nbr.acc(2).iter().all(|v| *v == 0.0));
        let w = mixing.weight(1, 0) as f32;
        assert!((nbr.acc(1)[0] - w * 1.0).abs() < 1e-7);
        assert!((nbr.acc(3)[3] - w * 2.0).abs() < 1e-7);
    }

    #[test]
    fn zero_broadcasts_keep_accumulators_zero() {
        let topo = Topology::new(TopologyKind::Complete, 4, 0);
        let mixing = uniform_neighbor(&topo);
        let mut nbr = NeighborAccumulator::new(&mixing, 8);
        nbr.apply_broadcast(0, &crate::compress::SparseVec::new());
        for i in 0..4 {
            assert!(nbr.acc(i).iter().all(|v| *v == 0.0));
        }
    }
}
