//! The leader loop: drive an algorithm for T iterations, evaluate at
//! intervals, account communication, and emit a metrics series.

use super::DecentralizedAlgo;
use crate::metrics::{RoundRecord, Series};
use crate::problems::GradientSource;
use crate::run::{Run, RunObserver};

/// Options for one training run.
#[derive(Clone, Debug)]
pub struct RunOptions {
    pub steps: u64,
    /// Evaluate every `eval_every` iterations (plus at t = steps−1).
    pub eval_every: u64,
    /// Print progress lines to stdout.
    pub verbose: bool,
    /// Worker threads for the per-node phases (1 ⇒ sequential, 0 ⇒
    /// available CPUs). Bit-for-bit deterministic across values.
    pub workers: usize,
}

impl Default for RunOptions {
    fn default() -> Self {
        RunOptions {
            steps: 1000,
            eval_every: 50,
            verbose: false,
            workers: 1,
        }
    }
}

/// Prints the classic per-eval progress line.
struct VerboseObserver {
    verbose: bool,
}

impl RunObserver for VerboseObserver {
    fn evaluated(&mut self, record: &RoundRecord, _done: bool) -> bool {
        if self.verbose {
            println!(
                "  t={:<7} loss={:.4} err={:.4} bits={} rounds={} consensus={:.3e}",
                record.t,
                record.loss,
                record.test_error,
                record.bits,
                record.comm_rounds,
                record.consensus
            );
        }
        false
    }
}

/// Run `algo` on `src` and return the evaluated metric series.
///
/// Compatibility facade over the [`Run`](crate::run::Run) handle: the
/// borrowed algorithm/source pair drives through the exact same loop the
/// sweep engine and the examples use (the `&mut dyn` forwarding impls
/// make borrows first-class run inputs).
pub fn run(
    algo: &mut dyn DecentralizedAlgo,
    src: &mut dyn GradientSource,
    opts: &RunOptions,
) -> Series {
    algo.set_workers(opts.workers);
    let label = algo.name();
    let mut run = Run::new(algo, src, opts.steps, opts.eval_every, label);
    run.drive(&mut VerboseObserver {
        verbose: opts.verbose,
    })
    .expect("VerboseObserver cannot fail");
    run.into_series()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::compress::SignTopK;
    use crate::coordinator::sparq::{SparqConfig, SparqSgd};
    use crate::graph::{uniform_neighbor, Topology, TopologyKind};
    use crate::problems::QuadraticProblem;
    use crate::schedule::{LrSchedule, SyncSchedule};
    use crate::trigger::{EventTrigger, ThresholdSchedule};

    #[test]
    fn produces_monotone_time_series() {
        let topo = Topology::new(TopologyKind::Ring, 6, 0);
        let cfg = SparqConfig {
            mixing: uniform_neighbor(&topo),
            compressor: Box::new(SignTopK::new(3)),
            trigger: EventTrigger::new(ThresholdSchedule::Zero),
            lr: LrSchedule::InverseTime { a: 40.0, b: 2.0 },
            sync: SyncSchedule::EveryH(5),
            gamma: None,
            momentum: 0.0,
            seed: 1,
        };
        let mut algo = SparqSgd::new(cfg, 12);
        let mut prob = QuadraticProblem::new(12, 6, 0.5, 2.0, 0.05, 1.0, 2);
        let series = run(
            &mut algo,
            &mut prob,
            &RunOptions {
                steps: 500,
                eval_every: 100,
                verbose: false,
                workers: 1,
            },
        );
        // t=0 eval + 5 interval evals
        assert_eq!(series.records.len(), 6);
        assert!(series
            .records
            .windows(2)
            .all(|w| w[0].t < w[1].t && w[0].bits <= w[1].bits));
        // optimization actually happened
        let first = series.records.first().unwrap();
        let last = series.records.last().unwrap();
        assert!(last.opt_gap < first.opt_gap * 0.1);
    }
}
