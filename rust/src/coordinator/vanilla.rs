//! Vanilla decentralized SGD (D-PSGD, [LZZ+17]) — the uncompressed
//! baseline of Figures 1a–1d.
//!
//! ```text
//! x_i^{(t+1)} = Σ_j w_ij x_j^{(t)} − η_t g_i^{(t)}
//! ```
//!
//! Every round each node broadcasts its full 32-bit parameter vector to
//! all neighbors; this is what SPARQ's 1000×/15K× bit-savings factors are
//! measured against.
//!
//! In engine terms (see [`engine`](super::engine)): [`AlwaysComm`] comm
//! policy + [`ExactAveraging`] update rule (the gradient is applied
//! *after* mixing, so the rule skips the local half-step). The Identity
//! compressor is installed only so the engine is fully specified; exact
//! averaging charges the full 32·d bits per copy itself.

use super::engine::{AlwaysComm, DecentralizedEngine, EngineConfig, ExactAveraging};
use crate::compress::Identity;
use crate::graph::MixingMatrix;
use crate::schedule::LrSchedule;

/// Thin constructor: D-PSGD as a [`DecentralizedEngine`] composition.
pub struct VanillaDecentralized;

impl VanillaDecentralized {
    pub fn new(
        mixing: MixingMatrix,
        lr: LrSchedule,
        momentum: f32,
        d: usize,
        seed: u64,
    ) -> DecentralizedEngine {
        let n = mixing.n();
        DecentralizedEngine::new(
            EngineConfig {
                mixing,
                compressor: Box::new(Identity),
                comm: Box::new(AlwaysComm),
                rule: Box::new(ExactAveraging::new(n, d)),
                // Exact averaging has no γ-consensus step; pinning γ = 0
                // also skips the eigen solve at construction.
                gamma: Some(0.0),
                lr,
                momentum,
                seed,
                name: "vanilla-dpsgd".into(),
            },
            d,
        )
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::comm::Bus;
    use crate::coordinator::DecentralizedAlgo;
    use crate::graph::{uniform_neighbor, Topology, TopologyKind};
    use crate::problems::QuadraticProblem;

    #[test]
    fn bits_are_full_precision() {
        let topo = Topology::new(TopologyKind::Ring, 6, 0);
        let mut algo = VanillaDecentralized::new(
            uniform_neighbor(&topo),
            LrSchedule::Constant(0.05),
            0.0,
            20,
            1,
        );
        let mut prob = QuadraticProblem::new(20, 6, 0.5, 2.0, 0.0, 1.0, 2);
        let mut bus = Bus::new(6);
        algo.step(0, &mut prob, &mut bus);
        // 6 nodes × 2 neighbors × 32·20 bits
        assert_eq!(bus.total_bits, 6 * 2 * 32 * 20);
    }

    #[test]
    fn converges_and_reaches_consensus() {
        let topo = Topology::new(TopologyKind::Ring, 8, 0);
        let mut algo = VanillaDecentralized::new(
            uniform_neighbor(&topo),
            LrSchedule::InverseTime { a: 50.0, b: 2.0 },
            0.0,
            16,
            3,
        );
        let mut prob = QuadraticProblem::new(16, 8, 0.5, 2.0, 0.05, 1.0, 4);
        let mut bus = Bus::new(8);
        for t in 0..2000 {
            algo.step(t, &mut prob, &mut bus);
        }
        let gap = prob.suboptimality(&algo.x_bar());
        assert!(gap < 0.02, "suboptimality {gap}");
        assert!(algo.consensus_distance() < 0.1);
    }

    #[test]
    fn single_node_is_plain_sgd() {
        // n = 1 ring degenerates to SGD: W = [1], no communication terms.
        let topo = Topology::new(TopologyKind::Ring, 1, 0);
        let mut algo = VanillaDecentralized::new(
            uniform_neighbor(&topo),
            LrSchedule::Constant(0.2),
            0.0,
            8,
            5,
        );
        let mut prob = QuadraticProblem::new(8, 1, 0.5, 1.5, 0.0, 1.0, 6);
        let mut bus = Bus::new(1);
        for t in 0..300 {
            algo.step(t, &mut prob, &mut bus);
        }
        assert!(prob.suboptimality(algo.params(0)) < 1e-4);
        assert_eq!(bus.total_bits, 0); // no neighbors
    }
}
