//! Vanilla decentralized SGD (D-PSGD, [LZZ+17]) — the uncompressed
//! baseline of Figures 1a–1d.
//!
//! ```text
//! x_i^{(t+1)} = Σ_j w_ij x_j^{(t)} − η_t g_i^{(t)}
//! ```
//!
//! Every round each node broadcasts its full 32-bit parameter vector to
//! all neighbors; this is what SPARQ's 1000×/15K× bit-savings factors are
//! measured against.

use super::node::NodeState;
use super::{gradient_phase, DecentralizedAlgo};
use crate::comm::Bus;
use crate::graph::MixingMatrix;
use crate::problems::GradientSource;
use crate::schedule::LrSchedule;
use crate::util::threadpool::ThreadPool;
use crate::util::Rng;

pub struct VanillaDecentralized {
    pub mixing: MixingMatrix,
    pub lr: LrSchedule,
    pub momentum: f32,
    nodes: Vec<NodeState>,
    mixed: Vec<Vec<f32>>,
    pool: ThreadPool,
}

impl VanillaDecentralized {
    pub fn new(
        mixing: MixingMatrix,
        lr: LrSchedule,
        momentum: f32,
        d: usize,
        seed: u64,
    ) -> VanillaDecentralized {
        let n = mixing.n();
        let mut root = Rng::new(seed);
        let nodes = (0..n)
            .map(|i| NodeState::new(d, momentum > 0.0, root.fork(i as u64)))
            .collect();
        VanillaDecentralized {
            mixing,
            lr,
            momentum,
            nodes,
            mixed: vec![vec![0.0; d]; n],
            pool: ThreadPool::new(1),
        }
    }

    pub fn init_params(&mut self, x0: &[f32]) {
        for node in self.nodes.iter_mut() {
            node.x.copy_from_slice(x0);
        }
    }
}

impl DecentralizedAlgo for VanillaDecentralized {
    fn step(&mut self, t: u64, src: &mut dyn GradientSource, bus: &mut Bus) {
        let n = self.nodes.len();
        let d = self.nodes[0].x.len();
        let eta = self.lr.eta(t) as f32;

        // Gradients at current params (no local half-step here — the
        // gradient is applied after mixing below).
        gradient_phase(&self.pool, &mut self.nodes, src, None);

        // Exact neighbor averaging (everyone broadcasts x_i in full) —
        // each row reads the immutable parameter bank and writes only its
        // own mixed buffer, so rows fan out on the pool.
        for i in 0..n {
            bus.charge_broadcast(i, self.mixing.topology.degree(i), 32 * d as u64);
        }
        let pool = &self.pool;
        let mixing = &self.mixing;
        let nodes = &self.nodes;
        pool.for_each_mut(&mut self.mixed, |i, row| {
            row.fill(0.0);
            let wii = mixing.weight(i, i) as f32;
            for (m, x) in row.iter_mut().zip(nodes[i].x.iter()) {
                *m = wii * x;
            }
            for &j in &mixing.topology.neighbors[i] {
                let w = mixing.weight(i, j) as f32;
                for (m, x) in row.iter_mut().zip(nodes[j].x.iter()) {
                    *m += w * x;
                }
            }
        });

        // Commit: x_i = mixed_i − η·(momentum-adjusted gradient) —
        // per-node independent, parallel.
        let momentum = self.momentum;
        let mixed = &self.mixed;
        self.pool.for_each_mut(&mut self.nodes, |i, node| {
            match node.momentum.as_mut() {
                Some(m) => {
                    for ((x, mi), (g, mix)) in node
                        .x
                        .iter_mut()
                        .zip(m.iter_mut())
                        .zip(node.grad.iter().zip(mixed[i].iter()))
                    {
                        *mi = momentum * *mi + g;
                        *x = mix - eta * *mi;
                    }
                }
                None => {
                    for (x, (g, mix)) in node
                        .x
                        .iter_mut()
                        .zip(node.grad.iter().zip(mixed[i].iter()))
                    {
                        *x = mix - eta * g;
                    }
                }
            }
        });
        bus.end_round();
    }

    fn params(&self, node: usize) -> &[f32] {
        &self.nodes[node].x
    }

    fn set_params(&mut self, x0: &[f32]) {
        self.init_params(x0);
    }

    fn set_node_params(&mut self, node: usize, x: &[f32]) {
        self.nodes[node].x.copy_from_slice(x);
    }

    fn momentum(&self, node: usize) -> Option<&[f32]> {
        self.nodes[node].momentum.as_deref()
    }

    fn set_node_momentum(&mut self, node: usize, m: &[f32]) {
        if let Some(buf) = self.nodes[node].momentum.as_mut() {
            buf.copy_from_slice(m);
        }
    }

    fn set_workers(&mut self, workers: usize) {
        self.pool = ThreadPool::new(workers);
    }

    fn n(&self) -> usize {
        self.nodes.len()
    }

    fn last_fired(&self) -> usize {
        self.nodes.len()
    }

    fn name(&self) -> String {
        "vanilla-dpsgd".into()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::graph::{uniform_neighbor, Topology, TopologyKind};
    use crate::problems::QuadraticProblem;

    #[test]
    fn bits_are_full_precision() {
        let topo = Topology::new(TopologyKind::Ring, 6, 0);
        let mut algo = VanillaDecentralized::new(
            uniform_neighbor(&topo),
            LrSchedule::Constant(0.05),
            0.0,
            20,
            1,
        );
        let mut prob = QuadraticProblem::new(20, 6, 0.5, 2.0, 0.0, 1.0, 2);
        let mut bus = Bus::new(6);
        algo.step(0, &mut prob, &mut bus);
        // 6 nodes × 2 neighbors × 32·20 bits
        assert_eq!(bus.total_bits, 6 * 2 * 32 * 20);
    }

    #[test]
    fn converges_and_reaches_consensus() {
        let topo = Topology::new(TopologyKind::Ring, 8, 0);
        let mut algo = VanillaDecentralized::new(
            uniform_neighbor(&topo),
            LrSchedule::InverseTime { a: 50.0, b: 2.0 },
            0.0,
            16,
            3,
        );
        let mut prob = QuadraticProblem::new(16, 8, 0.5, 2.0, 0.05, 1.0, 4);
        let mut bus = Bus::new(8);
        for t in 0..2000 {
            algo.step(t, &mut prob, &mut bus);
        }
        let gap = prob.suboptimality(&algo.x_bar());
        assert!(gap < 0.02, "suboptimality {gap}");
        assert!(algo.consensus_distance() < 0.1);
    }

    #[test]
    fn single_node_is_plain_sgd() {
        // n = 1 ring degenerates to SGD: W = [1], no communication terms.
        let topo = Topology::new(TopologyKind::Ring, 1, 0);
        let mut algo = VanillaDecentralized::new(
            uniform_neighbor(&topo),
            LrSchedule::Constant(0.2),
            0.0,
            8,
            5,
        );
        let mut prob = QuadraticProblem::new(8, 1, 0.5, 1.5, 0.0, 1.0, 6);
        let mut bus = Bus::new(1);
        for t in 0..300 {
            algo.step(t, &mut prob, &mut bus);
        }
        assert!(prob.suboptimality(algo.params(0)) < 1e-4);
        assert_eq!(bus.total_bits, 0); // no neighbors
    }
}
