//! CHOCO-SGD [KSJ19, KLSJ19] — the state-of-the-art baseline the paper
//! compares against (Figures 1a–1d).
//!
//! CHOCO is SPARQ without the two communication-saving mechanisms: every
//! iteration is a sync round (H = 1) and every node always transmits its
//! compressed difference (no event trigger). In engine terms (see
//! [`engine`](super::engine)): [`AlwaysComm`] comm policy +
//! [`EstimateTracking`] update rule + the configured compressor — the
//! *same* update rule as SPARQ, so the degenerate-case equivalence
//! SPARQ(c_t = 0, H = 1) ≡ CHOCO is structural, not mirrored code (the
//! `sparq_degenerates_to_choco_exactly` test still pins it bit-for-bit).

use super::engine::{AlwaysComm, DecentralizedEngine, EngineConfig, EstimateTracking};
use crate::compress::Compressor;
use crate::graph::MixingMatrix;
use crate::schedule::LrSchedule;

/// Thin constructor: CHOCO-SGD as a [`DecentralizedEngine`] composition.
pub struct ChocoSgd;

impl ChocoSgd {
    pub fn new(
        mixing: MixingMatrix,
        compressor: Box<dyn Compressor>,
        lr: LrSchedule,
        momentum: f32,
        d: usize,
        seed: u64,
    ) -> DecentralizedEngine {
        Self::with_gamma(mixing, compressor, lr, momentum, None, d, seed)
    }

    /// Like [`new`](Self::new) with an explicit consensus step size γ
    /// (`None` ⇒ the tuned heuristic, computed from the mixing matrix's
    /// eigen solve). Sweeps pass the cached tuned value here so one solve
    /// serves every run on the same graph — bit-identical to letting the
    /// engine compute it.
    pub fn with_gamma(
        mixing: MixingMatrix,
        compressor: Box<dyn Compressor>,
        lr: LrSchedule,
        momentum: f32,
        gamma: Option<f64>,
        d: usize,
        seed: u64,
    ) -> DecentralizedEngine {
        let name = format!("choco(C={})", compressor.name());
        let rule = EstimateTracking::new(&mixing, d);
        DecentralizedEngine::new(
            EngineConfig {
                mixing,
                compressor,
                comm: Box::new(AlwaysComm),
                rule: Box::new(rule),
                gamma,
                lr,
                momentum,
                seed,
                name,
            },
            d,
        )
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::comm::Bus;
    use crate::compress::{SignL1, SignTopK, TopK};
    use crate::coordinator::DecentralizedAlgo;
    use crate::graph::{uniform_neighbor, Topology, TopologyKind};
    use crate::problems::QuadraticProblem;

    fn mk(comp: Box<dyn Compressor>) -> (DecentralizedEngine, QuadraticProblem, Bus) {
        let topo = Topology::new(TopologyKind::Ring, 8, 0);
        let mixing = uniform_neighbor(&topo);
        let algo = ChocoSgd::new(
            mixing,
            comp,
            LrSchedule::InverseTime { a: 50.0, b: 2.0 },
            0.0,
            16,
            7,
        );
        let prob = QuadraticProblem::new(16, 8, 0.5, 2.0, 0.05, 1.0, 3);
        (algo, prob, Bus::new(8))
    }

    #[test]
    fn transmits_every_round() {
        let (mut algo, mut prob, mut bus) = mk(Box::new(TopK::new(4)));
        for t in 0..10 {
            algo.step(t, &mut prob, &mut bus);
        }
        // 8 nodes × 10 rounds
        assert_eq!(bus.total_messages, 80);
        assert_eq!(bus.comm_rounds, 10);
        assert_eq!(algo.last_fired(), 8);
    }

    #[test]
    fn converges_with_each_compressor() {
        for comp in [
            Box::new(SignTopK::new(4)) as Box<dyn Compressor>,
            Box::new(TopK::new(4)),
            Box::new(SignL1),
        ] {
            let name = comp.name();
            let (mut algo, mut prob, mut bus) = mk(comp);
            for t in 0..2500 {
                algo.step(t, &mut prob, &mut bus);
            }
            let gap = prob.suboptimality(&algo.x_bar());
            assert!(gap < 0.05, "{name}: suboptimality {gap}");
        }
    }
}
