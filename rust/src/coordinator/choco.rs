//! CHOCO-SGD [KSJ19, KLSJ19] — the state-of-the-art baseline the paper
//! compares against (Figures 1a–1d).
//!
//! CHOCO is SPARQ without the two communication-saving mechanisms: every
//! iteration is a sync round (H = 1) and every node always transmits its
//! compressed difference (no event trigger). The update is otherwise the
//! same estimate-tracking + consensus scheme, so this implementation is a
//! thin deterministic wrapper over the same primitives — and the
//! `sparq_equals_choco` test pins the equivalence SPARQ(c_t=0, H=1) ≡
//! CHOCO on identical seeds.

use super::consensus::NeighborAccumulator;
use super::node::NodeState;
use super::{gradient_phase, DecentralizedAlgo};
use crate::comm::Bus;
use crate::compress::Compressor;
use crate::graph::{MixingMatrix, SpectralInfo};
use crate::linalg::vecops::sub_into;
use crate::problems::GradientSource;
use crate::schedule::LrSchedule;
use crate::util::threadpool::ThreadPool;
use crate::util::Rng;

pub struct ChocoSgd {
    pub mixing: MixingMatrix,
    pub compressor: Box<dyn Compressor>,
    pub lr: LrSchedule,
    pub gamma: f64,
    pub momentum: f32,
    nodes: Vec<NodeState>,
    xhat: Vec<Vec<f32>>,
    /// Same sparse consensus machinery as SPARQ (consensus.rs) — the phase
    /// structure below mirrors sparq.rs exactly so the degenerate-case
    /// equivalence SPARQ(c_t=0, H=1) ≡ CHOCO stays bit-for-bit.
    nbr: NeighborAccumulator,
    pool: ThreadPool,
}

impl ChocoSgd {
    pub fn new(
        mixing: MixingMatrix,
        compressor: Box<dyn Compressor>,
        lr: LrSchedule,
        momentum: f32,
        d: usize,
        seed: u64,
    ) -> ChocoSgd {
        let n = mixing.n();
        let spectral = SpectralInfo::compute(&mixing);
        let gamma =
            spectral.gamma_tuned(compressor.omega(d), compressor.effective_omega(d));
        let mut root = Rng::new(seed);
        let nodes = (0..n)
            .map(|i| NodeState::new(d, momentum > 0.0, root.fork(i as u64)))
            .collect();
        let nbr = NeighborAccumulator::new(&mixing, d);
        ChocoSgd {
            mixing,
            compressor,
            lr,
            gamma,
            momentum,
            nodes,
            xhat: vec![vec![0.0; d]; n],
            nbr,
            pool: ThreadPool::new(1),
        }
    }

    pub fn init_params(&mut self, x0: &[f32]) {
        for node in self.nodes.iter_mut() {
            node.x.copy_from_slice(x0);
        }
    }
}

impl DecentralizedAlgo for ChocoSgd {
    fn step(&mut self, t: u64, src: &mut dyn GradientSource, bus: &mut Bus) {
        let n = self.nodes.len();
        let eta = self.lr.eta(t) as f32;

        gradient_phase(&self.pool, &mut self.nodes, src, Some((eta, self.momentum)));

        // Every node transmits every round (the CHOCO contract):
        // compress in parallel, then apply in deterministic node order.
        let pool = &self.pool;
        let compressor = &*self.compressor;
        let xhat = &self.xhat;
        pool.for_each_mut(&mut self.nodes, |i, node| {
            sub_into(&node.x_half, &xhat[i], &mut node.diff);
            compressor.compress_sparse(&node.diff, &mut node.rng, &mut node.q);
        });

        let d = self.xhat[0].len();
        for i in 0..n {
            let q = &self.nodes[i].q;
            let bits = self.compressor.message_bits(d, q.nnz());
            bus.charge_broadcast(i, self.mixing.topology.degree(i), bits);
            q.add_to(&mut self.xhat[i]);
            self.nbr.apply_broadcast(i, q);
        }

        let gamma = self.gamma as f32;
        let xhat = &self.xhat;
        let nbr = &self.nbr;
        self.pool.for_each_mut(&mut self.nodes, |i, node| {
            std::mem::swap(&mut node.x, &mut node.x_half);
            nbr.commit(i, gamma, &xhat[i], &mut node.x);
        });
        bus.end_round();
    }

    fn params(&self, node: usize) -> &[f32] {
        &self.nodes[node].x
    }

    fn set_params(&mut self, x0: &[f32]) {
        self.init_params(x0);
    }

    fn set_node_params(&mut self, node: usize, x: &[f32]) {
        self.nodes[node].x.copy_from_slice(x);
    }

    fn momentum(&self, node: usize) -> Option<&[f32]> {
        self.nodes[node].momentum.as_deref()
    }

    fn set_node_momentum(&mut self, node: usize, m: &[f32]) {
        if let Some(buf) = self.nodes[node].momentum.as_mut() {
            buf.copy_from_slice(m);
        }
    }

    fn set_workers(&mut self, workers: usize) {
        self.pool = ThreadPool::new(workers);
    }

    fn n(&self) -> usize {
        self.nodes.len()
    }

    fn last_fired(&self) -> usize {
        self.nodes.len() // everyone transmits
    }

    fn name(&self) -> String {
        format!("choco(C={})", self.compressor.name())
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::compress::{SignL1, SignTopK, TopK};
    use crate::graph::{uniform_neighbor, Topology, TopologyKind};
    use crate::problems::QuadraticProblem;

    fn mk(comp: Box<dyn Compressor>) -> (ChocoSgd, QuadraticProblem, Bus) {
        let topo = Topology::new(TopologyKind::Ring, 8, 0);
        let mixing = uniform_neighbor(&topo);
        let algo = ChocoSgd::new(
            mixing,
            comp,
            LrSchedule::InverseTime { a: 50.0, b: 2.0 },
            0.0,
            16,
            7,
        );
        let prob = QuadraticProblem::new(16, 8, 0.5, 2.0, 0.05, 1.0, 3);
        (algo, prob, Bus::new(8))
    }

    #[test]
    fn transmits_every_round() {
        let (mut algo, mut prob, mut bus) = mk(Box::new(TopK::new(4)));
        for t in 0..10 {
            algo.step(t, &mut prob, &mut bus);
        }
        // 8 nodes × 10 rounds
        assert_eq!(bus.total_messages, 80);
        assert_eq!(bus.comm_rounds, 10);
    }

    #[test]
    fn converges_with_each_compressor() {
        for comp in [
            Box::new(SignTopK::new(4)) as Box<dyn Compressor>,
            Box::new(TopK::new(4)),
            Box::new(SignL1),
        ] {
            let name = comp.name();
            let (mut algo, mut prob, mut bus) = mk(comp);
            for t in 0..2500 {
                algo.step(t, &mut prob, &mut bus);
            }
            let gap = prob.suboptimality(&algo.x_bar());
            assert!(gap < 0.05, "{name}: suboptimality {gap}");
        }
    }
}
