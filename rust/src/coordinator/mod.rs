//! The L3 coordination layer: Algorithm 1 (SPARQ-SGD) and baselines over
//! a simulated synchronous graph.
//!
//! * [`sparq::SparqSgd`] — the paper's algorithm: local SGD steps, event
//!   trigger at sync indices, compressed estimate updates, consensus step.
//! * [`choco::ChocoSgd`] — CHOCO-SGD [KSJ19]: compressed updates every
//!   iteration, no trigger, no local steps (H = 1).
//! * [`vanilla::VanillaDecentralized`] — D-PSGD [LZZ+17]: exact (32-bit)
//!   neighbor averaging every iteration.
//! * [`runner`] — the leader loop: steps an algorithm, evaluates metrics,
//!   accounts bits, emits `metrics::RoundRecord`s.

pub mod node;
pub mod checkpoint;
pub mod consensus;
pub mod sparq;
pub mod choco;
pub mod vanilla;
pub mod runner;

pub use checkpoint::Checkpoint;
pub use choco::ChocoSgd;
pub use consensus::NeighborAccumulator;
pub use runner::{run, RunOptions};
pub use sparq::{SparqConfig, SparqSgd};
pub use vanilla::VanillaDecentralized;

use crate::comm::Bus;
use crate::problems::GradientSource;
use crate::util::threadpool::ThreadPool;

/// The per-node gradient phase shared by every coordinator: stochastic
/// gradient into `node.grad`, then (optionally) the local half-step.
/// Runs on the pool when the source exposes a `Sync` shared-state handle
/// (`GradientSource::shared` — thread-safety is enforced by the type
/// system, no unsafe involved); per-node RNG streams make the result
/// identical either way.
pub(crate) fn gradient_phase(
    pool: &ThreadPool,
    nodes: &mut [node::NodeState],
    src: &mut dyn GradientSource,
    local_step: Option<(f32, f32)>,
) {
    if pool.workers > 1 {
        if let Some(shared) = src.shared() {
            pool.for_each_mut(nodes, |i, node| {
                let x = std::mem::take(&mut node.x);
                shared.grad_shared(i, &x, &mut node.rng, &mut node.grad);
                node.x = x;
                if let Some((eta, momentum)) = local_step {
                    node.local_step(eta, momentum);
                }
            });
            return;
        }
    }
    for (i, node) in nodes.iter_mut().enumerate() {
        let x = std::mem::take(&mut node.x);
        src.grad(i, &x, &mut node.rng, &mut node.grad);
        node.x = x;
        if let Some((eta, momentum)) = local_step {
            node.local_step(eta, momentum);
        }
    }
}

/// A decentralized optimization algorithm advanced one synchronous
/// iteration at a time.
pub trait DecentralizedAlgo {
    /// Advance from iteration t to t+1. Gradients come from `src`,
    /// communication is charged to `bus`.
    fn step(&mut self, t: u64, src: &mut dyn GradientSource, bus: &mut Bus);

    /// Node i's current parameters x_i^{(t)}.
    fn params(&self, node: usize) -> &[f32];

    /// Set every node's parameters to the same initial vector x^{(0)}.
    fn set_params(&mut self, x0: &[f32]);

    /// Set one node's parameters (checkpoint restore).
    fn set_node_params(&mut self, node: usize, x: &[f32]);

    /// Node i's momentum buffer, if the algorithm carries one.
    fn momentum(&self, _node: usize) -> Option<&[f32]> {
        None
    }

    /// Restore one node's momentum buffer (no-op if the run has none).
    fn set_node_momentum(&mut self, _node: usize, _m: &[f32]) {}

    /// Set the worker-thread count for the per-node phases (1 ⇒ fully
    /// sequential, 0 ⇒ available CPUs). Results are bit-for-bit identical
    /// for every worker count — parallel phases only touch per-node state
    /// driven by per-node RNG streams. Default: no-op for algorithms
    /// without parallel phases.
    fn set_workers(&mut self, _workers: usize) {}

    /// Number of nodes.
    fn n(&self) -> usize;

    /// Average iterate x̄ (the quantity the theorems track).
    fn x_bar(&self) -> Vec<f32> {
        let n = self.n();
        let d = self.params(0).len();
        let mut bar = vec![0.0f32; d];
        for i in 0..n {
            for (b, v) in bar.iter_mut().zip(self.params(i).iter()) {
                *b += v;
            }
        }
        for b in bar.iter_mut() {
            *b /= n as f32;
        }
        bar
    }

    /// Consensus distance Σ_i ‖x_i − x̄‖² (Lemma 1's tracked quantity).
    fn consensus_distance(&self) -> f64 {
        let bar = self.x_bar();
        let mut acc = 0.0;
        for i in 0..self.n() {
            acc += crate::linalg::vecops::dist2(self.params(i), &bar);
        }
        acc
    }

    /// Number of nodes whose trigger fired in the last sync round (for
    /// metrics; baselines return n or 0 as appropriate).
    fn last_fired(&self) -> usize {
        0
    }

    /// Algorithm name for logs.
    fn name(&self) -> String;
}
