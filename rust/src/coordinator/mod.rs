//! The L3 coordination layer: one policy-driven engine running the whole
//! SPARQ/CHOCO/D-PSGD family over a simulated synchronous graph.
//!
//! Architecture (since the engine refactor):
//!
//! * [`engine::DecentralizedEngine`] — the single step loop. It is
//!   parameterized by two small policy traits plus the compressor:
//!   - [`engine::CommPolicy`] (*when* to sync, *which* nodes transmit):
//!     [`engine::Triggered`] = sync schedule + event trigger (SPARQ),
//!     [`engine::AlwaysComm`] = every round, every node (CHOCO, D-PSGD);
//!   - [`engine::UpdateRule`] (*what* a sync round does):
//!     [`engine::EstimateTracking`] = compressed estimate bank +
//!     γ-consensus (SPARQ, CHOCO), [`engine::ExactAveraging`] =
//!     full-precision neighbor averaging (D-PSGD);
//!   - [`crate::compress::Compressor`] — the paper's operators.
//! * [`sparq::SparqSgd`] / [`squarm::SquarmSgd`] / [`choco::ChocoSgd`] /
//!   [`vanilla::VanillaDecentralized`] — thin constructors assembling
//!   those compositions; there is no per-algorithm step code anymore, and
//!   `rust/tests/engine_equivalence.rs` pins each constructor to its seed
//!   coordinator bit-for-bit.
//! * Scenario layers pluggable into any composition:
//!   [`crate::comm::LinkModel`] (seeded message drops / stragglers,
//!   bits charged per delivered copy) and
//!   [`crate::graph::TopologySchedule`] (time-varying mixing matrices,
//!   consensus state rebuilt on switch).
//! * [`runner`] — the leader loop: steps an algorithm, evaluates metrics,
//!   accounts bits, emits `metrics::RoundRecord`s.
//!
//! A new scheme is a config line, not a new file: compose an
//! [`engine::EngineConfig`] from existing policies (e.g. local SGD with
//! periodic exact exchanges = `Triggered` sync schedule +
//! `ExactAveraging`, or estimate tracking on sampled gossip edges) and
//! hand it to the runner. Note the composition contract: per-node drift
//! thresholds ([`engine::CommPolicy::fires`]) apply only to
//! estimate-tracking rules — exact averaging has no x̂ bank to measure
//! drift against and is gated by the sync schedule alone.

pub mod node;
pub mod checkpoint;
pub mod consensus;
pub mod engine;
pub mod sparq;
pub mod squarm;
pub mod choco;
pub mod vanilla;
pub mod runner;

pub use checkpoint::{Checkpoint, RestoreError};
pub use choco::ChocoSgd;
pub use consensus::NeighborAccumulator;
pub use engine::{
    AlwaysComm, CommPolicy, DecentralizedEngine, EngineConfig, EstimateTracking,
    ExactAveraging, SyncCtx, SyncOutcome, Triggered, UpdateRule,
};
pub use runner::{run, RunOptions};
pub use sparq::{SparqConfig, SparqSgd};
pub use squarm::{SquarmConfig, SquarmSgd};
pub use vanilla::VanillaDecentralized;

use crate::comm::Bus;
use crate::problems::GradientSource;
use crate::util::threadpool::ThreadPool;

/// The per-node gradient phase shared by every coordinator: stochastic
/// gradient into `node.grad`, then (optionally) the local half-step.
/// Runs on the pool when the source exposes a `Sync` shared-state handle
/// (`GradientSource::shared` — thread-safety is enforced by the type
/// system, no unsafe involved); per-node RNG streams make the result
/// identical either way. Nodes flagged in `down` (a crashed node under a
/// fault plan — `comm::fault`) compute nothing: their parameters, RNG
/// streams, and buffers are frozen exactly as they were when the crash
/// window opened.
pub(crate) fn gradient_phase(
    pool: &ThreadPool,
    nodes: &mut [node::NodeState],
    src: &mut dyn GradientSource,
    local_step: Option<(f32, f32)>,
    down: &[bool],
) {
    if pool.workers > 1 {
        if let Some(shared) = src.shared() {
            pool.for_each_mut(nodes, |i, node| {
                if down[i] {
                    return;
                }
                let x = std::mem::take(&mut node.x);
                shared.grad_shared(i, &x, &mut node.rng, &mut node.grad);
                node.x = x;
                if let Some((eta, momentum)) = local_step {
                    node.local_step(eta, momentum);
                }
            });
            return;
        }
    }
    for (i, node) in nodes.iter_mut().enumerate() {
        if down[i] {
            continue;
        }
        let x = std::mem::take(&mut node.x);
        src.grad(i, &x, &mut node.rng, &mut node.grad);
        node.x = x;
        if let Some((eta, momentum)) = local_step {
            node.local_step(eta, momentum);
        }
    }
}

/// A decentralized optimization algorithm advanced one synchronous
/// iteration at a time.
pub trait DecentralizedAlgo {
    /// Advance from iteration t to t+1. Gradients come from `src`,
    /// communication is charged to `bus`.
    fn step(&mut self, t: u64, src: &mut dyn GradientSource, bus: &mut Bus);

    /// Node i's current parameters x_i^{(t)}.
    fn params(&self, node: usize) -> &[f32];

    /// Set every node's parameters to the same initial vector x^{(0)}.
    fn set_params(&mut self, x0: &[f32]);

    /// Set one node's parameters (checkpoint restore).
    fn set_node_params(&mut self, node: usize, x: &[f32]);

    /// Node i's momentum buffer, if the algorithm carries one.
    fn momentum(&self, _node: usize) -> Option<&[f32]> {
        None
    }

    /// Restore one node's momentum buffer (no-op if the run has none).
    fn set_node_momentum(&mut self, _node: usize, _m: &[f32]) {}

    /// Node i's trigger-side momentum buffer u (SQuARM-SGD), if the
    /// algorithm evaluates its event trigger on a momentum-buffered
    /// drift. `None` for plain-drift triggers, and before the first sync
    /// round (the buffer is allocated lazily).
    fn trigger_momentum(&self, _node: usize) -> Option<&[f32]> {
        None
    }

    /// Restore one node's trigger-momentum buffer (no-op by default).
    fn set_node_trigger_momentum(&mut self, _node: usize, _u: &[f32]) {}

    /// Node i's public estimate x̂_i, if the algorithm keeps an estimate
    /// bank (estimate-tracking rules; `None` for exact averaging).
    fn estimate(&self, _node: usize) -> Option<&[f32]> {
        None
    }

    /// Node i's materialized consensus accumulator Σ_j w_ij x̂_j, if one
    /// exists. Checkpointed alongside the estimate bank: the accumulator
    /// is maintained *incrementally* during a run, so recomputing it from
    /// the bank on restore would re-associate the floating-point sums and
    /// break bit-for-bit resume.
    fn consensus_acc(&self, _node: usize) -> Option<&[f32]> {
        None
    }

    /// Restore the estimate bank and consensus accumulator from a
    /// checkpoint (no-op for algorithms without them). Does NOT charge
    /// the bus — restore reconstructs state whose traffic was already
    /// paid for before the snapshot.
    fn restore_estimates(&mut self, _xhat: &[Vec<f32>], _acc: &[Vec<f32>]) {}

    /// Node i's RNG stream state, if the algorithm owns per-node streams
    /// (required for bit-for-bit checkpoint resume).
    fn rng_state(&self, _node: usize) -> Option<[u64; 4]> {
        None
    }

    /// Restore one node's RNG stream (no-op by default).
    fn set_rng_state(&mut self, _node: usize, _state: [u64; 4]) {}

    /// Restore cumulative trigger statistics (see
    /// [`fired_stats`](Self::fired_stats)).
    fn set_fired_stats(&mut self, _fired: u64, _checks: u64) {}

    /// Prepare the algorithm to resume at iteration `t0`: replay any
    /// time-varying internal schedule (e.g. topology switches) so the
    /// state the checkpoint is about to restore matches the structures in
    /// force at `t0`. Must be called *before*
    /// [`restore_estimates`](Self::restore_estimates). No-op for
    /// algorithms without time-varying structure.
    fn prepare_resume(&mut self, _t0: u64) {}

    /// Set the worker-thread count for the per-node phases (1 ⇒ fully
    /// sequential, 0 ⇒ available CPUs). Results are bit-for-bit identical
    /// for every worker count — parallel phases only touch per-node state
    /// driven by per-node RNG streams. Default: no-op for algorithms
    /// without parallel phases.
    fn set_workers(&mut self, _workers: usize) {}

    /// Install a broadcast transport (`comm::transport`) so sync-round
    /// messages cross a real socket instead of staying in-memory — the
    /// cluster runtime's hook. Default: no-op (dropping the transport is
    /// correct for algorithms without a communication phase; the engine
    /// overrides this).
    fn set_transport(&mut self, _transport: Box<dyn crate::comm::Transport>) {}

    /// Number of nodes.
    fn n(&self) -> usize;

    /// Average iterate x̄ (the quantity the theorems track).
    fn x_bar(&self) -> Vec<f32> {
        let n = self.n();
        let d = self.params(0).len();
        let mut bar = vec![0.0f32; d];
        for i in 0..n {
            for (b, v) in bar.iter_mut().zip(self.params(i).iter()) {
                *b += v;
            }
        }
        for b in bar.iter_mut() {
            *b /= n as f32;
        }
        bar
    }

    /// Consensus distance Σ_i ‖x_i − x̄‖² (Lemma 1's tracked quantity).
    fn consensus_distance(&self) -> f64 {
        let bar = self.x_bar();
        let mut acc = 0.0;
        for i in 0..self.n() {
            acc += crate::linalg::vecops::dist2(self.params(i), &bar);
        }
        acc
    }

    /// Number of nodes whose trigger fired in the last sync round (for
    /// metrics; baselines return n or 0 as appropriate).
    fn last_fired(&self) -> usize {
        0
    }

    /// Cumulative fault bookkeeping (crashes, rejoin resyncs, corrupt
    /// discards), when the algorithm runs under a fault plan
    /// (`comm::fault`). Zero for algorithms without fault support.
    fn fault_counters(&self) -> crate::comm::FaultCounters {
        crate::comm::FaultCounters::default()
    }

    /// Restore cumulative fault counters from a checkpoint (no-op for
    /// algorithms without fault support).
    fn set_fault_counters(&mut self, _counters: crate::comm::FaultCounters) {}

    /// Cumulative (transmitted, opportunities) statistics, when tracked —
    /// `fired / checks` is the transmit rate the robustness sweeps
    /// report. "Opportunities" counts n per sync round; for trigger-free
    /// compositions (CHOCO, exact averaging) the rate is 1.0 minus
    /// straggler skips, not evidence that drift checks ran.
    fn fired_stats(&self) -> (u64, u64) {
        (0, 0)
    }

    /// Algorithm name for logs.
    fn name(&self) -> String;
}

/// Forward every trait method through a level of indirection — including
/// the ones with default bodies, which carry real state on the engine
/// (estimates, RNG streams, trigger stats): a forwarding impl that fell
/// back to the defaults would silently break checkpointing.
macro_rules! forward_decentralized_algo {
    () => {
        fn step(&mut self, t: u64, src: &mut dyn GradientSource, bus: &mut Bus) {
            (**self).step(t, src, bus)
        }
        fn params(&self, node: usize) -> &[f32] {
            (**self).params(node)
        }
        fn set_params(&mut self, x0: &[f32]) {
            (**self).set_params(x0)
        }
        fn set_node_params(&mut self, node: usize, x: &[f32]) {
            (**self).set_node_params(node, x)
        }
        fn momentum(&self, node: usize) -> Option<&[f32]> {
            (**self).momentum(node)
        }
        fn set_node_momentum(&mut self, node: usize, m: &[f32]) {
            (**self).set_node_momentum(node, m)
        }
        fn trigger_momentum(&self, node: usize) -> Option<&[f32]> {
            (**self).trigger_momentum(node)
        }
        fn set_node_trigger_momentum(&mut self, node: usize, u: &[f32]) {
            (**self).set_node_trigger_momentum(node, u)
        }
        fn estimate(&self, node: usize) -> Option<&[f32]> {
            (**self).estimate(node)
        }
        fn consensus_acc(&self, node: usize) -> Option<&[f32]> {
            (**self).consensus_acc(node)
        }
        fn restore_estimates(&mut self, xhat: &[Vec<f32>], acc: &[Vec<f32>]) {
            (**self).restore_estimates(xhat, acc)
        }
        fn rng_state(&self, node: usize) -> Option<[u64; 4]> {
            (**self).rng_state(node)
        }
        fn set_rng_state(&mut self, node: usize, state: [u64; 4]) {
            (**self).set_rng_state(node, state)
        }
        fn set_fired_stats(&mut self, fired: u64, checks: u64) {
            (**self).set_fired_stats(fired, checks)
        }
        fn prepare_resume(&mut self, t0: u64) {
            (**self).prepare_resume(t0)
        }
        fn set_workers(&mut self, workers: usize) {
            (**self).set_workers(workers)
        }
        fn set_transport(&mut self, transport: Box<dyn crate::comm::Transport>) {
            (**self).set_transport(transport)
        }
        fn n(&self) -> usize {
            (**self).n()
        }
        fn x_bar(&self) -> Vec<f32> {
            (**self).x_bar()
        }
        fn consensus_distance(&self) -> f64 {
            (**self).consensus_distance()
        }
        fn last_fired(&self) -> usize {
            (**self).last_fired()
        }
        fn fault_counters(&self) -> crate::comm::FaultCounters {
            (**self).fault_counters()
        }
        fn set_fault_counters(&mut self, counters: crate::comm::FaultCounters) {
            (**self).set_fault_counters(counters)
        }
        fn fired_stats(&self) -> (u64, u64) {
            (**self).fired_stats()
        }
        fn name(&self) -> String {
            (**self).name()
        }
    };
}

/// `&mut dyn DecentralizedAlgo` (and `&mut Engine`) is itself an
/// algorithm — lets the generic [`Run`](crate::run::Run) handle drive
/// borrowed algorithms (the `coordinator::runner::run` compatibility
/// path) as well as owned ones.
impl<T: DecentralizedAlgo + ?Sized> DecentralizedAlgo for &mut T {
    forward_decentralized_algo!();
}

/// `Box<dyn DecentralizedAlgo>` is itself an algorithm (owned form for
/// [`Run`](crate::run::Run)).
impl<T: DecentralizedAlgo + ?Sized> DecentralizedAlgo for Box<T> {
    forward_decentralized_algo!();
}
