//! SPARQ-SGD — Algorithm 1, as a policy composition over the engine.
//!
//! Per iteration t (synchronous, all nodes):
//!
//! 1. line 3–4: stochastic gradient + local step → x_i^{t+½}
//! 2. if (t+1) ∈ I_T (line 5):
//!    a. line 7: trigger check ‖x_i^{t+½} − x̂_i^{(t)}‖² > c_t η_t²
//!    b. line 8–9: fired nodes broadcast q_i = C(x_i^{t+½} − x̂_i^{(t)})
//!       (charged to the bus); silent nodes send nothing (line 11)
//!    c. line 13: every node updates x̂_j ← x̂_j + q_j for all j
//!    d. line 15: consensus x_i ← x_i^{t+½} + γ Σ_j w_ij (x̂_j − x̂_i)
//! 3. else (line 17): x_i ← x_i^{t+½}, estimates unchanged.
//!
//! First-round bootstrap: x̂^{(0)} = 0 and the paper has every node send
//! its (compressed) initial parameters in round one; with the x^{(0)} = 0
//! init used throughout the experiments this is automatic (zero drift ⇒
//! nothing to send). For nonzero init the first sync round's trigger sees
//! the full ‖x^{(½)}‖² drift and fires, which is exactly that bootstrap.
//!
//! In engine terms (see [`engine`](super::engine)), SPARQ is exactly:
//! [`Triggered`] comm policy (sync at I_T, fire on the drift threshold) +
//! [`EstimateTracking`] update rule + the configured [`Compressor`].
//! [`SparqSgd::new`] is a thin constructor assembling that composition —
//! the step loop itself lives in `engine.rs`, shared with CHOCO-SGD and
//! D-PSGD, and the `engine_equivalence` suite pins that it reproduces the
//! seed SPARQ coordinator bit-for-bit.

use super::engine::{DecentralizedEngine, EngineConfig, EstimateTracking, Triggered};
use crate::compress::Compressor;
use crate::graph::MixingMatrix;
use crate::schedule::{LrSchedule, SyncSchedule};
use crate::trigger::EventTrigger;

/// Everything that parameterizes a SPARQ run (Algorithm 1's inputs).
pub struct SparqConfig {
    pub mixing: MixingMatrix,
    pub compressor: Box<dyn Compressor>,
    pub trigger: EventTrigger,
    pub lr: LrSchedule,
    pub sync: SyncSchedule,
    /// Consensus step size γ; `None` ⇒ tuned heuristic
    /// `SpectralInfo::gamma_tuned` (the paper's experiments grid-search γ;
    /// pass `Some(γ*)` for the worst-case Lemma-6 value).
    pub gamma: Option<f64>,
    /// Momentum factor (Section 5.2 uses 0.9; 0 disables).
    pub momentum: f32,
    pub seed: u64,
}

/// Thin constructor: SPARQ-SGD as a [`DecentralizedEngine`] composition.
pub struct SparqSgd;

impl SparqSgd {
    pub fn new(cfg: SparqConfig, d: usize) -> DecentralizedEngine {
        let name = format!(
            "sparq(C={}, trigger={:?}, H={:?})",
            cfg.compressor.name(),
            cfg.trigger.schedule,
            cfg.sync
        );
        let rule = EstimateTracking::new(&cfg.mixing, d);
        DecentralizedEngine::new(
            EngineConfig {
                mixing: cfg.mixing,
                compressor: cfg.compressor,
                comm: Box::new(Triggered {
                    sync: cfg.sync,
                    trigger: cfg.trigger,
                }),
                rule: Box::new(rule),
                gamma: cfg.gamma,
                lr: cfg.lr,
                momentum: cfg.momentum,
                seed: cfg.seed,
                name,
            },
            d,
        )
    }
}
