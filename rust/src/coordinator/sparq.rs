//! SPARQ-SGD — Algorithm 1, verbatim.
//!
//! Per iteration t (synchronous, all nodes):
//!
//! 1. line 3–4: stochastic gradient + local step → x_i^{t+½}
//! 2. if (t+1) ∈ I_T (line 5):
//!    a. line 7: trigger check ‖x_i^{t+½} − x̂_i^{(t)}‖² > c_t η_t²
//!    b. line 8–9: fired nodes broadcast q_i = C(x_i^{t+½} − x̂_i^{(t)})
//!       (charged to the bus); silent nodes send nothing (line 11)
//!    c. line 13: every node updates x̂_j ← x̂_j + q_j for all j
//!    d. line 15: consensus x_i ← x_i^{t+½} + γ Σ_j w_ij (x̂_j − x̂_i)
//! 3. else (line 17): x_i ← x_i^{t+½}, estimates unchanged.
//!
//! First-round bootstrap: x̂^{(0)} = 0 and the paper has every node send
//! its (compressed) initial parameters in round one; with the x^{(0)} = 0
//! init used throughout the experiments this is automatic (zero drift ⇒
//! nothing to send). For nonzero init the first sync round's trigger sees
//! the full ‖x^{(½)}‖² drift and fires, which is exactly that bootstrap.
//!
//! Execution structure (EXPERIMENTS.md §Perf, sparse fast path): messages
//! are built as [`crate::compress::SparseVec`]s and applied in O(nnz);
//! the consensus step reads a materialized neighbor accumulator
//! (consensus.rs) instead of doing per-edge dense passes; and the
//! per-node phases (gradient/local-step, trigger + compress, consensus
//! commit) run on a `util::ThreadPool`. Every parallel phase touches only
//! per-node state driven by per-node RNG streams, and the cross-node
//! apply runs sequentially in node order, so runs are bit-for-bit
//! identical for any worker count.

use super::consensus::NeighborAccumulator;
use super::node::NodeState;
use super::{gradient_phase, DecentralizedAlgo};
use crate::comm::Bus;
use crate::compress::Compressor;
use crate::graph::{MixingMatrix, SpectralInfo};
use crate::linalg::vecops::sub_into;
use crate::problems::GradientSource;
use crate::schedule::{LrSchedule, SyncSchedule};
use crate::trigger::EventTrigger;
use crate::util::threadpool::ThreadPool;
use crate::util::Rng;

/// Everything that parameterizes a SPARQ run (Algorithm 1's inputs).
pub struct SparqConfig {
    pub mixing: MixingMatrix,
    pub compressor: Box<dyn Compressor>,
    pub trigger: EventTrigger,
    pub lr: LrSchedule,
    pub sync: SyncSchedule,
    /// Consensus step size γ; `None` ⇒ tuned heuristic
    /// `SpectralInfo::gamma_tuned` (the paper's experiments grid-search γ;
    /// pass `Some(γ*)` for the worst-case Lemma-6 value).
    pub gamma: Option<f64>,
    /// Momentum factor (Section 5.2 uses 0.9; 0 disables).
    pub momentum: f32,
    pub seed: u64,
}

pub struct SparqSgd {
    pub cfg: SparqConfig,
    pub gamma: f64,
    nodes: Vec<NodeState>,
    /// Public estimates x̂_j (one authoritative copy per node; see node.rs).
    xhat: Vec<Vec<f32>>,
    /// Materialized Σ_j w_ij x̂_j per node, maintained in O(nnz·deg) per
    /// broadcast (the sparse fast path — see consensus.rs).
    nbr: NeighborAccumulator,
    /// Worker pool for the per-node phases (workers = 1 ⇒ sequential;
    /// results are bit-identical for any worker count).
    pool: ThreadPool,
    fired_last: usize,
    /// Cumulative trigger statistics.
    pub total_fired: u64,
    pub total_checks: u64,
}

impl SparqSgd {
    pub fn new(cfg: SparqConfig, d: usize) -> SparqSgd {
        let n = cfg.mixing.n();
        let spectral = SpectralInfo::compute(&cfg.mixing);
        let omega = cfg.compressor.omega(d);
        let omega_eff = cfg.compressor.effective_omega(d);
        let gamma = cfg
            .gamma
            .unwrap_or_else(|| spectral.gamma_tuned(omega, omega_eff));
        let mut root = Rng::new(cfg.seed);
        let nodes = (0..n)
            .map(|i| NodeState::new(d, cfg.momentum > 0.0, root.fork(i as u64)))
            .collect();
        let nbr = NeighborAccumulator::new(&cfg.mixing, d);
        SparqSgd {
            cfg,
            gamma,
            nodes,
            xhat: vec![vec![0.0; d]; n],
            nbr,
            pool: ThreadPool::new(1),
            fired_last: 0,
            total_fired: 0,
            total_checks: 0,
        }
    }

    /// Set all nodes to the same initial parameters.
    pub fn init_params(&mut self, x0: &[f32]) {
        for node in self.nodes.iter_mut() {
            node.x.copy_from_slice(x0);
        }
    }

    /// Spectral info of the configured mixing matrix.
    pub fn spectral(&self) -> SpectralInfo {
        SpectralInfo::compute(&self.cfg.mixing)
    }

    /// The estimate bank (exposed for tests).
    pub fn xhat(&self, i: usize) -> &[f32] {
        &self.xhat[i]
    }
}

impl DecentralizedAlgo for SparqSgd {
    fn step(&mut self, t: u64, src: &mut dyn GradientSource, bus: &mut Bus) {
        let n = self.nodes.len();
        let eta64 = self.cfg.lr.eta(t);
        let eta = eta64 as f32;
        let momentum = self.cfg.momentum;

        // lines 3–4: gradient + local half-step, every node — parallel
        // across nodes when the source supports shared-state evaluation.
        gradient_phase(&self.pool, &mut self.nodes, src, Some((eta, momentum)));

        if self.cfg.sync.is_sync(t) {
            // lines 7–9: trigger check and (if fired) compress, all
            // against the *pre-update* x̂ bank. Each node touches only its
            // own row and scratch, so the phase fans out on the pool.
            let pool = &self.pool;
            let cfg = &self.cfg;
            let xhat = &self.xhat;
            pool.for_each_mut(&mut self.nodes, |i, node| {
                node.fired = cfg.trigger.fires(&node.x_half, &xhat[i], t, eta64);
                if node.fired {
                    // line 8: q_i = C(x_i^{t+½} − x̂_i), straight to sparse.
                    sub_into(&node.x_half, &xhat[i], &mut node.diff);
                    cfg.compressor
                        .compress_sparse(&node.diff, &mut node.rng, &mut node.q);
                }
            });

            // lines 9–13: charge broadcasts and apply estimate updates in
            // deterministic node order. All O(nnz): x̂_i += q_i plus the
            // receivers' neighbor-accumulator moves; silent nodes (line
            // 11) send 0 and cost nothing on the wire.
            let d = self.xhat[0].len();
            self.total_checks += n as u64;
            let mut fired_count = 0usize;
            for i in 0..n {
                if !self.nodes[i].fired {
                    continue;
                }
                fired_count += 1;
                let q = &self.nodes[i].q;
                let bits = self.cfg.compressor.message_bits(d, q.nnz());
                bus.charge_broadcast(i, self.cfg.mixing.topology.degree(i), bits);
                q.add_to(&mut self.xhat[i]);
                self.nbr.apply_broadcast(i, q);
            }
            self.fired_last = fired_count;
            self.total_fired += fired_count as u64;

            // line 15: consensus from the post-update estimates — one
            // fused pass per node from the materialized accumulator (no
            // per-edge full-d read-modify-write), parallel across nodes.
            // Commit by buffer swap — x_half is fully rewritten by the
            // next local_step, so no copy is needed (§Perf, L3 iter 4).
            let gamma = self.gamma as f32;
            let xhat = &self.xhat;
            let nbr = &self.nbr;
            self.pool.for_each_mut(&mut self.nodes, |i, node| {
                std::mem::swap(&mut node.x, &mut node.x_half);
                nbr.commit(i, gamma, &xhat[i], &mut node.x);
            });
        } else {
            // line 17: commit the local step only (buffer swap, no copy).
            for node in self.nodes.iter_mut() {
                std::mem::swap(&mut node.x, &mut node.x_half);
            }
            self.fired_last = 0;
        }
        bus.end_round();
    }

    fn params(&self, node: usize) -> &[f32] {
        &self.nodes[node].x
    }

    fn set_params(&mut self, x0: &[f32]) {
        self.init_params(x0);
    }

    fn set_node_params(&mut self, node: usize, x: &[f32]) {
        self.nodes[node].x.copy_from_slice(x);
    }

    fn momentum(&self, node: usize) -> Option<&[f32]> {
        self.nodes[node].momentum.as_deref()
    }

    fn set_node_momentum(&mut self, node: usize, m: &[f32]) {
        if let Some(buf) = self.nodes[node].momentum.as_mut() {
            buf.copy_from_slice(m);
        }
    }

    fn set_workers(&mut self, workers: usize) {
        self.pool = ThreadPool::new(workers);
    }

    fn n(&self) -> usize {
        self.nodes.len()
    }

    fn last_fired(&self) -> usize {
        self.fired_last
    }

    fn name(&self) -> String {
        format!(
            "sparq(C={}, trigger={:?}, H={:?})",
            self.cfg.compressor.name(),
            self.cfg.trigger.schedule,
            self.cfg.sync
        )
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::compress::{Identity, SignTopK};
    use crate::graph::{uniform_neighbor, Topology, TopologyKind};
    use crate::problems::QuadraticProblem;
    use crate::trigger::ThresholdSchedule;

    fn mk(
        n: usize,
        d: usize,
        comp: Box<dyn Compressor>,
        trig: ThresholdSchedule,
        h: u64,
    ) -> (SparqSgd, QuadraticProblem, Bus) {
        let topo = Topology::new(TopologyKind::Ring, n, 0);
        let mixing = uniform_neighbor(&topo);
        let cfg = SparqConfig {
            mixing,
            compressor: comp,
            trigger: EventTrigger::new(trig),
            lr: LrSchedule::InverseTime { a: 50.0, b: 2.0 },
            sync: SyncSchedule::EveryH(h),
            gamma: None,
            momentum: 0.0,
            seed: 7,
        };
        let algo = SparqSgd::new(cfg, d);
        let prob = QuadraticProblem::new(d, n, 0.5, 2.0, 0.05, 1.0, 3);
        let bus = Bus::new(n);
        (algo, prob, bus)
    }

    #[test]
    fn average_preserved_during_sync_round() {
        // Paper Eq. (20): x̄^{t+1} = x̄^{t+½} — the consensus step never
        // moves the average; only gradients do.
        let (mut algo, mut prob, mut bus) = mk(
            8,
            12,
            Box::new(SignTopK::new(3)),
            ThresholdSchedule::Zero,
            1,
        );
        for t in 0..20 {
            // x̄^{t+1} must equal x̄^{t} − (η_t/n) Σ_i g_i (paper Eq. 20 +
            // Eq. 3); node.grad still holds g_i^{(t)} after the step.
            let bar_before = algo.x_bar();
            algo.step(t, &mut prob, &mut bus);
            let eta = algo.cfg.lr.eta(t) as f32;
            let mut expected = bar_before;
            for i in 0..8 {
                for (e, g) in expected.iter_mut().zip(algo.nodes[i].grad.iter()) {
                    *e -= eta * g / 8.0;
                }
            }
            let bar = algo.x_bar();
            for (a, b) in bar.iter().zip(expected.iter()) {
                assert!((a - b).abs() < 1e-4, "t={t}: {a} vs {b}");
            }
        }
    }

    #[test]
    fn silent_nodes_cost_no_bits() {
        // Impossible threshold ⇒ nobody ever fires ⇒ zero bits on the bus.
        let (mut algo, mut prob, mut bus) = mk(
            6,
            10,
            Box::new(SignTopK::new(2)),
            ThresholdSchedule::Constant(1e12),
            1,
        );
        for t in 0..30 {
            algo.step(t, &mut prob, &mut bus);
        }
        assert_eq!(bus.total_bits, 0);
        assert_eq!(algo.total_fired, 0);
        assert_eq!(algo.total_checks, 30 * 6);
    }

    #[test]
    fn no_sync_rounds_never_communicate() {
        let (mut algo, mut prob, mut bus) =
            mk(4, 8, Box::new(Identity), ThresholdSchedule::Zero, 10);
        for t in 0..9 {
            // t = 0..8: (t+1) ∈ {1..9}, none divisible by 10
            algo.step(t, &mut prob, &mut bus);
            assert_eq!(bus.total_bits, 0, "t={t}");
        }
        algo.step(9, &mut prob, &mut bus); // t+1 = 10 syncs
        assert!(bus.total_bits > 0);
    }

    #[test]
    fn estimates_track_params_with_identity_compression() {
        // With Identity compression and always-firing trigger at H=1,
        // x̂_i = x_i^{t+½} after each sync round (perfect estimates).
        // x^{t+½} is reconstructed as x_prev − η g (plain SGD, no momentum).
        let (mut algo, mut prob, mut bus) =
            mk(4, 8, Box::new(Identity), ThresholdSchedule::Zero, 1);
        for t in 0..10 {
            let prev: Vec<Vec<f32>> = (0..4).map(|i| algo.params(i).to_vec()).collect();
            algo.step(t, &mut prob, &mut bus);
            let eta = algo.cfg.lr.eta(t) as f32;
            for i in 0..4 {
                for ((h, xp), g) in algo
                    .xhat(i)
                    .iter()
                    .zip(prev[i].iter())
                    .zip(algo.nodes[i].grad.iter())
                {
                    let x_half = xp - eta * g;
                    assert!((h - x_half).abs() < 1e-5, "t={t} node {i}");
                }
            }
        }
    }

    #[test]
    fn converges_on_quadratic() {
        let (mut algo, mut prob, mut bus) = mk(
            8,
            16,
            Box::new(SignTopK::new(4)),
            ThresholdSchedule::Poly { c0: 1.0, eps: 0.5 },
            5,
        );
        for t in 0..3000 {
            algo.step(t, &mut prob, &mut bus);
        }
        let gap = prob.suboptimality(&algo.x_bar());
        assert!(gap < 0.05, "suboptimality {gap}");
        // consensus drift is bounded and decaying (Lemma 1: ∝ η_t²/p²; at
        // t=3000 it is well below its early-training peak)
        assert!(
            algo.consensus_distance() < 10.0,
            "consensus {}",
            algo.consensus_distance()
        );
        // and the trigger actually saved some broadcasts
        assert!(algo.total_fired < algo.total_checks);
    }

    #[test]
    fn deterministic_replay() {
        let run = || {
            let (mut algo, mut prob, mut bus) = mk(
                5,
                10,
                Box::new(SignTopK::new(3)),
                ThresholdSchedule::Constant(10.0),
                5,
            );
            for t in 0..200 {
                algo.step(t, &mut prob, &mut bus);
            }
            (algo.x_bar(), bus.total_bits)
        };
        let (x1, b1) = run();
        let (x2, b2) = run();
        assert_eq!(x1, x2);
        assert_eq!(b1, b2);
    }
}
