//! Synchronization-index sets I_T (Section 2).
//!
//! Workers check the trigger / take a consensus step only at indices in
//! I_T; gap(I_T) = max consecutive difference ≤ H is the paper's "number
//! of local iterations" knob. `EveryH` is the experiments' setting (H=5);
//! `Explicit` supports arbitrary (e.g. randomized) index sets for
//! ablations, as long as the caller respects gap ≤ H.

#[derive(Clone, Debug, PartialEq)]
pub enum SyncSchedule {
    /// (t+1) ∈ I_T iff (t+1) % h == 0.
    EveryH(u64),
    /// Explicit sorted list of indices.
    Explicit(Vec<u64>),
}

impl SyncSchedule {
    /// Parse a sync spec: `every:H` (H ≥ 1) or `explicit:3,5,10` (a
    /// strictly increasing list of positive indices). Errors name the
    /// offending field so config typos surface instead of silently
    /// degrading to a default cadence.
    pub fn parse(s: &str) -> Result<SyncSchedule, String> {
        match s.split_once(':') {
            Some(("every", h)) => {
                let h: u64 = h
                    .parse()
                    .map_err(|_| format!("sync period {h:?} is not an integer"))?;
                if h == 0 {
                    return Err("sync period H must be >= 1 (H = 1 syncs every round)".into());
                }
                Ok(SyncSchedule::EveryH(h))
            }
            Some(("explicit", list)) => {
                let mut v = Vec::new();
                for part in list.split(',') {
                    let i: u64 = part
                        .parse()
                        .map_err(|_| format!("sync index {part:?} is not an integer"))?;
                    if i == 0 {
                        return Err(
                            "sync indices are 1-based ((t+1) ∈ I_T); 0 is not an index".into()
                        );
                    }
                    if let Some(&last) = v.last() {
                        if i <= last {
                            return Err(format!(
                                "sync indices must be strictly increasing, got {i} after {last}"
                            ));
                        }
                    }
                    v.push(i);
                }
                if v.is_empty() {
                    return Err("explicit sync schedule needs at least one index".into());
                }
                Ok(SyncSchedule::Explicit(v))
            }
            _ => Err(format!(
                "unknown sync spec {s:?}; expected every:H or explicit:I1,I2,..."
            )),
        }
    }

    /// Does iteration t synchronize? Matches Algorithm 1's "(t+1) ∈ I_T"
    /// convention: pass t and it tests membership of t+1.
    pub fn is_sync(&self, t: u64) -> bool {
        match self {
            SyncSchedule::EveryH(h) => (t + 1) % h.max(&1) == 0,
            SyncSchedule::Explicit(v) => v.binary_search(&(t + 1)).is_ok(),
        }
    }

    /// gap(I_T) over the horizon [0, t_max] (Section 2 definition, with
    /// the leading gap from 0 to the first index included).
    pub fn gap(&self, t_max: u64) -> u64 {
        match self {
            SyncSchedule::EveryH(h) => *h,
            SyncSchedule::Explicit(v) => {
                let mut prev = 0u64;
                let mut g = 0u64;
                for &i in v.iter().filter(|&&i| i <= t_max) {
                    g = g.max(i - prev);
                    prev = i;
                }
                g
            }
        }
    }

    /// Last synchronization index ≤ t (I_(t₀) in the proofs).
    pub fn last_sync_before(&self, t: u64) -> u64 {
        match self {
            SyncSchedule::EveryH(h) => {
                let h = (*h).max(1);
                (t / h) * h
            }
            SyncSchedule::Explicit(v) => {
                match v.binary_search(&t) {
                    Ok(i) => v[i],
                    Err(0) => 0,
                    Err(i) => v[i - 1],
                }
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn every_h_membership() {
        let s = SyncSchedule::EveryH(5);
        // t such that (t+1) % 5 == 0: t = 4, 9, 14, ...
        assert!(!s.is_sync(0));
        assert!(s.is_sync(4));
        assert!(!s.is_sync(5));
        assert!(s.is_sync(9));
        assert_eq!(s.gap(100), 5);
    }

    #[test]
    fn h1_syncs_every_step() {
        // H = 1 degenerates to every-round synchronization: every t is a
        // sync index, the gap is exactly 1, and every iteration is its own
        // last-sync point.
        let s = SyncSchedule::EveryH(1);
        assert!((0..20).all(|t| s.is_sync(t)));
        assert_eq!(s.gap(1000), 1);
        for t in 0..20 {
            assert_eq!(s.last_sync_before(t), t);
        }
    }

    #[test]
    fn parse_specs_and_errors() {
        assert_eq!(SyncSchedule::parse("every:5"), Ok(SyncSchedule::EveryH(5)));
        assert_eq!(SyncSchedule::parse("every:1"), Ok(SyncSchedule::EveryH(1)));
        assert_eq!(
            SyncSchedule::parse("explicit:3,5,10"),
            Ok(SyncSchedule::Explicit(vec![3, 5, 10]))
        );
        let err = SyncSchedule::parse("every:0").unwrap_err();
        assert!(err.contains(">= 1"), "{err}");
        let err = SyncSchedule::parse("every:soon").unwrap_err();
        assert!(err.contains("soon"), "{err}");
        let err = SyncSchedule::parse("explicit:5,3").unwrap_err();
        assert!(err.contains("strictly increasing"), "{err}");
        let err = SyncSchedule::parse("explicit:3,3").unwrap_err();
        assert!(err.contains("strictly increasing"), "{err}");
        let err = SyncSchedule::parse("explicit:0,3").unwrap_err();
        assert!(err.contains("1-based"), "{err}");
        let err = SyncSchedule::parse("sometimes").unwrap_err();
        assert!(err.contains("expected"), "{err}");
        // parse round-trips through the membership predicate
        let s = SyncSchedule::parse("explicit:2,4,9").unwrap();
        assert!(s.is_sync(1) && s.is_sync(3) && s.is_sync(8));
        assert!(!s.is_sync(2) && !s.is_sync(4));
    }

    #[test]
    fn every_h_boundary_iterations() {
        // The boundary convention is (t+1) % H == 0: the *last* iteration
        // of each block syncs, never the first.
        for h in [2u64, 3, 7, 10] {
            let s = SyncSchedule::EveryH(h);
            assert!(!s.is_sync(0), "H={h}");
            assert!(s.is_sync(h - 1), "H={h}");
            assert!(!s.is_sync(h), "H={h}");
            assert!(s.is_sync(2 * h - 1), "H={h}");
            // exactly one sync index in every window of H iterations
            for start in 0..3 * h {
                let count = (start..start + h).filter(|&t| s.is_sync(t)).count();
                assert_eq!(count, 1, "H={h} window at {start}");
            }
        }
    }

    #[test]
    fn explicit_membership_and_gap() {
        let s = SyncSchedule::Explicit(vec![3, 5, 10, 18]);
        assert!(s.is_sync(2)); // t+1 = 3
        assert!(!s.is_sync(3));
        assert!(s.is_sync(9));
        assert_eq!(s.gap(20), 8); // 18 - 10
        assert_eq!(s.gap(9), 3); // indices ≤ 9 are {3, 5}; gaps 3, 2
    }

    #[test]
    fn last_sync() {
        let s = SyncSchedule::EveryH(5);
        assert_eq!(s.last_sync_before(12), 10);
        assert_eq!(s.last_sync_before(4), 0);
        let e = SyncSchedule::Explicit(vec![3, 5, 10]);
        assert_eq!(e.last_sync_before(7), 5);
        assert_eq!(e.last_sync_before(2), 0);
        assert_eq!(e.last_sync_before(10), 10);
    }
}
