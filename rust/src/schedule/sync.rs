//! Synchronization-index sets I_T (Section 2).
//!
//! Workers check the trigger / take a consensus step only at indices in
//! I_T; gap(I_T) = max consecutive difference ≤ H is the paper's "number
//! of local iterations" knob. `EveryH` is the experiments' setting (H=5);
//! `Explicit` supports arbitrary (e.g. randomized) index sets for
//! ablations, as long as the caller respects gap ≤ H.

#[derive(Clone, Debug, PartialEq)]
pub enum SyncSchedule {
    /// (t+1) ∈ I_T iff (t+1) % h == 0.
    EveryH(u64),
    /// Explicit sorted list of indices.
    Explicit(Vec<u64>),
}

impl SyncSchedule {
    /// Does iteration t synchronize? Matches Algorithm 1's "(t+1) ∈ I_T"
    /// convention: pass t and it tests membership of t+1.
    pub fn is_sync(&self, t: u64) -> bool {
        match self {
            SyncSchedule::EveryH(h) => (t + 1) % h.max(&1) == 0,
            SyncSchedule::Explicit(v) => v.binary_search(&(t + 1)).is_ok(),
        }
    }

    /// gap(I_T) over the horizon [0, t_max] (Section 2 definition, with
    /// the leading gap from 0 to the first index included).
    pub fn gap(&self, t_max: u64) -> u64 {
        match self {
            SyncSchedule::EveryH(h) => *h,
            SyncSchedule::Explicit(v) => {
                let mut prev = 0u64;
                let mut g = 0u64;
                for &i in v.iter().filter(|&&i| i <= t_max) {
                    g = g.max(i - prev);
                    prev = i;
                }
                g
            }
        }
    }

    /// Last synchronization index ≤ t (I_(t₀) in the proofs).
    pub fn last_sync_before(&self, t: u64) -> u64 {
        match self {
            SyncSchedule::EveryH(h) => {
                let h = (*h).max(1);
                (t / h) * h
            }
            SyncSchedule::Explicit(v) => {
                match v.binary_search(&t) {
                    Ok(i) => v[i],
                    Err(0) => 0,
                    Err(i) => v[i - 1],
                }
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn every_h_membership() {
        let s = SyncSchedule::EveryH(5);
        // t such that (t+1) % 5 == 0: t = 4, 9, 14, ...
        assert!(!s.is_sync(0));
        assert!(s.is_sync(4));
        assert!(!s.is_sync(5));
        assert!(s.is_sync(9));
        assert_eq!(s.gap(100), 5);
    }

    #[test]
    fn h1_syncs_every_step() {
        let s = SyncSchedule::EveryH(1);
        assert!((0..20).all(|t| s.is_sync(t)));
    }

    #[test]
    fn explicit_membership_and_gap() {
        let s = SyncSchedule::Explicit(vec![3, 5, 10, 18]);
        assert!(s.is_sync(2)); // t+1 = 3
        assert!(!s.is_sync(3));
        assert!(s.is_sync(9));
        assert_eq!(s.gap(20), 8); // 18 - 10
        assert_eq!(s.gap(9), 3); // indices ≤ 9 are {3, 5}; gaps 3, 2
    }

    #[test]
    fn last_sync() {
        let s = SyncSchedule::EveryH(5);
        assert_eq!(s.last_sync_before(12), 10);
        assert_eq!(s.last_sync_before(4), 0);
        let e = SyncSchedule::Explicit(vec![3, 5, 10]);
        assert_eq!(e.last_sync_before(7), 5);
        assert_eq!(e.last_sync_before(2), 0);
        assert_eq!(e.last_sync_before(10), 10);
    }
}
