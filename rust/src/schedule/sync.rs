//! Synchronization-index sets I_T (Section 2).
//!
//! Workers check the trigger / take a consensus step only at indices in
//! I_T; gap(I_T) = max consecutive difference ≤ H is the paper's "number
//! of local iterations" knob. `EveryH` is the experiments' setting (H=5);
//! `Explicit` supports arbitrary (e.g. randomized) index sets for
//! ablations, as long as the caller respects gap ≤ H. The
//! `random:H:STEPS:SEED` spec form materializes exactly that ablation:
//! a seeded index set with i.i.d. gaps drawn uniformly from {1, …, H}
//! (so gap(I_T) ≤ H by construction), expanded deterministically at
//! parse time into an `Explicit` schedule.

#[derive(Clone, Debug, PartialEq)]
pub enum SyncSchedule {
    /// (t+1) ∈ I_T iff (t+1) % h == 0.
    EveryH(u64),
    /// Explicit sorted list of indices.
    Explicit(Vec<u64>),
}

/// splitmix64 — the standard 64-bit finalizer-based generator. Local
/// copy so the randomized-I_T expansion is a pure function of its spec
/// string, independent of any engine RNG stream.
fn splitmix64(state: &mut u64) -> u64 {
    *state = state.wrapping_add(0x9e3779b97f4a7c15);
    let mut z = *state;
    z = (z ^ (z >> 30)).wrapping_mul(0xbf58476d1ce4e5b9);
    z = (z ^ (z >> 27)).wrapping_mul(0x94d049bb133111eb);
    z ^ (z >> 31)
}

impl SyncSchedule {
    /// Parse a sync spec: `every:H` (H ≥ 1), `explicit:3,5,10` (a
    /// strictly increasing list of positive indices), or
    /// `random:H:STEPS:SEED` — the Section 2 randomized-I_T ablation,
    /// expanded deterministically into an `Explicit` set whose
    /// consecutive gaps are i.i.d. uniform over {1, …, H} (so
    /// gap(I_T) ≤ H holds by construction) covering iterations
    /// 1..=STEPS. Errors name the offending field so config typos
    /// surface instead of silently degrading to a default cadence.
    pub fn parse(s: &str) -> Result<SyncSchedule, String> {
        if let Some(rest) = s.strip_prefix("random:") {
            let parts: Vec<&str> = rest.split(':').collect();
            let [h, steps, seed] = parts.as_slice() else {
                return Err(format!(
                    "random sync spec {s:?} must have the form random:H:STEPS:SEED"
                ));
            };
            let h: u64 = h
                .parse()
                .map_err(|_| format!("random sync gap bound {h:?} is not an integer"))?;
            if h == 0 {
                return Err("random sync gap bound H must be >= 1".into());
            }
            let steps: u64 = steps
                .parse()
                .map_err(|_| format!("random sync horizon {steps:?} is not an integer"))?;
            if steps == 0 {
                return Err("random sync horizon STEPS must be >= 1".into());
            }
            let seed: u64 = seed
                .parse()
                .map_err(|_| format!("random sync seed {seed:?} is not an integer"))?;
            let mut state = seed;
            let mut v = Vec::new();
            let mut next = 0u64;
            loop {
                next += 1 + splitmix64(&mut state) % h;
                if next > steps {
                    break;
                }
                v.push(next);
            }
            if v.is_empty() {
                // first draw already overshot the horizon; keep the set
                // non-empty so is_sync/gap stay well-defined — a single
                // index at the horizon preserves gap ≤ H only if
                // STEPS ≤ H, which is exactly the case here.
                v.push(steps);
            }
            return Ok(SyncSchedule::Explicit(v));
        }
        match s.split_once(':') {
            Some(("every", h)) => {
                let h: u64 = h
                    .parse()
                    .map_err(|_| format!("sync period {h:?} is not an integer"))?;
                if h == 0 {
                    return Err("sync period H must be >= 1 (H = 1 syncs every round)".into());
                }
                Ok(SyncSchedule::EveryH(h))
            }
            Some(("explicit", list)) => {
                let mut v = Vec::new();
                for part in list.split(',') {
                    let i: u64 = part
                        .parse()
                        .map_err(|_| format!("sync index {part:?} is not an integer"))?;
                    if i == 0 {
                        return Err(
                            "sync indices are 1-based ((t+1) ∈ I_T); 0 is not an index".into()
                        );
                    }
                    if let Some(&last) = v.last() {
                        if i <= last {
                            return Err(format!(
                                "sync indices must be strictly increasing, got {i} after {last}"
                            ));
                        }
                    }
                    v.push(i);
                }
                if v.is_empty() {
                    return Err("explicit sync schedule needs at least one index".into());
                }
                Ok(SyncSchedule::Explicit(v))
            }
            _ => Err(format!(
                "unknown sync spec {s:?}; expected every:H or explicit:I1,I2,..."
            )),
        }
    }

    /// Does iteration t synchronize? Matches Algorithm 1's "(t+1) ∈ I_T"
    /// convention: pass t and it tests membership of t+1.
    pub fn is_sync(&self, t: u64) -> bool {
        match self {
            SyncSchedule::EveryH(h) => (t + 1) % h.max(&1) == 0,
            SyncSchedule::Explicit(v) => v.binary_search(&(t + 1)).is_ok(),
        }
    }

    /// gap(I_T) over the horizon [0, t_max] (Section 2 definition, with
    /// the leading gap from 0 to the first index included).
    pub fn gap(&self, t_max: u64) -> u64 {
        match self {
            SyncSchedule::EveryH(h) => *h,
            SyncSchedule::Explicit(v) => {
                let mut prev = 0u64;
                let mut g = 0u64;
                for &i in v.iter().filter(|&&i| i <= t_max) {
                    g = g.max(i - prev);
                    prev = i;
                }
                g
            }
        }
    }

    /// Last synchronization index ≤ t (I_(t₀) in the proofs).
    pub fn last_sync_before(&self, t: u64) -> u64 {
        match self {
            SyncSchedule::EveryH(h) => {
                let h = (*h).max(1);
                (t / h) * h
            }
            SyncSchedule::Explicit(v) => {
                match v.binary_search(&t) {
                    Ok(i) => v[i],
                    Err(0) => 0,
                    Err(i) => v[i - 1],
                }
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn every_h_membership() {
        let s = SyncSchedule::EveryH(5);
        // t such that (t+1) % 5 == 0: t = 4, 9, 14, ...
        assert!(!s.is_sync(0));
        assert!(s.is_sync(4));
        assert!(!s.is_sync(5));
        assert!(s.is_sync(9));
        assert_eq!(s.gap(100), 5);
    }

    #[test]
    fn h1_syncs_every_step() {
        // H = 1 degenerates to every-round synchronization: every t is a
        // sync index, the gap is exactly 1, and every iteration is its own
        // last-sync point.
        let s = SyncSchedule::EveryH(1);
        assert!((0..20).all(|t| s.is_sync(t)));
        assert_eq!(s.gap(1000), 1);
        for t in 0..20 {
            assert_eq!(s.last_sync_before(t), t);
        }
    }

    #[test]
    fn parse_specs_and_errors() {
        assert_eq!(SyncSchedule::parse("every:5"), Ok(SyncSchedule::EveryH(5)));
        assert_eq!(SyncSchedule::parse("every:1"), Ok(SyncSchedule::EveryH(1)));
        assert_eq!(
            SyncSchedule::parse("explicit:3,5,10"),
            Ok(SyncSchedule::Explicit(vec![3, 5, 10]))
        );
        let err = SyncSchedule::parse("every:0").unwrap_err();
        assert!(err.contains(">= 1"), "{err}");
        let err = SyncSchedule::parse("every:soon").unwrap_err();
        assert!(err.contains("soon"), "{err}");
        let err = SyncSchedule::parse("explicit:5,3").unwrap_err();
        assert!(err.contains("strictly increasing"), "{err}");
        let err = SyncSchedule::parse("explicit:3,3").unwrap_err();
        assert!(err.contains("strictly increasing"), "{err}");
        let err = SyncSchedule::parse("explicit:0,3").unwrap_err();
        assert!(err.contains("1-based"), "{err}");
        let err = SyncSchedule::parse("sometimes").unwrap_err();
        assert!(err.contains("expected"), "{err}");
        // parse round-trips through the membership predicate
        let s = SyncSchedule::parse("explicit:2,4,9").unwrap();
        assert!(s.is_sync(1) && s.is_sync(3) && s.is_sync(8));
        assert!(!s.is_sync(2) && !s.is_sync(4));
    }

    #[test]
    fn random_spec_expands_deterministically_with_bounded_gaps() {
        // Same spec string ⇒ same index set, every time.
        let a = SyncSchedule::parse("random:5:200:42").unwrap();
        let b = SyncSchedule::parse("random:5:200:42").unwrap();
        assert_eq!(a, b);
        // A different seed gives a different set (with overwhelming
        // probability for a 200-step horizon; pinned for these seeds).
        let c = SyncSchedule::parse("random:5:200:43").unwrap();
        assert_ne!(a, c);
        let SyncSchedule::Explicit(v) = &a else {
            panic!("random must expand to Explicit, got {a:?}")
        };
        // Strictly increasing, 1-based, within the horizon, gap ≤ H
        // including the leading gap from 0 (Section 2's gap(I_T) ≤ H).
        let mut prev = 0u64;
        for &i in v {
            assert!(i >= 1 && i <= 200, "index {i} out of horizon");
            assert!(i > prev, "not strictly increasing at {i}");
            assert!(i - prev <= 5, "gap {} > H at {i}", i - prev);
            prev = i;
        }
        assert!(a.gap(200) <= 5, "gap(I_T) must be ≤ H, got {}", a.gap(200));
        // H = 1 degenerates to every index (gaps are all exactly 1).
        let dense = SyncSchedule::parse("random:1:20:7").unwrap();
        assert_eq!(
            dense,
            SyncSchedule::Explicit((1..=20).collect::<Vec<u64>>())
        );
    }

    #[test]
    fn random_spec_errors_and_edge_cases() {
        let err = SyncSchedule::parse("random:0:100:1").unwrap_err();
        assert!(err.contains(">= 1"), "{err}");
        let err = SyncSchedule::parse("random:5:0:1").unwrap_err();
        assert!(err.contains("STEPS"), "{err}");
        let err = SyncSchedule::parse("random:5:100").unwrap_err();
        assert!(err.contains("random:H:STEPS:SEED"), "{err}");
        let err = SyncSchedule::parse("random:soon:100:1").unwrap_err();
        assert!(err.contains("soon"), "{err}");
        // Horizon shorter than the first drawn gap still yields a
        // non-empty schedule with its single index at the horizon.
        for seed in 0..8u64 {
            let s = SyncSchedule::parse(&format!("random:100:3:{seed}")).unwrap();
            let SyncSchedule::Explicit(v) = &s else { panic!() };
            assert!(!v.is_empty());
            assert!(v.iter().all(|&i| (1..=3).contains(&i)));
        }
    }

    #[test]
    fn every_h_boundary_iterations() {
        // The boundary convention is (t+1) % H == 0: the *last* iteration
        // of each block syncs, never the first.
        for h in [2u64, 3, 7, 10] {
            let s = SyncSchedule::EveryH(h);
            assert!(!s.is_sync(0), "H={h}");
            assert!(s.is_sync(h - 1), "H={h}");
            assert!(!s.is_sync(h), "H={h}");
            assert!(s.is_sync(2 * h - 1), "H={h}");
            // exactly one sync index in every window of H iterations
            for start in 0..3 * h {
                let count = (start..start + h).filter(|&t| s.is_sync(t)).count();
                assert_eq!(count, 1, "H={h} window at {start}");
            }
        }
    }

    #[test]
    fn explicit_membership_and_gap() {
        let s = SyncSchedule::Explicit(vec![3, 5, 10, 18]);
        assert!(s.is_sync(2)); // t+1 = 3
        assert!(!s.is_sync(3));
        assert!(s.is_sync(9));
        assert_eq!(s.gap(20), 8); // 18 - 10
        assert_eq!(s.gap(9), 3); // indices ≤ 9 are {3, 5}; gaps 3, 2
    }

    #[test]
    fn last_sync() {
        let s = SyncSchedule::EveryH(5);
        assert_eq!(s.last_sync_before(12), 10);
        assert_eq!(s.last_sync_before(4), 0);
        let e = SyncSchedule::Explicit(vec![3, 5, 10]);
        assert_eq!(e.last_sync_before(7), 5);
        assert_eq!(e.last_sync_before(2), 0);
        assert_eq!(e.last_sync_before(10), 10);
    }
}
