//! Learning-rate schedules η_t.
//!
//! * `Constant` — Theorem 2 (η = √(n/T)).
//! * `InverseTime` — η_t = b/(a+t): Theorem 1 uses b = 8/μ and
//!   a ≥ max{5H/p, 32L/μ}; Section 5.1 uses η_t = 1/(t+100).
//! * `WarmupPiecewise` — Section 5.2: linear warmup for `warmup_epochs`,
//!   then divide by `decay_factor` at each milestone epoch.

#[derive(Clone, Debug, PartialEq)]
pub enum LrSchedule {
    Constant(f64),
    /// b / (a + t)
    InverseTime { a: f64, b: f64 },
    /// Section 5.2 schedule, in units of epochs.
    WarmupPiecewise {
        base: f64,
        warmup_epochs: usize,
        milestones: Vec<usize>,
        decay_factor: f64,
        steps_per_epoch: usize,
    },
}

impl LrSchedule {
    pub fn eta(&self, t: u64) -> f64 {
        match self {
            LrSchedule::Constant(e) => *e,
            LrSchedule::InverseTime { a, b } => b / (a + t as f64),
            LrSchedule::WarmupPiecewise {
                base,
                warmup_epochs,
                milestones,
                decay_factor,
                steps_per_epoch,
            } => {
                let spe = (*steps_per_epoch).max(1);
                let warm_steps = warmup_epochs * spe;
                if (t as usize) < warm_steps && warm_steps > 0 {
                    // linear warmup from base/warm_steps to base
                    return base * (t as f64 + 1.0) / warm_steps as f64;
                }
                let epoch = t as usize / spe;
                let decays = milestones.iter().filter(|&&m| epoch >= m).count();
                base / decay_factor.powi(decays as i32)
            }
        }
    }

    /// Theorem 1's inverse-time schedule: η_t = 8/(μ(a+t)) with
    /// a = max{5H/p, 32L/μ}.
    pub fn theorem1(mu: f64, l_smooth: f64, h: usize, p: f64) -> LrSchedule {
        let a = (5.0 * h as f64 / p).max(32.0 * l_smooth / mu);
        LrSchedule::InverseTime { a, b: 8.0 / mu }
    }

    /// Theorem 2's constant rate η = √(n/T).
    pub fn theorem2(n: usize, t_total: u64) -> LrSchedule {
        LrSchedule::Constant((n as f64 / t_total as f64).sqrt())
    }

    /// Theorem 3's decaying non-convex schedule η_t = b/(a+t) with
    /// a ≥ 8bL (the appendix B.5 variant, O(1/log T) guarantee).
    pub fn theorem3(b: f64, l_smooth: f64) -> LrSchedule {
        LrSchedule::InverseTime {
            a: 8.0 * b * l_smooth,
            b,
        }
    }

    /// Parse "const:E", "invtime:A:B", "warmup:BASE:WEP:FACTOR:SPE:M1,M2,..".
    pub fn parse(s: &str) -> Option<LrSchedule> {
        Self::parse_checked(s).ok()
    }

    /// [`parse`](Self::parse) with field-naming errors (what the typed
    /// [`LrSpec`](crate::config::LrSpec) surfaces): every numeric field
    /// must be finite, the decay factor must be positive, and the warmup
    /// epoch arithmetic must be well-defined (`SPE >= 1`).
    pub fn parse_checked(s: &str) -> Result<LrSchedule, String> {
        let num = |field: &str, v: &str| -> Result<f64, String> {
            let x: f64 = v
                .parse()
                .map_err(|_| format!("lr {field} {v:?} is not a number"))?;
            if !x.is_finite() {
                return Err(format!("lr {field} must be finite, got {x}"));
            }
            Ok(x)
        };
        let int = |field: &str, v: &str| -> Result<usize, String> {
            v.parse::<usize>()
                .map_err(|_| format!("lr {field} {v:?} is not a non-negative integer"))
        };
        let p: Vec<&str> = s.split(':').collect();
        match p.as_slice() {
            ["const", e] => Ok(LrSchedule::Constant(num("eta", e)?)),
            ["invtime", a, b] => Ok(LrSchedule::InverseTime {
                a: num("a", a)?,
                b: num("b", b)?,
            }),
            ["warmup", base, wep, factor, spe, ms] => {
                let decay_factor = num("decay_factor", factor)?;
                if decay_factor <= 0.0 {
                    return Err(format!(
                        "lr decay_factor must be positive, got {decay_factor}"
                    ));
                }
                let steps_per_epoch = int("steps_per_epoch", spe)?;
                if steps_per_epoch == 0 {
                    return Err("lr steps_per_epoch must be >= 1".into());
                }
                Ok(LrSchedule::WarmupPiecewise {
                    base: num("base", base)?,
                    warmup_epochs: int("warmup_epochs", wep)?,
                    decay_factor,
                    steps_per_epoch,
                    milestones: ms
                        .split(',')
                        .map(|m| int("milestone", m))
                        .collect::<Result<Vec<_>, _>>()?,
                })
            }
            _ => Err(format!(
                "unknown lr spec {s:?}; expected const:E, invtime:A:B, or \
                 warmup:BASE:WEP:FACTOR:SPE:M1,M2,..."
            )),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn inverse_time_values() {
        let s = LrSchedule::InverseTime { a: 100.0, b: 1.0 };
        assert!((s.eta(0) - 0.01).abs() < 1e-12);
        assert!((s.eta(100) - 0.005).abs() < 1e-12);
    }

    #[test]
    fn theorem1_constraints() {
        // a must dominate both 5H/p and 32L/μ; check η_0 ≤ 1/(4L)
        // (the condition used in the Theorem 1 proof).
        let (mu, l, h, p) = (0.5, 2.0, 5, 0.01);
        let s = LrSchedule::theorem1(mu, l, h, p);
        if let LrSchedule::InverseTime { a, b } = &s {
            assert!(*a >= 5.0 * h as f64 / p - 1e-9);
            assert!(*a >= 32.0 * l / mu - 1e-9);
            assert!((b - 16.0).abs() < 1e-12);
        } else {
            panic!()
        }
        assert!(s.eta(0) <= 1.0 / (4.0 * l) + 1e-12);
    }

    #[test]
    fn theorem2_eta() {
        let s = LrSchedule::theorem2(8, 512);
        assert!((s.eta(0) - 0.125).abs() < 1e-12);
        assert_eq!(s.eta(0), s.eta(100));
    }

    #[test]
    fn theorem3_satisfies_eta_bound() {
        // a >= 8bL ⇒ η_t <= 1/(8L) for all t (the bound the proof needs).
        let l = 2.0;
        let s = LrSchedule::theorem3(1.5, l);
        for t in [0u64, 10, 1000] {
            assert!(s.eta(t) <= 1.0 / (8.0 * l) + 1e-12, "t={t}");
        }
    }

    #[test]
    fn warmup_then_decay() {
        let s = LrSchedule::WarmupPiecewise {
            base: 0.1,
            warmup_epochs: 5,
            milestones: vec![150, 250],
            decay_factor: 5.0,
            steps_per_epoch: 10,
        };
        assert!(s.eta(0) < 0.1 / 10.0); // early warmup tiny
        assert!((s.eta(49) - 0.1).abs() < 1e-9); // end of warmup
        assert!((s.eta(1000) - 0.1).abs() < 1e-12); // epoch 100
        assert!((s.eta(1500) - 0.02).abs() < 1e-12); // epoch 150
        assert!((s.eta(2500) - 0.004).abs() < 1e-12); // epoch 250
    }

    #[test]
    fn parse_specs() {
        assert_eq!(
            LrSchedule::parse("const:0.05"),
            Some(LrSchedule::Constant(0.05))
        );
        assert_eq!(
            LrSchedule::parse("invtime:100:1"),
            Some(LrSchedule::InverseTime { a: 100.0, b: 1.0 })
        );
        let w = LrSchedule::parse("warmup:0.1:5:5:10:150,250").unwrap();
        if let LrSchedule::WarmupPiecewise { milestones, .. } = w {
            assert_eq!(milestones, vec![150, 250]);
        } else {
            panic!()
        }
    }

    #[test]
    fn parse_checked_rejects_decay_factor_boundaries() {
        // Pin the full rejection surface for decay_factor: any value that
        // would drive `base / decay_factor.powi(decays)` to ±inf/NaN
        // mid-run must fail at parse time with a field-naming error.
        for bad in ["0", "-5", "-0.5", "inf", "-inf", "nan", "NaN"] {
            let spec = format!("warmup:0.1:5:{bad}:10:150,250");
            let err = LrSchedule::parse_checked(&spec).unwrap_err();
            assert!(
                err.contains("decay_factor"),
                "spec {spec:?} error must name the field: {err}"
            );
        }
        // Boundary: any strictly positive finite factor is accepted,
        // including < 1 (an *increasing* schedule — unusual but finite).
        assert!(LrSchedule::parse_checked("warmup:0.1:5:0.5:10:150").is_ok());
        assert!(LrSchedule::parse_checked("warmup:0.1:5:1e-300:10:150").is_ok());
        // The other numeric fields share the finiteness gate.
        for spec in [
            "warmup:inf:5:5:10:150",
            "warmup:nan:5:5:10:150",
            "const:inf",
            "const:nan",
            "invtime:inf:1",
            "invtime:100:nan",
        ] {
            let err = LrSchedule::parse_checked(spec).unwrap_err();
            assert!(err.contains("finite") || err.contains("not a number"), "{spec}: {err}");
        }
    }

    #[test]
    fn parse_checked_names_the_offending_field() {
        let err = LrSchedule::parse_checked("const:fast").unwrap_err();
        assert!(err.contains("eta") && err.contains("fast"), "{err}");
        let err = LrSchedule::parse_checked("invtime:100:inf").unwrap_err();
        assert!(err.contains('b') && err.contains("finite"), "{err}");
        let err = LrSchedule::parse_checked("warmup:0.1:5:0:10:150").unwrap_err();
        assert!(err.contains("decay_factor"), "{err}");
        let err = LrSchedule::parse_checked("warmup:0.1:5:5:0:150").unwrap_err();
        assert!(err.contains("steps_per_epoch"), "{err}");
        let err = LrSchedule::parse_checked("linear:0.1").unwrap_err();
        assert!(err.contains("expected"), "{err}");
        // Option-facade agrees with the checked parser
        assert!(LrSchedule::parse("const:fast").is_none());
        assert!(LrSchedule::parse("const:0.05").is_some());
    }
}
