//! Learning-rate schedules and synchronization-index sets I_T.

pub mod lr;
pub mod sync;

pub use lr::LrSchedule;
pub use sync::SyncSchedule;
