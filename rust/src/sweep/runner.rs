//! Concurrent sweep execution: run-level parallelism over the engine's
//! per-node parallelism, JSONL result streaming, resume, and adaptive
//! early-stop budgets.
//!
//! Execution contract (pinned by `rust/tests/sweep_system.rs` and
//! `rust/tests/sweep_distributed.rs`):
//!
//! * **Determinism.** Per-run results are bit-for-bit identical for any
//!   worker budget: each run owns its RNG streams and per-run node
//!   workers don't affect results, so scheduling order is immaterial.
//! * **Runner equivalence.** [`execute_one`] replicates
//!   `coordinator::runner::run`'s evaluation loop exactly (same record
//!   cadence, same field order), so a sweep run of a config equals
//!   `experiments::run_config` of the same config.
//! * **Resume.** A completed run is one JSONL record in
//!   `<out>/results.jsonl` plus `<out>/series/<id>.jsonl`; with
//!   `resume`, such runs are skipped and their stored series returned.
//!   Incomplete long runs resume from their latest
//!   `coordinator::checkpoint` snapshot (`<out>/ckpt/<id>.ckpt` + the
//!   partial series) bit-for-bit.
//! * **Early stop (adaptive budgets).** With a `target_error` /
//!   `target_loss` set, a run halts at the first *evaluation record*
//!   that reaches the target (the `first_reaching_*` projection applied
//!   online). Evaluation cadence is part of the config, so the stop
//!   round is identical for every worker budget and for serial vs
//!   distributed execution, and the truncated series is a **bit-exact
//!   prefix** of the untruncated run's series. The truncation is
//!   recorded in the JSONL result (`"truncated": {t, reason, target}`)
//!   so resumed/merged result sets stay well-defined. The worker that
//!   ran the truncated run immediately picks up the next pending run
//!   (the pool hands out slots dynamically).

use std::collections::HashMap;
use std::fs::{self, File, OpenOptions};
use std::io::{BufWriter, Write};
use std::path::{Path, PathBuf};
use std::sync::atomic::{AtomicUsize, Ordering};
use std::sync::Mutex;
use std::time::Instant;

use crate::comm::FaultCounters;
use crate::config::ExperimentConfig;
use crate::coordinator::Checkpoint;
use crate::metrics::{float_json, json_f64_lossy, RoundRecord, Series};
use crate::run::{DriveEnd, Run, RunObserver};
use crate::util::json::Json;
use crate::util::threadpool::ThreadPool;

use super::cache::ArtifactCache;
use super::spec::{config_hash, SweepSpec};

// The run-lifecycle event types moved to the `run` module with the Run
// handle (PR-4 shapes, unchanged apart from `Started.node_workers`);
// re-exported here so sweep-level consumers keep their import paths.
pub use crate::run::{EventHook, RunEvent};

/// Per-iteration callback for [`execute_one`]: `Ok(false)` abandons the
/// run (distributed mode returns it when the claim heartbeat fails).
pub(crate) type Tick<'a> = &'a mut dyn FnMut(u64) -> Result<bool, String>;

/// Node-worker budget for one run. `Fixed` pins the split (distributed
/// mode — the grid is shared across processes, so a local pending count
/// means little); `Dynamic` re-reads ⌊budget / min(run_workers,
/// pending)⌋ every iteration, so as the run pool drains, surviving runs
/// widen onto the freed threads mid-run instead of keeping the split
/// chosen at sweep start. Results are bit-for-bit identical for any
/// worker count (pinned by `rust/tests/sparse_parallel.rs`), so the
/// re-split is pure scheduling.
pub(crate) enum NodeBudget<'a> {
    Fixed(usize),
    Dynamic {
        budget: usize,
        run_workers: usize,
        pending: &'a AtomicUsize,
    },
}

impl NodeBudget<'_> {
    pub(crate) fn current(&self) -> usize {
        match self {
            NodeBudget::Fixed(w) => (*w).max(1),
            NodeBudget::Dynamic {
                budget,
                run_workers,
                pending,
            } => {
                let p = pending.load(Ordering::Relaxed).max(1);
                (*budget / (*run_workers).min(p).max(1)).max(1)
            }
        }
    }
}

/// Options for one sweep invocation.
#[derive(Clone)]
pub struct SweepOptions {
    /// Total worker budget shared by run-level and node-level
    /// parallelism (0 ⇒ available CPUs): min(budget, pending runs)
    /// concurrent runs, each stepping with ⌊budget / that⌋ node workers.
    /// Does not affect results. Configs' own `workers` fields are
    /// ignored inside sweeps — the budget governs.
    pub workers: usize,
    /// Output directory (`results.jsonl`, `series/`, `ckpt/`); `None`
    /// keeps everything in memory.
    pub out: Option<PathBuf>,
    /// Skip runs whose result record already exists; pick up incomplete
    /// runs from their mid-run checkpoints.
    pub resume: bool,
    /// Snapshot long runs every this many iterations (0 ⇒ never).
    /// Requires `out`.
    pub checkpoint_every: u64,
    /// Print per-run progress lines.
    pub verbose: bool,
    /// Fault-injection hook for the resume tests: abandon each run
    /// (without recording a result) once it reaches this iteration.
    pub fault_abort_at: Option<u64>,
    /// Early-stop a run at the first evaluation record with
    /// `test_error <= target_error` (adaptive budget; see module docs).
    pub target_error: Option<f64>,
    /// Early-stop a run at the first evaluation record with
    /// `loss <= target_loss`.
    pub target_loss: Option<f64>,
    /// Run lifecycle observer (scheduling-order tests, progress UIs).
    pub on_event: Option<EventHook>,
}

impl Default for SweepOptions {
    fn default() -> Self {
        SweepOptions {
            workers: 1,
            out: None,
            resume: false,
            checkpoint_every: 0,
            verbose: false,
            fault_abort_at: None,
            target_error: None,
            target_loss: None,
            on_event: None,
        }
    }
}

impl std::fmt::Debug for SweepOptions {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        f.debug_struct("SweepOptions")
            .field("workers", &self.workers)
            .field("out", &self.out)
            .field("resume", &self.resume)
            .field("checkpoint_every", &self.checkpoint_every)
            .field("verbose", &self.verbose)
            .field("fault_abort_at", &self.fault_abort_at)
            .field("target_error", &self.target_error)
            .field("target_loss", &self.target_loss)
            .field("on_event", &self.on_event.as_ref().map(|_| "<hook>"))
            .finish()
    }
}

/// How an early-stopped run was truncated (recorded in the JSONL
/// result as `"truncated": {"t": ..., "reason": ..., "target": ...}`).
#[derive(Clone, Debug, PartialEq)]
pub struct EarlyStop {
    /// Iteration of the evaluation record that reached the target (the
    /// run's series ends exactly at this record).
    pub t: u64,
    /// "target_error" or "target_loss".
    pub reason: String,
    /// The target value that was reached.
    pub target: f64,
}

/// One run's result.
#[derive(Clone, Debug)]
pub struct RunOutcome {
    /// [`config_hash`] of the expanded config (keys resume).
    pub id: String,
    /// Display label (the suite curve name).
    pub label: String,
    pub cfg: ExperimentConfig,
    pub series: Series,
    /// Cumulative (transmitted, opportunities) trigger statistics.
    pub fired: u64,
    pub checks: u64,
    pub wall_ms: u64,
    /// Fault-plan event totals (all zero on fault-free runs).
    pub fault: FaultCounters,
    /// True when the run was satisfied from a stored result (resume).
    pub skipped: bool,
    /// False only for fault-aborted/abandoned runs (no result recorded).
    pub completed: bool,
    /// Present when an early-stop target truncated the run.
    pub stopped: Option<EarlyStop>,
}

/// Aggregate result of a sweep invocation (outcomes in input order).
#[derive(Debug)]
pub struct SweepReport {
    pub outcomes: Vec<RunOutcome>,
    pub executed: usize,
    pub skipped: usize,
    pub wall_ms: u64,
    /// Artifact-cache hit/miss summary for logs.
    pub cache_summary: String,
}

impl SweepReport {
    /// The outcome for a given expanded-config id, if present.
    pub fn by_id(&self, id: &str) -> Option<&RunOutcome> {
        self.outcomes.iter().find(|o| o.id == id)
    }
}

/// Expand a spec and run it (fresh artifact cache). Spec-declared
/// early-stop targets apply unless the options already set one.
pub fn run_spec(spec: &SweepSpec, opts: &SweepOptions) -> Result<SweepReport, String> {
    let runs = spec.expand()?;
    let cache = ArtifactCache::new();
    run_configs(runs, &spec.apply_targets(opts), &cache)
}

struct Slot {
    label: String,
    cfg: ExperimentConfig,
    id: String,
    outcome: Option<RunOutcome>,
}

/// Run an explicit labelled config list on the sweep engine (the
/// refactored experiment drivers call this; [`run_spec`] layers grid
/// expansion on top).
pub fn run_configs(
    runs: Vec<(String, ExperimentConfig)>,
    opts: &SweepOptions,
    cache: &ArtifactCache,
) -> Result<SweepReport, String> {
    let sweep_start = Instant::now();
    let budget = if opts.workers == 0 {
        std::thread::available_parallelism()
            .map(|n| n.get())
            .unwrap_or(1)
    } else {
        opts.workers
    };

    // Output layout + previously completed records.
    let mut series_dir = None;
    let mut ckpt_dir = None;
    let mut completed: HashMap<String, Json> = HashMap::new();
    let mut sink: Option<Mutex<BufWriter<File>>> = None;
    if let Some(out) = &opts.out {
        let sdir = out.join("series");
        let cdir = out.join("ckpt");
        fs::create_dir_all(&sdir).map_err(|e| format!("{}: {e}", sdir.display()))?;
        fs::create_dir_all(&cdir).map_err(|e| format!("{}: {e}", cdir.display()))?;
        let results_path = out.join("results.jsonl");
        if opts.resume && results_path.exists() {
            let text = fs::read_to_string(&results_path)
                .map_err(|e| format!("{}: {e}", results_path.display()))?;
            for (lineno, line) in text.lines().enumerate() {
                if line.trim().is_empty() {
                    continue;
                }
                // A torn line (process killed mid-append; non-atomic
                // O_APPEND on a network filesystem) must not wedge the
                // whole directory: the affected run simply re-runs and
                // its fresh record supersedes the damage (last wins).
                match Json::parse(line) {
                    Ok(j) => {
                        if let Some(id) = j.get("id").and_then(Json::as_str) {
                            completed.insert(id.to_string(), j.clone());
                        }
                    }
                    Err(e) => eprintln!(
                        "[sweep] ignoring unparsable record {}:{}: {e}",
                        results_path.display(),
                        lineno + 1
                    ),
                }
            }
        }
        let file = OpenOptions::new()
            .create(true)
            .append(opts.resume)
            .write(true)
            .truncate(!opts.resume)
            .open(&results_path)
            .map_err(|e| format!("{}: {e}", results_path.display()))?;
        sink = Some(Mutex::new(BufWriter::new(file)));
        series_dir = Some(sdir);
        ckpt_dir = Some(cdir);
    }

    let mut slots: Vec<Slot> = runs
        .into_iter()
        .map(|(label, cfg)| {
            let id = config_hash(&cfg);
            Slot {
                label,
                cfg,
                id,
                outcome: None,
            }
        })
        .collect();

    reject_duplicate_ids(slots.iter().map(|s| (&s.id, &s.label)))?;

    let pending = slots
        .iter()
        .filter(|s| !completed.contains_key(&s.id))
        .count();
    let run_workers = budget.min(pending.max(1)).max(1);
    // Dynamic rebalancing: the worker split is ⌊budget / min(run_workers,
    // pending)⌋, re-read as runs finish — when the pool drains below the
    // run-level concurrency, surviving runs widen onto the freed threads
    // (mid-run too, via the Run observer's workers hint).
    let pending_ctr = AtomicUsize::new(pending);

    let errors: Mutex<Vec<String>> = Mutex::new(Vec::new());
    let completed = &completed;
    let series_dir = series_dir.as_deref();
    let ckpt_dir = ckpt_dir.as_deref();
    let sink_ref = sink.as_ref();
    let pending_ctr = &pending_ctr;
    ThreadPool::new(run_workers).for_each_mut(&mut slots, |_, slot| {
        // Resume: a stored record + series satisfies the run outright.
        if let Some(record) = completed.get(&slot.id) {
            match load_completed(&slot.label, &slot.cfg, &slot.id, record, series_dir) {
                Ok(outcome) => {
                    if opts.verbose {
                        println!("[sweep] skip {} (resume: already complete)", slot.label);
                    }
                    slot.outcome = Some(outcome);
                    return;
                }
                Err(e) => {
                    // Record without a readable series — re-run it.
                    if opts.verbose {
                        println!("[sweep] re-run {}: {e}", slot.label);
                    }
                }
            }
        }
        // Re-runs of records with unreadable series were not part of the
        // initial pending count — enter them now so the dynamic re-split
        // sees every executing run (otherwise concurrent runs could each
        // be granted the full budget and oversubscribe the machine).
        if completed.contains_key(&slot.id) {
            pending_ctr.fetch_add(1, Ordering::Relaxed);
        }
        let node_budget = NodeBudget::Dynamic {
            budget,
            run_workers,
            pending: pending_ctr,
        };
        if let Some(hook) = &opts.on_event {
            hook(&RunEvent::Started {
                id: slot.id.clone(),
                label: slot.label.clone(),
                node_workers: node_budget.current(),
            });
        }
        let res = execute_one(
            &slot.label,
            &slot.cfg,
            &slot.id,
            cache,
            &node_budget,
            opts,
            ckpt_dir,
            None,
        );
        pending_ctr.fetch_sub(1, Ordering::Relaxed);
        match res {
            Ok(outcome) => {
                if outcome.completed {
                    if let Err(e) = persist(&outcome, series_dir, sink_ref) {
                        errors.lock().unwrap().push(e);
                        return;
                    }
                }
                if opts.verbose {
                    let state = if !outcome.completed {
                        "paused"
                    } else if outcome.stopped.is_some() {
                        "early-stop"
                    } else {
                        "done"
                    };
                    let last = outcome.series.records.last();
                    println!(
                        "[sweep] {state} {} ({} ms, loss={:.5}, bits={})",
                        slot.label,
                        outcome.wall_ms,
                        last.map(|r| r.loss).unwrap_or(f64::NAN),
                        last.map(|r| r.bits).unwrap_or(0),
                    );
                }
                if let Some(hook) = &opts.on_event {
                    hook(&RunEvent::Finished {
                        id: slot.id.clone(),
                        label: slot.label.clone(),
                        completed: outcome.completed,
                        stopped: outcome.stopped.is_some(),
                    });
                }
                slot.outcome = Some(outcome);
            }
            Err(e) => errors.lock().unwrap().push(format!("{}: {e}", slot.label)),
        }
    });

    let errors = errors.into_inner().unwrap();
    if !errors.is_empty() {
        return Err(errors.join("; "));
    }
    if let Some(s) = &sink {
        s.lock().unwrap().flush().map_err(|e| e.to_string())?;
    }

    let outcomes: Vec<RunOutcome> = slots
        .into_iter()
        .map(|s| s.outcome.expect("no outcome and no error"))
        .collect();
    let executed = outcomes.iter().filter(|o| !o.skipped && o.completed).count();
    let skipped = outcomes.iter().filter(|o| o.skipped).count();
    Ok(SweepReport {
        outcomes,
        executed,
        skipped,
        wall_ms: sweep_start.elapsed().as_millis() as u64,
        cache_summary: cache.summary(),
    })
}

/// Reject run sets where two entries share a config id: the hash
/// normalizes only name/workers, so such runs are the same semantic
/// config — they would produce identical results while racing on the
/// same series file. Shared by the serial and distributed runners.
pub(crate) fn reject_duplicate_ids<I, A, B>(slots: I) -> Result<(), String>
where
    I: Iterator<Item = (A, B)>,
    A: AsRef<str>,
    B: AsRef<str>,
{
    let mut seen: HashMap<String, String> = HashMap::new();
    for (id, label) in slots {
        let (id, label) = (id.as_ref(), label.as_ref());
        if let Some(prev) = seen.insert(id.to_string(), label.to_string()) {
            return Err(format!(
                "runs {prev:?} and {label:?} are the same config (id {id}) — \
                 deduplicate the grid"
            ));
        }
    }
    Ok(())
}

/// Parse a result record's `"truncated"` object back into an
/// [`EarlyStop`], if present.
pub(crate) fn parse_truncated(record: &Json) -> Option<EarlyStop> {
    record.get("truncated").map(|tj| EarlyStop {
        t: tj.get("t").and_then(Json::as_u64).unwrap_or(0),
        reason: tj
            .get("reason")
            .and_then(Json::as_str)
            .unwrap_or("")
            .to_string(),
        target: tj.get("target").map(json_f64_lossy).unwrap_or(f64::NAN),
    })
}

/// Rebuild a [`RunOutcome`] from its stored record + series.
pub(crate) fn load_completed(
    label: &str,
    cfg: &ExperimentConfig,
    id: &str,
    record: &Json,
    series_dir: Option<&Path>,
) -> Result<RunOutcome, String> {
    let dir = series_dir.ok_or("no series directory")?;
    let path = dir.join(format!("{id}.jsonl"));
    let series_label = record
        .get("series_label")
        .and_then(Json::as_str)
        .unwrap_or(label)
        .to_string();
    let series = Series::read_jsonl(&path, series_label)
        .map_err(|e| format!("stored series unreadable: {e}"))?;
    // Strict counters: a damaged record numeric (fractional/negative)
    // errors out here, and the caller's recovery path re-runs the config
    // instead of resuming from silently-truncated values.
    let u = |k: &str| -> Result<u64, String> {
        match record.get(k) {
            None => Ok(0),
            Some(v) => v
                .as_u64()
                .ok_or_else(|| format!("stored {k:?} is not a non-negative integer")),
        }
    };
    Ok(RunOutcome {
        id: id.to_string(),
        label: label.to_string(),
        cfg: cfg.clone(),
        series,
        fired: u("fired")?,
        checks: u("checks")?,
        wall_ms: u("wall_ms")?,
        fault: parse_fault(record),
        skipped: true,
        completed: true,
        stopped: parse_truncated(record),
    })
}

/// Fault counters from a stored record (`"fault"` is written only for
/// runs whose plan actually fired — absence means all-zero).
pub(crate) fn parse_fault(record: &Json) -> FaultCounters {
    let Some(fj) = record.get("fault") else {
        return FaultCounters::default();
    };
    let u = |k: &str| fj.get(k).and_then(Json::as_u64).unwrap_or(0);
    FaultCounters {
        crashes: u("crashes"),
        resyncs: u("resyncs"),
        corrupt_discards: u("corrupt"),
    }
}

/// Stream a completed run to disk: series file first, then the record
/// line (so a record's existence implies a readable series). The record
/// line is buffered whole and flushed immediately, so concurrent
/// appenders (distributed mode opens the shared `results.jsonl` with
/// `O_APPEND` from several processes) emit one write syscall per line
/// and lines never interleave.
pub(crate) fn persist(
    outcome: &RunOutcome,
    series_dir: Option<&Path>,
    sink: Option<&Mutex<BufWriter<File>>>,
) -> Result<(), String> {
    let (Some(dir), Some(sink)) = (series_dir, sink) else {
        return Ok(());
    };
    let spath = dir.join(format!("{}.jsonl", outcome.id));
    outcome
        .series
        .write_jsonl(&spath)
        .map_err(|e| format!("{}: {e}", spath.display()))?;
    let final_record = outcome
        .series
        .records
        .last()
        .map(|r| r.to_json())
        .unwrap_or_else(Json::obj);
    let mut record = Json::obj()
        .set("id", outcome.id.as_str())
        .set("name", outcome.cfg.name.as_str())
        .set("label", outcome.label.as_str())
        .set("series_label", outcome.series.label.as_str())
        .set("algo", outcome.cfg.algo.as_str())
        .set("fired", outcome.fired)
        .set("checks", outcome.checks)
        .set("wall_ms", outcome.wall_ms)
        .set("records", outcome.series.records.len())
        .set("final", final_record)
        .set("config", outcome.cfg.to_json());
    // Additive top-level key (the report's family panel groups on it):
    // the trigger-side composition — "squarm:B" for the momentum family,
    // "percoord" for per-coordinate triggers — written only for
    // non-default compositions so existing result files stay
    // byte-identical (absent ⇒ plain sparq).
    if !outcome.cfg.family.is_default() {
        record = record.set("family", outcome.cfg.family.as_str());
    } else if outcome.cfg.trigger.per_coord() {
        record = record.set("family", "percoord");
    }
    // Written only when a fault plan actually fired, so pre-fault (and
    // fault-free) result files stay byte-identical.
    if !outcome.fault.is_zero() {
        record = record.set(
            "fault",
            Json::obj()
                .set("crashes", outcome.fault.crashes)
                .set("resyncs", outcome.fault.resyncs)
                .set("corrupt", outcome.fault.corrupt_discards),
        );
    }
    if let Some(stop) = &outcome.stopped {
        record = record.set(
            "truncated",
            Json::obj()
                .set("t", stop.t)
                .set("reason", stop.reason.as_str())
                .set("target", float_json(stop.target)),
        );
    }
    let mut w = sink.lock().unwrap();
    writeln!(w, "{}", record.to_string()).map_err(|e| e.to_string())?;
    w.flush().map_err(|e| e.to_string())
}

/// The early-stop target a record reaches, if any (`target_error` is
/// checked before `target_loss`; NaN metrics never satisfy a target).
fn target_hit(opts: &SweepOptions, r: &RoundRecord) -> Option<EarlyStop> {
    if let Some(te) = opts.target_error {
        if r.test_error <= te {
            return Some(EarlyStop {
                t: r.t,
                reason: "target_error".into(),
                target: te,
            });
        }
    }
    if let Some(tl) = opts.target_loss {
        if r.loss <= tl {
            return Some(EarlyStop {
                t: r.t,
                reason: "target_loss".into(),
                target: tl,
            });
        }
    }
    None
}

/// The sweep engine's [`RunObserver`]: early-stop targets at evaluation
/// records, checkpoint cadence, fault injection, the distributed
/// heartbeat tick, and the dynamic worker re-split.
struct SweepObserver<'a> {
    opts: &'a SweepOptions,
    ckpt_path: Option<&'a PathBuf>,
    partial_path: Option<&'a PathBuf>,
    tick: Option<Tick<'a>>,
    budget: &'a NodeBudget<'a>,
    stopped: Option<EarlyStop>,
}

impl RunObserver for SweepObserver<'_> {
    fn tick(&mut self, t: u64) -> Result<bool, String> {
        match self.tick.as_mut() {
            Some(tk) => tk(t),
            None => Ok(true),
        }
    }

    fn evaluated(&mut self, rec: &RoundRecord, done: bool) -> bool {
        // A target hit on the final record is not a truncation.
        if done {
            return false;
        }
        self.stopped = target_hit(self.opts, rec);
        self.stopped.is_some()
    }

    fn checkpoint_due(&mut self, t: u64) -> bool {
        self.opts.checkpoint_every > 0
            && t % self.opts.checkpoint_every == 0
            && self.ckpt_path.is_some()
    }

    fn persist(&mut self, ck: Checkpoint, series: &Series) -> Result<(), String> {
        let (Some(cp), Some(pp)) = (self.ckpt_path, self.partial_path) else {
            return Ok(());
        };
        ck.save(cp).map_err(|e| format!("{}: {e}", cp.display()))?;
        series
            .write_jsonl(pp)
            .map_err(|e| format!("{}: {e}", pp.display()))
    }

    fn abort_due(&mut self, t: u64) -> bool {
        self.opts.fault_abort_at == Some(t)
    }

    fn workers_hint(&mut self, _t: u64) -> Option<usize> {
        Some(self.budget.current())
    }
}

/// Execute one run through the [`Run`](crate::run::Run) handle, with
/// optional mid-run checkpointing, checkpoint resume, and early-stop
/// targets. `tick`, when given, is called once per iteration
/// (distributed mode refreshes its claim heartbeat there): `Ok(false)`
/// abandons the run — no result is recorded and the returned outcome has
/// `completed == false`.
#[allow(clippy::too_many_arguments)]
pub(crate) fn execute_one(
    label: &str,
    cfg: &ExperimentConfig,
    id: &str,
    cache: &ArtifactCache,
    budget: &NodeBudget<'_>,
    opts: &SweepOptions,
    ckpt_dir: Option<&Path>,
    tick: Option<Tick<'_>>,
) -> Result<RunOutcome, String> {
    let run_start = Instant::now();
    let resolved = cfg.resolve().map_err(|e| e.to_string())?;
    let mut run = Run::from_resolved(&resolved, Some(cache), budget.current());
    let series_label = run.series().label.clone();

    let ckpt_path = ckpt_dir.map(|dir| dir.join(format!("{id}.ckpt")));
    let partial_path = ckpt_dir.map(|dir| dir.join(format!("{id}.partial.jsonl")));
    if opts.resume {
        if let (Some(cp), Some(pp)) = (&ckpt_path, &partial_path) {
            if cp.exists() && pp.exists() {
                let ck = Checkpoint::load(cp).map_err(|e| format!("checkpoint: {e}"))?;
                let series = Series::read_jsonl(pp, series_label.clone())
                    .map_err(|e| format!("partial series: {e}"))?;
                match run.restore(&ck, series) {
                    Ok(()) => {
                        if opts.verbose {
                            println!("[sweep] resume {label} from t={}", run.t());
                        }
                    }
                    Err(e) => {
                        // A stale or foreign snapshot (edited spec, wrong
                        // run id collision) must not poison the sweep:
                        // drop it and run fresh from t = 0.
                        eprintln!("[sweep] discarding checkpoint for {label}: {e}");
                        fs::remove_file(cp).ok();
                        fs::remove_file(pp).ok();
                    }
                }
            }
        }
    }

    let outcome = |run: &Run, series: Series, completed: bool, stopped: Option<EarlyStop>| {
        let (fired, checks) = run.fired_stats();
        RunOutcome {
            id: id.to_string(),
            label: label.to_string(),
            cfg: cfg.clone(),
            series,
            fired,
            checks,
            wall_ms: run_start.elapsed().as_millis() as u64,
            fault: run.algo().fault_counters(),
            skipped: false,
            completed,
            stopped,
        }
    };
    let cleanup = |ckpt_path: &Option<PathBuf>, partial_path: &Option<PathBuf>| {
        // Complete (or early-stopped): mid-run snapshots are superseded
        // by the result record.
        if let Some(cp) = ckpt_path {
            fs::remove_file(cp).ok();
        }
        if let Some(pp) = partial_path {
            fs::remove_file(pp).ok();
        }
    };

    // A target introduced after the partial progress was made: the
    // loaded prefix may already cross it. Truncate to the first
    // crossing and finish immediately — the series stays a bit-exact
    // prefix of the untruncated trajectory. The recorded fired/checks
    // come from the checkpoint (the closest snapshot; RoundRecord's
    // `fired` is per-round, so the crossing-time cumulative stats are
    // not recoverable), which can exceed the online-stop values — but
    // only on this path, which is unreachable under a consistent spec:
    // with the target in effect from the start, execution stops at the
    // crossing and never checkpoints past it, so serial and distributed
    // runs of one spec still record identical statistics.
    if run.t() > 0 {
        let hit = run
            .series()
            .records
            .iter()
            .position(|r| target_hit(opts, r).is_some());
        if let Some(i) = hit {
            let stop = target_hit(opts, &run.series().records[i]);
            run.series_mut().records.truncate(i + 1);
            cleanup(&ckpt_path, &partial_path);
            let series = run.series().clone();
            return Ok(outcome(&run, series, true, stop));
        }
    }

    let mut obs = SweepObserver {
        opts,
        ckpt_path: ckpt_path.as_ref(),
        partial_path: partial_path.as_ref(),
        tick,
        budget,
        stopped: None,
    };
    let end = run.drive(&mut obs)?;
    let stopped = obs.stopped.take();
    match end {
        DriveEnd::Abandoned => {
            // Claim lost / fault injection: leave checkpoints in place
            // for takeover; no result is recorded.
            let series = run.series().clone();
            Ok(outcome(&run, series, false, None))
        }
        DriveEnd::Completed | DriveEnd::Stopped => {
            cleanup(&ckpt_path, &partial_path);
            let series = run.series().clone();
            Ok(outcome(&run, series, true, stopped))
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::experiments::run_config;

    fn quick_cfg(seed: u64) -> ExperimentConfig {
        ExperimentConfig {
            name: format!("quick-{seed}"),
            nodes: 5,
            steps: 120,
            eval_every: 40,
            problem: "quadratic:16".into(),
            compressor: "sign_topk:25%".into(),
            trigger: "const:20".into(),
            seed,
            ..Default::default()
        }
    }

    #[test]
    fn sweep_run_equals_run_config() {
        let cfg = quick_cfg(3);
        let expect = run_config(&cfg, false);
        let cache = ArtifactCache::new();
        let report = run_configs(
            vec![("quick".into(), cfg)],
            &SweepOptions::default(),
            &cache,
        )
        .unwrap();
        assert_eq!(report.outcomes.len(), 1);
        assert_eq!(report.executed, 1);
        let got = &report.outcomes[0].series;
        assert_eq!(got.to_csv(), expect.to_csv());
    }

    #[test]
    fn budget_splits_into_run_and_node_workers() {
        // Pure scheduling property: any budget produces the same series.
        let mk = || vec![
            ("a".to_string(), quick_cfg(1)),
            ("b".to_string(), quick_cfg(2)),
        ];
        let cache = ArtifactCache::new();
        let serial = run_configs(mk(), &SweepOptions::default(), &cache).unwrap();
        let wide = run_configs(
            mk(),
            &SweepOptions {
                workers: 8,
                ..Default::default()
            },
            &cache,
        )
        .unwrap();
        for (a, b) in serial.outcomes.iter().zip(wide.outcomes.iter()) {
            assert_eq!(a.id, b.id);
            assert_eq!(a.series.to_csv(), b.series.to_csv());
        }
    }

    #[test]
    fn duplicate_configs_are_rejected_not_raced() {
        // Same semantic config under two labels hashes to one id — the
        // runs would race on the same series file, so the set is an error.
        let cache = ArtifactCache::new();
        let mut renamed = quick_cfg(1);
        renamed.name = "other-name".into();
        let err = run_configs(
            vec![("a".into(), quick_cfg(1)), ("b".into(), renamed)],
            &SweepOptions::default(),
            &cache,
        )
        .unwrap_err();
        assert!(err.contains("same config"), "{err}");
    }

    #[test]
    fn bad_config_surfaces_as_error_not_poison() {
        // expand() rejects bad specs, but run_configs can still receive a
        // config whose string specs fail at build time — builders panic,
        // which would poison the pool. Guard the easy case: zero steps is
        // legal and produces the t=0 record only.
        let mut cfg = quick_cfg(1);
        cfg.steps = 0;
        let cache = ArtifactCache::new();
        let report = run_configs(
            vec![("empty".into(), cfg)],
            &SweepOptions::default(),
            &cache,
        )
        .unwrap();
        assert_eq!(report.outcomes[0].series.records.len(), 1);
    }
}
