//! Concurrent sweep execution: run-level parallelism over the engine's
//! per-node parallelism, JSONL result streaming, and resume.
//!
//! Execution contract (pinned by `rust/tests/sweep_system.rs`):
//!
//! * **Determinism.** Per-run results are bit-for-bit identical for any
//!   worker budget: each run owns its RNG streams and per-run node
//!   workers don't affect results, so scheduling order is immaterial.
//! * **Runner equivalence.** [`execute_one`] replicates
//!   `coordinator::runner::run`'s evaluation loop exactly (same record
//!   cadence, same field order), so a sweep run of a config equals
//!   `experiments::run_config` of the same config.
//! * **Resume.** A completed run is one JSONL record in
//!   `<out>/results.jsonl` plus `<out>/series/<id>.jsonl`; with
//!   `resume`, such runs are skipped and their stored series returned.
//!   Incomplete long runs resume from their latest
//!   `coordinator::checkpoint` snapshot (`<out>/ckpt/<id>.ckpt` + the
//!   partial series) bit-for-bit.

use std::collections::HashMap;
use std::fs::{self, File, OpenOptions};
use std::io::{BufWriter, Write};
use std::path::{Path, PathBuf};
use std::sync::Mutex;
use std::time::Instant;

use crate::comm::Bus;
use crate::config::ExperimentConfig;
use crate::coordinator::{checkpoint, Checkpoint, DecentralizedAlgo};
use crate::experiments::builder::{build_algo_with, build_problem_with};
use crate::metrics::{RoundRecord, Series};
use crate::problems::GradientSource;
use crate::util::json::Json;
use crate::util::threadpool::ThreadPool;
use crate::util::Rng;

use super::cache::ArtifactCache;
use super::spec::{config_hash, SweepSpec};

/// Options for one sweep invocation.
#[derive(Clone, Debug)]
pub struct SweepOptions {
    /// Total worker budget shared by run-level and node-level
    /// parallelism (0 ⇒ available CPUs): min(budget, pending runs)
    /// concurrent runs, each stepping with ⌊budget / that⌋ node workers.
    /// Does not affect results. Configs' own `workers` fields are
    /// ignored inside sweeps — the budget governs.
    pub workers: usize,
    /// Output directory (`results.jsonl`, `series/`, `ckpt/`); `None`
    /// keeps everything in memory.
    pub out: Option<PathBuf>,
    /// Skip runs whose result record already exists; pick up incomplete
    /// runs from their mid-run checkpoints.
    pub resume: bool,
    /// Snapshot long runs every this many iterations (0 ⇒ never).
    /// Requires `out`.
    pub checkpoint_every: u64,
    /// Print per-run progress lines.
    pub verbose: bool,
    /// Fault-injection hook for the resume tests: abandon each run
    /// (without recording a result) once it reaches this iteration.
    pub fault_abort_at: Option<u64>,
}

impl Default for SweepOptions {
    fn default() -> Self {
        SweepOptions {
            workers: 1,
            out: None,
            resume: false,
            checkpoint_every: 0,
            verbose: false,
            fault_abort_at: None,
        }
    }
}

/// One run's result.
#[derive(Clone, Debug)]
pub struct RunOutcome {
    /// [`config_hash`] of the expanded config (keys resume).
    pub id: String,
    /// Display label (the suite curve name).
    pub label: String,
    pub cfg: ExperimentConfig,
    pub series: Series,
    /// Cumulative (transmitted, opportunities) trigger statistics.
    pub fired: u64,
    pub checks: u64,
    pub wall_ms: u64,
    /// True when the run was satisfied from a stored result (resume).
    pub skipped: bool,
    /// False only for fault-aborted runs (no result recorded).
    pub completed: bool,
}

/// Aggregate result of a sweep invocation (outcomes in input order).
#[derive(Debug)]
pub struct SweepReport {
    pub outcomes: Vec<RunOutcome>,
    pub executed: usize,
    pub skipped: usize,
    pub wall_ms: u64,
    /// Artifact-cache hit/miss summary for logs.
    pub cache_summary: String,
}

impl SweepReport {
    /// The outcome for a given expanded-config id, if present.
    pub fn by_id(&self, id: &str) -> Option<&RunOutcome> {
        self.outcomes.iter().find(|o| o.id == id)
    }
}

/// Expand a spec and run it (fresh artifact cache).
pub fn run_spec(spec: &SweepSpec, opts: &SweepOptions) -> Result<SweepReport, String> {
    let runs = spec.expand()?;
    let cache = ArtifactCache::new();
    run_configs(runs, opts, &cache)
}

struct Slot {
    label: String,
    cfg: ExperimentConfig,
    id: String,
    outcome: Option<RunOutcome>,
}

/// Run an explicit labelled config list on the sweep engine (the
/// refactored experiment drivers call this; [`run_spec`] layers grid
/// expansion on top).
pub fn run_configs(
    runs: Vec<(String, ExperimentConfig)>,
    opts: &SweepOptions,
    cache: &ArtifactCache,
) -> Result<SweepReport, String> {
    let sweep_start = Instant::now();
    let budget = if opts.workers == 0 {
        std::thread::available_parallelism()
            .map(|n| n.get())
            .unwrap_or(1)
    } else {
        opts.workers
    };

    // Output layout + previously completed records.
    let mut series_dir = None;
    let mut ckpt_dir = None;
    let mut completed: HashMap<String, Json> = HashMap::new();
    let mut sink: Option<Mutex<BufWriter<File>>> = None;
    if let Some(out) = &opts.out {
        let sdir = out.join("series");
        let cdir = out.join("ckpt");
        fs::create_dir_all(&sdir).map_err(|e| format!("{}: {e}", sdir.display()))?;
        fs::create_dir_all(&cdir).map_err(|e| format!("{}: {e}", cdir.display()))?;
        let results_path = out.join("results.jsonl");
        if opts.resume && results_path.exists() {
            let text = fs::read_to_string(&results_path)
                .map_err(|e| format!("{}: {e}", results_path.display()))?;
            for line in text.lines().filter(|l| !l.trim().is_empty()) {
                let j = Json::parse(line)
                    .map_err(|e| format!("{}: {e}", results_path.display()))?;
                if let Some(id) = j.get("id").and_then(Json::as_str) {
                    completed.insert(id.to_string(), j.clone());
                }
            }
        }
        let file = OpenOptions::new()
            .create(true)
            .append(opts.resume)
            .write(true)
            .truncate(!opts.resume)
            .open(&results_path)
            .map_err(|e| format!("{}: {e}", results_path.display()))?;
        sink = Some(Mutex::new(BufWriter::new(file)));
        series_dir = Some(sdir);
        ckpt_dir = Some(cdir);
    }

    let mut slots: Vec<Slot> = runs
        .into_iter()
        .map(|(label, cfg)| {
            let id = config_hash(&cfg);
            Slot {
                label,
                cfg,
                id,
                outcome: None,
            }
        })
        .collect();

    // Two runs with the same id are the same semantic config (the hash
    // normalizes only name/workers) — they would produce identical
    // results while racing on the same series file, so reject the set.
    {
        let mut seen: HashMap<&str, &str> = HashMap::new();
        for s in &slots {
            if let Some(prev) = seen.insert(&s.id, &s.label) {
                return Err(format!(
                    "runs {prev:?} and {:?} are the same config (id {}) — \
                     deduplicate the grid",
                    s.label, s.id
                ));
            }
        }
    }

    let pending = slots
        .iter()
        .filter(|s| !completed.contains_key(&s.id))
        .count();
    let run_workers = budget.min(pending.max(1)).max(1);
    let node_workers = (budget / run_workers).max(1);

    let errors: Mutex<Vec<String>> = Mutex::new(Vec::new());
    let completed = &completed;
    let series_dir = series_dir.as_deref();
    let ckpt_dir = ckpt_dir.as_deref();
    let sink_ref = sink.as_ref();
    ThreadPool::new(run_workers).for_each_mut(&mut slots, |_, slot| {
        // Resume: a stored record + series satisfies the run outright.
        if let Some(record) = completed.get(&slot.id) {
            match load_completed(slot, record, series_dir) {
                Ok(outcome) => {
                    if opts.verbose {
                        println!("[sweep] skip {} (resume: already complete)", slot.label);
                    }
                    slot.outcome = Some(outcome);
                    return;
                }
                Err(e) => {
                    // Record without a readable series — re-run it.
                    if opts.verbose {
                        println!("[sweep] re-run {}: {e}", slot.label);
                    }
                }
            }
        }
        match execute_one(slot, cache, node_workers, opts, ckpt_dir) {
            Ok(outcome) => {
                if outcome.completed {
                    if let Err(e) = persist(&outcome, series_dir, sink_ref) {
                        errors.lock().unwrap().push(e);
                        return;
                    }
                }
                if opts.verbose {
                    let state = if outcome.completed { "done" } else { "paused" };
                    let last = outcome.series.records.last();
                    println!(
                        "[sweep] {state} {} ({} ms, loss={:.5}, bits={})",
                        slot.label,
                        outcome.wall_ms,
                        last.map(|r| r.loss).unwrap_or(f64::NAN),
                        last.map(|r| r.bits).unwrap_or(0),
                    );
                }
                slot.outcome = Some(outcome);
            }
            Err(e) => errors.lock().unwrap().push(format!("{}: {e}", slot.label)),
        }
    });

    let errors = errors.into_inner().unwrap();
    if !errors.is_empty() {
        return Err(errors.join("; "));
    }
    if let Some(s) = &sink {
        s.lock().unwrap().flush().map_err(|e| e.to_string())?;
    }

    let outcomes: Vec<RunOutcome> = slots
        .into_iter()
        .map(|s| s.outcome.expect("no outcome and no error"))
        .collect();
    let executed = outcomes.iter().filter(|o| !o.skipped && o.completed).count();
    let skipped = outcomes.iter().filter(|o| o.skipped).count();
    Ok(SweepReport {
        outcomes,
        executed,
        skipped,
        wall_ms: sweep_start.elapsed().as_millis() as u64,
        cache_summary: cache.summary(),
    })
}

/// Rebuild a [`RunOutcome`] from its stored record + series.
fn load_completed(
    slot: &Slot,
    record: &Json,
    series_dir: Option<&Path>,
) -> Result<RunOutcome, String> {
    let dir = series_dir.ok_or("no series directory")?;
    let path = dir.join(format!("{}.jsonl", slot.id));
    let series_label = record
        .get("series_label")
        .and_then(Json::as_str)
        .unwrap_or(&slot.label)
        .to_string();
    let series = Series::read_jsonl(&path, series_label)
        .map_err(|e| format!("stored series unreadable: {e}"))?;
    let u = |k: &str| record.get(k).and_then(Json::as_f64).unwrap_or(0.0) as u64;
    Ok(RunOutcome {
        id: slot.id.clone(),
        label: slot.label.clone(),
        cfg: slot.cfg.clone(),
        series,
        fired: u("fired"),
        checks: u("checks"),
        wall_ms: u("wall_ms"),
        skipped: true,
        completed: true,
    })
}

/// Stream a completed run to disk: series file first, then the record
/// line (so a record's existence implies a readable series).
fn persist(
    outcome: &RunOutcome,
    series_dir: Option<&Path>,
    sink: Option<&Mutex<BufWriter<File>>>,
) -> Result<(), String> {
    let (Some(dir), Some(sink)) = (series_dir, sink) else {
        return Ok(());
    };
    let spath = dir.join(format!("{}.jsonl", outcome.id));
    outcome
        .series
        .write_jsonl(&spath)
        .map_err(|e| format!("{}: {e}", spath.display()))?;
    let final_record = outcome
        .series
        .records
        .last()
        .map(|r| r.to_json())
        .unwrap_or_else(Json::obj);
    let record = Json::obj()
        .set("id", outcome.id.as_str())
        .set("name", outcome.cfg.name.as_str())
        .set("label", outcome.label.as_str())
        .set("series_label", outcome.series.label.as_str())
        .set("algo", outcome.cfg.algo.as_str())
        .set("fired", outcome.fired)
        .set("checks", outcome.checks)
        .set("wall_ms", outcome.wall_ms)
        .set("records", outcome.series.records.len())
        .set("final", final_record)
        .set("config", outcome.cfg.to_json());
    let mut w = sink.lock().unwrap();
    writeln!(w, "{}", record.to_string()).map_err(|e| e.to_string())?;
    w.flush().map_err(|e| e.to_string())
}

/// Execute one run, replicating `coordinator::runner::run`'s evaluation
/// loop exactly, with optional mid-run checkpointing and checkpoint
/// resume.
fn execute_one(
    slot: &Slot,
    cache: &ArtifactCache,
    node_workers: usize,
    opts: &SweepOptions,
    ckpt_dir: Option<&Path>,
) -> Result<RunOutcome, String> {
    let cfg = &slot.cfg;
    let run_start = Instant::now();
    let mut problem = build_problem_with(cfg, Some(cache));
    let d = problem.dim();
    let mut algo = build_algo_with(cfg, d, Some(cache));
    let mut init_rng = Rng::new(cfg.seed ^ 0x1217);
    if let Some(x0) = problem.init_params(&mut init_rng) {
        algo.set_params(&x0);
    }
    algo.set_workers(node_workers);
    let mut bus = Bus::new(algo.n());
    let series_label = format!("{}:{}", cfg.name, algo.name());
    let mut series = Series::new(series_label.clone());
    let mut start_t = 0u64;

    let ckpt_path = ckpt_dir.map(|dir| dir.join(format!("{}.ckpt", slot.id)));
    let partial_path = ckpt_dir.map(|dir| dir.join(format!("{}.partial.jsonl", slot.id)));
    if opts.resume {
        if let (Some(cp), Some(pp)) = (&ckpt_path, &partial_path) {
            if cp.exists() && pp.exists() {
                let ck = Checkpoint::load(cp).map_err(|e| format!("checkpoint: {e}"))?;
                checkpoint::restore(algo.as_mut(), &ck);
                checkpoint::restore_bus(&mut bus, &ck);
                series = Series::read_jsonl(pp, series_label.clone())
                    .map_err(|e| format!("partial series: {e}"))?;
                start_t = ck.t;
                if opts.verbose {
                    println!("[sweep] resume {} from t={start_t}", slot.label);
                }
            }
        }
    }

    let evaluate = |algo: &dyn DecentralizedAlgo,
                    src: &mut dyn GradientSource,
                    bus: &Bus,
                    t: u64,
                    series: &mut Series| {
        let xbar = algo.x_bar();
        let loss = src.global_loss(&xbar);
        series.push(RoundRecord {
            t,
            loss,
            test_error: src.test_error(&xbar).unwrap_or(f64::NAN),
            opt_gap: src.opt_gap(&xbar).unwrap_or(f64::NAN),
            bits: bus.total_bits,
            comm_rounds: bus.comm_rounds,
            consensus: algo.consensus_distance(),
            fired: algo.last_fired(),
        });
    };

    if start_t == 0 {
        evaluate(algo.as_ref(), problem.as_mut(), &bus, 0, &mut series);
    }
    for t in start_t..cfg.steps {
        algo.step(t, problem.as_mut(), &mut bus);
        let done = t + 1 == cfg.steps;
        if (t + 1) % cfg.eval_every.max(1) == 0 || done {
            evaluate(algo.as_ref(), problem.as_mut(), &bus, t + 1, &mut series);
        }
        if !done && opts.checkpoint_every > 0 && (t + 1) % opts.checkpoint_every == 0 {
            if let (Some(cp), Some(pp)) = (&ckpt_path, &partial_path) {
                let ck = checkpoint::snapshot(algo.as_ref(), t + 1, &bus);
                ck.save(cp).map_err(|e| format!("{}: {e}", cp.display()))?;
                series
                    .write_jsonl(pp)
                    .map_err(|e| format!("{}: {e}", pp.display()))?;
            }
        }
        if opts.fault_abort_at == Some(t + 1) && !done {
            let (fired, checks) = algo.fired_stats();
            return Ok(RunOutcome {
                id: slot.id.clone(),
                label: slot.label.clone(),
                cfg: cfg.clone(),
                series,
                fired,
                checks,
                wall_ms: run_start.elapsed().as_millis() as u64,
                skipped: false,
                completed: false,
            });
        }
    }

    // Complete: mid-run snapshots are superseded by the result record.
    if let Some(cp) = &ckpt_path {
        fs::remove_file(cp).ok();
    }
    if let Some(pp) = &partial_path {
        fs::remove_file(pp).ok();
    }
    let (fired, checks) = algo.fired_stats();
    Ok(RunOutcome {
        id: slot.id.clone(),
        label: slot.label.clone(),
        cfg: cfg.clone(),
        series,
        fired,
        checks,
        wall_ms: run_start.elapsed().as_millis() as u64,
        skipped: false,
        completed: true,
    })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::experiments::run_config;

    fn quick_cfg(seed: u64) -> ExperimentConfig {
        ExperimentConfig {
            name: format!("quick-{seed}"),
            nodes: 5,
            steps: 120,
            eval_every: 40,
            problem: "quadratic:16".into(),
            compressor: "sign_topk:25%".into(),
            trigger: "const:20".into(),
            seed,
            ..Default::default()
        }
    }

    #[test]
    fn sweep_run_equals_run_config() {
        let cfg = quick_cfg(3);
        let expect = run_config(&cfg, false);
        let cache = ArtifactCache::new();
        let report = run_configs(
            vec![("quick".into(), cfg)],
            &SweepOptions::default(),
            &cache,
        )
        .unwrap();
        assert_eq!(report.outcomes.len(), 1);
        assert_eq!(report.executed, 1);
        let got = &report.outcomes[0].series;
        assert_eq!(got.to_csv(), expect.to_csv());
    }

    #[test]
    fn budget_splits_into_run_and_node_workers() {
        // Pure scheduling property: any budget produces the same series.
        let mk = || vec![
            ("a".to_string(), quick_cfg(1)),
            ("b".to_string(), quick_cfg(2)),
        ];
        let cache = ArtifactCache::new();
        let serial = run_configs(mk(), &SweepOptions::default(), &cache).unwrap();
        let wide = run_configs(
            mk(),
            &SweepOptions {
                workers: 8,
                ..Default::default()
            },
            &cache,
        )
        .unwrap();
        for (a, b) in serial.outcomes.iter().zip(wide.outcomes.iter()) {
            assert_eq!(a.id, b.id);
            assert_eq!(a.series.to_csv(), b.series.to_csv());
        }
    }

    #[test]
    fn duplicate_configs_are_rejected_not_raced() {
        // Same semantic config under two labels hashes to one id — the
        // runs would race on the same series file, so the set is an error.
        let cache = ArtifactCache::new();
        let mut renamed = quick_cfg(1);
        renamed.name = "other-name".into();
        let err = run_configs(
            vec![("a".into(), quick_cfg(1)), ("b".into(), renamed)],
            &SweepOptions::default(),
            &cache,
        )
        .unwrap_err();
        assert!(err.contains("same config"), "{err}");
    }

    #[test]
    fn bad_config_surfaces_as_error_not_poison() {
        // expand() rejects bad specs, but run_configs can still receive a
        // config whose string specs fail at build time — builders panic,
        // which would poison the pool. Guard the easy case: zero steps is
        // legal and produces the t=0 record only.
        let mut cfg = quick_cfg(1);
        cfg.steps = 0;
        let cache = ArtifactCache::new();
        let report = run_configs(
            vec![("empty".into(), cfg)],
            &SweepOptions::default(),
            &cache,
        )
        .unwrap();
        assert_eq!(report.outcomes[0].series.records.len(), 1);
    }
}
