//! `sparq sweep report`: Fig-1 savings tables and CSV panels from a
//! sweep output directory, without re-running anything.
//!
//! Reads `<out>/results.jsonl` + `<out>/series/<id>.jsonl` (the
//! artifacts every sweep — serial or distributed — streams) and emits:
//!
//! * the Remark-4 savings table: per run, the communication rounds and
//!   cumulative bits at which it first reaches a target
//!   (`first_reaching_error` / `first_reaching_loss` applied offline),
//!   the savings factor relative to the first run that reaches it, the
//!   transmit rate, and any early-stop truncation;
//! * the four Figure-1 CSV panels (test error vs rounds, test error vs
//!   bits, loss vs iteration, loss vs bits) in long format, one row per
//!   evaluation record per run.
//!
//! All float cells use Rust's shortest-round-trip `Display`, so the
//! PR-3 non-finite encodings survive verbatim: a diverging run's `inf`
//! loss reads from the series as `f64::INFINITY` and is re-emitted as
//! the string "inf" (NaN as "NaN"). A committed fixture pins the table
//! and panels byte-for-byte (`rust/tests/sweep_report_golden.rs`).
//!
//! Merged result sets are well-defined: records are listed in file
//! order and a duplicated run id (possible only after a torn-series
//! re-run) resolves to the **last** record, matching the runner's
//! append-order semantics.

use std::collections::HashMap;
use std::fmt::Write as _;
use std::fs;
use std::path::{Path, PathBuf};

use crate::comm::FaultCounters;
use crate::metrics::{RoundRecord, Series};
use crate::util::json::Json;

use super::runner::{parse_fault, parse_truncated, EarlyStop};

/// Which record field a target applies to.
#[derive(Clone, Copy, Debug, PartialEq)]
pub enum TargetMetric {
    TestError,
    Loss,
}

impl TargetMetric {
    pub fn name(&self) -> &'static str {
        match self {
            TargetMetric::TestError => "test_error",
            TargetMetric::Loss => "loss",
        }
    }

    pub fn value(&self, r: &RoundRecord) -> f64 {
        match self {
            TargetMetric::TestError => r.test_error,
            TargetMetric::Loss => r.loss,
        }
    }
}

/// One completed run loaded back from a sweep output directory.
#[derive(Clone, Debug)]
pub struct ReportRun {
    pub id: String,
    pub name: String,
    pub label: String,
    pub algo: String,
    /// Algorithm family ("sparq" when the record predates families or
    /// ran the default composition).
    pub family: String,
    pub fired: u64,
    pub checks: u64,
    /// Fault-plan event totals (all zero unless the run's plan fired).
    pub fault: FaultCounters,
    /// Early-stop truncation recorded by the runner, if any.
    pub truncated: Option<EarlyStop>,
    pub series: Series,
}

impl ReportRun {
    /// First record reaching `metric <= target` (NaN never qualifies).
    pub fn first_reaching(&self, metric: TargetMetric, target: f64) -> Option<&RoundRecord> {
        self.series
            .records
            .iter()
            .find(|r| metric.value(r) <= target)
    }
}

/// Load every completed run from `<out>` (see module docs for ordering
/// and duplicate-id semantics).
pub fn load(out: &Path) -> Result<Vec<ReportRun>, String> {
    let results_path = out.join("results.jsonl");
    let text = fs::read_to_string(&results_path)
        .map_err(|e| format!("{}: {e}", results_path.display()))?;
    let series_dir = out.join("series");
    let mut runs: Vec<ReportRun> = Vec::new();
    let mut index: HashMap<String, usize> = HashMap::new();
    for (lineno, line) in text.lines().enumerate() {
        if line.trim().is_empty() {
            continue;
        }
        // Tolerate torn lines exactly like the distributed runner's
        // completed-index does (a killed appender or non-atomic
        // O_APPEND on a network filesystem can leave one behind, and
        // nothing ever compacts the append-only log) — warn and skip
        // rather than refusing to report the rest of the sweep.
        let j = match Json::parse(line) {
            Ok(j) => j,
            Err(e) => {
                eprintln!(
                    "[report] ignoring unparsable record {}:{}: {e}",
                    results_path.display(),
                    lineno + 1
                );
                continue;
            }
        };
        let Some(id) = j.get("id").and_then(Json::as_str).map(str::to_string) else {
            eprintln!(
                "[report] ignoring record without an id at {}:{}",
                results_path.display(),
                lineno + 1
            );
            continue;
        };
        let s = |k: &str, dflt: &str| -> String {
            j.get(k).and_then(Json::as_str).unwrap_or(dflt).to_string()
        };
        // Strict counters: a *missing* key reads as 0 (records written
        // before the key existed), but a damaged value — fractional,
        // negative, non-numeric — is a report error naming the run and
        // field, not a silent 0 that renders as a 0.0% transmit rate.
        let u = |k: &str| -> Result<u64, String> {
            match j.get(k) {
                None => Ok(0),
                Some(v) => v.as_u64().ok_or_else(|| {
                    format!(
                        "{}:{}: run {id} field {k:?} is not a non-negative integer",
                        results_path.display(),
                        lineno + 1
                    )
                }),
            }
        };
        let label = s("label", &id);
        let series_label = s("series_label", &label);
        let spath = series_dir.join(format!("{id}.jsonl"));
        let series = Series::read_jsonl(&spath, series_label)
            .map_err(|e| format!("{}: {e}", spath.display()))?;
        let run = ReportRun {
            name: s("name", &label),
            algo: s("algo", ""),
            family: s("family", "sparq"),
            fired: u("fired")?,
            checks: u("checks")?,
            fault: parse_fault(&j),
            truncated: parse_truncated(&j),
            series,
            label,
            id: id.clone(),
        };
        match index.get(&id) {
            Some(&i) => runs[i] = run, // duplicate id: last record wins
            None => {
                index.insert(id, runs.len());
                runs.push(run);
            }
        }
    }
    Ok(runs)
}

/// Render the Remark-4 savings table (see module docs). The savings
/// factor is each run's bits-to-target over the *first listed run that
/// reaches the target* — list SPARQ first (as the fig1 specs do) and
/// the column reads "how many times more bits the baseline spent".
pub fn savings_table(runs: &[ReportRun], metric: TargetMetric, target: f64) -> String {
    let mut out = String::new();
    let _ = writeln!(
        out,
        "# sweep report: {} runs, target {} <= {}",
        runs.len(),
        metric.name(),
        target
    );
    let _ = writeln!(
        out,
        "{:<38} {:>12} {:>16} {:>12} {:>9}",
        "run", "comm rounds", "bits to target", "savings", "tx rate"
    );
    let reference_bits = runs
        .iter()
        .find_map(|run| run.first_reaching(metric, target).map(|r| r.bits));
    for run in runs {
        let tx = format!("{:.1}%", 100.0 * run.fired as f64 / run.checks.max(1) as f64);
        let mut line = match run.first_reaching(metric, target) {
            Some(r) => {
                let factor = match reference_bits {
                    Some(rb) if rb > 0 => format!("{:.1}x", r.bits as f64 / rb as f64),
                    _ => "-".to_string(),
                };
                format!(
                    "{:<38} {:>12} {:>16} {:>12} {:>9}",
                    run.label, r.comm_rounds, r.bits, factor, tx
                )
            }
            None => format!(
                "{:<38} {:>12} {:>16} {:>12} {:>9}",
                run.label, "-", "(not reached)", "-", tx
            ),
        };
        // Chaos runs annotate their fault totals; fault-free lines are
        // unchanged (the golden fixture pins them byte-for-byte).
        if !run.fault.is_zero() {
            let _ = write!(
                line,
                "  faults crash={} resync={} corrupt={}",
                run.fault.crashes, run.fault.resyncs, run.fault.corrupt_discards
            );
        }
        if let Some(stop) = &run.truncated {
            let _ = write!(line, "  early-stop t={} ({})", stop.t, stop.reason);
        }
        let _ = writeln!(out, "{line}");
    }
    out
}

/// Render the cross-family comparison panel: one line per algorithm
/// family (first-seen order), aggregating that family's runs — best
/// (fewest) bits-to-target among runs that reach it, the comm rounds at
/// that crossing, and the pooled transmit rate Σfired / Σchecks. This is
/// the panel the family sweeps read to answer "does momentum triggering
/// (or per-coordinate firing) buy communication at this target?".
pub fn family_table(runs: &[ReportRun], metric: TargetMetric, target: f64) -> String {
    let mut out = String::new();
    let _ = writeln!(
        out,
        "# family comparison: target {} <= {}",
        metric.name(),
        target
    );
    let _ = writeln!(
        out,
        "{:<12} {:>6} {:>16} {:>12} {:>9}",
        "family", "runs", "bits to target", "comm rounds", "tx rate"
    );
    let mut order: Vec<&str> = Vec::new();
    let mut groups: HashMap<&str, Vec<&ReportRun>> = HashMap::new();
    for run in runs {
        let fam: &str = if run.family.is_empty() {
            "sparq"
        } else {
            &run.family
        };
        if !groups.contains_key(fam) {
            order.push(fam);
        }
        groups.entry(fam).or_default().push(run);
    }
    for fam in order {
        let g = &groups[fam];
        let best = g
            .iter()
            .filter_map(|r| {
                r.first_reaching(metric, target)
                    .map(|rec| (rec.bits, rec.comm_rounds))
            })
            .min();
        let (fired, checks) = g
            .iter()
            .fold((0u64, 0u64), |(f, c), r| (f + r.fired, c + r.checks));
        let tx = format!("{:.1}%", 100.0 * fired as f64 / checks.max(1) as f64);
        let line = match best {
            Some((bits, rounds)) => format!(
                "{:<12} {:>6} {:>16} {:>12} {:>9}",
                fam,
                g.len(),
                bits,
                rounds,
                tx
            ),
            None => format!(
                "{:<12} {:>6} {:>16} {:>12} {:>9}",
                fam,
                g.len(),
                "(not reached)",
                "-",
                tx
            ),
        };
        let _ = writeln!(out, "{line}");
    }
    out
}

/// RFC-4180 quoting for the CSV label column. Labels can legitimately
/// contain commas — an axis over `topology_schedule` yields labels like
/// "topology_schedule=switch:ring,torus:500" — which would otherwise
/// silently mis-column every row for that run.
fn csv_field(s: &str) -> String {
    if s.contains(',') || s.contains('"') || s.contains('\n') {
        format!("\"{}\"", s.replace('"', "\"\""))
    } else {
        s.to_string()
    }
}

/// The four Fig-1 CSV panels in long format, as (file name, content).
/// Float cells use `Display` (shortest round-trip; "inf"/"NaN" for
/// non-finite values — the same encodings the JSONL stores).
pub fn panels_csv(runs: &[ReportRun]) -> Vec<(&'static str, String)> {
    let mut a = String::from("label,t,comm_rounds,test_error\n");
    let mut b = String::from("label,t,bits,test_error\n");
    let mut c = String::from("label,t,loss\n");
    let mut d = String::from("label,t,bits,loss\n");
    for run in runs {
        let label = csv_field(&run.label);
        for r in &run.series.records {
            let _ = writeln!(a, "{label},{},{},{}", r.t, r.comm_rounds, r.test_error);
            let _ = writeln!(b, "{label},{},{},{}", r.t, r.bits, r.test_error);
            let _ = writeln!(c, "{label},{},{}", r.t, r.loss);
            let _ = writeln!(d, "{label},{},{},{}", r.t, r.bits, r.loss);
        }
    }
    vec![
        ("fig1a.csv", a),
        ("fig1b.csv", b),
        ("fig1c.csv", c),
        ("fig1d.csv", d),
    ]
}

/// Write the panels under `dir`, returning the written paths.
pub fn write_panels(runs: &[ReportRun], dir: &Path) -> Result<Vec<PathBuf>, String> {
    fs::create_dir_all(dir).map_err(|e| format!("{}: {e}", dir.display()))?;
    let mut paths = Vec::new();
    for (name, content) in panels_csv(runs) {
        let path = dir.join(name);
        fs::write(&path, content).map_err(|e| format!("{}: {e}", path.display()))?;
        paths.push(path);
    }
    Ok(paths)
}

#[cfg(test)]
mod tests {
    use super::*;

    fn run(label: &str, pts: &[(u64, f64, f64, u64, u64)]) -> ReportRun {
        let mut series = Series::new(label);
        for &(t, err, loss, bits, rounds) in pts {
            series.push(RoundRecord {
                t,
                loss,
                test_error: err,
                opt_gap: f64::NAN,
                bits,
                comm_rounds: rounds,
                consensus: 0.0,
                fired: 0,
            });
        }
        ReportRun {
            id: label.to_string(),
            name: label.to_string(),
            label: label.to_string(),
            algo: "sparq".into(),
            family: "sparq".into(),
            fired: 1,
            checks: 4,
            fault: FaultCounters::default(),
            truncated: None,
            series,
        }
    }

    #[test]
    fn family_table_groups_and_aggregates() {
        let mut a = run("sparq-1", &[(0, 0.9, 2.0, 0, 0), (10, 0.1, 1.0, 400, 5)]);
        a.fired = 2;
        let mut b = run("sparq-2", &[(0, 0.9, 2.0, 0, 0), (10, 0.1, 1.0, 300, 7)]);
        b.fired = 4;
        let mut c = run("squarm-1", &[(0, 0.9, 2.0, 0, 0), (10, 0.1, 1.0, 150, 9)]);
        c.family = "squarm:0.9".into();
        let never = {
            let mut r = run("percoord-1", &[(0, 0.9, 2.0, 0, 0)]);
            r.family = "percoord".into();
            r
        };
        let table = family_table(&[a, b, c, never], TargetMetric::TestError, 0.1);
        let lines: Vec<&str> = table.lines().collect();
        // header + column row + three family lines, first-seen order
        assert!(lines[0].starts_with("# family comparison"), "{table}");
        let sparq = lines.iter().find(|l| l.starts_with("sparq ")).unwrap();
        // best bits among the two sparq runs is 300; pooled tx = 6/8
        assert!(sparq.contains("300"), "{table}");
        assert!(sparq.contains("75.0%"), "{table}");
        let squarm = lines.iter().find(|l| l.starts_with("squarm:0.9")).unwrap();
        assert!(squarm.contains("150"), "{table}");
        let pc = lines.iter().find(|l| l.starts_with("percoord")).unwrap();
        assert!(pc.contains("(not reached)"), "{table}");
        // family order follows first appearance in the run list
        let is = |p: &str| lines.iter().position(|l| l.starts_with(p)).unwrap();
        assert!(is("sparq ") < is("squarm:0.9"));
        assert!(is("squarm:0.9") < is("percoord"));
    }

    #[test]
    fn savings_factor_is_relative_to_first_reaching_run() {
        let runs = vec![
            run("a", &[(0, 0.9, 2.0, 0, 0), (10, 0.1, 1.0, 100, 5)]),
            run("b", &[(0, 0.9, 2.0, 0, 0), (10, 0.1, 1.0, 2500, 10)]),
        ];
        let table = savings_table(&runs, TargetMetric::TestError, 0.1);
        assert!(table.contains("1.0x"), "{table}");
        assert!(table.contains("25.0x"), "{table}");
        assert!(table.contains("25.0%"), "tx rate: {table}");
    }

    #[test]
    fn unreached_target_renders_placeholder() {
        let runs = vec![run("never", &[(0, 0.9, 2.0, 0, 0)])];
        let table = savings_table(&runs, TargetMetric::TestError, 0.1);
        assert!(table.contains("(not reached)"), "{table}");
        // NaN metrics never qualify as reaching
        let runs = vec![run("nan", &[(0, f64::NAN, f64::NAN, 0, 0)])];
        let table = savings_table(&runs, TargetMetric::Loss, 10.0);
        assert!(table.contains("(not reached)"), "{table}");
    }

    #[test]
    fn fault_totals_annotate_only_chaos_lines() {
        let mut chaos = run("chaos", &[(0, 0.9, 2.0, 0, 0), (10, 0.1, 1.0, 100, 5)]);
        chaos.fault = FaultCounters {
            crashes: 2,
            resyncs: 3,
            corrupt_discards: 17,
        };
        let clean = run("clean", &[(0, 0.9, 2.0, 0, 0), (10, 0.1, 1.0, 100, 5)]);
        let table = savings_table(&[chaos, clean], TargetMetric::TestError, 0.1);
        let lines: Vec<&str> = table.lines().collect();
        let chaos_line = lines.iter().find(|l| l.starts_with("chaos")).unwrap();
        assert!(
            chaos_line.ends_with("faults crash=2 resync=3 corrupt=17"),
            "{table}"
        );
        let clean_line = lines.iter().find(|l| l.starts_with("clean")).unwrap();
        assert!(!clean_line.contains("faults"), "{table}");
    }

    #[test]
    fn labels_with_commas_are_csv_quoted() {
        let runs = vec![run(
            "topology_schedule=switch:ring,torus:500",
            &[(0, 0.9, 2.0, 0, 0)],
        )];
        let panels = panels_csv(&runs);
        let c = &panels.iter().find(|(n, _)| *n == "fig1c.csv").unwrap().1;
        assert!(
            c.contains("\"topology_schedule=switch:ring,torus:500\",0,2"),
            "{c}"
        );
        // plain labels stay unquoted; embedded quotes double
        assert_eq!(csv_field("plain label"), "plain label");
        assert_eq!(csv_field("a\"b"), "\"a\"\"b\"");
    }

    #[test]
    fn panels_encode_nonfinite_as_strings() {
        let runs = vec![run("x", &[(0, f64::NAN, f64::INFINITY, 0, 0)])];
        let panels = panels_csv(&runs);
        let c = &panels.iter().find(|(n, _)| *n == "fig1c.csv").unwrap().1;
        assert!(c.contains("x,0,inf"), "{c}");
        let a = &panels.iter().find(|(n, _)| *n == "fig1a.csv").unwrap().1;
        assert!(a.contains("x,0,0,NaN"), "{a}");
    }
}
