//! Declarative sweep engine with concurrent run scheduling.
//!
//! The paper's evaluation is inherently a *sweep*: Figure 1 and the
//! Remark-4 savings comparison vary trigger thresholds, H, compression
//! operators, and topologies across many otherwise-identical runs, and
//! related work widens the grids further (Qsparse-local-SGD sweeps
//! synchronization schedules, EventGraD sweeps event thresholds). This
//! module replaces the experiment drivers' hand-rolled serial loops with
//! one engine:
//!
//! * [`SweepSpec`] — a declarative grid: a base [`ExperimentConfig`]
//!   (`config::ExperimentConfig`), a list of *variants* (named partial
//!   overrides — the "five curves of Fig 1"), and *axes* (field →
//!   value-list cross products — seeds, H, thresholds). JSON on disk or
//!   the builder API in code; expansion validates every field through
//!   `ExperimentConfig::from_json`, so a typo'd axis name is an error,
//!   not a silently ignored knob.
//! * [`ArtifactCache`] — cross-run sharing of cacheable construction
//!   artifacts: topology/mixing matrices, the eigen solve behind the
//!   tuned consensus step size γ (one solve per distinct graph instead
//!   of one per run), and synthetic dataset shards keyed by
//!   (problem, nodes, seed).
//! * [`run_configs`] / [`run_spec`] — concurrent execution on
//!   `util::ThreadPool` with a **total worker budget**: run-level
//!   parallelism layered over the engine's per-node parallelism
//!   (budget W over R pending runs ⇒ min(W, R) concurrent runs, each
//!   stepping with ⌊W / min(W, R)⌋ node workers). Results are
//!   **bit-for-bit identical for any budget** — each run owns its RNG
//!   streams, and node-worker counts don't affect results
//!   (`rust/tests/sparse_parallel.rs`); `rust/tests/sweep_system.rs`
//!   pins the sweep-level guarantee at workers = 1 vs 8.
//! * **Streaming results + resume.** With an output directory, each
//!   completed run appends one JSONL record to `results.jsonl` and
//!   writes its full `metrics::Series` to `series/<id>.jsonl`, where
//!   `<id>` is [`config_hash`] of the expanded config (name- and
//!   worker-normalized). `--resume` skips any run whose record already
//!   exists, loading its stored series instead; long runs additionally
//!   snapshot mid-run via `coordinator::checkpoint` (`checkpoint_every`)
//!   and resume from the snapshot **bit-for-bit**.
//!
//! The five experiment drivers (`experiments::{fig1, savings, rates,
//! ablation, robustness}`) are now thin declarative specs over this
//! engine. EXPERIMENTS.md §Sweep documents the spec format, resume
//! semantics, and the wall-clock measurement protocol.
//!
//! Two layers scale the engine beyond one process (ISSUE 4):
//!
//! * [`distributed`] — N `sparq sweep --distributed` processes (or
//!   machines on a shared filesystem) split one grid via advisory
//!   per-run-id claim files: create-exclusive acquisition, heartbeat
//!   lease refresh, stale-claim takeover after a configurable lease,
//!   crash-safe because completed runs are detected from
//!   `results.jsonl` and half-finished ones resume from checkpoints
//!   exactly as `--resume` does. Per-run series remain bit-for-bit
//!   identical to a serial sweep however the grid is split.
//! * **Adaptive budgets** — a spec-declared `target_error` /
//!   `target_loss` early-stops each run at the first evaluation record
//!   reaching the target; the truncation is recorded in the result
//!   record, the truncated series is a bit-exact prefix of the
//!   untruncated run, and the freed worker immediately picks up the
//!   next pending run.
//!
//! [`report`] renders the Fig-1 savings tables and CSV panels from a
//! sweep output directory without re-running anything
//! (`sparq sweep report`).

pub mod cache;
pub mod distributed;
pub mod report;
pub mod runner;
pub mod spec;

pub use cache::ArtifactCache;
pub use distributed::{
    list_claims, now_secs, run_distributed, status_table, Acquire, Claim, ClaimInfo, ClaimStore,
    DistributedOptions,
};
pub use runner::{
    run_configs, run_spec, EarlyStop, EventHook, RunEvent, RunOutcome, SweepOptions, SweepReport,
};
pub use spec::{config_hash, SweepSpec};
