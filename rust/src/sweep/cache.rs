//! Cross-run artifact sharing for sweeps.
//!
//! A sweep's runs are mostly identical: thirty runs over seeds and
//! trigger thresholds all build the same ring, eigen-solve the same
//! mixing matrix for the tuned consensus step size γ, and synthesize the
//! same dataset shards. [`ArtifactCache`] memoizes those constructions
//! behind mutexes so concurrent runs share them:
//!
//! * **mixing matrices** keyed by (topology-schedule spec | topology,
//!   nodes, seed) — the schedule's initial matrix for non-static specs;
//! * **spectral info** (the eigen solve behind `gamma_tuned`) keyed the
//!   same way — one solve per distinct graph instead of per run (dense
//!   O(n³) Jacobi at n ≤ 256, sparse O(|E|)-matvec Lanczos above —
//!   `graph::spectral`);
//! * **dataset shards** keyed by (problem spec, nodes, seed) — the
//!   generated `Partition` + test set for logreg/mlp, the whole problem
//!   for quadratics.
//!
//! Caching is *transparent*: every cached value is exactly what the
//! uncached construction path produces for the same key (generation is
//! seeded and deterministic), so cached and uncached runs are bit-for-bit
//! identical — `experiments::builder` tests pin this. Hit/miss counters
//! are exposed for those tests and the CLI summary.

use std::collections::HashMap;
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::Mutex;

use crate::config::ExperimentConfig;
use crate::data::{Dataset, Partition};
use crate::graph::{MixingMatrix, SpectralInfo};
use crate::problems::QuadraticProblem;

/// Key for topology-derived artifacts: (schedule-or-topology spec,
/// nodes, seed). The schedule spec dominates when non-static, because it
/// names its own graphs.
type TopoKey = (String, usize, u64);
/// Key for dataset artifacts: (problem spec, nodes, seed).
type DataKey = (String, usize, u64);

/// Cached synthetic data for one (problem, nodes, seed) key.
#[derive(Clone)]
pub enum CachedData {
    /// Quadratic problems are cheap plain data — cache the problem whole.
    Quadratic(QuadraticProblem),
    /// Classification problems: the generated shards + shared test set
    /// (the per-run problem object wraps clones of these).
    Shards { part: Partition, test: Dataset },
}

#[derive(Default)]
struct Counters {
    hits: AtomicU64,
    misses: AtomicU64,
}

impl Counters {
    fn hit(&self) {
        self.hits.fetch_add(1, Ordering::Relaxed);
    }
    fn miss(&self) {
        self.misses.fetch_add(1, Ordering::Relaxed);
    }
    fn read(&self) -> (u64, u64) {
        (
            self.hits.load(Ordering::Relaxed),
            self.misses.load(Ordering::Relaxed),
        )
    }
}

/// Shared, thread-safe construction cache (see module docs).
#[derive(Default)]
pub struct ArtifactCache {
    mixing: Mutex<HashMap<TopoKey, MixingMatrix>>,
    spectral: Mutex<HashMap<TopoKey, SpectralInfo>>,
    data: Mutex<HashMap<DataKey, CachedData>>,
    mixing_stats: Counters,
    spectral_stats: Counters,
    data_stats: Counters,
}

impl ArtifactCache {
    pub fn new() -> ArtifactCache {
        ArtifactCache::default()
    }

    /// The topology key for a config (schedule spec dominates when it
    /// names its own graphs).
    pub fn topo_key(cfg: &ExperimentConfig) -> TopoKey {
        let spec = if cfg.topology_schedule.is_static() {
            format!("static:{}", cfg.topology)
        } else {
            cfg.topology_schedule.to_string()
        };
        (spec, cfg.nodes, cfg.seed)
    }

    /// Memoized mixing-matrix construction.
    pub fn mixing_or_else(
        &self,
        key: TopoKey,
        build: impl FnOnce() -> MixingMatrix,
    ) -> MixingMatrix {
        let mut map = self.mixing.lock().unwrap();
        if let Some(m) = map.get(&key) {
            self.mixing_stats.hit();
            return m.clone();
        }
        self.mixing_stats.miss();
        let m = build();
        map.insert(key, m.clone());
        m
    }

    /// Memoized eigen solve of a mixing matrix. The caller passes the
    /// matrix it already holds for the same key, so a miss never
    /// re-derives the graph.
    pub fn spectral_or_compute(&self, key: TopoKey, mixing: &MixingMatrix) -> SpectralInfo {
        let mut map = self.spectral.lock().unwrap();
        if let Some(s) = map.get(&key) {
            self.spectral_stats.hit();
            return *s;
        }
        self.spectral_stats.miss();
        let s = SpectralInfo::compute(mixing);
        map.insert(key, s);
        s
    }

    /// Memoized dataset synthesis.
    pub fn data_or_else(
        &self,
        key: DataKey,
        build: impl FnOnce() -> CachedData,
    ) -> CachedData {
        let mut map = self.data.lock().unwrap();
        if let Some(d) = map.get(&key) {
            self.data_stats.hit();
            return d.clone();
        }
        self.data_stats.miss();
        let d = build();
        map.insert(key, d.clone());
        d
    }

    /// (hits, misses) per cache, for tests and the CLI summary.
    pub fn mixing_stats(&self) -> (u64, u64) {
        self.mixing_stats.read()
    }
    pub fn spectral_stats(&self) -> (u64, u64) {
        self.spectral_stats.read()
    }
    pub fn data_stats(&self) -> (u64, u64) {
        self.data_stats.read()
    }

    /// One-line summary for logs: "mixing 4/1, spectral 4/1, data 3/2"
    /// (hits/misses).
    pub fn summary(&self) -> String {
        let (mh, mm) = self.mixing_stats();
        let (sh, sm) = self.spectral_stats();
        let (dh, dm) = self.data_stats();
        format!("mixing {mh}/{mm}, spectral {sh}/{sm}, data {dh}/{dm} (hits/misses)")
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::graph::{uniform_neighbor, Topology, TopologyKind};

    #[test]
    fn mixing_and_spectral_memoize_per_key() {
        let cache = ArtifactCache::new();
        let build = || uniform_neighbor(&Topology::new(TopologyKind::Ring, 8, 0));
        let key = ("static:ring".to_string(), 8usize, 0u64);
        let a = cache.mixing_or_else(key.clone(), build);
        let b = cache.mixing_or_else(key.clone(), || panic!("must hit the cache"));
        assert_eq!(a.topology.neighbors, b.topology.neighbors);
        assert_eq!(cache.mixing_stats(), (1, 1));

        let sa = cache.spectral_or_compute(key.clone(), &a);
        let sb = cache.spectral_or_compute(key, &a);
        assert_eq!(sa.delta, sb.delta);
        assert_eq!(cache.spectral_stats(), (1, 1));

        // a different key is a fresh miss
        let key2 = ("static:complete".to_string(), 8usize, 0u64);
        cache.mixing_or_else(key2, || {
            uniform_neighbor(&Topology::new(TopologyKind::Complete, 8, 0))
        });
        assert_eq!(cache.mixing_stats(), (1, 2));
    }

    #[test]
    fn topo_key_prefers_schedule_spec() {
        let cfg = ExperimentConfig::default();
        assert_eq!(
            ArtifactCache::topo_key(&cfg),
            ("static:ring".to_string(), 8, 42)
        );
        let cfg = ExperimentConfig {
            topology_schedule: "switch:ring,torus:100".into(),
            nodes: 16,
            ..Default::default()
        };
        assert_eq!(
            ArtifactCache::topo_key(&cfg),
            ("switch:ring,torus:100".to_string(), 16, 42)
        );
    }
}
