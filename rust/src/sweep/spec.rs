//! The declarative sweep grid: variants × axes over an `ExperimentConfig`
//! base, plus the stable config hash that keys resume.
//!
//! JSON form (see `examples/specs/`):
//!
//! ```json
//! {
//!   "name": "fig1-convex",
//!   "base": { "nodes": 60, "problem": "logreg:784:10:5", "steps": 3000 },
//!   "variants": [
//!     { "label": "SPARQ-SGD (SignTopK)", "algo": "sparq" },
//!     { "label": "CHOCO-SGD (Sign)", "algo": "choco", "compressor": "sign" }
//!   ],
//!   "axes": { "seed": [1, 2, 3] }
//! }
//! ```
//!
//! Expansion order is deterministic: variants in listed order, then the
//! axes cross product with keys in sorted order and the last key varying
//! fastest. Every expanded object round-trips through
//! `ExperimentConfig::from_json`, so unknown fields and ill-typed values
//! are rejected with the config parser's messages.

use crate::config::ExperimentConfig;
use crate::util::json::Json;

/// Variant keys that are spec metadata, not config fields.
const VARIANT_META_KEYS: &[&str] = &["label"];

/// A declarative sweep: base config + variants + axes (see module docs),
/// plus sweep-level execution policy: early-stop targets (adaptive
/// budgets) and the distributed claim lease.
#[derive(Clone, Debug)]
pub struct SweepSpec {
    pub name: String,
    /// Base config fields (JSON object; may be empty — defaults apply).
    base: Json,
    /// Partial-override objects, one per variant ("label" names the
    /// curve). An empty list means a single all-defaults variant.
    variants: Vec<Json>,
    /// (field, values) cross-product axes, sorted by field name.
    axes: Vec<(String, Vec<Json>)>,
    /// Early-stop every run at the first evaluation record with
    /// `test_error <= target_error` (must lie in (0, 1]). Targets are
    /// execution policy, not config: they do not enter [`config_hash`],
    /// so adding one never forces re-runs — truncation is recorded in
    /// the result record instead.
    pub target_error: Option<f64>,
    /// Early-stop every run at the first record with `loss <=
    /// target_loss` (any finite value).
    pub target_loss: Option<f64>,
    /// Stale-claim takeover lease for `--distributed` execution
    /// (seconds, > 0).
    pub lease_secs: Option<f64>,
    /// Clock-skew allowance added to the lease before takeover
    /// (seconds, >= 0; defaults to the CLI's 2s when unset).
    pub lease_margin_secs: Option<f64>,
}

impl SweepSpec {
    /// Empty spec (all-defaults base, one variant, no axes).
    pub fn new(name: impl Into<String>) -> SweepSpec {
        SweepSpec {
            name: name.into(),
            base: Json::obj(),
            variants: Vec::new(),
            axes: Vec::new(),
            target_error: None,
            target_loss: None,
            lease_secs: None,
            lease_margin_secs: None,
        }
    }

    /// Set the early-stop test-error target (builder API).
    pub fn target_error(mut self, target: f64) -> Self {
        self.target_error = Some(target);
        self
    }

    /// Set the early-stop loss target (builder API).
    pub fn target_loss(mut self, target: f64) -> Self {
        self.target_loss = Some(target);
        self
    }

    /// Set the distributed claim lease (builder API).
    pub fn lease_secs(mut self, secs: f64) -> Self {
        self.lease_secs = Some(secs);
        self
    }

    /// Set the distributed clock-skew lease margin (builder API).
    pub fn lease_margin_secs(mut self, secs: f64) -> Self {
        self.lease_margin_secs = Some(secs);
        self
    }

    /// Copy the spec's early-stop targets into a [`SweepOptions`] clone,
    /// keeping any target the options already pin (CLI overrides win).
    pub fn apply_targets(&self, opts: &crate::sweep::SweepOptions) -> crate::sweep::SweepOptions {
        let mut opts = opts.clone();
        if opts.target_error.is_none() {
            opts.target_error = self.target_error;
        }
        if opts.target_loss.is_none() {
            opts.target_loss = self.target_loss;
        }
        opts
    }

    /// Set the base config (builder API).
    pub fn base(mut self, cfg: &ExperimentConfig) -> Self {
        self.base = cfg.to_json();
        self
    }

    /// Add a cross-product axis over a config field.
    pub fn axis(mut self, field: impl Into<String>, values: Vec<Json>) -> Self {
        let field = field.into();
        self.axes.retain(|(k, _)| *k != field);
        self.axes.push((field, values));
        self.axes.sort_by(|a, b| a.0.cmp(&b.0));
        self
    }

    /// String-valued axis convenience.
    pub fn axis_str(self, field: &str, values: &[&str]) -> Self {
        self.axis(field, values.iter().map(|v| Json::from(*v)).collect())
    }

    /// Integer-valued axis convenience.
    pub fn axis_u64(self, field: &str, values: &[u64]) -> Self {
        self.axis(field, values.iter().map(|&v| Json::from(v)).collect())
    }

    /// Add a labelled variant (partial config override).
    pub fn variant(mut self, label: &str, overrides: &[(&str, Json)]) -> Self {
        let mut obj = Json::obj().set("label", label);
        for (k, v) in overrides {
            obj = obj.set(k, v.clone());
        }
        self.variants.push(obj);
        self
    }

    /// Parse a spec from its JSON form.
    pub fn from_json(j: &Json) -> Result<SweepSpec, String> {
        let obj = j
            .as_obj()
            .ok_or_else(|| "sweep spec must be a JSON object".to_string())?;
        for key in obj.keys() {
            if ![
                "name",
                "base",
                "variants",
                "axes",
                "target_error",
                "target_loss",
                "lease_secs",
                "lease_margin_secs",
            ]
            .contains(&key.as_str())
            {
                return Err(format!(
                    "unknown sweep spec key {key:?}; valid keys: name, base, variants, axes, \
                     target_error, target_loss, lease_secs, lease_margin_secs"
                ));
            }
        }
        let opt_f64 = |k: &str| -> Result<Option<f64>, String> {
            match j.get(k) {
                None => Ok(None),
                Some(v) => v
                    .as_f64()
                    .map(Some)
                    .ok_or_else(|| format!("sweep spec key {k:?} must be a number")),
            }
        };
        let name = match j.get("name") {
            None => "sweep".to_string(),
            Some(v) => v
                .as_str()
                .ok_or("sweep spec name must be a string")?
                .to_string(),
        };
        let base = match j.get("base") {
            None => Json::obj(),
            Some(v) => {
                v.as_obj().ok_or("sweep spec base must be an object")?;
                v.clone()
            }
        };
        let mut variants = Vec::new();
        if let Some(v) = j.get("variants") {
            let arr = v.as_arr().ok_or("sweep spec variants must be an array")?;
            for item in arr {
                item.as_obj()
                    .ok_or("each sweep variant must be an object")?;
                variants.push(item.clone());
            }
        }
        let mut axes = Vec::new();
        if let Some(a) = j.get("axes") {
            let m = a.as_obj().ok_or("sweep spec axes must be an object")?;
            for (k, v) in m {
                let values = v
                    .as_arr()
                    .ok_or_else(|| format!("axis {k:?} must be an array of values"))?;
                if values.is_empty() {
                    return Err(format!("axis {k:?} has no values"));
                }
                axes.push((k.clone(), values.to_vec()));
            }
            // BTreeMap iteration is already sorted; keep the invariant
            // explicit for the builder path too.
            axes.sort_by(|a, b| a.0.cmp(&b.0));
        }
        let spec = SweepSpec {
            name,
            base,
            variants,
            axes,
            target_error: opt_f64("target_error")?,
            target_loss: opt_f64("target_loss")?,
            lease_secs: opt_f64("lease_secs")?,
            lease_margin_secs: opt_f64("lease_margin_secs")?,
        };
        spec.validate()?;
        Ok(spec)
    }

    pub fn from_file(path: &str) -> Result<SweepSpec, String> {
        let text = std::fs::read_to_string(path).map_err(|e| format!("{path}: {e}"))?;
        let j = Json::parse(&text).map_err(|e| format!("{path}: {e}"))?;
        Self::from_json(&j)
    }

    /// The spec's JSON form (round-trips through [`from_json`]).
    pub fn to_json(&self) -> Json {
        let mut axes = Json::obj();
        for (k, v) in &self.axes {
            axes = axes.set(k, Json::Arr(v.clone()));
        }
        let mut out = Json::obj()
            .set("name", self.name.as_str())
            .set("base", self.base.clone())
            .set("variants", Json::Arr(self.variants.clone()))
            .set("axes", axes);
        if let Some(t) = self.target_error {
            out = out.set("target_error", t);
        }
        if let Some(t) = self.target_loss {
            out = out.set("target_loss", t);
        }
        if let Some(l) = self.lease_secs {
            out = out.set("lease_secs", l);
        }
        if let Some(m) = self.lease_margin_secs {
            out = out.set("lease_margin_secs", m);
        }
        out
    }

    fn validate(&self) -> Result<(), String> {
        if let Some(t) = self.target_error {
            if !(t.is_finite() && t > 0.0 && t <= 1.0) {
                return Err(format!(
                    "target_error must lie in (0, 1] (test error is a rate), got {t}"
                ));
            }
        }
        if let Some(t) = self.target_loss {
            if !t.is_finite() {
                return Err(format!("target_loss must be finite, got {t}"));
            }
        }
        if let Some(l) = self.lease_secs {
            if !(l.is_finite() && l > 0.0) {
                return Err(format!(
                    "lease_secs must be a positive number of seconds, got {l}"
                ));
            }
        }
        if let Some(m) = self.lease_margin_secs {
            if !(m.is_finite() && m >= 0.0) {
                return Err(format!(
                    "lease_margin_secs must be a non-negative number of seconds, got {m}"
                ));
            }
        }
        for (k, values) in &self.axes {
            if k == "name" || k == "workers" {
                return Err(format!(
                    "axis {k:?} is not sweepable ({k} does not change run results)"
                ));
            }
            if !ExperimentConfig::KEYS.contains(&k.as_str()) {
                return Err(format!(
                    "unknown axis {k:?}; valid config fields: {}",
                    ExperimentConfig::KEYS.join(", ")
                ));
            }
            if values.is_empty() {
                return Err(format!("axis {k:?} has no values"));
            }
            for (i, v) in values.iter().enumerate() {
                if values[..i].contains(v) {
                    return Err(format!(
                        "axis {k:?} lists the value {} twice — duplicate grid \
                         points share a result id and would race on resume",
                        json_value_label(v)
                    ));
                }
            }
        }
        for variant in &self.variants {
            for key in variant.as_obj().expect("validated object").keys() {
                if !VARIANT_META_KEYS.contains(&key.as_str())
                    && !ExperimentConfig::KEYS.contains(&key.as_str())
                {
                    return Err(format!(
                        "unknown variant key {key:?}; valid: label, {}",
                        ExperimentConfig::KEYS.join(", ")
                    ));
                }
            }
        }
        Ok(())
    }

    /// Number of runs the spec expands to.
    pub fn len(&self) -> usize {
        let per_variant: usize = self.axes.iter().map(|(_, v)| v.len()).product();
        self.variants.len().max(1) * per_variant
    }

    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }

    /// Expand into the labelled run set (deterministic order; see module
    /// docs). Each run's `name` is unique within the spec.
    pub fn expand(&self) -> Result<Vec<(String, ExperimentConfig)>, String> {
        self.validate()?;
        let one_variant = [Json::obj()];
        let variants: &[Json] = if self.variants.is_empty() {
            &one_variant
        } else {
            &self.variants
        };
        let mut out = Vec::with_capacity(self.len());
        for (vi, variant) in variants.iter().enumerate() {
            let vmap = variant.as_obj().expect("validated object");
            let vlabel = vmap.get("label").and_then(Json::as_str).map(str::to_string);
            // A variant-provided "name" becomes the run-name stem (axis
            // parts still append, keeping names unique); otherwise names
            // derive from the spec name + variant label. The base's
            // "name" never survives — it would collide across runs.
            let vname = vmap.get("name").and_then(Json::as_str).map(str::to_string);
            // odometer over the axes cross product, last axis fastest
            let mut idx = vec![0usize; self.axes.len()];
            loop {
                let mut obj = self
                    .base
                    .as_obj()
                    .cloned()
                    .unwrap_or_default();
                for (k, v) in vmap {
                    if !VARIANT_META_KEYS.contains(&k.as_str()) {
                        obj.insert(k.clone(), v.clone());
                    }
                }
                let mut axis_parts = Vec::with_capacity(self.axes.len());
                for (ai, (k, values)) in self.axes.iter().enumerate() {
                    let v = &values[idx[ai]];
                    axis_parts.push(format!("{k}={}", json_value_label(v)));
                    obj.insert(k.clone(), v.clone());
                }
                let mut name_parts = match &vname {
                    Some(n) => vec![n.clone()],
                    None => {
                        let mut parts = vec![self.name.clone()];
                        match &vlabel {
                            Some(l) => parts.push(l.clone()),
                            None if variants.len() > 1 => parts.push(format!("v{vi}")),
                            None => {}
                        }
                        parts
                    }
                };
                if !axis_parts.is_empty() {
                    name_parts.push(axis_parts.join(","));
                }
                let name = name_parts.join("/");
                obj.insert("name".into(), Json::Str(name.clone()));
                let cfg = ExperimentConfig::from_json(&Json::Obj(obj))
                    .map_err(|e| format!("run {name:?}: {e}"))?;
                let label = vlabel.clone().unwrap_or_else(|| name.clone());
                out.push((label, cfg));

                // advance the odometer
                let mut pos = self.axes.len();
                loop {
                    if pos == 0 {
                        break;
                    }
                    pos -= 1;
                    idx[pos] += 1;
                    if idx[pos] < self.axes[pos].1.len() {
                        break;
                    }
                    idx[pos] = 0;
                }
                if self.axes.is_empty() || idx.iter().all(|&i| i == 0) {
                    break;
                }
            }
        }
        Ok(out)
    }
}

/// Render an axis value for run names ("h=5", "trigger=const:50").
fn json_value_label(v: &Json) -> String {
    match v {
        Json::Str(s) => s.clone(),
        other => other.to_string(),
    }
}

/// Stable identity of an expanded config, used to key resume records and
/// series files. `name` and `workers` are normalized out: neither changes
/// run results (worker-count invariance is pinned by
/// `rust/tests/sparse_parallel.rs`), so relabelling a run or changing the
/// sweep budget must not force a re-run.
pub fn config_hash(cfg: &ExperimentConfig) -> String {
    let mut canonical = cfg.clone();
    canonical.name = String::new();
    canonical.workers = 1;
    // Deployment knobs can't change results either: a cluster run is
    // pinned bit-identical to the in-process engine, so the same
    // experiment hashes the same however it is executed.
    canonical.cluster = crate::config::ClusterSpec::default();
    let text = canonical.to_json().to_string();
    format!("{:016x}", fnv64(text.as_bytes()))
}

/// FNV-1a 64 over a byte string (run ids above, serve job ids).
pub(crate) fn fnv64(bytes: &[u8]) -> u64 {
    let mut h: u64 = 0xcbf2_9ce4_8422_2325;
    for &b in bytes {
        h ^= b as u64;
        h = h.wrapping_mul(0x0000_0100_0000_01b3);
    }
    h
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::Algo;

    #[test]
    fn expands_cross_product_in_deterministic_order() {
        let spec = SweepSpec::new("grid")
            .base(&ExperimentConfig::default())
            .axis_u64("h", &[1, 5])
            .axis_u64("seed", &[7, 8]);
        let runs = spec.expand().unwrap();
        assert_eq!(runs.len(), 4);
        assert_eq!(spec.len(), 4);
        // axes sorted (h before seed), last key fastest
        let names: Vec<&str> = runs.iter().map(|(_, c)| c.name.as_str()).collect();
        assert_eq!(
            names,
            vec![
                "grid/h=1,seed=7",
                "grid/h=1,seed=8",
                "grid/h=5,seed=7",
                "grid/h=5,seed=8"
            ]
        );
        assert_eq!(runs[2].1.h.period(), Some(5));
        assert_eq!(runs[2].1.seed, 7);
    }

    #[test]
    fn variants_expand_with_labels_and_overrides() {
        let spec = SweepSpec::new("fig")
            .base(&ExperimentConfig::default())
            .variant("sparq", &[("algo", Json::from("sparq"))])
            .variant(
                "choco-sign",
                &[("algo", Json::from("choco")), ("compressor", Json::from("sign"))],
            )
            .axis_u64("seed", &[1]);
        let runs = spec.expand().unwrap();
        assert_eq!(runs.len(), 2);
        assert_eq!(runs[0].0, "sparq");
        assert_eq!(runs[1].0, "choco-sign");
        assert_eq!(runs[1].1.algo, Algo::Choco);
        assert_eq!(runs[1].1.compressor, "sign");
        assert_eq!(runs[1].1.name, "fig/choco-sign/seed=1");
    }

    #[test]
    fn json_roundtrip_and_validation() {
        let j = Json::parse(
            r#"{
                "name": "smoke",
                "base": {"problem": "quadratic:16", "nodes": 4, "steps": 50},
                "axes": {"seed": [1, 2], "h": [1, 5]}
            }"#,
        )
        .unwrap();
        let spec = SweepSpec::from_json(&j).unwrap();
        assert_eq!(spec.len(), 4);
        let back = SweepSpec::from_json(&spec.to_json()).unwrap();
        assert_eq!(back.len(), 4);
        assert_eq!(
            back.expand().unwrap().iter().map(|(_, c)| c.name.clone()).collect::<Vec<_>>(),
            spec.expand().unwrap().iter().map(|(_, c)| c.name.clone()).collect::<Vec<_>>()
        );

        // typo'd axis is an error, not an ignored knob
        let j = Json::parse(r#"{"axes": {"trigerr": ["const:5"]}}"#).unwrap();
        let err = SweepSpec::from_json(&j).unwrap_err();
        assert!(err.contains("trigerr"), "{err}");
        // non-sweepable axes rejected
        let j = Json::parse(r#"{"axes": {"workers": [1, 8]}}"#).unwrap();
        assert!(SweepSpec::from_json(&j).is_err());
        // bad value types surface the config parser's message
        let j = Json::parse(r#"{"axes": {"steps": [-5]}}"#).unwrap();
        let err = SweepSpec::from_json(&j).unwrap().expand().unwrap_err();
        assert!(err.contains("non-negative"), "{err}");
        // unknown variant keys rejected
        let j = Json::parse(r#"{"variants": [{"lable": "x"}]}"#).unwrap();
        assert!(SweepSpec::from_json(&j).is_err());
        // duplicate axis values would collide on the result id — rejected
        let j = Json::parse(r#"{"axes": {"seed": [1, 2, 1]}}"#).unwrap();
        let err = SweepSpec::from_json(&j).unwrap_err();
        assert!(err.contains("twice"), "{err}");
        // an empty axis is rejected on the builder path too (from_json
        // catches it at parse; expand's validation catches builder use)
        let spec = SweepSpec::new("x").axis("seed", Vec::new());
        let err = spec.expand().unwrap_err();
        assert!(err.contains("no values"), "{err}");
    }

    #[test]
    fn empty_spec_is_one_default_run() {
        let runs = SweepSpec::new("solo").expand().unwrap();
        assert_eq!(runs.len(), 1);
        assert_eq!(runs[0].1.name, "solo");
        assert_eq!(runs[0].1, ExperimentConfig {
            name: "solo".into(),
            ..Default::default()
        });
    }

    #[test]
    fn targets_and_lease_roundtrip_and_validate() {
        let j = Json::parse(
            r#"{"target_error": 0.15, "target_loss": 0.5, "lease_secs": 30,
                "lease_margin_secs": 3}"#,
        )
        .unwrap();
        let spec = SweepSpec::from_json(&j).unwrap();
        assert_eq!(spec.target_error, Some(0.15));
        assert_eq!(spec.target_loss, Some(0.5));
        assert_eq!(spec.lease_secs, Some(30.0));
        assert_eq!(spec.lease_margin_secs, Some(3.0));
        let back = SweepSpec::from_json(&spec.to_json()).unwrap();
        assert_eq!(back.target_error, Some(0.15));
        assert_eq!(back.lease_secs, Some(30.0));
        assert_eq!(back.lease_margin_secs, Some(3.0));
        // a spec without them round-trips without them (old specs load
        // unchanged)
        let plain = SweepSpec::from_json(&SweepSpec::new("x").to_json()).unwrap();
        assert_eq!(plain.target_error, None);
        assert_eq!(plain.lease_secs, None);

        for bad in [
            r#"{"target_error": 0}"#,
            r#"{"target_error": 1.5}"#,
            r#"{"target_error": -0.1}"#,
            r#"{"lease_secs": 0}"#,
            r#"{"lease_secs": -5}"#,
            r#"{"lease_margin_secs": -1}"#,
            r#"{"target_loss": "low"}"#,
        ] {
            let j = Json::parse(bad).unwrap();
            assert!(SweepSpec::from_json(&j).is_err(), "{bad}");
        }

        // spec targets fill options only where the options are unset
        use crate::sweep::SweepOptions;
        let spec = SweepSpec::new("t").target_error(0.2).target_loss(0.9);
        let opts = spec.apply_targets(&SweepOptions::default());
        assert_eq!(opts.target_error, Some(0.2));
        assert_eq!(opts.target_loss, Some(0.9));
        let pinned = SweepOptions {
            target_error: Some(0.05),
            ..Default::default()
        };
        let opts = spec.apply_targets(&pinned);
        assert_eq!(opts.target_error, Some(0.05));
        assert_eq!(opts.target_loss, Some(0.9));
    }

    #[test]
    fn config_hash_ignores_name_and_workers_only() {
        let a = ExperimentConfig::default();
        let mut b = a.clone();
        b.name = "renamed".into();
        b.workers = 8;
        assert_eq!(config_hash(&a), config_hash(&b));
        let mut c = a.clone();
        c.seed = 43;
        assert_ne!(config_hash(&a), config_hash(&c));
        let mut d = a.clone();
        d.trigger = "const:99".into();
        assert_ne!(config_hash(&a), config_hash(&d));
        assert_eq!(config_hash(&a).len(), 16);
    }
}
