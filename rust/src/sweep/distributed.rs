//! Distributed multi-process sweep execution over a shared filesystem.
//!
//! N independent `sparq sweep --distributed` processes (or machines
//! mounting one output directory) cooperatively execute a single grid.
//! The run set is already hash-keyed and resume-safe (ISSUE 3), so the
//! only coordination needed is *advisory run-claim locking*:
//!
//! * **Claim files** (`<out>/claims/<id>.claim`): acquired with
//!   create-exclusive (`O_CREAT | O_EXCL` — the filesystem arbitrates
//!   races, exactly one creator wins), refreshed by a heartbeat that
//!   rewrites the claim's wall-clock stamp (which also bumps the file
//!   mtime), and released after the run's result record is durably
//!   appended.
//! * **Stale takeover**: a claim whose stamp is older than the lease is
//!   presumed dead (crashed process). Takeover renames the stale claim
//!   to a per-claimant tombstone — rename is atomic within the
//!   directory, so concurrent takeover attempts produce exactly one
//!   winner of the *removal*; acquisition itself still goes through
//!   create-exclusive, so even a third process that never saw the stale
//!   claim competes fairly. Cleanup of the tombstone is idempotent.
//! * **Crash safety**: completed runs are detected from
//!   `results.jsonl` exactly as `--resume` does, and half-finished runs
//!   resume from their `<out>/ckpt/<id>.ckpt` snapshot bit-for-bit, so
//!   a takeover lands on the uninterrupted trajectory
//!   (`rust/tests/sweep_distributed.rs` pins both).
//!
//! The locking is *advisory*: a live-but-stalled owner whose claim is
//! taken over discovers the loss at its next heartbeat and abandons the
//! run without recording a result (ownership is re-verified immediately
//! before persisting). Exactly-once *recording* therefore holds under
//! crash/takeover; a pathological stall shorter than one heartbeat
//! interval can duplicate *work*, never results, beyond a last-wins
//! duplicate line that `sweep report` resolves deterministically.
//!
//! Property tests (`rust/tests/properties.rs`) pin the lease algebra:
//! takeover never fires before the lease expires under any interleaving
//! of heartbeat timestamps, racing claimants yield exactly one winner,
//! and stale-claim cleanup is idempotent.

use std::fs::{self, File, OpenOptions};
use std::io::{BufWriter, Write};
use std::path::{Path, PathBuf};
use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::Mutex;
use std::time::{Duration, Instant, SystemTime};

use crate::config::ExperimentConfig;
use crate::util::json::Json;

use super::cache::ArtifactCache;
use super::runner::{
    execute_one, load_completed, persist, RunEvent, RunOutcome, SweepOptions, SweepReport,
};
use super::spec::config_hash;

// ---------------------------------------------------------------------
// Claim store
// ---------------------------------------------------------------------

/// Seconds since the Unix epoch (the claim-stamp clock; one shared
/// filesystem ⇒ one clock domain is assumed, as with any mtime lease).
pub fn now_secs() -> f64 {
    SystemTime::now()
        .duration_since(SystemTime::UNIX_EPOCH)
        .map(|d| d.as_secs_f64())
        .unwrap_or(0.0)
}

/// The lease predicate: a claim stamped at `stamp` is stale at `now`
/// iff a full lease has elapsed since its last heartbeat. This is the
/// single decision point for takeover — the property tests drive it
/// through arbitrary heartbeat interleavings.
pub fn claim_is_stale(now: f64, stamp: f64, lease_secs: f64) -> bool {
    now - stamp >= lease_secs
}

/// [`claim_is_stale`] with a clock-skew margin: stamps are written by
/// the *owner's* clock and judged by the *claimant's*, and one shared
/// filesystem does not imply one clock domain (NFS mounts from machines
/// seconds apart). The margin widens the lease by the claimant's skew
/// allowance so a fast-clocked claimant cannot take over a live run
/// early; it delays legitimate takeover by at most `margin_secs`.
pub fn claim_is_stale_with_margin(
    now: f64,
    stamp: f64,
    lease_secs: f64,
    margin_secs: f64,
) -> bool {
    claim_is_stale(now, stamp, lease_secs + margin_secs.max(0.0))
}

/// Result of a claim attempt.
#[derive(Debug)]
pub enum Acquire {
    /// We own the run now (release or abandon via the [`Claim`]).
    Acquired(Claim),
    /// A live (non-stale) claimant holds it.
    Held,
}

/// A held claim on one run id. Dropping a `Claim` does **not** release
/// it — that is the crash-safety story (an abandoned claim expires via
/// the lease); call [`Claim::release`] after persisting the result.
#[derive(Debug)]
pub struct Claim {
    path: PathBuf,
    id: String,
    owner: String,
    heartbeats: u64,
}

impl Claim {
    /// Refresh the lease stamp. Returns `Ok(false)` when the claim was
    /// taken over (the file now names another owner, or vanished) — the
    /// caller must abandon the run without recording a result.
    pub fn heartbeat(&mut self) -> Result<bool, String> {
        self.heartbeat_at(now_secs())
    }

    /// [`heartbeat`](Self::heartbeat) with an explicit clock (tests).
    pub fn heartbeat_at(&mut self, now: f64) -> Result<bool, String> {
        match read_claim(&self.path) {
            Ok(Some((owner, _))) if owner == self.owner => {}
            Ok(_) => return Ok(false), // taken over or released
            Err(e) => return Err(e),
        }
        self.heartbeats += 1;
        write_claim(&self.path, &self.id, &self.owner, now, self.heartbeats)?;
        Ok(true)
    }

    /// True while the claim file still names us as owner.
    pub fn is_mine(&self) -> Result<bool, String> {
        Ok(matches!(read_claim(&self.path)?, Some((owner, _)) if owner == self.owner))
    }

    /// Release after the result record is durably on disk. A claim that
    /// was meanwhile taken over is left untouched (not ours to delete).
    pub fn release(self) -> Result<(), String> {
        if self.is_mine()? {
            fs::remove_file(&self.path).map_err(|e| format!("{}: {e}", self.path.display()))?;
        }
        Ok(())
    }
}

/// Advisory per-run-id claim files under one directory.
#[derive(Debug, Clone)]
pub struct ClaimStore {
    dir: PathBuf,
    owner: String,
    lease_secs: f64,
    /// Clock-skew allowance added to the lease before takeover (see
    /// [`claim_is_stale_with_margin`]); 0 by default — deployments set
    /// it via [`with_margin`](Self::with_margin) / `--lease-margin-secs`.
    margin_secs: f64,
}

impl ClaimStore {
    /// `owner` must be unique per process (see [`default_owner`]).
    pub fn new(
        dir: impl Into<PathBuf>,
        owner: impl Into<String>,
        lease_secs: f64,
    ) -> Result<ClaimStore, String> {
        if !(lease_secs.is_finite() && lease_secs > 0.0) {
            return Err(format!("claim lease must be positive, got {lease_secs}"));
        }
        let dir = dir.into();
        fs::create_dir_all(&dir).map_err(|e| format!("{}: {e}", dir.display()))?;
        Ok(ClaimStore {
            dir,
            owner: owner.into(),
            lease_secs,
            margin_secs: 0.0,
        })
    }

    /// Set the clock-skew lease margin (non-negative seconds).
    pub fn with_margin(mut self, margin_secs: f64) -> Result<ClaimStore, String> {
        if !(margin_secs.is_finite() && margin_secs >= 0.0) {
            return Err(format!(
                "lease margin must be a non-negative number of seconds, got {margin_secs}"
            ));
        }
        self.margin_secs = margin_secs;
        Ok(self)
    }

    fn claim_path(&self, id: &str) -> PathBuf {
        self.dir.join(format!("{id}.claim"))
    }

    /// Try to acquire the claim for `id` at the current wall clock.
    pub fn try_acquire(&self, id: &str) -> Result<Acquire, String> {
        self.try_acquire_at(id, now_secs())
    }

    /// [`try_acquire`](Self::try_acquire) with an explicit clock
    /// (property tests drive arbitrary timestamp interleavings).
    ///
    /// Exactly-once: acquisition only ever succeeds through
    /// create-exclusive, so however many processes race — including
    /// through a stale takeover — at most one holds the claim.
    pub fn try_acquire_at(&self, id: &str, now: f64) -> Result<Acquire, String> {
        let path = self.claim_path(id);
        // Bounded retries: each loop either returns or has removed a
        // stale claim (making the next create-exclusive decisive).
        for _attempt in 0..4 {
            match OpenOptions::new().write(true).create_new(true).open(&path) {
                Ok(mut f) => {
                    let body = claim_json(id, &self.owner, now, 0);
                    f.write_all(body.as_bytes())
                        .and_then(|_| f.flush())
                        .map_err(|e| format!("{}: {e}", path.display()))?;
                    return Ok(Acquire::Acquired(Claim {
                        path,
                        id: id.to_string(),
                        owner: self.owner.clone(),
                        heartbeats: 0,
                    }));
                }
                Err(e) if e.kind() == std::io::ErrorKind::AlreadyExists => {
                    if !self.cleanup_stale_at(id, now)? {
                        return Ok(Acquire::Held);
                    }
                    // Stale claim removed — loop back to create-exclusive
                    // (another racer may still beat us there; that's the
                    // point).
                }
                Err(e) => return Err(format!("{}: {e}", path.display())),
            }
        }
        Ok(Acquire::Held)
    }

    /// Remove the claim for `id` if (and only if) it is stale at `now`.
    /// Returns true when a stale claim was removed by *this* call.
    /// Idempotent: repeated calls (or concurrent callers — rename
    /// arbitrates) return false without error once the claim is gone.
    pub fn cleanup_stale_at(&self, id: &str, now: f64) -> Result<bool, String> {
        let path = self.claim_path(id);
        let stamp = match read_claim(&path) {
            Ok(Some((_, stamp))) => stamp,
            Ok(None) => return Ok(false), // already gone
            // Unreadable content (e.g. a torn concurrent rewrite): fall
            // back to the file mtime — a heartbeat rewrites the file, so
            // a fresh mtime means a live owner.
            Err(_) => match fs::metadata(&path).and_then(|m| m.modified()) {
                Ok(mtime) => mtime
                    .duration_since(SystemTime::UNIX_EPOCH)
                    .map(|d| d.as_secs_f64())
                    .unwrap_or(0.0),
                Err(_) => return Ok(false), // vanished mid-check
            },
        };
        if !claim_is_stale_with_margin(now, stamp, self.lease_secs, self.margin_secs) {
            return Ok(false);
        }
        // Atomic removal via rename: exactly one concurrent caller wins
        // the rename; everyone else sees ENOENT and reports false.
        let tomb = self.dir.join(format!("{id}.stale.{}", self.owner));
        match fs::rename(&path, &tomb) {
            Ok(()) => {
                fs::remove_file(&tomb).ok();
                Ok(true)
            }
            Err(_) => Ok(false),
        }
    }

    /// Claim ids currently held (diagnostics / tests).
    pub fn held_ids(&self) -> Vec<String> {
        let mut out = Vec::new();
        if let Ok(entries) = fs::read_dir(&self.dir) {
            for e in entries.flatten() {
                if let Some(name) = e.file_name().to_str() {
                    if let Some(id) = name.strip_suffix(".claim") {
                        out.push(id.to_string());
                    }
                }
            }
        }
        out.sort();
        out
    }
}

/// One held claim, as `sparq sweep status` reports it.
#[derive(Clone, Debug)]
pub struct ClaimInfo {
    pub id: String,
    /// Owner token (empty for an unreadable/torn claim file).
    pub owner: String,
    /// Last heartbeat stamp (seconds since epoch; NaN if unreadable).
    pub stamp: f64,
    /// Heartbeats recorded so far.
    pub heartbeats: u64,
    /// Age of the last heartbeat relative to `now` (seconds).
    pub age_secs: f64,
}

impl ClaimInfo {
    /// Heartbeat freshness under a lease + skew margin: "live" within
    /// the lease, "expiring" past the lease but within the margin,
    /// "stale" once takeover-eligible. (Same predicate the takeover path
    /// evaluates, with `now − stamp = age`.)
    pub fn staleness(&self, lease_secs: f64, margin_secs: f64) -> &'static str {
        if self.stamp.is_nan()
            || claim_is_stale_with_margin(self.age_secs, 0.0, lease_secs, margin_secs)
        {
            "stale"
        } else if claim_is_stale(self.age_secs, 0.0, lease_secs) {
            "expiring"
        } else {
            "live"
        }
    }
}

/// List the claims held under `<out>/claims/` at wall-clock `now`
/// (unreadable claim files appear with an empty owner and their mtime
/// as the stamp, matching the takeover path's fallback).
pub fn list_claims(out_dir: &Path, now: f64) -> Result<Vec<ClaimInfo>, String> {
    let dir = out_dir.join("claims");
    let mut out = Vec::new();
    let entries = match fs::read_dir(&dir) {
        Ok(e) => e,
        // No claims directory = no held claims (serial sweeps, or a
        // distributed sweep that finished cleanly).
        Err(e) if e.kind() == std::io::ErrorKind::NotFound => return Ok(out),
        Err(e) => return Err(format!("{}: {e}", dir.display())),
    };
    for entry in entries.flatten() {
        let name = entry.file_name();
        let Some(id) = name.to_str().and_then(|n| n.strip_suffix(".claim")) else {
            continue;
        };
        let path = entry.path();
        let (owner, stamp, heartbeats) = match fs::read_to_string(&path)
            .ok()
            .and_then(|text| Json::parse(&text).ok())
        {
            Some(j) => (
                j.get("owner").and_then(Json::as_str).unwrap_or("").to_string(),
                j.get("stamp").and_then(Json::as_f64).unwrap_or(f64::NAN),
                j.get("heartbeats").and_then(Json::as_u64).unwrap_or(0),
            ),
            None => {
                // Torn write: fall back to the mtime, like takeover does.
                let mtime = fs::metadata(&path)
                    .and_then(|m| m.modified())
                    .ok()
                    .and_then(|t| t.duration_since(SystemTime::UNIX_EPOCH).ok())
                    .map(|d| d.as_secs_f64())
                    .unwrap_or(f64::NAN);
                (String::new(), mtime, 0)
            }
        };
        out.push(ClaimInfo {
            id: id.to_string(),
            owner,
            stamp,
            heartbeats,
            age_secs: if stamp.is_nan() { f64::NAN } else { now - stamp },
        });
    }
    out.sort_by(|a, b| a.id.cmp(&b.id));
    Ok(out)
}

/// Render the claim list as the `sparq sweep status` table.
pub fn status_table(claims: &[ClaimInfo], lease_secs: f64, margin_secs: f64) -> String {
    use std::fmt::Write;
    let mut out = String::new();
    let _ = writeln!(
        out,
        "{:<18} {:<22} {:>10} {:>11} {:>10}",
        "run id", "owner", "age (s)", "heartbeats", "state"
    );
    for c in claims {
        let _ = writeln!(
            out,
            "{:<18} {:<22} {:>10.1} {:>11} {:>10}",
            c.id,
            if c.owner.is_empty() { "(unreadable)" } else { &c.owner },
            c.age_secs,
            c.heartbeats,
            c.staleness(lease_secs, margin_secs),
        );
    }
    let _ = writeln!(
        out,
        "{} claim(s) held; takeover after {:.0}s lease + {:.0}s skew margin",
        claims.len(),
        lease_secs,
        margin_secs
    );
    out
}

fn claim_json(id: &str, owner: &str, stamp: f64, heartbeats: u64) -> String {
    Json::obj()
        .set("id", id)
        .set("owner", owner)
        .set("stamp", stamp)
        .set("heartbeats", heartbeats)
        .to_string()
}

fn write_claim(
    path: &Path,
    id: &str,
    owner: &str,
    stamp: f64,
    heartbeats: u64,
) -> Result<(), String> {
    fs::write(path, claim_json(id, owner, stamp, heartbeats))
        .map_err(|e| format!("{}: {e}", path.display()))
}

/// `Ok(None)` = no claim file; `Err` = file exists but is unreadable.
fn read_claim(path: &Path) -> Result<Option<(String, f64)>, String> {
    let text = match fs::read_to_string(path) {
        Ok(t) => t,
        Err(e) if e.kind() == std::io::ErrorKind::NotFound => return Ok(None),
        Err(e) => return Err(format!("{}: {e}", path.display())),
    };
    let j = Json::parse(&text).map_err(|e| format!("{}: {e}", path.display()))?;
    let owner = j
        .get("owner")
        .and_then(Json::as_str)
        .ok_or_else(|| format!("{}: claim has no owner", path.display()))?
        .to_string();
    let stamp = j
        .get("stamp")
        .and_then(Json::as_f64)
        .ok_or_else(|| format!("{}: claim has no stamp", path.display()))?;
    Ok(Some((owner, stamp)))
}

/// A process-unique owner token: pid + wall-clock nanos, mixed. Two
/// processes on one machine cannot share a pid; two machines cannot
/// share a boot-nanos draw at pid granularity in practice.
pub fn default_owner() -> String {
    let nanos = SystemTime::now()
        .duration_since(SystemTime::UNIX_EPOCH)
        .map(|d| d.subsec_nanos() as u64 ^ d.as_secs())
        .unwrap_or(0);
    format!("{}-{:08x}", std::process::id(), nanos & 0xffff_ffff)
}

// ---------------------------------------------------------------------
// Distributed runner
// ---------------------------------------------------------------------

/// Knobs of the claim/lease protocol.
#[derive(Clone, Debug)]
pub struct DistributedOptions {
    /// Stale-claim takeover lease (seconds).
    pub lease_secs: f64,
    /// Clock-skew allowance added to the lease before takeover
    /// (seconds; see [`claim_is_stale_with_margin`]). One-filesystem-
    /// many-clocks deployments must keep this > 0 — the default covers
    /// typical NTP-synced drift.
    pub lease_margin_secs: f64,
    /// Heartbeat refresh interval (seconds); must be well under the
    /// lease. 0 ⇒ lease/4.
    pub heartbeat_secs: f64,
    /// Poll interval while waiting on runs held by other processes.
    pub poll_ms: u64,
    /// Unique owner token; empty ⇒ [`default_owner`].
    pub owner: String,
}

impl Default for DistributedOptions {
    fn default() -> Self {
        DistributedOptions {
            lease_secs: 60.0,
            lease_margin_secs: 2.0,
            heartbeat_secs: 0.0,
            poll_ms: 200,
            owner: String::new(),
        }
    }
}

#[derive(Clone, Copy, PartialEq, Debug)]
enum SlotState {
    /// Eligible for a claim attempt.
    Pending,
    /// Held by another process at last attempt.
    Waiting,
    /// Being executed by one of our workers.
    Running,
    /// Outcome available.
    Done,
}

struct DSlot {
    label: String,
    cfg: ExperimentConfig,
    id: String,
    state: SlotState,
    outcome: Option<RunOutcome>,
}

enum Pick {
    Idx(usize),
    AllDone,
    Stalled,
}

/// Cooperatively execute a labelled config list against a shared output
/// directory. Resume semantics are always on (completed runs are
/// detected from `results.jsonl`, half-finished ones from their
/// checkpoints), `results.jsonl` is opened append-only, and every run
/// is guarded by a claim from [`ClaimStore`]. Returns when every run in
/// the grid has an outcome — runs completed by *other* processes are
/// loaded from disk and reported as skipped.
///
/// Determinism: each run's execution is the same `execute_one` the
/// serial engine uses, so per-run series are bit-for-bit identical to a
/// serial sweep regardless of how the grid was split.
pub fn run_distributed(
    runs: Vec<(String, ExperimentConfig)>,
    opts: &SweepOptions,
    dopts: &DistributedOptions,
    cache: &ArtifactCache,
) -> Result<SweepReport, String> {
    let sweep_start = Instant::now();
    let out = opts
        .out
        .clone()
        .ok_or("distributed sweeps require an output directory (--out)")?;
    if !(dopts.lease_secs.is_finite() && dopts.lease_secs > 0.0) {
        return Err(format!(
            "lease must be a positive number of seconds, got {}",
            dopts.lease_secs
        ));
    }
    let heartbeat = if dopts.heartbeat_secs > 0.0 {
        Duration::from_secs_f64(dopts.heartbeat_secs.min(dopts.lease_secs / 2.0))
    } else {
        Duration::from_secs_f64((dopts.lease_secs / 4.0).max(0.01))
    };
    let poll = Duration::from_millis(dopts.poll_ms.max(10));
    let owner = if dopts.owner.is_empty() {
        default_owner()
    } else {
        dopts.owner.clone()
    };

    let series_dir = out.join("series");
    let ckpt_dir = out.join("ckpt");
    fs::create_dir_all(&series_dir).map_err(|e| format!("{}: {e}", series_dir.display()))?;
    fs::create_dir_all(&ckpt_dir).map_err(|e| format!("{}: {e}", ckpt_dir.display()))?;
    let claims = ClaimStore::new(out.join("claims"), owner, dopts.lease_secs)?
        .with_margin(dopts.lease_margin_secs)?;
    let results_path = out.join("results.jsonl");
    let sink: Mutex<BufWriter<File>> = Mutex::new(BufWriter::new(
        OpenOptions::new()
            .create(true)
            .append(true)
            .open(&results_path)
            .map_err(|e| format!("{}: {e}", results_path.display()))?,
    ));

    let slots: Vec<DSlot> = runs
        .into_iter()
        .map(|(label, cfg)| {
            let id = config_hash(&cfg);
            DSlot {
                label,
                cfg,
                id,
                state: SlotState::Pending,
                outcome: None,
            }
        })
        .collect();
    super::runner::reject_duplicate_ids(slots.iter().map(|s| (&s.id, &s.label)))?;

    let budget = if opts.workers == 0 {
        std::thread::available_parallelism()
            .map(|n| n.get())
            .unwrap_or(1)
    } else {
        opts.workers
    };
    let run_workers = budget.min(slots.len()).max(1);
    let node_workers = (budget / run_workers).max(1);

    // Resume semantics are not optional here: a distributed sweep must
    // never truncate shared state another process is appending to.
    let mut opts = opts.clone();
    opts.resume = true;

    let state = Mutex::new(slots);
    let crashed = AtomicBool::new(false);
    let errors: Mutex<Vec<String>> = Mutex::new(Vec::new());
    let executed_here = Mutex::new(0usize);
    let completed_index = Mutex::new(CompletedIndex::new(results_path.clone()));

    std::thread::scope(|scope| {
        for _ in 0..run_workers {
            let state = &state;
            let crashed = &crashed;
            let errors = &errors;
            let executed_here = &executed_here;
            let completed_index = &completed_index;
            let claims = &claims;
            let opts = &opts;
            let sink = &sink;
            let series_dir = series_dir.as_path();
            let ckpt_dir = ckpt_dir.as_path();
            scope.spawn(move || loop {
                if crashed.load(Ordering::SeqCst) || !errors.lock().unwrap().is_empty() {
                    break;
                }
                // Pick the first claimable slot.
                let pick = {
                    let mut st = state.lock().unwrap();
                    if st.iter().all(|s| s.state == SlotState::Done) {
                        Pick::AllDone
                    } else if let Some(i) =
                        st.iter().position(|s| s.state == SlotState::Pending)
                    {
                        st[i].state = SlotState::Running;
                        Pick::Idx(i)
                    } else {
                        Pick::Stalled
                    }
                };
                match pick {
                    Pick::AllDone => break,
                    Pick::Stalled => {
                        // Everything is Waiting (foreign claims) or
                        // Running (our other workers). Refresh the
                        // completed set from disk — a foreign holder may
                        // have finished — then retry Waiting slots
                        // (their claims may have gone stale).
                        // Lock order is always index → state.
                        let mut ix = completed_index.lock().unwrap();
                        ix.refresh();
                        let mut resolved = false;
                        {
                            let mut st = state.lock().unwrap();
                            if st.iter().all(|s| s.state == SlotState::Done) {
                                break;
                            }
                            for s in st.iter_mut() {
                                if s.state != SlotState::Waiting {
                                    continue;
                                }
                                if let Some(record) = ix.get(&s.id) {
                                    match load_completed(
                                        &s.label,
                                        &s.cfg,
                                        &s.id,
                                        record,
                                        Some(series_dir),
                                    ) {
                                        Ok(outcome) => {
                                            s.outcome = Some(outcome);
                                            s.state = SlotState::Done;
                                            resolved = true;
                                        }
                                        Err(_) => {
                                            // Record without a readable
                                            // series (torn write): retry
                                            // the claim next cycle.
                                            s.state = SlotState::Pending;
                                        }
                                    }
                                } else {
                                    s.state = SlotState::Pending;
                                }
                            }
                        }
                        drop(ix);
                        if !resolved {
                            std::thread::sleep(poll);
                        }
                        continue;
                    }
                    Pick::Idx(i) => {
                        let (label, cfg, id) = {
                            let st = state.lock().unwrap();
                            (st[i].label.clone(), st[i].cfg.clone(), st[i].id.clone())
                        };
                        let set = |state_ref: &Mutex<Vec<DSlot>>,
                                   s: SlotState,
                                   outcome: Option<RunOutcome>| {
                            let mut st = state_ref.lock().unwrap();
                            st[i].state = s;
                            if outcome.is_some() {
                                st[i].outcome = outcome;
                            }
                        };

                        // Already completed (by anyone, any time)?
                        let recorded = |ix_mutex: &Mutex<CompletedIndex>| -> Option<Json> {
                            let mut ix = ix_mutex.lock().unwrap();
                            ix.refresh();
                            ix.get(&id).cloned()
                        };
                        if let Some(record) = recorded(completed_index) {
                            match load_completed(&label, &cfg, &id, &record, Some(series_dir)) {
                                Ok(outcome) => {
                                    if opts.verbose {
                                        println!("[sweep] skip {label} (already complete)");
                                    }
                                    set(state, SlotState::Done, Some(outcome));
                                    continue;
                                }
                                Err(e) => {
                                    if opts.verbose {
                                        println!("[sweep] re-run {label}: {e}");
                                    }
                                }
                            }
                        }

                        let mut claim = match claims.try_acquire(&id) {
                            Ok(Acquire::Acquired(c)) => c,
                            Ok(Acquire::Held) => {
                                set(state, SlotState::Waiting, None);
                                continue;
                            }
                            Err(e) => {
                                errors.lock().unwrap().push(format!("{label}: {e}"));
                                break;
                            }
                        };
                        // Re-check now that the claim is held: a previous
                        // holder persists *before* releasing, so a record
                        // appearing between the pre-claim check and the
                        // acquisition means the run already finished —
                        // step aside instead of re-executing it (closes
                        // the check-then-act window that would otherwise
                        // double-execute and double-record the run).
                        if let Some(record) = recorded(completed_index) {
                            if let Ok(outcome) =
                                load_completed(&label, &cfg, &id, &record, Some(series_dir))
                            {
                                if opts.verbose {
                                    println!("[sweep] skip {label} (completed during claim)");
                                }
                                claim.release().ok();
                                set(state, SlotState::Done, Some(outcome));
                                continue;
                            }
                            // Unreadable series: keep the claim, re-run.
                        }
                        if let Some(hook) = &opts.on_event {
                            hook(&RunEvent::Started {
                                id: id.clone(),
                                label: label.clone(),
                                node_workers,
                            });
                        }

                        // Heartbeat from the per-iteration tick; on a
                        // lost claim the run is abandoned result-free.
                        let mut claim_lost = false;
                        let mut last_hb = Instant::now();
                        let mut tick = |_t: u64| -> Result<bool, String> {
                            if last_hb.elapsed() >= heartbeat {
                                last_hb = Instant::now();
                                if !claim.heartbeat()? {
                                    claim_lost = true;
                                    return Ok(false);
                                }
                            }
                            Ok(true)
                        };
                        let res = execute_one(
                            &label,
                            &cfg,
                            &id,
                            cache,
                            &super::runner::NodeBudget::Fixed(node_workers),
                            opts,
                            Some(ckpt_dir),
                            Some(&mut tick),
                        );
                        match res {
                            Err(e) => {
                                // Deterministic failure: release so other
                                // processes don't burn a lease waiting.
                                claim.release().ok();
                                errors.lock().unwrap().push(format!("{label}: {e}"));
                                break;
                            }
                            Ok(outcome) if !outcome.completed => {
                                if claim_lost {
                                    // Someone took the run over; let the
                                    // Waiting machinery track them.
                                    set(state, SlotState::Waiting, None);
                                    continue;
                                }
                                // Fault injection: simulate a crash —
                                // leave the claim and checkpoints in
                                // place and stop the whole process.
                                crashed.store(true, Ordering::SeqCst);
                                errors.lock().unwrap().push(format!(
                                    "{label}: aborted by fault injection (claims and \
                                     checkpoints left for takeover)"
                                ));
                                break;
                            }
                            Ok(outcome) => {
                                // Re-verify ownership at the last moment:
                                // persisting after a takeover would
                                // double-record the run.
                                match claim.is_mine() {
                                    Ok(true) => {}
                                    Ok(false) => {
                                        set(state, SlotState::Waiting, None);
                                        continue;
                                    }
                                    Err(e) => {
                                        errors.lock().unwrap().push(format!("{label}: {e}"));
                                        break;
                                    }
                                }
                                if let Err(e) = persist(&outcome, Some(series_dir), Some(sink)) {
                                    errors.lock().unwrap().push(format!("{label}: {e}"));
                                    break;
                                }
                                if let Err(e) = claim.release() {
                                    errors.lock().unwrap().push(format!("{label}: {e}"));
                                    break;
                                }
                                if opts.verbose {
                                    let last = outcome.series.records.last();
                                    let state_str = if outcome.stopped.is_some() {
                                        "early-stop"
                                    } else {
                                        "done"
                                    };
                                    println!(
                                        "[sweep] {state_str} {label} ({} ms, loss={:.5}, bits={})",
                                        outcome.wall_ms,
                                        last.map(|r| r.loss).unwrap_or(f64::NAN),
                                        last.map(|r| r.bits).unwrap_or(0),
                                    );
                                }
                                if let Some(hook) = &opts.on_event {
                                    hook(&RunEvent::Finished {
                                        id: id.clone(),
                                        label: label.clone(),
                                        completed: true,
                                        stopped: outcome.stopped.is_some(),
                                    });
                                }
                                *executed_here.lock().unwrap() += 1;
                                set(state, SlotState::Done, Some(outcome));
                            }
                        }
                    }
                }
            });
        }
    });

    let errors = errors.into_inner().unwrap();
    if !errors.is_empty() {
        return Err(errors.join("; "));
    }
    sink.lock().unwrap().flush().map_err(|e| e.to_string())?;

    let outcomes: Vec<RunOutcome> = state
        .into_inner()
        .unwrap()
        .into_iter()
        .map(|s| s.outcome.expect("all slots done without error"))
        .collect();
    let executed = executed_here.into_inner().unwrap();
    let skipped = outcomes.iter().filter(|o| o.skipped).count();
    Ok(SweepReport {
        outcomes,
        executed,
        skipped,
        wall_ms: sweep_start.elapsed().as_millis() as u64,
        cache_summary: cache.summary(),
    })
}

/// Incremental index over the shared `results.jsonl`: the file is
/// append-only, so each refresh reads only the bytes past the last
/// consumed offset instead of re-parsing the whole file on every
/// scheduling cycle (which would be O(grid² · record) on the shared
/// filesystem as a sweep drains). A torn tail line (a concurrent
/// appender mid-write) is left unconsumed and picked up whole on the
/// next refresh; later records for an id win, matching append order.
pub(crate) struct CompletedIndex {
    path: PathBuf,
    offset: u64,
    map: std::collections::HashMap<String, Json>,
}

impl CompletedIndex {
    pub(crate) fn new(path: PathBuf) -> CompletedIndex {
        CompletedIndex {
            path,
            offset: 0,
            map: std::collections::HashMap::new(),
        }
    }

    /// Pull any newly appended whole lines into the index.
    pub(crate) fn refresh(&mut self) {
        use std::io::{Read, Seek, SeekFrom};
        let Ok(mut f) = File::open(&self.path) else {
            return;
        };
        let len = f.metadata().map(|m| m.len()).unwrap_or(0);
        if len < self.offset {
            // Truncated/replaced out from under us: start over.
            self.offset = 0;
            self.map.clear();
        }
        if len == self.offset || f.seek(SeekFrom::Start(self.offset)).is_err() {
            return;
        }
        let mut buf = Vec::with_capacity((len - self.offset) as usize);
        if f.take(len - self.offset).read_to_end(&mut buf).is_err() {
            return;
        }
        // Consume only whole lines; a partial tail stays for next time.
        let Some(consumed) = buf.iter().rposition(|&b| b == b'\n').map(|p| p + 1) else {
            return;
        };
        let text = String::from_utf8_lossy(&buf[..consumed]);
        for line in text.lines().filter(|l| !l.trim().is_empty()) {
            if let Ok(j) = Json::parse(line) {
                if let Some(id) = j.get("id").and_then(Json::as_str) {
                    self.map.insert(id.to_string(), j.clone());
                }
            }
        }
        self.offset += consumed as u64;
    }

    pub(crate) fn get(&self, id: &str) -> Option<&Json> {
        self.map.get(id)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn tmp_claims(tag: &str) -> PathBuf {
        let dir = std::env::temp_dir().join(format!(
            "sparq-claims-{tag}-{}-{:x}",
            std::process::id(),
            now_secs().to_bits()
        ));
        std::fs::remove_dir_all(&dir).ok();
        dir
    }

    #[test]
    fn acquire_release_roundtrip() {
        let dir = tmp_claims("rt");
        let store = ClaimStore::new(&dir, "a", 30.0).unwrap();
        let claim = match store.try_acquire("run1").unwrap() {
            Acquire::Acquired(c) => c,
            Acquire::Held => panic!("fresh store must grant the claim"),
        };
        assert_eq!(store.held_ids(), vec!["run1".to_string()]);
        // Second claimant is refused while the lease is fresh.
        let other = ClaimStore::new(&dir, "b", 30.0).unwrap();
        assert!(matches!(other.try_acquire("run1").unwrap(), Acquire::Held));
        claim.release().unwrap();
        assert!(store.held_ids().is_empty());
        // Released claim is acquirable again.
        assert!(matches!(
            other.try_acquire("run1").unwrap(),
            Acquire::Acquired(_)
        ));
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn stale_claim_is_taken_over_and_old_owner_detects_loss() {
        let dir = tmp_claims("stale");
        let store_a = ClaimStore::new(&dir, "a", 5.0).unwrap();
        let t0 = 1000.0;
        let mut claim_a = match store_a.try_acquire_at("run1", t0).unwrap() {
            Acquire::Acquired(c) => c,
            Acquire::Held => panic!("must acquire"),
        };
        let store_b = ClaimStore::new(&dir, "b", 5.0).unwrap();
        // Before the lease expires: held.
        assert!(matches!(
            store_b.try_acquire_at("run1", t0 + 4.9).unwrap(),
            Acquire::Held
        ));
        // At/after the lease: taken over.
        let claim_b = match store_b.try_acquire_at("run1", t0 + 5.0).unwrap() {
            Acquire::Acquired(c) => c,
            Acquire::Held => panic!("stale claim must be taken over"),
        };
        // The original owner's next heartbeat reports the loss.
        assert!(!claim_a.heartbeat_at(t0 + 5.1).unwrap());
        assert!(!claim_a.is_mine().unwrap());
        assert!(claim_b.is_mine().unwrap());
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn heartbeat_extends_the_lease() {
        let dir = tmp_claims("hb");
        let store_a = ClaimStore::new(&dir, "a", 5.0).unwrap();
        let t0 = 50.0;
        let mut claim = match store_a.try_acquire_at("r", t0).unwrap() {
            Acquire::Acquired(c) => c,
            Acquire::Held => panic!(),
        };
        assert!(claim.heartbeat_at(t0 + 4.0).unwrap());
        let store_b = ClaimStore::new(&dir, "b", 5.0).unwrap();
        // 5s past t0 but only 1s past the heartbeat: not stale.
        assert!(matches!(
            store_b.try_acquire_at("r", t0 + 5.0).unwrap(),
            Acquire::Held
        ));
        // 5s past the heartbeat: stale.
        assert!(matches!(
            store_b.try_acquire_at("r", t0 + 9.0).unwrap(),
            Acquire::Acquired(_)
        ));
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn cleanup_stale_is_idempotent() {
        let dir = tmp_claims("idem");
        let store = ClaimStore::new(&dir, "a", 2.0).unwrap();
        let _claim = store.try_acquire_at("r", 0.0).unwrap();
        let other = ClaimStore::new(&dir, "b", 2.0).unwrap();
        assert!(other.cleanup_stale_at("r", 10.0).unwrap());
        assert!(!other.cleanup_stale_at("r", 10.0).unwrap());
        assert!(!other.cleanup_stale_at("r", 10.0).unwrap());
        assert!(matches!(
            other.try_acquire_at("r", 10.0).unwrap(),
            Acquire::Acquired(_)
        ));
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn unreadable_claim_with_fresh_mtime_is_not_stolen() {
        let dir = tmp_claims("torn");
        std::fs::create_dir_all(&dir).unwrap();
        // Simulate a torn write: garbage content, mtime = now.
        std::fs::write(dir.join("r.claim"), b"{torn").unwrap();
        let store = ClaimStore::new(&dir, "b", 3600.0).unwrap();
        assert!(matches!(store.try_acquire("r").unwrap(), Acquire::Held));
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn lease_must_be_positive() {
        assert!(ClaimStore::new(std::env::temp_dir(), "a", 0.0).is_err());
        assert!(ClaimStore::new(std::env::temp_dir(), "a", -1.0).is_err());
        assert!(ClaimStore::new(std::env::temp_dir(), "a", f64::NAN).is_err());
        // margins must be non-negative and finite
        let store = ClaimStore::new(std::env::temp_dir(), "a", 5.0).unwrap();
        assert!(store.clone().with_margin(-1.0).is_err());
        assert!(store.clone().with_margin(f64::NAN).is_err());
        assert!(store.with_margin(2.0).is_ok());
    }

    #[test]
    fn lease_margin_delays_takeover_by_exactly_the_skew_allowance() {
        let dir = tmp_claims("margin");
        let store_a = ClaimStore::new(&dir, "a", 5.0).unwrap();
        let t0 = 1000.0;
        let _claim_a = store_a.try_acquire_at("run1", t0).unwrap();
        let store_b = ClaimStore::new(&dir, "b", 5.0)
            .unwrap()
            .with_margin(2.0)
            .unwrap();
        // Past the lease but inside the margin: a fast-clocked claimant
        // must NOT steal the run.
        assert!(matches!(
            store_b.try_acquire_at("run1", t0 + 5.0).unwrap(),
            Acquire::Held
        ));
        assert!(matches!(
            store_b.try_acquire_at("run1", t0 + 6.9).unwrap(),
            Acquire::Held
        ));
        // At lease + margin: takeover proceeds.
        assert!(matches!(
            store_b.try_acquire_at("run1", t0 + 7.0).unwrap(),
            Acquire::Acquired(_)
        ));
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn status_listing_reports_owner_age_and_staleness() {
        // The status view reads the same layout the runner writes:
        // <out>/claims/<id>.claim.
        let out = tmp_claims("status-out");
        let store = ClaimStore::new(out.join("claims"), "worker-1", 30.0).unwrap();
        let t0 = 5000.0;
        let mut claim = match store.try_acquire_at("runA", t0).unwrap() {
            Acquire::Acquired(c) => c,
            Acquire::Held => panic!("must acquire"),
        };
        claim.heartbeat_at(t0 + 10.0).unwrap();
        let _other = store.try_acquire_at("runB", t0 + 12.0).unwrap();

        let claims = list_claims(&out, t0 + 15.0).unwrap();
        assert_eq!(claims.len(), 2);
        assert_eq!(claims[0].id, "runA");
        assert_eq!(claims[0].owner, "worker-1");
        assert_eq!(claims[0].heartbeats, 1);
        assert!((claims[0].age_secs - 5.0).abs() < 1e-9, "{}", claims[0].age_secs);
        assert_eq!(claims[0].staleness(30.0, 2.0), "live");
        // Aged past the lease but not the margin: expiring; then stale.
        assert_eq!(
            ClaimInfo { age_secs: 31.0, ..claims[0].clone() }.staleness(30.0, 2.0),
            "expiring"
        );
        assert_eq!(
            ClaimInfo { age_secs: 32.0, ..claims[0].clone() }.staleness(30.0, 2.0),
            "stale"
        );

        let table = status_table(&claims, 30.0, 2.0);
        assert!(table.contains("runA") && table.contains("worker-1"), "{table}");
        assert!(table.contains("2 claim(s) held"), "{table}");

        // an out dir without claims/ lists empty (not an error)
        let empty = tmp_claims("status-none");
        std::fs::create_dir_all(&empty).unwrap();
        assert!(list_claims(&empty, 0.0).unwrap().is_empty());
        std::fs::remove_dir_all(&out).ok();
        std::fs::remove_dir_all(&empty).ok();
    }
}
