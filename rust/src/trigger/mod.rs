//! Event-triggered communication (the paper's headline mechanism).
//!
//! At each synchronization index, node i fires — i.e. transmits a
//! compressed update — only when its local parameter has drifted far
//! enough from the public estimate its neighbors hold (Algorithm 1 line 7):
//!
//! ```text
//! ‖x_i^{t+½} − x̂_i^{(t)}‖² > c_t · η_t²
//! ```
//!
//! Threshold schedules c_t provided (all with c_t ~ o(t) as required by
//! Theorem 1's analysis, except `Constant` which the paper also uses in
//! its experiments before switching to periodic increases):
//!
//! * `Zero` — always fire when reached (SPARQ with local steps only; also
//!   how CHOCO-SGD is expressed in this framework).
//! * `Constant(c0)` — the Section 5.1 initial setting (c₀ = 5000).
//! * `Poly { c0, eps }` — c_t = c₀ · t^{1−ε} (Theorem 1 form).
//! * `PiecewiseEpoch { init, step, every, until }` — the Section 5.2
//!   schedule (2.0, +1.0 every 10 epochs until epoch 60).
//!
//! Besides the norm test above, [`EventTrigger`] supports an
//! EventGraD-style **per-coordinate** mode (`percoord:C`): each
//! coordinate j fires independently when `diff_j² > C · η_t²`, only
//! fired coordinates enter the compressor, and the node transmits iff
//! any coordinate fired. See [`EventTrigger::parse`].

use crate::linalg::vecops::dist2;

#[derive(Clone, Debug, PartialEq)]
pub enum ThresholdSchedule {
    Zero,
    Constant(f64),
    /// c_t = c0 * t^(1-eps), eps in (0,1).
    ///
    /// **t = 0 semantics (pinned):** `c(0)` is defined as 0.0 — the real
    /// power `0^{1-ε}` is 0 for ε ∈ (0, 1), the branch just avoids
    /// `powf`'s edge cases — so the *first* sync index fires whenever
    /// there is any drift at all, regardless of c₀. This matches
    /// Algorithm 1's bootstrap: x̂^{(0)} = 0, and the paper has every
    /// node broadcast its (compressed) initial parameters in the first
    /// round; a zero threshold at t = 0 is exactly that behavior. New
    /// trigger families (SQuARM, per-coordinate) inherit it deliberately.
    Poly { c0: f64, eps: f64 },
    /// Piecewise-constant in "epochs" of `steps_per_epoch` iterations:
    /// starts at `init`, increases by `step` every `every` epochs, frozen
    /// after `until` epochs.
    PiecewiseEpoch {
        init: f64,
        step: f64,
        every: usize,
        until: usize,
        steps_per_epoch: usize,
    },
}

impl ThresholdSchedule {
    /// c_t at iteration t.
    pub fn c(&self, t: u64) -> f64 {
        match self {
            ThresholdSchedule::Zero => 0.0,
            ThresholdSchedule::Constant(c0) => *c0,
            ThresholdSchedule::Poly { c0, eps } => {
                if t == 0 {
                    0.0
                } else {
                    c0 * (t as f64).powf(1.0 - eps)
                }
            }
            ThresholdSchedule::PiecewiseEpoch {
                init,
                step,
                every,
                until,
                steps_per_epoch,
            } => {
                let epoch = (t as usize / (*steps_per_epoch).max(1)).min(*until);
                init + step * (epoch / (*every).max(1)) as f64
            }
        }
    }

    /// Parse "zero", "const:C", "poly:C0:EPS", "piecewise:INIT:STEP:EVERY:UNTIL:SPE".
    ///
    /// Validated, with an error message naming the offending field:
    /// thresholds must be finite and non-negative, `poly` requires
    /// ε ∈ (0, 1) — c_t = c₀·t^{1−ε} is o(t) only there, which is what
    /// Theorem 1's analysis assumes (`poly:2:-1` would grow
    /// *superlinearly* and silently void the guarantee) — and the
    /// piecewise cadence fields (`EVERY`, `SPE`) must be ≥ 1 so the
    /// schedule's epoch arithmetic is well-defined.
    pub fn parse(s: &str) -> Result<ThresholdSchedule, String> {
        let num = |field: &str, v: &str| -> Result<f64, String> {
            v.parse::<f64>()
                .map_err(|_| format!("trigger {field} {v:?} is not a number"))
        };
        let finite_nonneg = |field: &str, x: f64| -> Result<f64, String> {
            if x.is_finite() && x >= 0.0 {
                Ok(x)
            } else {
                Err(format!(
                    "trigger {field} must be finite and non-negative, got {x}"
                ))
            }
        };
        let int = |field: &str, v: &str| -> Result<usize, String> {
            v.parse::<usize>()
                .map_err(|_| format!("trigger {field} {v:?} is not a non-negative integer"))
        };
        let p: Vec<&str> = s.split(':').collect();
        match p.as_slice() {
            ["zero"] => Ok(ThresholdSchedule::Zero),
            ["const", c] => {
                let c = finite_nonneg("c0", num("c0", c)?)?;
                Ok(ThresholdSchedule::Constant(c))
            }
            ["poly", c0, eps] => {
                let c0 = finite_nonneg("c0", num("c0", c0)?)?;
                let eps = num("eps", eps)?;
                if !(eps > 0.0 && eps < 1.0) {
                    return Err(format!(
                        "trigger eps must lie in the open interval (0, 1) so that \
                         c_t = c0·t^(1-eps) is o(t) (Theorem 1), got {eps}"
                    ));
                }
                Ok(ThresholdSchedule::Poly { c0, eps })
            }
            ["piecewise", init, step, every, until, spe] => {
                let init = finite_nonneg("init", num("init", init)?)?;
                let step = finite_nonneg("step", num("step", step)?)?;
                let every = int("every", every)?;
                let until = int("until", until)?;
                let steps_per_epoch = int("steps_per_epoch", spe)?;
                if every == 0 {
                    return Err("trigger every must be >= 1 epoch".into());
                }
                if steps_per_epoch == 0 {
                    return Err("trigger steps_per_epoch must be >= 1".into());
                }
                Ok(ThresholdSchedule::PiecewiseEpoch {
                    init,
                    step,
                    every,
                    until,
                    steps_per_epoch,
                })
            }
            _ => Err(format!(
                "unknown trigger spec {s:?}; expected zero, const:C, poly:C0:EPS, \
                 percoord:C, or piecewise:INIT:STEP:EVERY:UNTIL:STEPS_PER_EPOCH"
            )),
        }
    }
}

/// The event trigger itself.
#[derive(Clone, Debug)]
pub struct EventTrigger {
    pub schedule: ThresholdSchedule,
    /// EventGraD-style per-coordinate mode: each coordinate fires
    /// independently on `diff_j² > c_t · η_t²` and non-fired coordinates
    /// are withheld (masked to 0 before compression). `false` = the
    /// paper's norm test over the whole vector.
    pub per_coord: bool,
}

impl EventTrigger {
    /// Norm-triggered (Algorithm 1) — the default mode.
    pub fn new(schedule: ThresholdSchedule) -> Self {
        EventTrigger {
            schedule,
            per_coord: false,
        }
    }

    /// EventGraD-style per-coordinate trigger over `schedule`.
    pub fn new_per_coord(schedule: ThresholdSchedule) -> Self {
        EventTrigger {
            schedule,
            per_coord: true,
        }
    }

    /// Parse the full trigger grammar: every [`ThresholdSchedule`] form
    /// (norm mode) plus the per-coordinate form `percoord:C`.
    pub fn parse(s: &str) -> Result<EventTrigger, String> {
        if let Some(c) = s.strip_prefix("percoord:") {
            let c: f64 = c
                .parse()
                .map_err(|_| format!("trigger percoord threshold {c:?} is not a number"))?;
            if !c.is_finite() || c < 0.0 {
                return Err(format!(
                    "trigger percoord threshold must be finite and non-negative, got {c}"
                ));
            }
            return Ok(EventTrigger::new_per_coord(ThresholdSchedule::Constant(c)));
        }
        ThresholdSchedule::parse(s).map(EventTrigger::new)
    }

    /// The per-coordinate threshold c_t · η_t² when in per-coordinate
    /// mode, `None` for the norm mode. The engine's sync pass consults
    /// this to decide between the whole-vector drift test and the
    /// coordinate mask.
    pub fn coord_threshold(&self, t: u64, eta_t: f64) -> Option<f64> {
        if self.per_coord {
            Some(self.schedule.c(t) * eta_t * eta_t)
        } else {
            None
        }
    }

    /// Algorithm 1 line 7 (strict inequality).
    pub fn fires(&self, x_half: &[f32], xhat: &[f32], t: u64, eta_t: f64) -> bool {
        self.fires_drift(dist2(x_half, xhat), t, eta_t)
    }

    /// Algorithm 1 line 7 given a precomputed drift ‖x^{t+½} − x̂‖²
    /// (the engine's fused trigger→compress pass computes the drift
    /// while materializing the difference vector — `sub_into_dist2`).
    pub fn fires_drift(&self, drift2: f64, t: u64, eta_t: f64) -> bool {
        drift2 > self.schedule.c(t) * eta_t * eta_t
    }

    /// The threshold value c_t η_t² (exposed for metrics/ablations).
    pub fn threshold(&self, t: u64, eta_t: f64) -> f64 {
        self.schedule.c(t) * eta_t * eta_t
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn zero_schedule_fires_on_any_drift() {
        let tr = EventTrigger::new(ThresholdSchedule::Zero);
        let x = vec![1.0f32, 0.0];
        let xh = vec![0.0f32, 0.0];
        assert!(tr.fires(&x, &xh, 0, 0.1));
        // no drift ⇒ strict inequality says no fire
        assert!(!tr.fires(&xh, &xh, 0, 0.1));
    }

    #[test]
    fn constant_threshold_semantics() {
        let tr = EventTrigger::new(ThresholdSchedule::Constant(100.0));
        let eta = 0.1; // threshold = 100 * 0.01 = 1.0
        let xh = vec![0.0f32; 4];
        let below = vec![0.4f32, 0.4, 0.4, 0.4]; // ||.||² = 0.64
        let above = vec![0.6f32, 0.6, 0.6, 0.6]; // ||.||² = 1.44
        assert!(!tr.fires(&below, &xh, 5, eta));
        assert!(tr.fires(&above, &xh, 5, eta));
    }

    #[test]
    fn poly_grows_sublinearly() {
        let s = ThresholdSchedule::Poly { c0: 2.0, eps: 0.5 };
        assert_eq!(s.c(0), 0.0);
        assert!((s.c(100) - 2.0 * 10.0).abs() < 1e-9); // 2 * 100^0.5
        // o(t): c_t / t -> 0
        assert!(s.c(1_000_000) / 1_000_000.0 < 0.01);
    }

    #[test]
    fn piecewise_epoch_schedule_matches_paper() {
        // Section 5.2: init 2.0, +1.0 every 10 epochs until 60.
        let s = ThresholdSchedule::PiecewiseEpoch {
            init: 2.0,
            step: 1.0,
            every: 10,
            until: 60,
            steps_per_epoch: 100,
        };
        assert_eq!(s.c(0), 2.0);
        assert_eq!(s.c(999), 2.0); // epoch 9
        assert_eq!(s.c(1000), 3.0); // epoch 10
        assert_eq!(s.c(5999), 7.0); // epoch 59
        assert_eq!(s.c(6000), 8.0); // epoch 60 — frozen after
        assert_eq!(s.c(100_000), 8.0);
    }

    #[test]
    fn parse_specs() {
        assert_eq!(
            ThresholdSchedule::parse("zero"),
            Ok(ThresholdSchedule::Zero)
        );
        assert_eq!(
            ThresholdSchedule::parse("const:5000"),
            Ok(ThresholdSchedule::Constant(5000.0))
        );
        assert_eq!(
            ThresholdSchedule::parse("poly:2:0.5"),
            Ok(ThresholdSchedule::Poly { c0: 2.0, eps: 0.5 })
        );
        assert!(ThresholdSchedule::parse("piecewise:2:1:10:60:100").is_ok());
        assert!(ThresholdSchedule::parse("wat").is_err());
    }

    #[test]
    fn parse_rejects_analysis_violating_schedules() {
        // ε ∉ (0,1) ⇒ c_t is not o(t) (Theorem 1's assumption)
        assert!(ThresholdSchedule::parse("poly:2:-1").is_err());
        assert!(ThresholdSchedule::parse("poly:2:0").is_err());
        assert!(ThresholdSchedule::parse("poly:2:1").is_err());
        assert!(ThresholdSchedule::parse("poly:2:1.5").is_err());
        // non-finite / negative thresholds
        assert!(ThresholdSchedule::parse("poly:-3:0.5").is_err());
        assert!(ThresholdSchedule::parse("poly:inf:0.5").is_err());
        assert!(ThresholdSchedule::parse("poly:nan:0.5").is_err());
        assert!(ThresholdSchedule::parse("const:-5").is_err());
        assert!(ThresholdSchedule::parse("const:inf").is_err());
        assert!(ThresholdSchedule::parse("piecewise:inf:1:10:60:100").is_err());
        assert!(ThresholdSchedule::parse("piecewise:-5:1:10:60:100").is_err());
        assert!(ThresholdSchedule::parse("piecewise:2:-1:10:60:100").is_err());
        // the valid interior still parses
        assert!(ThresholdSchedule::parse("poly:2:0.5").is_ok());
        assert!(ThresholdSchedule::parse("const:0").is_ok());
    }

    #[test]
    fn parse_errors_name_the_offending_field() {
        let err = ThresholdSchedule::parse("poly:2:1.5").unwrap_err();
        assert!(err.contains("(0, 1)"), "{err}");
        assert!(err.contains("1.5"), "{err}");
        let err = ThresholdSchedule::parse("const:-5").unwrap_err();
        assert!(err.contains("non-negative"), "{err}");
        let err = ThresholdSchedule::parse("const:many").unwrap_err();
        assert!(err.contains("not a number") && err.contains("many"), "{err}");
        let err = ThresholdSchedule::parse("piecewise:2:1:0:60:100").unwrap_err();
        assert!(err.contains("every"), "{err}");
        let err = ThresholdSchedule::parse("piecewise:2:1:10:60:0").unwrap_err();
        assert!(err.contains("steps_per_epoch"), "{err}");
        let err = ThresholdSchedule::parse("carousel:5").unwrap_err();
        assert!(err.contains("carousel") && err.contains("expected"), "{err}");
        // wrong arity falls through to the usage message
        assert!(ThresholdSchedule::parse("poly:2").is_err());
        assert!(ThresholdSchedule::parse("piecewise:2:1:10:60").is_err());
    }

    #[test]
    fn poly_eps_limits_behave() {
        // ε → 0⁺: c_t ≈ c0·t (still o(t) formally, nearly linear growth).
        let near_zero = ThresholdSchedule::parse("poly:3:0.001").unwrap();
        let c = near_zero.c(1000);
        assert!(c > 3.0 * 900.0 && c < 3.0 * 1000.0, "c(1000) = {c}");
        // ε → 1⁻: c_t ≈ c0 (nearly constant).
        let near_one = ThresholdSchedule::parse("poly:3:0.999").unwrap();
        let c = near_one.c(1_000_000);
        assert!(c > 3.0 && c < 3.1, "c(1e6) = {c}");
        // both remain monotone non-decreasing in t
        for s in [near_zero, near_one] {
            let mut prev = s.c(1);
            for t in 2..50 {
                let cur = s.c(t);
                assert!(cur >= prev, "{s:?} not monotone at t={t}");
                prev = cur;
            }
        }
    }

    #[test]
    fn poly_t0_first_sync_always_fires() {
        // Satellite pin: c(0) = 0.0 regardless of c0, so the FIRST sync
        // index fires on any nonzero drift (Algorithm 1's bootstrap —
        // x̂^(0) = 0, every node broadcasts its compressed initial
        // parameters in round one). New families inherit this.
        for c0 in [1.0, 5000.0, 1e12] {
            let s = ThresholdSchedule::Poly { c0, eps: 0.5 };
            assert_eq!(s.c(0), 0.0, "c0 = {c0}");
            let tr = EventTrigger::new(s);
            // any drift at all fires at t = 0 (strict > 0)
            assert!(tr.fires_drift(1e-30, 0, 10.0), "c0 = {c0}");
            // ...and zero drift does not (strict inequality)
            assert!(!tr.fires_drift(0.0, 0, 10.0), "c0 = {c0}");
            // while at t = 1 a huge c0 suppresses the same drift
            if c0 >= 5000.0 {
                assert!(!tr.fires_drift(1e-30, 1, 10.0), "c0 = {c0}");
            }
        }
        // per-coordinate mode inherits the t = 0 bootstrap too
        let tr = EventTrigger::new_per_coord(ThresholdSchedule::Poly {
            c0: 5000.0,
            eps: 0.5,
        });
        assert_eq!(tr.coord_threshold(0, 10.0), Some(0.0));
    }

    #[test]
    fn percoord_parse_and_threshold() {
        let tr = EventTrigger::parse("percoord:2.5").unwrap();
        assert!(tr.per_coord);
        assert_eq!(tr.schedule, ThresholdSchedule::Constant(2.5));
        // coord threshold is c · η² in per-coord mode, None otherwise
        assert_eq!(tr.coord_threshold(7, 0.1), Some(2.5 * 0.01));
        let norm = EventTrigger::parse("const:2.5").unwrap();
        assert!(!norm.per_coord);
        assert_eq!(norm.coord_threshold(7, 0.1), None);

        // percoord:0 — every nonzero coordinate fires (strict >)
        let zero = EventTrigger::parse("percoord:0").unwrap();
        assert_eq!(zero.coord_threshold(3, 0.1), Some(0.0));

        // grammar errors name the field and list percoord in the usage
        let err = EventTrigger::parse("percoord:lots").unwrap_err();
        assert!(err.contains("percoord") && err.contains("lots"), "{err}");
        assert!(EventTrigger::parse("percoord:-1").is_err());
        assert!(EventTrigger::parse("percoord:inf").is_err());
        let err = EventTrigger::parse("carousel:5").unwrap_err();
        assert!(err.contains("percoord:C"), "{err}");
        // non-percoord forms delegate unchanged
        assert!(EventTrigger::parse("poly:2:0.5").is_ok());
        assert!(EventTrigger::parse("zero").is_ok());
    }

    #[test]
    fn piecewise_boundaries_are_exact() {
        // Every boundary iteration: the step lands exactly at epoch
        // multiples of `every`, and the freeze at `until` is inclusive.
        let s = ThresholdSchedule::PiecewiseEpoch {
            init: 1.0,
            step: 0.5,
            every: 3,
            until: 9,
            steps_per_epoch: 10,
        };
        // epoch = t / 10, level = min(epoch, 9) / 3
        assert_eq!(s.c(0), 1.0); // epoch 0
        assert_eq!(s.c(29), 1.0); // epoch 2 — last before first step
        assert_eq!(s.c(30), 1.5); // epoch 3 — boundary
        assert_eq!(s.c(59), 1.5); // epoch 5
        assert_eq!(s.c(60), 2.0); // epoch 6
        assert_eq!(s.c(89), 2.0); // epoch 8
        assert_eq!(s.c(90), 2.5); // epoch 9 = until (inclusive)
        assert_eq!(s.c(91), 2.5);
        assert_eq!(s.c(10_000), 2.5); // frozen forever after
    }
}
