//! Event-triggered communication (the paper's headline mechanism).
//!
//! At each synchronization index, node i fires — i.e. transmits a
//! compressed update — only when its local parameter has drifted far
//! enough from the public estimate its neighbors hold (Algorithm 1 line 7):
//!
//! ```text
//! ‖x_i^{t+½} − x̂_i^{(t)}‖² > c_t · η_t²
//! ```
//!
//! Threshold schedules c_t provided (all with c_t ~ o(t) as required by
//! Theorem 1's analysis, except `Constant` which the paper also uses in
//! its experiments before switching to periodic increases):
//!
//! * `Zero` — always fire when reached (SPARQ with local steps only; also
//!   how CHOCO-SGD is expressed in this framework).
//! * `Constant(c0)` — the Section 5.1 initial setting (c₀ = 5000).
//! * `Poly { c0, eps }` — c_t = c₀ · t^{1−ε} (Theorem 1 form).
//! * `PiecewiseEpoch { init, step, every, until }` — the Section 5.2
//!   schedule (2.0, +1.0 every 10 epochs until epoch 60).

use crate::linalg::vecops::dist2;

#[derive(Clone, Debug, PartialEq)]
pub enum ThresholdSchedule {
    Zero,
    Constant(f64),
    /// c_t = c0 * t^(1-eps), eps in (0,1).
    Poly { c0: f64, eps: f64 },
    /// Piecewise-constant in "epochs" of `steps_per_epoch` iterations:
    /// starts at `init`, increases by `step` every `every` epochs, frozen
    /// after `until` epochs.
    PiecewiseEpoch {
        init: f64,
        step: f64,
        every: usize,
        until: usize,
        steps_per_epoch: usize,
    },
}

impl ThresholdSchedule {
    /// c_t at iteration t.
    pub fn c(&self, t: u64) -> f64 {
        match self {
            ThresholdSchedule::Zero => 0.0,
            ThresholdSchedule::Constant(c0) => *c0,
            ThresholdSchedule::Poly { c0, eps } => {
                if t == 0 {
                    0.0
                } else {
                    c0 * (t as f64).powf(1.0 - eps)
                }
            }
            ThresholdSchedule::PiecewiseEpoch {
                init,
                step,
                every,
                until,
                steps_per_epoch,
            } => {
                let epoch = (t as usize / (*steps_per_epoch).max(1)).min(*until);
                init + step * (epoch / (*every).max(1)) as f64
            }
        }
    }

    /// Parse "zero", "const:C", "poly:C0:EPS", "piecewise:INIT:STEP:EVERY:UNTIL:SPE".
    ///
    /// Validated: thresholds must be finite and non-negative, and `poly`
    /// requires ε ∈ (0, 1) — c_t = c₀·t^{1−ε} is o(t) only there, which
    /// is what Theorem 1's analysis assumes (`poly:2:-1` would grow
    /// *superlinearly* and silently void the guarantee).
    pub fn parse(s: &str) -> Option<ThresholdSchedule> {
        let finite_nonneg = |x: f64| x.is_finite() && x >= 0.0;
        let p: Vec<&str> = s.split(':').collect();
        match p.as_slice() {
            ["zero"] => Some(ThresholdSchedule::Zero),
            ["const", c] => {
                let c: f64 = c.parse().ok()?;
                if !finite_nonneg(c) {
                    return None;
                }
                Some(ThresholdSchedule::Constant(c))
            }
            ["poly", c0, eps] => {
                let c0: f64 = c0.parse().ok()?;
                let eps: f64 = eps.parse().ok()?;
                if !finite_nonneg(c0) || !(eps > 0.0 && eps < 1.0) {
                    return None;
                }
                Some(ThresholdSchedule::Poly { c0, eps })
            }
            ["piecewise", init, step, every, until, spe] => {
                let init: f64 = init.parse().ok()?;
                let step: f64 = step.parse().ok()?;
                if !finite_nonneg(init) || !finite_nonneg(step) {
                    return None;
                }
                Some(ThresholdSchedule::PiecewiseEpoch {
                    init,
                    step,
                    every: every.parse().ok()?,
                    until: until.parse().ok()?,
                    steps_per_epoch: spe.parse().ok()?,
                })
            }
            _ => None,
        }
    }
}

/// The event trigger itself.
#[derive(Clone, Debug)]
pub struct EventTrigger {
    pub schedule: ThresholdSchedule,
}

impl EventTrigger {
    pub fn new(schedule: ThresholdSchedule) -> Self {
        EventTrigger { schedule }
    }

    /// Algorithm 1 line 7 (strict inequality).
    pub fn fires(&self, x_half: &[f32], xhat: &[f32], t: u64, eta_t: f64) -> bool {
        let c = self.schedule.c(t);
        dist2(x_half, xhat) > c * eta_t * eta_t
    }

    /// The threshold value c_t η_t² (exposed for metrics/ablations).
    pub fn threshold(&self, t: u64, eta_t: f64) -> f64 {
        self.schedule.c(t) * eta_t * eta_t
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn zero_schedule_fires_on_any_drift() {
        let tr = EventTrigger::new(ThresholdSchedule::Zero);
        let x = vec![1.0f32, 0.0];
        let xh = vec![0.0f32, 0.0];
        assert!(tr.fires(&x, &xh, 0, 0.1));
        // no drift ⇒ strict inequality says no fire
        assert!(!tr.fires(&xh, &xh, 0, 0.1));
    }

    #[test]
    fn constant_threshold_semantics() {
        let tr = EventTrigger::new(ThresholdSchedule::Constant(100.0));
        let eta = 0.1; // threshold = 100 * 0.01 = 1.0
        let xh = vec![0.0f32; 4];
        let below = vec![0.4f32, 0.4, 0.4, 0.4]; // ||.||² = 0.64
        let above = vec![0.6f32, 0.6, 0.6, 0.6]; // ||.||² = 1.44
        assert!(!tr.fires(&below, &xh, 5, eta));
        assert!(tr.fires(&above, &xh, 5, eta));
    }

    #[test]
    fn poly_grows_sublinearly() {
        let s = ThresholdSchedule::Poly { c0: 2.0, eps: 0.5 };
        assert_eq!(s.c(0), 0.0);
        assert!((s.c(100) - 2.0 * 10.0).abs() < 1e-9); // 2 * 100^0.5
        // o(t): c_t / t -> 0
        assert!(s.c(1_000_000) / 1_000_000.0 < 0.01);
    }

    #[test]
    fn piecewise_epoch_schedule_matches_paper() {
        // Section 5.2: init 2.0, +1.0 every 10 epochs until 60.
        let s = ThresholdSchedule::PiecewiseEpoch {
            init: 2.0,
            step: 1.0,
            every: 10,
            until: 60,
            steps_per_epoch: 100,
        };
        assert_eq!(s.c(0), 2.0);
        assert_eq!(s.c(999), 2.0); // epoch 9
        assert_eq!(s.c(1000), 3.0); // epoch 10
        assert_eq!(s.c(5999), 7.0); // epoch 59
        assert_eq!(s.c(6000), 8.0); // epoch 60 — frozen after
        assert_eq!(s.c(100_000), 8.0);
    }

    #[test]
    fn parse_specs() {
        assert_eq!(ThresholdSchedule::parse("zero"), Some(ThresholdSchedule::Zero));
        assert_eq!(
            ThresholdSchedule::parse("const:5000"),
            Some(ThresholdSchedule::Constant(5000.0))
        );
        assert_eq!(
            ThresholdSchedule::parse("poly:2:0.5"),
            Some(ThresholdSchedule::Poly { c0: 2.0, eps: 0.5 })
        );
        assert!(ThresholdSchedule::parse("piecewise:2:1:10:60:100").is_some());
        assert!(ThresholdSchedule::parse("wat").is_none());
    }

    #[test]
    fn parse_rejects_analysis_violating_schedules() {
        // ε ∉ (0,1) ⇒ c_t is not o(t) (Theorem 1's assumption)
        assert!(ThresholdSchedule::parse("poly:2:-1").is_none());
        assert!(ThresholdSchedule::parse("poly:2:0").is_none());
        assert!(ThresholdSchedule::parse("poly:2:1").is_none());
        assert!(ThresholdSchedule::parse("poly:2:1.5").is_none());
        // non-finite / negative thresholds
        assert!(ThresholdSchedule::parse("poly:-3:0.5").is_none());
        assert!(ThresholdSchedule::parse("poly:inf:0.5").is_none());
        assert!(ThresholdSchedule::parse("poly:nan:0.5").is_none());
        assert!(ThresholdSchedule::parse("const:-5").is_none());
        assert!(ThresholdSchedule::parse("const:inf").is_none());
        assert!(ThresholdSchedule::parse("piecewise:inf:1:10:60:100").is_none());
        assert!(ThresholdSchedule::parse("piecewise:-5:1:10:60:100").is_none());
        assert!(ThresholdSchedule::parse("piecewise:2:-1:10:60:100").is_none());
        // the valid interior still parses
        assert!(ThresholdSchedule::parse("poly:2:0.5").is_some());
        assert!(ThresholdSchedule::parse("const:0").is_some());
    }
}
