//! Synthetic byte corpus + LM batcher for the end-to-end transformer run.
//!
//! A Markov "toy language": sentences assembled from a closed vocabulary
//! of words with a bigram transition structure, emitted as bytes. The LM
//! can drive its loss well below the unigram entropy, so the E2E driver
//! has a real learnable signal while remaining fully self-contained.

use crate::util::Rng;

const WORDS: &[&str] = &[
    "the", "quick", "brown", "fox", "jumps", "over", "lazy", "dog",
    "gradient", "descent", "converges", "slowly", "consensus", "spreads",
    "across", "sparse", "networks", "while", "signals", "decay",
    "nodes", "compress", "their", "updates", "and", "triggers", "fire",
    "rarely", "near", "optimum",
];

/// Generate `n_bytes` of toy text with bigram structure.
pub fn generate_corpus(n_bytes: usize, seed: u64) -> Vec<u8> {
    let mut rng = Rng::new(seed ^ 0xC0_4B5);
    let mut out = Vec::with_capacity(n_bytes + 16);
    let mut prev = rng.below(WORDS.len());
    while out.len() < n_bytes {
        // bigram: next word depends deterministically-ish on prev
        let jump = 1 + rng.below(3);
        let next = (prev * 7 + jump) % WORDS.len();
        out.extend_from_slice(WORDS[next].as_bytes());
        out.push(b' ');
        if rng.below(12) == 0 {
            out.pop();
            out.extend_from_slice(b". ");
        }
        prev = next;
    }
    out.truncate(n_bytes);
    out
}

/// Batcher yielding [b × (seq+1)] i32 token windows.
pub struct LmBatcher {
    corpus: Vec<u8>,
    pub seq: usize,
}

impl LmBatcher {
    pub fn new(corpus: Vec<u8>, seq: usize) -> Self {
        assert!(corpus.len() > seq + 1, "corpus shorter than one window");
        LmBatcher { corpus, seq }
    }

    /// Random contiguous windows, flattened row-major.
    pub fn batch(&self, b: usize, rng: &mut Rng) -> Vec<i32> {
        let mut out = Vec::with_capacity(b * (self.seq + 1));
        for _ in 0..b {
            let start = rng.below(self.corpus.len() - self.seq - 1);
            out.extend(
                self.corpus[start..start + self.seq + 1]
                    .iter()
                    .map(|&c| c as i32),
            );
        }
        out
    }

    pub fn len(&self) -> usize {
        self.corpus.len()
    }

    pub fn is_empty(&self) -> bool {
        self.corpus.is_empty()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn corpus_properties() {
        let c = generate_corpus(5000, 1);
        assert_eq!(c.len(), 5000);
        // printable ASCII only
        assert!(c.iter().all(|&b| (b' '..=b'z').contains(&b)));
        // deterministic
        assert_eq!(c, generate_corpus(5000, 1));
        assert_ne!(c, generate_corpus(5000, 2));
    }

    #[test]
    fn corpus_has_structure() {
        // Bigram structure ⇒ some byte pairs are far more common than
        // uniform; check the most common pair frequency is > 3%.
        let c = generate_corpus(20_000, 3);
        let mut counts = std::collections::HashMap::new();
        for w in c.windows(2) {
            *counts.entry((w[0], w[1])).or_insert(0usize) += 1;
        }
        let max = counts.values().max().unwrap();
        assert!(*max as f64 / c.len() as f64 > 0.03);
    }

    #[test]
    fn batch_windows() {
        let b = LmBatcher::new(generate_corpus(2000, 4), 32);
        let mut rng = Rng::new(5);
        let batch = b.batch(4, &mut rng);
        assert_eq!(batch.len(), 4 * 33);
        assert!(batch.iter().all(|&t| (0..256).contains(&t)));
    }
}
