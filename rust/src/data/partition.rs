//! Heterogeneous data partitioning across nodes.
//!
//! Section 5.1 runs n = 60 nodes with a "heterogeneous distribution of
//! data across classes": each node's local dataset is dominated by a few
//! classes. `by_class_shards` reproduces that (each node draws from
//! `classes_per_node` classes chosen round-robin), `iid_split` is the
//! homogeneous control.

use super::synthetic::{ClassGaussian, Dataset};
use crate::util::Rng;

/// One node's local data plus a batch sampler.
#[derive(Clone, Debug)]
pub struct Partition {
    pub shards: Vec<Dataset>,
}

impl Partition {
    pub fn n_nodes(&self) -> usize {
        self.shards.len()
    }

    /// Sample a mini-batch of `b` rows (with replacement) from node i.
    pub fn batch(&self, node: usize, b: usize, rng: &mut Rng) -> (Vec<f32>, Vec<i32>) {
        let ds = &self.shards[node];
        let idx: Vec<usize> = (0..b).map(|_| rng.below(ds.len())).collect();
        ds.gather(&idx)
    }
}

/// Heterogeneous by-class sharding: node i draws `per_node` samples from
/// `classes_per_node` classes starting at class (i * classes_per_node)
/// mod C — adjacent nodes see (mostly) different classes.
pub fn by_class_shards(
    gen: &ClassGaussian,
    n_nodes: usize,
    per_node: usize,
    classes_per_node: usize,
    rng: &mut Rng,
) -> Partition {
    assert!(classes_per_node >= 1 && classes_per_node <= gen.classes);
    let mut shards = Vec::with_capacity(n_nodes);
    for i in 0..n_nodes {
        let mut xs = Vec::new();
        let mut ys = Vec::new();
        let base = (i * classes_per_node) % gen.classes;
        for j in 0..per_node {
            let c = (base + j % classes_per_node) % gen.classes;
            let s = gen.generate_class(1, c, rng);
            xs.extend_from_slice(&s.x);
            ys.extend_from_slice(&s.y);
        }
        shards.push(Dataset {
            dim: gen.dim,
            classes: gen.classes,
            x: xs,
            y: ys,
        });
    }
    Partition { shards }
}

/// IID split: every node draws from the global mixture.
pub fn iid_split(
    gen: &ClassGaussian,
    n_nodes: usize,
    per_node: usize,
    rng: &mut Rng,
) -> Partition {
    Partition {
        shards: (0..n_nodes).map(|_| gen.generate(per_node, rng)).collect(),
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn by_class_is_heterogeneous() {
        let gen = ClassGaussian::new(8, 10, 1.0, 1);
        let mut rng = Rng::new(2);
        let p = by_class_shards(&gen, 5, 40, 2, &mut rng);
        assert_eq!(p.n_nodes(), 5);
        for (i, shard) in p.shards.iter().enumerate() {
            let mut classes: Vec<i32> = shard.y.clone();
            classes.sort_unstable();
            classes.dedup();
            assert_eq!(classes.len(), 2, "node {i} classes {classes:?}");
        }
        // node 0 and node 1 see disjoint classes (0,1) vs (2,3)
        assert_ne!(p.shards[0].y[0], p.shards[1].y[0]);
    }

    #[test]
    fn iid_sees_many_classes() {
        let gen = ClassGaussian::new(8, 10, 1.0, 3);
        let mut rng = Rng::new(4);
        let p = iid_split(&gen, 2, 200, &mut rng);
        let mut classes: Vec<i32> = p.shards[0].y.clone();
        classes.sort_unstable();
        classes.dedup();
        assert!(classes.len() >= 8);
    }

    #[test]
    fn batch_shapes() {
        let gen = ClassGaussian::new(6, 4, 1.0, 5);
        let mut rng = Rng::new(6);
        let p = iid_split(&gen, 3, 50, &mut rng);
        let (xs, ys) = p.batch(1, 7, &mut rng);
        assert_eq!(xs.len(), 42);
        assert_eq!(ys.len(), 7);
    }
}
