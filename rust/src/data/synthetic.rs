//! Class-conditional Gaussian datasets ("synthetic MNIST / CIFAR").
//!
//! Each class c gets a mean vector μ_c drawn once from N(0, sep²·I); a
//! sample of class c is x = μ_c + N(0, I). `sep` controls class
//! separability and therefore the attainable test error — the defaults
//! give logistic regression a ~0.1 test error at convergence, matching
//! the regime of the paper's Figure 1a/1b.

use crate::util::Rng;

/// Dense dataset with int labels.
#[derive(Clone, Debug)]
pub struct Dataset {
    pub dim: usize,
    pub classes: usize,
    /// Row-major [n_samples × dim].
    pub x: Vec<f32>,
    pub y: Vec<i32>,
}

impl Dataset {
    pub fn len(&self) -> usize {
        self.y.len()
    }

    pub fn is_empty(&self) -> bool {
        self.y.is_empty()
    }

    pub fn sample(&self, i: usize) -> (&[f32], i32) {
        (&self.x[i * self.dim..(i + 1) * self.dim], self.y[i])
    }

    /// Gather rows into a contiguous batch (xs, ys).
    pub fn gather(&self, idx: &[usize]) -> (Vec<f32>, Vec<i32>) {
        let mut xs = Vec::with_capacity(idx.len() * self.dim);
        let mut ys = Vec::with_capacity(idx.len());
        for &i in idx {
            let (row, label) = self.sample(i);
            xs.extend_from_slice(row);
            ys.push(label);
        }
        (xs, ys)
    }
}

/// Class-Gaussian generator.
#[derive(Clone, Debug)]
pub struct ClassGaussian {
    pub dim: usize,
    pub classes: usize,
    /// Separation of class means (in units of the within-class sd).
    pub sep: f32,
    means: Vec<f32>, // [classes × dim]
}

impl ClassGaussian {
    pub fn new(dim: usize, classes: usize, sep: f32, seed: u64) -> Self {
        let mut rng = Rng::new(seed ^ 0xC1A55);
        let mut means = vec![0.0f32; classes * dim];
        rng.fill_normal(&mut means, sep);
        ClassGaussian {
            dim,
            classes,
            sep,
            means,
        }
    }

    pub fn mean(&self, c: usize) -> &[f32] {
        &self.means[c * self.dim..(c + 1) * self.dim]
    }

    /// Generate `n` samples with uniformly random labels.
    pub fn generate(&self, n: usize, rng: &mut Rng) -> Dataset {
        let mut x = vec![0.0f32; n * self.dim];
        let mut y = vec![0i32; n];
        for i in 0..n {
            let c = rng.below(self.classes);
            y[i] = c as i32;
            let mu = self.mean(c);
            let row = &mut x[i * self.dim..(i + 1) * self.dim];
            for (v, m) in row.iter_mut().zip(mu.iter()) {
                *v = m + rng.normal_f32();
            }
        }
        Dataset {
            dim: self.dim,
            classes: self.classes,
            x,
            y,
        }
    }

    /// Generate `n` samples all of class `c` (for heterogeneous shards).
    pub fn generate_class(&self, n: usize, c: usize, rng: &mut Rng) -> Dataset {
        let mut x = vec![0.0f32; n * self.dim];
        let mu = self.mean(c);
        for i in 0..n {
            let row = &mut x[i * self.dim..(i + 1) * self.dim];
            for (v, m) in row.iter_mut().zip(mu.iter()) {
                *v = m + rng.normal_f32();
            }
        }
        Dataset {
            dim: self.dim,
            classes: self.classes,
            x,
            y: vec![c as i32; n],
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn shapes_and_labels() {
        let gen = ClassGaussian::new(20, 4, 2.0, 1);
        let mut rng = Rng::new(2);
        let ds = gen.generate(100, &mut rng);
        assert_eq!(ds.len(), 100);
        assert_eq!(ds.x.len(), 2000);
        assert!(ds.y.iter().all(|&c| (0..4).contains(&(c as usize))));
    }

    #[test]
    fn deterministic_given_seed() {
        let g1 = ClassGaussian::new(10, 3, 1.0, 7);
        let g2 = ClassGaussian::new(10, 3, 1.0, 7);
        let mut r1 = Rng::new(9);
        let mut r2 = Rng::new(9);
        assert_eq!(g1.generate(10, &mut r1).x, g2.generate(10, &mut r2).x);
    }

    #[test]
    fn classes_are_separated() {
        // With sep = 4, a nearest-mean classifier should be near-perfect.
        let gen = ClassGaussian::new(30, 3, 4.0, 3);
        let mut rng = Rng::new(4);
        let ds = gen.generate(300, &mut rng);
        let mut correct = 0;
        for i in 0..ds.len() {
            let (row, label) = ds.sample(i);
            let best = (0..3)
                .min_by(|&a, &b| {
                    let da: f32 = row
                        .iter()
                        .zip(gen.mean(a))
                        .map(|(x, m)| (x - m) * (x - m))
                        .sum();
                    let db: f32 = row
                        .iter()
                        .zip(gen.mean(b))
                        .map(|(x, m)| (x - m) * (x - m))
                        .sum();
                    da.partial_cmp(&db).unwrap()
                })
                .unwrap();
            if best as i32 == label {
                correct += 1;
            }
        }
        assert!(correct > 290, "correct = {correct}/300");
    }

    #[test]
    fn gather_batches() {
        let gen = ClassGaussian::new(5, 2, 1.0, 5);
        let mut rng = Rng::new(6);
        let ds = gen.generate(10, &mut rng);
        let (xs, ys) = ds.gather(&[0, 3, 7]);
        assert_eq!(xs.len(), 15);
        assert_eq!(ys.len(), 3);
        assert_eq!(&xs[5..10], ds.sample(3).0);
    }
}
