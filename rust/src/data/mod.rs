//! Synthetic datasets and heterogeneous partitioning.
//!
//! DESIGN.md §Substitutions: no MNIST/CIFAR files exist in this
//! environment, so class-conditional Gaussian generators stand in. The
//! properties SPARQ-SGD's experiments exercise are (a) a well-conditioned
//! ERM landscape with a meaningful test error and (b) *heterogeneous*
//! local distributions (Section 5.1: "heterogeneous distribution of data
//! across classes") — both are controlled explicitly here.

pub mod synthetic;
pub mod partition;
pub mod corpus;

pub use partition::{by_class_shards, iid_split, Partition};
pub use synthetic::{ClassGaussian, Dataset};
