//! Spectral quantities of the mixing matrix and the Lemma-6 consensus
//! step size.
//!
//! δ = 1 − |λ₂(W)| (spectral gap), β = max_i (1 − λ_i(W)) = ‖I − W‖₂, and
//!
//! ```text
//! γ* = 2δω / (64δ + δ² + 16β² + 8δβ² − 16δω)          (Lemma 6)
//! p  = γ*δ / 8                                         (Theorem 1)
//! ```
//!
//! with the paper's crude bound p ≥ δ²ω/644 used as a sanity check.

use super::mixing::MixingMatrix;
use crate::linalg::symmetric_eigenvalues;

#[derive(Clone, Copy, Debug)]
pub struct SpectralInfo {
    /// λ₁ (should be 1 for doubly-stochastic W).
    pub lambda1: f64,
    /// Second-largest eigenvalue *in absolute value*.
    pub lambda2_abs: f64,
    /// δ = 1 − |λ₂|.
    pub delta: f64,
    /// β = max_i (1 − λ_i) = 1 − λ_min.
    pub beta: f64,
}

impl SpectralInfo {
    pub fn compute(mm: &MixingMatrix) -> SpectralInfo {
        let eigs = symmetric_eigenvalues(&mm.w, 1e-12);
        let n = eigs.len();
        let lambda1 = eigs[0];
        // |λ₂| = max absolute eigenvalue excluding one copy of λ₁ = 1.
        let lambda2_abs = if n == 1 {
            0.0
        } else {
            // eigs sorted descending; candidates are eigs[1] (next largest)
            // and eigs[n-1] (most negative).
            eigs[1].abs().max(eigs[n - 1].abs())
        };
        let beta = 1.0 - eigs[n - 1];
        SpectralInfo {
            lambda1,
            lambda2_abs,
            delta: 1.0 - lambda2_abs,
            beta,
        }
    }

    /// Lemma 6 consensus step size γ* for compression parameter ω.
    pub fn gamma_star(&self, omega: f64) -> f64 {
        let d = self.delta;
        let b2 = self.beta * self.beta;
        2.0 * d * omega / (64.0 * d + d * d + 16.0 * b2 + 8.0 * d * b2 - 16.0 * d * omega)
    }

    /// p = γδ/8 (Theorem 1), for the given γ.
    pub fn p(&self, gamma: f64) -> f64 {
        gamma * self.delta / 8.0
    }

    /// Paper's crude lower bound p ≥ δ²ω/644.
    pub fn p_lower_bound(&self, omega: f64) -> f64 {
        self.delta * self.delta * omega / 644.0
    }

    /// Practical consensus step size: the Lemma-6 γ* is a worst-case
    /// guarantee that is orders of magnitude conservative (the paper's
    /// experiments, like CHOCO-SGD's, grid-search γ). This heuristic uses
    /// the *typical-case* compression quality with a square-root scaling
    /// matched to a γ sweep on the Fig-1c workload (EXPERIMENTS.md
    /// §Ablations): γ = max(γ*, min(0.5, √ω_eff)).
    pub fn gamma_tuned(&self, omega_contract: f64, omega_eff: f64) -> f64 {
        let star = self.gamma_star(omega_contract);
        star.max(omega_eff.sqrt().min(0.5))
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::graph::mixing::uniform_neighbor;
    use crate::graph::topology::{Topology, TopologyKind};

    fn info(kind: TopologyKind, n: usize) -> SpectralInfo {
        let t = Topology::new(kind, n, 5);
        SpectralInfo::compute(&uniform_neighbor(&t))
    }

    #[test]
    fn lambda1_is_one() {
        for (kind, n) in [
            (TopologyKind::Ring, 12),
            (TopologyKind::Complete, 8),
            (TopologyKind::Torus, 16),
        ] {
            let s = info(kind, n);
            assert!((s.lambda1 - 1.0).abs() < 1e-9, "{kind:?}");
            assert!(s.delta > 0.0 && s.delta <= 1.0, "{kind:?} δ={}", s.delta);
            assert!(s.beta <= 2.0 + 1e-9);
        }
    }

    #[test]
    fn ring_delta_closed_form() {
        // Uniform ring weights: λ_k = 1/3 + 2/3 cos(2πk/n);
        // |λ₂| = 1/3 + 2/3 cos(2π/n) for moderate n (positive branch wins).
        let n = 12;
        let s = info(TopologyKind::Ring, n);
        let expect = 1.0 / 3.0 + 2.0 / 3.0 * (2.0 * std::f64::consts::PI / n as f64).cos();
        assert!((s.lambda2_abs - expect).abs() < 1e-9);
    }

    #[test]
    fn complete_has_max_gap() {
        // Uniform weights on complete graph: W = J/n, λ₂ = 0 ⇒ δ = 1.
        let s = info(TopologyKind::Complete, 8);
        assert!((s.delta - 1.0).abs() < 1e-9);
    }

    #[test]
    fn better_connectivity_larger_gap() {
        let ring = info(TopologyKind::Ring, 16);
        let torus = info(TopologyKind::Torus, 16);
        let complete = info(TopologyKind::Complete, 16);
        assert!(ring.delta < torus.delta);
        assert!(torus.delta < complete.delta);
    }

    #[test]
    fn gamma_star_in_unit_interval_and_p_bound() {
        for omega in [0.05, 0.3, 1.0] {
            let s = info(TopologyKind::Ring, 60);
            let g = s.gamma_star(omega);
            assert!(g > 0.0 && g <= 1.0, "γ*={g}");
            let p = s.p(g);
            assert!(
                p >= s.p_lower_bound(omega) - 1e-12,
                "p={p} < bound {}",
                s.p_lower_bound(omega)
            );
        }
    }

    #[test]
    fn gamma_monotone_in_omega() {
        let s = info(TopologyKind::Ring, 20);
        assert!(s.gamma_star(0.1) < s.gamma_star(0.5));
        assert!(s.gamma_star(0.5) < s.gamma_star(1.0));
    }
}
