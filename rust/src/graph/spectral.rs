//! Spectral quantities of the mixing matrix and the Lemma-6 consensus
//! step size.
//!
//! δ = 1 − |λ₂(W)| (spectral gap), β = max_i (1 − λ_i(W)) = ‖I − W‖₂, and
//!
//! ```text
//! γ* = 2δω / (64δ + δ² + 16β² + 8δβ² − 16δω)          (Lemma 6)
//! p  = γ*δ / 8                                         (Theorem 1)
//! ```
//!
//! with the paper's crude bound p ≥ δ²ω/644 used as a sanity check.
//!
//! Two solver paths, dispatched on n:
//!
//! * **Dense (n ≤ [`DENSE_EIGEN_MAX_N`])** — materialize W and run the
//!   cyclic-Jacobi solver: exact full spectrum, bit-identical to the
//!   historical dense implementation for every paper-scale graph.
//! * **Iterative (n > [`DENSE_EIGEN_MAX_N`])** — two Lanczos runs on the
//!   O(|E|) sparse operator: one on W for λ₁ and λ_min (β must come from
//!   W itself — λ_min > 0 is possible for lazy matrices, so deflation
//!   would hide it), one on the mean-deflated B = W − (1/n)·11ᵀW for the
//!   second-largest eigenvalue. |λ₂| = max(θ_max(B), 0, −λ_min). Ritz
//!   values sit *inside* the true spectrum, so the estimates err toward
//!   a larger δ and smaller β; the tolerance contract is pinned by
//!   `tests/scale_sparse.rs` (dense vs iterative ≤ 1e-8 at small n).

use super::mixing::MixingMatrix;
use crate::linalg::lanczos::{lanczos_extremes, SymOp, LANCZOS_MAX_ITERS};
use crate::linalg::symmetric_eigenvalues;

/// Largest n solved by dense Jacobi; above this the Lanczos path runs.
/// Every historical experiment (n ≤ 60) and test graph sits below the
/// threshold, so small-n spectral numbers — and hence tuned γ and
/// `config_hash`-adjacent series — stay bit-identical.
pub const DENSE_EIGEN_MAX_N: usize = 256;

/// Fixed seed for the Lanczos start vectors (spectral results must be
/// deterministic — they feed tuned γ and the artifact cache).
const LANCZOS_SEED: u64 = 0x5bec_19a1;

#[derive(Clone, Copy, Debug)]
pub struct SpectralInfo {
    /// λ₁ (should be 1 for doubly-stochastic W).
    pub lambda1: f64,
    /// Second-largest eigenvalue *in absolute value*.
    pub lambda2_abs: f64,
    /// δ = 1 − |λ₂|.
    pub delta: f64,
    /// β = max_i (1 − λ_i) = 1 − λ_min.
    pub beta: f64,
}

/// W with the λ₁ = 1 eigenspace (the all-ones vector) projected out:
/// B x = P W P x where P = I − (1/n)·11ᵀ. Symmetric, same spectrum as W
/// minus one copy of λ₁, so its largest eigenvalue is λ₂ (or 0 if the
/// rest of the spectrum is negative).
struct DeflatedMixing<'a>(&'a MixingMatrix);

fn subtract_mean(x: &mut [f64]) {
    let mean = x.iter().sum::<f64>() / x.len() as f64;
    for v in x.iter_mut() {
        *v -= mean;
    }
}

impl SymOp for DeflatedMixing<'_> {
    fn n(&self) -> usize {
        self.0.n()
    }
    fn apply(&self, x: &[f64], y: &mut [f64]) {
        let mut px = x.to_vec();
        subtract_mean(&mut px);
        self.0.matvec_into(&px, y);
        subtract_mean(y);
    }
}

impl SymOp for MixingMatrix {
    fn n(&self) -> usize {
        MixingMatrix::n(self)
    }
    fn apply(&self, x: &[f64], y: &mut [f64]) {
        self.matvec_into(x, y);
    }
}

impl SpectralInfo {
    pub fn compute(mm: &MixingMatrix) -> SpectralInfo {
        if mm.n() <= DENSE_EIGEN_MAX_N {
            Self::compute_dense(mm)
        } else {
            Self::compute_iterative(mm)
        }
    }

    /// Exact full-spectrum path: dense W + cyclic Jacobi (O(n³)).
    pub fn compute_dense(mm: &MixingMatrix) -> SpectralInfo {
        let eigs = symmetric_eigenvalues(&mm.to_dense(), 1e-12);
        let n = eigs.len();
        let lambda1 = eigs[0];
        // |λ₂| = max absolute eigenvalue excluding one copy of λ₁ = 1.
        let lambda2_abs = if n == 1 {
            0.0
        } else {
            // eigs sorted descending; candidates are eigs[1] (next largest)
            // and eigs[n-1] (most negative).
            eigs[1].abs().max(eigs[n - 1].abs())
        };
        let beta = 1.0 - eigs[n - 1];
        SpectralInfo {
            lambda1,
            lambda2_abs,
            delta: 1.0 - lambda2_abs,
            beta,
        }
    }

    /// Sparse path: extremal eigenvalues only, via O(|E|)-matvec Lanczos.
    pub fn compute_iterative(mm: &MixingMatrix) -> SpectralInfo {
        let m = LANCZOS_MAX_ITERS.min(mm.n());
        // Run 1: W itself → λ₁ (top) and λ_min (bottom, for β).
        let w_ext = lanczos_extremes(mm, m, LANCZOS_SEED);
        // Run 2: mean-deflated W → λ₂ from above (clamped at 0: a
        // deflated spectrum that is entirely negative contributes no
        // positive candidate for |λ₂|).
        let b_ext = lanczos_extremes(&DeflatedMixing(mm), m, LANCZOS_SEED ^ 0x9e3779b97f4a7c15);
        let lambda_min = w_ext.theta_min;
        let lambda2_abs = b_ext.theta_max.max(0.0).max(-lambda_min);
        SpectralInfo {
            lambda1: w_ext.theta_max,
            lambda2_abs,
            delta: 1.0 - lambda2_abs,
            beta: 1.0 - lambda_min,
        }
    }

    /// Lemma 6 consensus step size γ* for compression parameter ω.
    pub fn gamma_star(&self, omega: f64) -> f64 {
        let d = self.delta;
        let b2 = self.beta * self.beta;
        2.0 * d * omega / (64.0 * d + d * d + 16.0 * b2 + 8.0 * d * b2 - 16.0 * d * omega)
    }

    /// p = γδ/8 (Theorem 1), for the given γ.
    pub fn p(&self, gamma: f64) -> f64 {
        gamma * self.delta / 8.0
    }

    /// Paper's crude lower bound p ≥ δ²ω/644.
    pub fn p_lower_bound(&self, omega: f64) -> f64 {
        self.delta * self.delta * omega / 644.0
    }

    /// Practical consensus step size: the Lemma-6 γ* is a worst-case
    /// guarantee that is orders of magnitude conservative (the paper's
    /// experiments, like CHOCO-SGD's, grid-search γ). This heuristic uses
    /// the *typical-case* compression quality with a square-root scaling
    /// matched to a γ sweep on the Fig-1c workload (EXPERIMENTS.md
    /// §Ablations): γ = max(γ*, min(0.5, √ω_eff)).
    pub fn gamma_tuned(&self, omega_contract: f64, omega_eff: f64) -> f64 {
        let star = self.gamma_star(omega_contract);
        star.max(omega_eff.sqrt().min(0.5))
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::graph::mixing::uniform_neighbor;
    use crate::graph::topology::{Topology, TopologyKind};

    fn info(kind: TopologyKind, n: usize) -> SpectralInfo {
        let t = Topology::new(kind, n, 5);
        SpectralInfo::compute(&uniform_neighbor(&t))
    }

    #[test]
    fn lambda1_is_one() {
        for (kind, n) in [
            (TopologyKind::Ring, 12),
            (TopologyKind::Complete, 8),
            (TopologyKind::Torus, 16),
        ] {
            let s = info(kind, n);
            assert!((s.lambda1 - 1.0).abs() < 1e-9, "{kind:?}");
            assert!(s.delta > 0.0 && s.delta <= 1.0, "{kind:?} δ={}", s.delta);
            assert!(s.beta <= 2.0 + 1e-9);
        }
    }

    #[test]
    fn ring_delta_closed_form() {
        // Uniform ring weights: λ_k = 1/3 + 2/3 cos(2πk/n);
        // |λ₂| = 1/3 + 2/3 cos(2π/n) for moderate n (positive branch wins).
        let n = 12;
        let s = info(TopologyKind::Ring, n);
        let expect = 1.0 / 3.0 + 2.0 / 3.0 * (2.0 * std::f64::consts::PI / n as f64).cos();
        assert!((s.lambda2_abs - expect).abs() < 1e-9);
    }

    #[test]
    fn complete_has_max_gap() {
        // Uniform weights on complete graph: W = J/n, λ₂ = 0 ⇒ δ = 1.
        let s = info(TopologyKind::Complete, 8);
        assert!((s.delta - 1.0).abs() < 1e-9);
    }

    #[test]
    fn better_connectivity_larger_gap() {
        let ring = info(TopologyKind::Ring, 16);
        let torus = info(TopologyKind::Torus, 16);
        let complete = info(TopologyKind::Complete, 16);
        assert!(ring.delta < torus.delta);
        assert!(torus.delta < complete.delta);
    }

    #[test]
    fn gamma_star_in_unit_interval_and_p_bound() {
        for omega in [0.05, 0.3, 1.0] {
            let s = info(TopologyKind::Ring, 60);
            let g = s.gamma_star(omega);
            assert!(g > 0.0 && g <= 1.0, "γ*={g}");
            let p = s.p(g);
            assert!(
                p >= s.p_lower_bound(omega) - 1e-12,
                "p={p} < bound {}",
                s.p_lower_bound(omega)
            );
        }
    }

    #[test]
    fn gamma_monotone_in_omega() {
        let s = info(TopologyKind::Ring, 20);
        assert!(s.gamma_star(0.1) < s.gamma_star(0.5));
        assert!(s.gamma_star(0.5) < s.gamma_star(1.0));
    }

    #[test]
    fn iterative_matches_ring_closed_form_above_threshold() {
        // n = 300 > DENSE_EIGEN_MAX_N exercises the Lanczos path against
        // the uniform-ring closed form λ_k = 1/3 + 2/3·cos(2πk/n).
        let n = 300;
        let s = info(TopologyKind::Ring, n);
        let two_pi = 2.0 * std::f64::consts::PI;
        let lam2 = 1.0 / 3.0 + 2.0 / 3.0 * (two_pi / n as f64).cos();
        let lam_min = (0..n)
            .map(|k| 1.0 / 3.0 + 2.0 / 3.0 * (two_pi * k as f64 / n as f64).cos())
            .fold(f64::INFINITY, f64::min);
        assert!((s.lambda1 - 1.0).abs() < 1e-8, "λ₁={}", s.lambda1);
        assert!((s.lambda2_abs - lam2).abs() < 1e-7, "|λ₂|={}", s.lambda2_abs);
        assert!((s.beta - (1.0 - lam_min)).abs() < 1e-7, "β={}", s.beta);
    }

    #[test]
    fn dense_and_iterative_agree_below_threshold() {
        let t = Topology::new(TopologyKind::Torus, 36, 0);
        let mm = uniform_neighbor(&t);
        let d = SpectralInfo::compute_dense(&mm);
        let i = SpectralInfo::compute_iterative(&mm);
        assert!((d.lambda2_abs - i.lambda2_abs).abs() < 1e-8);
        assert!((d.beta - i.beta).abs() < 1e-8);
    }
}
