//! Graph topologies.
//!
//! The paper's experiments use rings (n = 60 convex, n = 8 non-convex);
//! footnote 5 points at expander graphs as the design sweet spot (constant
//! degree, large spectral gap) — `RandomRegular` plus `Hypercube`/`Torus`
//! let `examples/topology_sweep.rs` reproduce that comparison.

use crate::util::Rng;

#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum TopologyKind {
    Ring,
    Complete,
    Star,
    Path,
    /// 2-D torus grid (n must be a perfect square).
    Torus,
    Hypercube,
    /// Random d-regular graph (expander with high probability).
    RandomRegular(usize),
}

impl TopologyKind {
    /// Canonical spec string (inverse of [`parse`](Self::parse)).
    pub fn spec_str(&self) -> String {
        match self {
            TopologyKind::Ring => "ring".into(),
            TopologyKind::Complete => "complete".into(),
            TopologyKind::Star => "star".into(),
            TopologyKind::Path => "path".into(),
            TopologyKind::Torus => "torus".into(),
            TopologyKind::Hypercube => "hypercube".into(),
            TopologyKind::RandomRegular(d) => format!("regular{d}"),
        }
    }

    /// Is this kind constructible on n nodes? Returns the constraint it
    /// violates otherwise — the checks [`Topology::new`] would assert on,
    /// surfaced at config-resolve time instead of run time.
    pub fn check_nodes(&self, n: usize) -> Result<(), String> {
        if n == 0 {
            return Err("need at least one node".into());
        }
        match self {
            TopologyKind::Torus => {
                let side = (n as f64).sqrt().round() as usize;
                if side * side != n {
                    return Err(format!("torus needs a perfect-square node count, got {n}"));
                }
            }
            TopologyKind::Hypercube => {
                if !n.is_power_of_two() {
                    return Err(format!("hypercube needs a power-of-two node count, got {n}"));
                }
            }
            TopologyKind::RandomRegular(d) => {
                if *d == 0 {
                    return Err("regular degree must be >= 1".into());
                }
                if *d >= n {
                    return Err(format!("regular degree {d} must be < node count {n}"));
                }
                if n * d % 2 != 0 {
                    return Err(format!("regular graph needs n·d even, got n={n} d={d}"));
                }
            }
            _ => {}
        }
        Ok(())
    }

    pub fn parse(s: &str) -> Option<TopologyKind> {
        match s {
            "ring" => Some(TopologyKind::Ring),
            "complete" => Some(TopologyKind::Complete),
            "star" => Some(TopologyKind::Star),
            "path" => Some(TopologyKind::Path),
            "torus" => Some(TopologyKind::Torus),
            "hypercube" => Some(TopologyKind::Hypercube),
            s if s.starts_with("regular") => {
                s.strip_prefix("regular")
                    .and_then(|d| d.parse().ok())
                    .map(TopologyKind::RandomRegular)
            }
            _ => None,
        }
    }
}

/// Undirected graph as adjacency lists (sorted, no self-loops).
#[derive(Clone, Debug)]
pub struct Topology {
    pub n: usize,
    pub kind: TopologyKind,
    pub neighbors: Vec<Vec<usize>>,
}

impl Topology {
    pub fn new(kind: TopologyKind, n: usize, seed: u64) -> Topology {
        assert!(n >= 1, "need at least one node");
        let neighbors = match kind {
            TopologyKind::Ring => ring(n),
            TopologyKind::Complete => complete(n),
            TopologyKind::Star => star(n),
            TopologyKind::Path => path(n),
            TopologyKind::Torus => torus(n),
            TopologyKind::Hypercube => hypercube(n),
            TopologyKind::RandomRegular(d) => random_regular(n, d, seed),
        };
        let mut t = Topology { n, kind, neighbors };
        t.normalize();
        t
    }

    fn normalize(&mut self) {
        for (i, adj) in self.neighbors.iter_mut().enumerate() {
            adj.sort_unstable();
            adj.dedup();
            adj.retain(|&j| j != i);
        }
    }

    pub fn degree(&self, i: usize) -> usize {
        self.neighbors[i].len()
    }

    pub fn max_degree(&self) -> usize {
        self.neighbors.iter().map(Vec::len).max().unwrap_or(0)
    }

    pub fn edge_count(&self) -> usize {
        self.neighbors.iter().map(Vec::len).sum::<usize>() / 2
    }

    pub fn is_connected(&self) -> bool {
        if self.n == 0 {
            return true;
        }
        let mut seen = vec![false; self.n];
        let mut stack = vec![0usize];
        seen[0] = true;
        let mut count = 1;
        while let Some(u) = stack.pop() {
            for &v in &self.neighbors[u] {
                if !seen[v] {
                    seen[v] = true;
                    count += 1;
                    stack.push(v);
                }
            }
        }
        count == self.n
    }

    pub fn is_undirected(&self) -> bool {
        for (i, adj) in self.neighbors.iter().enumerate() {
            for &j in adj {
                if !self.neighbors[j].contains(&i) {
                    return false;
                }
            }
        }
        true
    }
}

fn ring(n: usize) -> Vec<Vec<usize>> {
    (0..n)
        .map(|i| {
            if n == 1 {
                vec![]
            } else if n == 2 {
                vec![(i + 1) % 2]
            } else {
                vec![(i + n - 1) % n, (i + 1) % n]
            }
        })
        .collect()
}

fn complete(n: usize) -> Vec<Vec<usize>> {
    (0..n)
        .map(|i| (0..n).filter(|&j| j != i).collect())
        .collect()
}

fn star(n: usize) -> Vec<Vec<usize>> {
    (0..n)
        .map(|i| {
            if i == 0 {
                (1..n).collect()
            } else {
                vec![0]
            }
        })
        .collect()
}

fn path(n: usize) -> Vec<Vec<usize>> {
    (0..n)
        .map(|i| {
            let mut adj = Vec::new();
            if i > 0 {
                adj.push(i - 1);
            }
            if i + 1 < n {
                adj.push(i + 1);
            }
            adj
        })
        .collect()
}

fn torus(n: usize) -> Vec<Vec<usize>> {
    let side = (n as f64).sqrt().round() as usize;
    assert_eq!(side * side, n, "torus needs a perfect-square node count");
    let idx = |r: usize, c: usize| r * side + c;
    (0..n)
        .map(|i| {
            let (r, c) = (i / side, i % side);
            vec![
                idx((r + side - 1) % side, c),
                idx((r + 1) % side, c),
                idx(r, (c + side - 1) % side),
                idx(r, (c + 1) % side),
            ]
        })
        .collect()
}

fn hypercube(n: usize) -> Vec<Vec<usize>> {
    assert!(n.is_power_of_two(), "hypercube needs a power-of-two node count");
    let bits = n.trailing_zeros() as usize;
    (0..n)
        .map(|i| (0..bits).map(|b| i ^ (1 << b)).collect())
        .collect()
}

/// Random d-regular graph via the pairing (configuration) model with
/// rejection of self-loops/multi-edges; retries until simple + connected.
fn random_regular(n: usize, d: usize, seed: u64) -> Vec<Vec<usize>> {
    assert!(d < n, "degree must be < n");
    assert!(n * d % 2 == 0, "n*d must be even");
    let mut rng = Rng::new(seed ^ 0xDE6_u64);
    'outer: for _attempt in 0..1000 {
        let mut stubs: Vec<usize> = (0..n).flat_map(|i| std::iter::repeat(i).take(d)).collect();
        rng.shuffle(&mut stubs);
        let mut adj = vec![Vec::new(); n];
        for pair in stubs.chunks(2) {
            let (u, v) = (pair[0], pair[1]);
            if u == v || adj[u].contains(&v) {
                continue 'outer; // reject and retry
            }
            adj[u].push(v);
            adj[v].push(u);
        }
        let t = Topology {
            n,
            kind: TopologyKind::RandomRegular(d),
            neighbors: adj.clone(),
        };
        if t.is_connected() {
            return adj;
        }
    }
    panic!("failed to sample a connected {d}-regular graph on {n} nodes");
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn ring_structure() {
        let t = Topology::new(TopologyKind::Ring, 60, 0);
        assert!(t.is_connected());
        assert!(t.is_undirected());
        assert!(t.neighbors.iter().all(|a| a.len() == 2));
        assert_eq!(t.edge_count(), 60);
    }

    #[test]
    fn ring_small() {
        let t = Topology::new(TopologyKind::Ring, 2, 0);
        assert_eq!(t.neighbors, vec![vec![1], vec![0]]);
        let t1 = Topology::new(TopologyKind::Ring, 1, 0);
        assert_eq!(t1.neighbors, vec![Vec::<usize>::new()]);
    }

    #[test]
    fn complete_structure() {
        let t = Topology::new(TopologyKind::Complete, 8, 0);
        assert!(t.neighbors.iter().all(|a| a.len() == 7));
        assert_eq!(t.edge_count(), 28);
    }

    #[test]
    fn star_structure() {
        let t = Topology::new(TopologyKind::Star, 10, 0);
        assert_eq!(t.degree(0), 9);
        assert!((1..10).all(|i| t.degree(i) == 1));
        assert!(t.is_connected());
    }

    #[test]
    fn torus_structure() {
        let t = Topology::new(TopologyKind::Torus, 16, 0);
        assert!(t.is_connected() && t.is_undirected());
        assert!(t.neighbors.iter().all(|a| a.len() == 4));
    }

    #[test]
    fn hypercube_structure() {
        let t = Topology::new(TopologyKind::Hypercube, 16, 0);
        assert!(t.is_connected());
        assert!(t.neighbors.iter().all(|a| a.len() == 4));
    }

    #[test]
    fn random_regular_structure() {
        let t = Topology::new(TopologyKind::RandomRegular(4), 30, 7);
        assert!(t.is_connected());
        assert!(t.is_undirected());
        assert!(t.neighbors.iter().all(|a| a.len() == 4));
    }

    #[test]
    fn random_regular_deterministic() {
        let a = Topology::new(TopologyKind::RandomRegular(3), 20, 42);
        let b = Topology::new(TopologyKind::RandomRegular(3), 20, 42);
        assert_eq!(a.neighbors, b.neighbors);
    }

    #[test]
    fn parse_kinds() {
        assert_eq!(TopologyKind::parse("ring"), Some(TopologyKind::Ring));
        assert_eq!(
            TopologyKind::parse("regular4"),
            Some(TopologyKind::RandomRegular(4))
        );
        assert_eq!(TopologyKind::parse("nope"), None);
    }

    #[test]
    fn spec_str_inverts_parse() {
        for s in ["ring", "complete", "star", "path", "torus", "hypercube", "regular4"] {
            assert_eq!(TopologyKind::parse(s).unwrap().spec_str(), s);
        }
    }

    #[test]
    fn check_nodes_mirrors_constructor_asserts() {
        assert!(TopologyKind::Torus.check_nodes(16).is_ok());
        assert!(TopologyKind::Torus.check_nodes(15).is_err());
        assert!(TopologyKind::Hypercube.check_nodes(16).is_ok());
        assert!(TopologyKind::Hypercube.check_nodes(12).is_err());
        assert!(TopologyKind::RandomRegular(3).check_nodes(20).is_ok());
        assert!(TopologyKind::RandomRegular(3).check_nodes(5).is_err()); // n·d odd
        assert!(TopologyKind::RandomRegular(8).check_nodes(8).is_err()); // d >= n
        assert!(TopologyKind::Ring.check_nodes(0).is_err());
        assert!(TopologyKind::Ring.check_nodes(2).is_ok());
    }
}
