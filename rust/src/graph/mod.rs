//! Communication graph substrate: topologies, doubly-stochastic mixing
//! matrices, and spectral quantities (δ, β) used by the paper's
//! consensus-step-size formula (Lemma 6).

pub mod topology;
pub mod mixing;
pub mod spectral;
pub mod dynamic;

pub use dynamic::TopologySchedule;
pub use mixing::{metropolis_hastings, uniform_neighbor, MixingMatrix};
pub use spectral::SpectralInfo;
pub use topology::{Topology, TopologyKind};
