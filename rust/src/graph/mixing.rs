//! Doubly-stochastic mixing matrices W over a topology.
//!
//! Section 3 requires W symmetric, doubly stochastic, with spectral gap
//! δ = 1 − |λ₂| > 0 for any connected graph. Two standard constructions:
//!
//! * **Metropolis–Hastings**: w_ij = 1 / (1 + max(deg_i, deg_j)) for
//!   {i,j} ∈ E — always symmetric + doubly stochastic, degree-adaptive.
//! * **Uniform neighbor**: w_ij = 1/(Δ+1) with Δ the max degree (the
//!   classic "lazy uniform" gossip weights used for rings in the paper's
//!   experiments, e.g. 1/3 on a ring).

use super::topology::Topology;
use crate::linalg::Matrix;

/// A mixing matrix tied to its topology.
#[derive(Clone, Debug)]
pub struct MixingMatrix {
    pub w: Matrix,
    pub topology: Topology,
}

impl MixingMatrix {
    /// w_ij as f64.
    pub fn weight(&self, i: usize, j: usize) -> f64 {
        self.w[(i, j)]
    }

    pub fn n(&self) -> usize {
        self.topology.n
    }

    /// Validate paper Section 3 requirements; returns error description.
    pub fn validate(&self) -> Result<(), String> {
        if !self.w.is_symmetric(1e-9) {
            return Err("W is not symmetric".into());
        }
        if !self.w.is_doubly_stochastic(1e-9) {
            return Err("W is not doubly stochastic".into());
        }
        for i in 0..self.n() {
            for j in 0..self.n() {
                if i != j && self.w[(i, j)] > 0.0 && !self.topology.neighbors[i].contains(&j) {
                    return Err(format!("W has weight on non-edge ({i},{j})"));
                }
            }
        }
        Ok(())
    }
}

/// Metropolis–Hastings weights.
pub fn metropolis_hastings(topology: &Topology) -> MixingMatrix {
    let n = topology.n;
    let mut w = Matrix::zeros(n, n);
    for i in 0..n {
        for &j in &topology.neighbors[i] {
            let wij = 1.0 / (1.0 + topology.degree(i).max(topology.degree(j)) as f64);
            w[(i, j)] = wij;
        }
    }
    for i in 0..n {
        let off: f64 = (0..n).filter(|&j| j != i).map(|j| w[(i, j)]).sum();
        w[(i, i)] = 1.0 - off;
    }
    MixingMatrix {
        w,
        topology: topology.clone(),
    }
}

/// Uniform 1/(Δ+1) neighbor weights (self-weight absorbs the remainder).
pub fn uniform_neighbor(topology: &Topology) -> MixingMatrix {
    let n = topology.n;
    let delta = topology.max_degree();
    let share = 1.0 / (delta as f64 + 1.0);
    let mut w = Matrix::zeros(n, n);
    for i in 0..n {
        for &j in &topology.neighbors[i] {
            w[(i, j)] = share;
        }
        w[(i, i)] = 1.0 - topology.degree(i) as f64 * share;
    }
    MixingMatrix {
        w,
        topology: topology.clone(),
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::graph::topology::TopologyKind;

    fn check(kind: TopologyKind, n: usize) {
        let t = Topology::new(kind, n, 3);
        for mm in [metropolis_hastings(&t), uniform_neighbor(&t)] {
            mm.validate().unwrap();
        }
    }

    #[test]
    fn valid_on_all_topologies() {
        check(TopologyKind::Ring, 60);
        check(TopologyKind::Complete, 8);
        check(TopologyKind::Star, 9);
        check(TopologyKind::Path, 7);
        check(TopologyKind::Torus, 16);
        check(TopologyKind::Hypercube, 8);
        check(TopologyKind::RandomRegular(4), 20);
    }

    #[test]
    fn ring_uniform_is_one_third() {
        let t = Topology::new(TopologyKind::Ring, 10, 0);
        let mm = uniform_neighbor(&t);
        assert!((mm.weight(0, 1) - 1.0 / 3.0).abs() < 1e-12);
        assert!((mm.weight(0, 0) - 1.0 / 3.0).abs() < 1e-12);
        assert_eq!(mm.weight(0, 5), 0.0);
    }

    #[test]
    fn mh_complete_is_uniform() {
        let t = Topology::new(TopologyKind::Complete, 5, 0);
        let mm = metropolis_hastings(&t);
        for i in 0..5 {
            for j in 0..5 {
                assert!((mm.weight(i, j) - 0.2).abs() < 1e-12);
            }
        }
    }
}
