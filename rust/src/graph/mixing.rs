//! Doubly-stochastic mixing matrices W over a topology.
//!
//! Section 3 requires W symmetric, doubly stochastic, with spectral gap
//! δ = 1 − |λ₂| > 0 for any connected graph. Two standard constructions:
//!
//! * **Metropolis–Hastings**: w_ij = 1 / (1 + max(deg_i, deg_j)) for
//!   {i,j} ∈ E — always symmetric + doubly stochastic, degree-adaptive.
//! * **Uniform neighbor**: w_ij = 1/(Δ+1) with Δ the max degree (the
//!   classic "lazy uniform" gossip weights used for rings in the paper's
//!   experiments, e.g. 1/3 on a ring).
//!
//! Storage is sparse: per-row off-diagonal weights aligned with the
//! topology's (sorted) adjacency lists plus a diagonal vector — O(|E|)
//! instead of the former dense n×n `Matrix` (~128 MB at n = 4096), so
//! graph construction and every per-round consumer scale with edges.
//! The constructors reproduce the dense implementation bit-for-bit: the
//! dense row sums only ever added structural zeros outside the adjacency
//! list, and adding 0.0 to a finite positive f64 is exact, so summing
//! the stored weights in the same ascending-j order yields the identical
//! diagonal value at any n.

use super::topology::Topology;
use crate::linalg::Matrix;

/// A mixing matrix tied to its topology (sparse, edge-aligned storage).
#[derive(Clone, Debug)]
pub struct MixingMatrix {
    pub topology: Topology,
    /// Off-diagonal weights: `weights[i][k]` is w_ij for
    /// `j = topology.neighbors[i][k]` (adjacency lists are sorted).
    weights: Vec<Vec<f64>>,
    /// Self-weights w_ii.
    diag: Vec<f64>,
}

impl MixingMatrix {
    /// Assemble from edge-aligned parts. `weights` must parallel
    /// `topology.neighbors` row by row; `diag` holds the self-weights
    /// (callers compute it with their own association so construction
    /// stays bit-identical to whatever reference they mirror).
    pub fn from_parts(topology: Topology, weights: Vec<Vec<f64>>, diag: Vec<f64>) -> MixingMatrix {
        assert_eq!(weights.len(), topology.n, "weight row count");
        assert_eq!(diag.len(), topology.n, "diagonal length");
        for (i, row) in weights.iter().enumerate() {
            assert_eq!(
                row.len(),
                topology.neighbors[i].len(),
                "row {i} weight/adjacency mismatch"
            );
            debug_assert!(
                topology.neighbors[i].windows(2).all(|w| w[0] < w[1]),
                "row {i} adjacency must be sorted for weight lookups"
            );
        }
        MixingMatrix {
            topology,
            weights,
            diag,
        }
    }

    /// w_ij as f64 (binary search over the sorted adjacency row;
    /// structural zeros — non-edges — return 0.0).
    pub fn weight(&self, i: usize, j: usize) -> f64 {
        if i == j {
            return self.diag[i];
        }
        match self.topology.neighbors[i].binary_search(&j) {
            Ok(k) => self.weights[i][k],
            Err(_) => 0.0,
        }
    }

    /// w_ii without a search.
    #[inline]
    pub fn self_weight(&self, i: usize) -> f64 {
        self.diag[i]
    }

    /// Row i's off-diagonal entries as parallel (neighbor, weight)
    /// slices — the hot-loop accessor (no per-edge binary search).
    #[inline]
    pub fn row(&self, i: usize) -> (&[usize], &[f64]) {
        (&self.topology.neighbors[i], &self.weights[i])
    }

    pub fn n(&self) -> usize {
        self.topology.n
    }

    /// Number of stored off-diagonal weights (= Σ_i deg(i) = 2|E|) —
    /// exposed so tests can pin the O(|E|) storage invariant.
    pub fn stored_weights(&self) -> usize {
        self.weights.iter().map(Vec::len).sum()
    }

    /// y = W x with O(|E|) work (the sparse operator behind the
    /// iterative spectral path — `linalg::lanczos`).
    pub fn matvec_into(&self, x: &[f64], y: &mut [f64]) {
        let n = self.n();
        debug_assert_eq!(x.len(), n);
        debug_assert_eq!(y.len(), n);
        for i in 0..n {
            let mut acc = self.diag[i] * x[i];
            for (&j, &w) in self.topology.neighbors[i].iter().zip(self.weights[i].iter()) {
                acc += w * x[j];
            }
            y[i] = acc;
        }
    }

    /// Materialize the dense matrix (small-n eigen solves and tests
    /// only — never on a per-round path).
    pub fn to_dense(&self) -> Matrix {
        let n = self.n();
        let mut w = Matrix::zeros(n, n);
        for i in 0..n {
            w[(i, i)] = self.diag[i];
            for (&j, &wij) in self.topology.neighbors[i].iter().zip(self.weights[i].iter()) {
                w[(i, j)] = wij;
            }
        }
        w
    }

    /// Validate paper Section 3 requirements in O(|E| log deg); returns
    /// an error description. Weight on a non-edge is structurally
    /// impossible in the sparse representation, and symmetry plus unit
    /// row sums imply unit column sums.
    pub fn validate(&self) -> Result<(), String> {
        let tol = 1e-9;
        for i in 0..self.n() {
            let mut rsum = self.diag[i];
            if self.diag[i] < -tol {
                return Err(format!("negative self-weight at node {i}"));
            }
            for (&j, &wij) in self.topology.neighbors[i].iter().zip(self.weights[i].iter()) {
                if wij < -tol {
                    return Err(format!("negative weight on edge ({i},{j})"));
                }
                if (wij - self.weight(j, i)).abs() > tol {
                    return Err("W is not symmetric".into());
                }
                rsum += wij;
            }
            if (rsum - 1.0).abs() > tol {
                return Err(format!("row {i} sums to {rsum}, not 1"));
            }
        }
        Ok(())
    }
}

/// Metropolis–Hastings weights.
pub fn metropolis_hastings(topology: &Topology) -> MixingMatrix {
    let n = topology.n;
    let mut weights = Vec::with_capacity(n);
    let mut diag = vec![0.0; n];
    for i in 0..n {
        let row: Vec<f64> = topology.neighbors[i]
            .iter()
            .map(|&j| 1.0 / (1.0 + topology.degree(i).max(topology.degree(j)) as f64))
            .collect();
        // Ascending-j summation — the same nonzero terms in the same
        // order as the dense row sum, hence the identical f64 diagonal.
        let off: f64 = row.iter().sum();
        diag[i] = 1.0 - off;
        weights.push(row);
    }
    MixingMatrix::from_parts(topology.clone(), weights, diag)
}

/// Uniform 1/(Δ+1) neighbor weights (self-weight absorbs the remainder).
pub fn uniform_neighbor(topology: &Topology) -> MixingMatrix {
    let n = topology.n;
    let delta = topology.max_degree();
    let share = 1.0 / (delta as f64 + 1.0);
    let mut weights = Vec::with_capacity(n);
    let mut diag = vec![0.0; n];
    for i in 0..n {
        let deg = topology.degree(i);
        weights.push(vec![share; deg]);
        diag[i] = 1.0 - deg as f64 * share;
    }
    MixingMatrix::from_parts(topology.clone(), weights, diag)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::graph::topology::TopologyKind;

    fn check(kind: TopologyKind, n: usize) {
        let t = Topology::new(kind, n, 3);
        for mm in [metropolis_hastings(&t), uniform_neighbor(&t)] {
            mm.validate().unwrap();
        }
    }

    #[test]
    fn valid_on_all_topologies() {
        check(TopologyKind::Ring, 60);
        check(TopologyKind::Complete, 8);
        check(TopologyKind::Star, 9);
        check(TopologyKind::Path, 7);
        check(TopologyKind::Torus, 16);
        check(TopologyKind::Hypercube, 8);
        check(TopologyKind::RandomRegular(4), 20);
    }

    #[test]
    fn ring_uniform_is_one_third() {
        let t = Topology::new(TopologyKind::Ring, 10, 0);
        let mm = uniform_neighbor(&t);
        assert!((mm.weight(0, 1) - 1.0 / 3.0).abs() < 1e-12);
        assert!((mm.weight(0, 0) - 1.0 / 3.0).abs() < 1e-12);
        assert_eq!(mm.weight(0, 5), 0.0);
    }

    #[test]
    fn mh_complete_is_uniform() {
        let t = Topology::new(TopologyKind::Complete, 5, 0);
        let mm = metropolis_hastings(&t);
        for i in 0..5 {
            for j in 0..5 {
                assert!((mm.weight(i, j) - 0.2).abs() < 1e-12);
            }
        }
    }

    #[test]
    fn storage_is_edge_proportional() {
        let t = Topology::new(TopologyKind::Ring, 4096, 0);
        let mm = uniform_neighbor(&t);
        assert_eq!(mm.stored_weights(), 2 * t.edge_count());
        assert_eq!(mm.stored_weights(), 2 * 4096);
    }

    #[test]
    fn to_dense_round_trips_and_matvec_agrees() {
        let t = Topology::new(TopologyKind::Torus, 16, 0);
        let mm = metropolis_hastings(&t);
        let dense = mm.to_dense();
        for i in 0..16 {
            for j in 0..16 {
                assert_eq!(dense[(i, j)], mm.weight(i, j), "({i},{j})");
            }
        }
        let x: Vec<f64> = (0..16).map(|k| (k as f64 * 0.37).sin()).collect();
        let mut y = vec![0.0; 16];
        mm.matvec_into(&x, &mut y);
        let dense_y = dense.matvec(&x);
        for (a, b) in y.iter().zip(dense_y.iter()) {
            assert!((a - b).abs() < 1e-12);
        }
    }

    #[test]
    fn validate_rejects_asymmetric_and_bad_row_sums() {
        let t = Topology::new(TopologyKind::Path, 3, 0);
        // Path 0-1-2: perturb one directed weight ⇒ asymmetric.
        let weights = vec![vec![0.4], vec![0.3, 0.3], vec![0.3]];
        let diag = vec![0.6, 0.4, 0.7];
        let mm = MixingMatrix::from_parts(t.clone(), weights, diag);
        assert!(mm.validate().unwrap_err().contains("symmetric"));
        // Row sum off by 0.1.
        let weights = vec![vec![0.3], vec![0.3, 0.3], vec![0.3]];
        let diag = vec![0.6, 0.4, 0.7];
        let mm = MixingMatrix::from_parts(t, weights, diag);
        assert!(mm.validate().unwrap_err().contains("sums to"));
    }
}
