//! Time-varying communication topologies.
//!
//! The seed engine fixed one mixing matrix for the whole run. Real
//! decentralized deployments change topology mid-training — machines
//! join racks, gossip protocols sample a few edges per round — and the
//! theory (e.g. B-connected time-varying graphs in the decentralized-SGD
//! literature) covers exactly these schedules. A [`TopologySchedule`]
//! tells the engine which mixing matrix is in force at each sync index:
//!
//! * `static` — today's behavior: the `topology` config field, never
//!   changed (zero overhead on the step loop).
//! * `switch:K1,K2,...:P` — cycle through topology kinds, switching every
//!   P iterations (e.g. `switch:ring,torus:500`). The run starts on K1.
//! * `sample:BASE:M` — randomized gossip: each sync round activates M
//!   edges sampled uniformly (seeded, per-round) from the BASE graph;
//!   consensus runs on the sampled subgraph only. Individual rounds may
//!   be disconnected — mixing happens across rounds, as in asynchronous
//!   gossip analyses.
//!
//! On every switch the engine swaps its mixing matrix and asks the
//! update rule to rebuild topology-derived state (the consensus
//! [`NeighborAccumulator`](crate::coordinator::NeighborAccumulator) is
//! reconstructed from the current estimate bank in one dense pass —
//! incremental maintenance then resumes on the new edge set). For
//! estimate-tracking rules the rebuild is *charged*: each node
//! broadcasts its full-precision x̂ to its new neighborhood (32·d per
//! copy), since a freshly-wired neighbor has no other way to obtain the
//! estimate it is about to track. That makes `switch` cheap (one resync
//! per phase boundary) but `sample` expensive under estimate tracking
//! (a resync every sync round) — per-round sampled gossip pairs
//! naturally with the exact-averaging rule, which re-broadcasts its
//! state anyway and needs no resync. The consensus step size γ is tuned
//! once against the *initial* matrix (kinds[0] / the full BASE graph);
//! pass an explicit γ to override.
//!
//! Sampling is seeded and stateless in `t` (a fresh `Rng` is derived per
//! round from `(seed, t)`), so schedules replay bit-for-bit and never
//! interact with node RNG streams or worker counts.

use super::mixing::{uniform_neighbor, MixingMatrix};
use super::topology::{Topology, TopologyKind};
use crate::util::rng::{splitmix64, Rng};

#[derive(Clone, Debug)]
enum ScheduleKind {
    Static,
    Switch {
        kinds: Vec<TopologyKind>,
        period: u64,
    },
    EdgeSample {
        base: Topology,
        /// Undirected edge list (i < j) of the base graph.
        edges: Vec<(usize, usize)>,
        /// Edges activated per sync round.
        m: usize,
    },
}

/// A schedule of mixing matrices over iterations (see module docs).
#[derive(Clone, Debug)]
pub struct TopologySchedule {
    kind: ScheduleKind,
    n: usize,
    seed: u64,
    /// Index of the currently-installed phase (switch schedules only).
    current: usize,
}

impl TopologySchedule {
    /// The no-op schedule (today's fixed-topology behavior).
    pub fn fixed() -> TopologySchedule {
        TopologySchedule {
            kind: ScheduleKind::Static,
            n: 0,
            seed: 0,
            current: 0,
        }
    }

    /// Parse a schedule spec for an n-node run: `static`,
    /// `switch:K1,K2,...:P`, or `sample:BASE:M`. The grammar lives in
    /// [`crate::config::ScheduleSpec`] (the typed config surface); this
    /// wrapper adds the n-dependent construction and validation.
    pub fn parse(spec: &str, n: usize, seed: u64) -> Result<TopologySchedule, String> {
        let parsed: crate::config::ScheduleSpec = spec.parse().map_err(|e| {
            // Strip the ConfigError framing back down to the bare reason
            // string this API always returned.
            match e {
                crate::config::ConfigError::Value { reason, .. } => reason,
                other => other.to_string(),
            }
        })?;
        Self::from_spec(&parsed, n, seed)
    }

    /// Build the replayable schedule from a validated
    /// [`ScheduleSpec`](crate::config::ScheduleSpec) for an n-node run
    /// (checks the n-dependent constraints: the base graph must be
    /// constructible and must have at least M edges).
    pub fn from_spec(
        spec: &crate::config::ScheduleSpec,
        n: usize,
        seed: u64,
    ) -> Result<TopologySchedule, String> {
        use crate::config::ScheduleKindSpec;
        let kind = match spec.kind() {
            ScheduleKindSpec::Static => return Ok(TopologySchedule::fixed()),
            ScheduleKindSpec::Switch { kinds, period } => {
                for k in kinds {
                    k.check_nodes(n)
                        .map_err(|e| format!("switch topology {:?}: {e}", k.spec_str()))?;
                }
                ScheduleKind::Switch {
                    kinds: kinds.clone(),
                    period: *period,
                }
            }
            ScheduleKindSpec::Sample { base: base_kind, m } => {
                base_kind
                    .check_nodes(n)
                    .map_err(|e| format!("sample base {:?}: {e}", base_kind.spec_str()))?;
                let base = Topology::new(*base_kind, n, seed);
                let mut edges = Vec::new();
                for (i, adj) in base.neighbors.iter().enumerate() {
                    for &j in adj {
                        if i < j {
                            edges.push((i, j));
                        }
                    }
                }
                if *m > edges.len() {
                    return Err(format!(
                        "sample asks for {m} edges per round but the base graph has \
                         only {}",
                        edges.len()
                    ));
                }
                ScheduleKind::EdgeSample {
                    base,
                    edges,
                    m: *m,
                }
            }
        };
        Ok(TopologySchedule {
            kind,
            n,
            seed,
            current: 0,
        })
    }

    /// True for the fixed (seed-equivalent) schedule.
    pub fn is_static(&self) -> bool {
        matches!(self.kind, ScheduleKind::Static)
    }

    /// The mixing matrix the run must *start* on (`None` ⇒ whatever the
    /// static config builds). For `switch` this is kinds[0]; for `sample`
    /// it is the full base graph, so spectral tuning sees the long-run
    /// connectivity.
    pub fn initial_mixing(&self) -> Option<MixingMatrix> {
        match &self.kind {
            ScheduleKind::Static => None,
            ScheduleKind::Switch { kinds, .. } => {
                Some(uniform_neighbor(&Topology::new(kinds[0], self.n, self.seed)))
            }
            ScheduleKind::EdgeSample { base, .. } => Some(uniform_neighbor(base)),
        }
    }

    /// Called by the engine at each sync index: returns the new mixing
    /// matrix when the topology changes at iteration t, `None` when the
    /// installed one stays in force.
    pub fn update(&mut self, t: u64) -> Option<MixingMatrix> {
        match &self.kind {
            ScheduleKind::Static => None,
            ScheduleKind::Switch { kinds, period } => {
                let idx = ((t / period) % kinds.len() as u64) as usize;
                if idx == self.current {
                    return None;
                }
                self.current = idx;
                Some(uniform_neighbor(&Topology::new(kinds[idx], self.n, self.seed)))
            }
            ScheduleKind::EdgeSample { base, edges, m } => {
                let mut s = self
                    .seed
                    .wrapping_add(t.wrapping_mul(0x9E37_79B9_7F4A_7C15))
                    ^ 0x5A4D_7019_C3E8_2B61;
                let mut rng = Rng::new(splitmix64(&mut s));
                let chosen = rng.sample_indices(edges.len(), *m);
                let mut neighbors: Vec<Vec<usize>> = vec![Vec::new(); self.n];
                for &e in &chosen {
                    let (i, j) = edges[e];
                    neighbors[i].push(j);
                    neighbors[j].push(i);
                }
                for adj in neighbors.iter_mut() {
                    adj.sort_unstable();
                }
                Some(uniform_neighbor(&Topology {
                    n: self.n,
                    kind: base.kind,
                    neighbors,
                }))
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn static_never_updates() {
        let mut s = TopologySchedule::parse("static", 8, 1).unwrap();
        assert!(s.is_static());
        assert!(s.initial_mixing().is_none());
        assert!((0..100).all(|t| s.update(t).is_none()));
    }

    #[test]
    fn switch_changes_at_period_boundaries() {
        let mut s = TopologySchedule::parse("switch:ring,torus:500", 16, 3).unwrap();
        assert!(!s.is_static());
        // starts on ring (degree 2 everywhere)
        let init = s.initial_mixing().unwrap();
        assert!(init.topology.neighbors.iter().all(|a| a.len() == 2));
        // stays on ring through the first phase
        assert!(s.update(0).is_none());
        assert!(s.update(499).is_none());
        // switches to torus (degree 4) at t = 500
        let m = s.update(500).expect("switch at t=500");
        assert!(m.topology.neighbors.iter().all(|a| a.len() == 4));
        m.validate().unwrap();
        assert!(s.update(700).is_none());
        // cycles back to ring at t = 1000
        let m = s.update(1000).expect("switch back at t=1000");
        assert!(m.topology.neighbors.iter().all(|a| a.len() == 2));
    }

    #[test]
    fn edge_sample_is_seeded_and_per_round() {
        let mut a = TopologySchedule::parse("sample:complete:4", 8, 7).unwrap();
        let mut b = TopologySchedule::parse("sample:complete:4", 8, 7).unwrap();
        let ma = a.update(13).unwrap();
        let mb = b.update(13).unwrap();
        assert_eq!(ma.topology.neighbors, mb.topology.neighbors);
        ma.validate().unwrap();
        // exactly 4 undirected edges activated
        let deg_sum: usize = ma.topology.neighbors.iter().map(Vec::len).sum();
        assert_eq!(deg_sum, 8);
        // a different round samples a different subgraph (w.h.p.)
        let mc = a.update(14).unwrap();
        assert_ne!(ma.topology.neighbors, mc.topology.neighbors);
        // full base graph as the initial (tuning) matrix
        let init = a.initial_mixing().unwrap();
        assert_eq!(init.topology.edge_count(), 28);
    }

    #[test]
    fn parse_rejects_bad_specs() {
        assert!(TopologySchedule::parse("switch:ring", 8, 0).is_err());
        assert!(TopologySchedule::parse("switch:ring,wat:10", 8, 0).is_err());
        assert!(TopologySchedule::parse("switch:ring,torus:0", 16, 0).is_err());
        assert!(TopologySchedule::parse("sample:ring:0", 8, 0).is_err());
        // an 8-node ring has 8 edges — asking for more is an error, not a clamp
        assert!(TopologySchedule::parse("sample:ring:9", 8, 0).is_err());
        assert!(TopologySchedule::parse("sample:ring:8", 8, 0).is_ok());
        assert!(TopologySchedule::parse("carousel:ring:5", 8, 0).is_err());
    }
}
