//! Metrics: per-round records and CSV/JSONL sinks.
//!
//! The experiment drivers log one [`RoundRecord`] per evaluation interval;
//! the figures' axes (test error vs comm rounds, vs cumulative bits) are
//! projections of these records.

use std::fmt::Write as _;
use std::fs::File;
use std::io::{BufWriter, Write};
use std::path::Path;

use crate::util::json::Json;

/// One evaluated point of a training run.
#[derive(Clone, Debug, PartialEq)]
pub struct RoundRecord {
    /// Iteration t.
    pub t: u64,
    /// Global objective f(x̄).
    pub loss: f64,
    /// Test error in [0,1] (NaN if the problem has none).
    pub test_error: f64,
    /// f(x̄) − f* if the optimum is known (NaN otherwise).
    pub opt_gap: f64,
    /// Cumulative bits transmitted so far.
    pub bits: u64,
    /// Cumulative communication rounds so far.
    pub comm_rounds: u64,
    /// Σ_i ‖x_i − x̄‖² at this point.
    pub consensus: f64,
    /// Nodes that fired the trigger at the last sync round.
    pub fired: usize,
}

impl RoundRecord {
    pub fn csv_header() -> &'static str {
        "t,loss,test_error,opt_gap,bits,comm_rounds,consensus,fired"
    }

    pub fn to_csv(&self) -> String {
        format!(
            "{},{:.6e},{:.6},{:.6e},{},{},{:.6e},{}",
            self.t,
            self.loss,
            self.test_error,
            self.opt_gap,
            self.bits,
            self.comm_rounds,
            self.consensus,
            self.fired
        )
    }

    pub fn to_json(&self) -> Json {
        Json::obj()
            .set("t", self.t)
            .set("loss", self.loss)
            .set("test_error", self.test_error)
            .set("opt_gap", self.opt_gap)
            .set("bits", self.bits)
            .set("comm_rounds", self.comm_rounds)
            .set("consensus", self.consensus)
            .set("fired", self.fired)
    }
}

/// A labelled series of records (one algorithm's curve).
#[derive(Clone, Debug, Default)]
pub struct Series {
    pub label: String,
    pub records: Vec<RoundRecord>,
}

impl Series {
    pub fn new(label: impl Into<String>) -> Series {
        Series {
            label: label.into(),
            records: Vec::new(),
        }
    }

    pub fn push(&mut self, r: RoundRecord) {
        self.records.push(r);
    }

    /// First record reaching `test_error <= target`, if any.
    pub fn first_reaching_error(&self, target: f64) -> Option<&RoundRecord> {
        self.records.iter().find(|r| r.test_error <= target)
    }

    /// First record reaching `loss <= target`, if any.
    pub fn first_reaching_loss(&self, target: f64) -> Option<&RoundRecord> {
        self.records.iter().find(|r| r.loss <= target)
    }

    /// Render as CSV text.
    pub fn to_csv(&self) -> String {
        let mut s = String::new();
        let _ = writeln!(s, "# series: {}", self.label);
        let _ = writeln!(s, "{}", RoundRecord::csv_header());
        for r in &self.records {
            let _ = writeln!(s, "{}", r.to_csv());
        }
        s
    }

    pub fn write_csv(&self, path: &Path) -> std::io::Result<()> {
        let mut f = BufWriter::new(File::create(path)?);
        f.write_all(self.to_csv().as_bytes())
    }

    pub fn write_jsonl(&self, path: &Path) -> std::io::Result<()> {
        let mut f = BufWriter::new(File::create(path)?);
        for r in &self.records {
            writeln!(f, "{}", r.to_json().to_string())?;
        }
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn rec(t: u64, err: f64, bits: u64) -> RoundRecord {
        RoundRecord {
            t,
            loss: err * 2.0,
            test_error: err,
            opt_gap: f64::NAN,
            bits,
            comm_rounds: t,
            consensus: 0.0,
            fired: 1,
        }
    }

    #[test]
    fn first_reaching() {
        let mut s = Series::new("x");
        s.push(rec(0, 0.9, 10));
        s.push(rec(10, 0.5, 20));
        s.push(rec(20, 0.2, 30));
        assert_eq!(s.first_reaching_error(0.5).unwrap().t, 10);
        assert_eq!(s.first_reaching_error(0.1), None);
    }

    #[test]
    fn csv_roundtrip_fields() {
        let r = rec(5, 0.25, 100);
        let line = r.to_csv();
        let fields: Vec<&str> = line.split(',').collect();
        assert_eq!(fields.len(), 8);
        assert_eq!(fields[0], "5");
        assert_eq!(fields[4], "100");
    }

    #[test]
    fn jsonl_is_valid_json() {
        let r = rec(3, 0.4, 77);
        let j = r.to_json();
        let parsed = crate::util::json::Json::parse(&j.to_string()).unwrap();
        assert_eq!(parsed.get("bits").unwrap().as_usize(), Some(77));
    }
}
